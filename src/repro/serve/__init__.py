"""Prediction-as-a-service: an async serving subsystem over compiled
plans.

This is the long-running counterpart to ``api.compile``: a
single-process asyncio server (stdlib only) that keeps compiled plans
hot across requests instead of paying trace/pack/jit per caller.  The
paper's model answers "what bandwidth does each kernel get?" from just
``(f, b_s)`` per kernel (Eqs. 1–5), which makes prediction cheap enough
to serve interactively — the serving layers make it cheap enough to
serve *concurrently*:

* **plan cache** (:mod:`repro.serve.cache`) — compiled plans keyed by
  scenario *structure* (:func:`repro.api.structure_key`) and
  power-of-two batch bucket (the substrate's :func:`repro.core.
  backend.bucket` policy), with LRU eviction, warmup, and per-key
  hit/miss stats in the ``repro.obs`` metrics registry
  (``serve.plan.*``; ``backend.cache_stats(scope="plan")``).
* **request coalescer** (:mod:`repro.serve.coalesce`) — concurrent
  requests arriving within one tick pack into a single batched
  ``plan.run()`` and fan back out per request, with admission control
  (queue bound → 429, per-request deadline → 504) and graceful drain.
* **transport** (:mod:`repro.serve.http`) — ndjson-over-HTTP via an
  asyncio server: ``python -m repro.serve --port 8787``, with
  ``/healthz`` and ``/statsz``.  The cache + coalescer core is
  importable and testable without sockets.

Not to be confused with :mod:`repro.launch.serve`, which is the *model
inference* demo (transformer decode-loop latency on the TPU overlap
model).  ``python -m repro.serve`` starts this subsystem — the
prediction service over the paper's bandwidth-sharing model;
``examples/serve_decode.py`` drives the decode demo.

See ``docs/serving.md`` for the architecture, request schema, and a
Perfetto walkthrough of a traced request.
"""

from .cache import PlanCache, plan_cache_stats
from .coalesce import (BadRequest, Coalescer, DeadlineExceeded, Draining,
                       QueueFull, ServeConfig, ServeError)
from .http import App
from .protocol import build_response, error_response, parse_request

__all__ = [
    "App", "Coalescer", "PlanCache", "ServeConfig",
    "ServeError", "BadRequest", "QueueFull", "Draining",
    "DeadlineExceeded",
    "parse_request", "build_response", "error_response",
    "plan_cache_stats",
]
