"""HLO-level analysis: collective-traffic extraction and roofline terms.

``compiled.cost_analysis()`` reports flops and HBM bytes but *not* collective
traffic, so we parse the (optimized) HLO text and account every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Two byte accountings are produced per collective:
  * ``operand_bytes`` — the plain sum of operand tensor sizes (the
    specification-level number), and
  * ``wire_bytes``    — per-device link traffic under a ring/bidirectional
    schedule (all-gather: out·(G−1)/G; reduce-scatter: in·(G−1)/G;
    all-reduce: 2·in·(G−1)/G; all-to-all: in·(G−1)/G; permute: in),
which is what the collective roofline term should charge against ICI.
"""

from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Iterable

from .machine import TPU_V5E, TpuModel

#: Storage width in *bits* per HLO element type — bits, not bytes, so
#: the sub-byte types (s4/u4, the 4-bit floats) size correctly.  XLA
#: packs them two-per-byte in dense buffers.
_DTYPE_BITS = {
    "s4": 4, "u4": 4, "f4e2m1fn": 4,
    "pred": 8, "s8": 8, "u8": 8,
    "f8e4m3fn": 8, "f8e5m2": 8, "f8e4m3": 8, "f8e3m4": 8,
    "f8e4m3fnuz": 8, "f8e5m2fnuz": 8, "f8e4m3b11fnuz": 8, "f8e8m0fnu": 8,
    "s16": 16, "u16": 16, "f16": 16, "bf16": 16,
    "s32": 32, "u32": 32, "f32": 32, "tf32": 32,
    "s64": 64, "u64": 64, "f64": 64, "c64": 64, "c128": 128,
}

#: Byte view kept for callers that reason in whole bytes (sub-byte
#: types round up to 1 here; traffic math should use _DTYPE_BITS).
_DTYPE_BYTES = {k: max(1, v // 8) for k, v in _DTYPE_BITS.items()}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  f32[256,1024]{1,0}  or bf16[8,128] or f32[] (scalar)
_SHAPE_RE = re.compile(r"\b([a-z]{1,4}\d*[a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    """Dense-buffer bytes of one ``dtype[dims]`` HLO shape.

    Unknown element types raise (with the nearest known name) instead
    of silently contributing 0 bytes — a new XLA dtype slipping through
    would undercount every collective it appears in.
    """
    bits = _DTYPE_BITS.get(dtype)
    if bits is None:
        import difflib
        near = difflib.get_close_matches(dtype, _DTYPE_BITS, n=1,
                                         cutoff=0.5)
        hint = f"; did you mean {near[0]!r}?" if near else ""
        raise ValueError(
            f"unknown HLO element type {dtype!r} in shape "
            f"{dtype}[{dims}]{hint} (known types: "
            f"{sorted(_DTYPE_BITS)}) — add it to "
            f"repro.core.hlo._DTYPE_BITS with its storage width")
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return (n * bits + 7) // 8


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota v2 form: [num_groups,group_size]
        return max(1, int(m.group(2)))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return max(1, len(first.split(",")))
    return default


@dataclasses.dataclass(frozen=True)
class CollectiveStats:
    counts: dict[str, int]
    operand_bytes: dict[str, int]
    wire_bytes: dict[str, float]

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def collective_stats(hlo_text: str, *, default_group: int = 1
                     ) -> CollectiveStats:
    """Scan HLO text and accumulate collective traffic per op kind."""
    counts: Counter[str] = Counter()
    op_bytes: Counter[str] = Counter()
    wire: Counter[str] = Counter()

    for line in hlo_text.splitlines():
        s = line.strip()
        # Match instruction lines: "%name = <shape> <op>(" or fusion-root
        # "<shape> <op>(".  Skip "-start/-done" duplicates (count -start).
        op = None
        for cand in _COLLECTIVES:
            if re.search(rf"[=)\s]\s*{cand}(-start)?\(", s):
                if f"{cand}-done" in s:
                    op = None
                else:
                    op = cand
                break
        if op is None:
            continue
        shapes = _SHAPE_RE.findall(s)
        if not shapes:
            continue
        # First shape token is the result (possibly a tuple element); operand
        # shapes follow inside the parens.  Heuristic: result = first, operands
        # = shapes appearing after the op name.
        opidx = s.find(op + "(")
        if opidx < 0:
            opidx = s.find(op + "-start(")
        head = s[:opidx]
        res_shapes = _SHAPE_RE.findall(head)
        operand_shapes = _SHAPE_RE.findall(s[opidx:])
        result_b = sum(_shape_bytes(d, dims) for d, dims in res_shapes)
        operand_b = sum(_shape_bytes(d, dims) for d, dims in operand_shapes)
        g = _group_size(s, default_group)
        ring = (g - 1) / g if g > 1 else 0.0

        counts[op] += 1
        op_bytes[op] += operand_b
        if op == "all-gather":
            wire[op] += result_b * ring
        elif op == "reduce-scatter":
            wire[op] += operand_b * ring
        elif op == "all-reduce":
            wire[op] += 2.0 * operand_b * ring
        elif op == "all-to-all":
            wire[op] += operand_b * ring
        else:  # collective-permute
            wire[op] += operand_b

    return CollectiveStats(counts=dict(counts), operand_bytes=dict(op_bytes),
                           wire_bytes=dict(wire))


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """The three roofline terms (seconds) for one compiled step on one chip."""

    name: str
    t_compute: float
    t_memory: float
    t_collective: float
    flops: float              # HLO flops per chip
    hbm_bytes: float          # HLO bytes per chip
    wire_bytes: float         # collective bytes per chip
    model_flops: float = 0.0  # 6·N·D-style useful flops per chip

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the bound: how close the step is to the
        hardware roofline if perfectly overlapped."""
        if self.t_bound <= 0 or self.model_flops <= 0:
            return 0.0
        t_useful = self.t_compute * (
            self.model_flops / self.flops if self.flops else 0.0)
        return t_useful / self.t_bound

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0


def roofline_terms(name: str, cost: dict, stats: CollectiveStats,
                   *, n_chips: int, model_flops_total: float = 0.0,
                   tpu: TpuModel = TPU_V5E) -> RooflineTerms:
    """Build the three-term roofline from ``compiled.cost_analysis()`` plus
    collective stats.  The compiled module is the SPMD per-device program,
    so cost_analysis flops/bytes and HLO collective bytes are PER-DEVICE
    already; only ``model_flops_total`` (a global figure) is divided down.
    """
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    wire = stats.total_wire_bytes  # already per-device (HLO is SPMD)
    return RooflineTerms(
        name=name,
        t_compute=flops / tpu.peak_flops_bf16,
        t_memory=hbm / (tpu.hbm_bw_gbs * 1e9),
        t_collective=wire / (tpu.ici_links * tpu.ici_link_gbs * 1e9),
        flops=flops, hbm_bytes=hbm, wire_bytes=wire,
        model_flops=model_flops_total / n_chips,
    )
