"""Contention-domain topology engine (core/topology.py).

Covers the PR acceptance scenario: two groups pinned to different domains
are solved independently — each domain's prediction equals what that
group would attain alone on its own domain.
"""

import pytest

from repro.core import sharing, table2, topology
from repro.core.sharing import Group
from repro.core.topology import (ContentionDomain, Placed, Topology,
                                 TopologyNode, multi_socket, predict_placed,
                                 predict_single_domain, preset,
                                 single_domain, spread_counts, tpu_pod)


def _clx_groups():
    dcopy = table2.kernel("DCOPY")
    ddot2 = table2.kernel("DDOT2")
    return Group.of(dcopy, "CLX", 10), Group.of(ddot2, "CLX", 10)


# ---------------------------------------------------------------------------
# Tree structure
# ---------------------------------------------------------------------------


def test_presets_exist_and_leaf_counts():
    assert preset("CLX").domains[0].n_cores == 20
    assert len(preset("CLX-2S").domains) == 2
    assert len(preset("ROME-2S-NPS4").domains) == 8
    assert preset("ROME-2S-NPS4").total_cores == 64
    assert len(preset("TPUv5e-pod4").domains) == 4
    with pytest.raises(KeyError, match="unknown topology preset"):
        preset("KNL")


def test_domain_lookup():
    topo = multi_socket(topology.BDW1, 2)
    assert topo.domain("BDW-1/s1/d0").machine is topology.BDW1
    assert "BDW-1/s0/d0" in topo
    assert "BDW-1/s9/d0" not in topo
    with pytest.raises(KeyError, match="no contention domain"):
        topo.domain("nope")


def test_nested_tree_flattens_depth_first():
    inner = TopologyNode("pkg", (ContentionDomain("a", 4),
                                 ContentionDomain("b", 4)))
    root = TopologyNode("node", (inner, ContentionDomain("c", 8)))
    topo = Topology(root)
    assert topo.domain_names == ("a", "b", "c")
    assert topo.total_cores == 16


# ---------------------------------------------------------------------------
# Placement solves
# ---------------------------------------------------------------------------


def test_two_domains_predict_independently():
    """PR acceptance: groups pinned to different domains each see an
    uncontended domain — identical to running each alone."""
    g1, g2 = _clx_groups()
    topo = preset("CLX-2S")
    pred = predict_placed(topo, [Placed(g1, "CLX/s0/d0"),
                                 Placed(g2, "CLX/s1/d0")])
    solo1 = sharing.predict([g1])
    solo2 = sharing.predict([g2])
    assert pred.bw_group[0] == pytest.approx(solo1.bw_group[0], rel=1e-12)
    assert pred.bw_group[1] == pytest.approx(solo2.bw_group[0], rel=1e-12)
    assert pred.domain_bw("CLX/s0/d0") == pytest.approx(
        solo1.total_bw, rel=1e-12)
    assert pred.total_bw == pytest.approx(
        solo1.total_bw + solo2.total_bw, rel=1e-12)


def test_same_domain_reproduces_single_domain_model():
    """Both groups on one leaf == the paper's single-domain prediction."""
    g1, g2 = _clx_groups()
    topo = preset("CLX-2S")
    pred = predict_placed(topo, [Placed(g1, "CLX/s0/d0"),
                                 Placed(g2, "CLX/s0/d0")], strict=False)
    ref = sharing.predict([g1, g2])
    assert pred.bw_group == pytest.approx(ref.bw_group, rel=1e-12)
    assert pred.by_domain["CLX/s0/d0"].b_overlap == pytest.approx(
        ref.b_overlap, rel=1e-12)
    # The second socket is idle.
    assert pred.domain_bw("CLX/s1/d0") == 0.0


def test_cross_domain_no_interference():
    """Adding load on domain B never changes domain A's shares."""
    g1, g2 = _clx_groups()
    hog = Group(n=20, f=0.9, bs=50.0, name="hog")
    topo = preset("CLX-2S")
    alone = predict_placed(topo, [Placed(g1, "CLX/s0/d0"),
                                  Placed(g2, "CLX/s0/d0")], strict=False)
    loaded = predict_placed(topo, [Placed(g1, "CLX/s0/d0"),
                                   Placed(g2, "CLX/s0/d0"),
                                   Placed(hog, "CLX/s1/d0")], strict=False)
    assert loaded.bw_group[:2] == pytest.approx(alone.bw_group, rel=1e-12)


def test_input_order_preserved_across_domains():
    """bw_group follows placement order even when domains interleave."""
    gs = [Group(n=2, f=0.3, bs=100.0, name=f"g{i}") for i in range(4)]
    topo = multi_socket(topology.BDW1, 2)
    doms = ["BDW-1/s0/d0", "BDW-1/s1/d0", "BDW-1/s0/d0", "BDW-1/s1/d0"]
    pred = predict_placed(topo, [Placed(g, d) for g, d in zip(gs, doms)])
    for i, (g, d) in enumerate(zip(gs, doms)):
        dom_pred = pred.by_domain[d]
        assert any(pred.bw_group[i] == pytest.approx(b)
                   for b in dom_pred.bw_group)
        assert pred.bw_per_core[i] == pytest.approx(
            pred.bw_group[i] / g.n)


def test_strict_capacity_and_unknown_domain():
    topo = single_domain(topology.CLX)
    big = Group(n=25, f=0.2, bs=100.0)
    with pytest.raises(ValueError, match="overcommitted"):
        predict_placed(topo, [Placed(big, "CLX/d0")])
    # strict=False allows oversubscription (SMT-style experiments).
    pred = predict_placed(topo, [Placed(big, "CLX/d0")], strict=False)
    assert pred.total_bw > 0
    with pytest.raises(KeyError, match="unknown domain"):
        predict_placed(topo, [Placed(big, "CLX/d7")])


def test_empty_placement_and_idle_domains():
    topo = preset("ROME-2S-NPS4")
    pred = predict_placed(topo, [])
    assert pred.total_bw == 0.0
    assert all(pred.by_domain[d].bw_group == () for d in topo.domain_names)


def test_single_domain_wrapper_equivalence():
    """predict_single_domain is a faithful wrapper of sharing.predict."""
    g1, g2 = _clx_groups()
    for kwargs in ({}, {"utilization": "queue"}, {"saturated": True}):
        ref = sharing.predict([g1, g2], **kwargs)
        wrap = predict_single_domain([g1, g2], **kwargs)
        assert wrap.bw_group == pytest.approx(ref.bw_group, rel=1e-12)
        assert wrap.alphas == pytest.approx(ref.alphas, rel=1e-12)
        assert wrap.b_overlap == pytest.approx(ref.b_overlap, rel=1e-12)


def test_solver_kwargs_forwarded():
    g1, g2 = _clx_groups()
    topo = single_domain(topology.CLX)
    placements = [Placed(g1, "CLX/d0"), Placed(g2, "CLX/d0")]
    sat = predict_placed(topo, placements, saturated=True)
    ref = sharing.predict([g1, g2], saturated=True)
    assert sat.bw_group == pytest.approx(ref.bw_group, rel=1e-12)


def test_spread_counts():
    assert spread_counts(10, 4) == (3, 3, 2, 2)
    assert spread_counts(8, 2) == (4, 4)
    assert sum(spread_counts(37, 8)) == 37


def test_tpu_pod_domains():
    topo = tpu_pod(n_chips=2, streams_per_chip=4)
    assert topo.domain_names == ("TPUv5e/chip0", "TPUv5e/chip1")
    d = topo.domain("TPUv5e/chip0")
    assert d.n_cores == 4
    assert d.saturated_bw_gbs == pytest.approx(819.0)


# ---------------------------------------------------------------------------
# Topology-aware consumers
# ---------------------------------------------------------------------------


def test_desync_two_domain_ranks_do_not_contend():
    """Two ranks running the same kernel finish in the same time whether
    they are alone on separate domains, and slower when sharing one."""
    from repro.core.desync import DesyncSimulator, Work

    prog = [Work("DCOPY", 64e6)]
    topo = preset("CLX-2S")
    sep = DesyncSimulator([list(prog), list(prog)], "CLX",
                          topology=topo,
                          placement=["CLX/s0/d0", "CLX/s1/d0"])
    recs_sep = sep.run()
    shared = DesyncSimulator([list(prog), list(prog)], "CLX",
                             topology=topo,
                             placement=["CLX/s0/d0", "CLX/s0/d0"])
    recs_shared = shared.run()
    t_sep = max(r.end for r in recs_sep)
    t_shared = max(r.end for r in recs_shared)
    # Separated ranks run at solo speed; sharing a domain costs bandwidth
    # only past the saturation knee — at 1+1 threads it merely must not be
    # faster.
    solo = DesyncSimulator([list(prog)], "CLX").run()
    assert t_sep == pytest.approx(max(r.end for r in solo), rel=1e-9)
    assert t_shared >= t_sep - 1e-12


def test_desync_placement_validation():
    from repro.core.desync import DesyncSimulator, Work

    topo = preset("CLX-2S")
    with pytest.raises(ValueError, match="together"):
        DesyncSimulator([[Work("DCOPY", 1e6)]], "CLX", topology=topo)
    with pytest.raises(ValueError, match="placement names"):
        DesyncSimulator([[Work("DCOPY", 1e6)]], "CLX", topology=topo,
                        placement=["CLX/s0/d0", "CLX/s1/d0"])
    with pytest.raises(KeyError):
        DesyncSimulator([[Work("DCOPY", 1e6)]], "CLX", topology=topo,
                        placement=["CLX/s9/d9"])


def test_pod_overlap_plan_straggler_chip():
    from repro.core.hlo import RooflineTerms
    from repro.runtime.overlap_schedule import plan_pod_overlap

    terms = RooflineTerms(name="step", t_compute=1e-3, t_memory=8e-4,
                          t_collective=5e-4, flops=2e11, hbm_bytes=6e8,
                          wire_bytes=2e8)
    plan = plan_pod_overlap(terms, chip_load=(1.0, 1.0, 1.3, 1.0))
    assert len(plan.by_chip) == 4
    assert plan.straggler_chip == "TPUv5e/chip2"
    assert plan.t_step == pytest.approx(
        plan.by_chip["TPUv5e/chip2"].t_planned)
    # Uniform load: all chips plan identically.
    uniform = plan_pod_overlap(terms)
    plans = list(uniform.by_chip.values())
    assert all(p.t_planned == pytest.approx(plans[0].t_planned)
               for p in plans)
