"""Fault-tolerant training loop.

Production behaviors, testable on one CPU host:
  * checkpoint/restart: async checkpoints every ``ckpt_every`` steps; on
    start, restore the latest and continue exactly (deterministic data).
  * preemption handling: a ``failure_hook`` can raise ``SimulatedFailure``
    at any step; ``run_with_restarts`` restarts the loop from the last
    checkpoint, bounded by ``max_restarts``.
  * elastic scaling: restart may pass a different mesh/host count — restore
    re-shards (checkpoint/store.py) and the data pipeline re-shards
    deterministically.
  * straggler mitigation: the StragglerMonitor injects barriers when the
    desync model says skew is being amplified (on real multi-host metal; a
    no-op on one host but exercised by tests via synthetic durations).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..data import HostLoader, SyntheticLM
from .straggler import StragglerMonitor

log = logging.getLogger("repro.loop")


class SimulatedFailure(RuntimeError):
    """Raised by failure hooks to simulate preemption/node loss."""


@dataclasses.dataclass
class LoopResult:
    final_step: int
    losses: list[float]
    restarts: int
    restored_from: int | None


def train_loop(*, step_fn, state, loader: HostLoader,
               n_steps: int, ckpt: CheckpointManager | None = None,
               ckpt_every: int = 50,
               monitor: StragglerMonitor | None = None,
               failure_hook: Callable[[int], None] | None = None,
               start_step: int = 0) -> tuple[LoopResult, object]:
    losses = []
    step = start_step
    for batch in loader:
        if step >= n_steps:
            break
        t0 = time.perf_counter()
        if failure_hook is not None:
            failure_hook(step)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if not np.isfinite(loss):
            raise FloatingPointError(f"loss diverged at step {step}: {loss}")
        step += 1
        if monitor is not None:
            monitor.record([time.perf_counter() - t0])
            if monitor.should_inject_barrier():
                jax.block_until_ready(state.params)  # the barrier
        if ckpt is not None and step % ckpt_every == 0:
            ckpt.save_async(step, state, extra={"loss": loss})
    if ckpt is not None:
        ckpt.save_async(step, state, extra={"final": True})
        ckpt.wait()
    return LoopResult(final_step=step, losses=losses, restarts=0,
                      restored_from=None), state


def run_with_restarts(*, make_state, make_step_fn, dataset: SyntheticLM,
                      ckpt_dir: str, n_steps: int, ckpt_every: int = 50,
                      max_restarts: int = 3,
                      failure_hook: Callable[[int], None] | None = None,
                      host_index: int = 0, host_count: int = 1
                      ) -> LoopResult:
    """The crash-resilient outer loop: build state, restore if a checkpoint
    exists, run, and on SimulatedFailure restart from the last checkpoint."""
    restarts = 0
    restored_from = None
    all_losses: list[float] = []
    while True:
        ckpt = CheckpointManager(ckpt_dir)
        state = make_state()
        restored, manifest = ckpt.restore_latest(state)
        start = 0
        if restored is not None:
            state = restored
            start = int(manifest["step"])
            restored_from = start
            log.info("restored from step %d", start)
        step_fn = make_step_fn()
        loader = HostLoader(dataset, start_step=start,
                            host_index=host_index, host_count=host_count)
        try:
            result, state = train_loop(
                step_fn=step_fn, state=state, loader=loader,
                n_steps=n_steps, ckpt=ckpt, ckpt_every=ckpt_every,
                failure_hook=failure_hook, start_step=start)
            all_losses.extend(result.losses)
            return LoopResult(final_step=result.final_step,
                              losses=all_losses, restarts=restarts,
                              restored_from=restored_from)
        except SimulatedFailure as e:
            restarts += 1
            log.warning("simulated failure: %s (restart %d)", e, restarts)
            if restarts > max_restarts:
                raise
        finally:
            loader.close()
            ckpt.wait()
