"""Static kernel-feature analysis: jaxpr traffic auditing and the
trace-contract linter.

The paper's model needs exactly two things per kernel — its memory
streams and its flops per iteration.  This package derives both from
the kernel's own jaxpr instead of a hand-transcribed table:

  traffic  — :func:`audit`: walk the closed jaxpr (through
             pallas_call / scan / while / pjit / cond), classify every
             buffer as a streaming load, store, RFO write-allocate,
             resident operand, or accumulator, and count flops.
  features — :func:`features` / :func:`derive`: collapse a
             :class:`TrafficAudit` into per-iteration
             :class:`LoopFeatures` (reads/writes/rfo/flops — the Table
             II row shape), with layer-condition reuse and a
             write-allocate policy toggle.
  lint     — :func:`lint`: trace-contract diagnostics (weak consts
             baked into traces, bucket-policy bypass, silent f32→f64
             promotion, placed-grid padding escapes), in the
             registry's suggestion-bearing error style.
  report   — ``python -m repro.analysis.report``: the derived features
             next to Table II and the calibrated values, plus the
             repo-corpus lint sweep CI gates on.

The features feed the resolution chain as the ``"static"`` rung:
``api.from_static_analysis(fn, args)`` /
``KernelSpec.from_static_analysis`` — same ECM bridge as
``from_loop_features``, no measurement and no transcription.
"""

from .features import LoopFeatures, derive, features
from .lint import (RULES, Diagnostic, lint, lint_callable, lint_grid,
                   lint_plan)
from .traffic import Stream, TrafficAudit, audit

_REPORT_NAMES = ("cross_check", "lint_corpus", "static_suite")


def __getattr__(name: str):
    # Lazy: importing .report at package-import time shadows
    # ``python -m repro.analysis.report`` (runpy warns about the
    # double-import) — resolve its names on first use instead.
    if name in _REPORT_NAMES:
        from . import report
        return getattr(report, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "audit", "TrafficAudit", "Stream",
    "features", "derive", "LoopFeatures",
    "lint", "lint_callable", "lint_plan", "lint_grid", "Diagnostic",
    "RULES",
    "cross_check", "lint_corpus", "static_suite",
]
