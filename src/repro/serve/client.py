"""Tiny blocking client for the serving subsystem (stdlib
``http.client``): the helper the tests, CI smoke job, and load
benchmark share.  Not a public SDK — the wire format *is* the API
(ndjson lines, docs/serving.md); this just saves every caller the
chunked-transfer boilerplate.
"""

from __future__ import annotations

import http.client
import json
from typing import Iterable, Iterator


def get_json(host: str, port: int, path: str,
             timeout: float = 10.0) -> tuple[int, dict]:
    """GET a JSON endpoint (``/healthz``, ``/statsz``); returns
    ``(status, payload)``."""
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        return resp.status, json.loads(resp.read().decode())
    finally:
        conn.close()


def iter_solve(host: str, port: int, rows: Iterable[dict], *,
               path: str = "/v1/solve",
               timeout: float = 60.0) -> Iterator[dict]:
    """POST request lines as one ndjson body and yield response lines
    as the server streams them (request order)."""
    body = "".join(json.dumps(r) + "\n" for r in rows).encode()
    conn = http.client.HTTPConnection(host, port, timeout=timeout)
    try:
        conn.request("POST", path, body=body,
                     headers={"Content-Type": "application/x-ndjson",
                              "Content-Length": str(len(body))})
        resp = conn.getresponse()
        if resp.status != 200:
            raise ConnectionError(
                f"{path} -> {resp.status}: {resp.read().decode()!r}")
        buf = b""
        while True:
            chunk = resp.read(65536)
            if not chunk:
                break
            buf += chunk
            while b"\n" in buf:
                line, buf = buf.split(b"\n", 1)
                if line.strip():
                    yield json.loads(line)
        if buf.strip():
            yield json.loads(buf)
    finally:
        conn.close()


def solve(host: str, port: int, rows: Iterable[dict],
          **kwargs) -> list[dict]:
    """:func:`iter_solve`, materialized."""
    return list(iter_solve(host, port, rows, **kwargs))
