"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init — the dry-run sets
XLA_FLAGS before importing anything).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model_axis: int = 1):
    """Whatever this host actually has — used by examples and tests."""
    n = len(jax.devices())
    if n % model_axis:
        model_axis = 1
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
