"""Fused RMSNorm (+ optional residual add) Pallas TPU kernel.

Unfused, RMSNorm is three HBM round-trips (read x, read x for the reduce,
write y); fused it is one read + one write — a pure bandwidth optimization,
i.e. exactly the kind of ``f``-reducing transform the paper's model values.
Rows are tiled into VMEM as (block_rows, hidden) tiles; hidden stays whole
per tile so the row reduction needs no cross-block state.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 64


def _rmsnorm_kernel(x_ref, w_ref, out_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * w_ref[...].astype(jnp.float32)
    out_ref[...] = y.astype(out_ref.dtype)


def _rmsnorm_res_kernel(x_ref, res_ref, w_ref, out_ref, newres_ref, *,
                        eps: float):
    h = x_ref[...].astype(jnp.float32) + res_ref[...].astype(jnp.float32)
    newres_ref[...] = h.astype(newres_ref.dtype)
    ms = jnp.mean(h * h, axis=-1, keepdims=True)
    y = h * jax.lax.rsqrt(ms + eps) * w_ref[...].astype(jnp.float32)
    out_ref[...] = y.astype(out_ref.dtype)


def _blocks(rows: int, block_rows: int) -> tuple[int, int]:
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows -= 1
    return rows // block_rows, block_rows


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            block_rows: int = DEFAULT_BLOCK_ROWS,
            interpret: bool = True) -> jax.Array:
    """y = x / rms(x) * w over the last axis.  x: (..., hidden)."""
    shape = x.shape
    hidden = shape[-1]
    rows = x.size // hidden
    xf = x.reshape(rows, hidden)
    nblk, br = _blocks(rows, block_rows)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((br, hidden), lambda i: (i, 0)),
            pl.BlockSpec((1, hidden), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, hidden), x.dtype),
        interpret=interpret,
    )(xf, w.reshape(1, hidden))
    return out.reshape(shape)


def rmsnorm_residual(x: jax.Array, residual: jax.Array, w: jax.Array, *,
                     eps: float = 1e-6, block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused h = x + residual; y = rmsnorm(h) * w.  Returns (y, h)."""
    shape = x.shape
    hidden = shape[-1]
    rows = x.size // hidden
    xf = x.reshape(rows, hidden)
    rf = residual.reshape(rows, hidden)
    nblk, br = _blocks(rows, block_rows)
    y, h = pl.pallas_call(
        functools.partial(_rmsnorm_res_kernel, eps=eps),
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((br, hidden), lambda i: (i, 0)),
            pl.BlockSpec((br, hidden), lambda i: (i, 0)),
            pl.BlockSpec((1, hidden), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, hidden), lambda i: (i, 0)),
            pl.BlockSpec((br, hidden), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, hidden), x.dtype),
            jax.ShapeDtypeStruct((rows, hidden), x.dtype),
        ],
        interpret=interpret,
    )(xf, rf, w.reshape(1, hidden))
    return y.reshape(shape), h.reshape(shape)
