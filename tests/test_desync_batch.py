"""Batched desync engine vs. the scalar reference engine.

Acceptance gate of the batched-engine PR: with B = 1 the numpy batch path
must reproduce the scalar engine's record list *exactly* (same order, same
floats); multi-scenario batches must match per-scenario scalar runs to
solver tolerance; and randomly generated barrier-complete programs must
satisfy the engine invariants on both paths.
"""

import random

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.desync import (Allreduce, DesyncSimulator, Idle,
                               WaitNeighbors, Work, durations_by_tag,
                               skewness)
from repro.core.desync_batch import run_batch
from repro.core.sharing import HAVE_JAX
from repro.core.table2 import TABLE2
from repro.core.topology import preset
from repro.runtime.straggler import StepPhase, StragglerMonitor

MB = 1e6


def _programs(tail, seed, n=12):
    rng = random.Random(seed)
    return [[Idle(rng.expovariate(1 / 6e-5), tag="noise"),
             Work("Schoenauer", 20 * MB, tag="symgs"),
             Work("DDOT2", 4 * MB, tag="ddot2"),
             *tail]
            for _ in range(n)]


TAILS = {
    "allreduce": [Allreduce(), Work("DAXPY", 15 * MB, tag="daxpy")],
    "p2p": [WaitNeighbors(), Work("Schoenauer", 20 * MB, tag="spmv")],
    "daxpy": [Work("DAXPY", 15 * MB, tag="daxpy")],
}


# ---------------------------------------------------------------------------
# B = 1 exact equivalence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tail", sorted(TAILS), ids=sorted(TAILS))
def test_b1_reproduces_scalar_records_exactly(tail):
    """Record-for-record, bitwise: same ranks, indices, tags, floats, and
    emission order as the scalar engine."""
    progs = _programs(TAILS[tail], seed=2)
    scalar = DesyncSimulator(progs, "CLX").run(t_max=60)
    batch = run_batch([progs], "CLX", t_max=60)
    assert batch.records[0] == scalar


def test_b1_exact_on_multi_domain_topology():
    topo = preset("CLX-2S")
    place = [topo.domain_names[i % 2] for i in range(8)]
    progs = _programs(TAILS["allreduce"], seed=5, n=8)
    scalar = DesyncSimulator(progs, "CLX", topology=topo,
                             placement=place).run(t_max=60)
    batch = run_batch([progs], "CLX", topology=topo, placement=place,
                      t_max=60)
    assert batch.records[0] == scalar


def test_b1_truncated_run_matches_scalar():
    """t_max cuts both engines at the same point."""
    progs = _programs(TAILS["daxpy"], seed=0)
    t_max = 5e-4
    scalar = DesyncSimulator(progs, "CLX").run(t_max=t_max)
    batch = run_batch([progs], "CLX", t_max=t_max)
    assert batch.records[0] == scalar


# ---------------------------------------------------------------------------
# Multi-scenario batches
# ---------------------------------------------------------------------------


def test_batch_matches_per_scenario_scalar_runs():
    """Every scenario of a heterogeneous batch matches its own scalar run
    (tolerance-level: only padding widths differ numerically)."""
    batch_progs = [_programs(TAILS[k], seed=s)
                   for s, k in enumerate(("allreduce", "daxpy", "p2p",
                                          "allreduce"))]
    res = run_batch(batch_progs, "CLX", t_max=60)
    for b, progs in enumerate(batch_progs):
        scalar = DesyncSimulator(progs, "CLX").run(t_max=60)
        got = res.records[b]
        assert [(r.rank, r.index, r.tag) for r in got] == \
            [(r.rank, r.index, r.tag) for r in scalar]
        np.testing.assert_allclose([r.start for r in got],
                                   [r.start for r in scalar], rtol=1e-9)
        np.testing.assert_allclose([r.end for r in got],
                                   [r.end for r in scalar], rtol=1e-9)


def test_batch_deadlock_masks_by_default():
    """A deadlocked scenario no longer poisons the batch: it is reported
    in the ``failed`` mask with its partial records, and every healthy
    scenario still runs to completion (regression for the former
    whole-batch RuntimeError abort)."""
    deadlocked = [[Allreduce()], [Allreduce(), Allreduce()]]
    healthy = [[Work("DDOT2", MB, tag="d")], [Work("DAXPY", MB, tag="x")]]
    res = run_batch([deadlocked, healthy, deadlocked], "CLX", t_max=1.0)
    assert res.failed.tolist() == [True, False, True]
    assert res.n_failed == 2
    # the healthy scenario matches its own scalar run, record-for-record
    scalar = DesyncSimulator(healthy, "CLX").run(t_max=1.0)
    assert res.records[1] == scalar
    # the deadlocked scenarios froze at the rendezvous: the lone-rank
    # allreduce of scenario 0 retired (rank 1 is parked at its second),
    # but nothing past the deadlock point exists
    assert all(r.index == 0 for r in res.records[0])
    # ensemble statistics cannot silently absorb the partial scenarios:
    # skew is NaN for failed entries, per-scenario aggregation raises
    sk = res.skew_by_tag("d")
    assert np.isnan(sk[0]) and np.isnan(sk[2]) and not np.isnan(sk[1])
    with pytest.raises(ValueError, match="deadlocked"):
        res.durations_by_tag(0, "Allreduce")
    assert res.durations_by_tag(0, "Allreduce", allow_failed=True)
    assert res.durations_by_tag(1, "d")  # healthy scenario unaffected


def test_batch_deadlock_raise_mode():
    with pytest.raises(RuntimeError, match="deadlock"):
        run_batch([[[Allreduce()], [Allreduce(), Allreduce()]]], "CLX",
                  t_max=1.0, on_deadlock="raise")
    with pytest.raises(ValueError, match="on_deadlock"):
        run_batch([[[Work("DDOT2", MB)]]], "CLX", on_deadlock="ignore")


def test_healthy_batch_has_clean_failed_mask():
    progs = _programs(TAILS["allreduce"], seed=1, n=4)
    res = run_batch([progs, progs], "CLX", t_max=60)
    assert res.failed.tolist() == [False, False]
    assert res.n_failed == 0


def test_batch_validation_errors():
    with pytest.raises(ValueError, match="rectangular"):
        run_batch([[[Allreduce()]], [[Allreduce()], [Allreduce()]]], "CLX")
    with pytest.raises(ValueError, match="backend"):
        run_batch([[[Work("DDOT2", MB)]]], "CLX", backend="fortran")
    topo = preset("CLX-2S")
    with pytest.raises(ValueError, match="placement"):
        run_batch([[[Work("DDOT2", MB)]]], "CLX", topology=topo)


# ---------------------------------------------------------------------------
# Property test: random barrier-complete programs
# ---------------------------------------------------------------------------


def _random_programs(rng: random.Random, n_ranks: int):
    """Random small deadlock-free programs.

    Every rank passes the same number of allreduces (each release retires
    one allreduce per rank, so equal counts keep the rendezvous complete).
    Neighbor waits are only generated in barrier-free programs: a waiter
    needs its neighbors to *reach its pc*, and a neighbor parked at an
    allreduce that cannot assemble (because the waiter is not at one) is a
    genuine deadlock the simulator must — and does — report.
    """
    n_barriers = rng.randint(0, 2)
    kernels = ["DDOT2", "DAXPY", "STREAM"]

    def filler():
        items = [Work(rng.choice(kernels), rng.uniform(0.1, 4.0) * MB),
                 Idle(rng.uniform(1e-6, 1e-4))]
        if n_barriers == 0:
            items.append(WaitNeighbors())
        return rng.choice(items)

    progs = []
    for _ in range(n_ranks):
        prog = [filler() for _ in range(rng.randint(0, 3))]
        for _ in range(n_barriers):
            prog.append(Allreduce())
            for _ in range(rng.randint(0, 2)):
                prog.append(Work(rng.choice(kernels),
                                 rng.uniform(0.1, 4.0) * MB))
        progs.append(prog)
    return progs


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=2, max_value=6),
       st.integers(min_value=1, max_value=4))
def test_random_programs_invariants(seed, n_ranks, n_scenarios):
    rng = random.Random(seed)
    batch_progs = [_random_programs(rng, n_ranks)
                   for _ in range(n_scenarios)]
    res = run_batch(batch_progs, "CLX", t_max=120.0)  # no deadlock raised
    for b, progs in enumerate(batch_progs):
        by_rank = {}
        for rec in res.records[b]:
            by_rank.setdefault(rec.rank, []).append(rec)
        for r, prog in enumerate(progs):
            recs = sorted(by_rank.get(r, []), key=lambda x: x.index)
            # barrier-complete + generous t_max => every item retires once
            assert len(recs) == len(prog)
            assert [x.index for x in recs] == list(range(len(prog)))
            for a, c in zip(recs, recs[1:]):
                assert c.start == a.end
                assert c.end >= c.start
            # total bytes conserved: each Work item's record must last at
            # least bytes / b_s — even owning the whole interface, the
            # kernel cannot move its bytes faster than saturation
            for item, rec in zip(prog, recs):
                if isinstance(item, Work) and item.bytes > 0:
                    bs = TABLE2[item.kernel].bs["CLX"] * 1e9
                    assert rec.duration >= item.bytes / bs * (1 - 1e-9)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_random_programs_b1_exactness(seed):
    rng = random.Random(seed)
    progs = _random_programs(rng, 5)
    scalar = DesyncSimulator(progs, "CLX").run(t_max=120.0)
    assert run_batch([progs], "CLX", t_max=120.0).records[0] == scalar


# ---------------------------------------------------------------------------
# jax backend
# ---------------------------------------------------------------------------


@pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
def test_jax_backend_matches_numpy():
    batch_progs = [_programs(TAILS[k], seed=s, n=6)
                   for s, k in enumerate(("allreduce", "p2p", "daxpy"))]
    rn = run_batch(batch_progs, "CLX", t_max=60, backend="numpy")
    rj = run_batch(batch_progs, "CLX", t_max=60, backend="jax")
    np.testing.assert_allclose(rn.start, rj.start, rtol=1e-9, atol=1e-15)
    np.testing.assert_allclose(rn.end, rj.end, rtol=1e-9, atol=1e-15)
    np.testing.assert_allclose(rn.t_end, rj.t_end, rtol=1e-9)
    for a, b in zip(rn.records, rj.records):
        assert len(a) == len(b)


@pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
def test_jax_backend_deadlock_masks_and_raises():
    deadlocked = [[Allreduce()], [Allreduce(), Allreduce()]]
    healthy = [[Work("DDOT2", MB, tag="d")], [Work("DAXPY", MB, tag="x")]]
    res = run_batch([deadlocked, healthy], "CLX", t_max=1.0,
                    backend="jax")
    assert res.failed.tolist() == [True, False]
    assert len(res.records[1]) == 2
    with pytest.raises(RuntimeError, match="deadlock"):
        run_batch([deadlocked], "CLX", t_max=1.0, backend="jax",
                  on_deadlock="raise")


# ---------------------------------------------------------------------------
# Consumers: seed-ensemble straggler mode, result helpers
# ---------------------------------------------------------------------------


def _phases(f_followup):
    return [StepPhase("fwd", bytes_hbm=40e6, f=0.19, bs=800.0),
            StepPhase("probe", bytes_hbm=8e6, f=0.15, bs=800.0),
            StepPhase("grad_io", bytes_hbm=30e6, f=f_followup, bs=800.0)]


def test_seed_ensemble_is_deterministic():
    mon = StragglerMonitor(n_workers=16)
    a = mon.predict_amplification(_phases(0.9), probe=1, ensemble=16)
    b = mon.predict_amplification(_phases(0.9), probe=1, ensemble=16)
    assert a == b
    # a different seed gives a different (but same-sign) estimate
    c = mon.predict_amplification(_phases(0.9), probe=1, ensemble=16,
                                  seed=100)
    assert c != a and c > 0


def test_seed_ensemble_sign_agreement():
    """The ensemble estimate keeps the paper's amplification signs."""
    mon = StragglerMonitor(n_workers=16)
    assert mon.predict_amplification(_phases(0.9), probe=1,
                                     ensemble=16) > 0.2
    assert mon.predict_amplification(_phases(0.05), probe=1,
                                     ensemble=16) < -0.2


def test_single_draw_matches_scalar_engine():
    """ensemble=1 goes through the batch engine but must equal a scalar
    simulation of the same program (B=1 exactness, end to end).  Member
    0 of base seed 0 draws from the facade's splittable seed stream
    (api.derive_member_seed), so the scalar reference seeds the same
    way."""
    from repro.api import derive_member_seed
    from repro.core.table2 import KernelSpec
    mon = StragglerMonitor(n_workers=12)
    got = mon.predict_amplification(_phases(0.9), probe=1, ensemble=1)
    phases = _phases(0.9)
    specs = {ph.name: KernelSpec.synthetic(ph.name, ph.f, ph.bs)
             for ph in phases}
    rng = random.Random(derive_member_seed(0, 0))
    progs = []
    for _ in range(12):
        prog = [Idle(rng.expovariate(1 / 5e-5), tag="noise")]
        prog += [Work(ph.name, ph.bytes_hbm, tag=ph.name) for ph in phases]
        progs.append(prog)
    recs = DesyncSimulator(progs, "TPU", specs=specs).run(t_max=120.0)
    want = skewness(durations_by_tag(recs, "probe", n_ranks=12))
    assert got == want


def test_pod_plan_candidates_evaluated_as_one_batch():
    """overlap_schedule evaluates B candidate chip-load plans in a single
    batched run; results match evaluating each candidate alone, and the
    balanced plan wins (a lagging chip delays the gradient allreduce)."""
    from repro.core.hlo import RooflineTerms
    from repro.runtime.overlap_schedule import (best_pod_plan,
                                                evaluate_pod_plans)

    terms = RooflineTerms(name="step", t_compute=1e-3, t_memory=2e-3,
                          t_collective=5e-4, flops=1e12, hbm_bytes=1.5e9,
                          wire_bytes=2e8)
    cands = [(1.0, 1.0, 1.0, 1.0),
             (1.6, 0.8, 0.8, 0.8),
             (1.2, 1.2, 0.8, 0.8)]
    evals = evaluate_pod_plans(terms, cands)
    assert len(evals) == 3
    solo = [evaluate_pod_plans(terms, [c])[0] for c in cands]
    for a, b in zip(evals, solo):
        assert a.t_step == b.t_step  # batching is layout, not semantics
    idx, best = best_pod_plan(terms, cands)
    assert idx == 0 and best.balanced
    assert evals[1].t_step > evals[0].t_step
    assert evals[1].bwd_spread > evals[0].bwd_spread
    with pytest.raises(ValueError, match="candidate"):
        evaluate_pod_plans(terms, [(1.0, 1.0)])


def test_result_helpers():
    progs = _programs(TAILS["daxpy"], seed=3, n=8)
    res = run_batch([progs, progs], "CLX", t_max=60)
    assert res.n_scenarios == 2
    assert res.n_ranks == 8
    assert res.n_events == sum(len(r) for r in res.records)
    sk = res.skew_by_tag("ddot2")
    assert sk.shape == (2,)
    assert sk[0] == sk[1]  # identical scenarios
    d = res.durations_by_tag(0, "ddot2")
    assert len(d) == 8 and all(x > 0 for x in d)
