"""Placement-batched solve payoff: one PlacedBatchPlan.run() vs the
per-candidate predict loop.

Before this subsystem existed, sweeping B placement candidates on one
topology meant B separate ``api.predict(scenario)`` calls — B spec
resolutions, B ragged packings, B solver dispatches.  A placed
``ScenarioBatch`` now packs the whole sweep into one (B, D, K) grid and
solves it in a single flattened call.  This benchmark records:

* ``percall``  — the headline: one ``plan.run()`` against B separate
  placed ``api.predict`` calls (acceptance: >= 10x at B = 256);
* ``swap``     — ``plan.run(placement=...)``, re-solving the compiled
  sweep under a fresh candidate grid (the search inner loop);
* ``swap_f``   — ``plan.run(f=...)``, calibration numbers swapped into
  the placed grid with no re-trace;
* ``jit_cache`` — substrate cache hit rate when the identical sweep is
  compiled and run again (jax only; acceptance: 1.0 — a repeat sweep
  must never recompile).

``python benchmarks/placement_scaling.py --out BENCH_placement.json``
writes the committed artifact and exits nonzero if a bound is broken.
"""

from __future__ import annotations

import argparse
import gc
import json
import sys
import time

from repro import api
from repro.core import backend as backend_mod

B_SWEEP = 256
SPEEDUP_BOUND = 10.0   # plan.run() vs per-candidate predict loop
REPS = 30
SAMPLES = 7

KERNELS = ("DCOPY", "DDOT2", "DAXPY", "Schoenauer")
DOMAINS = ("CLX/s0/d0", "CLX/s1/d0")


def _time_pair_us(fn_a, fn_b, reps: int = REPS,
                  samples: int = SAMPLES) -> tuple[float, float]:
    """Best-of-``samples`` mean over ``reps`` calls for two functions,
    in µs; sample blocks alternate so drift hits both sides alike and
    GC is paused (same protocol as benchmarks/plan_overhead.py)."""
    best_a = best_b = float("inf")
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(samples):
            t0 = time.perf_counter()
            for _ in range(reps):
                fn_a()
            best_a = min(best_a, (time.perf_counter() - t0) / reps)
            t0 = time.perf_counter()
            for _ in range(reps):
                fn_b()
            best_b = min(best_b, (time.perf_counter() - t0) / reps)
            gc.collect()
    finally:
        if gc_was_enabled:
            gc.enable()
    return best_a * 1e6, best_b * 1e6


def _time_us(fn, reps: int = REPS, samples: int = SAMPLES) -> float:
    return _time_pair_us(fn, fn, reps=reps, samples=samples)[0]


def _placed_scenarios(b: int, shift: int = 0) -> list:
    """B placement candidates for a two-kernel co-run on CLX-2S: sweep
    thread splits and socket assignments (the Sec. 5 search pattern)."""
    base = api.Scenario.on("CLX").using("CLX-2S")
    out = []
    for i in range(b):
        j = i + shift
        sc = (base
              .placed(KERNELS[j % 3], 1 + j % 8, DOMAINS[j % 2])
              .placed(KERNELS[(j + 1) % 4], 1 + (j * 3) % 8,
                      DOMAINS[(j + 1) % 2]))
        if j % 2:
            sc = sc.placed("DAXPY", 1 + j % 4, DOMAINS[0])
        out.append(sc)
    return out


def measure() -> dict:
    scens = _placed_scenarios(B_SWEEP)
    batch = api.ScenarioBatch.of(scens)
    plan = api.compile(batch)
    plan.run()                      # warm caches + jit before timing

    t_percall = _time_us(lambda: [api.predict(sc) for sc in scens],
                         reps=3, samples=5)
    t_run = _time_us(plan.run)
    alt = api.ScenarioBatch.of(_placed_scenarios(B_SWEEP, shift=1))
    placement2 = alt.placements
    t_swap = _time_us(lambda: plan.run(placement=placement2))
    f2 = plan.grid.f * 1.01
    t_swap_f = _time_us(lambda: plan.run(f=f2))

    # Repeat-sweep cache behaviour: compiling the same sweep again must
    # reuse every jitted solver — zero recompiles, hit rate 1.0.
    cache = None
    if backend_mod.HAVE_JAX:
        for b in (200, B_SWEEP):    # populate the 256-row bucket
            api.compile(api.ScenarioBatch.of(
                _placed_scenarios(b))).run(backend="jax")
        before = backend_mod.cache_stats()
        for b in (200, B_SWEEP):    # the repeat sweep, compiled afresh
            api.compile(api.ScenarioBatch.of(
                _placed_scenarios(b))).run(backend="jax")
        after = backend_mod.cache_stats()
        hits = after["hits"] - before["hits"]
        misses = after["misses"] - before["misses"]
        cache = {
            "lookups": hits + misses,
            "hits": hits,
            "misses": misses,
            "hit_rate": round(hits / (hits + misses), 3)
            if hits + misses else 0.0,
            "process_entries": after["entries"],
        }

    return {
        "B": B_SWEEP,
        "backend": plan.engine,
        "bucket": list(plan.bucket),
        "percall_us": round(t_percall, 1),
        "plan_run_us": round(t_run, 3),
        "swap_placement_us": round(t_swap, 3),
        "swap_f_us": round(t_swap_f, 3),
        "speedup_vs_percall": round(t_percall / t_run, 1),
        "jit_cache": cache,
    }


def check(r: dict) -> bool:
    ok = r["speedup_vs_percall"] >= SPEEDUP_BOUND
    if r["jit_cache"] is not None:
        # A repeated sweep must be compile-free.
        ok &= r["jit_cache"]["hit_rate"] == 1.0
    return ok


def rows():
    r = measure()
    out = [
        (f"placement/B={r['B']}/percall_predict", r["percall_us"],
         f"plan_run={r['plan_run_us']:.1f}us;"
         f"speedup={r['speedup_vs_percall']:.1f}x"),
        (f"placement/B={r['B']}/plan_run", r["plan_run_us"],
         f"bucket={tuple(r['bucket'])}"),
        (f"placement/B={r['B']}/swap_placement", r["swap_placement_us"],
         "no-retrace"),
        (f"placement/B={r['B']}/swap_f", r["swap_f_us"], "no-retrace"),
    ]
    if r["jit_cache"] is not None:
        c = r["jit_cache"]
        out.append(("placement/jit_cache/repeat_sweep", 0.0,
                    f"hit_rate={c['hit_rate']};hits={c['hits']};"
                    f"misses={c['misses']}"))
    out.append(("placement/check/bounds", 0.0,
                f"ok={check(r)};speedup>={SPEEDUP_BOUND:.0f}x"))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args(argv)
    r = measure()
    ok = check(r)
    report = {
        "benchmark": "placement_scaling",
        "jax": backend_mod.HAVE_JAX,
        "bound_speedup_vs_percall": SPEEDUP_BOUND,
        "bound_repeat_hit_rate": 1.0,
        "ok": ok,
        "results": r,
    }
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.out}  (ok={ok})")
    print(f"B={r['B']}: per-candidate {r['percall_us']:.0f}us  "
          f"plan.run {r['plan_run_us']:.0f}us  "
          f"({r['speedup_vs_percall']:.1f}x)  "
          f"placement-swap {r['swap_placement_us']:.0f}us  "
          f"f-swap {r['swap_f_us']:.0f}us")
    if r["jit_cache"] is not None:
        print(f"jit cache (repeat sweep): {r['jit_cache']}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
