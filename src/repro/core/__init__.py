"""Core of the reproduction: the paper's analytic bandwidth-sharing model
(Afzal, Hager, Wellein 2020) and its TPU-native applications.

Public API:
  machine   — Table I machine models + TPU v5e chip model
  table2    — Table II kernel suite (f, b_s per architecture)
  ecm       — ECM single-core model (Eqs. 1–3) + multicore scaling
  sharing   — bandwidth-sharing model (Eqs. 4–5), N-group generalized,
              scalar + batched (vmapped) solver paths
  topology  — contention-domain trees (sockets → ccNUMA domains; TPU pods
              → chips) and placement of groups onto domains
  memsim    — microscopic queue-level simulator (validation instrument)
  desync    — rank-level discrete-event desynchronization simulator
  overlap   — overlap-aware TPU step model (compute/collective HBM sharing)
  hlo       — collective-traffic parsing + roofline terms from compiled HLO
"""

from . import (desync, ecm, hlo, machine, memsim, overlap, sharing, table2,
               topology)
from .machine import BDW1, BDW2, CLX, ROME, TPU_V5E, MachineModel, TpuModel
from .sharing import (BatchSharePrediction, Group, SharePrediction, pair,
                      predict, predict_batch, solve_batch)
from .table2 import ARCHS, FIG9_KERNELS, TABLE2, KernelSpec, kernel
from .topology import (ContentionDomain, Placed, Topology, TopologyNode,
                       TopologyPrediction, predict_placed)

__all__ = [
    "desync", "ecm", "hlo", "machine", "memsim", "overlap", "sharing",
    "table2", "topology", "BDW1", "BDW2", "CLX", "ROME", "TPU_V5E",
    "MachineModel", "TpuModel", "Group", "SharePrediction",
    "BatchSharePrediction", "pair", "predict", "predict_batch",
    "solve_batch", "ARCHS", "FIG9_KERNELS", "TABLE2", "KernelSpec",
    "kernel", "ContentionDomain", "Placed", "Topology", "TopologyNode",
    "TopologyPrediction", "predict_placed",
]
