"""Calibration subsystem: schema, batched fit, and spec integration.

Fast tier-1 coverage; the full Table II × arch certification grid runs in
the slow suite (tests/test_calibrate_roundtrip.py) and the CI round-trip
job.
"""

import json

import numpy as np
import pytest

from repro.calibrate import (PairTrace, ScalingTrace, TraceSet, certify,
                             dump_traces, fit_envelope, fit_scaling,
                             fit_scaling_cell, forward_bandwidth,
                             load_traces, predict_pairs,
                             synthesize_pair_trace,
                             synthesize_scaling_trace)
from repro.calibrate.fit import aggregate_ensemble, calibrated_specs
from repro.core import memsim, sharing, table2
from repro.core.sharing import HAVE_JAX, utilization_curve


# ---------------------------------------------------------------------------
# Trace schema
# ---------------------------------------------------------------------------


def _trace(**kw):
    base = dict(kernel="DCOPY", arch="CLX", cores=(1, 2, 4),
                bandwidth=(19.8, 39.6, 79.2))
    base.update(kw)
    return ScalingTrace(**base)


def test_scaling_trace_validation():
    with pytest.raises(ValueError, match="core counts"):
        _trace(cores=(2, 1, 4))
    with pytest.raises(ValueError, match="core counts"):
        _trace(cores=(0, 1, 2))
    with pytest.raises(ValueError, match="bandwidth samples"):
        _trace(bandwidth=(1.0,))
    with pytest.raises(ValueError, match="positive"):
        _trace(bandwidth=(19.8, -1.0, 79.2))
    with pytest.raises(ValueError, match="empty"):
        _trace(cores=(), bandwidth=())


def test_pair_trace_validation():
    with pytest.raises(ValueError, match="exactly"):
        PairTrace(kernels=("A",), arch="CLX", n=(1, 1),
                  bandwidth=(1.0, 1.0))
    with pytest.raises(ValueError, match="positive"):
        PairTrace(kernels=("A", "B"), arch="CLX", n=(0, 1),
                  bandwidth=(1.0, 1.0))


@pytest.mark.parametrize("ndjson", [False, True], ids=["json", "ndjson"])
def test_trace_round_trip_through_disk(tmp_path, ndjson):
    traces = [
        _trace(seed=3, noise=0.02, source="memsim"),
        PairTrace(kernels=("DCOPY", "DDOT2"), arch="CLX", n=(12, 8),
                  bandwidth=(59.1, 47.3), seed=5, source="memsim"),
    ]
    path = tmp_path / ("t.ndjson" if ndjson else "t.json")
    dump_traces(traces, path, ndjson=ndjson)
    ts = load_traces(path)
    assert ts.scaling == (traces[0],)
    assert ts.pairs == (traces[1],)
    assert len(ts) == 2


def test_single_record_ndjson_round_trip(tmp_path):
    """Regression: an append-friendly campaign with exactly one trace so
    far must load back (the one-line ndjson file parses as a bare JSON
    object)."""
    path = tmp_path / "one.ndjson"
    tr = _trace(seed=1)
    dump_traces([tr], path, ndjson=True)
    ts = load_traces(path)
    assert ts.scaling == (tr,)


def test_loader_rejects_unknown_schema_version(tmp_path):
    path = tmp_path / "t.json"
    d = _trace().to_json_dict()
    d["schema"] = 99
    path.write_text(json.dumps({"schema": 99, "traces": [d]}))
    with pytest.raises(ValueError, match="schema"):
        load_traces(path)


def test_wrapper_schema_covers_records(tmp_path):
    """Regression: records inside a `{"schema": 1, "traces": [...]}`
    wrapper need not repeat the schema per record — the wrapper's
    declaration covers them (a per-record schema still wins)."""
    path = tmp_path / "t.json"
    d = _trace().to_json_dict()
    del d["schema"]
    path.write_text(json.dumps({"schema": 1, "traces": [d]}))
    assert load_traces(path).scaling == (_trace(),)
    bad = dict(d, schema=99)
    path.write_text(json.dumps({"schema": 1, "traces": [bad]}))
    with pytest.raises(ValueError, match="99"):
        load_traces(path)


def test_synthesized_traces_are_seed_reproducible():
    a = synthesize_scaling_trace("DCOPY", "ROME", seed=11, noise=0.03,
                                 n_events=4000)
    b = synthesize_scaling_trace("DCOPY", "ROME", seed=11, noise=0.03,
                                 n_events=4000)
    c = synthesize_scaling_trace("DCOPY", "ROME", seed=12, noise=0.03,
                                 n_events=4000)
    assert a == b
    assert a.bandwidth != c.bandwidth
    assert a.source == "memsim" and a.seed == 11
    assert a.cores == tuple(range(1, 9))  # ROME domain size


# ---------------------------------------------------------------------------
# Fitting
# ---------------------------------------------------------------------------


def _synthetic_trace(f, bs, n_max=16, utilization="queue"):
    cores = tuple(range(1, n_max + 1))
    bw = forward_bandwidth(np.array(cores), f, bs,
                           utilization=utilization)
    return ScalingTrace(kernel="syn", arch="X", cores=cores,
                        bandwidth=tuple(float(b) for b in bw))


@pytest.mark.parametrize("utilization", ["queue", "recursion"])
@pytest.mark.parametrize("f,bs", [(0.09, 103.0), (0.31, 54.0),
                                  (0.83, 32.0)])
def test_fit_recovers_exact_forward_curves(utilization, f, bs):
    """On noiseless model-generated curves the fit must invert the
    forward model to sub-percent accuracy across the physical f range."""
    tr = _synthetic_trace(f, bs, utilization=utilization)
    f_hat, bs_hat = fit_scaling_cell(tr, utilization=utilization,
                                     backend="numpy")
    assert f_hat == pytest.approx(f, rel=5e-3)
    assert bs_hat == pytest.approx(bs, rel=5e-3)


def test_batched_fit_is_one_pass_and_matches_per_cell():
    """The batched pass over heterogeneous cells equals the sequential
    per-cell loop it replaces."""
    traces = [_synthetic_trace(0.2, 100.0),
              _synthetic_trace(0.45, 60.0, n_max=8),
              _synthetic_trace(0.8, 33.0, n_max=10)]
    fit = fit_scaling(traces, backend="numpy")
    assert len(fit) == 3
    for i, tr in enumerate(traces):
        f_i, bs_i = fit_scaling_cell(tr, backend="numpy")
        assert fit.f[i] == pytest.approx(f_i, rel=1e-9)
        assert fit.bs[i] == pytest.approx(bs_i, rel=1e-9)


@pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
def test_fit_backends_agree():
    traces = [_synthetic_trace(0.2, 100.0),
              _synthetic_trace(0.45, 60.0, n_max=8)]
    fn = fit_scaling(traces, backend="numpy")
    fj = fit_scaling(traces, backend="jax")
    np.testing.assert_allclose(fn.f, fj.f, rtol=1e-9)
    np.testing.assert_allclose(fn.bs, fj.bs, rtol=1e-9)


def test_fit_recovers_memsim_inputs_within_bound():
    """End-to-end on the instrument itself (small grid; the full Table II
    sweep is the slow certification)."""
    spec = table2.kernel("STREAM")
    traces = [synthesize_scaling_trace(spec, "ROME", seed=s, noise=0.02,
                                       n_events=6000) for s in range(3)]
    fit = fit_scaling(traces, utilization="queue")
    agg = aggregate_ensemble(fit)
    cell = agg[("STREAM", "ROME")]
    assert cell["f"].value == pytest.approx(spec.f["ROME"], rel=0.08)
    assert cell["bs"].value == pytest.approx(spec.bs["ROME"], rel=0.08)
    assert cell["f"].n_seeds == 3
    assert cell["f"].lo <= cell["f"].value <= cell["f"].hi


def test_fit_input_validation():
    with pytest.raises(ValueError, match="utilization"):
        fit_scaling([_synthetic_trace(0.2, 100.0)], utilization="magic")
    with pytest.raises(ValueError, match="backend"):
        fit_scaling([_synthetic_trace(0.2, 100.0)], backend="fortran")
    empty = fit_scaling(TraceSet())
    assert len(empty) == 0


def test_utilization_curve_matches_solver_envelope():
    """The fit's forward model and the sharing solver share one law:
    b_s·U(n; f) equals the solver's homogeneous total bandwidth."""
    f, bs = 0.19, 104.2
    for mode in ("queue", "recursion"):
        for n in (1, 3, 8, 20):
            pred = sharing.predict([sharing.Group(n=n, f=f, bs=bs)],
                                   utilization=mode)
            want = forward_bandwidth(n, f, bs, utilization=mode)
            assert pred.total_bw == pytest.approx(float(want), rel=1e-12)


def test_utilization_curve_neutral_entries():
    u = utilization_curve([0, 1, 4], 0.25, mode="queue")
    assert u[0] == 1.0 and u[1] == 0.25 and u[2] == 1.0
    # A typo'd mode raises the registry's suggestion-bearing KeyError
    # (the solver-level utilization= check stays a ValueError).
    with pytest.raises(KeyError, match="utilization mode"):
        utilization_curve([1], 0.2, mode="nope")


# ---------------------------------------------------------------------------
# Calibrated specs are first-class citizens
# ---------------------------------------------------------------------------


def test_calibrated_specs_feed_the_whole_stack():
    spec = table2.kernel("DAXPY")
    traces = [synthesize_scaling_trace(spec, "ROME", seed=s, noise=0.01,
                                       n_events=6000) for s in range(2)]
    cal = calibrated_specs(fit_scaling(traces))["DAXPY"]
    # Group.of consumes it unchanged
    g = sharing.Group.of(cal, "ROME", 4)
    assert g.bs == cal.bs["ROME"] and g.f == cal.f["ROME"]
    # the solver and the desync engine consume it unchanged
    pred = sharing.predict([g])
    assert pred.total_bw > 0
    from repro.core.desync import DesyncSimulator, Work
    recs = DesyncSimulator([[Work("DAXPY", 1e6)]], "ROME",
                           specs={"DAXPY": cal}).run(t_max=10)
    assert len(recs) == 1
    # template inheritance keeps the stream decomposition
    assert (cal.reads, cal.writes, cal.rfo) == \
        (spec.reads, spec.writes, spec.rfo)


def test_envelope_fit_recovers_bs_from_pairs():
    """Eq. 4 in reverse: per-kernel b_s from saturated paired totals."""
    a, b = table2.kernel("DCOPY"), table2.kernel("DDOT2")
    pairs = [synthesize_pair_trace(a, b, "CLX", na, 20 - na, seed=na,
                                   n_events=6000)
             for na in (4, 8, 12, 16)]
    env = fit_envelope(pairs)
    assert env.bs["CLX"]["DCOPY"] == pytest.approx(a.bs["CLX"], rel=0.08)
    assert env.bs["CLX"]["DDOT2"] == pytest.approx(b.bs["CLX"], rel=0.08)
    assert env.residual["CLX"] < 3.0
    mix = env.envelope("CLX", [("DCOPY", 10), ("DDOT2", 10)])
    want = sharing.overlapped_saturated_bw(
        [sharing.Group.of(a, "CLX", 10), sharing.Group.of(b, "CLX", 10)])
    assert mix == pytest.approx(want, rel=0.08)


def test_predict_pairs_is_one_batched_solve():
    specs = {k: table2.kernel(k) for k in ("DCOPY", "DDOT2", "DAXPY")}
    pairs = [
        PairTrace(kernels=("DCOPY", "DDOT2"), arch="CLX", n=(12, 8),
                  bandwidth=(1.0, 1.0)),
        PairTrace(kernels=("DAXPY", "DCOPY"), arch="ROME", n=(4, 4),
                  bandwidth=(1.0, 1.0)),
    ]
    got = predict_pairs(specs, pairs)
    assert got.shape == (2, 2)
    want = sharing.pair(specs["DCOPY"], specs["DDOT2"], "CLX", 12, 8,
                        utilization="queue")
    np.testing.assert_allclose(got[0], want.bw_group, rtol=1e-12)
    assert predict_pairs(specs, []).shape == (0, 2)


# ---------------------------------------------------------------------------
# Certification (reduced grid; full grid is slow-marked)
# ---------------------------------------------------------------------------


def test_certify_quick_grid_passes_bound():
    report = certify(["DCOPY", "DDOT2"], ["ROME"], seeds=(0, 1),
                     noise=0.02, n_events=5000, pairs_per_arch=2)
    assert report.ok()
    assert len(report.cells) == 2
    assert report.max_f_err < 0.08
    assert report.max_bs_err < 0.08
    assert report.max_pair_err < 0.08
    assert report.wall_batched_s > 0 and report.wall_sequential_s > 0
    d = report.to_json_dict()
    assert d["ok"] and len(d["cells"]) == 2
    assert d["fit_wall_s"]["speedup_x"] == pytest.approx(report.speedup)
    json.dumps(d)  # artifact must be serializable


def test_certify_works_on_custom_specs_and_detects_mismatch():
    """certify() accepts a custom ground-truth table (synthetic kernels
    calibrate too), and the error metric is not vacuous: scoring a fit
    against a contradicting truth blows the bound."""
    custom = {
        "PROBE": table2.KernelSpec.synthetic("PROBE", 0.19, 104.2,
                                             arch="ROME"),
    }
    report = certify(["PROBE"], ["ROME"], seeds=(0,), noise=0.0,
                     n_events=5000, pairs_per_arch=0, specs=custom,
                     sequential_baseline=False)
    assert report.ok() and len(report.cells) == 1
    from repro.calibrate.certify import CellError
    bad = CellError(kernel="PROBE", arch="ROME",
                    f_true=0.80, f_fit=report.cells[0].f_fit,
                    bs_true=36.0, bs_fit=report.cells[0].bs_fit)
    assert bad.f_err > 0.08 and bad.bs_err > 0.08


def test_holdout_pairs_are_heterogeneous():
    """Regression: with >= 2 kernels in the grid, every held-out pair
    must mix two distinct kernels (a self-pair would just re-test the
    fitted homogeneous curve)."""
    from repro.calibrate.certify import _holdout_pairs
    truth = dict(table2.TABLE2)
    for kernels in (["DCOPY", "DAXPY"], sorted(truth)[:5], sorted(truth)):
        pairs = _holdout_pairs(kernels, ["CLX", "ROME"], 4, truth)
        assert len(pairs) == 8
        for ka, kb, arch, na, nb in pairs:
            assert ka != kb, (kernels, ka)
            assert na >= 1 and nb >= 1
    # degenerate grids do not crash
    assert _holdout_pairs([], ["CLX"], 2, truth) == []
    solo = _holdout_pairs(["DCOPY"], ["CLX"], 1, truth)
    assert solo == [("DCOPY", "DCOPY", "CLX", 10, 10)]


def test_memsim_trace_matches_queue_forward_model():
    """The instrument realizes the queue forward model to a few percent —
    the premise the whole calibration rests on."""
    spec = table2.kernel("DDOT2")
    tr = synthesize_scaling_trace(spec, "CLX", n_events=6000)
    want = forward_bandwidth(np.array(tr.cores), spec.f["CLX"],
                             spec.bs["CLX"], utilization="queue")
    np.testing.assert_allclose(tr.bandwidth, want, rtol=0.06)
