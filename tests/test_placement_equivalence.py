"""Differential-testing layer for the placement-batched solver.

The placed batch path (``solve_placed_batch`` → ``predict_placed_batch``
→ ``PlacedBatchPlan``) re-implements nothing: it *routes* B placed
scenarios through the same flattened array solver the single-scenario
``predict_placed`` uses.  These tests prove that claim differentially:

* at B = 1 the grid solve is **bit-for-bit** the per-scenario solver on
  the numpy path (the packed grid's K equals the lone scenario's own
  group maximum, so even padding widths coincide);
* across random ragged batches, every materialized ``scenario(i)``
  equals a lone ``predict_placed`` of the same placement, exactly
  (numpy) or to 1e-12 (jax, where padding to a different bucket width
  may shift the last ulp);
* the fused batch × ensemble simulate path is row-for-row identical to
  the explicit cross-product loop the known-issues doc used to
  prescribe;
* the occupancy mask — not luck — guards the result: NaN/inf-poisoned
  padding lanes change nothing, and empty padded domains attain exactly
  zero bandwidth.

Random topologies come from the presets, placements/raggedness/(f, b_s)
from hypothesis (real or the deterministic fallback shim).
"""

import random

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import api
from repro.core import backend
from repro.core.sharing import Group, solve_batch, solve_placed_batch
from repro.core.topology import (Placed, pack_placed, predict_placed,
                                 predict_placed_batch, preset)

TOPOLOGIES = ["CLX", "CLX-2S", "ROME-2S-NPS4", "TPUv5e-pod4"]

topo_names = st.sampled_from(TOPOLOGIES)
seeds = st.integers(min_value=0, max_value=10**6)


def _random_placements(rng, topo, *, max_groups=5, max_n=4):
    """A random ragged placement list (n = 0 groups included — they are
    genuine occupants of the grid, not padding)."""
    out = []
    for j in range(rng.randint(0, max_groups)):
        out.append(Placed(
            Group(n=rng.randint(0, max_n),
                  f=rng.uniform(0.05, 1.0),
                  bs=rng.uniform(20.0, 220.0),
                  name=f"g{j}"),
            rng.choice(topo.domain_names)))
    return out


# ---------------------------------------------------------------------------
# B = 1: bit-for-bit with the single-scenario solver
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(tname=topo_names, seed=seeds)
def test_placed_batch_b1_bit_for_bit(tname, seed):
    rng = random.Random(seed)
    topo = preset(tname)
    placements = _random_placements(rng, topo)
    res = predict_placed_batch(topo, [placements], strict=False,
                               backend="numpy")
    ref = predict_placed(topo, placements, strict=False, backend="numpy")
    # Dataclass equality covers every float of every domain: b_overlap,
    # alphas, per-group bandwidths, input-order bw_group — bit-for-bit.
    assert res.scenario(0) == ref
    assert res.bw_group[0] == ref.bw_group
    # total_bw is a reduction — numpy's pairwise sum may order it
    # differently from the per-domain Python sum; the summands are
    # bit-identical (asserted above), so only the last ulp can move.
    assert float(res.total_bw[0]) == pytest.approx(ref.total_bw, rel=1e-14)


@settings(max_examples=40, deadline=None)
@given(tname=topo_names, seed=seeds,
       b=st.integers(min_value=1, max_value=9))
def test_placed_batch_rows_match_singles(tname, seed, b):
    rng = random.Random(seed)
    topo = preset(tname)
    batch = [_random_placements(rng, topo) for _ in range(b)]
    res = predict_placed_batch(topo, batch, strict=False, backend="numpy")
    for i, placements in enumerate(batch):
        assert res.scenario(i) == predict_placed(
            topo, placements, strict=False, backend="numpy")


@pytest.mark.skipif(not backend.HAVE_JAX, reason="jax not importable")
def test_placed_batch_jax_matches_numpy_tightly():
    # Cross-padding-width comparisons on jax may shift the last ulp;
    # the contract there is 1e-12, not bitwise.
    rng = random.Random(0)
    topo = preset("ROME-2S-NPS4")
    batch = [_random_placements(rng, topo) for _ in range(12)]
    ref = predict_placed_batch(topo, batch, strict=False, backend="numpy")
    got = predict_placed_batch(topo, batch, strict=False, backend="jax")
    np.testing.assert_allclose(got.shares.bw_group, ref.shares.bw_group,
                               rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(got.shares.util, ref.shares.util,
                               rtol=1e-12, atol=0)


@settings(max_examples=40, deadline=None)
@given(seed=seeds)
def test_facade_placed_batch_matches_single_predicts(seed):
    # Same differential claim one layer up: ScenarioBatch → compiled
    # PlacedBatchPlan rows == per-scenario facade predicts.
    rng = random.Random(seed)
    kernels = ["DCOPY", "DDOT2", "DAXPY", "Schoenauer"]
    domains = ("CLX/s0/d0", "CLX/s1/d0")
    scens = []
    for _ in range(rng.randint(1, 6)):
        sc = api.Scenario.on("CLX").using("CLX-2S").options(strict=False)
        for _ in range(rng.randint(1, 4)):
            sc = sc.placed(rng.choice(kernels), rng.randint(0, 6),
                           rng.choice(domains))
        scens.append(sc)
    res = api.predict(api.ScenarioBatch.of(scens), backend="numpy")
    assert isinstance(res, api.PlacedBatchPrediction)
    for i, sc in enumerate(scens):
        assert res[i] == api.predict(sc, backend="numpy")


# ---------------------------------------------------------------------------
# Fused batch × ensemble == explicit cross-product
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=seeds,
       b=st.integers(min_value=1, max_value=3),
       e=st.integers(min_value=1, max_value=4))
def test_fused_ensemble_equals_explicit_cross_product(seed, b, e):
    rng = random.Random(seed)
    ranks = rng.randint(2, 4)       # simulate batches must be rectangular
    scens = []
    for i in range(b):
        sc = (api.Scenario.on("CLX").ranks(ranks)
              .step("DCOPY", rng.uniform(0.5, 4.0) * 1e6, tag="w"))
        if rng.random() < 0.5:
            sc = sc.barrier()
        scens.append(sc.with_noise(rng.uniform(1e-6, 1e-4),
                                   seed=rng.randint(0, 100), ensemble=e))
    fused = api.simulate(api.ScenarioBatch.of(scens))
    assert fused.n_scenarios == b * e
    for i, sc in enumerate(scens):
        solo = api.simulate(sc)     # the explicit per-scenario loop
        rows = fused.rows_for(i)
        assert len(rows) == e
        for m, row in enumerate(rows):
            assert solo.records(m) == fused.records(row)
            assert solo.t_end[m] == fused.t_end[row]


# ---------------------------------------------------------------------------
# Mask correctness: the mask, not luck, guards the result
# ---------------------------------------------------------------------------


def test_empty_padded_domains_contribute_exactly_zero():
    topo = preset("ROME-2S-NPS4")          # 8 domains
    # Populate only two of the eight; six domain rows are pure padding.
    placements = [
        Placed(Group(4, 0.3, 120.0, "a"), "ROME/s0/d1"),
        Placed(Group(2, 0.8, 90.0, "b"), "ROME/s1/d3"),
    ]
    res = predict_placed_batch(topo, [placements], backend="numpy")
    dom_bw = res.shares.domain_bw[0]
    occupied = {"ROME/s0/d1", "ROME/s1/d3"}
    for d, name in enumerate(topo.domain_names):
        if name not in occupied:
            assert dom_bw[d] == 0.0                       # exactly
            assert res.shares.b_overlap[0, d] == 0.0
    # ...and never perturb the occupied domains: each matches a lone
    # single-domain solve of just its groups, bit for bit.
    lone = solve_batch(np.array([[4.0], [2.0]]),
                       np.array([[0.3], [0.8]]),
                       np.array([[120.0], [90.0]]), backend="numpy")
    d1 = topo.domain_names.index("ROME/s0/d1")
    d3 = topo.domain_names.index("ROME/s1/d3")
    assert res.shares.bw_group[0, d1, 0] == lone.bw_group[0, 0]
    assert res.shares.bw_group[0, d3, 0] == lone.bw_group[1, 0]


@pytest.mark.parametrize("poison", [np.nan, np.inf, -np.inf, 1e300])
def test_poisoned_padding_is_guarded_by_the_mask(poison):
    # Deliberately poison every masked-out lane of a packed grid.  If
    # the implementation multiplied by the mask (0 · NaN = NaN) or
    # simply trusted the padding to be zero, this would blow up; the
    # select-before-solve contract makes the result bit-identical.
    topo = preset("CLX-2S")
    rng = random.Random(7)
    batch = [_random_placements(rng, topo) for _ in range(6)]
    grid = pack_placed(topo, batch, strict=False)
    ref = solve_placed_batch(grid.n, grid.f, grid.bs, mask=grid.mask,
                             backend="numpy")
    bad = ~grid.mask
    n_p, f_p, bs_p = grid.n.copy(), grid.f.copy(), grid.bs.copy()
    n_p[bad] = poison
    f_p[bad] = poison
    bs_p[bad] = poison
    got = solve_placed_batch(n_p, f_p, bs_p, mask=grid.mask,
                             backend="numpy")
    np.testing.assert_array_equal(got.bw_group, ref.bw_group)
    np.testing.assert_array_equal(got.b_overlap, ref.b_overlap)
    np.testing.assert_array_equal(got.alphas, ref.alphas)
    np.testing.assert_array_equal(got.util, ref.util)
    assert np.isfinite(got.bw_group).all()


def test_default_mask_is_occupancy_by_thread_count():
    # Without an explicit mask, n > 0 defines occupancy — and masked
    # lanes are forced neutral before the solve.
    n = np.array([[[2.0, 0.0], [3.0, 0.0]]])
    f = np.array([[[0.5, np.nan], [0.25, np.nan]]])
    bs = np.array([[[100.0, np.nan], [80.0, np.nan]]])
    res = solve_placed_batch(n, f, bs, backend="numpy")
    assert np.isfinite(res.bw_group).all()
    assert res.f[0, 0, 1] == 0.0 and res.bs[0, 1, 1] == 0.0
    ref = solve_batch(np.array([[2.0], [3.0]]), np.array([[0.5], [0.25]]),
                      np.array([[100.0], [80.0]]), backend="numpy")
    np.testing.assert_array_equal(res.bw_group[0, :, 0], ref.bw_group[:, 0])


def test_genuine_zero_thread_groups_stay_occupied():
    # A placed n = 0 group is an occupant (neutral in Eqs. 4–5 but
    # present in results), distinct from padding: its (f, bs) survive
    # into the materialized scenario.
    topo = preset("CLX")
    placements = [Placed(Group(0, 0.9, 150.0, "idle"), "CLX/d0"),
                  Placed(Group(4, 0.3, 100.0, "busy"), "CLX/d0")]
    res = predict_placed_batch(topo, [placements], backend="numpy")
    assert bool(res.grid.mask[0, 0, 0]) and bool(res.grid.mask[0, 0, 1])
    sc = res.scenario(0)
    assert sc.placements[0].group == placements[0].group
    assert sc.bw_group[0] == 0.0
    assert sc == predict_placed(topo, placements, backend="numpy")


# ---------------------------------------------------------------------------
# Grid packing invariants
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(tname=topo_names, seed=seeds,
       b=st.integers(min_value=1, max_value=8))
def test_pack_placed_roundtrip(tname, seed, b):
    rng = random.Random(seed)
    topo = preset(tname)
    batch = [_random_placements(rng, topo) for _ in range(b)]
    grid = pack_placed(topo, batch, strict=False)
    D = len(topo.domain_names)
    assert grid.n.shape[0] == b and grid.n.shape[1] == D
    assert grid.mask.sum() == sum(len(p) for p in batch)
    for i, placements in enumerate(batch):
        assert len(grid.slots[i]) == len(placements)
        for j, p in enumerate(placements):
            d, k = grid.slots[i][j]
            assert topo.domain_names[d] == p.domain
            assert grid.n[i, d, k] == p.group.n
            assert grid.f[i, d, k] == p.group.f
            assert grid.bs[i, d, k] == p.group.bs
            assert bool(grid.mask[i, d, k])
    # Unmasked lanes are exactly neutral zeros.
    assert grid.n[~grid.mask].sum() == 0.0
    assert grid.f[~grid.mask].sum() == 0.0


def test_pack_placed_validation_messages():
    topo = preset("CLX")
    good = [Placed(Group(2, 0.5, 100.0), "CLX/d0")]
    with pytest.raises(KeyError, match="scenario 1.*unknown domain"):
        pack_placed(topo, [good, [Placed(Group(1, 0.5, 100.0), "nope")]])
    cap = topo.domain("CLX/d0").n_cores
    with pytest.raises(ValueError, match="overcommitted"):
        pack_placed(topo, [[Placed(Group(cap + 1, 0.5, 100.0),
                                   "CLX/d0")]])
    # strict=False allows overcommit, mirroring predict_placed.
    grid = pack_placed(topo, [[Placed(Group(cap + 1, 0.5, 100.0),
                                      "CLX/d0")]], strict=False)
    assert grid.n[0, 0, 0] == cap + 1
