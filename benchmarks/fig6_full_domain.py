"""Paper Fig. 6: bandwidth share per kernel on a fully populated domain.

Three pairings (DCOPY+DDOT2, JacobiL3-v1+DDOT1, STREAM+JacobiL2-v1) on all
four architectures.  For every split (n_I, n_t - n_I) we report the model's
per-core bandwidth for both kernels, the total, and the queue-simulator
measurement with its relative deviation.

The model side of the sweep is declared once through the facade
(api.ScenarioBatch.split_sweep) and solved in a single api.predict call —
the engine dispatch picks the batched solver.  The microscopic queue
simulator stays per-split (it is the measurement instrument, not the
model).  The ``us`` column times the model solve only — it is not
comparable to pre-batching revisions, which included the simulator in
the window.
"""

from __future__ import annotations

import time

from repro import api
from repro.core import memsim, sharing, table2

PAIRINGS = [("DCOPY", "DDOT2"), ("JacobiL3-v1", "DDOT1"),
            ("STREAM", "JacobiL2-v1")]
DOMAIN = {"BDW-1": 10, "BDW-2": 18, "CLX": 20, "ROME": 8}


def sweep_batch(ka: str, kb: str, arch: str,
                n_dom: int) -> api.ScenarioBatch:
    """All (n_a, n_dom - n_a) splits of one pairing as one scenario set."""
    return api.ScenarioBatch.split_sweep(arch, ka, kb, n_dom,
                                         utilization="queue")


def rows():
    out = []
    for arch, n_dom in DOMAIN.items():
        for ka, kb in PAIRINGS:
            a, b = table2.kernel(ka), table2.kernel(kb)
            scenarios = sweep_batch(ka, kb, arch, n_dom)
            t0 = time.perf_counter()
            batch = api.predict(scenarios)
            us = (time.perf_counter() - t0) * 1e6 / (n_dom - 1)
            per_core = batch.bw_per_core
            worst = 0.0
            for row, na in enumerate(range(1, n_dom)):
                nb = n_dom - na
                sim = memsim.simulate(
                    [sharing.Group.of(a, arch, na),
                     sharing.Group.of(b, arch, nb)], n_events=20_000)
                for i, n in ((0, na), (1, nb)):
                    err = abs(sim[i] / n - per_core[row, i]) \
                        / per_core[row, i]
                    worst = max(worst, err)
            mid = n_dom // 2 - 1  # row index of the (n_dom//2, rest) split
            out.append((
                f"fig6/{arch}/{ka}+{kb}", us,
                f"bw_core=({per_core[mid, 0]:.2f},{per_core[mid, 1]:.2f})"
                f";total={batch.total_bw[mid]:.1f};max_err={worst*100:.1f}%"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
