"""Per-architecture smoke tests: reduced same-family config, one forward /
loss / decode step on CPU; asserts shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model_for

ARCHS = [a for a in configs.ARCH_IDS]


def _batch(cfg, b=2, s=16, key=0):
    rng = np.random.default_rng(key)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patches, cfg.d_model)),
            jnp.float32) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_step(arch):
    cfg = configs.get_reduced(arch)
    model = model_for(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step(arch):
    cfg = configs.get_reduced(arch)
    model = model_for(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg, key=1)

    def scalar_loss(p):
        return model.loss(p, batch)[0]

    grads = jax.jit(jax.grad(scalar_loss))(params)
    leaves = jax.tree.leaves(grads)
    assert leaves, f"{arch}: empty grads"
    for g in leaves:
        assert jnp.all(jnp.isfinite(g)), f"{arch}: non-finite grad"
    # At least some gradient signal somewhere.
    total = sum(float(jnp.sum(jnp.abs(g))) for g in leaves)
    assert total > 0, f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = configs.get_reduced(arch)
    model = model_for(cfg)
    params = model.init(jax.random.key(2))
    b, max_seq = 2, 32
    cache = model.init_cache(b, max_seq)
    tokens = jnp.zeros((b,), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    step = jax.jit(model.decode_step)
    for t in range(3):
        logits, cache = step(params, cache, tokens, pos)
        assert logits.shape == (b, cfg.vocab)
        assert jnp.all(jnp.isfinite(logits)), f"{arch}: non-finite logits"
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact_numbers(arch):
    """The full (published) config fields match the assignment sheet."""
    cfg = configs.get_config(arch)
    expected = {
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "mamba2-1.3b": (48, 2048, 1, 1, 0, 50280),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == expected


def test_moe_configs():
    olmoe = configs.get_config("olmoe-1b-7b")
    assert (olmoe.moe.n_experts, olmoe.moe.top_k) == (64, 8)
    granite = configs.get_config("granite-moe-1b-a400m")
    assert (granite.moe.n_experts, granite.moe.top_k) == (32, 8)


def test_mamba_ssm_state():
    cfg = configs.get_config("mamba2-1.3b")
    assert cfg.ssm_state == 128
    assert cfg.is_attention_free


def test_long_context_support_flags():
    assert configs.get_config("recurrentgemma-2b").supports_long_context
    assert configs.get_config("mamba2-1.3b").supports_long_context
    for a in ("qwen2-0.5b", "qwen2.5-32b", "nemotron-4-15b"):
        assert not configs.get_config(a).supports_long_context
