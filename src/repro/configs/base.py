"""Model/run configuration shared by all architectures.

One dataclass covers every assigned family; family-specific fields are
ignored by the others.  Each ``configs/<arch>.py`` exports:
  CONFIG     — the exact published configuration,
  reduced()  — a tiny same-family config for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None         # default d_model // n_heads
    act: Literal["swiglu", "sq_relu", "gelu", "geglu"] = "swiglu"
    qkv_bias: bool = False
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # --- MoE ---
    moe: MoeConfig | None = None

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0                  # N (state size); 0 => not an SSM
    ssm_chunk: int = 256                # SSD chunk length
    ssm_expand: int = 2                 # d_inner = expand * d_model
    ssm_heads: int = 0                  # SSD heads (d_inner / head_dim)

    # --- hybrid (recurrentgemma) ---
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    local_window: int = 0                # local attention window
    lru_width: int = 0                   # RG-LRU width (defaults d_model)

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    n_audio_frames: int = 1500           # stub frontend output length

    # --- VLM (internvl) ---
    n_patches: int = 0                   # stub ViT patch count prepended

    # --- numerics / execution ---
    dtype: str = "bfloat16"              # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True                   # checkpoint each layer in training
    remat_policy: str = "nothing"        # "nothing" | "dots" (save matmuls)
    use_scan: bool = True                # lax.scan over layers
    kernels: Literal["jnp", "pallas", "interpret"] = "jnp"
    logits_softcap: float = 0.0

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing: SSM and local-attention hybrids."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count N (embedding included once)."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim_
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            per = (d * (2 * d_in + 2 * self.ssm_state)  # in_proj approx
                   + d_in * d + d_in * 2 * self.ssm_state)
            return emb + L * per
        attn = d * hd * (self.n_heads + 2 * self.kv_heads) + \
            self.n_heads * hd * d
        if self.moe is not None:
            ff = 3 * d * self.moe.d_ff_expert * self.moe.n_experts \
                + d * self.moe.n_experts
        else:
            n_mats = 3 if self.act in ("swiglu", "geglu") else 2
            ff = n_mats * d * self.d_ff
        layers = L * (attn + ff)
        if self.family == "encdec":
            layers += self.enc_layers * (attn + ff) + L * attn  # cross-attn
        return emb + layers

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        hd = self.head_dim_
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.kv_heads) + \
            self.n_heads * hd * d
        ff = 3 * d * self.moe.d_ff_expert * self.moe.top_k \
            + d * self.moe.n_experts
        return emb + L * (attn + ff)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
