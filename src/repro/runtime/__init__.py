from . import loop, overlap_schedule, sharding, steps, straggler
from .loop import SimulatedFailure, run_with_restarts, train_loop
from .steps import (TrainState, build_serve_step, build_train_step,
                    init_train_state, jit_serve_step, jit_train_step,
                    train_state_shardings)

__all__ = [
    "loop", "overlap_schedule", "sharding", "steps", "straggler",
    "SimulatedFailure", "run_with_restarts", "train_loop", "TrainState",
    "build_serve_step", "build_train_step", "init_train_state",
    "jit_serve_step", "jit_train_step", "train_state_shardings",
]
