"""Batched estimators: recover ``(f, b_s)`` from measured scaling curves.

The forward model is the paper's own (Eqs. 1–5, via
:func:`repro.core.sharing.utilization_curve`): a homogeneous run of a
kernel with request fraction ``f`` and saturated bandwidth ``b_s`` attains

    b(n) = b_s · U(n; f)

aggregate bandwidth on ``n`` cores, where ``U`` is the sub-saturation
utilization law — ``min(1, n·f)`` for the ideal queue interface (which is
also what the memsim instrument realizes) or the latency-penalty
recursion for real hardware.  Fitting inverts this curve: ``b_s`` from the
plateau, ``f`` from the single-core point and the knee position.

The estimator is a *profile least squares* over a fixed ``f`` grid: for
every candidate ``f`` the optimal ``b_s`` is closed-form (the model is
linear in ``b_s``), so the residual profile over the grid is computed for
**all (kernel, arch, seed) cells at once** — one vectorized numpy pass or
one ``jax.vmap``-ped, jitted pass, no per-cell Python loop — followed by
a sub-grid refinement of the winning ``f`` inside its bracket.  The
refinement is jacobian-based Gauss–Newton over the identical vectorized
residual (analytic ``∂U/∂f`` from
:func:`repro.core.sharing.utilization_curve_grad` on numpy, ``jax.jvp``
on jax): quadratic convergence instead of the retired golden section's
fixed φ-rate bracket shrink, at a third of the residual evaluations,
plus *free* curvature-based confidence intervals from the Gauss–Newton
normal matrix (``ScalingFit.f_sigma`` / ``bs_sigma``).  Seed ensembles
aggregate into medians with percentile confidence intervals
(:func:`aggregate_ensemble`), and :func:`calibrated_specs` materializes
the result as first-class :class:`repro.core.table2.KernelSpec` objects
that ``Group.of``, the topology solver, and the desync engines consume
unchanged.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Mapping, Sequence

import numpy as np

from ..core import backend as backend_mod
from ..core.backend import HAVE_JAX
from ..core.sharing import (UTILIZATION_MODES, solve_batch,
                            utilization_curve, utilization_curve_grad)
from ..core.table2 import TABLE2, KernelSpec
from ..obs import metrics
from ..obs import trace as trace_mod
from .traces import PairTrace, ScalingTrace, TraceSet

#: Default candidate grid: log-spaced so relative resolution is uniform
#: across the physical range of ``f`` (~0.08 on CLX stencils to ~1 on Rome).
DEFAULT_F_GRID = np.geomspace(0.01, 1.0, 512)


def forward_bandwidth(n, f, bs, *, utilization: str = "queue",
                      p0_factor: float = 0.5) -> np.ndarray:
    """The Eq. 1–5 forward model: aggregate bandwidth of a homogeneous run
    at each core count ``n`` (broadcasts like numpy)."""
    u = utilization_curve(n, f, mode=utilization, p0_factor=p0_factor)
    return np.asarray(bs) * u


@dataclasses.dataclass(frozen=True)
class ScalingFit:
    """Per-cell ``(f, b_s)`` estimates for a batch of scaling traces.

    ``f_sigma`` / ``bs_sigma`` are per-cell curvature (1σ) uncertainties
    from the Gauss–Newton normal matrix at the optimum — the local
    sensitivity of the fit to measurement noise, complementary to the
    cross-seed percentile CIs of :func:`aggregate_ensemble`.  A cell
    whose curve never leaves saturation has ``f_sigma = inf`` (the knee
    position is unidentifiable from a flat plateau).  ``n_evals`` counts
    residual evaluations per cell (grid profile + refinement), the
    quantity the Gauss–Newton migration reduced; ``refine`` records which
    refiner produced the numbers.
    """

    f: np.ndarray          # (C,) fitted request fractions
    bs: np.ndarray         # (C,) fitted saturated bandwidths [GB/s]
    rss: np.ndarray        # (C,) residual sum of squares at the optimum
    traces: tuple[ScalingTrace, ...]
    utilization: str
    backend: str
    f_sigma: np.ndarray | None = None    # (C,) curvature 1σ of f
    bs_sigma: np.ndarray | None = None   # (C,) curvature 1σ of b_s
    refine: str = "gauss-newton"
    n_evals: int = 0

    def __len__(self) -> int:
        return len(self.traces)

    def cells(self) -> dict[tuple[str, str], list[int]]:
        """Indices grouped by (kernel, arch) — one entry per seed."""
        out: dict[tuple[str, str], list[int]] = {}
        for i, tr in enumerate(self.traces):
            out.setdefault((tr.kernel, tr.arch), []).append(i)
        return out


@dataclasses.dataclass(frozen=True)
class CalibratedValue:
    """Seed-ensemble estimate of one model input: median + percentile CI.

    ``sigma`` is the median per-seed *curvature* uncertainty (1σ, from
    the Gauss–Newton normal matrix) — how sharply the residual pins the
    value within one trace, vs. the ``lo``/``hi`` percentile band which
    measures spread *across* seeds.  0.0 when the fit carried no
    curvature information (a :class:`ScalingFit` constructed without
    sigmas)."""

    value: float
    lo: float
    hi: float
    n_seeds: int
    sigma: float = 0.0

    @property
    def spread(self) -> float:
        return self.hi - self.lo


# ---------------------------------------------------------------------------
# The batched profile-least-squares pass
# ---------------------------------------------------------------------------

_EPS = 1e-30


def _profile_rss_np(n, y, mask, f_grid, utilization, p0_factor):
    """Residual profile over the ``f`` grid for all cells at once.

    ``n, y, mask``: ``(C, N)`` padded cell arrays; ``f_grid``: ``(F,)``.
    Returns ``(rss (C, F), bs_star (C, F))`` where ``bs_star`` is the
    closed-form optimal ``b_s`` at each candidate ``f``.
    """
    u = utilization_curve(n[:, None, :], f_grid[None, :, None],
                          mode=utilization, p0_factor=p0_factor)  # (C,F,N)
    u = np.where(mask[:, None, :], u, 0.0)
    ym = np.where(mask[:, None, :], y[:, None, :], 0.0)
    num = (ym * u).sum(axis=-1)
    den = np.maximum((u * u).sum(axis=-1), _EPS)
    bs_star = num / den                                         # (C, F)
    resid = ym - bs_star[..., None] * u
    rss = (np.where(mask[:, None, :], resid, 0.0) ** 2).sum(axis=-1)
    return rss, bs_star


_INVPHI = (np.sqrt(5.0) - 1.0) / 2.0
_REFINE_ITERS = 32  # bracket shrinks by φ⁻¹ per iter: ~1e-6 of a grid step
_GN_ITERS = 12      # Gauss–Newton is quadratic near the optimum; 12
                    # trust-clipped steps inside the grid bracket land at
                    # machine precision with a third of golden's evals

#: The supported sub-grid refiners.  "golden" is a deprecated escape
#: hatch kept so the Gauss–Newton re-baseline is reversible.
REFINE_METHODS = ("gauss-newton", "golden")


def _refine_evals(refine: str, n_grid: int) -> int:
    """Residual evaluations per cell: the grid profile plus what the
    refiner spends (jacobian evaluations count as one residual pass —
    the derivative rides along analytically)."""
    if refine == "golden":
        return n_grid + 2 + 2 * _REFINE_ITERS + 1
    return n_grid + 2 * _GN_ITERS + 1


def _rss_at_np(n, y, mask, f, utilization, p0_factor):
    """RSS and closed-form ``b_s`` at one candidate ``f`` per cell
    (``f`` shape ``(C,)``)."""
    u = utilization_curve(n, f[:, None], mode=utilization,
                          p0_factor=p0_factor)
    u = np.where(mask, u, 0.0)
    ym = np.where(mask, y, 0.0)
    bs = (ym * u).sum(axis=-1) / np.maximum((u * u).sum(axis=-1), _EPS)
    rss = (np.where(mask, ym - bs[:, None] * u, 0.0) ** 2).sum(axis=-1)
    return rss, bs


def _refine_golden_np(n, y, mask, a, b, utilization, p0_factor):
    """Golden-section refinement inside the winning grid bracket
    ``[a, b]`` — vectorized over cells, fixed iteration count.
    Deprecated: the default refiner is :func:`_refine_gn_np`."""
    c = b - _INVPHI * (b - a)
    d = a + _INVPHI * (b - a)
    rc, _ = _rss_at_np(n, y, mask, c, utilization, p0_factor)
    rd, _ = _rss_at_np(n, y, mask, d, utilization, p0_factor)
    for _ in range(_REFINE_ITERS):
        left = rc < rd
        a = np.where(left, a, c)
        b = np.where(left, d, b)
        c = b - _INVPHI * (b - a)
        d = a + _INVPHI * (b - a)
        rc, _ = _rss_at_np(n, y, mask, c, utilization, p0_factor)
        rd, _ = _rss_at_np(n, y, mask, d, utilization, p0_factor)
    return 0.5 * (a + b)


def _gn_terms_np(n, y, mask, f, utilization, p0_factor):
    """One Gauss–Newton linearization of the *profiled* residual
    ``r(f) = y − b_s*(f)·u(f)`` at ``f`` (``(C,)``), with ``b_s*``'s own
    ``f``-dependence carried through (variable projection).  Returns
    ``(step, rss, bs)`` where ``step`` solves the 1-d normal equation
    ``(Σ (dm)²)·δ = Σ dm·r`` for the model derivative ``dm = ∂(b_s*·u)/∂f``.
    """
    u, du = utilization_curve_grad(n, f[:, None], mode=utilization,
                                   p0_factor=p0_factor)
    u = np.where(mask, u, 0.0)
    du = np.where(mask, du, 0.0)
    ym = np.where(mask, y, 0.0)
    su2 = (u * u).sum(axis=-1)
    syu = (ym * u).sum(axis=-1)
    bs = syu / np.maximum(su2, _EPS)
    dbs = ((ym * du).sum(axis=-1) * su2
           - syu * 2.0 * (u * du).sum(axis=-1)) \
        / np.maximum(su2 * su2, _EPS)
    dm = dbs[:, None] * u + bs[:, None] * du
    r = ym - bs[:, None] * u
    rss = (r * r).sum(axis=-1)
    step = (dm * r).sum(axis=-1) / np.maximum((dm * dm).sum(axis=-1),
                                              _EPS)
    return step, rss, bs


def _refine_gn_np(n, y, mask, f0, a, b, utilization, p0_factor):
    """Trust-clipped Gauss–Newton on the profiled residual, seeded at the
    grid argmin and confined to its bracket ``[a, b]`` (the same bracket
    golden section searched, so the two refiners converge to the same
    local optimum).  A step that fails to reduce the RSS is rejected and
    the trust radius quartered — the deterministic safeguard both the
    numpy and jax implementations share, so backends agree."""
    f = f0.copy()
    rss, _ = _rss_at_np(n, y, mask, f, utilization, p0_factor)
    trust = b - a
    for _ in range(_GN_ITERS):
        step, _, _ = _gn_terms_np(n, y, mask, f, utilization, p0_factor)
        cand = np.clip(f + np.clip(step, -trust, trust), a, b)
        rss_c, _ = _rss_at_np(n, y, mask, cand, utilization, p0_factor)
        ok = rss_c <= rss
        f = np.where(ok, cand, f)
        rss = np.where(ok, rss_c, rss)
        trust = np.where(ok, trust, 0.25 * trust)
    return f


def _curvature_np(n, y, mask, f, bs, rss, utilization, p0_factor):
    """Curvature (1σ) uncertainties from the two-parameter Gauss–Newton
    normal matrix at the optimum: ``J = [b_s·∂U/∂f, U]`` per sample,
    ``cov = σ²·(JᵀJ)⁻¹`` with ``σ² = rss/(m−2)``.  A flat (all-saturated)
    curve has no ``f`` information → ``f_sigma = inf`` and ``b_s``
    falls back to its one-parameter variance."""
    u, du = utilization_curve_grad(n, f[:, None], mode=utilization,
                                   p0_factor=p0_factor)
    u = np.where(mask, u, 0.0)
    du = np.where(mask, du, 0.0)
    j1 = bs[:, None] * du
    a11 = (j1 * j1).sum(axis=-1)
    a12 = (j1 * u).sum(axis=-1)
    a22 = (u * u).sum(axis=-1)
    det = a11 * a22 - a12 * a12
    m_eff = mask.sum(axis=-1)
    s2 = rss / np.maximum(m_eff - 2, 1)
    ok = det > 1e-12 * np.maximum(a11 * a22, _EPS)
    with np.errstate(divide="ignore", invalid="ignore"):
        f_sigma = np.where(ok, np.sqrt(np.maximum(s2 * a22, 0.0)
                                       / np.where(ok, det, 1.0)),
                           np.inf)
        bs_sigma = np.where(
            ok, np.sqrt(np.maximum(s2 * a11, 0.0) / np.where(ok, det, 1.0)),
            np.sqrt(s2 / np.maximum(a22, _EPS)))
    return f_sigma, bs_sigma


def _fit_cells_np(n, y, mask, f_grid, utilization, p0_factor,
                  refine="gauss-newton"):
    rss, _ = _profile_rss_np(n, y, mask, f_grid, utilization, p0_factor)
    j = rss.argmin(axis=-1)
    F = len(f_grid)
    a = f_grid[np.clip(j - 1, 0, F - 1)]
    b = f_grid[np.clip(j + 1, 0, F - 1)]
    if refine == "golden":
        f_hat = _refine_golden_np(n, y, mask, a, b, utilization,
                                  p0_factor)
    else:
        f_hat = _refine_gn_np(n, y, mask, f_grid[j], a, b, utilization,
                              p0_factor)
    rss_hat, bs_hat = _rss_at_np(n, y, mask, f_hat, utilization,
                                 p0_factor)
    f_sigma, bs_sigma = _curvature_np(n, y, mask, f_hat, bs_hat, rss_hat,
                                      utilization, p0_factor)
    return f_hat, bs_hat, rss_hat, f_sigma, bs_sigma


if HAVE_JAX:
    import jax
    import jax.numpy as jnp
    from jax import lax

    from ..core.sharing import utilization_curve_jax

    def _fit_single_jax(n, y, mask, f_grid, p0_factor, n_max, *, mode,
                        refine="gauss-newton"):
        """One cell: profile RSS over the f grid + sub-grid refinement
        (trust-clipped Gauss–Newton by default; the deprecated golden
        section behind ``refine="golden"``).  Shapes: ``n, y, mask`` are
        ``(N,)``; vmapped over the cell axis."""
        ym = jnp.where(mask, y, 0.0)

        def rss_at(f):
            u = utilization_curve_jax(n, f, mode=mode,
                                      p0_factor=p0_factor, n_max=n_max)
            u = jnp.where(mask, u, 0.0)
            bs = (ym * u).sum() / jnp.maximum((u * u).sum(), _EPS)
            rss = ((jnp.where(mask, ym - bs * u, 0.0)) ** 2).sum()
            return rss, bs

        def u_du(f):
            """``(U, ∂U/∂f)`` at scalar ``f`` — forward mode for the
            explicit laws, reverse mode for the fixed point (its
            ``custom_vjp`` has no jvp rule, by design)."""
            curve = functools.partial(utilization_curve_jax, n, mode=mode,
                                      p0_factor=p0_factor, n_max=n_max)
            if mode == "fixedpoint":
                return curve(f), jax.jacrev(curve)(f)
            return jax.jvp(curve, (f,), (jnp.ones_like(f),))

        u = utilization_curve_jax(n[None, :], f_grid[:, None], mode=mode,
                                  p0_factor=p0_factor, n_max=n_max)  # (F, N)
        u = jnp.where(mask[None, :], u, 0.0)
        bs_star = (ym[None, :] * u).sum(-1) / \
            jnp.maximum((u * u).sum(-1), _EPS)
        rss = (jnp.where(mask[None, :],
                         ym[None, :] - bs_star[:, None] * u, 0.0) ** 2
               ).sum(-1)                                        # (F,)
        F = f_grid.shape[0]
        j = jnp.argmin(rss)
        a = f_grid[jnp.clip(j - 1, 0, F - 1)]
        b = f_grid[jnp.clip(j + 1, 0, F - 1)]

        if refine == "golden":
            def body(_, state):
                a, b, c, d, rc, rd = state
                left = rc < rd
                a = jnp.where(left, a, c)
                b = jnp.where(left, d, b)
                c = b - _INVPHI * (b - a)
                d = a + _INVPHI * (b - a)
                rc = rss_at(c)[0]
                rd = rss_at(d)[0]
                return a, b, c, d, rc, rd

            c = b - _INVPHI * (b - a)
            d = a + _INVPHI * (b - a)
            state = (a, b, c, d, rss_at(c)[0], rss_at(d)[0])
            a2, b2, *_ = lax.fori_loop(0, _REFINE_ITERS, body, state)
            f_hat = 0.5 * (a2 + b2)
        else:
            # Trust-clipped Gauss–Newton on the profiled residual:
            # identical algorithm (and accept/reject rule) to
            # _refine_gn_np, so the backends agree.
            def gn_body(_, state):
                f, rss_f, trust = state
                uf, duf = u_du(f)
                uf = jnp.where(mask, uf, 0.0)
                duf = jnp.where(mask, duf, 0.0)
                su2 = (uf * uf).sum()
                syu = (ym * uf).sum()
                bs = syu / jnp.maximum(su2, _EPS)
                dbs = ((ym * duf).sum() * su2
                       - syu * 2.0 * (uf * duf).sum()) \
                    / jnp.maximum(su2 * su2, _EPS)
                dm = dbs * uf + bs * duf
                r = ym - bs * uf
                step = (dm * r).sum() / jnp.maximum((dm * dm).sum(),
                                                    _EPS)
                cand = jnp.clip(f + jnp.clip(step, -trust, trust), a, b)
                rss_c = rss_at(cand)[0]
                ok = rss_c <= rss_f
                return (jnp.where(ok, cand, f),
                        jnp.where(ok, rss_c, rss_f),
                        jnp.where(ok, trust, 0.25 * trust))

            f0 = f_grid[j]
            state = (f0, rss_at(f0)[0], b - a)
            f_hat, *_ = lax.fori_loop(0, _GN_ITERS, gn_body, state)

        rss_hat, bs_hat = rss_at(f_hat)

        # Curvature (1σ) from the 2-parameter normal matrix at the
        # optimum — same formulas as _curvature_np.
        uf, duf = u_du(f_hat)
        uf = jnp.where(mask, uf, 0.0)
        duf = jnp.where(mask, duf, 0.0)
        j1 = bs_hat * duf
        a11 = (j1 * j1).sum()
        a12 = (j1 * uf).sum()
        a22 = (uf * uf).sum()
        det = a11 * a22 - a12 * a12
        m_eff = mask.sum()
        s2 = rss_hat / jnp.maximum(m_eff - 2, 1)
        okc = det > 1e-12 * jnp.maximum(a11 * a22, _EPS)
        safe_det = jnp.where(okc, det, 1.0)
        f_sigma = jnp.where(
            okc, jnp.sqrt(jnp.maximum(s2 * a22, 0.0) / safe_det), jnp.inf)
        bs_sigma = jnp.where(
            okc, jnp.sqrt(jnp.maximum(s2 * a11, 0.0) / safe_det),
            jnp.sqrt(s2 / jnp.maximum(a22, _EPS)))
        return f_hat, bs_hat, rss_hat, f_sigma, bs_sigma

    def _build_jax_fit(mode: str, n_max: int, refine: str):
        """Jitted vmap of the per-cell fit for one shape bucket;
        registered in the substrate's process-wide solver cache."""
        vmapped = jax.vmap(
            functools.partial(_fit_single_jax, mode=mode, n_max=n_max,
                              refine=refine),
            in_axes=(0, 0, 0, None, None))
        return jax.jit(vmapped)

    def _fit_cells_jax(n, y, mask, f_grid, utilization, p0_factor,
                       refine="gauss-newton"):
        C, N = n.shape
        # Only the recursion law compiles an n-dependent loop; the queue
        # law shares one executable per (C, N, F) bucket.
        n_max = int(n.max()) if (n.size and utilization == "recursion") \
            else 0
        n_max_b = backend_mod.bucket(n_max) if n_max else 0
        Cb = backend_mod.bucket(C)
        fitter = backend_mod.jitted(
            ("calibrate.fit_scaling", utilization, refine, Cb, N,
             len(f_grid), n_max_b),
            lambda: _build_jax_fit(utilization, n_max_b, refine))
        with jax.experimental.enable_x64():
            # Padded cells are all-masked: their fit runs on zeros and
            # is sliced off below, so real cells are bit-for-bit the
            # unpadded pass.
            out = fitter(
                jnp.asarray(backend_mod.pad_rows(
                    np.asarray(n, np.float64), Cb), jnp.float64),
                jnp.asarray(backend_mod.pad_rows(
                    np.asarray(y, np.float64), Cb), jnp.float64),
                jnp.asarray(backend_mod.pad_rows(
                    np.asarray(mask, bool), Cb)),
                jnp.asarray(f_grid, jnp.float64),
                jnp.float64(p0_factor))
        return tuple(np.asarray(x)[:C] for x in out)


def fit_scaling(traces: TraceSet | Sequence[ScalingTrace], *,
                utilization: str = "queue",
                f_grid: np.ndarray | None = None, p0_factor: float = 0.5,
                backend: str = "auto", jax_cutoff: int | None = None,
                refine: str = "gauss-newton") -> ScalingFit:
    """Fit ``(f, b_s)`` for every scaling trace in one batched pass.

    ``utilization`` must match the instrument that produced the traces:
    ``"queue"`` for memsim-generated curves (and idealized interfaces),
    ``"recursion"`` (or its ``"fixedpoint"`` self-consistent limit) for
    real-hardware measurements with a soft knee.
    ``backend``: ``"numpy"``, ``"jax"`` (vmapped + jitted), or ``"auto"``
    — resolved by the substrate (:func:`repro.core.backend.resolve`)
    against the number of cells, honoring ``REPRO_JAX_CUTOFF`` / the
    ``jax_cutoff`` override like every batched path.  The jitted fit
    kernel — grid profile plus the sub-grid refinement — is one
    compiled plan per (cell-bucket, law, refiner) in the substrate's
    cache, so repeated fits of same-shaped trace sets skip
    recompilation.

    ``refine`` selects the sub-grid refiner inside the winning grid
    bracket:

    * ``"gauss-newton"`` (default) — jacobian-based Gauss–Newton over the
      identical profiled residual, with analytic ``∂U/∂f``
      (:func:`repro.core.sharing.utilization_curve_grad` / ``jax.jvp``).
      Quadratic convergence, ~1/3 the residual evaluations of golden
      section, and curvature-based ``f_sigma``/``bs_sigma`` CIs for free.
    * ``"golden"`` — **deprecated** escape hatch: the pre-jacobian
      golden-section bracket shrink, kept one release so the re-baseline
      is reversible (docs/known-issues.md).  Emits a
      ``DeprecationWarning``; both refiners converge to the same bracket
      optimum within ~1e-9 relative.
    """
    if not isinstance(traces, TraceSet):
        traces = TraceSet(scaling=tuple(traces))
    if refine not in REFINE_METHODS:
        raise ValueError(
            f"unknown refine method {refine!r} (choose from "
            f"{REFINE_METHODS})")
    if refine == "golden":
        warnings.warn(
            "refine='golden' is deprecated: the golden-section refiner "
            "is retired in favor of jacobian-based Gauss-Newton (same "
            "optimum, fewer residual evaluations, curvature CIs); this "
            "escape hatch will be removed once the re-baseline has "
            "soaked", DeprecationWarning, stacklevel=2)
    if not traces.scaling:
        return ScalingFit(f=np.zeros(0), bs=np.zeros(0), rss=np.zeros(0),
                          traces=(), utilization=utilization,
                          backend=backend, f_sigma=np.zeros(0),
                          bs_sigma=np.zeros(0), refine=refine)
    if utilization not in UTILIZATION_MODES:
        raise ValueError(f"unknown utilization mode {utilization!r}")
    f_grid = DEFAULT_F_GRID if f_grid is None else np.asarray(f_grid)
    n, y, mask, tr = traces.to_arrays()
    backend = backend_mod.resolve(backend, n.shape[0],
                                  jax_cutoff=jax_cutoff)
    with trace_mod.span("calibrate.fit", cells=int(n.shape[0]),
                        backend=backend, utilization=utilization,
                        refine=refine) as sp:
        if backend == "jax":
            f_hat, bs_hat, rss, f_sig, bs_sig = _fit_cells_jax(
                n, y, mask, f_grid, utilization, p0_factor, refine)
        else:
            f_hat, bs_hat, rss, f_sig, bs_sig = _fit_cells_np(
                n, y, mask, f_grid, utilization, p0_factor, refine)
        n_evals = _refine_evals(refine, len(f_grid))
        if trace_mod.enabled():
            # Per-cell evals and convergence: rss is the converged
            # residual sum of squares of each (kernel, arch, seed) cell.
            sp.set(evals_per_cell=n_evals,
                   rss_max=float(rss.max()) if rss.size else 0.0,
                   rss_median=float(np.median(rss)) if rss.size else 0.0)
            metrics.counter("calibrate.fit.cells").inc(int(n.shape[0]))
            metrics.counter("calibrate.fit.evals").inc(
                n_evals * int(n.shape[0]))
            for r in rss:
                metrics.histogram("calibrate.fit.rss").observe(float(r))
    return ScalingFit(f=f_hat, bs=bs_hat, rss=rss, traces=tuple(tr),
                      utilization=utilization, backend=backend,
                      f_sigma=f_sig, bs_sigma=bs_sig, refine=refine,
                      n_evals=n_evals)


def fit_scaling_cell(trace: ScalingTrace, **kwargs) -> tuple[float, float]:
    """Scalar convenience: fit one trace, return ``(f, b_s)``.  The
    sequential per-cell baseline the benchmark compares the batched pass
    against is a Python loop over this function."""
    fit = fit_scaling([trace], **kwargs)
    return float(fit.f[0]), float(fit.bs[0])


# ---------------------------------------------------------------------------
# Seed-ensemble aggregation → calibrated specs
# ---------------------------------------------------------------------------


def aggregate_ensemble(fit: ScalingFit, *, ci: float = 0.9
                       ) -> dict[tuple[str, str],
                                 dict[str, CalibratedValue]]:
    """Collapse a seed ensemble into per-(kernel, arch) estimates.

    Returns ``{(kernel, arch): {"f": CalibratedValue,
    "bs": CalibratedValue}}`` with the median as the point estimate and
    the central ``ci`` percentile interval over seeds as the confidence
    band (degenerate — lo == hi == value — for single-seed cells).
    The per-seed curvature sigmas (when the fit carries them) aggregate
    as their median into :attr:`CalibratedValue.sigma` — the
    within-trace counterpart of the across-seed percentile band.
    """
    lo_q, hi_q = 50 * (1 - ci), 50 * (1 + ci)
    out: dict[tuple[str, str], dict[str, CalibratedValue]] = {}
    for key, idx in fit.cells().items():
        cell: dict[str, CalibratedValue] = {}
        for field, arr, sig in (("f", fit.f, fit.f_sigma),
                                ("bs", fit.bs, fit.bs_sigma)):
            vals = arr[idx]
            cell[field] = CalibratedValue(
                value=float(np.median(vals)),
                lo=float(np.percentile(vals, lo_q)),
                hi=float(np.percentile(vals, hi_q)),
                n_seeds=len(idx),
                sigma=float(np.median(sig[idx])) if sig is not None
                else 0.0)
        out[key] = cell
    return out


def calibrated_specs(fit: ScalingFit, *,
                     templates: Mapping[str, KernelSpec] | None = None,
                     ci: float = 0.9) -> dict[str, KernelSpec]:
    """Materialize a fit as first-class :class:`KernelSpec` objects.

    Each kernel present in the fit gets one spec whose ``f``/``bs``
    mappings cover every fitted architecture (ensemble medians).  When a
    ``templates`` mapping (default: Table II) has a spec of the same
    name, its stream decomposition is inherited via
    :meth:`KernelSpec.from_calibration`, so ECM prediction and the
    desync engines consume the calibrated spec unchanged.
    """
    templates = TABLE2 if templates is None else templates
    agg = aggregate_ensemble(fit, ci=ci)
    per_kernel: dict[str, tuple[dict, dict]] = {}
    for (kern, arch), cell in sorted(agg.items()):
        f_map, bs_map = per_kernel.setdefault(kern, ({}, {}))
        f_map[arch] = min(cell["f"].value, 1.0)
        bs_map[arch] = cell["bs"].value
    return {
        kern: KernelSpec.from_calibration(
            kern, f_map, bs_map, template=templates.get(kern))
        for kern, (f_map, bs_map) in per_kernel.items()
    }


# ---------------------------------------------------------------------------
# Saturation-envelope fit from paired measurements (Eq. 4 in reverse)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EnvelopeFit:
    """Per-arch least-squares solution of Eq. 4 from paired totals:
    ``bs[arch][kernel]`` is the kernel's inferred homogeneous saturated
    bandwidth; any mix's envelope follows as the thread-weighted mean."""

    bs: dict[str, dict[str, float]]
    residual: dict[str, float]     # RMS of (measured − fitted) totals

    def envelope(self, arch: str, groups: Sequence[tuple[str, int]]
                 ) -> float:
        """Eq. 4 for an arbitrary mix ``[(kernel, n), ...]`` on ``arch``."""
        n_tot = sum(n for _, n in groups)
        if n_tot == 0:
            return 0.0
        return sum(n * self.bs[arch][k] for k, n in groups) / n_tot


def fit_envelope(pairs: Sequence[PairTrace]) -> EnvelopeFit:
    """Recover per-kernel ``b_s`` from saturated paired totals.

    Eq. 4 makes the mix envelope *linear* in the per-kernel saturated
    bandwidths: ``b_total = Σ (n_i / n_tot) · b_s,i``.  Stacking every
    pair trace of an architecture gives an overdetermined linear system,
    solved here per arch via ridge-stabilized normal equations — all
    architectures in one batched ``np.linalg.solve`` call.
    """
    pairs = tuple(pairs)
    if not pairs:
        return EnvelopeFit(bs={}, residual={})
    archs = sorted({p.arch for p in pairs})
    kernels = sorted({k for p in pairs for k in p.kernels})
    a_idx = {a: i for i, a in enumerate(archs)}
    k_idx = {k: i for i, k in enumerate(kernels)}
    A, K = len(archs), len(kernels)
    gram = np.zeros((A, K, K))
    rhs = np.zeros((A, K))
    rows: dict[str, list[tuple[np.ndarray, float]]] = {a: [] for a in archs}
    for p in pairs:
        row = np.zeros(K)
        n_tot = sum(p.n)
        for k, n in zip(p.kernels, p.n):
            row[k_idx[k]] += n / n_tot
        y = sum(p.bandwidth)
        ai = a_idx[p.arch]
        gram[ai] += np.outer(row, row)
        rhs[ai] += row * y
        rows[p.arch].append((row, y))
    # Tiny ridge keeps uncovered kernels solvable; they come out ~0 and
    # are reported as NaN below.
    ridge = 1e-9 * np.maximum(np.trace(gram, axis1=1, axis2=2), 1.0) / K
    gram += ridge[:, None, None] * np.eye(K)[None]
    sol = np.linalg.solve(gram, rhs[..., None])[..., 0]      # (A, K)
    covered = np.zeros((A, K), dtype=bool)
    for p in pairs:
        for k in p.kernels:
            covered[a_idx[p.arch], k_idx[k]] = True
    bs = {a: {k: (float(sol[a_idx[a], k_idx[k]])
                  if covered[a_idx[a], k_idx[k]] else float("nan"))
              for k in kernels}
          for a in archs}
    residual = {}
    for a in archs:
        errs = [y - float(row @ sol[a_idx[a]]) for row, y in rows[a]]
        residual[a] = float(np.sqrt(np.mean(np.square(errs))))
    return EnvelopeFit(bs=bs, residual=residual)


# ---------------------------------------------------------------------------
# Paired-share prediction from calibrated specs (one batched solve)
# ---------------------------------------------------------------------------


def predict_pairs(specs: Mapping[str, KernelSpec],
                  pairs: Sequence[PairTrace], *,
                  utilization: str | float = "queue") -> np.ndarray:
    """Model-predicted per-group bandwidths for every pair trace, solved
    in **one** :func:`repro.core.sharing.solve_batch` call (the PR-2
    batch machinery).  Returns ``(len(pairs), 2)`` GB/s."""
    pairs = tuple(pairs)
    if not pairs:
        return np.zeros((0, 2))
    n = np.array([p.n for p in pairs], dtype=np.float64)
    f = np.array([[specs[k].f[p.arch] for k in p.kernels] for p in pairs])
    bs = np.array([[specs[k].bs[p.arch] for k in p.kernels]
                   for p in pairs])
    batch = solve_batch(n, f, bs, utilization=utilization)
    return batch.bw_group
