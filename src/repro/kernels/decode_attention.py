"""Pallas TPU decode-attention kernel: one new token against a KV cache.

Decode is the memory-bound kernel par excellence — arithmetic intensity
~O(1) flop/byte, so it is the TPU analogue of the paper's streaming suite
and a first-class citizen of the bandwidth-sharing analysis (the
``decode_32k`` / ``long_500k`` shapes).

Grid: (batch, kv_heads, kv_blocks); the kv dimension is innermost and
sequential, carrying online-softmax state in VMEM scratch.  All query heads
in a GQA group are processed together as a (group, d) tile — the cache block
is loaded once per group rather than once per head, cutting HBM traffic by
the group factor (this IS the GQA bandwidth win, expressed as a BlockSpec).
Positions beyond ``lengths[b]`` are masked via a scalar-prefetch operand.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
STATS_LANES = 128


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, out_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, bk: int,
                   n_kv_blocks: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]

    @pl.when(ik * bk < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (group, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (group, bk)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True),
            l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        out_ref[0, 0] = (acc_ref[...] / l).astype(out_ref.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, scale: float | None = None,
                     block_k: int = 512, interpret: bool = True
                     ) -> jax.Array:
    """Single-token attention against a KV cache.

    Args:
      q: (B, H, D) — current-step queries.
      k_cache, v_cache: (B, KV, S, D).
      lengths: (B,) int32 — valid cache length per sequence.
    Returns:
      (B, H, D).
    """
    b, h, d = q.shape
    _, kv, s, _ = k_cache.shape
    if h % kv:
        raise ValueError(f"H={h} not a multiple of KV={kv}")
    group = h // kv
    scale = (d ** -0.5) if scale is None else scale
    bk = min(block_k, s)
    if s % bk:
        raise ValueError(f"cache len {s} not divisible by block {bk}")
    n_k = s // bk

    # (B, KV, group, D): all query heads of one kv group contiguous.
    qg = q.reshape(b, kv, group, d)

    # With num_scalar_prefetch=1, every index_map receives the prefetched
    # scalar ref as an extra trailing argument.
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kv, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda ib, ih, ik, lens: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda ib, ih, ik, lens: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda ib, ih, ik, lens: (ib, ih, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda ib, ih, ik, lens: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, STATS_LANES), jnp.float32),
            pltpu.VMEM((group, STATS_LANES), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )

    kernel = functools.partial(_decode_kernel, scale=scale, bk=bk,
                               n_kv_blocks=n_k)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, group, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(b, h, d)
