"""Overlap scheduler: decides compute/collective co-scheduling using the
paper's bandwidth-sharing model (core/overlap.py).

Given the roofline decomposition of a training step (from the dry-run HLO or
from analytic estimates), it answers:
  * should the gradient reduce-scatter overlap the backward pass at all?
  * if so, into how many buckets should it be split?
  * what is the predicted step time under each policy?

The classical heuristic ("always overlap, assume it's free") over-predicts
speedup when the collective's HBM drain contends with the backward matmuls'
streams — exactly the effect the paper models with Eqs. 4–5.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from ..configs.base import ModelConfig
from ..core.hlo import RooflineTerms
from ..core.machine import TPU_V5E, TpuModel
from ..core.overlap import Phase, best_bucket_count, overlap_pair
from ..core.topology import Topology, tpu_pod


@dataclasses.dataclass(frozen=True)
class OverlapPlan:
    overlap: bool
    n_buckets: int
    t_serial: float
    t_planned: float
    t_naive_roofline: float     # what "perfect overlap" would promise

    @property
    def predicted_gain(self) -> float:
        return self.t_serial / self.t_planned if self.t_planned else 1.0


def plan_gradient_overlap(terms: RooflineTerms, *,
                          backward_frac: float = 2 / 3,
                          tpu: TpuModel = TPU_V5E) -> OverlapPlan:
    """Build the overlap plan from a step's roofline terms.

    ``backward_frac``: share of compute/HBM belonging to the backward pass
    (2/3 for standard fwd+bwd without remat; remat shifts it higher).
    """
    bwd = Phase("bwd",
                flops=terms.flops * backward_frac,
                hbm_bytes=terms.hbm_bytes * backward_frac)
    # The gradient collective: its wire bytes on ICI, and an HBM drain of
    # the same magnitude (send buffers are read + recv written once).
    coll = Phase("grad_rs",
                 ici_bytes=terms.wire_bytes,
                 hbm_bytes=2.0 * terms.wire_bytes)
    t_serial = bwd.t_solo(tpu) + coll.t_solo(tpu)
    nb, t_planned = best_bucket_count(bwd, coll, tpu=tpu)
    pred = overlap_pair(bwd, coll, tpu)
    return OverlapPlan(
        overlap=nb > 0 and t_planned < t_serial * 0.995,
        n_buckets=max(nb, 1),
        t_serial=t_serial,
        t_planned=min(t_planned, t_serial),
        t_naive_roofline=pred.t_naive,
    )


@dataclasses.dataclass(frozen=True)
class PodOverlapPlan:
    """Per-chip overlap plans across a pod slice: each chip's HBM domain is
    independent, so the step time is gated by the slowest chip."""

    topology: Topology
    by_chip: Mapping[str, OverlapPlan]

    @property
    def t_step(self) -> float:
        """Data-parallel step time: the allreduce gates on the slowest
        chip's planned time."""
        return max(p.t_planned for p in self.by_chip.values())

    @property
    def straggler_chip(self) -> str:
        return max(self.by_chip, key=lambda c: self.by_chip[c].t_planned)


def plan_pod_overlap(terms: RooflineTerms, *,
                     topology: Topology | None = None,
                     chip_load: Sequence[float] | None = None,
                     backward_frac: float = 2 / 3,
                     tpu: TpuModel = TPU_V5E) -> PodOverlapPlan:
    """Plan gradient overlap per chip of a pod topology.

    Each leaf domain of ``topology`` (default: a 4-chip v5e pod from
    :func:`repro.core.topology.tpu_pod`) is planned independently —
    contention domains do not interact, so a straggling chip changes only
    its own plan.  ``chip_load`` scales each chip's compute/HBM work
    (data-parallel imbalance, e.g. ragged batch shards); default uniform.
    """
    topo = topology if topology is not None else tpu_pod(tpu)
    chips = topo.domain_names
    load = tuple(chip_load) if chip_load is not None else (1.0,) * len(chips)
    if len(load) != len(chips):
        raise ValueError(
            f"chip_load has {len(load)} entries for {len(chips)} chips")
    by_chip = {}
    for chip, scale in zip(chips, load):
        scaled = dataclasses.replace(
            terms,
            t_compute=terms.t_compute * scale,
            t_memory=terms.t_memory * scale,
            flops=terms.flops * scale,
            hbm_bytes=terms.hbm_bytes * scale)
        by_chip[chip] = plan_gradient_overlap(
            scaled, backward_frac=backward_frac, tpu=tpu)
    return PodOverlapPlan(topology=topo, by_chip=by_chip)
