"""Trace-contract linter: static audit of the substrate's invariants.

The batched substrate (``core/backend.py`` + compiled plans) relies on
contracts that nothing enforced until now — they fail silently, as
recompiles or wrong-but-plausible numbers, never as exceptions.  This
module checks them statically and reports *suggestion-bearing*
diagnostics in the registry's error style:

``weak-const``
    A 0-d constant is baked into the traced closure.  Every rebind of
    the closure (a Python scalar captured from an outer scope, a
    freshly-built 0-d array) re-traces and re-compiles; passed as an
    argument it would be a stable tracer instead.

``bucket-bypass``
    A jit boundary is traced at a large, non-power-of-two leading
    shape, bypassing the substrate's bucket policy
    (:func:`repro.core.backend.bucket`): a sweep over nearby sizes
    compiles one executable per size instead of O(log B) total.  On a
    plan, the check is that its cached ``bucket`` still matches the
    policy (drift guard for subclasses / deserialized plans).

``f64-promotion``
    Under x64, a strongly-typed float64 scalar (``np.float64``, a 0-d
    f64 array) silently promotes a float32 kernel to float64 — double
    the traffic, and a different executable than the f32 trace.  On a
    plan, the packed solver arrays must already be float64: float32
    arrays are promoted on *every* run.

``padding-escape``
    A placed grid's padding lanes must stay exactly neutral
    (``n = f = b_s = 0`` wherever ``mask`` is False) and its occupied
    lanes finite — a swap/broadcast that writes live numbers into
    masked lanes corrupts every masked reduction downstream.

Entry points: :func:`lint_callable` (trace-level rules),
:func:`lint_plan` / :func:`lint_grid` (compiled-artifact rules), and
the :func:`lint` dispatcher.  ``python -m repro.analysis.report
--lint`` runs the whole catalog over the in-repo kernels and plans.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable, Sequence

import numpy as np

from ..core import backend as backend_mod
from ..core import topology as topology_mod
from ..core.backend import HAVE_JAX

if HAVE_JAX:
    import jax

#: Rule catalog: identifier -> one-line description (docs/analysis.md
#: renders this table; ``rules=`` arguments validate against it).
RULES = {
    "weak-const": "0-d constant baked into a traced closure "
                  "(re-traces on every rebind)",
    "bucket-bypass": "jit boundary traced at a large non-power-of-two "
                     "leading shape (one executable per size)",
    "f64-promotion": "silent float32 -> float64 promotion under x64, "
                     "or non-float64 packed solver arrays",
    "padding-escape": "placed-grid padding carries live numbers outside "
                      "its mask (or masked-in cells are non-finite)",
}

#: Leading sizes below this never trip ``bucket-bypass``: tiny shapes
#: re-trace cheaply and are usually structural, not batch axes.
MIN_BUCKET_DIM = 64
MIN_BUCKET_ELEMS = 1024


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One linter finding, registry-style: what broke, where, and the
    concrete fix."""

    rule: str          # key of RULES
    severity: str      # "error" | "warning"
    target: str        # what was linted ("map_stream", "plan[batch]")
    message: str
    suggestion: str

    def __str__(self) -> str:
        return (f"[{self.rule}] {self.target}: {self.message} "
                f"— fix: {self.suggestion}")


def _check_rules(rules: Iterable[str] | None) -> tuple[str, ...]:
    if rules is None:
        return tuple(RULES)
    rules = tuple(rules)
    for r in rules:
        if r not in RULES:
            from ..api.registry import unknown_key_error
            raise unknown_key_error("lint rule", r, tuple(RULES))
    return rules


def _iter_jaxprs(jaxpr):
    """The jaxpr and every sub-jaxpr reachable from it (call-like
    primitives, control flow, pallas kernel bodies)."""
    stack = [jaxpr]
    seen = set()
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        yield j
        for eqn in j.eqns:
            for key in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr"):
                sub = eqn.params.get(key)
                if sub is not None:
                    stack.append(getattr(sub, "jaxpr", sub))
            for branch in eqn.params.get("branches", ()) or ():
                stack.append(getattr(branch, "jaxpr", branch))


# ---------------------------------------------------------------------------
# Callable rules
# ---------------------------------------------------------------------------


def _lint_weak_const(closed, target: str) -> list[Diagnostic]:
    out = []
    for i, const in enumerate(closed.consts):
        shape = getattr(const, "shape", None)
        if shape == ():
            val = np.asarray(const).item()
            out.append(Diagnostic(
                rule="weak-const", severity="warning", target=target,
                message=f"0-d constant ({val!r}) is baked into the "
                        f"traced closure (const #{i}); rebinding the "
                        f"closure re-traces and re-compiles",
                suggestion="pass the scalar as a traced argument (or "
                           "bind it with functools.partial of a "
                           "hashable static value)"))
    return out


def _lint_bucket_bypass(closed, target: str) -> list[Diagnostic]:
    out = []
    for jaxpr in _iter_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name != "pjit":
                continue
            for iv in eqn.invars:
                aval = getattr(iv, "aval", None)
                shape = getattr(aval, "shape", ())
                if not shape:
                    continue
                lead = int(shape[0])
                size = int(math.prod(shape))
                if (lead >= MIN_BUCKET_DIM and size >= MIN_BUCKET_ELEMS
                        and backend_mod.bucket(lead) != lead):
                    out.append(Diagnostic(
                        rule="bucket-bypass", severity="warning",
                        target=target,
                        message=f"jit boundary traced at leading shape "
                                f"{lead} (operand {tuple(shape)}); a "
                                f"sweep over nearby sizes compiles one "
                                f"executable per size",
                        suggestion=f"pad the leading axis to the "
                                   f"substrate bucket "
                                   f"(repro.core.backend.bucket({lead})"
                                   f" = {backend_mod.bucket(lead)}, "
                                   f"pad_rows) and mask/slice back"))
    return out


def _lint_f64_promotion(fn, args, target: str) -> list[Diagnostic]:
    if not HAVE_JAX:
        return []
    try:
        from jax.experimental import enable_x64
        with enable_x64():
            closed = jax.make_jaxpr(fn)(*args)
    except Exception:  # noqa: BLE001 — a fn that only traces in x32
        return []      # mode cannot promote; nothing to report
    out = []
    for jaxpr in _iter_jaxprs(closed.jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name != "convert_element_type":
                continue
            src = getattr(eqn.invars[0], "aval", None)
            dst = eqn.params.get("new_dtype")
            if src is None or dst is None:
                continue
            if str(src.dtype) == "float32" and str(dst) == "float64":
                out.append(Diagnostic(
                    rule="f64-promotion", severity="warning",
                    target=target,
                    message="a strongly-typed float64 scalar/array in "
                            "the trace promotes float32 data to "
                            "float64 under x64 (double the traffic, a "
                            "second executable)",
                    suggestion="use a Python float (weak type) or cast "
                               "the constant to the kernel dtype "
                               "(jnp.float32(...)) before tracing"))
                break  # one diagnostic per trace is enough signal
        if out:
            break
    return out


def lint_callable(fn: Callable, *args: Any, name: str | None = None,
                  rules: Iterable[str] | None = None) -> list[Diagnostic]:
    """Run the trace-level rules over ``fn(*args)`` (traced, never
    executed).  Unknown rule names fail with a suggestion."""
    active = _check_rules(rules)
    if not HAVE_JAX:
        return []
    target = name or getattr(fn, "__name__", None) or \
        getattr(getattr(fn, "func", None), "__name__", "callable")
    closed = jax.make_jaxpr(fn)(*args)
    out: list[Diagnostic] = []
    if "weak-const" in active:
        out += _lint_weak_const(closed, target)
    if "bucket-bypass" in active:
        out += _lint_bucket_bypass(closed, target)
    if "f64-promotion" in active:
        out += _lint_f64_promotion(fn, args, target)
    return out


# ---------------------------------------------------------------------------
# Plan / grid rules
# ---------------------------------------------------------------------------


def lint_grid(grid: topology_mod.PlacedGrid, *, target: str = "grid",
              rules: Iterable[str] | None = None) -> list[Diagnostic]:
    """``padding-escape`` over one packed ``(B, D, K)`` grid."""
    active = _check_rules(rules)
    out: list[Diagnostic] = []
    if "padding-escape" not in active:
        return out
    mask = np.asarray(grid.mask)
    for field in ("n", "f", "bs"):
        arr = np.asarray(getattr(grid, field))
        escaped = (~mask) & (arr != 0)
        if escaped.any():
            b, d, k = (int(x[0]) for x in np.nonzero(escaped))
            out.append(Diagnostic(
                rule="padding-escape", severity="error", target=target,
                message=f"padding lane (b={b}, d={d}, k={k}) carries "
                        f"{field} = {arr[b, d, k]!r} outside the "
                        f"occupancy mask; masked reductions downstream "
                        f"will absorb it",
                suggestion="re-pack with repro.core.topology."
                           "pack_placed (padding must stay exactly "
                           "zero), or zero the swapped array under "
                           "~mask before run()"))
    live = np.asarray(grid.f, dtype=float), np.asarray(grid.bs, dtype=float)
    for field, arr in zip(("f", "bs"), live):
        bad = mask & ~np.isfinite(arr)
        if bad.any():
            b, d, k = (int(x[0]) for x in np.nonzero(bad))
            out.append(Diagnostic(
                rule="padding-escape", severity="error", target=target,
                message=f"occupied cell (b={b}, d={d}, k={k}) has "
                        f"non-finite {field} = {arr[b, d, k]!r}",
                suggestion="check the spec/calibration that produced "
                           "this cell; the solvers assume finite "
                           "inputs on every masked-in lane"))
    return out


def _lint_plan_arrays(arrays: dict[str, np.ndarray], target: str
                      ) -> list[Diagnostic]:
    out = []
    for field, arr in arrays.items():
        if arr.dtype != np.float64:
            out.append(Diagnostic(
                rule="f64-promotion", severity="warning", target=target,
                message=f"packed solver array {field!r} has dtype "
                        f"{arr.dtype}; the solvers compute in float64, "
                        f"so every run() pays a promotion copy",
                suggestion="pack float64 once (np.asarray(..., "
                           "np.float64)) instead of promoting per run"))
    return out


def lint_plan(plan, *, rules: Iterable[str] | None = None
              ) -> list[Diagnostic]:
    """Run the compiled-artifact rules over one :class:`repro.api.Plan`.

    Scalar / placed / simulate plans carry no packed solver arrays or
    padding masks, so they lint clean by construction."""
    from ..api import plan as plan_mod
    active = _check_rules(rules)
    out: list[Diagnostic] = []
    if isinstance(plan, plan_mod.BatchPlan):
        target = "plan[batch]"
        if "f64-promotion" in active:
            out += _lint_plan_arrays(
                {"n": plan.n, "f": plan.f, "bs": plan.bs}, target)
        if "bucket-bypass" in active:
            expect = (backend_mod.bucket(len(plan)), plan.n.shape[1])
            if tuple(plan.bucket) != expect:
                out.append(Diagnostic(
                    rule="bucket-bypass", severity="warning",
                    target=target,
                    message=f"plan.bucket = {tuple(plan.bucket)} no "
                            f"longer matches the substrate policy "
                            f"{expect}; its jit-cache entry will not "
                            f"be shared",
                    suggestion="recompile the plan (api.compile) "
                               "instead of carrying one across a "
                               "bucket-policy change"))
    elif isinstance(plan, plan_mod.PlacedBatchPlan):
        target = "plan[placed-batch]"
        if "f64-promotion" in active:
            out += _lint_plan_arrays(
                {"grid.n": plan.grid.n, "grid.f": plan.grid.f,
                 "grid.bs": plan.grid.bs}, target)
        if "padding-escape" in active:
            out += lint_grid(plan.grid, target=target,
                             rules=("padding-escape",))
        if "bucket-bypass" in active:
            B, D, K = plan.grid.n.shape
            expect = (backend_mod.bucket(B * D), K)
            if tuple(plan.bucket) != expect:
                out.append(Diagnostic(
                    rule="bucket-bypass", severity="warning",
                    target=target,
                    message=f"plan.bucket = {tuple(plan.bucket)} no "
                            f"longer matches the substrate policy "
                            f"{expect}",
                    suggestion="recompile the plan (api.compile)"))
    return out


def lint(obj, *args: Any, **kwargs: Any) -> list[Diagnostic]:
    """Dispatch: a :class:`PlacedGrid` or :class:`Plan` goes to the
    artifact rules, anything callable to the trace rules."""
    from ..api import plan as plan_mod
    if isinstance(obj, topology_mod.PlacedGrid):
        return lint_grid(obj, **kwargs)
    if isinstance(obj, plan_mod.Plan):
        return lint_plan(obj, **kwargs)
    if callable(obj):
        return lint_callable(obj, *args, **kwargs)
    raise TypeError(
        f"cannot lint {type(obj).__name__}: expected a callable, a "
        f"Plan, or a PlacedGrid")
