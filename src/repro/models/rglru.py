"""RecurrentGemma-style hybrid: RG-LRU recurrent blocks + local attention.

recurrentgemma-2b: 26 layers in the cyclic pattern (rec, rec, local-attn);
MQA (kv=1) with a 2048-token sliding window; GeGLU MLP after every mixer.

RG-LRU recurrence (per channel):
  r_t = sigmoid(W_a x_t + b_a)           # recurrence gate
  i_t = sigmoid(W_x x_t + b_x)           # input gate
  log a_t = -c * softplus(Λ) * r_t       # c = 8
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ x_t)

Training uses an associative scan over the sequence (O(log S) depth); decode
keeps the (B, lru_width) hidden state — O(1) memory in context length, which
is why this arch (with mamba2) runs the long_500k shape.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers

LRU_C = 8.0
CONV_W = 4


def _lru_width(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------


def rec_block_params(cfg: ModelConfig, key):
    d, w = cfg.d_model, _lru_width(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "wx": layers.dense_init(ks[0], d, w, dt),
        "wy": layers.dense_init(ks[1], d, w, dt),
        "conv_w": (jax.random.normal(ks[2], (CONV_W, w), jnp.float32)
                   * 0.5).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "wa": layers.dense_init(ks[3], w, w, dt),
        "ba": jnp.zeros((w,), dt),
        "wi": layers.dense_init(ks[4], w, w, dt),
        "bi": jnp.zeros((w,), dt),
        "lam": jnp.full((w,), 2.0, dt),   # softplus(2) ~ 2.1 -> slow decay
        "wo": layers.dense_init(ks[5], w, d, dt),
    }


def _mixer_group_params(cfg: ModelConfig, key, kind: str):
    kmix, kmlp = jax.random.split(key)
    mix = (rec_block_params(cfg, kmix) if kind == "rec"
           else layers.attention_params(cfg, kmix))
    return {
        "ln1": layers.norm_params(cfg),
        "mix": mix,
        "ln2": layers.norm_params(cfg),
        "mlp": layers.mlp_params(cfg, kmlp),
    }


def _layer_plan(cfg: ModelConfig) -> list[str]:
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


def init_params(cfg: ModelConfig, key):
    plan = _layer_plan(cfg)
    ke, kl, *_ = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_layers)
    rec_keys = [k for k, t in zip(lkeys, plan) if t == "rec"]
    attn_keys = [k for k, t in zip(lkeys, plan) if t == "attn"]
    p = {
        "embed": layers.embed_init(ke, cfg.vocab, cfg.d_model,
                                   jnp.dtype(cfg.param_dtype)),
        "ln_f": layers.norm_params(cfg),
    }
    if rec_keys:
        p["rec"] = jax.vmap(
            functools.partial(_mixer_group_params, cfg, kind="rec")
        )(jnp.stack(rec_keys))
    if attn_keys:
        p["attn"] = jax.vmap(
            functools.partial(_mixer_group_params, cfg, kind="attn")
        )(jnp.stack(attn_keys))
    return p


# --------------------------------------------------------------------------
# RG-LRU
# --------------------------------------------------------------------------


def _rglru_gates(lp, x):
    """x: (..., W) -> (log_a, gated input) in f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ lp["wa"].astype(jnp.float32)
                       + lp["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ lp["wi"].astype(jnp.float32)
                       + lp["bi"].astype(jnp.float32))
    log_a = -LRU_C * jax.nn.softplus(lp["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, gated


def _rglru_scan(lp, x):
    """Sequence RG-LRU via associative scan.  x: (B, S, W)."""
    a, gated = _rglru_gates(lp, x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype)


def _rec_mixer(cfg: ModelConfig, lp, x):
    """x: (B, S, D) -> (B, S, D)."""
    xb = x @ lp["wx"].astype(x.dtype)
    gate = x @ lp["wy"].astype(x.dtype)
    # causal depthwise conv width 4
    pads = [(0, 0), (CONV_W - 1, 0), (0, 0)]
    xp = jnp.pad(xb, pads)
    w = lp["conv_w"].astype(x.dtype)
    xb = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(CONV_W)) \
        + lp["conv_b"].astype(x.dtype)
    h = _rglru_scan(lp, xb)
    out = jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype) * h
    return out @ lp["wo"].astype(x.dtype)


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _block(cfg: ModelConfig, kind: str, lp, x, positions):
    h = layers.apply_norm(cfg, lp["ln1"], x)
    if kind == "rec":
        x = x + _rec_mixer(cfg, lp["mix"], h)
    else:
        x = x + layers.attention(cfg, lp["mix"], h, positions,
                                 local_window=cfg.local_window)
    h = layers.apply_norm(cfg, lp["ln2"], x)
    return x + layers.apply_mlp(cfg, lp["mlp"], h)


def hidden_states(cfg: ModelConfig, params, x, positions):
    """Scan over each block kind's stacked params, preserving the cyclic
    pattern.  The pattern is short-cycled (rec, rec, attn), so we scan the
    full cycles and unroll the remainder — HLO stays O(pattern), not O(L)."""
    plan = _layer_plan(cfg)
    body = _block
    if cfg.remat:
        body = layers.remat(cfg, _block, static_argnums=(0, 1))

    pat = cfg.block_pattern or ("rec", "rec", "attn")
    if not cfg.use_scan:
        idx = {"rec": 0, "attn": 0}
        for kind in plan:
            lp = jax.tree.map(lambda a: a[idx[kind]], params[kind])
            idx[kind] += 1
            x = body(cfg, kind, lp, x, positions)
        return layers.apply_norm(cfg, params["ln_f"], x)

    n_cycles = len(plan) // len(pat)
    # Split stacked params into the scanned cycles and the unrolled tail.
    counts = {"rec": 0, "attn": 0}
    for k in pat:
        counts[k] += 1

    def take(tree, lo, hi):
        return jax.tree.map(lambda a: a[lo:hi], tree)

    scanned = {}
    tails = {}
    for kind in ("rec", "attn"):
        if kind not in params:
            continue
        n_scan = counts[kind] * n_cycles
        head = take(params[kind], 0, n_scan)
        # Regroup (n_scan, ...) -> (n_cycles, per_cycle, ...).
        scanned[kind] = jax.tree.map(
            lambda a: a.reshape(n_cycles, counts[kind], *a.shape[1:]), head)
        tails[kind] = take(params[kind], n_scan, None)

    def cycle_body(carry, cyc):
        x = carry
        idx = {"rec": 0, "attn": 0}
        for kind in pat:
            lp = jax.tree.map(lambda a: a[idx[kind]], cyc[kind])
            idx[kind] += 1
            x = body(cfg, kind, lp, x, positions)
        return x, None

    if n_cycles:
        x, _ = jax.lax.scan(cycle_body, x,
                            {k: v for k, v in scanned.items()})

    # Unrolled tail in pattern order.
    idx = {"rec": 0, "attn": 0}
    for kind in plan[n_cycles * len(pat):]:
        lp = jax.tree.map(lambda a: a[idx[kind]], tails[kind])
        idx[kind] += 1
        x = body(cfg, kind, lp, x, positions)
    return layers.apply_norm(cfg, params["ln_f"], x)


def forward(cfg: ModelConfig, params, tokens):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(tokens.shape[1])[None, :]
    x = hidden_states(cfg, params, x, positions)
    return layers.unembed(cfg, params["embed"], x)


def loss_fn(cfg: ModelConfig, params, batch):
    logits = forward(cfg, params, batch["tokens"])
    labels = batch["labels"]
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss, {"lm_loss": loss}


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Attention blocks: ring-buffer KV of the local window; recurrent
    blocks: (B, W) hidden + conv history — O(1) in context length."""
    plan = _layer_plan(cfg)
    n_rec = sum(k == "rec" for k in plan)
    n_attn = len(plan) - n_rec
    w = _lru_width(cfg)
    hd = cfg.head_dim_
    dt = jnp.dtype(cfg.dtype)
    window = min(cfg.local_window or max_seq, max_seq)
    return {
        "h": jnp.zeros((n_rec, batch, w), jnp.float32),
        "conv": jnp.zeros((n_rec, batch, CONV_W - 1, w), dt),
        "k": jnp.zeros((n_attn, batch, window, cfg.kv_heads, hd), dt),
        "v": jnp.zeros((n_attn, batch, window, cfg.kv_heads, hd), dt),
    }


def _rec_step(cfg, lp, x, h_state, conv_state):
    """x: (B, D) -> (B, D); O(1) state update."""
    xb = x @ lp["wx"].astype(x.dtype)
    gate = x @ lp["wy"].astype(x.dtype)
    hist = jnp.concatenate([conv_state, xb[:, None, :]], axis=1)
    w = lp["conv_w"].astype(x.dtype)
    xb = jnp.einsum("bwc,wc->bc", hist, w) + lp["conv_b"].astype(x.dtype)
    a, gated = _rglru_gates(lp, xb)
    h_new = a * h_state + gated
    out = jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype) \
        * h_new.astype(x.dtype)
    return out @ lp["wo"].astype(x.dtype), h_new, hist[:, 1:]


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    plan = _layer_plan(cfg)
    x = params["embed"][tokens[:, None]].astype(jnp.dtype(cfg.dtype))
    rec_i = attn_i = 0
    new = {k: [] for k in ("h", "conv", "k", "v")}
    for kind in plan:
        if kind == "rec":
            lp = jax.tree.map(lambda a: a[rec_i], params["rec"])
            h = layers.apply_norm(cfg, lp["ln1"], x)
            y, hs, cs = _rec_step(cfg, lp["mix"], h[:, 0], cache["h"][rec_i],
                                  cache["conv"][rec_i])
            x = x + y[:, None]
            new["h"].append(hs)
            new["conv"].append(cs)
            rec_i += 1
        else:
            lp = jax.tree.map(lambda a: a[attn_i], params["attn"])
            h = layers.apply_norm(cfg, lp["ln1"], x)
            y, ck, cv = layers.attention_decode(
                cfg, lp["mix"], h, cache["k"][attn_i], cache["v"][attn_i],
                pos, local_window=cfg.local_window)
            x = x + y
            new["k"].append(ck)
            new["v"].append(cv)
            attn_i += 1
        hm = layers.apply_norm(cfg, lp["ln2"], x)
        x = x + layers.apply_mlp(cfg, lp["mlp"], hm)
    x = layers.apply_norm(cfg, params["ln_f"], x)
    logits = layers.unembed(cfg, params["embed"], x)[:, 0]
    new_cache = {k: jnp.stack(v) if v else cache[k] for k, v in new.items()}
    return logits, new_cache
