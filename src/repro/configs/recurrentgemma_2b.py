"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1:2 pattern.
[arXiv:2402.19427; hf]"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    kv_heads=1,            # MQA
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    act="geglu",
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    lru_width=2560,
    tie_embeddings=True,
    logits_softcap=30.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=2, kv_heads=1, d_ff=128,
        vocab=512, head_dim=32, local_window=16, lru_width=64, remat=False,
        dtype="float32")
