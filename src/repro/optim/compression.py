"""Gradient compression for cross-pod reduction (distributed-optimization
trick): int8 block quantization with error feedback.

At 512+ chips the inter-pod all-reduce of f32 gradients is the dominant
collective-roofline term; int8 halves-to-quarters the wire bytes.  Error
feedback (Seide et al.) keeps the quantization bias out of the long-run
trajectory: the residual e is added back before the next quantization.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    return jnp.pad(x.reshape(-1), (0, pad)), n


def compress_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """g -> (int8 values, f32 per-block scales)."""
    flat, _ = _pad_to_block(g.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress_int8(q: jax.Array, scale: jax.Array, shape, dtype
                    ) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def error_feedback_compress(g: jax.Array, err: jax.Array
                            ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize (g + err); return (q, scale, new_err)."""
    corrected = g.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = compress_int8(corrected)
    deq = decompress_int8(q, scale, g.shape, jnp.float32)
    new_err = corrected - deq
    return q, scale, new_err.astype(err.dtype)
