"""granite-moe-1b-a400m [moe]: 32 experts, top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

import dataclasses

from .base import ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    kv_heads=8,
    d_ff=512,
    vocab=49155,
    act="swiglu",
    head_dim=64,
    moe=MoeConfig(n_experts=32, top_k=8, d_ff_expert=512),
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=64,
        vocab=512, head_dim=16,
        moe=MoeConfig(n_experts=4, top_k=2, d_ff_expert=64),
        remat=False, dtype="float32")
