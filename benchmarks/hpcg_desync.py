"""Paper Figs. 1 and 3: HPCG desynchronization phenomenology, reproduced in
the discrete-event simulator driven ONLY by the sharing model.

Scenarios (20 MPI ranks on one CLX socket, kernel sizes in the HPCG ratio —
SymGS ~20x DDOT):
  fig1  : SymGS -> DDOT2 -> MPI_Allreduce        (plain HPCG)
  fig3a : SymGS -> DDOT2 -> p2p wait -> SpMV     (modified, no allreduce)
  fig3b : SymGS -> DDOT2 -> DAXPY                (modified, no allreduce)

Each scenario is one declarative facade build; its 6-seed noise ensemble
advances in a single batched simulate() call instead of a per-seed loop.
Reported: skewness of accumulated DDOT2 time (paper: fig1/3a negative =
resync; fig3b positive = desync), start/end spreads, and the late-starters-
run-faster monotonicity of Fig. 1(c).
"""

from __future__ import annotations

import time

from repro import api

MB = 1e6
N_RANKS = 20
ARCH = "CLX"
N_SEEDS = 6

BASE = (api.Scenario.on(ARCH).ranks(N_RANKS)
        .with_noise(6e-5, seed=0, ensemble=N_SEEDS)
        .step("Schoenauer", 40 * MB, tag="symgs")
        .step("DDOT2", 8 * MB, tag="ddot2"))

SCENARIOS = {
    "fig1_allreduce_resync":
        BASE.barrier().step("DAXPY", 30 * MB, tag="daxpy"),
    "fig3a_p2p_spmv":
        BASE.halo().step("Schoenauer", 40 * MB, tag="spmv"),
    "fig3b_daxpy_desync":
        BASE.step("DAXPY", 30 * MB, tag="daxpy"),
}


def run_scenario(scenario):
    res = api.simulate(scenario, t_max=60)
    sss, ess, mono = [], [], []
    for b in range(N_SEEDS):
        recs = res.records(b)
        sss.append(res.start_spread("ddot2", b))
        ess.append(res.end_spread("ddot2", b))
        dd = sorted((r.start, r.duration) for r in recs if r.tag == "ddot2")
        k = len(dd) // 3
        early = sum(d for _, d in dd[:k]) / k
        late = sum(d for _, d in dd[-k:]) / k
        mono.append(early / late)
    n = N_SEEDS
    return (res.mean_skew("ddot2"), sum(sss) / n, sum(ess) / n,
            sum(mono) / n)


def rows():
    out = []
    for name, scenario in SCENARIOS.items():
        t0 = time.perf_counter()
        sk, ss, es, mono = run_scenario(scenario)
        us = (time.perf_counter() - t0) * 1e6 / N_SEEDS
        out.append((f"hpcg/{name}", us,
                    f"skew={sk:+.2f};start_spread={ss*1e3:.2f}ms;"
                    f"end_spread={es*1e3:.2f}ms;early/late_runtime="
                    f"{mono:.2f}"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
