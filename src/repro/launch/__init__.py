# Launch entry points: mesh construction, multi-pod dry-run, train, serve.
# NOTE: launch/dryrun.py must be executed as a script/module so its XLA_FLAGS
# device-count override precedes jax initialization; do not import it from
# library code.
