"""Fault-tolerant checkpointing: atomic, async, elastic.

* **Atomic**: each checkpoint is written to ``step_<N>.tmp/`` and renamed to
  ``step_<N>/`` only after every file (and a manifest with tree structure +
  a content digest) is fsync'd — a crash mid-write can never corrupt the
  restore path.
* **Async**: ``CheckpointManager.save_async`` snapshots device arrays to
  host memory synchronously (cheap) and writes in a background thread —
  training continues during the disk write.
* **Elastic**: arrays are stored unsharded (gathered per leaf); restore
  ``device_put``s onto whatever mesh/sharding the *new* job built, so a
  restart may change pod count, data-parallel width, or layout freely.
  Combined with the deterministic data pipeline this gives exact
  continue-from-step semantics after resizing.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


def _digest(arrays: list[np.ndarray]) -> str:
    h = hashlib.sha256()
    for a in arrays:
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes()[:4096])  # prefix digest: cheap corruption check
    return h.hexdigest()


def save_checkpoint(directory: str, step: int, tree, *,
                    extra: dict | None = None) -> str:
    """Write checkpoint synchronously; returns the final path."""
    arrays, treedef = _flatten(tree)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": a for i, a in enumerate(arrays)})
    manifest = {
        "step": step,
        "n_arrays": len(arrays),
        "treedef": str(treedef),
        "digest": _digest(arrays),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, name, MANIFEST)):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, like, *, shardings=None):
    """Restore a checkpoint into the structure of ``like``.

    ``shardings``: optional pytree (matching ``like``) of Sharding objects —
    the elastic-restore path places each leaf directly onto the new mesh.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = [z[f"a{i}"] for i in range(manifest["n_arrays"])]
    if manifest["digest"] != _digest(arrays):
        raise IOError(f"checkpoint {path} failed digest check")
    leaves_like, treedef = jax.tree.flatten(like)
    if len(arrays) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, expected "
            f"{len(leaves_like)} — architecture mismatch")
    if shardings is not None:
        shard_leaves = jax.tree.flatten(shardings)[0]
        arrays = [jax.device_put(a, s)
                  for a, s in zip(arrays, shard_leaves)]
    else:
        arrays = [jax.device_put(a.astype(l.dtype))
                  for a, l in zip(arrays, leaves_like)]
    return jax.tree.unflatten(treedef, arrays), manifest


class CheckpointManager:
    """Async, retention-managed checkpointing."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save_async(self, step: int, tree, *, extra: dict | None = None):
        self.wait()
        # Snapshot to host synchronously; write in background.
        arrays, treedef = _flatten(tree)
        host_tree = jax.tree.unflatten(treedef, arrays)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra=extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, like, *, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        tree, manifest = load_checkpoint(self.directory, step, like,
                                         shardings=shardings)
        return tree, manifest
