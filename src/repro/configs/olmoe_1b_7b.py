"""olmoe-1b-7b [moe]: 64 experts, top-8.  [arXiv:2409.02060; hf]"""

import dataclasses

from .base import ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    kv_heads=16,
    d_ff=1024,
    vocab=50304,
    act="swiglu",
    moe=MoeConfig(n_experts=64, top_k=8, d_ff_expert=1024),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=64,
        vocab=512, moe=MoeConfig(n_experts=8, top_k=2, d_ff_expert=64),
        remat=False, dtype="float32")
