"""One kernel-spec resolution chain for the whole library.

Before the facade, every entry style re-implemented its own lookup:
``sharing.Group.of`` indexed ``spec.f[arch]`` raw, the desync engines
indexed a ``specs`` dict raw, the calibration pipeline built specs via
``KernelSpec.from_calibration``, and the ECM route lived apart in
``core.ecm``.  This module is the single resolver all of them share:

    resolve(ref, arch=...)  ->  (KernelSpec, provenance)

accepting, in order of the chain:

1. a **Table II name** (``"DCOPY"``) — or a name in a caller-supplied
   ``specs`` mapping (provenance ``"table2"`` / ``"custom"``);
2. a ready **KernelSpec** (provenance ``"explicit"``, or ``"synthetic"``
   for specs minted by :meth:`KernelSpec.synthetic`);
3. a **calibration result** — a mapping with ``"f"``/``"bs"`` entries
   whose values are floats, per-arch mappings, or
   :class:`repro.calibrate.fit.CalibratedValue`-like objects (anything
   with a ``.value``) — materialized through
   :meth:`KernelSpec.from_calibration` (provenance ``"calibrated"``);
4. an ``(f, bs)`` **pair** of floats — a synthetic one-off spec
   (provenance ``"synthetic"``);
5. **static analysis** via :func:`from_static_analysis` — the loop
   features are *derived* from the kernel's own jaxpr by
   :mod:`repro.analysis` and fed through the same ECM bridge
   (provenance ``"static"``);
6. **loop features** via :func:`from_loop_features` — hand-written
   stream counts + flops, with ``f`` *predicted* by the ECM model
   instead of measured (provenance ``"ecm"``).

The provenance string travels into :class:`repro.api.results.Prediction`
so every number in a result can be traced back to where its ``(f, b_s)``
inputs came from.

The module also owns the shared *unknown-key* error helper: a lookup
miss anywhere in the library (kernel names, architectures, topology
presets) raises a ``KeyError`` that lists the known keys and suggests
the nearest name instead of echoing the bare key back.
"""

from __future__ import annotations

import dataclasses
import difflib
from typing import Mapping, Sequence

from ..core import ecm as ecm_model
from ..core.machine import MachineModel
from ..core.table2 import ARCHS, TABLE2, KernelSpec

#: Provenance labels, in resolution-chain order.
PROVENANCES = ("table2", "custom", "explicit", "synthetic", "calibrated",
               "static", "ecm")


# ---------------------------------------------------------------------------
# Unknown-key errors with suggestions (shared across the library)
# ---------------------------------------------------------------------------


def suggest(key: str, known: Sequence[str]) -> str | None:
    """Nearest known key by edit similarity, or ``None`` when nothing is
    close enough to be a plausible typo."""
    matches = difflib.get_close_matches(str(key), list(known), n=1,
                                        cutoff=0.5)
    return matches[0] if matches else None


def unknown_key_message(kind: str, key: str, known: Sequence[str]) -> str:
    """Error text for a failed ``kind`` lookup: the bad key, the nearest
    suggestion (if any), and the full sorted key list."""
    known = sorted(known)
    msg = f"unknown {kind} {key!r}"
    near = suggest(key, known)
    if near is not None:
        msg += f"; did you mean {near!r}?"
    msg += f" (known {kind}s: {known})"
    return msg


def unknown_key_error(kind: str, key: str,
                      known: Sequence[str]) -> KeyError:
    """A ``KeyError`` carrying :func:`unknown_key_message` — raise this
    from every lookup miss so callers always see their options."""
    return KeyError(unknown_key_message(kind, key, known))


def known_kernels(specs: Mapping[str, KernelSpec] | None = None
                  ) -> tuple[str, ...]:
    return tuple(sorted(TABLE2 if specs is None else specs))


def known_archs(spec: KernelSpec | None = None) -> tuple[str, ...]:
    return tuple(ARCHS) if spec is None else tuple(sorted(spec.f))


# ---------------------------------------------------------------------------
# The resolution chain
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResolvedSpec:
    """A spec plus where it came from (the facade's provenance record)."""

    spec: KernelSpec
    provenance: str  # one of PROVENANCES

    @property
    def name(self) -> str:
        return self.spec.name


def _calibrated_mapping(name: str, ref: Mapping, arch: str | None
                        ) -> ResolvedSpec:
    """Chain step 3: a ``{"f": ..., "bs": ...}`` calibration result.
    Values may be plain floats (then ``arch`` keys them), per-arch
    mappings, or CalibratedValue-likes (``.value`` is used)."""

    def per_arch(v):
        if hasattr(v, "value"):            # CalibratedValue duck-type
            v = v.value
        if isinstance(v, Mapping):
            return {a: (x.value if hasattr(x, "value") else float(x))
                    for a, x in v.items()}
        if arch is None:
            raise ValueError(
                f"calibrated spec {name!r} has scalar f/bs values; pass "
                f"arch= so they can be keyed")
        return {arch: float(v)}

    spec = KernelSpec.from_calibration(
        name, per_arch(ref["f"]), per_arch(ref["bs"]),
        template=TABLE2.get(name))
    return ResolvedSpec(spec=spec, provenance="calibrated")


def resolve(ref, *, arch: str | None = None,
            specs: Mapping[str, KernelSpec] | None = None,
            name: str | None = None) -> ResolvedSpec:
    """Resolve any accepted kernel reference to a (spec, provenance) pair.

    ``arch`` (when given) is validated against the resolved spec's
    architecture set, so resolution errors surface at *build* time with a
    suggestion, not as a bare ``KeyError`` deep inside a solver.
    ``specs`` overrides the Table II registry for name lookups (custom
    phase dictionaries, calibrated tables).  ``name`` labels anonymous
    refs (``(f, bs)`` pairs and calibration mappings).
    """
    if isinstance(ref, KernelSpec):
        prov = "explicit"
        if not ref.body and ref.name not in (specs or TABLE2):
            prov = "synthetic"  # minted via KernelSpec.synthetic / (f, bs)
        out = ResolvedSpec(spec=ref, provenance=prov)
    elif isinstance(ref, str):
        table = TABLE2 if specs is None else specs
        if ref not in table:
            raise unknown_key_error("kernel", ref, known_kernels(specs))
        out = ResolvedSpec(spec=table[ref],
                           provenance="table2" if specs is None
                           else "custom")
    elif isinstance(ref, Mapping) and "f" in ref and "bs" in ref:
        out = _calibrated_mapping(name or str(ref.get("name", "cal")),
                                  ref, arch)
    elif isinstance(ref, tuple) and len(ref) == 2 \
            and all(isinstance(x, (int, float)) for x in ref):
        f, bs = float(ref[0]), float(ref[1])
        out = ResolvedSpec(
            spec=KernelSpec.synthetic(name or f"synthetic(f={f:g})", f, bs,
                                      arch=arch or "TPU"),
            provenance="synthetic")
    else:
        raise TypeError(
            f"cannot resolve kernel reference {ref!r}: expected a Table II "
            f"name, a KernelSpec, a {{'f': .., 'bs': ..}} calibration "
            f"mapping, or an (f, bs) pair")
    if arch is not None and arch not in out.spec.f:
        raise unknown_key_error("architecture", arch,
                                known_archs(out.spec))
    return out


def _machine_for(machine: "MachineModel | str") -> MachineModel:
    """Accept a ready :class:`MachineModel` or an architecture name
    (looked up in the x86 machine table with a suggestion on a miss)."""
    if isinstance(machine, MachineModel):
        return machine
    if isinstance(machine, str):
        from ..core.machine import X86_MACHINES
        if machine not in X86_MACHINES:
            raise unknown_key_error("machine", machine, X86_MACHINES)
        return X86_MACHINES[machine]
    raise TypeError(
        f"machine must be a MachineModel or an architecture name, got "
        f"{type(machine).__name__}")


def from_loop_features(name: str, *, reads: int, writes: int, rfo: int,
                       flops_per_iter: float,
                       machine: MachineModel | str,
                       read_only: bool | None = None,
                       bandwidth_class: str | None = None) -> ResolvedSpec:
    """Chain step 6: build a spec from loop features alone, with ``f``
    *predicted* by the ECM model (Eqs. 1–2) and ``b_s`` taken from the
    machine's saturated-bandwidth class — the paper's "predicted using
    the ECM model" route, no measurement required.

    ``machine`` may be a :class:`MachineModel` or a Table II
    architecture name; ``bandwidth_class`` overrides the automatic
    ``read_only``/``read_write`` saturated-bandwidth selection.  Both
    lookups fail with the registry's suggestion-bearing unknown-key
    error rather than a bare ``KeyError``.
    """
    machine = _machine_for(machine)
    if read_only is None:
        read_only = writes == 0 and rfo == 0
    bclass = bandwidth_class if bandwidth_class is not None else \
        ("read_only" if read_only else "read_write")
    if bclass not in machine.saturated_bw_gbs:
        raise unknown_key_error("bandwidth class", bclass,
                                tuple(machine.saturated_bw_gbs))
    proto = KernelSpec(name=name, body="", reads=reads, writes=writes,
                       rfo=rfo, flops_per_iter=flops_per_iter,
                       f={}, bs={}, read_only=read_only)
    pred = ecm_model.predict(proto, machine)
    spec = dataclasses.replace(
        proto,
        f={machine.name: pred.f},
        bs={machine.name: machine.saturated_bw_gbs[bclass]})
    return ResolvedSpec(spec=spec, provenance="ecm")


def from_static_analysis(fn, args: Sequence = (), *,
                         machine: "MachineModel | str | None" = None,
                         name: str | None = None, reuse: bool = True,
                         write_allocate: bool = True) -> ResolvedSpec:
    """Chain step 5: derive the loop features *statically* from the
    kernel's own jaxpr (:mod:`repro.analysis`) and feed them through
    the ECM bridge — no hand-transcribed stream counts.

    ``fn(*args)`` must be jax-traceable (bind static arguments with
    ``functools.partial``).  ``machine=None`` predicts ``(f, b_s)`` for
    every Table II architecture; a single machine (model or name)
    restricts the spec to it.  ``reuse`` applies the layer condition to
    same-base load streams and ``write_allocate`` charges RFO streams
    for non-aliased stores — see :func:`repro.analysis.features.derive`.
    """
    # Lazy import: analysis sits above core and traces with jax; the
    # registry must stay importable without it (numpy-only installs).
    from ..analysis.features import features as _features
    lf = _features(fn, *args, name=name, reuse=reuse,
                   write_allocate=write_allocate)
    if machine is None:
        from ..core.machine import X86_MACHINES
        machines = list(X86_MACHINES.values())
    else:
        machines = [_machine_for(machine)]
    f: dict[str, float] = {}
    bs: dict[str, float] = {}
    last = None
    for m in machines:
        last = from_loop_features(
            lf.name, reads=lf.reads, writes=lf.writes, rfo=lf.rfo,
            flops_per_iter=lf.flops_per_iter, machine=m,
            read_only=lf.read_only)
        f.update(last.spec.f)
        bs.update(last.spec.bs)
    spec = dataclasses.replace(last.spec, f=f, bs=bs)
    return ResolvedSpec(spec=spec, provenance="static")
