"""Paper Figs. 1 and 3: HPCG desynchronization phenomenology, reproduced in
the discrete-event simulator driven ONLY by the sharing model.

Scenarios (20 MPI ranks on one CLX socket, kernel sizes in the HPCG ratio —
SymGS ~20x DDOT):
  fig1  : SymGS -> DDOT2 -> MPI_Allreduce        (plain HPCG)
  fig3a : SymGS -> DDOT2 -> p2p wait -> SpMV     (modified, no allreduce)
  fig3b : SymGS -> DDOT2 -> DAXPY                (modified, no allreduce)

Reported: skewness of accumulated DDOT2 time (paper: fig1/3a negative =
resync; fig3b positive = desync), start/end spreads, and the late-starters-
run-faster monotonicity of Fig. 1(c).
"""

from __future__ import annotations

import random
import time

from repro.core.desync import (Allreduce, DesyncSimulator, Idle,
                               WaitNeighbors, Work, durations_by_tag,
                               end_spread, skewness, start_spread)

MB = 1e6
N_RANKS = 20
ARCH = "CLX"


def _programs(tail, seed):
    rng = random.Random(seed)
    progs = []
    for _ in range(N_RANKS):
        progs.append([
            Idle(rng.expovariate(1 / 6e-5), tag="noise"),
            Work("Schoenauer", 40 * MB, tag="symgs"),
            Work("DDOT2", 8 * MB, tag="ddot2"),
            *tail,
        ])
    return progs


SCENARIOS = {
    "fig1_allreduce_resync": [Allreduce(), Work("DAXPY", 30 * MB,
                                                tag="daxpy")],
    "fig3a_p2p_spmv": [WaitNeighbors(tag="p2p"),
                       Work("Schoenauer", 40 * MB, tag="spmv")],
    "fig3b_daxpy_desync": [Work("DAXPY", 30 * MB, tag="daxpy")],
}


def run_scenario(tail, seeds=range(6)):
    sks, sss, ess, mono = [], [], [], []
    for s in seeds:
        sim = DesyncSimulator(_programs(tail, s), ARCH)
        recs = sim.run(t_max=60)
        sks.append(skewness(durations_by_tag(recs, "ddot2",
                                             n_ranks=N_RANKS)))
        sss.append(start_spread(recs, "ddot2"))
        ess.append(end_spread(recs, "ddot2"))
        dd = sorted((r.start, r.duration) for r in recs if r.tag == "ddot2")
        k = len(dd) // 3
        early = sum(d for _, d in dd[:k]) / k
        late = sum(d for _, d in dd[-k:]) / k
        mono.append(early / late)
    n = len(sks)
    return (sum(sks) / n, sum(sss) / n, sum(ess) / n, sum(mono) / n)


def rows():
    out = []
    for name, tail in SCENARIOS.items():
        t0 = time.perf_counter()
        sk, ss, es, mono = run_scenario(tail)
        us = (time.perf_counter() - t0) * 1e6 / 6
        out.append((f"hpcg/{name}", us,
                    f"skew={sk:+.2f};start_spread={ss*1e3:.2f}ms;"
                    f"end_spread={es*1e3:.2f}ms;early/late_runtime="
                    f"{mono:.2f}"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
