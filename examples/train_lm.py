"""End-to-end training driver: a small qwen2-family LM on synthetic data
with checkpointing and a simulated preemption mid-run.

Defaults are sized for the CPU container (a ~1M-param model, 120 steps).
On real hardware drop --tiny to train the ~0.5B qwen2-0.5b config via the
production launcher path (same code).

Run:  PYTHONPATH=src python examples/train_lm.py
"""

import argparse
import tempfile

import jax

from repro import configs
from repro.data import SyntheticLM
from repro.models import model_for
from repro.optim import constant
from repro.runtime import (SimulatedFailure, init_train_state,
                           run_with_restarts)
from repro.runtime.steps import build_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--tiny", action="store_true", default=True)
    ap.add_argument("--crash-at", type=int, default=60,
                    help="simulate a preemption at this step (0=off)")
    args = ap.parse_args()

    cfg = configs.get_reduced("qwen2-0.5b")
    model = model_for(cfg)
    dataset = SyntheticLM(cfg, seq_len=64, global_batch=8)

    crashed = {"armed": args.crash_at > 0}

    def failure_hook(step):
        if crashed["armed"] and step == args.crash_at:
            crashed["armed"] = False
            raise SimulatedFailure(f"preempted at step {step}")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        result = run_with_restarts(
            make_state=lambda: init_train_state(model, jax.random.key(0)),
            make_step_fn=lambda: jax.jit(
                build_train_step(model, lr_fn=constant(3e-4))),
            dataset=dataset,
            ckpt_dir=ckpt_dir,
            n_steps=args.steps,
            ckpt_every=25,
            failure_hook=failure_hook,
        )

    print(f"\nfinished at step {result.final_step} after "
          f"{result.restarts} restart(s) "
          f"(restored from step {result.restored_from})")
    k = max(1, len(result.losses) // 10)
    first = sum(result.losses[:k]) / k
    last = sum(result.losses[-k:]) / k
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    assert last < first, "training failed to reduce loss"


if __name__ == "__main__":
    main()
