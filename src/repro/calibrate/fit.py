"""Batched estimators: recover ``(f, b_s)`` from measured scaling curves.

The forward model is the paper's own (Eqs. 1–5, via
:func:`repro.core.sharing.utilization_curve`): a homogeneous run of a
kernel with request fraction ``f`` and saturated bandwidth ``b_s`` attains

    b(n) = b_s · U(n; f)

aggregate bandwidth on ``n`` cores, where ``U`` is the sub-saturation
utilization law — ``min(1, n·f)`` for the ideal queue interface (which is
also what the memsim instrument realizes) or the latency-penalty
recursion for real hardware.  Fitting inverts this curve: ``b_s`` from the
plateau, ``f`` from the single-core point and the knee position.

The estimator is a *profile least squares* over a fixed ``f`` grid: for
every candidate ``f`` the optimal ``b_s`` is closed-form (the model is
linear in ``b_s``), so the residual profile over the grid is computed for
**all (kernel, arch, seed) cells at once** — one vectorized numpy pass or
one ``jax.vmap``-ped, jitted pass, no per-cell Python loop — followed by
a parabolic sub-grid refinement of the winning ``f``.  Seed ensembles
aggregate into medians with percentile confidence intervals
(:func:`aggregate_ensemble`), and :func:`calibrated_specs` materializes
the result as first-class :class:`repro.core.table2.KernelSpec` objects
that ``Group.of``, the topology solver, and the desync engines consume
unchanged.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Mapping, Sequence

import numpy as np

from ..core import backend as backend_mod
from ..core.backend import HAVE_JAX
from ..core.sharing import solve_batch, utilization_curve
from ..core.table2 import TABLE2, KernelSpec
from .traces import PairTrace, ScalingTrace, TraceSet

#: Default candidate grid: log-spaced so relative resolution is uniform
#: across the physical range of ``f`` (~0.08 on CLX stencils to ~1 on Rome).
DEFAULT_F_GRID = np.geomspace(0.01, 1.0, 512)


def forward_bandwidth(n, f, bs, *, utilization: str = "queue",
                      p0_factor: float = 0.5) -> np.ndarray:
    """The Eq. 1–5 forward model: aggregate bandwidth of a homogeneous run
    at each core count ``n`` (broadcasts like numpy)."""
    u = utilization_curve(n, f, mode=utilization, p0_factor=p0_factor)
    return np.asarray(bs) * u


@dataclasses.dataclass(frozen=True)
class ScalingFit:
    """Per-cell ``(f, b_s)`` estimates for a batch of scaling traces."""

    f: np.ndarray          # (C,) fitted request fractions
    bs: np.ndarray         # (C,) fitted saturated bandwidths [GB/s]
    rss: np.ndarray        # (C,) residual sum of squares at the optimum
    traces: tuple[ScalingTrace, ...]
    utilization: str
    backend: str

    def __len__(self) -> int:
        return len(self.traces)

    def cells(self) -> dict[tuple[str, str], list[int]]:
        """Indices grouped by (kernel, arch) — one entry per seed."""
        out: dict[tuple[str, str], list[int]] = {}
        for i, tr in enumerate(self.traces):
            out.setdefault((tr.kernel, tr.arch), []).append(i)
        return out


@dataclasses.dataclass(frozen=True)
class CalibratedValue:
    """Seed-ensemble estimate of one model input: median + percentile CI."""

    value: float
    lo: float
    hi: float
    n_seeds: int

    @property
    def spread(self) -> float:
        return self.hi - self.lo


# ---------------------------------------------------------------------------
# The batched profile-least-squares pass
# ---------------------------------------------------------------------------

_EPS = 1e-30


def _profile_rss_np(n, y, mask, f_grid, utilization, p0_factor):
    """Residual profile over the ``f`` grid for all cells at once.

    ``n, y, mask``: ``(C, N)`` padded cell arrays; ``f_grid``: ``(F,)``.
    Returns ``(rss (C, F), bs_star (C, F))`` where ``bs_star`` is the
    closed-form optimal ``b_s`` at each candidate ``f``.
    """
    u = utilization_curve(n[:, None, :], f_grid[None, :, None],
                          mode=utilization, p0_factor=p0_factor)  # (C,F,N)
    u = np.where(mask[:, None, :], u, 0.0)
    ym = np.where(mask[:, None, :], y[:, None, :], 0.0)
    num = (ym * u).sum(axis=-1)
    den = np.maximum((u * u).sum(axis=-1), _EPS)
    bs_star = num / den                                         # (C, F)
    resid = ym - bs_star[..., None] * u
    rss = (np.where(mask[:, None, :], resid, 0.0) ** 2).sum(axis=-1)
    return rss, bs_star


_INVPHI = (np.sqrt(5.0) - 1.0) / 2.0
_REFINE_ITERS = 32  # bracket shrinks by φ⁻¹ per iter: ~1e-6 of a grid step


def _rss_at_np(n, y, mask, f, utilization, p0_factor):
    """RSS and closed-form ``b_s`` at one candidate ``f`` per cell
    (``f`` shape ``(C,)``)."""
    u = utilization_curve(n, f[:, None], mode=utilization,
                          p0_factor=p0_factor)
    u = np.where(mask, u, 0.0)
    ym = np.where(mask, y, 0.0)
    bs = (ym * u).sum(axis=-1) / np.maximum((u * u).sum(axis=-1), _EPS)
    rss = (np.where(mask, ym - bs[:, None] * u, 0.0) ** 2).sum(axis=-1)
    return rss, bs


def _fit_cells_np(n, y, mask, f_grid, utilization, p0_factor):
    rss, _ = _profile_rss_np(n, y, mask, f_grid, utilization, p0_factor)
    j = rss.argmin(axis=-1)
    F = len(f_grid)
    # Golden-section refinement inside the winning grid bracket
    # [f_{j-1}, f_{j+1}] — vectorized over cells, fixed iteration count.
    a = f_grid[np.clip(j - 1, 0, F - 1)]
    b = f_grid[np.clip(j + 1, 0, F - 1)]
    c = b - _INVPHI * (b - a)
    d = a + _INVPHI * (b - a)
    rc, _ = _rss_at_np(n, y, mask, c, utilization, p0_factor)
    rd, _ = _rss_at_np(n, y, mask, d, utilization, p0_factor)
    for _ in range(_REFINE_ITERS):
        left = rc < rd
        a = np.where(left, a, c)
        b = np.where(left, d, b)
        c = b - _INVPHI * (b - a)
        d = a + _INVPHI * (b - a)
        rc, _ = _rss_at_np(n, y, mask, c, utilization, p0_factor)
        rd, _ = _rss_at_np(n, y, mask, d, utilization, p0_factor)
    f_hat = 0.5 * (a + b)
    rss_hat, bs_hat = _rss_at_np(n, y, mask, f_hat, utilization,
                                 p0_factor)
    return f_hat, bs_hat, rss_hat


if HAVE_JAX:
    import jax
    import jax.numpy as jnp
    from jax import lax

    from ..core.sharing import utilization_curve_jax

    def _fit_single_jax(n, y, mask, f_grid, p0_factor, n_max, *, mode):
        """One cell: profile RSS over the f grid + golden-section
        refinement.  Shapes: ``n, y, mask`` are ``(N,)``; vmapped over
        the cell axis."""
        ym = jnp.where(mask, y, 0.0)

        def rss_at(f):
            u = utilization_curve_jax(n, f, mode=mode,
                                      p0_factor=p0_factor, n_max=n_max)
            u = jnp.where(mask, u, 0.0)
            bs = (ym * u).sum() / jnp.maximum((u * u).sum(), _EPS)
            rss = ((jnp.where(mask, ym - bs * u, 0.0)) ** 2).sum()
            return rss, bs

        u = utilization_curve_jax(n[None, :], f_grid[:, None], mode=mode,
                                  p0_factor=p0_factor, n_max=n_max)  # (F, N)
        u = jnp.where(mask[None, :], u, 0.0)
        bs_star = (ym[None, :] * u).sum(-1) / \
            jnp.maximum((u * u).sum(-1), _EPS)
        rss = (jnp.where(mask[None, :],
                         ym[None, :] - bs_star[:, None] * u, 0.0) ** 2
               ).sum(-1)                                        # (F,)
        F = f_grid.shape[0]
        j = jnp.argmin(rss)
        a = f_grid[jnp.clip(j - 1, 0, F - 1)]
        b = f_grid[jnp.clip(j + 1, 0, F - 1)]

        def body(_, state):
            a, b, c, d, rc, rd = state
            left = rc < rd
            a = jnp.where(left, a, c)
            b = jnp.where(left, d, b)
            c = b - _INVPHI * (b - a)
            d = a + _INVPHI * (b - a)
            rc = rss_at(c)[0]
            rd = rss_at(d)[0]
            return a, b, c, d, rc, rd

        c = b - _INVPHI * (b - a)
        d = a + _INVPHI * (b - a)
        state = (a, b, c, d, rss_at(c)[0], rss_at(d)[0])
        a, b, *_ = lax.fori_loop(0, _REFINE_ITERS, body, state)
        f_hat = 0.5 * (a + b)
        rss_hat, bs_hat = rss_at(f_hat)
        return f_hat, bs_hat, rss_hat

    def _build_jax_fit(mode: str, n_max: int):
        """Jitted vmap of the per-cell fit for one shape bucket;
        registered in the substrate's process-wide solver cache."""
        vmapped = jax.vmap(
            functools.partial(_fit_single_jax, mode=mode, n_max=n_max),
            in_axes=(0, 0, 0, None, None))
        return jax.jit(vmapped)

    def _fit_cells_jax(n, y, mask, f_grid, utilization, p0_factor):
        C, N = n.shape
        # Only the recursion law compiles an n-dependent loop; the queue
        # law shares one executable per (C, N, F) bucket.
        n_max = int(n.max()) if (n.size and utilization == "recursion") \
            else 0
        n_max_b = backend_mod.bucket(n_max) if n_max else 0
        Cb = backend_mod.bucket(C)
        fitter = backend_mod.jitted(
            ("calibrate.fit_scaling", utilization, Cb, N, len(f_grid),
             n_max_b),
            lambda: _build_jax_fit(utilization, n_max_b))
        with jax.experimental.enable_x64():
            # Padded cells are all-masked: their fit runs on zeros and
            # is sliced off below, so real cells are bit-for-bit the
            # unpadded pass.
            out = fitter(
                jnp.asarray(backend_mod.pad_rows(
                    np.asarray(n, np.float64), Cb), jnp.float64),
                jnp.asarray(backend_mod.pad_rows(
                    np.asarray(y, np.float64), Cb), jnp.float64),
                jnp.asarray(backend_mod.pad_rows(
                    np.asarray(mask, bool), Cb)),
                jnp.asarray(f_grid, jnp.float64),
                jnp.float64(p0_factor))
        return tuple(np.asarray(x)[:C] for x in out)


def fit_scaling(traces: TraceSet | Sequence[ScalingTrace], *,
                utilization: str = "queue",
                f_grid: np.ndarray | None = None, p0_factor: float = 0.5,
                backend: str = "auto",
                jax_cutoff: int | None = None) -> ScalingFit:
    """Fit ``(f, b_s)`` for every scaling trace in one batched pass.

    ``utilization`` must match the instrument that produced the traces:
    ``"queue"`` for memsim-generated curves (and idealized interfaces),
    ``"recursion"`` for real-hardware measurements with a soft knee.
    ``backend``: ``"numpy"``, ``"jax"`` (vmapped + jitted), or ``"auto"``
    — resolved by the substrate (:func:`repro.core.backend.resolve`)
    against the number of cells, honoring ``REPRO_JAX_CUTOFF`` / the
    ``jax_cutoff`` override like every batched path.  The jitted fit
    kernel — grid profile plus the golden-section refinement — is one
    compiled plan per (cell-bucket, law) in the substrate's cache, so
    repeated fits of same-shaped trace sets skip recompilation.
    """
    if not isinstance(traces, TraceSet):
        traces = TraceSet(scaling=tuple(traces))
    if not traces.scaling:
        return ScalingFit(f=np.zeros(0), bs=np.zeros(0), rss=np.zeros(0),
                          traces=(), utilization=utilization,
                          backend=backend)
    if utilization not in ("queue", "recursion"):
        raise ValueError(f"unknown utilization mode {utilization!r}")
    f_grid = DEFAULT_F_GRID if f_grid is None else np.asarray(f_grid)
    n, y, mask, tr = traces.to_arrays()
    backend = backend_mod.resolve(backend, n.shape[0],
                                  jax_cutoff=jax_cutoff)
    if backend == "jax":
        f_hat, bs_hat, rss = _fit_cells_jax(n, y, mask, f_grid,
                                            utilization, p0_factor)
    else:
        f_hat, bs_hat, rss = _fit_cells_np(n, y, mask, f_grid,
                                           utilization, p0_factor)
    return ScalingFit(f=f_hat, bs=bs_hat, rss=rss, traces=tuple(tr),
                      utilization=utilization, backend=backend)


def fit_scaling_cell(trace: ScalingTrace, **kwargs) -> tuple[float, float]:
    """Scalar convenience: fit one trace, return ``(f, b_s)``.  The
    sequential per-cell baseline the benchmark compares the batched pass
    against is a Python loop over this function."""
    fit = fit_scaling([trace], **kwargs)
    return float(fit.f[0]), float(fit.bs[0])


# ---------------------------------------------------------------------------
# Seed-ensemble aggregation → calibrated specs
# ---------------------------------------------------------------------------


def aggregate_ensemble(fit: ScalingFit, *, ci: float = 0.9
                       ) -> dict[tuple[str, str],
                                 dict[str, CalibratedValue]]:
    """Collapse a seed ensemble into per-(kernel, arch) estimates.

    Returns ``{(kernel, arch): {"f": CalibratedValue,
    "bs": CalibratedValue}}`` with the median as the point estimate and
    the central ``ci`` percentile interval over seeds as the confidence
    band (degenerate — lo == hi == value — for single-seed cells).
    """
    lo_q, hi_q = 50 * (1 - ci), 50 * (1 + ci)
    out: dict[tuple[str, str], dict[str, CalibratedValue]] = {}
    for key, idx in fit.cells().items():
        cell: dict[str, CalibratedValue] = {}
        for field, arr in (("f", fit.f), ("bs", fit.bs)):
            vals = arr[idx]
            cell[field] = CalibratedValue(
                value=float(np.median(vals)),
                lo=float(np.percentile(vals, lo_q)),
                hi=float(np.percentile(vals, hi_q)),
                n_seeds=len(idx))
        out[key] = cell
    return out


def calibrated_specs(fit: ScalingFit, *,
                     templates: Mapping[str, KernelSpec] | None = None,
                     ci: float = 0.9) -> dict[str, KernelSpec]:
    """Materialize a fit as first-class :class:`KernelSpec` objects.

    Each kernel present in the fit gets one spec whose ``f``/``bs``
    mappings cover every fitted architecture (ensemble medians).  When a
    ``templates`` mapping (default: Table II) has a spec of the same
    name, its stream decomposition is inherited via
    :meth:`KernelSpec.from_calibration`, so ECM prediction and the
    desync engines consume the calibrated spec unchanged.
    """
    templates = TABLE2 if templates is None else templates
    agg = aggregate_ensemble(fit, ci=ci)
    per_kernel: dict[str, tuple[dict, dict]] = {}
    for (kern, arch), cell in sorted(agg.items()):
        f_map, bs_map = per_kernel.setdefault(kern, ({}, {}))
        f_map[arch] = min(cell["f"].value, 1.0)
        bs_map[arch] = cell["bs"].value
    return {
        kern: KernelSpec.from_calibration(
            kern, f_map, bs_map, template=templates.get(kern))
        for kern, (f_map, bs_map) in per_kernel.items()
    }


# ---------------------------------------------------------------------------
# Saturation-envelope fit from paired measurements (Eq. 4 in reverse)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EnvelopeFit:
    """Per-arch least-squares solution of Eq. 4 from paired totals:
    ``bs[arch][kernel]`` is the kernel's inferred homogeneous saturated
    bandwidth; any mix's envelope follows as the thread-weighted mean."""

    bs: dict[str, dict[str, float]]
    residual: dict[str, float]     # RMS of (measured − fitted) totals

    def envelope(self, arch: str, groups: Sequence[tuple[str, int]]
                 ) -> float:
        """Eq. 4 for an arbitrary mix ``[(kernel, n), ...]`` on ``arch``."""
        n_tot = sum(n for _, n in groups)
        if n_tot == 0:
            return 0.0
        return sum(n * self.bs[arch][k] for k, n in groups) / n_tot


def fit_envelope(pairs: Sequence[PairTrace]) -> EnvelopeFit:
    """Recover per-kernel ``b_s`` from saturated paired totals.

    Eq. 4 makes the mix envelope *linear* in the per-kernel saturated
    bandwidths: ``b_total = Σ (n_i / n_tot) · b_s,i``.  Stacking every
    pair trace of an architecture gives an overdetermined linear system,
    solved here per arch via ridge-stabilized normal equations — all
    architectures in one batched ``np.linalg.solve`` call.
    """
    pairs = tuple(pairs)
    if not pairs:
        return EnvelopeFit(bs={}, residual={})
    archs = sorted({p.arch for p in pairs})
    kernels = sorted({k for p in pairs for k in p.kernels})
    a_idx = {a: i for i, a in enumerate(archs)}
    k_idx = {k: i for i, k in enumerate(kernels)}
    A, K = len(archs), len(kernels)
    gram = np.zeros((A, K, K))
    rhs = np.zeros((A, K))
    rows: dict[str, list[tuple[np.ndarray, float]]] = {a: [] for a in archs}
    for p in pairs:
        row = np.zeros(K)
        n_tot = sum(p.n)
        for k, n in zip(p.kernels, p.n):
            row[k_idx[k]] += n / n_tot
        y = sum(p.bandwidth)
        ai = a_idx[p.arch]
        gram[ai] += np.outer(row, row)
        rhs[ai] += row * y
        rows[p.arch].append((row, y))
    # Tiny ridge keeps uncovered kernels solvable; they come out ~0 and
    # are reported as NaN below.
    ridge = 1e-9 * np.maximum(np.trace(gram, axis1=1, axis2=2), 1.0) / K
    gram += ridge[:, None, None] * np.eye(K)[None]
    sol = np.linalg.solve(gram, rhs[..., None])[..., 0]      # (A, K)
    covered = np.zeros((A, K), dtype=bool)
    for p in pairs:
        for k in p.kernels:
            covered[a_idx[p.arch], k_idx[k]] = True
    bs = {a: {k: (float(sol[a_idx[a], k_idx[k]])
                  if covered[a_idx[a], k_idx[k]] else float("nan"))
              for k in kernels}
          for a in archs}
    residual = {}
    for a in archs:
        errs = [y - float(row @ sol[a_idx[a]]) for row, y in rows[a]]
        residual[a] = float(np.sqrt(np.mean(np.square(errs))))
    return EnvelopeFit(bs=bs, residual=residual)


# ---------------------------------------------------------------------------
# Paired-share prediction from calibrated specs (one batched solve)
# ---------------------------------------------------------------------------


def predict_pairs(specs: Mapping[str, KernelSpec],
                  pairs: Sequence[PairTrace], *,
                  utilization: str | float = "queue") -> np.ndarray:
    """Model-predicted per-group bandwidths for every pair trace, solved
    in **one** :func:`repro.core.sharing.solve_batch` call (the PR-2
    batch machinery).  Returns ``(len(pairs), 2)`` GB/s."""
    pairs = tuple(pairs)
    if not pairs:
        return np.zeros((0, 2))
    n = np.array([p.n for p in pairs], dtype=np.float64)
    f = np.array([[specs[k].f[p.arch] for k in p.kernels] for p in pairs])
    bs = np.array([[specs[k].bs[p.arch] for k in p.kernels]
                   for p in pairs])
    batch = solve_batch(n, f, bs, utilization=utilization)
    return batch.bw_group
