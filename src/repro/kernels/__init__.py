"""Pallas TPU kernels for the bandwidth-critical compute layers.

The paper's contribution is bandwidth phenomenology, and its kernel suite
(Table II streaming loops + Jacobi stencils) is the calibration workload —
reimplemented here as Pallas TPU kernels with explicit BlockSpec VMEM
tiling.  Attention (prefill + decode) and fused RMSNorm are the serving/
training hot-spots the TPU adaptation adds on top.

Modules: stream, jacobi, flash_attention, decode_attention, rmsnorm,
ops (public jit'd API), ref (pure-jnp oracles).
"""

from . import ops, ref

__all__ = ["ops", "ref"]
