"""Static traffic auditor: golden per-iteration counts vs Table II.

The walker's whole claim is that Table II falls out of the kernels'
own jaxprs.  These tests pin that: exact byte/stream/flop golden values
for the STREAM and Jacobi kernels, the full-suite count cross-check,
the in-place aliasing (RFO-suppression) path, control-flow recursion,
the no-pallas fallback, and the registry's ``"static"`` rung.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.analysis import audit, derive, features
from repro.analysis.report import cross_check, static_suite
from repro.core.table2 import TABLE2, KernelSpec
from repro.kernels.stream import LANES, map_stream, reduce_stream

jax.config.update("jax_enable_x64", False)

N = LANES * 64


def _map(name, n_arrays, n=N, **kw):
    s = jnp.float32(3.0)
    arrays = tuple(jnp.ones(n, jnp.float32) for _ in range(n_arrays))
    return functools.partial(map_stream, name, **kw), (s, *arrays)


# ---------------------------------------------------------------------------
# Golden per-iteration byte counts (S3): STREAM copy/triad and Jacobi
# ---------------------------------------------------------------------------


def test_golden_dcopy():
    fn, args = _map("dcopy", 1)
    lf = features(fn, *args)
    assert (lf.reads, lf.writes, lf.rfo) == (1, 1, 1)
    assert lf.flops_per_iter == 0.0
    assert lf.iters == N
    assert lf.itemsize == 4
    assert lf.bytes_per_iter == 12.0          # load + store + RFO, f32
    assert lf.code_balance == float("inf")    # no flops at all


def test_golden_stream_triad():
    fn, args = _map("stream", 2)
    lf = features(fn, *args)
    assert (lf.reads, lf.writes, lf.rfo) == (2, 1, 1)
    assert lf.flops_per_iter == pytest.approx(2.0)
    assert lf.bytes_per_iter == 16.0          # 4 f32 streams
    assert lf.code_balance == pytest.approx(8.0)


def test_golden_jacobi_v1_layer_condition():
    from repro.kernels.jacobi import jacobi_v1
    a = jnp.ones((66, 128), jnp.float32)
    lc = features(jacobi_v1, a, jnp.float32(0.25), reuse=True)
    assert (lc.reads, lc.writes, lc.rfo) == (1, 1, 1)   # JacobiL2-v1
    assert lc.bytes_per_iter == 12.0
    assert lc.flops_per_iter == pytest.approx(4.0)
    no_lc = features(jacobi_v1, a, jnp.float32(0.25), reuse=False)
    assert (no_lc.reads, no_lc.writes, no_lc.rfo) == (3, 1, 1)  # L3-v1
    assert no_lc.bytes_per_iter == 20.0


def test_jacobi_views_share_one_base():
    from repro.kernels.jacobi import jacobi_v1
    a = jnp.ones((66, 128), jnp.float32)
    tr = audit(jacobi_v1, a, jnp.float32(0.25))
    bases = {s.base for s in tr.loads}
    assert bases == {"a"}           # up/mid/down recognized as one buffer
    assert len(tr.loads) == 3


# ---------------------------------------------------------------------------
# Full-suite count cross-check against Table II
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", static_suite(), ids=lambda c: c.label)
def test_suite_counts_match_table2(case):
    fn, args = case.build()
    lf = features(fn, *args, reuse=case.reuse)
    ref = TABLE2[case.table_name]
    if case.exact:
        assert (lf.reads, lf.writes, lf.rfo) == \
            (ref.reads, ref.writes, ref.rfo)
        assert lf.flops_per_iter == pytest.approx(ref.flops_per_iter,
                                                  abs=0.01)
    else:
        # functional DSCAL/DAXPY: one extra RFO vs the table's in-place
        # form — the documented write-allocate ambiguity.
        assert (lf.reads, lf.writes) == (ref.reads, ref.writes)
        assert lf.rfo == ref.rfo + 1


def test_cross_check_f_within_bounds():
    for row in cross_check("CLX"):
        assert row["ok"], row
        assert row["f_err"] <= row["bound"]


# ---------------------------------------------------------------------------
# In-place aliasing: input_output_aliases suppresses the RFO stream
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,n_arrays", [("dscal", 1), ("daxpy", 2)])
def test_in_place_suppresses_rfo(name, n_arrays):
    fn, args = _map(name, n_arrays, in_place=True)
    lf = features(fn, *args)
    ref = TABLE2[name.upper()]
    assert (lf.reads, lf.writes, lf.rfo) == \
        (ref.reads, ref.writes, ref.rfo)
    assert lf.rfo == 0
    tr = audit(fn, *args)
    assert any(s.aliased for s in tr.stores)


def test_in_place_numerics_unchanged():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal(N), jnp.float32)
    b = jnp.asarray(rng.standard_normal(N), jnp.float32)
    s = jnp.float32(1.7)
    np.testing.assert_allclose(
        map_stream("daxpy", s, a, b, in_place=True),
        map_stream("daxpy", s, a, b), rtol=1e-6)


def test_in_place_rejects_distinct_output_kernels():
    s = jnp.float32(1.0)
    a = jnp.ones(N, jnp.float32)
    with pytest.raises(ValueError, match="dscal"):
        map_stream("dcopy", s, a, in_place=True)


# ---------------------------------------------------------------------------
# Walker mechanics: grid fetches, control flow, fallback
# ---------------------------------------------------------------------------


def test_multi_step_grid_counts_all_fetches():
    fn, args = _map("dcopy", 1, n=LANES * 512)   # grid (2,)
    tr = audit(fn, *args)
    (load,) = tr.loads
    assert load.fetches == 2
    assert load.elements == LANES * 512
    lf = derive(tr)
    assert lf.iters == LANES * 512
    assert (lf.reads, lf.writes, lf.rfo) == (1, 1, 1)


def test_scan_multiplies_traffic():
    s = jnp.float32(0.5)
    a = jnp.ones(N, jnp.float32)

    def once(s, a):
        return map_stream("dscal", s, a)

    def repeated(s, a):
        def body(carry, _):
            return map_stream("dscal", s, carry), None
        out, _ = jax.lax.scan(body, a, None, length=3)
        return out

    single, tripled = audit(once, s, a), audit(repeated, s, a)
    assert tripled.flops == pytest.approx(3 * single.flops)
    assert tripled.total_bytes == pytest.approx(3 * single.total_bytes)


def test_fallback_pure_jnp_boundary_traffic():
    def dot(a, b):
        return jnp.sum(a * b)

    a = jnp.ones(N, jnp.float32)
    lf = features(dot, a, a + 1)
    assert (lf.reads, lf.writes, lf.rfo) == (2, 0, 0)
    assert lf.read_only
    assert lf.flops_per_iter == pytest.approx(2.0)


def test_reduction_accumulator_not_a_store_stream():
    fn, args = _map("dcopy", 1)  # placeholder to keep args style
    rfn = functools.partial(reduce_stream, "ddot2")
    arrays = (jnp.ones(N, jnp.float32), jnp.ones(N, jnp.float32))
    tr = audit(rfn, *arrays)
    assert not tr.stores            # (1,1) accumulator is grid-resident
    assert tr.reductions >= 1
    lf = derive(tr)
    assert (lf.reads, lf.writes, lf.rfo) == (2, 0, 0)
    assert any("accumulator" in n for n in lf.notes)


def test_audit_labels_from_signature():
    fn, args = _map("stream", 2)
    tr = audit(fn, *args)
    assert {s.base for s in tr.loads} == {"arrays[0]", "arrays[1]"}


# ---------------------------------------------------------------------------
# The "static" resolution rung
# ---------------------------------------------------------------------------


def test_from_static_analysis_provenance_and_archs():
    fn, args = _map("dcopy", 1)
    r = api.from_static_analysis(fn, args)
    assert r.provenance == "static"
    assert "static" in api.PROVENANCES
    assert set(r.spec.f) == {"BDW-1", "BDW-2", "CLX", "ROME"}
    assert set(r.spec.bs) == set(r.spec.f)
    single = api.from_static_analysis(fn, args, machine="CLX")
    assert set(single.spec.f) == {"CLX"}
    assert single.spec.f["CLX"] == pytest.approx(r.spec.f["CLX"])


def test_kernelspec_classmethod_matches_registry():
    fn, args = _map("stream", 2)
    spec = KernelSpec.from_static_analysis(fn, args, machine="ROME")
    via_api = api.from_static_analysis(fn, args, machine="ROME").spec
    assert spec.f == via_api.f
    assert spec.bs == via_api.bs


def test_static_provenance_travels_into_prediction():
    fn, args = _map("stream", 2)
    resolved = api.from_static_analysis(fn, args, machine="CLX")
    pred = api.predict(api.Scenario.on("CLX").run(resolved, 12))
    assert pred.total_bw > 0
    assert pred.groups[0].provenance == "static"
