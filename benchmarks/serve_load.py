"""Serving-subsystem load test: coalesced vs naive per-request predict.

Closed-loop load against the protocol-agnostic serving core (the plan
cache + request coalescer of ``repro.serve``, no sockets — transport
cost is a constant both designs would pay): C concurrent clients each
keep one request in flight, cycling distinct numeric payloads of one
scenario structure (the repeated-structure workload a monitoring or
calibration client generates).  The baseline is what a single-process
server without coalescing would do with the same requests — solve each
arrival with its own ``api.predict(scenario)`` call, one after another.

Reported per concurrency level: throughput (requests/s), p50/p99
client latency, and the speedup over the naive baseline (acceptance:
>= 5x at C >= 64).  The plan cache is warmed over every power-of-two
bucket the run can touch, so the measured phase must show hit rate 1.0
— the cache half of the serving contract.

``python benchmarks/serve_load.py --out BENCH_serve.json`` writes the
committed artifact and exits nonzero if a bound is broken.
"""

from __future__ import annotations

import argparse
import asyncio
import gc
import json
import sys
import time

import numpy as np

from repro import api
from repro.core import backend as backend_mod
from repro.serve import Coalescer, PlanCache, ServeConfig

CONCURRENCY = (4, 16, 64)
N_PER_LEVEL = 2048           # total requests at each concurrency level
REPEATS = 3                  # best-of repeats (noise floor, both sides)
SPEEDUP_BOUND = 5.0          # coalesced vs naive at C >= 64
SPEEDUP_AT_C = 64


def _scenarios(b: int) -> list:
    """b distinct numeric payloads of one scenario structure: a Table
    III-style four-kernel mix on CLX (20 cores split across DCOPY /
    DDOT2 / DAXPY / STREAM), core counts cycling with the index.

    The backend is pinned to numpy: serving ticks batch at most a few
    hundred rows, below the jax dispatch break-even on CPU
    (BENCH_plan.json's crossover) — an operator pins the backend for
    the batch regime the service actually runs in.  The naive baseline
    is unaffected (single-scenario predict always uses the scalar
    reference engine)."""
    base = api.Scenario.on("CLX").options(backend="numpy")
    out = []
    for k in range(b):
        a = 1 + k % 8
        c = 1 + (k // 2) % 6
        d = 1 + (k // 3) % 5
        out.append(base.run("DCOPY", a).run("DDOT2", c)
                   .run("DAXPY", d).run("STREAM", 20 - a - c - d))
    return out


def _percentiles(samples_s: list) -> dict:
    arr = np.sort(np.asarray(samples_s)) * 1e3
    return {
        "p50_ms": round(float(np.percentile(arr, 50)), 3),
        "p99_ms": round(float(np.percentile(arr, 99)), 3),
        "mean_ms": round(float(arr.mean()), 3),
    }


def _naive(scens: list, n: int) -> dict:
    """The no-serving baseline: n sequential api.predict calls (how a
    single-process server answers concurrent arrivals without
    coalescing — requests serialize).  Best of REPEATS passes: both
    sides of the speedup ratio report their quietest run, so the bound
    measures the designs, not the machine's noise floor."""
    best = None
    for _ in range(REPEATS):
        lat = []
        for k in range(min(64, n)):      # warm dispatch paths
            api.predict(scens[k % len(scens)])
        for k in range(n):
            t0 = time.perf_counter()
            api.predict(scens[k % len(scens)])
            lat.append(time.perf_counter() - t0)
        wall = sum(lat)
        if best is None or wall < best[0]:
            best = (wall, lat)
    wall, lat = best
    return {"n": n, "throughput_rps": round(n / wall, 1),
            **_percentiles(lat)}


async def _level(coalescer: Coalescer, scens: list, C: int,
                 n_total: int) -> dict:
    rounds = max(4, n_total // C)
    lat: list = []

    async def client(i: int) -> None:
        for k in range(rounds):
            sc = scens[(i * rounds + k) % len(scens)]
            t0 = time.perf_counter()
            await coalescer.submit(sc)
            lat.append(time.perf_counter() - t0)

    # One unmeasured round per client: first-touch jit builds and
    # event-loop warm-up happen here, not in the timed phase.  Then
    # best of REPEATS measured passes (matching the naive baseline).
    await asyncio.gather(*[client(0) for _ in range(C)])
    n = C * rounds
    best = None
    for _ in range(REPEATS):
        lat.clear()
        a0, k0 = coalescer.counts["accepted"], coalescer._ticks
        t0 = time.perf_counter()
        await asyncio.gather(*[client(i) for i in range(C)])
        wall = time.perf_counter() - t0
        batch = ((coalescer.counts["accepted"] - a0)
                 / max(1, coalescer._ticks - k0))
        if best is None or wall < best[0]:
            best = (wall, list(lat), batch)
    wall, lat, batch = best
    return {"C": C, "n": n, "avg_batch": round(batch, 1),
            "throughput_rps": round(n / wall, 1), **_percentiles(lat)}


async def _serve_phase(levels) -> tuple[list, dict]:
    cache = PlanCache(max_entries=64)
    template = _scenarios(1)[0]
    # Warm every bucket a closed loop at these levels can produce, so
    # the measured phase is a pure plan-cache-hit workload.
    buckets = [1 << k for k in range(
        backend_mod.bucket(max(levels)).bit_length())]
    cache.warmup(template, buckets=buckets)
    scens = _scenarios(256)
    out = []
    # tick_s=0 is "drain whatever queued": under closed-loop load every
    # client's resubmit lands during the fan-out yield, so batches stay
    # at C with no timed window at all (the per-level ``avg_batch``
    # numbers are the evidence).  A timed tick only matters for open
    # traffic that trickles in (the HTTP default keeps 1 ms).
    async with Coalescer(ServeConfig(tick_s=0.0, max_batch=512,
                                     max_queue=4096),
                         cache=cache) as c:
        before = cache.stats()
        for C in levels:
            out.append(await _level(c, scens, C, N_PER_LEVEL))
        after = cache.stats()
    served = {
        "hits": after["hits"] - before["hits"],
        "misses": after["misses"] - before["misses"],
        "warm_compiles": before["misses"],
        "entries": after["entries"],
    }
    lookups = served["hits"] + served["misses"]
    served["hit_rate"] = round(served["hits"] / lookups, 4) if lookups \
        else 0.0
    return out, served


def measure() -> dict:
    scens = _scenarios(256)
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        naive = _naive(scens, N_PER_LEVEL)
        levels, cache = asyncio.run(_serve_phase(CONCURRENCY))
    finally:
        if gc_was_enabled:
            gc.enable()
    for lv in levels:
        lv["speedup_vs_naive"] = round(
            lv["throughput_rps"] / naive["throughput_rps"], 2)
    at_c = {lv["C"]: lv for lv in levels}
    return {
        "backend": "jax+numpy" if backend_mod.HAVE_JAX else "numpy",
        "naive": naive,
        "levels": levels,
        "plan_cache": cache,
        "speedup_c64": at_c[SPEEDUP_AT_C]["speedup_vs_naive"],
    }


def check(r: dict) -> bool:
    return (r["speedup_c64"] >= SPEEDUP_BOUND
            and r["plan_cache"]["hit_rate"] >= 1.0)


def rows():
    r = measure()
    out = [(f"serve/naive/percall", 1e6 / r["naive"]["throughput_rps"],
            f"rps={r['naive']['throughput_rps']};"
            f"p99={r['naive']['p99_ms']}ms")]
    for lv in r["levels"]:
        out.append((f"serve/coalesced/C={lv['C']}",
                    1e6 / lv["throughput_rps"],
                    f"rps={lv['throughput_rps']};p50={lv['p50_ms']}ms;"
                    f"p99={lv['p99_ms']}ms;"
                    f"speedup={lv['speedup_vs_naive']}x"))
    c = r["plan_cache"]
    out.append(("serve/plan_cache/repeated_structure", 0.0,
                f"hit_rate={c['hit_rate']};hits={c['hits']};"
                f"misses={c['misses']}"))
    out.append(("serve/check/bounds", 0.0,
                f"ok={check(r)};speedup_c64>={SPEEDUP_BOUND:.0f}x"))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args(argv)
    r = measure()
    ok = check(r)
    report = {
        "benchmark": "serve_load",
        "jax": backend_mod.HAVE_JAX,
        "bound_speedup_c64": SPEEDUP_BOUND,
        "bound_hit_rate": 1.0,
        "ok": ok,
        "results": r,
    }
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.out}  (ok={ok})")
    print(f"naive per-request: {r['naive']['throughput_rps']} rps "
          f"(p99 {r['naive']['p99_ms']} ms)")
    for lv in r["levels"]:
        print(f"coalesced C={lv['C']:>3}: {lv['throughput_rps']:>8} rps  "
              f"p50 {lv['p50_ms']} ms  p99 {lv['p99_ms']} ms  "
              f"({lv['speedup_vs_naive']}x vs naive)")
    print(f"plan cache over the serving phase: {r['plan_cache']}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
