from .adamw import AdamWState, adamw_init, adamw_update, global_norm
from .schedules import constant, cosine_schedule, linear_warmup
from .compression import (compress_int8, decompress_int8,
                          error_feedback_compress)

__all__ = ["AdamWState", "adamw_init", "adamw_update", "global_norm",
           "constant", "cosine_schedule", "linear_warmup",
           "compress_int8", "decompress_int8", "error_feedback_compress"]
