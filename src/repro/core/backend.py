"""THE execution substrate: one backend policy for every batched engine.

Before this module existed, the ``numpy`` / ``jax`` / ``auto`` decision —
"is jax importable, is the batch big enough to amortize jit dispatch?" —
was re-implemented independently in ``core/sharing.py``,
``core/desync_batch.py``, ``calibrate/fit.py``, and ``api/engine.py``.
Four forks of the same policy meant four places to thread a new backend
through, four private cutoff constants, and four separate jit caches.
This module is the single implementation:

* **capability probe** — :data:`HAVE_JAX` is defined here (and only
  here); the other modules import it.
* **resolution policy** — :func:`resolve` maps a requested backend
  (``"numpy"`` / ``"jax"`` / ``"auto"``) plus a batch size to the
  backend that will actually run.  The ``auto`` cutoff is a
  configurable knob: the ``REPRO_JAX_CUTOFF`` environment variable sets
  the process default, and every batched entry point accepts a
  per-call ``jax_cutoff=`` override.
* **jitted-solver cache** — :func:`jitted` is a process-wide registry
  of compiled solver callables keyed by *padded shape bucket*
  (:func:`bucket` rounds sizes up to powers of two), so sweeping over
  nearby batch sizes reuses one XLA executable instead of recompiling
  per shape.  :func:`cache_stats` exposes hit/miss counters — the
  plan-overhead benchmark records the hit rate.
* **chunked streaming** — :func:`run_chunked` executes an array
  function over slabs of the batch axis and stitches the results, so a
  B far beyond memory streams through a bounded working set
  (``REPRO_CHUNK_B`` sets a process-wide default slab).

A future backend (pallas kernels, multi-device sharding) registers
here once — a new ``resolve`` target plus its ``jitted`` builders —
instead of being threaded through four modules.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Callable, Sequence

import numpy as np

from ..obs import metrics, trace

try:  # The jax paths are optional: numpy covers hermetic containers.
    import jax  # noqa: F401  (re-exported capability, used by clients)

    HAVE_JAX = True
except ModuleNotFoundError:  # pragma: no cover - exercised only without jax
    HAVE_JAX = False

#: Backends the substrate can resolve to.  ``"auto"`` is a request, not
#: a backend: :func:`resolve` always returns one of these.
BACKENDS = ("numpy", "jax")

#: Batches at least this large dispatch to the jitted jax solver under
#: ``backend="auto"``: below it, jit dispatch overhead outweighs the
#: vmap win (see BENCH_api.json).  Process default; override with the
#: ``REPRO_JAX_CUTOFF`` environment variable or per call via
#: ``jax_cutoff=``.
DEFAULT_JAX_CUTOFF = 64

#: Environment variable overriding :data:`DEFAULT_JAX_CUTOFF`.
JAX_CUTOFF_ENV = "REPRO_JAX_CUTOFF"

#: Environment variable setting a process-wide default chunk size for
#: :func:`run_chunked` consumers (0 / unset = no chunking).
CHUNK_ENV = "REPRO_CHUNK_B"


def _int_env(name: str, default: int) -> int:
    raw = os.environ.get(name)
    if raw is None or raw.strip() == "":
        return default
    try:
        value = int(raw)
    except ValueError:
        raise ValueError(
            f"{name} must be an integer, got {raw!r}") from None
    if value < 0:
        raise ValueError(f"{name} must be >= 0, got {value}")
    return value


def jax_cutoff(override: int | None = None) -> int:
    """Effective ``auto``-mode jax cutoff: the per-call ``override`` when
    given, else ``REPRO_JAX_CUTOFF`` from the environment, else
    :data:`DEFAULT_JAX_CUTOFF`.  The environment is re-read on every
    call, so tests (and long-running servers) can retune the knob
    without re-importing the library."""
    if override is not None:
        if override < 0:
            raise ValueError(f"jax_cutoff must be >= 0, got {override}")
        return int(override)
    return _int_env(JAX_CUTOFF_ENV, DEFAULT_JAX_CUTOFF)


def default_chunk(override: int | None = None) -> int | None:
    """Effective streaming chunk size (``None`` = unchunked): the
    per-call ``override`` when given, else ``REPRO_CHUNK_B`` from the
    environment (0 / unset = off)."""
    if override is not None:
        if override < 1:
            raise ValueError(f"chunk must be >= 1, got {override}")
        return int(override)
    value = _int_env(CHUNK_ENV, 0)
    return value if value > 0 else None


_effective_jax_cutoff = jax_cutoff  # alias: `resolve` shadows the name


def resolve(backend: str, batch_size: int | None = None, *,
            jax_cutoff: int | None = None,
            prefer: str = "jax") -> str:
    """Map a requested backend to the one that will run.

    ``backend``: ``"numpy"``, ``"jax"``, or ``"auto"``.  Explicit
    requests are honored (``"jax"`` raises :class:`RuntimeError` when
    jax is not importable — the caller asked for something the process
    cannot do).  ``"auto"`` resolves by policy:

    * ``prefer="jax"`` (the batched solvers): jax when importable and
      the batch is at least :func:`jax_cutoff` scenarios (an unknown
      ``batch_size=None`` counts as large);
    * ``prefer="numpy"`` (the desync event engine, whose numpy path is
      the reference implementation): numpy, always — jax runs only on
      explicit request.

    This is the **only** place in the tree that makes this decision.
    """
    if backend == "auto":
        if prefer == "numpy":
            return "numpy"
        if prefer != "jax":
            raise ValueError(f"unknown auto preference {prefer!r}")
        if not HAVE_JAX:
            return "numpy"
        cutoff = _effective_jax_cutoff(jax_cutoff)
        if batch_size is not None and batch_size < cutoff:
            return "numpy"
        return "jax"
    if backend == "jax":
        if not HAVE_JAX:
            raise RuntimeError("backend='jax' requested but jax is not "
                               "importable")
        return "jax"
    if backend == "numpy":
        return "numpy"
    raise ValueError(f"unknown backend {backend!r}")


# ---------------------------------------------------------------------------
# Process-wide jitted-solver cache, keyed by padded shape buckets
# ---------------------------------------------------------------------------

_JIT_CACHE: dict[tuple, Callable] = {}
_JIT_LOCK = threading.Lock()

# Hit/miss/compile-time accounting lives on the process-wide metrics
# registry (repro.obs.metrics) — this module's former private ``_STATS``
# dict, now visible to every exporter.  One labeled instrument per cache
# key gives :func:`cache_stats` its per-bucket breakdown.
_HIT_METRIC = "backend.jit.hit"
_MISS_METRIC = "backend.jit.miss"
_COMPILE_METRIC = "backend.jit.compile_s"


def _key_label(key: tuple) -> str:
    """Cache key -> flat metric label (``sharing.solve_batch/jax/256``)."""
    return "/".join(str(part) for part in key)


def bucket(n: int, *, minimum: int = 1) -> int:
    """Round ``n`` up to the next power of two (at least ``minimum``).

    Shape buckets bound the number of distinct compiled executables to
    O(log B) across a sweep of batch sizes: inputs are padded with
    neutral rows up to the bucket, solved, and sliced back."""
    n = max(int(n), minimum, 1)
    return 1 << (n - 1).bit_length()


def jitted(key: tuple, build: Callable[[], Callable]) -> Callable:
    """The process-wide compiled-solver registry.

    ``key`` identifies one compiled callable — by convention
    ``(module.fn, static-config..., bucketed-shapes...)`` — and
    ``build`` constructs it (typically ``jax.jit`` of a vmapped
    kernel) on the first request.  Subsequent requests with the same
    key return the cached callable, preserving jax's own
    per-callable compilation cache across calls, call sites, and
    plans."""
    label = _key_label(key)
    with _JIT_LOCK:
        fn = _JIT_CACHE.get(key)
    if fn is not None:
        metrics.counter(_HIT_METRIC, key=label).inc()
        return fn
    # Build outside the lock (compilation can be slow); a racing
    # duplicate build is harmless — setdefault keeps the first
    # insertion and discards the loser, and both callables compute
    # the same thing.
    with trace.span("backend.jit.build", key=label):
        t0 = time.perf_counter()
        fn = build()
        dt = time.perf_counter() - t0
    metrics.counter(_MISS_METRIC, key=label).inc()
    metrics.histogram(_COMPILE_METRIC, key=label).observe(dt)
    with _JIT_LOCK:
        _JIT_CACHE.setdefault(key, fn)
        return _JIT_CACHE[key]


#: Additional cache-stats scopes registered by higher layers (e.g. the
#: serving subsystem's plan cache); name → zero-arg provider returning a
#: stats dict.  The substrate cannot import those layers, so they
#: register themselves here at import time.
_SCOPE_PROVIDERS: dict[str, Callable[[], dict]] = {}


def register_cache_scope(name: str,
                         provider: Callable[[], dict]) -> None:
    """Register (or replace) a named cache-stats scope for
    :func:`cache_stats`.  ``provider`` is called lazily per query;
    ``name`` must not shadow the built-in ``"jit"``/``"all"`` scopes."""
    if name in ("jit", "all"):
        raise ValueError(f"scope name {name!r} is reserved")
    _SCOPE_PROVIDERS[name] = provider


def cache_stats(scope: str = "jit") -> dict:
    """Hit/miss counters and entry count of the substrate's caches.

    ``scope="jit"`` (the default, and the historical return shape)
    reports the jitted-solver cache, plus a per-bucket breakdown:
    ``"buckets"`` maps each cache-key label to its ``{"hits", "misses",
    "compile_s"}`` (compile wall time summed over rebuilds of that key).
    ``scope="all"`` reports every known cache once, keyed by scope name
    (``{"jit": ..., "plan": ...}`` with :mod:`repro.serve` imported) —
    the shape ``/statsz`` and ``repro.obs.report`` consume, with no
    double-counting because each scope owns disjoint counters.  Any
    other ``scope`` selects one registered scope by name."""
    if scope == "all":
        out = {"jit": cache_stats("jit")}
        for name, provider in sorted(_SCOPE_PROVIDERS.items()):
            out[name] = provider()
        return out
    if scope != "jit":
        provider = _SCOPE_PROVIDERS.get(scope)
        if provider is None:
            from ..api.registry import unknown_key_error
            raise unknown_key_error(
                "cache scope", scope,
                ["jit", "all", *sorted(_SCOPE_PROVIDERS)])
        return provider()
    buckets: dict[str, dict] = {}

    def _bucket(label: str) -> dict:
        return buckets.setdefault(
            label, {"hits": 0, "misses": 0, "compile_s": 0.0})

    hits = misses = 0
    for row in metrics.snapshot():
        label = row["labels"].get("key")
        if label is None:
            continue
        if row["name"] == _HIT_METRIC:
            _bucket(label)["hits"] = row["value"]
            hits += row["value"]
        elif row["name"] == _MISS_METRIC:
            _bucket(label)["misses"] = row["value"]
            misses += row["value"]
        elif row["name"] == _COMPILE_METRIC:
            _bucket(label)["compile_s"] = row["sum"]
    with _JIT_LOCK:
        entries = len(_JIT_CACHE)
    total = hits + misses
    return {
        "hits": hits,
        "misses": misses,
        "entries": entries,
        "hit_rate": (hits / total) if total else 0.0,
        "buckets": buckets,
    }


def clear_jit_cache() -> None:
    """Drop every cached callable and reset the **whole** metrics
    registry (not just the jit counters), so tests cannot leak counts
    across cases."""
    with _JIT_LOCK:
        _JIT_CACHE.clear()
    metrics.reset()


def pad_rows(arr: np.ndarray, rows: int) -> np.ndarray:
    """Pad ``arr`` along axis 0 with zeros up to ``rows`` (no copy when
    already that size).  Zero rows are exactly neutral for every solver
    on the substrate (``n = 0`` groups, ``mask = False`` cells, empty
    programs), so padding never perturbs the real rows."""
    if arr.shape[0] == rows:
        return arr
    if arr.shape[0] > rows:
        raise ValueError(
            f"cannot pad {arr.shape[0]} rows down to {rows}")
    pad = np.zeros((rows - arr.shape[0],) + arr.shape[1:], dtype=arr.dtype)
    return np.concatenate([arr, pad], axis=0)


# ---------------------------------------------------------------------------
# Chunked streaming execution
# ---------------------------------------------------------------------------


def run_chunked(fn: Callable[..., tuple], arrays: Sequence[np.ndarray],
                chunk: int) -> tuple:
    """Run ``fn(*slabs)`` over slabs of the shared batch axis and
    concatenate the per-slab result tuples.

    ``fn`` must map arrays of shape ``(b, ...)`` to a tuple of arrays
    whose axis 0 is also ``b`` (the batched solvers' contract).  The
    working set is one slab, so B far beyond memory streams through;
    results are bit-for-bit the unchunked call because every solver on
    the substrate is row-independent."""
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    B = arrays[0].shape[0]
    if B <= chunk:
        return fn(*arrays)
    parts = [fn(*(a[i:i + chunk] for a in arrays))
             for i in range(0, B, chunk)]
    return tuple(np.concatenate([p[j] for p in parts], axis=0)
                 for j in range(len(parts[0])))
