"""Static-analysis accuracy gate: derived features vs Table II.

Runs the jaxpr traffic auditor over every Table II kernel in the repo
(:func:`repro.analysis.report.static_suite`), bridges both the derived
and the transcribed stream counts through the same ECM model, and
commits the comparison as ``BENCH_analysis.json``:

* ``max_f_err`` — worst relative gap between the two bridged ``f``
  values across all cells and architectures.  Exact cells must agree to
  ~0 (their counts are integer-identical); the functional DSCAL/DAXPY
  forms carry the documented write-allocate ambiguity and are bounded
  by ``AMBIGUOUS_BOUND`` (15 %).  The gate in ``benchmarks/trend.py``
  holds the artifact to that 15 % ceiling.
* ``analysis_wall_us`` — wall time of one full-suite audit (trace +
  walk + normalize per kernel): static analysis must stay interactive.
* ``lint`` — the trace-contract lint sweep over the repo corpus must
  be clean.

``python benchmarks/analysis_accuracy.py --out BENCH_analysis.json``
writes the artifact and exits nonzero when a bound breaks;
``rows()`` feeds the same cells to ``benchmarks/run.py``.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from repro.analysis.report import (AMBIGUOUS_BOUND, cross_check,
                                   lint_corpus, static_suite)
from repro.core.table2 import ARCHS

#: Architectures the committed artifact cross-checks (the two the
#: paper's scaling study leans on; --all-archs covers the rest).
BENCH_ARCHS = ("CLX", "ROME")


def _suite_wall_us() -> dict[str, float]:
    """Wall time of one full static audit of every suite kernel, µs
    per kernel and total (trace + jaxpr walk + feature derivation)."""
    from repro.analysis import features
    per_kernel: dict[str, float] = {}
    for case in static_suite():
        fn, args = case.build()
        t0 = time.perf_counter()
        features(fn, *args, name=case.label, reuse=case.reuse)
        per_kernel[case.label] = (time.perf_counter() - t0) * 1e6
    return {"per_kernel": per_kernel,
            "total": sum(per_kernel.values()),
            "mean": sum(per_kernel.values()) / len(per_kernel)}


def build_report(archs=BENCH_ARCHS) -> dict:
    wall = _suite_wall_us()
    cells = []
    for arch in archs:
        cells.extend(cross_check(arch))
    diags = lint_corpus()
    max_f_err = max(c["f_err"] for c in cells)
    ok = all(c["ok"] for c in cells) and not diags
    return {
        "benchmark": "analysis_accuracy",
        "ok": ok,
        "archs": list(archs),
        "bound": AMBIGUOUS_BOUND,
        "max_f_err": max_f_err,
        "n_cells": len(cells),
        "n_exact": sum(c["exact"] for c in cells),
        "counts_match_all_exact": all(c["counts_match"] for c in cells
                                      if c["exact"]),
        "analysis_wall_us": wall,
        "lint": {"diagnostics": len(diags),
                 "rules_fired": sorted({d.rule for d in diags})},
        "cells": cells,
    }


def rows():
    """Benchmark-driver protocol: one row per (kernel, arch) cell with
    the per-kernel audit wall time and the bridged-f comparison."""
    wall = _suite_wall_us()["per_kernel"]
    for arch in BENCH_ARCHS:
        for c in cross_check(arch):
            yield (f"static[{c['label']}/{arch}]", wall[c["label"]], {
                "f_static": c["f_static"], "f_table_ecm": c["f_table_ecm"],
                "f_err": c["f_err"], "exact": c["exact"], "ok": c["ok"],
            })


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_analysis.json")
    ap.add_argument("--all-archs", action="store_true",
                    help="cross-check every Table II architecture")
    args = ap.parse_args(argv)
    report = build_report(ARCHS if args.all_archs else BENCH_ARCHS)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"{report['n_cells']} cells over {report['archs']}  "
          f"max f err {report['max_f_err']:.2%} "
          f"(bound {report['bound']:.0%})  "
          f"lint diagnostics {report['lint']['diagnostics']}  "
          f"audit {report['analysis_wall_us']['mean']:.0f} us/kernel")
    print(f"wrote {args.out}  (ok={report['ok']})")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
