"""Sharding rules: parameter/optimizer/batch/cache PartitionSpecs.

Scheme (Megatron + FSDP hybrid, per assigned mesh):
  mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
  * TP over "model": attention heads (column-shard wq/wk/wv, row-shard wo),
    MLP ff (column wi/wg, row wo), vocab (embed rows, unembed cols), MoE
    experts (EP), SSD/LRU channels.
  * DP/FSDP over ("pod", "data"): the batch dimension always; additionally
    the largest weight dim of big dense archs is FSDP-sharded (ZeRO-3 —
    optimizer state inherits it for free since it mirrors params).
  * Scan-stacked params carry a leading layer axis: specs below are written
    WITHOUT it and get None prepended automatically for stacked trees.

All rules are path-regex -> PartitionSpec; unlisted tensors replicate.
"""

from __future__ import annotations

import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

# dp: the (pod, data) superaxis; tp: "model".


def _dp(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def param_rules(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool | None = None
                ) -> list[tuple[str, P]]:
    """Ordered (regex, spec) rules over '/'-joined param paths."""
    dp = _dp(mesh)
    if fsdp is None:
        # FSDP for the big dense archs; small models replicate over dp.
        fsdp = cfg.param_count() * 4 > 4e9
    row = P("model", None)       # (in_sharded, out)
    col = P(None, "model")       # (in, out_sharded)
    col_f = P(dp, "model") if fsdp else col
    row_f = P("model", dp) if fsdp else row

    rules: list[tuple[str, P]] = [
        # embeddings: vocab-parallel
        (r"embed$", P("model", None)),
        (r"unembed$", col_f),
        # attention
        (r"attn/wq$|self/wq$|cross/wq$|mix/wq$", col_f),
        (r"attn/wk$|self/wk$|cross/wk$|mix/wk$", col),
        (r"attn/wv$|self/wv$|cross/wv$|mix/wv$", col),
        (r"attn/wo$|self/wo$|cross/wo$|mix/wo$", row_f),
        (r"/b[qkv]$", P("model")),
        # dense MLP
        (r"mlp/wi$|mlp/wg$", col_f),
        (r"mlp/wo$", row_f),
        # MoE: experts over "model" (EP); router replicated
        (r"moe/wi$|moe/wg$|moe/wo$", P("model", None, None)),
        (r"moe/router$", P()),
        # Mamba2 SSD
        (r"/wz$|/wx$", col),
        (r"/wb$|/wc$|/wdt$", P()),
        (r"conv_x$", P(None, "model")),
        (r"conv_xb$", P("model")),
        (r"out_proj$", row),
        (r"out_ln/w$", P("model")),
        # RG-LRU (recurrentgemma)
        (r"mix/wy$", col),
        (r"mix/conv_w$", P(None, "model")),
        (r"mix/conv_b$", P("model")),
        (r"mix/wa$|mix/wi$", P(None, "model")),
        (r"mix/ba$|mix/bi$|mix/lam$", P("model")),
        (r"mix/wo$", row),
    ]
    return rules


def _spec_for(path: str, rules, *, stacked: bool) -> P:
    for pat, spec in rules:
        if re.search(pat, path):
            if stacked:
                return P(None, *spec)
            return spec
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape, *,
                    fsdp: bool | None = None, dp_only: bool = False):
    """Pytree of NamedShardings matching a params (shape) tree.

    Detects scan-stacking by path: anything under 'layers/', 'rec/',
    'attn/' (top-level), 'enc/', 'dec/' carries a leading layer axis.

    ``dp_only``: no tensor parallelism — params are FSDP-sharded over ALL
    mesh axes on their largest dimension (so the batch can use the full
    mesh as data parallelism).  The right strategy when the arch's head
    count doesn't divide the model axis (qwen2-0.5b: 14 heads vs 16-way
    TP would replicate the whole attention computation 16x).
    """
    if dp_only:
        all_axes = tuple(mesh.axis_names)

        def assign_dp(path, leaf):
            shape = leaf.shape
            if not shape:
                return NamedSharding(mesh, P())
            # Shard the largest dim over all axes jointly, if divisible.
            dim = max(range(len(shape)), key=lambda i: shape[i])
            spec = [None] * len(shape)
            spec[dim] = all_axes
            return NamedSharding(mesh, _validate(P(*spec), shape, mesh))

        return jax.tree_util.tree_map_with_path(assign_dp, params_shape)

    rules = param_rules(cfg, mesh, fsdp=fsdp)
    stacked_roots = ("layers", "rec", "attn", "enc", "dec")

    def assign(path, leaf):
        ps = _path_str(path)
        stacked = ps.split("/", 1)[0] in stacked_roots
        spec = _spec_for(ps, rules, stacked=stacked)
        spec = _validate(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, params_shape)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    shape = dict(mesh.shape)
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= shape.get(a, 1)
        return n
    return shape.get(axis, 1)


def _validate(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the tensor can't divide (e.g. kv_heads < tp)."""
    new = []
    for i, axis in enumerate(spec):
        if i >= len(shape):
            break
        size = _axis_size(mesh, axis)
        if axis is not None and (size == 0 or shape[i] % size):
            new.append(None)
        else:
            new.append(axis)
    return P(*new)


def batch_shardings(mesh: Mesh, batch_specs: dict, *, dp_only: bool = False):
    """Global batch: leading dim over (pod, data) — or over ALL axes in
    dp_only mode (falling back to (pod, data) when the batch can't divide
    the full mesh)."""
    if dp_only:
        # Prefer the widest divisible axis combination.
        candidates = [tuple(mesh.axis_names),
                      tuple(a for a in ("data", "model")
                            if a in mesh.axis_names),
                      _dp(mesh)]
    else:
        candidates = [_dp(mesh)]
    out = {}
    for k, v in batch_specs.items():
        axes = candidates[-1]
        for cand in candidates:
            if v.shape and v.shape[0] % _axis_size(mesh, cand) == 0:
                axes = cand
                break
        spec = [axes] + [None] * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, _validate(P(*spec), v.shape, mesh))
    return out


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shape):
    """KV/state caches for decode.

    Layout: (L, B, S, KV, hd) attention caches — batch over dp, then prefer
    sharding KV heads over "model"; if KV heads don't divide the TP width
    (MQA), shard the *sequence* axis instead (context parallelism: XLA
    inserts the softmax-combine collectives).
    SSM/LRU states: (L, B, ...) — batch over dp, channels over model.
    """
    dp = _dp(mesh)
    tp = mesh.shape["model"]

    def assign(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if ps.endswith("k") or ps.endswith("v") or "xk" in ps or "xv" in ps:
            # (L, B, S, KV, hd)
            kv = shape[3] if len(shape) == 5 else 0
            if kv and kv % tp == 0:
                spec = P(None, dp, None, "model", None)
            else:
                spec = P(None, dp, "model", None, None)
        elif "ssm" in ps:
            # (L, B, H, N, P): heads over model
            spec = P(None, dp, "model", None, None)
        elif "conv" in ps:
            spec = P(None, dp, None, "model")
        elif ps.endswith("h"):           # RG-LRU hidden (L, B, W)
            spec = P(None, dp, "model")
        else:
            spec = P(*([None] * len(shape)))
        return NamedSharding(mesh, _validate(spec, shape, mesh))

    return jax.tree_util.tree_map_with_path(assign, cache_shape)
