"""Machine models: the paper's four x86 contention domains (Table I) plus the
TPU v5e chip model this framework targets.

A :class:`MachineModel` describes one *memory contention domain* — the unit over
which the paper's bandwidth-sharing model (core/sharing.py) arbitrates.  On the
x86 systems that is a ccNUMA domain; on TPU v5e it is a single chip's HBM
interface, shared between the MXU/VPU load streams, DMA engines, and the
ICI send/recv buffers of in-flight collectives.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping


@dataclasses.dataclass(frozen=True)
class CacheLevel:
    """One level of the cache/memory hierarchy (per-core unless ``shared``)."""

    name: str
    size_bytes: int
    shared: bool = False
    # Bandwidth of the data path *into* this level from the level above
    # (closer to the core), in bytes per core cycle.  ``None`` for L1 (register
    # file path is modelled via ld/st throughput instead).
    bw_bytes_per_cycle: float | None = None


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """A memory contention domain.

    ``overlapping_transfers`` switches the ECM composition rule (paper Eq. 1):
    ``False`` → Intel-style serial addition of transfer times,
    ``True``  → AMD-Rome-style full overlap (max of contributions).
    """

    name: str
    cores_per_domain: int
    clock_ghz: float
    # Theoretical (pin) memory bandwidth of the domain, GB/s.
    theoretical_bw_gbs: float
    # Measured saturated bandwidth envelope, GB/s.  Keyed by "read_only" /
    # "read_write"; kernels interpolate between these by their stream mix.
    saturated_bw_gbs: Mapping[str, float]
    cache_levels: tuple[CacheLevel, ...]
    # SIMD width in bytes for loads/stores (AVX2: 32, AVX-512: 64).
    simd_bytes: int
    # Sustained load / store slots per cycle.
    loads_per_cycle: float
    stores_per_cycle: float
    # FMA throughput: SIMD FMA instructions retired per cycle.
    fma_per_cycle: float
    overlapping_transfers: bool
    victim_llc: bool
    inclusive_llc: bool

    @property
    def cycle_s(self) -> float:
        return 1.0 / (self.clock_ghz * 1e9)

    def bw_bytes_per_cycle(self, gbs: float) -> float:
        """Convert a GB/s figure to bytes per core cycle on this machine."""
        return gbs * 1e9 / (self.clock_ghz * 1e9)

    @property
    def llc(self) -> CacheLevel:
        return self.cache_levels[-1]


# ---------------------------------------------------------------------------
# Paper Table I.  Saturated-bandwidth envelopes are taken from the read-only /
# read-write extremes of Table II (vectorSUM vs. Schoenauer family).
# ---------------------------------------------------------------------------

KiB = 1024
MiB = 1024 * KiB

BDW1 = MachineModel(
    name="BDW-1",
    cores_per_domain=10,
    clock_ghz=2.2,
    theoretical_bw_gbs=68.3,
    saturated_bw_gbs={"read_only": 59.9, "read_write": 53.2},
    cache_levels=(
        CacheLevel("L1", 32 * KiB),
        CacheLevel("L2", 256 * KiB, bw_bytes_per_cycle=64.0),
        CacheLevel("L3", 25 * MiB, shared=True, bw_bytes_per_cycle=32.0),
    ),
    simd_bytes=32,
    loads_per_cycle=2.0,
    stores_per_cycle=1.0,
    fma_per_cycle=2.0,
    overlapping_transfers=False,
    victim_llc=False,
    inclusive_llc=True,
)

BDW2 = MachineModel(
    name="BDW-2",
    cores_per_domain=18,
    clock_ghz=2.3,
    theoretical_bw_gbs=76.8,
    saturated_bw_gbs={"read_only": 66.9, "read_write": 62.2},
    cache_levels=(
        CacheLevel("L1", 32 * KiB),
        CacheLevel("L2", 256 * KiB, bw_bytes_per_cycle=64.0),
        CacheLevel("L3", 45 * MiB, shared=True, bw_bytes_per_cycle=32.0),
    ),
    simd_bytes=32,
    loads_per_cycle=2.0,
    stores_per_cycle=1.0,
    fma_per_cycle=2.0,
    overlapping_transfers=False,
    victim_llc=False,
    inclusive_llc=True,
)

CLX = MachineModel(
    name="CLX",
    cores_per_domain=20,
    clock_ghz=2.5,
    theoretical_bw_gbs=140.8,
    saturated_bw_gbs={"read_only": 111.1, "read_write": 102.4},
    cache_levels=(
        CacheLevel("L1", 32 * KiB),
        CacheLevel("L2", 1048 * KiB, bw_bytes_per_cycle=64.0),
        # 16+16 B/cy bidirectional mesh link to the (exclusive) LLC.
        CacheLevel("L3", int(27.5 * MiB), shared=True, bw_bytes_per_cycle=32.0),
    ),
    simd_bytes=64,
    loads_per_cycle=2.0,
    stores_per_cycle=1.0,
    fma_per_cycle=2.0,
    overlapping_transfers=False,
    victim_llc=True,
    inclusive_llc=False,
)

ROME = MachineModel(
    name="ROME",
    cores_per_domain=8,
    clock_ghz=2.35,
    theoretical_bw_gbs=42.7,  # one NPS4 quadrant of the 170.6 GB/s socket
    saturated_bw_gbs={"read_only": 36.0, "read_write": 32.2},
    cache_levels=(
        CacheLevel("L1", 32 * KiB),
        CacheLevel("L2", 512 * KiB, bw_bytes_per_cycle=64.0),  # 32+32 B/cy
        CacheLevel("L3", 8 * MiB, shared=True, bw_bytes_per_cycle=32.0),
    ),
    simd_bytes=32,
    loads_per_cycle=2.0,
    stores_per_cycle=1.0,
    fma_per_cycle=2.0,
    overlapping_transfers=True,
    victim_llc=True,
    inclusive_llc=False,
)

X86_MACHINES: dict[str, MachineModel] = {
    m.name: m for m in (BDW1, BDW2, CLX, ROME)
}


# ---------------------------------------------------------------------------
# TPU v5e — the target of the framework.  The "contention domain" is one
# chip's HBM interface; the "cores" of the paper map to concurrent on-chip
# streams (compute-phase loads, DMA prefetch, collective send/recv drains).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TpuModel:
    name: str
    peak_flops_bf16: float  # FLOP/s per chip
    hbm_bw_gbs: float       # GB/s per chip
    hbm_bytes: int
    vmem_bytes: int
    ici_link_gbs: float     # GB/s per ICI link direction
    ici_links: int          # links per chip in a 2D torus
    mxu_dim: int = 128      # systolic array edge — matmul tiling granularity
    lane_dim: int = 128     # VPU lane count — last-axis tiling granularity
    sublane_dim: int = 8    # VPU sublanes (fp32); 16 for bf16

    @property
    def balance_flops_per_byte(self) -> float:
        """Machine balance: flops per HBM byte at roofline ridge."""
        return self.peak_flops_bf16 / (self.hbm_bw_gbs * 1e9)


TPU_V5E = TpuModel(
    name="TPUv5e",
    peak_flops_bf16=197e12,
    hbm_bw_gbs=819.0,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * MiB,
    ici_link_gbs=50.0,
    ici_links=4,
)
