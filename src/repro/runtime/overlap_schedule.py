"""Overlap scheduler: decides compute/collective co-scheduling using the
paper's bandwidth-sharing model (core/overlap.py).

Given the roofline decomposition of a training step (from the dry-run HLO or
from analytic estimates), it answers:
  * should the gradient reduce-scatter overlap the backward pass at all?
  * if so, into how many buckets should it be split?
  * what is the predicted step time under each policy?

The classical heuristic ("always overlap, assume it's free") over-predicts
speedup when the collective's HBM drain contends with the backward matmuls'
streams — exactly the effect the paper models with Eqs. 4–5.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Mapping, Sequence

import numpy as np

from ..api import Scenario, ScenarioBatch
from ..api import compile as compile_plan
from ..configs.base import ModelConfig
from ..core.hlo import RooflineTerms
from ..core.machine import TPU_V5E, TpuModel
from ..core.overlap import Phase, best_bucket_count, overlap_pair
from ..core.sharing import solve_arrays
from ..core.topology import Topology, tpu_pod
from ..obs import trace


@dataclasses.dataclass(frozen=True)
class OverlapPlan:
    overlap: bool
    n_buckets: int
    t_serial: float
    t_planned: float
    t_naive_roofline: float     # what "perfect overlap" would promise

    @property
    def predicted_gain(self) -> float:
        return self.t_serial / self.t_planned if self.t_planned else 1.0


def plan_gradient_overlap(terms: RooflineTerms, *,
                          backward_frac: float = 2 / 3,
                          tpu: TpuModel = TPU_V5E) -> OverlapPlan:
    """Build the overlap plan from a step's roofline terms.

    ``backward_frac``: share of compute/HBM belonging to the backward pass
    (2/3 for standard fwd+bwd without remat; remat shifts it higher).
    """
    bwd = Phase("bwd",
                flops=terms.flops * backward_frac,
                hbm_bytes=terms.hbm_bytes * backward_frac)
    # The gradient collective: its wire bytes on ICI, and an HBM drain of
    # the same magnitude (send buffers are read + recv written once).
    coll = Phase("grad_rs",
                 ici_bytes=terms.wire_bytes,
                 hbm_bytes=2.0 * terms.wire_bytes)
    t_serial = bwd.t_solo(tpu) + coll.t_solo(tpu)
    nb, t_planned = best_bucket_count(bwd, coll, tpu=tpu)
    pred = overlap_pair(bwd, coll, tpu)
    return OverlapPlan(
        overlap=nb > 0 and t_planned < t_serial * 0.995,
        n_buckets=max(nb, 1),
        t_serial=t_serial,
        t_planned=min(t_planned, t_serial),
        t_naive_roofline=pred.t_naive,
    )


@dataclasses.dataclass(frozen=True)
class PodOverlapPlan:
    """Per-chip overlap plans across a pod slice: each chip's HBM domain is
    independent, so the step time is gated by the slowest chip."""

    topology: Topology
    by_chip: Mapping[str, OverlapPlan]

    @property
    def t_step(self) -> float:
        """Data-parallel step time: the allreduce gates on the slowest
        chip's planned time."""
        return max(p.t_planned for p in self.by_chip.values())

    @property
    def straggler_chip(self) -> str:
        return max(self.by_chip, key=lambda c: self.by_chip[c].t_planned)


def plan_pod_overlap(terms: RooflineTerms, *,
                     topology: Topology | None = None,
                     chip_load: Sequence[float] | None = None,
                     backward_frac: float = 2 / 3,
                     tpu: TpuModel = TPU_V5E) -> PodOverlapPlan:
    """Plan gradient overlap per chip of a pod topology.

    Each leaf domain of ``topology`` (default: a 4-chip v5e pod from
    :func:`repro.core.topology.tpu_pod`) is planned independently —
    contention domains do not interact, so a straggling chip changes only
    its own plan.  ``chip_load`` scales each chip's compute/HBM work
    (data-parallel imbalance, e.g. ragged batch shards); default uniform.
    """
    topo = topology if topology is not None else tpu_pod(tpu)
    chips = topo.domain_names
    load = tuple(chip_load) if chip_load is not None else (1.0,) * len(chips)
    if len(load) != len(chips):
        raise ValueError(
            f"chip_load has {len(load)} entries for {len(chips)} chips")
    by_chip = {}
    for chip, scale in zip(chips, load):
        scaled = dataclasses.replace(
            terms,
            t_compute=terms.t_compute * scale,
            t_memory=terms.t_memory * scale,
            flops=terms.flops * scale,
            hbm_bytes=terms.hbm_bytes * scale)
        by_chip[chip] = plan_gradient_overlap(
            scaled, backward_frac=backward_frac, tpu=tpu)
    return PodOverlapPlan(topology=topo, by_chip=by_chip)


# ---------------------------------------------------------------------------
# Batched candidate evaluation via the desync engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PodPlanEvaluation:
    """Simulated outcome of one candidate per-chip load assignment.

    With a noise ensemble (``evaluate_pod_plans(..., ensemble=E)``)
    ``t_step`` and ``bwd_spread`` are means over the candidate's E
    members and ``t_step_worst`` is the slowest member — rank on it to
    pick plans robust to launch jitter, not just fast on the noiseless
    trace.
    """

    chip_load: tuple[float, ...]
    t_step: float        # makespan: gradient allreduce gates on all chips
    bwd_spread: float    # spread of backward-pass finish times (desync)
    n_members: int = 1
    t_step_worst: float = 0.0

    def __post_init__(self):
        if self.t_step_worst == 0.0:
            object.__setattr__(self, "t_step_worst", self.t_step)

    @property
    def balanced(self) -> bool:
        return self.bwd_spread < 0.05 * self.t_step


def evaluate_pod_plans(terms: RooflineTerms,
                       candidate_loads: Sequence[Sequence[float]], *,
                       topology: Topology | None = None,
                       backward_frac: float = 2 / 3,
                       tpu: TpuModel = TPU_V5E,
                       backend: str = "numpy",
                       noise_s: float = 0.0,
                       seed: int = 0,
                       ensemble: int = 1
                       ) -> list[PodPlanEvaluation]:
    """Evaluate B candidate pod plans as **one** batched desync run.

    Each candidate assigns a load factor to every chip (ragged batch
    shards, re-sharding proposals, straggler mitigation plans).  Per chip
    the step is: backward-pass HBM work (scaled by its load), the gradient
    allreduce (ICI wire time; the global sync point), then the collective's
    HBM drain.  Chips live on their own HBM contention domains, so a
    candidate's step time emerges from the simulated dynamics — a lagging
    chip delays the allreduce for everyone, exactly the effect
    :meth:`PodOverlapPlan.t_step` approximates analytically.

    ``noise_s`` adds per-chip exponential launch jitter with that mean;
    ``ensemble`` simulates each candidate under that many independent
    seeds (streams split per ``(seed, member)``, see
    :func:`repro.api.plan.derive_member_seed`).  The whole candidate ×
    seed grid — B·E rows — still advances as **one** compiled engine
    call; per-candidate statistics are reduced from the fused result.

    Results are returned in candidate order (``min(..., key=t_step)``
    picks the winner).
    """
    topo = topology if topology is not None else tpu_pod(tpu)
    chips = topo.domain_names
    candidate_loads = [tuple(c) for c in candidate_loads]
    for i, load in enumerate(candidate_loads):
        if len(load) != len(chips):
            raise ValueError(
                f"candidate {i} has {len(load)} loads for "
                f"{len(chips)} chips")
    if ensemble < 1:
        raise ValueError(f"ensemble must be >= 1, got {ensemble}")
    if ensemble > 1 and noise_s <= 0.0:
        raise ValueError(
            f"ensemble={ensemble} without noise is {ensemble} identical "
            f"runs; pass noise_s > 0 (per-chip launch jitter mean)")

    bwd = Phase("bwd", flops=terms.flops * backward_frac,
                hbm_bytes=terms.hbm_bytes * backward_frac)
    drain = Phase("grad_drain", hbm_bytes=2.0 * terms.wire_bytes)
    wire_s = Phase("wire", ici_bytes=terms.wire_bytes).times(tpu)[2]
    # A lone Work group attains bw = f·b_s under the recursion law, so a
    # phase's simulated solo duration is hbm_bytes/(f·b_s) = t_solo — the
    # sim reproduces the roofline when nothing contends.
    fbs = {ph.name: (max(ph.request_fraction(tpu), 1e-6), tpu.hbm_bw_gbs)
           for ph in (bwd, drain)}
    scens = []
    for load in candidate_loads:
        sc = (Scenario.on("TPU").ranks(len(chips))
              .using(topo).on_domains(chips)
              .step(fbs["bwd"], [bwd.hbm_bytes * s for s in load],
                    name="bwd", tag="bwd")
              .barrier(cost_s=wire_s, tag="grad_ar"))
        if drain.hbm_bytes > 0:
            sc = sc.step(fbs["grad_drain"], drain.hbm_bytes,
                         name="grad_drain", tag="grad_drain")
        if noise_s > 0.0 or ensemble > 1:
            sc = sc.with_noise(noise_s, seed=seed, ensemble=ensemble)
        scens.append(sc)
    # Compile the candidate × seed grid once (program encoding, noise
    # draws, placement validation, backend selection), then run; the
    # jitted engine for this topology's shape bucket is cached
    # process-wide, so repeated searches on one pod compile once.
    # Plans are compared on t_step; a masked deadlocked candidate would
    # win with a bogus short step, so abort loudly instead.
    plan = compile_plan(ScenarioBatch.of(scens), verb="simulate")
    res = plan.run(t_max=1e6, backend=backend, on_deadlock="raise")
    out = []
    for i, load in enumerate(candidate_loads):
        rows = res.rows_for(i)
        steps = [res.makespan(b) for b in rows]
        spreads = [res.end_spread("bwd", b) for b in rows]
        out.append(PodPlanEvaluation(
            chip_load=load,
            t_step=sum(steps) / len(steps),
            bwd_spread=sum(spreads) / len(spreads),
            n_members=len(rows),
            t_step_worst=max(steps)))
    return out


# ---------------------------------------------------------------------------
# Gradient co-design: continuous relaxation of the pod-plan search
# ---------------------------------------------------------------------------


POD_PLAN_METHODS = ("enumerate", "gradient")


@dataclasses.dataclass(frozen=True)
class PodStepCoefficients:
    """The noiseless desync step, reduced to closed form.

    One rank per chip domain means nothing ever contends: each chip's
    backward pass attains the lone-group bandwidth of its domain (Eq. 4–5
    with a single group), the gradient allreduce is a barrier of fixed
    wire time, and the collective drain runs solo afterwards.  The step
    time is therefore exactly

        ``t(x) = max_c(a_c * x_c) + const``

    with ``a_c`` the seconds of backward HBM work per unit load on chip
    ``c`` and ``const = wire_s + t_drain``.  ``a_c`` is computed through
    :func:`repro.core.sharing.solve_arrays` — the same Eq. 4–5 solve the
    desync engine performs per event — so the analytic makespan matches
    the simulated one to float precision and stays differentiable in the
    loads.
    """

    chips: tuple[str, ...]
    a: np.ndarray          # (C,) seconds per unit load on each chip
    const: float           # barrier wire time + collective drain time

    def makespan(self, loads) -> np.ndarray:
        """``max_c(a_c * x_c) + const`` for one load vector or a batch
        of them (last axis = chips)."""
        x = np.asarray(loads, dtype=np.float64)
        return np.max(self.a * x, axis=-1) + self.const

    def makespan_and_grad(self, loads, *, softmax_tau: float | None = None
                          ) -> tuple[float, np.ndarray]:
        """Exact makespan plus its gradient in the loads.

        The max is piecewise linear; the default gradient is the
        subgradient averaged over (near-)argmax chips.  ``softmax_tau``
        smooths it — weights ``softmax((a*x)/tau)`` — mirroring the
        softmin knob in :mod:`repro.core.sharing`: forward values never
        change, only the gradient path.
        """
        x = np.asarray(loads, dtype=np.float64)
        z = self.a * x
        m = float(np.max(z))
        if softmax_tau is not None:
            if softmax_tau <= 0:
                raise ValueError(f"softmax_tau must be > 0, got "
                                 f"{softmax_tau}")
            w = np.exp((z - m) / softmax_tau)
        else:
            w = (z >= m - 1e-12 * max(abs(m), 1.0)).astype(np.float64)
        w = w / w.sum()
        return m + self.const, w * self.a


def pod_step_coefficients(terms: RooflineTerms, *,
                          topology: Topology | None = None,
                          backward_frac: float = 2 / 3,
                          tpu: TpuModel = TPU_V5E) -> PodStepCoefficients:
    """Closed-form coefficients of the noiseless pod step (see
    :class:`PodStepCoefficients`).  Built from the identical phase
    decomposition :func:`evaluate_pod_plans` hands the simulator."""
    topo = topology if topology is not None else tpu_pod(tpu)
    chips = topo.domain_names
    nc = len(chips)
    bwd = Phase("bwd", flops=terms.flops * backward_frac,
                hbm_bytes=terms.hbm_bytes * backward_frac)
    drain = Phase("grad_drain", hbm_bytes=2.0 * terms.wire_bytes)
    wire_s = Phase("wire", ici_bytes=terms.wire_bytes).times(tpu)[2]
    f_bwd = max(bwd.request_fraction(tpu), 1e-6)
    f_drn = max(drain.request_fraction(tpu), 1e-6)
    # Lone-group Eq. 4–5 solves — the bwd and drain phases never coexist
    # on a chip (the barrier separates them), so each is a single-group
    # row.  Identical law and parameters to the engine's per-event
    # solve, so the analytic step reproduces the simulation.
    _, _, _, bw = solve_arrays(
        np.ones((nc, 1)), np.full((nc, 1), f_bwd),
        np.full((nc, 1), tpu.hbm_bw_gbs), backend="numpy")
    _, _, _, bw_d = solve_arrays(
        np.ones((1, 1)), np.full((1, 1), f_drn),
        np.full((1, 1), tpu.hbm_bw_gbs), backend="numpy")
    a = bwd.hbm_bytes / (np.maximum(bw[:, 0], 1e-30) * 1e9)
    t_drain = (drain.hbm_bytes / (float(bw_d[0, 0]) * 1e9)
               if drain.hbm_bytes > 0 else 0.0)
    return PodStepCoefficients(chips=tuple(chips), a=a,
                               const=wire_s + t_drain)


def _project_capped_simplex(y: np.ndarray, total: float,
                            lb: np.ndarray, ub: np.ndarray) -> np.ndarray:
    """Euclidean projection onto ``{x : sum(x) = total, lb <= x <= ub}``
    by bisection on the dual variable of the sum constraint.

    ``sum(clip(y - lam, lb, ub))`` is monotone non-increasing in ``lam``,
    so 60 halvings pin it to float precision."""
    if not (lb.sum() - 1e-9 <= total <= ub.sum() + 1e-9):
        raise ValueError(
            f"infeasible projection: need sum(lb)={lb.sum():.6g} <= "
            f"total={total:.6g} <= sum(ub)={ub.sum():.6g}")
    lo = float(np.min(y - ub))
    hi = float(np.max(y - lb))
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if np.clip(y - mid, lb, ub).sum() > total:
            lo = mid
        else:
            hi = mid
    return np.clip(y - 0.5 * (lo + hi), lb, ub)


class StopReason(str, enum.Enum):
    """Why :func:`relax_pod_plan` stopped descending.

    A ``str`` subclass so results compare and serialize as the plain
    reason strings (``res.stop_reason == "converged"`` holds, and json
    export needs no special casing).
    """

    CONVERGED = "converged"            # gradient vanished or 50-step stall
    ITERS_EXHAUSTED = "iters_exhausted"  # ran the full iteration budget
    POINT_POLYTOPE = "point_polytope"  # lb == ub (or iters <= 0): no moves

    def __str__(self) -> str:  # str(reason) -> "converged", not the repr
        return self.value


@dataclasses.dataclass(frozen=True)
class PodPlanRelaxation:
    """Full outcome of the projected-gradient relaxation.

    Unpacks like the historical 3-tuple (``x, t, n_iters = relax_pod_
    plan(...)``) and additionally records the *objective trajectory* —
    the exact makespan of every projected iterate, starting with the
    initial feasible projection — and the :class:`StopReason`.
    """

    x: np.ndarray
    t: float
    n_iters: int
    trajectory: tuple[float, ...]
    stop_reason: StopReason

    def __iter__(self):
        yield self.x
        yield self.t
        yield self.n_iters


def relax_pod_plan(coeffs: PodStepCoefficients, *, total: float,
                   lb: Sequence[float], ub: Sequence[float],
                   iters: int = 300, softmax_tau: float | None = None
                   ) -> PodPlanRelaxation:
    """Projected gradient descent on the analytic makespan over the
    continuous load polytope ``{sum(x) = total, lb <= x <= ub}``.

    Returns a :class:`PodPlanRelaxation` — unpackable as the historical
    ``(x_star, t_star, n_iters)`` triple — holding the best iterate by
    *exact* makespan (the smoothed gradient only steers the descent),
    the per-iterate objective trajectory, and the stopping reason.  The
    objective is piecewise linear and the feasible set is a box-capped
    simplex, so a diminishing-step projected (sub)gradient converges to
    the balanced optimum ``a_c * x_c = const``.
    """
    with trace.span("runtime.relax_pod_plan", iters=iters) as sp:
        lb = np.asarray(lb, dtype=np.float64)
        ub = np.asarray(ub, dtype=np.float64)
        x = _project_capped_simplex(
            np.full(len(coeffs.a), total / len(coeffs.a)), total, lb, ub)
        t_x = float(coeffs.makespan(x))
        best_x, best_t = x, t_x
        trajectory = [t_x]
        span = float(np.max(ub - lb))
        if span <= 0 or iters <= 0:   # a point polytope: nothing to move
            sp.set(n_iters=0, stop_reason=StopReason.POINT_POLYTOPE.value)
            return PodPlanRelaxation(
                x=best_x, t=best_t, n_iters=0,
                trajectory=tuple(trajectory),
                stop_reason=StopReason.POINT_POLYTOPE)
        tau = softmax_tau if softmax_tau is not None else max(
            1e-3 * best_t, 1e-30)
        stall = 0
        it = 0
        reason = StopReason.ITERS_EXHAUSTED
        for it in range(1, iters + 1):
            _, g = coeffs.makespan_and_grad(x, softmax_tau=tau)
            gmax = float(np.max(np.abs(g)))
            if gmax <= 0:
                reason = StopReason.CONVERGED
                break
            eta = 0.5 * span / gmax / (1.0 + 0.05 * it)
            x = _project_capped_simplex(x - eta * g, total, lb, ub)
            t_x = float(coeffs.makespan(x))
            trajectory.append(t_x)
            if t_x < best_t * (1.0 - 1e-12):
                best_x, best_t, stall = x, t_x, 0
            else:
                stall += 1
                if stall >= 50:
                    reason = StopReason.CONVERGED
                    break
        sp.set(n_iters=it, stop_reason=reason.value, t_star=best_t)
        return PodPlanRelaxation(
            x=best_x, t=best_t, n_iters=it, trajectory=tuple(trajectory),
            stop_reason=reason)


@dataclasses.dataclass(frozen=True)
class GradientPlanResult:
    """Outcome of the gradient-relaxed pod-plan search.

    ``x_relaxed``/``t_relaxed`` are the continuous optimum and its
    analytic makespan; ``shortlist`` holds the candidate indices that
    were actually simulated (ranked by analytic makespan, ties broken
    toward the relaxed point); ``best_index``/``best`` identify the
    verified winner among them.  ``trajectory`` is the relaxation's
    exact-makespan objective at every projected iterate (first entry:
    the initial feasible projection) and ``stop_reason`` the
    :class:`StopReason` it ended on — together they show *how* the
    descent converged, not just where."""

    coefficients: PodStepCoefficients
    x_relaxed: tuple[float, ...]
    t_relaxed: float
    n_iters: int
    n_candidates: int
    shortlist: tuple[int, ...]
    best_index: int
    best: PodPlanEvaluation
    trajectory: tuple[float, ...] = ()
    stop_reason: StopReason = StopReason.ITERS_EXHAUSTED


def gradient_pod_plan(terms: RooflineTerms,
                      candidate_loads: Sequence[Sequence[float]], *,
                      topology: Topology | None = None,
                      backward_frac: float = 2 / 3,
                      tpu: TpuModel = TPU_V5E,
                      shortlist: int = 8,
                      iters: int = 300,
                      softmax_tau: float | None = None,
                      **sim_kwargs) -> GradientPlanResult:
    """Pick a pod plan by gradient descent instead of full enumeration.

    The analytic makespan (:func:`pod_step_coefficients`) is descended
    over the continuous load polytope spanned by the candidates, the
    candidates are ranked by that same analytic objective (ties broken
    by distance to the relaxed optimum — the rounding step), and only
    the top ``shortlist`` are verified through the desync simulator via
    :func:`evaluate_pod_plans` (which still accepts ``noise_s``/
    ``ensemble``/``backend`` through ``sim_kwargs``).  Simulation cost
    is O(shortlist) instead of O(candidates).

    All candidates must distribute the *same* total load — the gradient
    walks a fixed-sum polytope; mixed totals are a different design
    space and raise ``ValueError``.
    """
    topo = topology if topology is not None else tpu_pod(tpu)
    chips = topo.domain_names
    loads = np.asarray([tuple(c) for c in candidate_loads],
                       dtype=np.float64)
    if loads.size == 0:
        raise ValueError("no candidate plans given")
    if loads.ndim != 2 or loads.shape[1] != len(chips):
        raise ValueError(
            f"candidates have {loads.shape[-1] if loads.ndim == 2 else '?'}"
            f" loads for {len(chips)} chips")
    sums = loads.sum(axis=1)
    total = float(sums[0])
    if not np.allclose(sums, total, rtol=1e-6, atol=1e-12):
        raise ValueError(
            "gradient method needs every candidate to distribute the same "
            f"total load; candidate sums span [{sums.min():.6g}, "
            f"{sums.max():.6g}]")
    if shortlist < 1:
        raise ValueError(f"shortlist must be >= 1, got {shortlist}")

    coeffs = pod_step_coefficients(terms, topology=topo,
                                   backward_frac=backward_frac, tpu=tpu)
    relaxation = relax_pod_plan(
        coeffs, total=total, lb=loads.min(axis=0), ub=loads.max(axis=0),
        iters=iters, softmax_tau=softmax_tau)
    x_star, t_star, n_iters = relaxation
    # Round: rank candidates on the analytic objective, breaking ties by
    # closeness to the relaxed optimum, then sim-verify the survivors.
    t_cand = coeffs.makespan(loads)
    d2 = np.sum((loads - x_star) ** 2, axis=1)
    order = np.lexsort((d2, t_cand))
    keep = [int(i) for i in order[:min(shortlist, len(order))]]
    evals = evaluate_pod_plans(terms, [tuple(loads[i]) for i in keep],
                               topology=topo, backward_frac=backward_frac,
                               tpu=tpu, **sim_kwargs)
    j = min(range(len(evals)), key=lambda k: evals[k].t_step)
    return GradientPlanResult(
        coefficients=coeffs,
        x_relaxed=tuple(float(v) for v in x_star),
        t_relaxed=t_star,
        n_iters=n_iters,
        n_candidates=len(loads),
        shortlist=tuple(keep),
        best_index=keep[j],
        best=evals[j],
        trajectory=relaxation.trajectory,
        stop_reason=relaxation.stop_reason)


def best_pod_plan(terms: RooflineTerms,
                  candidate_loads: Sequence[Sequence[float]], *,
                  method: str = "enumerate",
                  shortlist: int = 8,
                  **kwargs) -> tuple[int, PodPlanEvaluation]:
    """Index and evaluation of the fastest candidate.

    ``method="enumerate"`` simulates every candidate in one batched
    desync run (exhaustive, O(candidates) simulation rows);
    ``method="gradient"`` descends the analytic makespan and simulates
    only a shortlist (see :func:`gradient_pod_plan`) — the right tool
    when the candidate space is too large to enumerate."""
    if method == "enumerate":
        evals = evaluate_pod_plans(terms, candidate_loads, **kwargs)
        if not evals:
            raise ValueError("no candidate plans given")
        i = min(range(len(evals)), key=lambda j: evals[j].t_step)
        return i, evals[i]
    if method == "gradient":
        res = gradient_pod_plan(terms, candidate_loads,
                                shortlist=shortlist, **kwargs)
        return res.best_index, res.best
    from ..api.registry import unknown_key_error
    raise unknown_key_error("pod-plan method", method,
                            list(POD_PLAN_METHODS))
