"""Public jit'd wrappers for the Pallas kernel suite.

Every op takes ``impl`` selecting the compute path:
  * ``"pallas"``    — the Pallas TPU kernel, compiled for the TPU backend.
  * ``"interpret"`` — the same kernel body executed by the Pallas
    interpreter (CPU-correct; what the tests validate against ref.py).
  * ``"jnp"``       — the pure-jnp oracle (default; used by the model zoo so
    the multi-pod dry-run lowers on any backend).

The tests sweep shapes/dtypes and assert allclose between "interpret" and
"jnp" for every kernel.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from . import decode_attention as _dec
from . import flash_attention as _fa
from . import jacobi as _jac
from . import ref
from . import rmsnorm as _rms
from . import stream as _stream

Impl = Literal["pallas", "interpret", "jnp"]


def _interp(impl: Impl) -> bool:
    if impl not in ("pallas", "interpret", "jnp"):
        raise ValueError(f"unknown impl {impl!r}")
    return impl == "interpret"


# --------------------------------------------------------------------------
# Streaming suite (paper Table II)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("name", "impl"))
def stream_map(name: str, scalar, *arrays, impl: Impl = "jnp"):
    if impl == "jnp":
        fns = {
            "dscal": lambda s, a: ref.dscal(s, a),
            "daxpy": lambda s, a, b: ref.daxpy(s, a, b),
            "add": lambda s, a, b: ref.add(a, b),
            "stream": lambda s, a, b: ref.stream_triad(s, a, b),
            "waxpby": lambda s, a, b: ref.waxpby(s[0], s[1], a, b),
            "dcopy": lambda s, a: ref.dcopy(a),
            "schoenauer": lambda s, a, b, c: ref.schoenauer(a, b, c),
        }
        return fns[name](scalar, *arrays)
    return _stream.map_stream(name, jnp.asarray(scalar), *arrays,
                              interpret=_interp(impl))


@functools.partial(jax.jit, static_argnames=("name", "impl"))
def stream_reduce(name: str, *arrays, impl: Impl = "jnp"):
    if impl == "jnp":
        fns = {"vectorsum": ref.vectorsum, "ddot1": ref.ddot1,
               "ddot2": ref.ddot2, "ddot3": ref.ddot3}
        return fns[name](*arrays)
    return _stream.reduce_stream(name, *arrays, interpret=_interp(impl))


# --------------------------------------------------------------------------
# Jacobi stencils
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("impl",))
def jacobi_v1(a, s, *, impl: Impl = "jnp"):
    if impl == "jnp":
        return ref.jacobi_v1(a, s)
    return _jac.jacobi_v1(a, s, interpret=_interp(impl))


@functools.partial(jax.jit,
                   static_argnames=("ax", "ay", "b1", "relax", "impl"))
def jacobi_v2(a, f, *, ax, ay, b1, relax, impl: Impl = "jnp"):
    if impl == "jnp":
        return ref.jacobi_v2(a, f, ax=ax, ay=ay, b1=b1, relax=relax)
    return _jac.jacobi_v2(a, f, ax=ax, ay=ay, b1=b1, relax=relax,
                          interpret=_interp(impl))


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("causal", "impl", "block_q",
                                             "block_k"))
def attention(q, k, v, *, causal: bool = True, impl: Impl = "jnp",
              block_q: int = 128, block_k: int = 128):
    if impl == "jnp":
        return ref.attention(q, k, v, causal=causal)
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interp(impl))


@functools.partial(jax.jit, static_argnames=("impl", "block_k"))
def decode_attention(q, k_cache, v_cache, lengths, *, impl: Impl = "jnp",
                     block_k: int = 512):
    if impl == "jnp":
        return ref.decode_attention(q, k_cache, v_cache, lengths)
    return _dec.decode_attention(q, k_cache, v_cache, lengths,
                                 block_k=block_k, interpret=_interp(impl))


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("eps", "impl"))
def rmsnorm(x, w, *, eps: float = 1e-6, impl: Impl = "jnp"):
    if impl == "jnp":
        return ref.rmsnorm(x, w, eps=eps)
    return _rms.rmsnorm(x, w, eps=eps, interpret=_interp(impl))


@functools.partial(jax.jit, static_argnames=("eps", "impl"))
def rmsnorm_residual(x, residual, w, *, eps: float = 1e-6,
                     impl: Impl = "jnp"):
    if impl == "jnp":
        return ref.rmsnorm_residual(x, residual, w, eps=eps)
    return _rms.rmsnorm_residual(x, residual, w, eps=eps,
                                 interpret=_interp(impl))
