"""Pallas TPU kernel for the paper's 2-D 5-point Jacobi stencils (Table II).

TPU adaptation of the layer-condition idea: on x86 the LC decides whether
three grid rows fit in L2; on TPU we tile rows into VMEM explicitly, so the
"layer condition" is *enforced by construction* — each grid step holds a
``block_rows + 2`` row window of the source grid (the halo rows) in VMEM.
The up/mid/down row views are materialized by the wrapper as shifted inputs
sharing one BlockSpec shape, which keeps the kernel body free of
inter-block halo logic (on real hardware the three views alias the same HBM
pages; XLA dedupes the loads).

v1:  b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s
v2:  r = (ax*(A[j][i-1]+A[j][i+1]) + ay*(A[j-1][i]+A[j+1][i])
          + b1*A[j][i] - F[j][i]) / b1
     B[j][i] = A[j][i] - relax * r ;  residual += r*r
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 64


def _v1_kernel(up, mid, down, s_ref, out):
    s = s_ref[0, 0]
    m = mid[...]
    left = jnp.roll(m, 1, axis=1)
    right = jnp.roll(m, -1, axis=1)
    res = (left + right + up[...] + down[...]) * s
    # Interior columns only; boundary columns copy the source (Dirichlet).
    col = jax.lax.broadcasted_iota(jnp.int32, m.shape, 1)
    w = m.shape[1]
    out[...] = jnp.where((col > 0) & (col < w - 1), res, m)


def _v2_kernel(up, mid, down, f, coef, out_b, out_r):
    ax, ay, b1, relax = coef[0, 0], coef[0, 1], coef[0, 2], coef[0, 3]
    m = mid[...]
    left = jnp.roll(m, 1, axis=1)
    right = jnp.roll(m, -1, axis=1)
    r1 = (ax * (left + right) + ay * (up[...] + down[...])
          + b1 * m - f[...]) / b1
    col = jax.lax.broadcasted_iota(jnp.int32, m.shape, 1)
    w = m.shape[1]
    interior = (col > 0) & (col < w - 1)
    r1 = jnp.where(interior, r1, 0.0)
    out_b[...] = jnp.where(interior, m - relax * r1, m)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_r[0, 0] = jnp.zeros((), out_r.dtype)

    out_r[0, 0] += jnp.sum(r1 * r1).astype(out_r.dtype)


def _shifted_views(a: jax.Array):
    """up/mid/down row views over the interior rows of ``a``."""
    return a[:-2], a[1:-1], a[2:]


def _row_blocks(rows: int, block_rows: int) -> tuple[int, int]:
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows -= 1
    return rows // block_rows, block_rows


def jacobi_v1(a: jax.Array, s: float | jax.Array, *,
              block_rows: int = DEFAULT_BLOCK_ROWS,
              interpret: bool = True) -> jax.Array:
    """One Jacobi-v1 sweep on the interior of ``a``; returns the full grid
    with boundary rows copied through."""
    h, w = a.shape
    up, mid, down = _shifted_views(a)
    rows = h - 2
    nblk, block_rows = _row_blocks(rows, block_rows)
    s2d = jnp.full((1, 1), s, a.dtype)

    inner = pl.pallas_call(
        _v1_kernel,
        grid=(nblk,),
        in_specs=[
            *[pl.BlockSpec((block_rows, w), lambda i: (i, 0))
              for _ in range(3)],
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, w), a.dtype),
        interpret=interpret,
    )(up, mid, down, s2d)
    return jnp.concatenate([a[:1], inner, a[-1:]], axis=0)


def jacobi_v2(a: jax.Array, f: jax.Array, *, ax: float, ay: float, b1: float,
              relax: float, block_rows: int = DEFAULT_BLOCK_ROWS,
              interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """One Jacobi-v2 sweep; returns (updated grid, residual sum-of-squares)."""
    h, w = a.shape
    up, mid, down = _shifted_views(a)
    f_in = f[1:-1]
    rows = h - 2
    nblk, block_rows = _row_blocks(rows, block_rows)
    coef = jnp.array([[ax, ay, b1, relax]], a.dtype)

    inner, res = pl.pallas_call(
        _v2_kernel,
        grid=(nblk,),
        in_specs=[
            *[pl.BlockSpec((block_rows, w), lambda i: (i, 0))
              for _ in range(3)],
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, w), a.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(up, mid, down, f_in, coef)
    full = jnp.concatenate([a[:1], inner, a[-1:]], axis=0)
    return full, res[0, 0]
