"""THE PAPER'S CONTRIBUTION: the analytic bandwidth-sharing model (Eqs. 4–5).

Given groups of threads concurrently executing different memory-bound loop
kernels on one contention domain, predict the memory-bandwidth share each
group (and each core) attains.  Inputs per group: thread count ``n``, memory
request fraction ``f``, and homogeneous saturated bandwidth ``b_s``.

The model generalizes naturally from the paper's two groups to N groups —
the request-proportional arbitration (Eq. 5) and the thread-weighted
saturation envelope (Eq. 4) are both linear in the groups.  We use the
N-group form throughout (the desync simulator routinely has >2 distinct
kernels in flight).

Two execution paths solve the same equations:

* the **scalar path** (:func:`predict`) — the original single-domain API,
  now a thin wrapper over the array core; returns plain-float
  :class:`SharePrediction` objects and stays the reference implementation;
* the **batched path** (:func:`solve_batch` / :func:`predict_batch`) —
  solves B independent scenarios of up to G groups in one shot, either with
  vectorized numpy or with a ``jax.vmap``-ped, jitted kernel.  Full-domain
  sweeps (benchmarks/fig6_full_domain.py, fig9_pairings.py) and topology
  solves (core/topology.py) go through this path.

Scenarios are rectangular arrays ``n, f, bs`` of shape ``(B, G)``; ragged
group lists are padded with ``n = 0`` entries, which are exactly neutral in
Eqs. 4–5 (they contribute nothing to any sum).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

from . import backend as backend_mod
from .backend import HAVE_JAX  # re-export: the probe lives on the substrate
from .table2 import KernelSpec
from ..obs import metrics, trace

if HAVE_JAX:  # pragma: no branch - capability guard, not dispatch
    import jax
    import jax.numpy as jnp
    from jax import lax


@dataclasses.dataclass(frozen=True)
class Group:
    """One group of threads all executing the same kernel."""

    n: int          # number of threads
    f: float        # memory request fraction of the kernel (Eq. 2/3)
    bs: float       # saturated bandwidth of the kernel, homogeneous run
    name: str = ""

    @staticmethod
    def of(kernel: KernelSpec, arch: str, n: int) -> "Group":
        if arch not in kernel.f or arch not in kernel.bs:
            from ..api.registry import unknown_key_error
            known = sorted(set(kernel.f) & set(kernel.bs))
            raise unknown_key_error("architecture", arch, known)
        return Group(n=n, f=kernel.f[arch], bs=kernel.bs[arch],
                     name=kernel.name)


@dataclasses.dataclass(frozen=True)
class SharePrediction:
    groups: tuple[Group, ...]
    b_overlap: float            # Eq. 4 saturation envelope [GB/s]
    alphas: tuple[float, ...]   # Eq. 5 request shares, sum to 1
    bw_group: tuple[float, ...]  # attained bandwidth per group [GB/s]

    @property
    def bw_per_core(self) -> tuple[float, ...]:
        return tuple(b / g.n if g.n else 0.0
                     for b, g in zip(self.bw_group, self.groups))

    @property
    def total_bw(self) -> float:
        return sum(self.bw_group)


def overlapped_saturated_bw(groups: Sequence[Group]) -> float:
    """Paper Eq. (4): thread-weighted mean of homogeneous saturated bws."""
    n_tot = sum(g.n for g in groups)
    if n_tot == 0:
        return 0.0
    return sum(g.n * g.bs for g in groups) / n_tot


def request_shares(groups: Sequence[Group]) -> tuple[float, ...]:
    """Paper Eq. (5): share of requests (hence bandwidth) per group."""
    weights = [g.n * g.f for g in groups]
    tot = sum(weights)
    if tot == 0.0:
        return tuple(0.0 for _ in groups)
    return tuple(w / tot for w in weights)


def predict(groups: Sequence[Group], *, saturated: bool | None = None,
            utilization: str | float = "recursion",
            p0_factor: float = 0.5) -> SharePrediction:
    """Bandwidth share per group.

    The envelope is ``U(n_t; f̄) · b(mix)``: the Eq. 4 mix envelope scaled by
    the interface utilization at the *mean* request fraction
    ``f̄ = Σ nᵢfᵢ / n_t``.  At saturation U → 1 and the model is exactly
    Eqs. 4–5; below saturation each group's share degrades to its demand
    (paper Sect. IV: the model "can also be applied to the nonsaturated
    case").

    ``utilization`` selects the sub-saturation law:
      * ``"recursion"`` — the paper's simplified latency-penalty recursion
        (Hofmann et al.), penalty ``p0 = p0_factor · T_Mem`` (paper uses
        p0_factor = 1/2; the full model fits it per machine).  Soft knee,
        matches real hardware (paper Fig. 7).
      * ``"queue"`` — ideal work-conserving interface, ``U = min(1, f̄·n_t)``.
        Hard knee, matches the idealized queue instrument (core/memsim.py).
      * a float — externally calibrated utilization.
    ``saturated=True`` forces U = 1.

    This is now a thin wrapper over the vectorized array core
    (:func:`_solve_arrays_np`) with batch size 1; :func:`solve_batch` runs
    the same math over many scenarios at once.
    """
    groups = tuple(groups)
    if not groups:
        return SharePrediction(groups=(), b_overlap=0.0, alphas=(),
                               bw_group=())
    n = np.array([[g.n for g in groups]], dtype=np.float64)
    f = np.array([[g.f for g in groups]], dtype=np.float64)
    bs = np.array([[g.bs for g in groups]], dtype=np.float64)
    b, alphas, util, bw = _solve_arrays_np(
        n, f, bs, utilization=utilization, p0_factor=p0_factor,
        saturated=saturated)
    return SharePrediction(
        groups=groups, b_overlap=float(b[0]),
        alphas=tuple(float(a) for a in alphas[0]),
        bw_group=tuple(float(x) for x in bw[0]))


def pair(kernel_a: KernelSpec, kernel_b: KernelSpec, arch: str,
         n_a: int, n_b: int, **kwargs) -> SharePrediction:
    """Convenience: the paper's two-kernel scenario on architecture ``arch``."""
    return predict([Group.of(kernel_a, arch, n_a),
                    Group.of(kernel_b, arch, n_b)], **kwargs)


def gain_vs_self(kernel_a: KernelSpec, kernel_b: KernelSpec, arch: str,
                 n_each: int) -> float:
    """Paper Fig. 9 bar height: relative bandwidth gain/loss of kernel A when
    paired with B (each on ``n_each`` cores), normalized to A self-paired."""
    mixed = pair(kernel_a, kernel_b, arch, n_each, n_each)
    homo = pair(kernel_a, kernel_a, arch, n_each, n_each)
    return mixed.bw_group[0] / homo.bw_group[0]


def runtime(groups: Sequence[Group], work_bytes: Sequence[float]
            ) -> tuple[float, ...]:
    """Predicted wall time per group to move ``work_bytes`` at the shared
    bandwidth (bytes / (bw per group)).  Used by the desync simulator."""
    pred = predict(groups)
    return tuple(
        wb / (bw * 1e9) if bw > 0 else float("inf")
        for wb, bw in zip(work_bytes, pred.bw_group)
    )


# ---------------------------------------------------------------------------
# Batched solver: B scenarios × G groups in one call.
# ---------------------------------------------------------------------------

_TINY = 1e-300  # division guard far below any physical n·f product

#: The named sub-saturation utilization laws (floats and ``saturated=True``
#: are accepted separately by the solvers).
UTILIZATION_MODES = ("queue", "recursion", "fixedpoint")

#: Bisection depth of the fixed-point utilization solve: 60 halvings of
#: [0, 1] put the bracket below float64 resolution, so the numpy and jax
#: forward passes agree bitwise.
_FP_BISECT_ITERS = 60


def _fixedpoint_u_np(n, f, p0_factor):
    """Self-consistent utilization ``u = min(1, n·f / (1 + p0·f·u·(n−1)))``
    by bisection on the monotone residual ``r(u) = u − S(u)``."""
    c = p0_factor * f * np.maximum(n - 1.0, 0.0)
    lo = np.zeros_like(c)
    hi = np.ones_like(c)
    for _ in range(_FP_BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        r = mid - np.minimum(1.0, n * f / (1.0 + c * mid))
        below = r < 0
        lo = np.where(below, mid, lo)
        hi = np.where(below, hi, mid)
    u = 0.5 * (lo + hi)
    if trace.enabled() and u.size:
        # Diagnostics only — one extra S(u) evaluation, never on the
        # untraced path, and the returned u is untouched either way.
        resid = np.max(np.abs(u - np.minimum(1.0, n * f / (1.0 + c * u))))
        metrics.counter("sharing.fp.solves").inc()
        metrics.counter("sharing.fp.bisect_iters").inc(_FP_BISECT_ITERS)
        metrics.histogram("sharing.fp.residual").observe(float(resid))
    return u


def utilization_curve(n, f, *, mode: str = "recursion",
                      p0_factor: float = 0.5) -> np.ndarray:
    """Sub-saturation interface utilization ``U(n; f)``, vectorized.

    ``n`` and ``f`` broadcast against each other; entries with ``n == 0``
    (or ``f == 0`` in recursion/fixedpoint mode) return 1.0, matching the
    neutral handling inside :func:`_solve_arrays_np`.  Modes:

    * ``"queue"`` — ideal work-conserving interface, ``U = min(1, f·n)``
      (the hard knee of the queue instrument, core/memsim.py);
    * ``"recursion"`` — the simplified latency-penalty recursion of
      Hofmann et al. with ``t_ecm = 1``, ``t_mem = f`` and penalty
      ``p0 = p0_factor · f`` (the soft knee of real hardware, paper
      Fig. 7; equivalent to :func:`repro.core.ecm.scaling_curve`);
    * ``"fixedpoint"`` — the recursion law's self-consistent limit,
      ``u = min(1, n·f / (1 + p0·f·u·(n−1)))``, solved as a fixed point.
      Same soft knee, but the jax path registers a ``custom_vjp`` via the
      implicit function theorem, so backprop costs one elementwise linear
      solve instead of unrolling iterations (docs/model.md).

    This is the single implementation of the utilization law: the batched
    solver evaluates it at each scenario's ``(n_tot, f̄)``, and the
    calibration fit (repro.calibrate.fit) evaluates it over whole scaling
    curves as the Eq. 1–5 forward model — so the two cannot drift.
    """
    n, f = np.broadcast_arrays(np.asarray(n, dtype=np.float64),
                               np.asarray(f, dtype=np.float64))
    active = n > 0
    if mode == "queue":
        return np.where(active, np.minimum(1.0, f * n), 1.0)
    if mode == "recursion":
        # Carry the recursion forward over core counts, freezing each
        # entry at its own n via masking (entries differ in n, share f).
        p0 = p0_factor * f
        u = f.copy()
        n_max = int(n.max()) if n.size else 0
        for i in range(2, n_max + 1):
            t_i = 1.0 + p0 * u * (i - 1)
            u = np.where(i <= n, np.minimum(1.0, i * f / t_i), u)
        return np.where(active & (f > 0), u, 1.0)
    if mode == "fixedpoint":
        u = _fixedpoint_u_np(n, f, p0_factor)
        return np.where(active & (f > 0), u, 1.0)
    from ..api.registry import unknown_key_error
    raise unknown_key_error("utilization mode", mode,
                            list(UTILIZATION_MODES))


def utilization_curve_grad(n, f, *, mode: str = "recursion",
                           p0_factor: float = 0.5
                           ) -> tuple[np.ndarray, np.ndarray]:
    """``(U(n; f), ∂U/∂f)`` for every utilization law, vectorized numpy.

    The derivative is carried analytically through the law itself —
    forward-mode through the recursion sweep, the implicit function
    theorem for the fixed point — so the calibration fit's Gauss–Newton
    refinement (repro.calibrate.fit) gets exact jacobians on the numpy
    backend, matching ``jax.jvp`` over :func:`utilization_curve_jax` on
    the jax backend.  Neutral entries (``n == 0`` / ``f == 0``) return
    ``(1, 0)``; saturated entries have exactly zero derivative (the min
    clamps).
    """
    n, f = np.broadcast_arrays(np.asarray(n, dtype=np.float64),
                               np.asarray(f, dtype=np.float64))
    active = n > 0
    if mode == "queue":
        u = np.where(active, np.minimum(1.0, f * n), 1.0)
        du = np.where(active & (f * n < 1.0), n, 0.0)
        return u, du
    if mode == "recursion":
        p0 = p0_factor * f
        u = f.copy()
        du = np.ones_like(f)
        n_max = int(n.max()) if n.size else 0
        for i in range(2, n_max + 1):
            t_i = 1.0 + p0 * u * (i - 1)
            dt_i = (p0_factor * u + p0 * du) * (i - 1)
            val = i * f / t_i
            dval = i / t_i - i * f * dt_i / (t_i * t_i)
            upd = i <= n
            u = np.where(upd, np.minimum(1.0, val), u)
            du = np.where(upd, np.where(val < 1.0, dval, 0.0), du)
        live = active & (f > 0)
        return np.where(live, u, 1.0), np.where(live, du, 0.0)
    if mode == "fixedpoint":
        u = _fixedpoint_u_np(n, f, p0_factor)
        # IFT on h(u, f) = u + p0·f·(n−1)·u² − n·f = 0 (unsaturated):
        # du/df = (n − p0·(n−1)·u²) / (1 + 2·p0·f·(n−1)·u).
        c = p0_factor * f * np.maximum(n - 1.0, 0.0)
        saturated = n * f >= 1.0 + c
        du = np.where(
            saturated, 0.0,
            (n - p0_factor * np.maximum(n - 1.0, 0.0) * u * u)
            / (1.0 + 2.0 * c * u))
        live = active & (f > 0)
        return np.where(live, u, 1.0), np.where(live, du, 0.0)
    from ..api.registry import unknown_key_error
    raise unknown_key_error("utilization mode", mode,
                            list(UTILIZATION_MODES))


def _solve_arrays_np(n: np.ndarray, f: np.ndarray, bs: np.ndarray, *,
                     utilization: str | float, p0_factor: float,
                     saturated: bool | None
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray]:
    """Vectorized Eqs. 4–5 over ``(B, G)`` arrays.

    Returns ``(b_overlap (B,), alphas (B,G), util (B,), bw_group (B,G))``.
    Entries with ``n == 0`` are neutral padding.  Reference implementation:
    the scalar :func:`predict` wraps this with B = 1.
    """
    n = np.asarray(n, dtype=np.float64)
    f = np.asarray(f, dtype=np.float64)
    bs = np.asarray(bs, dtype=np.float64)
    n_tot = n.sum(axis=-1)
    safe_n = np.maximum(n_tot, 1.0)

    # Eq. 4: thread-weighted saturation envelope.
    b = np.where(n_tot > 0, (n * bs).sum(axis=-1) / safe_n, 0.0)

    # Eq. 5: request-proportional arbitration.
    w = n * f
    w_sum = w.sum(axis=-1)
    alphas = np.where(w_sum[..., None] > 0,
                      w / np.maximum(w_sum, _TINY)[..., None], 0.0)

    # Interface utilization at the mean request fraction (sub-saturation).
    f_mean = np.where(n_tot > 0, w_sum / safe_n, 0.0)
    active = n_tot > 0
    if saturated is True:
        util = np.ones_like(b)
    elif isinstance(utilization, (int, float)):
        util = np.where(active, float(utilization), 1.0)
    elif utilization in UTILIZATION_MODES:
        util = utilization_curve(n_tot, f_mean, mode=utilization,
                                 p0_factor=p0_factor)
    else:
        raise ValueError(f"unknown utilization mode {utilization!r}")

    bw = alphas * (util * b)[..., None]
    return b, alphas, util, bw


if HAVE_JAX:

    def _softmin_jax(a, b, beta):
        """Smooth minimum ``−(1/β)·log(e^{−βa} + e^{−βb})``: a lower bound
        on ``min(a, b)`` approaching it as β → ∞, with everywhere-defined
        gradients (the saturation knee stops being a kink).  Stable via
        ``logaddexp``."""
        return -jnp.logaddexp(-beta * a, -beta * b) / beta

    def _min_fn(beta):
        """The saturation min of the gradient path: exact ``jnp.minimum``
        when ``beta`` is None (a.e.-correct subgradients, the default),
        the β-softmin otherwise."""
        if beta is None:
            return jnp.minimum
        return functools.partial(_softmin_jax, beta=beta)

    @functools.lru_cache(maxsize=None)
    def _fixedpoint_u_jax(beta):
        """The ``"fixedpoint"`` utilization law with a ``custom_vjp``.

        Forward: bisection on ``r(u) = u − S(u)`` with
        ``S(u) = min(1, n·f / (1 + p0·f·u·(n−1)))`` — ``r`` is strictly
        increasing (S is decreasing in u), so the root is unique and 60
        halvings of [0, 1] pin it to float64 resolution, matching
        :func:`_fixedpoint_u_np` bitwise.

        Backward: the implicit function theorem on the converged solution
        instead of unrolling the bisection.  With ``u* = S(u*)``,
        ``du* = ∂S/∂θ · dθ / (1 − ∂S/∂u)`` — and since ``∂S/∂u ≤ 0`` the
        denominator is ≥ 1, so the "linear solve" is one well-conditioned
        elementwise division.
        """
        smin = _min_fn(beta)

        def S(u, n, f, p0):
            c = p0 * f * jnp.maximum(n - 1.0, 0.0)
            return smin(1.0, n * f / (1.0 + c * u))

        @jax.custom_vjp
        def fixed_u(n, f, p0):
            def body(_, lohi):
                lo, hi = lohi
                mid = 0.5 * (lo + hi)
                below = mid - S(mid, n, f, p0) < 0
                return (jnp.where(below, mid, lo),
                        jnp.where(below, hi, mid))

            lo = jnp.zeros_like(n * f)
            lo, hi = lax.fori_loop(0, _FP_BISECT_ITERS, body,
                                   (lo, lo + 1.0))
            return 0.5 * (lo + hi)

        def fwd(n, f, p0):
            u = fixed_u(n, f, p0)
            return u, (u, n, f, p0)

        def bwd(res, g):
            u, n, f, p0 = res
            # S is elementwise, so vjp against ones is exactly ∂S/∂u.
            _, vjp_u = jax.vjp(lambda uu: S(uu, n, f, p0), u)
            ds_du = vjp_u(jnp.ones_like(u))[0]
            lam = g / (1.0 - ds_du)
            _, vjp_theta = jax.vjp(
                lambda nn, ff, pp: S(u, nn, ff, pp), n, f, p0)
            return vjp_theta(lam)

        fixed_u.defvjp(fwd, bwd)
        return fixed_u

    def utilization_curve_jax(n, f, *, mode: str, p0_factor, n_max: int,
                              beta: float | None = None):
        """JAX twin of :func:`utilization_curve` (broadcasting inputs;
        ``n_max`` is the static recursion bound, shared across a vmapped
        batch).  The single jax implementation of the utilization law —
        used by the batched solver below and by the calibration fit
        (repro.calibrate.fit), so the two cannot drift.  ``beta`` selects
        the saturation min of the *gradient path*: None (default) keeps
        the exact ``jnp.minimum``, a float smooths it with
        :func:`_softmin_jax` — forward callers always pass None, so
        values never change."""
        smin = _min_fn(beta)
        active = n > 0
        if mode == "queue":
            return jnp.where(active, smin(1.0, f * n), 1.0)
        if mode == "recursion":
            p0 = p0_factor * f
            u0 = f + 0.0 * n   # broadcast of the u(1) = f seed

            def body(i, u):
                fi = i.astype(u.dtype)
                t_i = 1.0 + p0 * u * (fi - 1.0)
                return jnp.where(fi <= n, smin(1.0, fi * f / t_i), u)

            u = lax.fori_loop(2, n_max + 1, body, u0)
            return jnp.where(active & (f > 0), u, 1.0)
        if mode == "fixedpoint":
            nn, ff = jnp.broadcast_arrays(n + 0.0 * f, f + 0.0 * n)
            u = _fixedpoint_u_jax(beta)(
                nn * 1.0, ff * 1.0, jnp.asarray(p0_factor, nn.dtype))
            return jnp.where(active & (f > 0), u, 1.0)
        raise ValueError(f"unknown utilization mode {mode!r}")

    def _solve_single_jax(n, f, bs, p0_aux, n_max, *, mode: str,
                          beta: float | None = None):
        """One scenario (shape ``(G,)``); vmapped over the batch axis.

        ``p0_aux`` carries ``p0_factor`` (recursion) or the fixed
        utilization (mode "fixed").  ``n_max`` is the loop bound, shared
        across the batch so the vmapped ``fori_loop`` stays uniform.
        ``beta`` is the gradient path's softmin knob (see
        :func:`utilization_curve_jax`); every piece of this solver other
        than the saturation min is already smooth, so the whole Eq. 4–5
        chain is differentiable end to end.
        """
        n_tot = n.sum()
        safe_n = jnp.maximum(n_tot, 1.0)
        b = jnp.where(n_tot > 0, (n * bs).sum() / safe_n, 0.0)
        w = n * f
        w_sum = w.sum()
        alphas = jnp.where(w_sum > 0, w / jnp.maximum(w_sum, _TINY), 0.0)
        f_mean = jnp.where(n_tot > 0, w_sum / safe_n, 0.0)
        active = n_tot > 0
        if mode == "saturated":
            util = jnp.ones_like(b)
        elif mode == "fixed":
            util = jnp.where(active, p0_aux, 1.0)
        else:  # queue / recursion / fixedpoint: the shared law
            util = utilization_curve_jax(n_tot, f_mean, mode=mode,
                                         p0_factor=p0_aux, n_max=n_max,
                                         beta=beta)
        bw = alphas * util * b
        return b, alphas, util, bw

    def _build_jax_solver(mode: str, n_max: int):
        """Jitted vmap of the single-scenario solver for one shape
        bucket; registered in the substrate's process-wide cache."""
        vmapped = jax.vmap(
            functools.partial(_solve_single_jax, mode=mode, n_max=n_max),
            in_axes=(0, 0, 0, None))
        return jax.jit(vmapped)

    def _build_jax_grad_solver(mode: str, n_max: int, beta: float | None,
                               argnums: tuple[int, ...]):
        """Jitted vmap of ``jacrev`` over the single-scenario solver's
        ``bw_group`` output — reverse mode so the ``"fixedpoint"`` law's
        ``custom_vjp`` (one linear solve per backward pass) is what runs;
        registered in the same substrate cache as the forward solvers."""
        def bw_of(n_, f_, bs_, aux):
            return _solve_single_jax(n_, f_, bs_, aux, n_max, mode=mode,
                                     beta=beta)[3]

        jac = jax.jacrev(bw_of, argnums=argnums)
        return jax.jit(jax.vmap(jac, in_axes=(0, 0, 0, None)))

    def _solve_arrays_jax(n, f, bs, *, utilization, p0_factor, saturated):
        """JAX twin of :func:`_solve_arrays_np` (float64 via local x64).

        The jitted solver is fetched from the substrate's cache keyed by
        the padded ``(B, G)`` bucket (plus the static recursion bound),
        so nearby batch sizes share one XLA executable: inputs are
        padded with neutral ``n = 0`` rows up to the bucket and the
        outputs sliced back — exactly neutral in Eqs. 4–5, so the real
        rows are bit-for-bit the unpadded solve.
        """
        if saturated is True:
            mode, aux = "saturated", 0.0
        elif isinstance(utilization, (int, float)):
            mode, aux = "fixed", float(utilization)
        elif utilization in UTILIZATION_MODES:
            mode, aux = utilization, p0_factor
        else:
            raise ValueError(f"unknown utilization mode {utilization!r}")
        n = np.asarray(n, dtype=np.float64)
        B, G = n.shape
        # Only the recursion mode compiles an n-dependent loop; the
        # other modes share one executable per (B, G) bucket.
        n_max = int(n.sum(axis=-1).max()) if (n.size and mode == "recursion") \
            else 0
        n_max_b = backend_mod.bucket(n_max) if n_max else 0
        Bb = backend_mod.bucket(B)
        solver = backend_mod.jitted(
            ("sharing.solve_batch", mode, Bb, G, n_max_b),
            lambda: _build_jax_solver(mode, n_max_b))
        with jax.experimental.enable_x64():
            out = solver(
                jnp.asarray(backend_mod.pad_rows(n, Bb), jnp.float64),
                jnp.asarray(backend_mod.pad_rows(
                    np.asarray(f, dtype=np.float64), Bb), jnp.float64),
                jnp.asarray(backend_mod.pad_rows(
                    np.asarray(bs, dtype=np.float64), Bb), jnp.float64),
                jnp.float64(aux))
        return tuple(np.asarray(x)[:B] for x in out)


@dataclasses.dataclass(frozen=True)
class BatchSharePrediction:
    """Solution of B independent sharing scenarios (arrays, batch-first)."""

    n: np.ndarray          # (B, G) thread counts (float, 0 = padding)
    f: np.ndarray          # (B, G) request fractions
    bs: np.ndarray         # (B, G) saturated bandwidths [GB/s]
    b_overlap: np.ndarray  # (B,)   Eq. 4 envelopes [GB/s]
    alphas: np.ndarray     # (B, G) Eq. 5 request shares
    util: np.ndarray       # (B,)   interface utilization factors
    bw_group: np.ndarray   # (B, G) attained bandwidth per group [GB/s]
    names: tuple[tuple[str, ...], ...] | None = None  # (B, G) group labels

    @property
    def bw_per_core(self) -> np.ndarray:
        return np.divide(self.bw_group, self.n,
                         out=np.zeros_like(self.bw_group),
                         where=self.n > 0)

    @property
    def total_bw(self) -> np.ndarray:
        return self.bw_group.sum(axis=-1)

    def __len__(self) -> int:
        return self.bw_group.shape[0]

    def scenario(self, i: int) -> "SharePrediction":
        """Materialize scenario ``i`` as a scalar-API prediction (padding
        groups dropped).  Group names survive the round trip when the batch
        was built with them (see :func:`groups_to_arrays`)."""
        keep = [j for j in range(self.n.shape[1]) if self.n[i, j] > 0]
        groups = tuple(Group(n=int(self.n[i, j]), f=float(self.f[i, j]),
                             bs=float(self.bs[i, j]),
                             name=(self.names[i][j] if self.names is not None
                                   else ""))
                       for j in keep)
        return SharePrediction(
            groups=groups, b_overlap=float(self.b_overlap[i]),
            alphas=tuple(float(self.alphas[i, j]) for j in keep),
            bw_group=tuple(float(self.bw_group[i, j]) for j in keep))


def solve_arrays(n: np.ndarray, f: np.ndarray, bs: np.ndarray, *,
                 backend: str = "auto",
                 utilization: str | float = "recursion",
                 p0_factor: float = 0.5, saturated: bool | None = None,
                 jax_cutoff: int | None = None,
                 chunk: int | None = None
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """The validated array core behind :func:`solve_batch`.

    ``n``, ``f``, ``bs`` must already be float64 arrays of shape
    ``(B, G)`` — compiled execution plans (:mod:`repro.api.plan`) call
    this directly to skip re-validation on every run.  Returns
    ``(b_overlap (B,), alphas (B,G), util (B,), bw_group (B,G))``.

    ``backend`` resolves through the substrate
    (:func:`repro.core.backend.resolve`): ``"auto"`` picks jax when
    importable and ``B >= jax_cutoff`` (default
    ``REPRO_JAX_CUTOFF`` / 64).  ``chunk`` streams the batch axis in
    slabs of that many scenarios (default ``REPRO_CHUNK_B``; unset =
    whole batch at once) — row-independent math, so chunking is
    bit-for-bit the unchunked solve.
    """
    backend = backend_mod.resolve(backend, n.shape[0],
                                  jax_cutoff=jax_cutoff)
    solve = _solve_arrays_jax if backend == "jax" else _solve_arrays_np
    kwargs = dict(utilization=utilization, p0_factor=p0_factor,
                  saturated=saturated)
    eff_chunk = backend_mod.default_chunk(chunk)
    chunked = eff_chunk is not None and n.shape[0] > eff_chunk

    def dispatch():
        if chunked:
            return backend_mod.run_chunked(
                lambda *arrs: solve(*arrs, **kwargs), (n, f, bs), eff_chunk)
        return solve(n, f, bs, **kwargs)

    if not trace.enabled():  # hot path: no attr dicts, no span object
        return dispatch()
    with trace.span("sharing.solve_arrays", backend=backend,
                    B=int(n.shape[0]), G=int(n.shape[1]),
                    utilization=str(utilization),
                    chunk=eff_chunk if chunked else None):
        return dispatch()


def resolve_backend(backend: str, batch_size: int | None = None, *,
                    jax_cutoff: int | None = None) -> str:
    """The backend a ``solve_batch``-family call with these parameters
    will run on (compiled plans record this at trace time)."""
    return backend_mod.resolve(backend, batch_size, jax_cutoff=jax_cutoff)


def solve_batch(n, f, bs, names=None, *,
                utilization: str | float = "recursion",
                p0_factor: float = 0.5, saturated: bool | None = None,
                backend: str = "auto", jax_cutoff: int | None = None,
                chunk: int | None = None) -> BatchSharePrediction:
    """Solve Eqs. 4–5 for a batch of scenarios.

    ``n``, ``f``, ``bs``: array-likes of shape ``(B, G)`` (a single ``(G,)``
    scenario is promoted to B = 1).  Groups with ``n = 0`` act as padding.
    ``names``: optional ``(B, G)`` nested sequence of group labels, carried
    through to :meth:`BatchSharePrediction.scenario` (padding entries "").
    ``backend``: ``"jax"`` (vmapped + jitted), ``"numpy"``, or ``"auto"``
    (resolved by the substrate: jax when importable and ``B >=
    jax_cutoff``, see :func:`repro.core.backend.resolve`).  Both backends
    compute in float64 and agree with the scalar :func:`predict` to
    ~1e-12 relative.  ``chunk`` streams huge batches in slabs (see
    :func:`solve_arrays`).
    """
    n = np.atleast_2d(np.asarray(n, dtype=np.float64))
    f = np.atleast_2d(np.asarray(f, dtype=np.float64))
    bs = np.atleast_2d(np.asarray(bs, dtype=np.float64))
    if not (n.shape == f.shape == bs.shape):
        raise ValueError(
            f"shape mismatch: n{n.shape} f{f.shape} bs{bs.shape}")
    if names is not None:
        names = tuple(tuple(row) for row in names)
        if len(names) != n.shape[0] or \
                any(len(row) != n.shape[1] for row in names):
            raise ValueError(
                f"names rows {[len(r) for r in names]} do not match "
                f"n{n.shape}")
    b, alphas, util, bw = solve_arrays(
        n, f, bs, backend=backend, utilization=utilization,
        p0_factor=p0_factor, saturated=saturated, jax_cutoff=jax_cutoff,
        chunk=chunk)
    return BatchSharePrediction(n=n, f=f, bs=bs, b_overlap=b, alphas=alphas,
                                util=util, bw_group=bw, names=names)


# ---------------------------------------------------------------------------
# Gradient path: jacobians of the Eq. 4–5 solve wrt its inputs.
# ---------------------------------------------------------------------------

#: Gradient input names → positional argument of the single-scenario
#: solver (``plan.grad(wrt=...)`` uses the same vocabulary).
WRT_ARGNUM = {"cores": 0, "f": 1, "b_s": 2}


def _resolve_grad_mode(utilization, saturated):
    """Map the solver's ``utilization``/``saturated`` knobs onto the jax
    kernel's static mode + traced aux, exactly like the forward path."""
    if saturated is True:
        return "saturated", 0.0
    if isinstance(utilization, (int, float)):
        return "fixed", float(utilization)
    if utilization in UTILIZATION_MODES:
        return utilization, None
    raise ValueError(f"unknown utilization mode {utilization!r}")


def solve_arrays_and_grad(n, f, bs, *, wrt=("f", "b_s"),
                          utilization: str | float = "recursion",
                          p0_factor: float = 0.5,
                          saturated: bool | None = None,
                          softmin_beta: float | None = None,
                          backend: str = "auto",
                          jax_cutoff: int | None = None
                          ) -> tuple[tuple[np.ndarray, np.ndarray,
                                           np.ndarray, np.ndarray],
                                     dict[str, np.ndarray]]:
    """Forward Eq. 4–5 solve plus jacobians of ``bw_group`` wrt inputs.

    Returns ``((b, alphas, util, bw), grads)`` where the forward tuple is
    exactly :func:`solve_arrays` (same ``backend`` dispatch, exact min)
    and ``grads[name]`` has shape ``(B, G, G)`` with
    ``grads[name][b, i, j] = ∂ bw_group[b, i] / ∂ name[b, j]``.

    ``wrt`` ⊆ ``("cores", "f", "b_s")`` — ``"cores"`` differentiates wrt
    the (relaxed, real-valued) thread counts ``n``.  The jacobians run in
    reverse mode on the jax backend, through :func:`_solve_single_jax`
    with lax selects everywhere (so padding rows stay neutral) and, in
    ``"fixedpoint"`` mode, through the implicit-function-theorem
    ``custom_vjp`` of :func:`_fixedpoint_u_jax`.  ``softmin_beta``
    smooths the saturation min *of the gradient path only* (forward
    values never change); None keeps exact a.e. subgradients.  The jitted
    jacobian kernel lives in the same :mod:`repro.core.backend`
    power-of-two bucket cache as the forward solvers, so repeat sweeps of
    nearby batch sizes share one compiled executable.

    Note the Eq. 4–5 coupling is global within a scenario: off-diagonal
    entries (group i's bandwidth wrt group j's inputs) are genuinely
    nonzero, and a padded ``n = 0`` group has zero sensitivity to its own
    ``f``/``b_s`` but a real ``"cores"`` column (adding threads to an
    empty slot changes the mix).  The placed-grid wrapper
    (:func:`solve_placed_and_grad`) zeroes masked lanes outright.
    """
    if not HAVE_JAX:
        raise RuntimeError(
            "solve_arrays_and_grad needs jax for the jacobian path (the "
            "forward-only solvers keep their numpy fallback); install "
            "jax[cpu] or finite-difference solve_arrays instead")
    wrt = tuple(wrt)
    for name in wrt:
        if name not in WRT_ARGNUM:
            from ..api.registry import unknown_key_error
            raise unknown_key_error("gradient input", name,
                                    sorted(WRT_ARGNUM))
    n = np.atleast_2d(np.asarray(n, dtype=np.float64))
    f = np.atleast_2d(np.asarray(f, dtype=np.float64))
    bs = np.atleast_2d(np.asarray(bs, dtype=np.float64))
    mode, fixed_aux = _resolve_grad_mode(utilization, saturated)
    forward = solve_arrays(
        n, f, bs, backend=backend, utilization=utilization,
        p0_factor=p0_factor, saturated=saturated, jax_cutoff=jax_cutoff)
    B, G = n.shape
    aux = p0_factor if fixed_aux is None else fixed_aux
    n_max = int(n.sum(axis=-1).max()) if (n.size and mode == "recursion") \
        else 0
    n_max_b = backend_mod.bucket(n_max) if n_max else 0
    beta = None if softmin_beta is None else float(softmin_beta)
    argnums = tuple(WRT_ARGNUM[name] for name in wrt)
    Bb = backend_mod.bucket(B)
    solver = backend_mod.jitted(
        ("sharing.grad", mode, beta, argnums, Bb, G, n_max_b),
        lambda: _build_jax_grad_solver(mode, n_max_b, beta, argnums))
    with trace.span("sharing.solve_grad", wrt=",".join(wrt), B=B, G=G,
                    mode=mode):
        with jax.experimental.enable_x64():
            jacs = solver(
                jnp.asarray(backend_mod.pad_rows(n, Bb), jnp.float64),
                jnp.asarray(backend_mod.pad_rows(f, Bb), jnp.float64),
                jnp.asarray(backend_mod.pad_rows(bs, Bb), jnp.float64),
                jnp.float64(aux))
    grads = {name: np.asarray(j)[:B]
             for name, j in zip(wrt, jacs)}
    return forward, grads


# ---------------------------------------------------------------------------
# Placement-batched solver: B scenarios × D domains × K groups in one call.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlacedBatchSharePrediction:
    """Solution of B placed scenarios over a padded domain grid.

    The axes are ``(B, D, K)``: B scenarios, each padded to D contention
    domains of up to K groups.  ``mask`` marks the *occupied* lanes —
    cells that carry a real placement (a genuine ``n = 0`` group is
    occupied; a padding lane is not).  Each ``(b, d)`` row is an
    independent Eq. 4–5 instance, so ``b_overlap`` and ``util`` are
    per-domain ``(B, D)`` arrays.
    """

    n: np.ndarray          # (B, D, K) thread counts (masked lanes 0)
    f: np.ndarray          # (B, D, K) request fractions (masked lanes 0)
    bs: np.ndarray         # (B, D, K) saturated bandwidths (masked lanes 0)
    mask: np.ndarray       # (B, D, K) bool, True = occupied lane
    b_overlap: np.ndarray  # (B, D)   Eq. 4 envelopes per domain [GB/s]
    alphas: np.ndarray     # (B, D, K) Eq. 5 request shares within a domain
    util: np.ndarray       # (B, D)   interface utilization per domain
    bw_group: np.ndarray   # (B, D, K) attained bandwidth per lane [GB/s]
    names: tuple[tuple[tuple[str, ...], ...], ...] | None = None

    def __len__(self) -> int:
        return self.bw_group.shape[0]

    @property
    def bw_per_core(self) -> np.ndarray:
        return np.divide(self.bw_group, self.n,
                         out=np.zeros_like(self.bw_group),
                         where=self.n > 0)

    @property
    def domain_bw(self) -> np.ndarray:
        """(B, D) total attained bandwidth per domain [GB/s]."""
        return self.bw_group.sum(axis=-1)

    @property
    def total_bw(self) -> np.ndarray:
        """(B,) aggregate attained bandwidth across every domain."""
        return self.bw_group.sum(axis=(-1, -2))


def solve_placed_batch(n, f, bs, *, mask=None, names=None,
                       utilization: str | float = "recursion",
                       p0_factor: float = 0.5,
                       saturated: bool | None = None,
                       backend: str = "auto", jax_cutoff: int | None = None,
                       chunk: int | None = None
                       ) -> PlacedBatchSharePrediction:
    """Solve Eqs. 4–5 for B placed scenarios in one flattened call.

    ``n``, ``f``, ``bs``: array-likes of shape ``(B, D, K)`` (a single
    ``(D, K)`` scenario is promoted to B = 1) — B scenarios, each padded
    to a common grid of D contention domains with up to K groups per
    domain.  Every ``(b, d)`` row is an independent Eq. 4–5 instance
    (memory controllers of different domains do not contend), so the
    whole grid flattens to one ``(B·D, K)`` :func:`solve_arrays` call —
    the same padded power-of-two bucketing (and therefore the same
    process-wide jit cache) the unplaced batched path uses, so ragged
    placement sweeps of nearby sizes share one compiled solver.

    ``mask`` marks occupied lanes (default ``n > 0``).  Masked-out lanes
    are forced to the neutral ``n = f = bs = 0`` *before* the solve —
    whatever garbage the padding carries (even NaN) cannot perturb the
    occupied lanes, and empty padded domains attain exactly zero
    bandwidth.  Dispatch (``backend``/``jax_cutoff``/``chunk``) resolves
    on the flattened ``B·D`` row count through the substrate policy.
    """
    n = np.asarray(n, dtype=np.float64)
    if n.ndim == 2:
        n = n[None]
    f = np.broadcast_to(np.asarray(f, dtype=np.float64), n.shape)
    bs = np.broadcast_to(np.asarray(bs, dtype=np.float64), n.shape)
    if n.ndim != 3:
        raise ValueError(
            f"placed batches are (B, D, K) arrays, got shape {n.shape}")
    if mask is None:
        mask = n > 0
    else:
        mask = np.broadcast_to(np.asarray(mask, dtype=bool), n.shape)
    # Select, not multiply: np.where drops poisoned padding (NaN/inf
    # included) instead of propagating it through 0 * NaN.
    zero = np.zeros_like(n)
    n = np.where(mask, n, zero)
    f = np.where(mask, f, zero)
    bs = np.where(mask, bs, zero)
    B, D, K = n.shape
    with trace.span("sharing.solve_placed_batch", B=B, D=D, K=K):
        b, alphas, util, bw = solve_arrays(
            n.reshape(B * D, K), f.reshape(B * D, K), bs.reshape(B * D, K),
            backend=backend, utilization=utilization, p0_factor=p0_factor,
            saturated=saturated, jax_cutoff=jax_cutoff, chunk=chunk)
    return PlacedBatchSharePrediction(
        n=n, f=f, bs=bs, mask=mask,
        b_overlap=b.reshape(B, D), alphas=alphas.reshape(B, D, K),
        util=util.reshape(B, D), bw_group=bw.reshape(B, D, K),
        names=names)


def solve_placed_and_grad(n, f, bs, *, mask=None, names=None,
                          wrt=("f", "b_s"),
                          utilization: str | float = "recursion",
                          p0_factor: float = 0.5,
                          saturated: bool | None = None,
                          softmin_beta: float | None = None,
                          backend: str = "auto",
                          jax_cutoff: int | None = None
                          ) -> tuple[PlacedBatchSharePrediction,
                                     dict[str, np.ndarray]]:
    """Placed-grid twin of :func:`solve_arrays_and_grad`.

    Forward is exactly :func:`solve_placed_batch`; ``grads[name]`` has
    shape ``(B, D, K, K)`` with
    ``grads[name][b, d, i, j] = ∂ bw_group[b, d, i] / ∂ name[b, d, j]``
    (domains are independent Eq. 4–5 instances, so there are no cross-
    domain terms).  Masked-out lanes are forced to zero *on both jacobian
    axes*: padding does not exist in the scenario, so its sensitivities —
    including the mathematically nonzero ``"cores"`` column a relaxed
    empty slot would carry — are defined to be 0, and poisoned padding
    (NaN/inf) cannot leak into real lanes' gradients any more than it can
    into their values.
    """
    n = np.asarray(n, dtype=np.float64)
    if n.ndim == 2:
        n = n[None]
    f = np.broadcast_to(np.asarray(f, dtype=np.float64), n.shape)
    bs = np.broadcast_to(np.asarray(bs, dtype=np.float64), n.shape)
    if n.ndim != 3:
        raise ValueError(
            f"placed batches are (B, D, K) arrays, got shape {n.shape}")
    if mask is None:
        mask = n > 0
    else:
        mask = np.broadcast_to(np.asarray(mask, dtype=bool), n.shape)
    zero = np.zeros_like(n)
    n = np.where(mask, n, zero)
    f = np.where(mask, f, zero)
    bs = np.where(mask, bs, zero)
    B, D, K = n.shape
    (b, alphas, util, bw), flat_grads = solve_arrays_and_grad(
        n.reshape(B * D, K), f.reshape(B * D, K), bs.reshape(B * D, K),
        wrt=wrt, utilization=utilization, p0_factor=p0_factor,
        saturated=saturated, softmin_beta=softmin_beta, backend=backend,
        jax_cutoff=jax_cutoff)
    lane = mask[..., :, None] & mask[..., None, :]   # (B, D, K, K)
    grads = {name: np.where(lane, g.reshape(B, D, K, K), 0.0)
             for name, g in flat_grads.items()}
    pred = PlacedBatchSharePrediction(
        n=n, f=f, bs=bs, mask=mask,
        b_overlap=b.reshape(B, D), alphas=alphas.reshape(B, D, K),
        util=util.reshape(B, D), bw_group=bw.reshape(B, D, K),
        names=names)
    return pred, grads


def groups_to_arrays(scenarios: Sequence[Sequence[Group]]
                     ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                tuple[tuple[str, ...], ...]]:
    """Pack ragged per-scenario group lists into padded ``(B, G)`` arrays
    plus a matching ``(B, G)`` grid of group names ("" for padding)."""
    g_max = max((len(s) for s in scenarios), default=0)
    shape = (len(scenarios), max(g_max, 1))
    n = np.zeros(shape)
    f = np.zeros(shape)
    bs = np.zeros(shape)
    names = [[""] * shape[1] for _ in scenarios]
    for i, sc in enumerate(scenarios):
        for j, g in enumerate(sc):
            n[i, j], f[i, j], bs[i, j] = g.n, g.f, g.bs
            names[i][j] = g.name
    return n, f, bs, tuple(tuple(row) for row in names)


def predict_batch(scenarios: Sequence[Sequence[Group]], **kwargs
                  ) -> BatchSharePrediction:
    """Batched :func:`predict` over a list of group lists."""
    return solve_batch(*groups_to_arrays(scenarios), **kwargs)
