"""Calibration round-trip benchmark: the measure→fit→predict loop.

Synthesizes memsim scaling curves for Table II kernels, recovers
``(f, b_s)`` with the batched calibration fit (one vectorized pass over
every (kernel, arch, seed) cell), predicts held-out paired shares from
the calibrated specs, and reports round-trip error against the paper's
8 % bound plus the batched-vs-sequential fit wall-clock.

Run:  PYTHONPATH=src python benchmarks/calibrate_roundtrip.py [--quick]
                                                              [--out FILE]

Writes ``BENCH_calibrate.json`` (the committed certification artifact)
and prints a summary; exits nonzero on a bound violation.  This is a
thin wrapper over :func:`repro.calibrate.certify.main` (one source of
truth for the artifact) plus the ``rows()`` adapter for
``benchmarks/run.py``.
"""

from __future__ import annotations

from repro.calibrate.certify import ERROR_BOUND, certify_quick
from repro.calibrate.certify import main as certify_main


def rows():
    """CSV rows for benchmarks/run.py (reduced grid, so the driver stays
    fast; the full Table II grid runs via __main__ / the slow CI job)."""
    report = certify_quick()
    out = [
        ("calibrate/fit_batched", report.wall_batched_s * 1e6,
         f"cells={len(report.cells)};speedup_vs_sequential="
         f"{report.speedup:.1f}x"),
        ("calibrate/roundtrip_f", 0.0,
         f"max_err={report.max_f_err:.4f};bound={ERROR_BOUND}"),
        ("calibrate/roundtrip_bs", 0.0,
         f"max_err={report.max_bs_err:.4f};bound={ERROR_BOUND}"),
        ("calibrate/pair_holdout", 0.0,
         f"max_err={report.max_pair_err:.4f};bound={ERROR_BOUND}"),
    ]
    if not report.ok():
        raise AssertionError(
            f"calibration round trip exceeded the {ERROR_BOUND:.0%} "
            f"bound: f {report.max_f_err:.2%}, bs {report.max_bs_err:.2%},"
            f" pairs {report.max_pair_err:.2%}")
    return out


if __name__ == "__main__":
    raise SystemExit(certify_main())
