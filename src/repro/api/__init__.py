"""The library's front door: declarative scenarios in, predictions out.

The paper's pitch is radical simplicity — two numbers per kernel,
``(f, b_s)``, predict any pairing — and this package is that simplicity
as an API.  Callers state *what* (kernels, machine, placement, noise)::

    from repro import api

    pred = api.predict(api.Scenario.on("CLX")
                       .run("DCOPY", 12).run("DDOT2", 8))
    pred.bw_per_core          # per-core GB/s for each kernel

and the library picks *how*: the scalar reference solver, the batched
numpy solver, the jitted jax backend, or the desync event engine —
see :mod:`repro.api.engine` for the dispatch table.

Modules:
  scenario — the frozen ``Scenario`` builder + ``ScenarioBatch`` sweeps
  registry — one kernel-spec resolution chain (Table II name →
             calibration → (f, bs) → ECM-from-loop-features) with
             suggestion-bearing lookup errors
  engine   — ``predict`` / ``simulate`` dispatch onto the core engines
  results  — the unified ``Prediction`` / ``BatchPrediction`` /
             ``SimulationResult`` schema with dict/ndjson export

The pre-facade entry points (``sharing.predict``, ``solve_batch``,
``topology.predict_placed``, ``DesyncSimulator``/``run_batch``,
``calibrate.fit_scaling``) remain supported — they are the engines the
facade dispatches to, and facade results are bit-for-bit theirs.
"""

from .engine import JAX_BATCH_CUTOFF, predict, simulate
from .registry import (ResolvedSpec, from_loop_features, known_archs,
                       known_kernels, resolve, suggest,
                       unknown_key_error, unknown_key_message)
from .results import (BatchPrediction, DomainShare, GroupShare, Prediction,
                      SimulationResult, dump_ndjson, load_ndjson)
from .scenario import (DEFAULT_WORK_BYTES, Noise, RunSpec, Scenario,
                       ScenarioBatch, StepSpec)

__all__ = [
    "predict", "simulate", "JAX_BATCH_CUTOFF",
    "Scenario", "ScenarioBatch", "RunSpec", "StepSpec", "Noise",
    "DEFAULT_WORK_BYTES",
    "resolve", "ResolvedSpec", "from_loop_features", "known_kernels",
    "known_archs", "suggest", "unknown_key_error", "unknown_key_message",
    "Prediction", "BatchPrediction", "SimulationResult", "GroupShare",
    "DomainShare", "dump_ndjson", "load_ndjson",
]
