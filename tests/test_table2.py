"""Validate the encoded Table II against the paper's own numbers."""

import math

import pytest

from repro.core import table2
from repro.core.table2 import ARCHS, PAPER_CODE_BALANCE, TABLE2


def test_suite_is_complete():
    # 15 kernels: 4 read-only BLAS1, 7 read-write streaming, 4 stencil cases.
    assert len(TABLE2) == 15
    assert set(PAPER_CODE_BALANCE) <= set(TABLE2)


@pytest.mark.parametrize("name", sorted(PAPER_CODE_BALANCE))
def test_code_balance_matches_paper(name):
    spec = TABLE2[name]
    expected = PAPER_CODE_BALANCE[name]
    if name.startswith("Jacobi"):
        # Stencil balances are per lattice-site update (flop counts include
        # the full residual form for v2) — allow the coarse flop accounting
        # 20% slack.
        assert spec.code_balance == pytest.approx(expected, rel=0.20)
    else:
        assert spec.code_balance == pytest.approx(expected, rel=1e-2)


def test_dcopy_has_no_flops():
    assert TABLE2["DCOPY"].flops_per_iter == 0
    assert math.isinf(TABLE2["DCOPY"].code_balance)


@pytest.mark.parametrize("arch", ARCHS)
def test_read_only_kernels_saturate_higher(arch):
    """Paper Sect. III: read-only kernels achieve 5–15% higher b_s 'as a
    general rule' (DDOT3 on CLX is the paper's own exception at 100.9)."""
    ro = [s.bs[arch] for s in TABLE2.values() if s.read_only]
    rw = [s.bs[arch] for s in TABLE2.values() if not s.read_only]
    mean = lambda xs: sum(xs) / len(xs)
    assert mean(ro) > mean(rw) * 1.02
    assert max(ro) > max(rw)


@pytest.mark.parametrize("arch", ARCHS)
def test_f_in_unit_interval(arch):
    for spec in TABLE2.values():
        assert 0.0 < spec.f[arch] <= 1.0


def test_rome_f_close_to_one():
    """Paper: on Rome 'f is often close to one' for streaming kernels."""
    for spec in TABLE2.values():
        if not spec.name.startswith("Jacobi"):
            assert spec.f["ROME"] > 0.7


def test_intel_f_well_below_one():
    """Non-overlapping hierarchies keep f small even for pure streaming."""
    for spec in TABLE2.values():
        for arch in ("BDW-1", "BDW-2", "CLX"):
            assert spec.f[arch] < 0.45


def test_clx_has_smallest_spread():
    """Paper Sect. V: CLX shows ~10% b_s spread vs ~20% on BDW-1, and less
    spread in f (2.4 vs 2.7) — the reason its sharing variations are mild."""
    def spread(arch, field):
        vals = [getattr(s, field)[arch] for s in TABLE2.values()]
        return max(vals) / min(vals)

    assert spread("CLX", "bs") < spread("BDW-1", "bs")
    assert spread("BDW-1", "bs") == pytest.approx(1.2, abs=0.05)
    assert spread("CLX", "bs") == pytest.approx(1.1, abs=0.05)
    assert spread("CLX", "f") < spread("BDW-1", "f")
    assert spread("BDW-1", "f") == pytest.approx(2.7, abs=0.2)
    assert spread("CLX", "f") == pytest.approx(2.4, abs=0.2)


def test_daxpy_dscal_f_relation():
    """Paper Fig. 9 discussion: f_DAXPY > f_DSCAL on Rome, reversed on Intel."""
    daxpy, dscal = TABLE2["DAXPY"], TABLE2["DSCAL"]
    assert daxpy.f["ROME"] > dscal.f["ROME"]
    for arch in ("BDW-1", "BDW-2", "CLX"):
        assert daxpy.f[arch] < dscal.f[arch]


def test_paper_quoted_f_values():
    """Sect. V quotes f_DAXPY = 0.315 and f_DDOT2 = 0.252 (BDW-1 column)."""
    assert TABLE2["DAXPY"].f["BDW-1"] == pytest.approx(0.315, abs=1e-3)
    assert TABLE2["DDOT2"].f["BDW-1"] == pytest.approx(0.252, abs=1e-3)


def test_layer_condition_reduces_f():
    """LC satisfied at L2 -> fewer L3 streams -> higher f than LC broken."""
    for arch in ARCHS:
        assert TABLE2["JacobiL2-v1"].f[arch] > TABLE2["JacobiL3-v1"].f[arch]
        assert TABLE2["JacobiL2-v2"].f[arch] > TABLE2["JacobiL3-v2"].f[arch]


def test_kernel_lookup_error():
    with pytest.raises(KeyError):
        table2.kernel("NOPE")


# ---------------------------------------------------------------------------
# RECONSTRUCTED cells: every interpolated value must stay inside the
# documented invariants (module docstring of core/table2.py).
# ---------------------------------------------------------------------------

RECON = sorted(table2.RECONSTRUCTED)


def test_reconstructed_triples_are_well_formed():
    for kern, field, arch in RECON:
        assert kern in TABLE2, (kern, field, arch)
        assert field in ("f", "bs"), (kern, field, arch)
        assert arch in ARCHS, (kern, field, arch)


@pytest.mark.parametrize("kern,field,arch",
                         [t for t in RECON if t[1] == "f"],
                         ids=lambda t: str(t))
def test_reconstructed_f_cells_in_admissible_range(kern, field, arch):
    val = TABLE2[kern].f[arch]
    assert 0.0 < val <= 1.0
    if arch == "ROME" and not kern.startswith("Jacobi"):
        # Rome invariant: f close to one for streaming kernels.
        assert val > 0.7, (kern, arch, val)
    if arch != "ROME":
        # Intel invariant: f well below one even for pure streaming.
        assert val < 0.45, (kern, arch, val)


@pytest.mark.parametrize("kern,field,arch",
                         [t for t in RECON if t[1] == "bs"],
                         ids=lambda t: str(t))
def test_reconstructed_bs_cells_respect_read_only_premium(kern, field,
                                                          arch):
    """Interpolated b_s values must sit on the correct side of the
    read-only > read-write saturation split used to fill them."""
    val = TABLE2[kern].bs[arch]
    assert val > 0.0
    spec = TABLE2[kern]
    rw = [s.bs[arch] for s in TABLE2.values() if not s.read_only]
    ro = [s.bs[arch] for s in TABLE2.values() if s.read_only]
    if spec.read_only:
        # Read-only kernels saturate 5–15 % above the write-kernel band
        # (DDOT3/CLX is the paper's own exception — not reconstructed):
        # an interpolated cell must clear the fastest write kernel but
        # stay within a bounded premium over it.
        assert val >= max(rw), (kern, arch, val)
        assert val <= 1.20 * max(rw), (kern, arch, val)
    else:
        assert val <= max(ro), (kern, arch, val)


def test_reconstructed_rome_daxpy_dscal_ordering():
    """The Rome f cells of DAXPY and DSCAL are both reconstructed; their
    documented ordering (f_DAXPY > f_DSCAL, reversed vs Intel) must hold
    in the filled table."""
    assert ("DAXPY", "f", "ROME") in table2.RECONSTRUCTED
    assert ("DSCAL", "f", "ROME") in table2.RECONSTRUCTED
    assert TABLE2["DAXPY"].f["ROME"] > TABLE2["DSCAL"].f["ROME"]


def test_reconstructed_cells_keep_clx_spread_smallest():
    """CLX must keep the smallest f and b_s spread among the Intel
    machines *including* the reconstructed cells (several of which are
    CLX entries).  Rome is excluded: its near-one f values compress its
    spread trivially, which is not the invariant the interpolation used."""
    def spread(arch, field):
        vals = [getattr(s, field)[arch] for s in TABLE2.values()]
        return max(vals) / min(vals)

    for field in ("f", "bs"):
        for other in ("BDW-1", "BDW-2"):
            assert spread("CLX", field) <= spread(other, field), \
                (field, other)


# ---------------------------------------------------------------------------
# from_calibration: calibrated inputs materialize as first-class specs
# ---------------------------------------------------------------------------


def test_from_calibration_with_template_keeps_streams():
    from repro.core.table2 import KernelSpec
    spec = KernelSpec.from_calibration(
        "DCOPY-cal", {"CLX": 0.21}, {"CLX": 101.0},
        template=TABLE2["DCOPY"])
    assert spec.name == "DCOPY-cal"
    assert spec.f == {"CLX": 0.21} and spec.bs == {"CLX": 101.0}
    # stream decomposition inherited -> ECM + desync keep working
    assert (spec.reads, spec.writes, spec.rfo) == (1, 1, 1)
    assert spec.single_core_bw("CLX") == pytest.approx(0.21 * 101.0)


def test_from_calibration_without_template():
    from repro.core.table2 import KernelSpec
    spec = KernelSpec.from_calibration("probe", {"TPU": 0.4},
                                       {"TPU": 800.0})
    assert spec.elem_transfers == 1


def test_from_calibration_rejects_unphysical_inputs():
    from repro.core.table2 import KernelSpec
    with pytest.raises(ValueError, match="outside"):
        KernelSpec.from_calibration("bad", {"CLX": 1.5}, {"CLX": 100.0})
    with pytest.raises(ValueError, match="> 0"):
        KernelSpec.from_calibration("bad", {"CLX": 0.5}, {"CLX": -1.0})
    with pytest.raises(ValueError, match="architecture sets"):
        KernelSpec.from_calibration("bad", {"CLX": 0.5}, {"ROME": 30.0})
