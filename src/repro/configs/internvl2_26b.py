"""internvl2-26b [vlm]: InternViT (stub frontend) + InternLM2 backbone.
[arXiv:2404.16821; hf] — the assignment specifies the transformer BACKBONE
only; input_specs() provides precomputed patch embeddings."""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    kv_heads=8,
    d_ff=16384,
    vocab=92553,
    act="swiglu",
    n_patches=1024,        # stub ViT patch embeddings prepended
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=512, n_patches=8, remat=False, dtype="float32")
