"""Render the dry-run roofline table (EXPERIMENTS.md §Roofline) from
results/dryrun*.jsonl.

  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single]
"""

from __future__ import annotations

import argparse
import glob
import json


def load(paths=None):
    recs = {}
    for path in sorted(paths or glob.glob("results/dryrun*.jsonl")):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                key = (r.get("arch"), r.get("shape"), r.get("mesh"),
                       r.get("variant", "baseline"))
                recs[key] = r  # last write wins (reruns supersede)
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(recs, mesh="single"):
    rows = []
    hdr = ("| arch | shape | T_comp | T_mem | T_coll | dominant | "
           "MODEL/HLO flop | roofline frac | HBM/dev | fits |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for (arch, shape, m, variant), r in sorted(recs.items()):
        if m != mesh or variant != "baseline":
            continue
        if r["status"] == "skipped":
            rows.append(f"| {arch} | {shape} | - | - | - | skipped | - | - "
                        f"| - | {r.get('reason','')[:40]} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {arch} | {shape} | - | - | - | ERROR | - | - "
                        f"| - | {r.get('error','')[:40]} |")
            continue
        rows.append(
            f"| {arch} | {shape} | {fmt_s(r['t_compute_s'])} "
            f"| {fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} "
            f"| {r['dominant']} | {r['useful_flop_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} "
            f"| {r['bytes_per_device_est']/2**30:.2f}GiB "
            f"| {'yes' if r.get('fits_hbm') else 'NO'} |")
    return "\n".join(rows)


def summary(recs):
    ok = [r for r in recs.values() if r["status"] == "ok"]
    skipped = [r for r in recs.values() if r["status"] == "skipped"]
    err = [r for r in recs.values() if r["status"] == "error"]
    lines = [f"cells: ok={len(ok)} skipped={len(skipped)} "
             f"errors={len(err)} total={len(recs)}"]
    for r in err:
        lines.append(f"  ERROR {r['arch']}/{r['shape']}/{r['mesh']}: "
                     f"{r.get('error','')[:120]}")
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    lines.append(f"dominant terms: {doms}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    args = ap.parse_args()
    recs = load()
    print(summary(recs))
    print()
    print(table(recs, args.mesh))


if __name__ == "__main__":
    main()
