"""Executable documentation: the equation-to-code map must not rot.

Every fenced ``python`` block in docs/*.md and README.md is executed (each
file's blocks share one namespace, so later blocks may build on earlier
ones), and every relative markdown link must resolve to a real file.
"""

from __future__ import annotations

import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted(REPO.glob("docs/*.md")) + [REPO / "README.md"]

_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)
# [text](target) — skipping images and in-page anchors.
_LINK_RE = re.compile(r"(?<!!)\[[^\]]+\]\(([^)#\s]+)[^)]*\)")


def _python_blocks(path: pathlib.Path) -> list[str]:
    return _BLOCK_RE.findall(path.read_text())


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_python_blocks_execute(doc):
    blocks = _python_blocks(doc)
    if not blocks:
        pytest.skip(f"{doc.name} has no python blocks")
    namespace: dict = {}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"{doc.name}[block {i}]", "exec"), namespace)
        except Exception as e:  # pragma: no cover - failure reporting
            pytest.fail(f"{doc.name} python block {i} failed: {e!r}\n"
                        f"---\n{block}")


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_relative_links_resolve(doc):
    broken = []
    for target in _LINK_RE.findall(doc.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        resolved = (doc.parent / target).resolve()
        if not resolved.exists():
            broken.append(target)
    assert not broken, f"{doc.name}: broken relative links {broken}"


def test_model_md_cites_equations_next_to_functions():
    """PR acceptance: docs/model.md names paper equation numbers alongside
    the functions implementing them."""
    text = (REPO / "docs" / "model.md").read_text()
    for eq, symbol in [
        ("Eq. 1", "EcmPrediction.t_ecm"),
        ("Eq. 2", "EcmPrediction.f"),
        ("Eq. 3", "KernelSpec.single_core_bw"),
        ("Eq. 4", "overlapped_saturated_bw"),
        ("Eq. 5", "request_shares"),
    ]:
        assert eq in text and symbol in text, (eq, symbol)
        # The equation number and its function must share a table row.
        row = [ln for ln in text.splitlines()
               if eq in ln and symbol in ln]
        assert row, f"{eq} and {symbol} never appear on the same line"
