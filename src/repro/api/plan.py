"""Compiled execution plans: trace a scenario once, run it many times.

The paper's model is cheap per evaluation but is consumed in bulk —
Fig. 6–9 sweeps, calibration's profile least squares, pod-plan searches
all solve the same Eqs. 4–5 / desync structures thousands of times with
only the numbers changing.  ``predict``/``simulate`` pay the full trace
on every call: kernel-spec resolution, provenance collection, array
packing, backend resolution, (for simulations) the per-item program
encoding walk.  A *plan* pays it once::

    plan = api.compile(batch)          # trace: resolve, pack, pick backend
    pred = plan.run()                  # re-execute: just the solve
    pred = plan.run(f=f2, b_s=bs2)     # same structure, new numbers
    pred = plan.run(cores=n2)          # swap thread counts

``plan.run()`` is bit-for-bit ``api.predict(x)`` / ``api.simulate(x)``
— the one-shot verbs are themselves sugar that compiles and runs — and
``plan.run(f=..., b_s=..., cores=...)`` equals a fresh compile of the
modified scenarios, without re-tracing.

Five plan shapes mirror the engine dispatch table:

================  ========================  ============================
plan kind         compiled from             runs on
================  ========================  ============================
``scalar``        single unplaced scenario  ``sharing.predict``
                                            (reference)
``placed``        single placed scenario    ``topology.predict_placed``
``batch``         :class:`ScenarioBatch`    ``sharing.solve_arrays`` —
                                            numpy or the substrate's
                                            cached jitted solver
``placed-batch``  placed ScenarioBatch      ``sharing.
                  (one shared topology)     solve_placed_batch`` over
                                            the packed (B, D, K) grid
``simulate``      any (programs encoded;    ``desync_batch.run_encoded``
                  batch × ensemble fused)
================  ========================  ============================

Backend + jit selection happens at compile time through
:func:`repro.core.backend.resolve` (the tree's only backend policy);
the jitted solvers live in the substrate's process-wide cache keyed by
padded shape bucket, so two plans of the same bucket share one XLA
executable — see ``docs/plans.md`` for the cache-key anatomy and when
compiling pays off.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Mapping, Sequence

import numpy as np

from ..core import backend as backend_mod
from ..core import desync_batch, sharing
from ..core import topology as topology_mod
from ..core.desync import Allreduce, Idle, Item, WaitNeighbors, Work
from ..core.sharing import Group
from ..core.table2 import KernelSpec
from ..obs import trace
from .results import (BatchPrediction, PlacedBatchPrediction, Prediction,
                      Sensitivities, SimulationResult,
                      from_share_prediction, from_topology_prediction)
from .scenario import Scenario, ScenarioBatch

# ---------------------------------------------------------------------------
# Deterministic seed splitting for noise ensembles
# ---------------------------------------------------------------------------

_M64 = (1 << 64) - 1
_GOLDEN = 0x9E3779B97F4A7C15  # 2^64 / phi: SplitMix64's stream constant


def derive_member_seed(seed: int, member: int) -> int:
    """Derive ensemble member ``member``'s RNG seed from the scenario's
    declared ``seed`` via a splittable counter (SplitMix64 finalizer
    over ``seed * golden + member``).

    The historical convention ``Random(seed + member)`` made adjacent
    ensembles share streams — ``(seed=0, member=1)`` and ``(seed=1,
    member=0)`` drew identical noise, silently correlating studies that
    differ only in their base seed.  The split keeps every
    ``(seed, member)`` pair on an independent, reproducible stream:
    repeated ``simulate()`` calls are deterministic by default, and two
    base seeds never alias.
    """
    z = (seed * _GOLDEN + member + 1) & _M64
    z ^= z >> 30
    z = (z * 0xBF58476D1CE4E5B9) & _M64
    z ^= z >> 27
    z = (z * 0x94D049BB133111EB) & _M64
    return z ^ (z >> 31)


def _noise_items(scenario: Scenario, member: int,
                 R: int) -> list[Item | None]:
    """Per-rank leading Idle items for ensemble member ``member`` —
    drawn in rank order from ``Random(derive_member_seed(seed,
    member))``, one independent stream per member."""
    noise = scenario.noise
    if noise is None:
        return [None] * R
    rng = random.Random(derive_member_seed(noise.seed, member))
    return [Idle(rng.expovariate(1.0 / noise.exp_mean_s), tag=noise.tag)
            for _ in range(R)]


def _programs_for(scenario: Scenario, member: int
                  ) -> tuple[list[list[Item]], Sequence[str] | None]:
    """One ensemble member's per-rank programs + placement."""
    if scenario.steps:
        R = scenario.n_ranks
        if R is None:
            raise ValueError("program-mode scenario never called .ranks(R)")
        lead = _noise_items(scenario, member, R)
        progs: list[list[Item]] = []
        for r in range(R):
            prog: list[Item] = [lead[r]] if lead[r] is not None else []
            for s in scenario.steps:
                if s.kind == "work":
                    prog.append(Work(s.resolved.name, s.bytes_for(r),
                                     tag=s.tag))
                elif s.kind == "barrier":
                    prog.append(Allreduce(cost_s=s.cost_s, tag=s.tag))
                elif s.kind == "halo":
                    prog.append(WaitNeighbors(cost_s=s.cost_s, tag=s.tag))
                else:
                    prog.append(Idle(s.cost_s, tag=s.tag))
            progs.append(prog)
        return progs, scenario.rank_domains
    # Group mode: each run contributes n ranks, one Work each.
    if not scenario.runs:
        raise ValueError("nothing to simulate: scenario has no groups or "
                         "steps")
    R = scenario.total_threads
    lead = _noise_items(scenario, member, R)
    progs = []
    placement: list[str] = []
    r = 0
    for run in scenario.runs:
        for _ in range(run.n):
            prog = [lead[r]] if lead[r] is not None else []
            prog.append(Work(run.resolved.name, run.bytes, tag=run.tag))
            progs.append(prog)
            placement.append(run.domain or "")
            r += 1
    has_domains = any(placement)
    if has_domains and not all(placement):
        raise ValueError(
            "either every group or no group must be placed on a domain")
    return progs, (tuple(placement) if has_domains else None)


def _collect_specs(scenarios: Sequence[Scenario]) -> dict[str, KernelSpec]:
    specs: dict[str, KernelSpec] = {}
    for sc in scenarios:
        for res in ([s.resolved for s in sc.steps if s.resolved is not None]
                    + [r.resolved for r in sc.runs]):
            prev = specs.get(res.name)
            if prev is not None and prev is not res.spec \
                    and prev != res.spec:
                raise ValueError(
                    f"two different specs named {res.name!r} in one "
                    f"simulation batch")
            specs[res.name] = res.spec
    return specs


# ---------------------------------------------------------------------------
# Plan shapes
# ---------------------------------------------------------------------------


class Plan:
    """A frozen, re-runnable trace of one scenario (or batch).

    Subclasses implement :meth:`run`; every plan exposes ``kind`` (the
    dispatch row it compiled to) and ``engine`` (the backend it will
    run on, resolved at compile time)."""

    kind: str = ""

    @property
    def engine(self) -> str:
        raise NotImplementedError

    def run(self, **overrides):
        """Re-execute the plan; see the subclass for accepted swaps."""
        raise NotImplementedError

    def grad(self, *, wrt=("f", "b_s"), softmin_beta=None):
        """Run the plan *and* differentiate it: the returned prediction
        carries a :class:`repro.api.results.Sensitivities` block with
        exact jacobians ``∂bw/∂wrt`` through the Eq. 1–5 chain (see the
        prediction-plan subclasses).  Simulation plans cannot be
        differentiated — see :meth:`SimulatePlan.grad`."""
        raise NotImplementedError(
            f"plan kind {self.kind!r} does not support grad()")


def _sensitivities_for(solver_options: Mapping, grads: dict,
                       wrt, softmin_beta) -> Sensitivities:
    return Sensitivities(
        wrt=tuple(wrt), jacobians=grads,
        utilization=solver_options.get("utilization", "recursion"),
        softmin_beta=softmin_beta)


def _swap_scalar(value, name: str, G: int):
    if value is None:
        return [None] * G
    values = list(value) if isinstance(value, (Sequence, np.ndarray)) \
        else [value] * G
    if len(values) != G:
        raise ValueError(
            f"{name} gives {len(values)} values for the plan's {G} "
            f"groups")
    return values


def _swap_groups(groups: tuple[Group, ...], cores, f, b_s
                 ) -> tuple[Group, ...]:
    G = len(groups)
    ns = _swap_scalar(cores, "cores", G)
    fs = _swap_scalar(f, "f", G)
    bss = _swap_scalar(b_s, "b_s", G)
    out = []
    for g, n_, f_, bs_ in zip(groups, ns, fs, bss):
        if n_ is not None or f_ is not None or bs_ is not None:
            g = dataclasses.replace(
                g, n=int(n_) if n_ is not None else g.n,
                f=float(f_) if f_ is not None else g.f,
                bs=float(bs_) if bs_ is not None else g.bs)
        out.append(g)
    return tuple(out)


@dataclasses.dataclass(frozen=True, eq=False)
class ScalarPlan(Plan):
    """Single unplaced scenario → the scalar reference solver."""

    kind = "scalar"
    arch: str
    groups: tuple[Group, ...]
    provenance: tuple[str, ...]
    solver_options: dict

    @property
    def engine(self) -> str:
        return "scalar"

    def run(self, *, cores=None, f=None, b_s=None, backend=None,
            jax_cutoff=None, chunk=None) -> Prediction:
        """Re-solve; ``cores``/``f``/``b_s`` swap per-group numbers
        (scalar or length-G sequence).  ``backend`` is accepted for
        signature uniformity — the scalar path *is* the reference
        implementation and always runs it."""
        with trace.span("api.plan.run", kind=self.kind, engine="scalar"):
            groups = self.groups \
                if cores is None and f is None and b_s is None \
                else _swap_groups(self.groups, cores, f, b_s)
            pred = sharing.predict(groups, **self.solver_options)
            return from_share_prediction(pred, arch=self.arch,
                                         provenance=self.provenance,
                                         engine="scalar")

    def grad(self, *, wrt=("f", "b_s"), softmin_beta=None) -> Prediction:
        """Solve and differentiate: jacobians ``∂bw_i/∂wrt_j`` of shape
        ``(G, G)`` per requested input, attached as
        ``prediction.sensitivities`` (forward values are the unchanged
        scalar solve).  Requires jax; ``softmin_beta`` smooths the
        saturation min on the gradient path only."""
        n = np.array([[float(g.n) for g in self.groups]])
        f = np.array([[g.f for g in self.groups]])
        bs = np.array([[g.bs for g in self.groups]])
        _, grads = sharing.solve_arrays_and_grad(
            n, f, bs, wrt=wrt, softmin_beta=softmin_beta,
            **self.solver_options)
        sens = _sensitivities_for(
            self.solver_options, {k: v[0] for k, v in grads.items()},
            wrt, softmin_beta)
        return dataclasses.replace(self.run(), sensitivities=sens)


@dataclasses.dataclass(frozen=True, eq=False)
class PlacedPlan(Plan):
    """Single topology-placed scenario → the per-domain solver."""

    kind = "placed"
    arch: str
    topo: topology_mod.Topology
    placements: tuple[topology_mod.Placed, ...]
    provenance: tuple[str, ...]
    solver_kwargs: dict        # utilization/p0/saturated/backend/strict

    @property
    def engine(self) -> str:
        return "topology"

    def run(self, *, cores=None, f=None, b_s=None, backend=None,
            jax_cutoff=None, chunk=None) -> Prediction:
        with trace.span("api.plan.run", kind=self.kind, engine="topology"):
            placements = self.placements
            if cores is not None or f is not None or b_s is not None:
                groups = _swap_groups(
                    tuple(p.group for p in placements), cores, f, b_s)
                placements = tuple(
                    topology_mod.Placed(g, p.domain)
                    for g, p in zip(groups, placements))
            kwargs = dict(self.solver_kwargs)
            if backend is not None:
                kwargs["backend"] = backend
            if jax_cutoff is not None:
                kwargs["jax_cutoff"] = jax_cutoff
            if chunk is not None:
                kwargs["chunk"] = chunk
            pred = topology_mod.predict_placed(self.topo, placements,
                                               **kwargs)
            return from_topology_prediction(pred, arch=self.arch,
                                            provenance=self.provenance)

    def grad(self, *, wrt=("f", "b_s"), softmin_beta=None) -> Prediction:
        """Solve and differentiate the placed scenario: jacobians of
        shape ``(D, K, K)`` in grid coordinates (domain, occupancy
        slot — the packing order of :func:`repro.core.topology.
        pack_placed`), attached as ``prediction.sensitivities``.
        Requires jax."""
        grid = topology_mod.pack_placed(
            self.topo, [self.placements],
            strict=self.solver_kwargs.get("strict", True))
        solver_options = {k: v for k, v in self.solver_kwargs.items()
                          if k in ("utilization", "p0_factor", "saturated")}
        _, grads = sharing.solve_placed_and_grad(
            grid.n, grid.f, grid.bs, mask=grid.mask, wrt=wrt,
            softmin_beta=softmin_beta, **solver_options)
        sens = _sensitivities_for(
            solver_options, {k: v[0] for k, v in grads.items()},
            wrt, softmin_beta)
        return dataclasses.replace(self.run(), sensitivities=sens)


def _swap_array(base: np.ndarray, value, name: str) -> np.ndarray:
    if value is None:
        return base
    arr = np.asarray(value, dtype=np.float64)
    try:
        return np.broadcast_to(arr, base.shape)
    except ValueError:
        raise ValueError(
            f"{name} has shape {arr.shape}, not broadcastable to the "
            f"plan's (B, G) = {base.shape}") from None


@dataclasses.dataclass(frozen=True, eq=False)
class BatchPlan(Plan):
    """B scenarios packed once → the batched array solver.

    The trace froze the padded ``(B, G)`` arrays, the per-row arch /
    provenance labels, and the resolved backend; ``run`` goes straight
    to :func:`repro.core.sharing.solve_arrays` — no re-validation, no
    re-packing, and on the jax backend the substrate's cached jitted
    solver (one compile per padded shape bucket, process-wide).
    """

    kind = "batch"
    archs: tuple[str, ...]
    n: np.ndarray
    f: np.ndarray
    bs: np.ndarray
    names: tuple[tuple[str, ...], ...]
    provenance: tuple[tuple[str, ...], ...]
    solver_options: dict
    backend: str               # resolved at compile time
    requested_backend: str     # what the scenarios asked for
    jax_cutoff: int | None
    chunk: int | None

    def __len__(self) -> int:
        return self.n.shape[0]

    @property
    def engine(self) -> str:
        return self.backend

    @property
    def bucket(self) -> tuple[int, int]:
        """The padded jit-cache shape bucket this plan solves in."""
        return (backend_mod.bucket(len(self)), self.n.shape[1])

    def run(self, *, cores=None, f=None, b_s=None, backend=None,
            jax_cutoff=None, chunk=None) -> BatchPrediction:
        """Re-solve the batch.  ``cores``/``f``/``b_s`` swap the packed
        arrays (anything broadcastable to ``(B, G)``); ``backend`` /
        ``jax_cutoff`` / ``chunk`` re-resolve dispatch for this run
        only.  Equal to a fresh ``compile(...).run()`` of the modified
        scenarios, bit for bit."""
        with trace.span("api.plan.run", kind=self.kind, B=len(self)) as sp:
            n_arr = _swap_array(self.n, cores, "cores")
            f_arr = _swap_array(self.f, f, "f")
            bs_arr = _swap_array(self.bs, b_s, "b_s")
            if backend is None and jax_cutoff is None:
                resolved = self.backend
            else:
                resolved = backend_mod.resolve(
                    backend or self.requested_backend, len(self),
                    jax_cutoff=jax_cutoff if jax_cutoff is not None
                    else self.jax_cutoff)
            sp.set(engine=resolved)
            b, alphas, util, bw = sharing.solve_arrays(
                n_arr, f_arr, bs_arr, backend=resolved,
                chunk=chunk if chunk is not None else self.chunk,
                **self.solver_options)
            raw = sharing.BatchSharePrediction(
                n=n_arr, f=f_arr, bs=bs_arr, b_overlap=b, alphas=alphas,
                util=util, bw_group=bw, names=self.names)
            return BatchPrediction(archs=self.archs, engine=resolved,
                                   raw=raw, provenance=self.provenance)

    def grad(self, *, wrt=("f", "b_s"), softmin_beta=None
             ) -> BatchPrediction:
        """Solve and differentiate the whole batch: jacobians
        ``∂bw[b, i]/∂wrt[b, j]`` of shape ``(B, G, G)`` per requested
        input, attached as ``prediction.sensitivities``.  Runs on the
        substrate's jit-bucket cache (requires jax); ``softmin_beta``
        smooths the saturation min on the gradient path only."""
        _, grads = sharing.solve_arrays_and_grad(
            self.n, self.f, self.bs, wrt=wrt, softmin_beta=softmin_beta,
            **self.solver_options)
        sens = _sensitivities_for(self.solver_options, grads, wrt,
                                  softmin_beta)
        return dataclasses.replace(self.run(), sensitivities=sens)


@dataclasses.dataclass(frozen=True, eq=False)
class PlacedBatchPlan(Plan):
    """B placements on one topology packed once → the grid solver.

    The trace paid placement validation and the ``(B, D, K)`` grid
    packing (:func:`repro.core.topology.pack_placed`); ``run`` goes
    straight to :func:`repro.core.sharing.solve_placed_batch`, which
    flattens to ``(B·D, K)`` rows — the same padded power-of-two
    buckets (and the same process-wide jitted solver cache) the
    unplaced :class:`BatchPlan` uses.
    """

    kind = "placed-batch"
    archs: tuple[str, ...]
    grid: topology_mod.PlacedGrid
    provenance: tuple[tuple[str, ...], ...]
    solver_options: dict
    backend: str               # resolved at compile time
    requested_backend: str
    strict: bool
    jax_cutoff: int | None
    chunk: int | None

    def __len__(self) -> int:
        return len(self.grid)

    @property
    def topo(self) -> topology_mod.Topology:
        return self.grid.topology

    @property
    def engine(self) -> str:
        return self.backend

    @property
    def bucket(self) -> tuple[int, int]:
        """The padded jit-cache shape bucket of the flattened solve:
        ``(bucket(B·D), K)`` — two placed sweeps of different raggedness
        that land in one bucket share one compiled solver."""
        B, D, K = self.grid.n.shape
        return (backend_mod.bucket(B * D), K)

    def _dispatch(self, backend, jax_cutoff) -> str:
        if backend is None and jax_cutoff is None:
            return self.backend
        B, D, _ = self.grid.n.shape
        return backend_mod.resolve(
            backend or self.requested_backend, B * D,
            jax_cutoff=jax_cutoff if jax_cutoff is not None
            else self.jax_cutoff)

    def run(self, *, cores=None, f=None, b_s=None, placement=None,
            backend=None, jax_cutoff=None, chunk=None
            ) -> PlacedBatchPrediction:
        """Re-solve the placed batch.

        ``cores``/``f``/``b_s`` swap grid numbers (anything
        broadcastable to the padded ``(B, D, K)``; padding lanes stay
        masked out regardless of what the broadcast writes there).
        ``placement`` swaps the whole placement batch — a sequence of
        B placement lists (:class:`repro.core.topology.Placed`) on the
        plan's topology, re-packed without re-tracing the scenarios.
        ``backend``/``jax_cutoff``/``chunk`` re-resolve dispatch for
        this run only.
        """
        with trace.span("api.plan.run", kind=self.kind,
                        B=len(self)) as sp:
            return self._run_traced(sp, cores, f, b_s, placement, backend,
                                    jax_cutoff, chunk)

    def _run_traced(self, sp, cores, f, b_s, placement, backend,
                    jax_cutoff, chunk) -> PlacedBatchPrediction:
        grid = self.grid
        if placement is not None:
            placement = [tuple(p) for p in placement]
            if len(placement) != len(self):
                raise ValueError(
                    f"placement gives {len(placement)} scenarios for the "
                    f"plan's {len(self)}")
            with trace.span("api.plan.pack"):
                grid = topology_mod.pack_placed(self.topo, placement,
                                                strict=self.strict)
        n_arr = _swap_array(grid.n, cores, "cores")
        f_arr = _swap_array(grid.f, f, "f")
        bs_arr = _swap_array(grid.bs, b_s, "b_s")
        resolved = self._dispatch(backend, jax_cutoff)
        sp.set(engine=resolved)
        shares = sharing.solve_placed_batch(
            n_arr, f_arr, bs_arr, mask=grid.mask, backend=resolved,
            chunk=chunk if chunk is not None else self.chunk,
            **self.solver_options)
        raw = topology_mod.TopologyBatchPrediction(grid=grid, shares=shares)
        prov = self.provenance
        if placement is not None:
            # Swapped placements may change per-scenario group counts;
            # keep labels where they still line up, "" beyond.
            prov = tuple(
                tuple(row[j] if j < len(row) else ""
                      for j in range(len(pl)))
                for row, pl in zip(prov, placement))
        return PlacedBatchPrediction(archs=self.archs, engine=resolved,
                                     raw=raw, provenance=prov)

    def grad(self, *, wrt=("f", "b_s"), softmin_beta=None
             ) -> PlacedBatchPrediction:
        """Solve and differentiate the placed batch: jacobians of shape
        ``(B, D, K, K)`` in grid coordinates per requested input, with
        masked (padding) lanes exactly zero, attached as
        ``prediction.sensitivities``.  Requires jax."""
        grid = self.grid
        _, grads = sharing.solve_placed_and_grad(
            grid.n, grid.f, grid.bs, mask=grid.mask, wrt=wrt,
            softmin_beta=softmin_beta, **self.solver_options)
        sens = _sensitivities_for(self.solver_options, grads, wrt,
                                  softmin_beta)
        return dataclasses.replace(self.run(), sensitivities=sens)


@dataclasses.dataclass(frozen=True, eq=False)
class SimulatePlan(Plan):
    """B member programs encoded once → the desync event engine.

    The trace paid the member expansion (noise draws included — a plan
    re-runs the *same* draws), the per-item encoding walk, and the
    placement/topology validation; ``run`` re-enters the engine through
    :func:`repro.core.desync_batch.run_encoded`.  On the jax backend
    the compiled ``lax.while_loop`` runner is shared process-wide per
    shape bucket, so re-running (or re-compiling a same-shaped
    ensemble) never recompiles.
    """

    kind = "simulate"
    arch: str
    enc: "desync_batch._Encoded"
    specs: dict[str, KernelSpec]
    placement: tuple[str, ...]
    t_max_default: float
    t_max_conflict: tuple | None   # (i, t_i, t_0) of first mismatch
    requested_backend: str
    n_members: int
    #: Fused batch×ensemble row origin: ``members[b] == (scenario,
    #: member)``; None when rows map 1:1 to input scenarios.
    members: tuple[tuple[int, int], ...] | None = None

    def __len__(self) -> int:
        return self.n_members

    @property
    def engine(self) -> str:
        resolved = backend_mod.resolve(self.requested_backend,
                                       self.n_members, prefer="numpy")
        return f"desync-{resolved}"

    def run(self, *, t_max: float | None = None, backend: str | None = None,
            on_deadlock: str = "mask",
            specs: Mapping[str, object] | None = None) -> SimulationResult:
        """Re-simulate.  ``t_max`` / ``backend`` / ``on_deadlock``
        override the compiled defaults; ``specs`` swaps kernel
        ``(f, b_s)`` numbers by name (a :class:`KernelSpec`, an
        ``(f, bs)`` pair, or a calibration mapping — anything the
        registry resolves) without re-encoding the programs."""
        with trace.span("api.plan.run", kind=self.kind,
                        B=self.n_members) as sp:
            if t_max is None:
                if self.t_max_conflict is not None:
                    i, t_i, t_0 = self.t_max_conflict
                    raise ValueError(
                        f"scenario {i} sets t_max={t_i} but scenario 0 "
                        f"sets {t_0}; a batch runs on one clock horizon "
                        f"(or pass t_max= to simulate() explicitly)")
                t_max = self.t_max_default
            merged = self.specs
            if specs:
                from .registry import resolve as registry_resolve
                from .registry import unknown_key_error
                merged = dict(self.specs)
                for name, ref in specs.items():
                    if name not in merged:
                        # A typo'd kernel name would otherwise make the
                        # swap a silent no-op.
                        raise unknown_key_error("kernel", name,
                                                sorted(merged))
                    merged[name] = registry_resolve(
                        ref, arch=self.arch, name=name).spec
            resolved = backend_mod.resolve(
                backend or self.requested_backend, self.n_members,
                prefer="numpy")
            sp.set(engine=f"desync-{resolved}")
            res = desync_batch.run_encoded(
                self.enc, self.arch, merged, placement=self.placement,
                t_max=t_max, backend=resolved, on_deadlock=on_deadlock)
            return SimulationResult(arch=self.arch,
                                    engine=f"desync-{resolved}", raw=res,
                                    members=self.members)

    def grad(self, *, wrt=("f", "b_s"), softmin_beta=None):
        """Simulations are not reverse-differentiable: the event loop
        branches on data (the jax engine is a ``lax.while_loop``), so
        no gradient flows through a full run.  Differentiate a
        prediction plan instead, or use :func:`repro.core.desync_batch.
        work_durations_and_grad` for the timing of one event step."""
        raise NotImplementedError(
            "simulate plans cannot be differentiated: the desync event "
            "loop branches on data (lax.while_loop on the jax backend). "
            "Use a predict plan's grad(), or "
            "repro.core.desync_batch.work_durations_and_grad for "
            "one event step's timing jacobians.")


# ---------------------------------------------------------------------------
# Cache-key hooks: structural fingerprints for plan reuse
# ---------------------------------------------------------------------------


def infer_verb(scenario: "Scenario | ScenarioBatch") -> str:
    """The engine family :func:`compile` would pick for ``scenario`` when
    no ``verb`` is given: ``"simulate"`` for program-mode scenarios and
    noise ensembles, ``"predict"`` for group-mode scenarios.  Exposed so
    callers that route requests *before* compiling — the serving
    subsystem's coalescer (:mod:`repro.serve`) — cannot drift from the
    compile-time inference."""
    if isinstance(scenario, ScenarioBatch):
        is_program = any(sc.steps or sc.noise is not None
                         for sc in scenario.scenarios)
    else:
        is_program = isinstance(scenario, Scenario) and (
            bool(scenario.steps) or scenario.noise is not None)
    return "simulate" if is_program else "predict"


def _topology_fingerprint(topo) -> tuple | None:
    """Hashable stand-in for a topology in structure keys.

    ``Topology`` objects embed machine models with dict-valued fields,
    so they are not hashable themselves; everything the *solvers* read
    from a topology is the ordered set of domain names and capacities,
    which is exactly what the fingerprint keeps."""
    if topo is None:
        return None
    return (topo.name, tuple((d.name, int(d.n_cores))
                             for d in topo.domains))


def _options_signature(sc: "Scenario") -> tuple:
    return (tuple(sorted(sc.solver_options().items())), sc.backend,
            sc.jax_cutoff, sc.chunk, sc.strict)


def structure_key(scenario: "Scenario | ScenarioBatch", *,
                  verb: str | None = None) -> tuple:
    """A hashable fingerprint of everything :func:`compile` *traces* —
    the plan-cache hook behind :mod:`repro.serve`.

    Two scenarios with equal keys compile to interchangeable plans:

    * ``verb="predict"`` keys record the structure only — arch, solver /
      dispatch options, topology fingerprint, and per-group ``(tag,
      kernel name, provenance, domain)`` — and deliberately **exclude
      the numeric payload** (``n``, ``f``, ``b_s``).  A cached plan for
      the key serves any same-structured scenario through
      ``plan.run(cores=..., f=..., b_s=...)`` (or a ``placement=`` swap
      on the placed path), which is the serving plan cache's contract.
    * ``verb="simulate"`` keys include the numbers, byte counts, noise
      block, and step sequence: the desync engine encodes programs (and
      draws noise) at compile time, so only structurally *identical*
      scenarios share a simulation plan.

    A :class:`ScenarioBatch` keys as the tuple of its scenarios' keys.
    ``verb=None`` infers the engine family via :func:`infer_verb`.
    """
    if isinstance(scenario, ScenarioBatch):
        return tuple(structure_key(sc, verb=verb)
                     for sc in scenario.scenarios)
    if not isinstance(scenario, Scenario):
        raise TypeError(
            f"structure_key takes a Scenario or ScenarioBatch, got "
            f"{type(scenario).__name__}")
    sc = scenario
    if verb is None:
        verb = infer_verb(sc)
    if verb not in ("predict", "simulate"):
        raise ValueError(
            f"unknown verb {verb!r}; expected 'predict' or 'simulate'")
    opts = _options_signature(sc)
    topo = _topology_fingerprint(sc.topo)
    if verb == "predict":
        rows = tuple((r.tag, r.resolved.name, r.resolved.provenance,
                      r.domain) for r in sc.runs)
        return ("predict", sc.arch, opts, topo, rows)
    runs = tuple(
        (r.tag, r.resolved.name, r.resolved.provenance, r.domain,
         int(r.n), float(r.bytes), float(r.spec.f[sc.arch]),
         float(r.spec.bs[sc.arch])) for r in sc.runs)
    steps = tuple(
        (s.kind, s.tag,
         s.resolved.name if s.resolved is not None else None,
         s.resolved.provenance if s.resolved is not None else None,
         s.bytes, s.cost_s,
         float(s.resolved.spec.f[sc.arch])
         if s.resolved is not None else None,
         float(s.resolved.spec.bs[sc.arch])
         if s.resolved is not None else None) for s in sc.steps)
    noise = None if sc.noise is None else (
        sc.noise.exp_mean_s, sc.noise.seed, sc.noise.ensemble,
        sc.noise.tag)
    return ("simulate", sc.arch, opts, topo, runs, steps, noise,
            sc.n_ranks, sc.rank_domains, sc.t_max)


# ---------------------------------------------------------------------------
# compile(): the one-time trace
# ---------------------------------------------------------------------------


def _compile_predict(scenario) -> Plan:
    if isinstance(scenario, ScenarioBatch):
        with trace.span("api.compile.validate"):
            scenario.predictable  # cached O(B) validation; raises on misuse
        first = scenario.scenarios[0]
        if scenario.is_placed:
            with trace.span("api.compile.pack", B=len(scenario)):
                grid = topology_mod.pack_placed(
                    first.topo, scenario.placements, strict=first.strict)
            B, D, _ = grid.n.shape
            resolved = backend_mod.resolve(first.backend, B * D,
                                           jax_cutoff=first.jax_cutoff)
            return PlacedBatchPlan(
                archs=scenario.archs, grid=grid,
                provenance=scenario.provenance,
                solver_options=first.solver_options(),
                backend=resolved, requested_backend=first.backend,
                strict=first.strict, jax_cutoff=first.jax_cutoff,
                chunk=first.chunk)
        with trace.span("api.compile.pack", B=len(scenario)):
            n, f, bs, names = scenario.arrays
        resolved = backend_mod.resolve(first.backend, len(scenario),
                                       jax_cutoff=first.jax_cutoff)
        return BatchPlan(archs=scenario.archs, n=n, f=f, bs=bs,
                         names=names, provenance=scenario.provenance,
                         solver_options=first.solver_options(),
                         backend=resolved,
                         requested_backend=first.backend,
                         jax_cutoff=first.jax_cutoff, chunk=first.chunk)
    if not isinstance(scenario, Scenario):
        raise TypeError(
            f"predict() takes a Scenario or ScenarioBatch, got "
            f"{type(scenario).__name__}")
    if scenario.steps:
        raise ValueError(
            "this scenario describes rank programs (.step); use "
            "simulate(scenario) for the event engine, or .run groups "
            "for predict()")
    if scenario.is_placed or scenario.topo is not None:
        if scenario.topo is None:
            raise ValueError(
                "scenario has .placed groups but no topology; add "
                ".using(<topology or preset name>)")
        missing = [r.tag for r in scenario.runs if r.domain is None]
        if missing:
            raise ValueError(
                f"groups {missing} have no domain but the scenario has a "
                f"topology; place every group with .placed(kernel, n, "
                f"domain)")
        placements = tuple(
            topology_mod.Placed(r.group(scenario.arch), r.domain)
            for r in scenario.runs)
        kwargs = scenario.solver_options()
        kwargs["backend"] = scenario.backend
        kwargs["strict"] = scenario.strict
        kwargs["jax_cutoff"] = scenario.jax_cutoff
        kwargs["chunk"] = scenario.chunk
        return PlacedPlan(arch=scenario.arch, topo=scenario.topo,
                          placements=placements,
                          provenance=scenario.provenance,
                          solver_kwargs=kwargs)
    return ScalarPlan(arch=scenario.arch, groups=scenario.groups,
                      provenance=scenario.provenance,
                      solver_options=scenario.solver_options())


def _compile_simulate(scenario, *,
                      fuse_ensembles: bool = True) -> SimulatePlan:
    member_map: tuple[tuple[int, int], ...] | None = None
    if isinstance(scenario, Scenario):
        members = [(scenario, b)
                   for b in range(scenario.noise.ensemble
                                  if scenario.noise else 1)]
        scenarios = [scenario]
    elif isinstance(scenario, ScenarioBatch):
        scenarios = list(scenario.scenarios)
        if fuse_ensembles:
            # Batch × ensemble composition: scenario i's E_i noise
            # members flatten to adjacent rows of one (Σ E_i) run, each
            # member on its own SplitMix64-derived seed stream.
            members = [(sc, m) for sc in scenarios
                       for m in range(sc.noise.ensemble if sc.noise
                                      else 1)]
            if len(members) != len(scenarios):
                member_map = tuple(
                    (i, m) for i, sc in enumerate(scenarios)
                    for m in range(sc.noise.ensemble if sc.noise else 1))
        else:
            for i, sc in enumerate(scenarios):
                if sc.noise is not None and sc.noise.ensemble != 1:
                    raise ValueError(
                        f"scenario {i} asks for a noise ensemble inside "
                        f"a ScenarioBatch but fuse_ensembles=False "
                        f"forces the legacy one-row-per-scenario path; "
                        f"drop fuse_ensembles=False to run the whole "
                        f"batch × ensemble grid in one call, or set "
                        f"ensemble=1 on the scenario")
            members = [(sc, 0) for sc in scenarios]
    else:
        raise TypeError(
            f"simulate() takes a Scenario or ScenarioBatch, got "
            f"{type(scenario).__name__}")

    first = scenarios[0]
    t_max_conflict = None
    programs_batch = []
    placement0: Sequence[str] | None = None
    for i, (sc, member) in enumerate(members):
        if sc.arch != first.arch:
            raise ValueError("all simulated scenarios must share one arch")
        if t_max_conflict is None and sc.t_max != first.t_max:
            t_max_conflict = (i, sc.t_max, first.t_max)
        if sc.topo != first.topo:
            raise ValueError(
                f"scenario {i} uses a different topology than "
                f"scenario 0; a batch shares one topology")
        progs, placement = _programs_for(sc, member)
        if i == 0:
            placement0 = placement
        elif placement != placement0:
            raise ValueError(
                "all simulated scenarios must share one placement")
        programs_batch.append(progs)

    topo = first.topo
    if placement0 is not None and topo is None:
        raise ValueError(
            "scenario places ranks on domains but has no topology; add "
            ".using(<topology or preset name>)")
    if topo is not None and placement0 is None:
        topo = None  # unplaced scenario on a topology: single shared domain

    # The engine-side contract (rectangularity, placement length,
    # domain existence, anonymous-domain default) — shared with
    # run_batch so the two entry paths cannot drift.
    with trace.span("api.compile.validate", members=len(members)):
        placement = desync_batch.validate_batch(programs_batch, topo,
                                                placement0)

    specs = _collect_specs(scenarios)
    with trace.span("api.compile.encode", members=len(members)):
        enc = desync_batch._encode(programs_batch, specs)
    return SimulatePlan(arch=first.arch, enc=enc, specs=specs,
                        placement=placement, t_max_default=first.t_max,
                        t_max_conflict=t_max_conflict,
                        requested_backend=first.backend,
                        n_members=len(members), members=member_map)


def compile(scenario: Scenario | ScenarioBatch, *,
            verb: str | None = None,
            fuse_ensembles: bool = True) -> Plan:
    """Trace a scenario (or batch) into a frozen, re-runnable plan.

    ``verb`` picks the engine family — ``"predict"`` (the Eq. 4–5
    sharing solvers) or ``"simulate"`` (the desync event engine).  By
    default it is inferred from the scenario's shape: program-mode
    scenarios (``.step``/``.ranks``) and noise ensembles compile to a
    simulation plan, group-mode scenarios to a prediction plan (pass
    ``verb="simulate"`` to run groups through the event engine, exactly
    like calling :func:`repro.api.simulate` on them).

    ``fuse_ensembles`` (simulate only, default on) expands each batch
    scenario's ``with_noise(ensemble=E)`` members into the compiled
    run — B scenarios × E seeds as one ``(Σ E_i)``-row engine call,
    with the row origin recorded on ``plan.members`` /
    ``result.members``.  ``fuse_ensembles=False`` forces the legacy
    one-row-per-scenario contract, which rejects inner ensembles.

    All build-time work happens here — registry resolution already
    happened when the scenario was built; this adds validation, array
    packing / program encoding, and backend + jit selection through the
    substrate — so ``plan.run()`` is just the solve.
    """
    if verb is None:
        verb = infer_verb(scenario)
    if verb not in ("predict", "simulate"):
        raise ValueError(
            f"unknown verb {verb!r}; expected 'predict' or 'simulate'")
    with trace.span("api.compile", verb=verb) as sp:
        if verb == "predict":
            plan = _compile_predict(scenario)
        else:
            plan = _compile_simulate(scenario,
                                     fuse_ensembles=fuse_ensembles)
        sp.set(kind=plan.kind, engine=plan.engine)
        return plan
