"""Decode-path consistency: stepping token-by-token through the KV/state
cache must reproduce the teacher-forced forward logits.

This is the strongest cache-correctness invariant available and covers the
attention ring buffers, SSM recurrences, RG-LRU states, and whisper's
cross-attention caches in one property.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import mamba2, model_for, rglru, transformer, whisper

ATOL = 2e-3   # f32 reduced configs; scan vs unrolled reassociation noise


def _tokens(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "qwen2.5-32b",
                                  "nemotron-4-15b", "olmoe-1b-7b",
                                  "granite-moe-1b-a400m"])
def test_transformer_decode_matches_forward(arch):
    cfg = configs.get_reduced(arch)
    if cfg.moe is not None:
        # Token-choice routing depends on batch composition: teacher-forced
        # groups differ from decode groups, so logits match only loosely.
        pytest.skip("MoE capacity routing is context-dependent by design")
    model = model_for(cfg)
    params = model.init(jax.random.key(0))
    b, s = 2, 12
    toks = _tokens(cfg, b, s)
    logits_tf, _ = transformer.forward(cfg, params, toks)

    cache = model.init_cache(b, s)
    outs = []
    pos = jnp.zeros((b,), jnp.int32)
    for t in range(s):
        lg, cache = model.decode_step(params, cache, toks[:, t], pos)
        outs.append(lg)
        pos = pos + 1
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_tf), atol=ATOL, rtol=1e-3)


def test_mamba2_decode_matches_forward():
    cfg = configs.get_reduced("mamba2-1.3b")
    model = model_for(cfg)
    params = model.init(jax.random.key(1))
    b, s = 2, 16
    toks = _tokens(cfg, b, s, seed=1)
    logits_tf = mamba2.forward(cfg, params, toks)

    cache = model.init_cache(b, s)
    outs = []
    pos = jnp.zeros((b,), jnp.int32)
    for t in range(s):
        lg, cache = model.decode_step(params, cache, toks[:, t], pos)
        outs.append(lg)
        pos = pos + 1
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_tf), atol=ATOL, rtol=1e-3)


def test_rglru_decode_matches_forward():
    cfg = configs.get_reduced("recurrentgemma-2b")
    model = model_for(cfg)
    params = model.init(jax.random.key(2))
    b, s = 2, 12   # below the reduced local window (16): exact equivalence
    toks = _tokens(cfg, b, s, seed=2)
    logits_tf = rglru.forward(cfg, params, toks)

    cache = model.init_cache(b, s)
    outs = []
    pos = jnp.zeros((b,), jnp.int32)
    for t in range(s):
        lg, cache = model.decode_step(params, cache, toks[:, t], pos)
        outs.append(lg)
        pos = pos + 1
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_tf), atol=ATOL, rtol=1e-3)


def test_rglru_local_window_ring_buffer():
    """Past the window, decode must keep working (ring overwrite) and only
    attend to the last `local_window` positions."""
    cfg = configs.get_reduced("recurrentgemma-2b")
    model = model_for(cfg)
    params = model.init(jax.random.key(3))
    b, s = 1, 40   # window is 16 in the reduced config
    toks = _tokens(cfg, b, s, seed=3)
    cache = model.init_cache(b, s)
    pos = jnp.zeros((b,), jnp.int32)
    for t in range(s):
        lg, cache = model.decode_step(params, cache, toks[:, t], pos)
        assert bool(jnp.all(jnp.isfinite(lg)))
        pos = pos + 1
    assert cache["k"].shape[2] == cfg.local_window


def test_whisper_decode_matches_forward():
    cfg = configs.get_reduced("whisper-tiny")
    model = model_for(cfg)
    params = model.init(jax.random.key(4))
    b, s = 2, 10
    rng = np.random.default_rng(4)
    frames = jnp.asarray(
        rng.standard_normal((b, cfg.n_audio_frames, cfg.d_model)),
        jnp.float32) * 0.1
    toks = _tokens(cfg, b, s, seed=4)
    enc_out = whisper.encode(cfg, params, frames)
    logits_tf = whisper.decode(cfg, params, toks, enc_out)

    cache = whisper.init_cache(cfg, b, s, enc_out=enc_out, params=params)
    outs = []
    pos = jnp.zeros((b,), jnp.int32)
    for t in range(s):
        lg, cache = whisper.decode_step(cfg, params, cache, toks[:, t], pos)
        outs.append(lg)
        pos = pos + 1
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_tf), atol=ATOL, rtol=1e-3)


def test_causality_property():
    """Perturbing future tokens must not change past logits."""
    cfg = configs.get_reduced("qwen2-0.5b")
    model = model_for(cfg)
    params = model.init(jax.random.key(5))
    toks = _tokens(cfg, 1, 16, seed=5)
    logits1, _ = transformer.forward(cfg, params, toks)
    toks2 = toks.at[:, 10:].set((toks[:, 10:] + 7) % cfg.vocab)
    logits2, _ = transformer.forward(cfg, params, toks2)
    np.testing.assert_allclose(np.asarray(logits1[:, :10]),
                               np.asarray(logits2[:, :10]), atol=1e-5)


def test_scan_unroll_equivalence():
    """cfg.use_scan must not change the math (dry-run extrapolation relies
    on this)."""
    cfg = configs.get_reduced("qwen2-0.5b")
    model = model_for(cfg)
    params = model.init(jax.random.key(6))
    toks = _tokens(cfg, 2, 8, seed=6)
    l1, _ = transformer.forward(cfg, params, toks)
    cfg2 = dataclasses.replace(cfg, use_scan=False)
    l2, _ = transformer.forward(cfg2, params, toks)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4,
                               rtol=1e-4)
