"""Execution-Cache-Memory (ECM) model — paper Eqs. (1)–(3).

Predicts the single-core runtime decomposition of a streaming/stencil loop
from first principles (stream counts + machine model), yielding the *memory
request fraction* ``f = T_Mem / T_ECM`` (Eq. 2) that drives the bandwidth
sharing model, plus the multicore saturation curve via the simplified
latency-penalty recursion of Hofmann et al. [6].

All times are in **cycles per unit of work**, where one unit of work is the
iterations covered by one cache line per stream (8 double-precision
iterations).
"""

from __future__ import annotations

import dataclasses

from .machine import MachineModel
from .table2 import KernelSpec

CACHELINE = 64  # bytes
ITERS_PER_UNIT = CACHELINE // 8  # doubles per cache line


@dataclasses.dataclass(frozen=True)
class EcmPrediction:
    """Single-core ECM decomposition (cycles per work unit) and derived f."""

    t_ol: float        # overlapping in-core execution (arithmetic, stores)
    t_l1reg: float     # load/store retirement (loads only on Intel)
    t_cache: tuple[float, ...]  # inter-cache transfer times, L1<-L2 first
    t_mem: float       # memory interface occupation
    overlapping: bool  # machine transfer-overlap flag

    @property
    def t_ecm(self) -> float:
        """Paper Eq. (1) for non-overlapping hierarchies; max-composition
        for fully-overlapping (Rome-like) hierarchies."""
        if self.overlapping:
            return max(self.t_ol, self.t_l1reg, *self.t_cache, self.t_mem)
        return max(self.t_ol, self.t_mem + sum(self.t_cache) + self.t_l1reg)

    @property
    def f(self) -> float:
        """Paper Eq. (2): fraction of time the memory interface is busy."""
        return self.t_mem / self.t_ecm

    def single_core_bw_gbs(self, machine: MachineModel, bytes_per_unit: float
                           ) -> float:
        """Predicted single-thread *memory* bandwidth (Eq. 3 forward)."""
        t_s = self.t_ecm * machine.cycle_s
        return bytes_per_unit / t_s / 1e9


def predict(kernel: KernelSpec, machine: MachineModel) -> EcmPrediction:
    """Analytic single-core ECM prediction for a streaming kernel.

    The application model assumes pure streaming (no temporal reuse beyond
    what the stream decomposition already encodes — stencil specs carry their
    post-layer-condition stream counts, so this holds for them too).
    """
    n_ld = kernel.reads + kernel.rfo     # RFO lines travel inward like loads
    n_st = kernel.writes
    n_streams = kernel.reads + kernel.writes + kernel.rfo

    # --- T_L1Reg: cycles to retire the load (Intel: loads only) µops for one
    # cache line per load stream.
    ld_instr_per_line = CACHELINE / machine.simd_bytes
    t_l1reg = kernel.reads * ld_instr_per_line / machine.loads_per_cycle
    st_instr = kernel.writes * ld_instr_per_line / machine.stores_per_cycle

    # --- T_OL: arithmetic + store retirement overlap with data transfers.
    flops_per_unit = kernel.flops_per_iter * ITERS_PER_UNIT
    simd_doubles = machine.simd_bytes // 8
    # FMA fuses mul+add; assume the usual 2-flop amortization.
    arith_instr = flops_per_unit / (2 * simd_doubles)
    t_arith = arith_instr / machine.fma_per_cycle
    t_ol = max(t_arith, st_instr)

    # --- inter-cache transfers: every stream moves one line per level.
    t_cache = tuple(
        n_streams * CACHELINE / lvl.bw_bytes_per_cycle
        for lvl in machine.cache_levels
        if lvl.bw_bytes_per_cycle is not None
    )

    # --- memory interface: use the kernel-class saturated bandwidth as the
    # achievable transfer rate (the paper's phenomenological input).
    bclass = "read_only" if kernel.read_only else "read_write"
    bw_cy = machine.bw_bytes_per_cycle(machine.saturated_bw_gbs[bclass])
    t_mem = n_streams * CACHELINE / bw_cy

    return EcmPrediction(
        t_ol=t_ol, t_l1reg=t_l1reg, t_cache=t_cache, t_mem=t_mem,
        overlapping=machine.overlapping_transfers,
    )


def scaling_curve(f: float, t_mem: float, t_ecm: float, n_max: int,
                  p0_factor: float = 0.5) -> list[float]:
    """Simplified multicore scaling model (paper Sect. III, after Eq. 3).

    At ``n`` cores a latency penalty ``p0 * u(n-1) * (n-1)`` is added to the
    single-core runtime, with ``u(1) = f`` and ``p0 = p0_factor * T_Mem``
    (the paper's simplified choice is 1/2; the full model of Hofmann et al.
    fits p0 per machine).  Returns the *utilization* ``u(n)`` of the memory
    interface for n = 1..n_max.
    """
    p0 = t_mem * p0_factor
    u = [f]
    for n in range(2, n_max + 1):
        t_n = t_ecm + p0 * u[-1] * (n - 1)
        u.append(min(1.0, n * t_mem / t_n))
    return u


def bandwidth_vs_cores(kernel: KernelSpec, arch: str, n_max: int, *,
                       utilization: str = "recursion") -> list[float]:
    """Predicted aggregate bandwidth (GB/s) at 1..n_max cores, from the
    measured ``(f, b_s)`` pair — the paper's phenomenological route.

    ``utilization`` selects the sub-saturation law (see
    :func:`repro.core.sharing.utilization_curve`): ``"recursion"`` (the
    default, this module's :func:`scaling_curve`) or ``"queue"`` (the hard
    knee of the queue instrument).  The same forward model, evaluated in
    reverse, is what :mod:`repro.calibrate.fit` inverts to recover
    ``(f, b_s)`` from a measured curve.
    """
    from .sharing import utilization_curve
    f, bs = kernel.f[arch], kernel.bs[arch]
    # In units where t_ecm = 1 (hence t_mem = f), the recursion mode is
    # exactly :func:`scaling_curve` — one shared law for both routes.
    u = utilization_curve(list(range(1, n_max + 1)), f, mode=utilization)
    return [float(ui) * bs for ui in u]
