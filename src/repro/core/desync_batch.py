"""Batched desync engine: B independent scenarios × R ranks in one run.

The scalar :class:`repro.core.desync.DesyncSimulator` advances one scenario
at a time, calling the Eq. 4–5 solver once per event step.  Every ensemble
study (noise-seed sweeps in ``runtime/straggler.py``, candidate-plan
comparisons in ``runtime/overlap_schedule.py``, the Fig. 1/3 seed averages)
re-runs it scenario by scenario, so the solver-call count — the dominant
per-step cost — scales with B.  This module keeps the *same* event
semantics but holds the state of all B scenarios in ``(B, R)`` arrays:

* per-scenario clocks ``t[b]`` advance independently (scenarios do not
  synchronize with each other — batching is purely an execution layout);
* each event step groups the in-flight kernels of *every* progressing
  scenario by ``(scenario, domain, kernel)`` and issues **one**
  :func:`repro.core.sharing.solve_batch` call for all populated
  ``(scenario, domain)`` pairs;
* retirement, collective resolution, and neighbor releases are vectorized
  masks over ``(B, R)``.

With ``B = 1`` the numpy engine performs bit-identical arithmetic in the
same order as the scalar engine and reproduces its record list exactly —
that equivalence is a tested invariant, so the scalar engine stays the
readable reference implementation.

An optional jax path (``backend="jax"``) runs the whole event loop as a
jitted ``lax.while_loop`` over fixed-shape state, for large fleets where
the per-step Python cost of the numpy path dominates.  It returns the same
``(B, R, L)`` start/end arrays (records are materialized sorted by
``(end, rank, index)``; floating-point results match the numpy path to
solver tolerance, not bitwise).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from . import backend as backend_mod
from .backend import HAVE_JAX
from .desync import (EPS, Allreduce, Idle, Item, Record, WaitNeighbors,
                     Work, durations_by_tag, skewness)
from .sharing import solve_batch
from .table2 import TABLE2, KernelSpec
from .topology import Topology
from ..obs import metrics, trace

_WORK, _ALLREDUCE, _WAITNB, _IDLE, _PAD = 0, 1, 2, 3, -1


# --------------------------------------------------------------------------
# Program encoding
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Encoded:
    """Array form of B × R programs, padded to the longest program L."""

    kind: np.ndarray      # (B, R, L) int8: item kind, _PAD past the end
    qty: np.ndarray       # (B, R, L) float64: bytes / duration_s / cost_s
    kern: np.ndarray      # (B, R, L) int32: index into kernels, -1 if none
    plen: np.ndarray      # (B, R) int32 program lengths
    tags: list            # [B][R][L] record tag strings
    kernels: tuple[str, ...]  # kernel names, sorted (index order == name order)


def _encode(programs_batch: Sequence[Sequence[Sequence[Item]]],
            specs: dict[str, KernelSpec]) -> _Encoded:
    B = len(programs_batch)
    R = len(programs_batch[0])
    L = max((len(p) for sc in programs_batch for p in sc), default=0)
    kinds = np.full((B, R, max(L, 1)), _PAD, dtype=np.int8)
    qty = np.zeros((B, R, max(L, 1)))
    kern = np.full((B, R, max(L, 1)), -1, dtype=np.int32)
    plen = np.zeros((B, R), dtype=np.int32)
    used: set[str] = set()
    for sc in programs_batch:
        for prog in sc:
            for item in prog:
                if isinstance(item, Work):
                    used.add(item.kernel)
    # Sorted by name, so sorting kernel indices == the scalar engine's
    # sort over kernel name strings.
    kernels = tuple(sorted(used))
    kern_idx = {k: i for i, k in enumerate(kernels)}
    for k in kernels:
        if k not in specs:
            raise KeyError(f"program references unknown kernel {k!r}")
    tags: list = []
    for b, sc in enumerate(programs_batch):
        sc_tags = []
        for r, prog in enumerate(sc):
            plen[b, r] = len(prog)
            row_tags = []
            for j, item in enumerate(prog):
                tag = item.tag or getattr(item, "kernel",
                                          type(item).__name__)
                row_tags.append(tag)
                if isinstance(item, Work):
                    kinds[b, r, j] = _WORK
                    qty[b, r, j] = item.bytes
                    kern[b, r, j] = kern_idx[item.kernel]
                elif isinstance(item, Allreduce):
                    kinds[b, r, j] = _ALLREDUCE
                    qty[b, r, j] = item.cost_s
                elif isinstance(item, WaitNeighbors):
                    kinds[b, r, j] = _WAITNB
                    qty[b, r, j] = item.cost_s
                elif isinstance(item, Idle):
                    kinds[b, r, j] = _IDLE
                    qty[b, r, j] = item.duration_s
                else:
                    raise TypeError(f"unknown program item {item!r}")
            sc_tags.append(row_tags)
        tags.append(sc_tags)
    return _Encoded(kind=kinds, qty=qty, kern=kern, plen=plen, tags=tags,
                    kernels=kernels)


# --------------------------------------------------------------------------
# Result
# --------------------------------------------------------------------------


@dataclasses.dataclass
class BatchRunResult:
    """Outcome of a batched run.

    ``records[b]`` is scenario b's record list; on the numpy backend it is
    in engine emission order (identical to the scalar engine for B = 1), on
    the jax backend sorted by ``(end, rank, index)``.  ``start``/``end``
    are dense ``(B, R, L)`` views of the same data (NaN where the item was
    never retired within ``t_max``).
    """

    records: list[list[Record]]
    start: np.ndarray     # (B, R, L)
    end: np.ndarray       # (B, R, L)
    t_end: np.ndarray     # (B,) final per-scenario clocks
    n_steps: int          # event-loop iterations executed
    backend: str
    #: Per-scenario deadlock mask (``on_deadlock="mask"``): ``failed[b]``
    #: is True when scenario b deadlocked; its records stop at the
    #: deadlock point while every other scenario ran to completion.
    failed: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=bool))

    @property
    def n_scenarios(self) -> int:
        return len(self.records)

    @property
    def n_failed(self) -> int:
        return int(self.failed.sum())

    @property
    def n_ranks(self) -> int:
        return self.start.shape[1]

    @property
    def n_events(self) -> int:
        """Total retirements across the batch (the benchmark's 'events')."""
        return sum(len(rs) for rs in self.records)

    def _is_failed(self, b: int) -> bool:
        return bool(self.failed[b]) if b < self.failed.size else False

    def durations_by_tag(self, b: int, tag: str, *, missing: float = 0.0,
                         allow_failed: bool = False) -> list[float]:
        """Per-rank accumulated ``tag`` time in scenario ``b`` (all R ranks,
        never silently truncated).  A deadlocked scenario's records stop
        at the deadlock point, so aggregating them would silently skew
        downstream statistics — asking for one raises unless
        ``allow_failed=True``."""
        if self._is_failed(b) and not allow_failed:
            raise ValueError(
                f"scenario {b} deadlocked (see BatchRunResult.failed); "
                f"its records are partial — pass allow_failed=True to "
                f"aggregate them anyway")
        return durations_by_tag(self.records[b], tag,
                                n_ranks=self.n_ranks, missing=missing)

    def skew_by_tag(self, tag: str) -> np.ndarray:
        """Fisher skewness of per-rank accumulated ``tag`` time, one entry
        per scenario — the paper's desync/resync indicator over the whole
        ensemble.  Deadlocked scenarios yield NaN (their records are
        partial), so they cannot silently bias an ensemble mean."""
        return np.array([
            float("nan") if self._is_failed(b)
            else skewness(self.durations_by_tag(b, tag))
            for b in range(self.n_scenarios)])


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------


def run_batch(programs_batch: Sequence[Sequence[Sequence[Item]]], arch: str,
              specs: dict[str, KernelSpec] | None = None, *,
              topology: Topology | None = None,
              placement: Sequence[str] | None = None,
              t_max: float = 10.0, backend: str = "numpy",
              on_deadlock: str = "mask") -> BatchRunResult:
    """Simulate B scenarios of R ranks each in one batched run.

    Arguments mirror :class:`repro.core.desync.DesyncSimulator` plus the
    leading batch axis: ``programs_batch[b][r]`` is rank r's program in
    scenario b.  All scenarios share R, ``topology``, and ``placement``
    (vary programs — noise draws, phase mixes, skew injections — across
    scenarios; a placement sweep is a topology-per-batch concern that the
    per-scenario clocks do not require).

    ``backend="numpy"`` (default) is the reference batched engine;
    ``"jax"`` lowers the event loop to a jitted ``lax.while_loop``.

    ``on_deadlock`` controls what a deadlocked scenario does to the rest
    of the batch: ``"mask"`` (default) freezes only the deadlocked
    scenario — its records stop at the deadlock point and its entry in
    :attr:`BatchRunResult.failed` is set — while every other scenario
    runs to completion; ``"raise"`` aborts the whole run with
    :class:`RuntimeError`, like the scalar engine (callers whose
    downstream statistics would be silently skewed by a missing scenario
    opt into this).
    """
    specs = dict(TABLE2 if specs is None else specs)
    programs_batch = [list(sc) for sc in programs_batch]
    if not programs_batch:
        if on_deadlock not in ("mask", "raise"):
            raise ValueError(f"unknown on_deadlock mode {on_deadlock!r}")
        return BatchRunResult(records=[], start=np.zeros((0, 0, 1)),
                              end=np.zeros((0, 0, 1)), t_end=np.zeros(0),
                              n_steps=0,
                              backend=backend_mod.resolve(
                                  backend, 0, prefer="numpy"),
                              failed=np.zeros(0, dtype=bool))
    placement = validate_batch(programs_batch, topology, placement)
    enc = _encode(programs_batch, specs)
    return run_encoded(enc, arch, specs, placement=placement, t_max=t_max,
                       backend=backend, on_deadlock=on_deadlock)


def validate_batch(programs_batch: Sequence[Sequence[Sequence[Item]]],
                   topology: Topology | None,
                   placement: Sequence[str] | None) -> tuple[str, ...]:
    """Shared input validation for :func:`run_batch` and the compiled
    plans (:mod:`repro.api.plan`): the batch must be rectangular,
    topology and placement come together, and every placed domain must
    exist.  Returns the normalized placement (the anonymous single
    domain when unplaced) — the one contract both entry paths enforce,
    so a rule added here applies to both."""
    n_ranks = len(programs_batch[0])
    for b, sc in enumerate(programs_batch):
        if len(sc) != n_ranks:
            raise ValueError(
                f"scenario {b} has {len(sc)} ranks, scenario 0 has "
                f"{n_ranks}; the batch must be rectangular")
    if (topology is None) != (placement is None):
        raise ValueError("topology and placement must be given together")
    if topology is not None:
        if len(placement) != n_ranks:
            raise ValueError(
                f"placement names {len(placement)} domains for "
                f"{n_ranks} ranks")
        for dom in placement:
            topology.domain(dom)
    return (tuple(placement) if placement is not None
            else ("domain0",) * n_ranks)


def run_encoded(enc: _Encoded, arch: str,
                specs: dict[str, KernelSpec], *,
                placement: Sequence[str], t_max: float = 10.0,
                backend: str = "numpy",
                on_deadlock: str = "mask") -> BatchRunResult:
    """Run an already-encoded program batch (the compiled-plan entry).

    :func:`run_batch` validates, encodes, and delegates here; a
    compiled execution plan (:mod:`repro.api.plan`) keeps the
    :class:`_Encoded` arrays from its trace and re-enters here on every
    ``run()``, skipping the per-call Python encoding walk.  ``backend``
    accepts ``"auto"`` and resolves through the substrate with the
    numpy-preferring policy (the numpy event loop is the reference
    implementation; jax runs on explicit request).
    """
    if on_deadlock not in ("mask", "raise"):
        raise ValueError(f"unknown on_deadlock mode {on_deadlock!r}")
    resolved = backend_mod.resolve(backend, enc.kind.shape[0],
                                   prefer="numpy")
    placement = tuple(placement)
    engine = _run_numpy if resolved == "numpy" else _run_jax
    if not trace.enabled():  # hot path: no span bookkeeping
        return engine(enc, arch, specs, placement, t_max, on_deadlock)
    B, R, L = enc.kind.shape
    with trace.span("desync.run", backend=resolved, B=B, R=R, L=L) as sp:
        result = engine(enc, arch, specs, placement, t_max, on_deadlock)
        deadlocked = int(result.failed.sum())
        sp.set(n_steps=result.n_steps, deadlocked=deadlocked)
        metrics.counter("desync.steps").inc(result.n_steps)
        metrics.counter("desync.runs").inc()
        if deadlocked:
            metrics.counter("desync.deadlocked_scenarios").inc(deadlocked)
        return result


# --------------------------------------------------------------------------
# numpy engine
# --------------------------------------------------------------------------


def _arch_vectors(kernels: Sequence[str], specs, arch
                  ) -> tuple[np.ndarray, np.ndarray]:
    f_vec = np.array([specs[k].f[arch] for k in kernels], dtype=np.float64)
    bs_vec = np.array([specs[k].bs[arch] for k in kernels],
                      dtype=np.float64)
    return f_vec, bs_vec


def _domain_order(placement: Sequence[str]) -> np.ndarray:
    """Rank → domain index, indices assigned in sorted-name order (the
    scalar engine sorts domains by name when building solver rows)."""
    dom_names = sorted(set(placement))
    dom_idx = {d: i for i, d in enumerate(dom_names)}
    return np.array([dom_idx[p] for p in placement], dtype=np.int64)


def _run_numpy(enc: _Encoded, arch: str, specs, placement, t_max: float,
               on_deadlock: str = "mask") -> BatchRunResult:
    B, R, L = enc.kind.shape
    K = len(enc.kernels)
    f_vec, bs_vec = _arch_vectors(enc.kernels, specs, arch)
    dom_of_rank = _domain_order(placement)
    D = int(dom_of_rank.max()) + 1 if R else 1

    pc = np.zeros((B, R), dtype=np.int64)
    rem = np.zeros((B, R))
    ready = np.zeros((B, R))
    started = np.zeros((B, R))
    blocked = np.zeros((B, R), dtype=bool)
    releasing = np.zeros((B, R), dtype=bool)
    t = np.zeros(B)
    dead = np.zeros(B, dtype=bool)
    start_arr = np.full((B, R, L), np.nan)
    end_arr = np.full((B, R, L), np.nan)
    records: list[list[Record]] = [[] for _ in range(B)]
    n_steps = 0
    trace_on = trace.enabled()  # latched: per-step probes check one bool

    def cur(arr):
        return np.take_along_axis(
            arr, np.minimum(pc, L - 1)[..., None], axis=2)[..., 0]

    def finish(b: int, r: int, now: float) -> None:
        """Retire (b, r)'s current item at ``now`` and begin the next —
        the batched twin of the scalar engine's finish_item/begin_item."""
        l = pc[b, r]
        records[b].append(
            Record(rank=r, index=int(l), tag=enc.tags[b][r][l],
                   start=float(started[b, r]), end=float(now)))
        start_arr[b, r, l] = started[b, r]
        end_arr[b, r, l] = now
        pc[b, r] += 1
        blocked[b, r] = False
        releasing[b, r] = False
        if pc[b, r] < enc.plen[b, r]:
            started[b, r] = now
            k = enc.kind[b, r, pc[b, r]]
            q = enc.qty[b, r, pc[b, r]]
            if k == _WORK:
                rem[b, r] = q
            elif k == _IDLE:
                ready[b, r] = now + q
            else:
                blocked[b, r] = True

    # Begin every rank's first item at t = 0 (empty programs start done).
    done = pc >= enc.plen
    k0 = cur(enc.kind)
    q0 = cur(enc.qty)
    begin = ~done
    rem = np.where(begin & (k0 == _WORK), q0, rem)
    ready = np.where(begin & (k0 == _IDLE), q0, ready)
    blocked = begin & ((k0 == _ALLREDUCE) | (k0 == _WAITNB))

    active = (t < t_max) & ~done.all(axis=1) & ~dead

    while active.any():
        n_steps += 1
        done = pc >= enc.plen
        ck = np.where(done, _PAD, cur(enc.kind))
        cq = cur(enc.qty)

        # -- allreduce resolution: every rank (incl. finished ones, which
        # can never rejoin the communicator) must be blocked at one.  The
        # scenario's clock advances by the collective's cost; the scenario
        # skips this step's integration phase (the scalar `continue`).
        is_ar = (ck == _ALLREDUCE) & blocked
        resolve = active & (is_ar.sum(axis=1) == R)
        for b in np.nonzero(resolve)[0]:
            cost = cq[b][is_ar[b]].max()
            t[b] = t[b] + cost
            for r in np.nonzero(is_ar[b])[0]:
                finish(int(b), int(r), t[b])
        prog = active & ~resolve
        if not prog.any():
            done = pc >= enc.plen
            active = (t < t_max) & ~done.all(axis=1) & ~dead
            continue

        # -- satisfied neighbor waits start draining their p2p cost
        is_wn = (ck == _WAITNB) & blocked & prog[:, None]
        if is_wn.any():
            ok_left = np.ones((B, R), dtype=bool)
            ok_left[:, 1:] = (pc[:, :-1] >= pc[:, 1:]) | done[:, :-1]
            ok_right = np.ones((B, R), dtype=bool)
            ok_right[:, :-1] = (pc[:, 1:] >= pc[:, :-1]) | done[:, 1:]
            released = is_wn & ok_left & ok_right
            ready = np.where(released, t[:, None] + cq, ready)
            blocked &= ~released
            releasing |= released

        # -- one Eq. 4–5 solve across every populated (scenario, domain)
        working = (ck == _WORK) & prog[:, None]
        if trace_on:
            metrics.histogram("desync.step.active_scenarios").observe(
                float(prog.sum()))
            metrics.histogram("desync.step.working_ranks").observe(
                float(working.sum()))
        rate = np.zeros((B, R))
        if working.any():
            kern_c = cur(enc.kern)
            b_ix, r_ix = np.nonzero(working)
            key = (b_ix * D + dom_of_rank[r_ix]) * K + kern_c[b_ix, r_ix]
            ukeys, inv, counts = np.unique(
                key, return_inverse=True, return_counts=True)
            g_row_key = ukeys // K          # scenario*D + domain, sorted
            g_kern = ukeys % K              # sorted within each row
            rows, row_of_group = np.unique(g_row_key, return_inverse=True)
            first_of_row = np.searchsorted(g_row_key, rows)
            col_of_group = np.arange(len(ukeys)) - first_of_row[row_of_group]
            g_cols = int(col_of_group.max()) + 1
            n_arr = np.zeros((len(rows), g_cols))
            f_arr = np.zeros((len(rows), g_cols))
            bs_arr = np.zeros((len(rows), g_cols))
            n_arr[row_of_group, col_of_group] = counts
            f_arr[row_of_group, col_of_group] = f_vec[g_kern]
            bs_arr[row_of_group, col_of_group] = bs_vec[g_kern]
            batch = solve_batch(n_arr, f_arr, bs_arr, backend="numpy")
            per_core = batch.bw_per_core
            rate[b_ix, r_ix] = per_core[row_of_group[inv],
                                        col_of_group[inv]] * 1e9  # bytes/s

        # -- next event time, per scenario
        cand = np.full((B, R), np.inf)
        w_pos = working & (rate > 0)
        cand[w_pos] = rem[w_pos] / rate[w_pos]
        idle_like = ((ck == _IDLE) | releasing) & prog[:, None]
        cand = np.where(idle_like, np.maximum(ready - t[:, None], 0.0),
                        cand)
        dt = cand.min(axis=1) if R else np.full(B, np.inf)
        stuck = prog & ~np.isfinite(dt)
        if stuck.any():
            if on_deadlock == "raise":
                b = int(np.nonzero(stuck)[0][0])
                raise RuntimeError(
                    f"desync simulator deadlock at t={t[b]:.6f}s "
                    f"(scenario {b}): pcs={pc[b].tolist()}")
            dead |= stuck       # freeze only the deadlocked scenarios
            prog &= ~stuck
        dt = np.where(prog, np.maximum(dt, EPS), 0.0)
        t = np.where(prog, t + dt, t)

        # -- advance work and retire finished items
        rem = np.where(working, rem - rate * dt[:, None], rem)
        fin = np.where(prog[:, None],
                       (working & (rem <= EPS * np.maximum(1.0, cq)))
                       | (idle_like & (t[:, None] >= ready - EPS)),
                       False)
        for b, r in zip(*np.nonzero(fin)):
            finish(int(b), int(r), t[b])

        done = pc >= enc.plen
        active = (t < t_max) & ~done.all(axis=1) & ~dead

    return BatchRunResult(records=records, start=start_arr, end=end_arr,
                          t_end=t, n_steps=n_steps, backend="numpy",
                          failed=dead)


# --------------------------------------------------------------------------
# jax engine: the same event loop as a jitted lax.while_loop
# --------------------------------------------------------------------------


def _records_from_arrays(enc: _Encoded, start_arr: np.ndarray,
                         end_arr: np.ndarray) -> list[list[Record]]:
    """Materialize per-scenario record lists from dense start/end arrays,
    sorted by (end, rank, index) — a deterministic order that coincides
    with engine emission order except for exact end-time ties."""
    B, R, L = start_arr.shape
    records: list[list[Record]] = []
    for b in range(B):
        recs = []
        for r in range(R):
            for l in range(int(enc.plen[b, r])):
                if math.isfinite(end_arr[b, r, l]):
                    recs.append(Record(rank=r, index=l,
                                       tag=enc.tags[b][r][l],
                                       start=float(start_arr[b, r, l]),
                                       end=float(end_arr[b, r, l])))
        recs.sort(key=lambda rec: (rec.end, rec.rank, rec.index))
        records.append(recs)
    return records


def _build_jax_runner(B: int, R: int, L: int, K: int, D: int):
    """One jitted desync event loop for one ``(B, R, L, K, D)`` shape
    bucket.

    Every array the loop consumes — programs, placement, and the
    per-kernel ``(f, b_s)`` vectors — is an *argument* of the jitted
    runner, not a closure capture, so the substrate can cache the
    compiled executable process-wide: repeated straggler ensembles,
    pod-plan searches on one topology, and plans re-run with swapped
    kernel specs all reuse one compilation.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    from .sharing import _solve_single_jax

    def take(arr, pcs):
        return jnp.take_along_axis(
            arr, jnp.minimum(pcs, L - 1)[..., None], axis=2)[..., 0]

    # Every (scenario, domain) pair is one Eq. 4–5 instance over the K
    # kernels; reuse the sharing module's single-scenario jax solver
    # (the same code path solve_batch vmaps) so the two engines cannot
    # drift.  n_max = R is the static recursion bound: iterations past
    # a row's n_tot are masked no-ops, as in _solve_arrays_np.
    solver = jax.vmap(
        lambda n_, f_, bs_: _solve_single_jax(
            n_, f_, bs_, 0.5, R, mode="recursion"))

    def runner(kind, qty, kern, plen, dom, f_k, bs_k, t_max, max_steps):

        def rates_of(working, kern_c):
            """Per-rank progress rates from one batched Eq. 4–5 solve over
            the (B, D, K) occupancy tensor (engine defaults:
            utilization='recursion', p0_factor=0.5)."""
            seg = dom[None, :] * K + kern_c          # (B, R)
            seg = jnp.where(working, seg, 0)
            occ = jnp.zeros((B, D * K), jnp.float64).at[
                jnp.arange(B)[:, None], seg].add(
                    working.astype(jnp.float64))
            n = occ.reshape(B, D, K)
            _, _, _, bw = solver(
                n.reshape(B * D, K),
                jnp.broadcast_to(f_k, (B * D, K)),
                jnp.broadcast_to(bs_k, (B * D, K)))
            bw = bw.reshape(B, D, K)
            per_core = jnp.where(n > 0, bw / jnp.maximum(n, 1.0), 0.0)
            rate = per_core[jnp.arange(B)[:, None], dom[None, :],
                            jnp.clip(kern_c, 0, K - 1)] * 1e9
            return jnp.where(working, rate, 0.0)

        def step(state):
            (t, pc, rem, ready, started, blocked, releasing,
             start_a, end_a, steps, dead) = state
            done = pc >= plen
            alldone = done.all(axis=1)
            active = (t < t_max) & ~alldone & ~dead
            ck = jnp.where(done, _PAD, take(kind, pc))
            cq = take(qty, pc)

            # allreduce resolution (skips the integration phase below)
            is_ar = (ck == _ALLREDUCE) & blocked
            resolve = active & (is_ar.sum(axis=1) == R)
            cost = jnp.where(is_ar, cq, -jnp.inf).max(axis=1)
            t = jnp.where(resolve, t + cost, t)
            prog = active & ~resolve

            # neighbor releases
            is_wn = (ck == _WAITNB) & blocked & prog[:, None]
            ok_left = jnp.concatenate(
                [jnp.ones((B, 1), bool),
                 (pc[:, :-1] >= pc[:, 1:]) | done[:, :-1]], axis=1)
            ok_right = jnp.concatenate(
                [(pc[:, 1:] >= pc[:, :-1]) | done[:, 1:],
                 jnp.ones((B, 1), bool)], axis=1)
            released = is_wn & ok_left & ok_right
            ready = jnp.where(released, t[:, None] + cq, ready)
            blocked = blocked & ~released
            releasing = releasing | released

            # rates, next event, integration
            working = (ck == _WORK) & prog[:, None]
            kern_c = take(kern, pc)
            rate = rates_of(working, kern_c)
            cand = jnp.where(working & (rate > 0),
                             rem / jnp.where(rate > 0, rate, 1.0), jnp.inf)
            idle_like = ((ck == _IDLE) | releasing) & prog[:, None]
            cand = jnp.where(idle_like,
                             jnp.maximum(ready - t[:, None], 0.0), cand)
            dt = cand.min(axis=1)
            newly_dead = prog & ~jnp.isfinite(dt)
            dead = dead | newly_dead
            prog = prog & ~newly_dead
            dt = jnp.maximum(jnp.where(jnp.isfinite(dt), dt, 0.0), EPS)
            t = jnp.where(prog, t + dt, t)
            rem = jnp.where(working & prog[:, None],
                            rem - rate * dt[:, None], rem)

            # retire + record
            fin = jnp.where(prog[:, None],
                            (working & (rem <= EPS * jnp.maximum(1.0, cq)))
                            | (idle_like & (t[:, None] >= ready - EPS)),
                            False)
            fin = fin | (resolve[:, None] & is_ar)
            onehot = jnp.arange(L)[None, None, :] == pc[:, :, None]
            write = onehot & fin[:, :, None]
            start_a = jnp.where(write, started[:, :, None], start_a)
            end_a = jnp.where(write, t[:, None, None], end_a)

            # begin next items
            pc = pc + fin.astype(pc.dtype)
            done2 = pc >= plen
            began = fin & ~done2
            k2 = take(kind, pc)
            q2 = take(qty, pc)
            started = jnp.where(began, t[:, None], started)
            rem = jnp.where(began & (k2 == _WORK), q2, rem)
            ready = jnp.where(began & (k2 == _IDLE), t[:, None] + q2,
                              ready)
            blocked = jnp.where(fin,
                                began & ((k2 == _ALLREDUCE)
                                         | (k2 == _WAITNB)), blocked)
            releasing = releasing & ~fin
            return (t, pc, rem, ready, started, blocked, releasing,
                    start_a, end_a, steps + 1, dead)

        def cond(state):
            (t, pc, _, _, _, _, _, _, _, steps, dead) = state
            done = (pc >= plen).all(axis=1)
            active = (t < t_max) & ~done & ~dead
            return active.any() & (steps < max_steps)

        pc0 = jnp.zeros((B, R), jnp.int32)
        done0 = pc0 >= plen
        k0 = take(kind, pc0)
        q0 = take(qty, pc0)
        begin0 = ~done0
        state = (
            jnp.zeros(B, jnp.float64),                          # t
            pc0,
            jnp.where(begin0 & (k0 == _WORK), q0, 0.0),          # rem
            jnp.where(begin0 & (k0 == _IDLE), q0, 0.0),          # ready
            jnp.zeros((B, R), jnp.float64),                      # started
            begin0 & ((k0 == _ALLREDUCE) | (k0 == _WAITNB)),     # blocked
            jnp.zeros((B, R), bool),                             # releasing
            jnp.full((B, R, L), jnp.nan, jnp.float64),           # start
            jnp.full((B, R, L), jnp.nan, jnp.float64),           # end
            jnp.int64(0),
            jnp.zeros(B, bool),                                  # deadlock
        )
        t, pc, _, _, _, _, _, start_a, end_a, steps, dead = \
            lax.while_loop(cond, step, state)
        return t, pc, start_a, end_a, steps, dead

    return jax.jit(runner)


def _run_jax(enc: _Encoded, arch: str, specs, placement, t_max: float,
             on_deadlock: str = "mask") -> BatchRunResult:
    import jax
    import jax.numpy as jnp

    B, R, L = enc.kind.shape
    K = max(len(enc.kernels), 1)
    f_vec, bs_vec = _arch_vectors(enc.kernels, specs, arch)
    if not len(f_vec):
        f_vec = np.zeros(1)
        bs_vec = np.zeros(1)
    dom_of_rank = _domain_order(placement)
    D = int(dom_of_rank.max()) + 1 if R else 1
    # Each retiring step retires >= 1 item per active scenario (and pure
    # allreduce-resolution steps retire a full wavefront), so R*L bounds
    # the loop up to EPS-sized stutter steps near large clock values
    # (ulp(t) > EPS); the 2x margin absorbs those, and exhausting the
    # budget anyway is reported as an error below, never as silently
    # truncated records.
    max_steps = 2 * R * L + 16

    # Shape-bucket the batch and program axes so nearby ensemble / plan
    # sizes reuse one compiled executable: padded scenarios have empty
    # programs (plen 0, immediately done) and padded program slots are
    # _PAD items past every plen — both exactly neutral to the loop.
    Bb = backend_mod.bucket(B)
    Lb = backend_mod.bucket(L)
    kind_p = np.full((Bb, R, Lb), _PAD, dtype=enc.kind.dtype)
    kind_p[:B, :, :L] = enc.kind
    qty_p = np.zeros((Bb, R, Lb))
    qty_p[:B, :, :L] = enc.qty
    kern_p = np.full((Bb, R, Lb), -1, dtype=enc.kern.dtype)
    kern_p[:B, :, :L] = enc.kern
    plen_p = np.zeros((Bb, R), dtype=enc.plen.dtype)
    plen_p[:B] = enc.plen

    runner = backend_mod.jitted(
        ("desync.run_batch", Bb, R, Lb, K, D),
        lambda: _build_jax_runner(Bb, R, Lb, K, D))
    with jax.experimental.enable_x64():
        out = runner(jnp.asarray(kind_p, jnp.int32),
                     jnp.asarray(qty_p, jnp.float64),
                     jnp.asarray(kern_p, jnp.int32),
                     jnp.asarray(plen_p, jnp.int32),
                     jnp.asarray(dom_of_rank, jnp.int32),
                     jnp.asarray(f_vec, jnp.float64),
                     jnp.asarray(bs_vec, jnp.float64),
                     jnp.float64(t_max), jnp.int64(max_steps))
        t, pc, start_a, end_a, steps, dead = \
            tuple(np.asarray(x) for x in out)
    t, pc, dead = t[:B], pc[:B], dead[:B]
    start_a, end_a = start_a[:B, :, :L], end_a[:B, :, :L]

    if dead.any() and on_deadlock == "raise":
        b = int(np.nonzero(dead)[0][0])
        raise RuntimeError(
            f"desync simulator deadlock at t={t[b]:.6f}s "
            f"(scenario {b}): pcs={pc[b].tolist()}")
    still_active = (t < t_max) & ~dead \
        & ~(pc >= np.asarray(enc.plen)).all(axis=1)
    if still_active.any():
        b = int(np.nonzero(still_active)[0][0])
        raise RuntimeError(
            f"desync jax backend exhausted its step budget "
            f"({max_steps}) with scenario {b} unfinished at "
            f"t={t[b]:.6f}s — records would be truncated; use the "
            f"numpy backend or report this as an engine bug")
    return BatchRunResult(
        records=_records_from_arrays(enc, start_a, end_a),
        start=start_a, end=end_a, t_end=t, n_steps=int(steps),
        backend="jax", failed=dead)


# --------------------------------------------------------------------------
# Differentiable timing twin
# --------------------------------------------------------------------------

# The event engines advance state with data-dependent control flow (the
# numpy loop branches per step; the jax path is a ``lax.while_loop``,
# which is not reverse-differentiable), so gradients cannot flow through
# a full simulation.  But each *event step's* timing is pure arithmetic
# on the Eq. 4–5 solve: a rank of group g progresses at
# ``bw_g / n_g * 1e9`` bytes/s (see ``rates_of`` above), so co-running
# groups with no intervening retirement finish their work items after
#
#     t_g = bytes_g * n_g / (bw_g * 1e9)  seconds.
#
# The helpers below expose that step-timing map — and its exact jacobian
# through the share solve via :func:`repro.core.sharing.
# solve_arrays_and_grad` — for gradient-based co-design on top of the
# engine's own arithmetic.


def work_durations(n, f, bs, bytes_, **solver_kwargs) -> np.ndarray:
    """Per-rank seconds for each group to stream ``bytes_`` while all
    groups co-run — one event step of the desync engine, vectorized over
    scenarios.  All arguments broadcast to ``(B, G)``; ``solver_kwargs``
    forward to :func:`repro.core.sharing.solve_arrays` (engine defaults:
    ``utilization="recursion"``, ``p0_factor=0.5``)."""
    from .sharing import solve_arrays
    n, f, bs, bytes_ = np.broadcast_arrays(
        *(np.asarray(a, dtype=np.float64)
          for a in (n, f, bs, bytes_)))
    _, _, _, bw = solve_arrays(n, f, bs, **solver_kwargs)
    active = (n > 0) & (bytes_ > 0)
    return np.where(active,
                    bytes_ * n / (np.maximum(bw, _DUR_TINY) * 1e9), 0.0)


_DUR_TINY = 1e-300


def work_durations_and_grad(n, f, bs, bytes_, *, wrt=("f", "b_s"),
                            **grad_kwargs
                            ) -> tuple[np.ndarray, dict[str, np.ndarray]]:
    """:func:`work_durations` plus exact jacobians of every duration in
    the requested solver inputs.

    Chains ``d t_i / d θ_j = -bytes_i * n_i / (bw_i**2 * 1e9) *
    d bw_i / d θ_j`` through :func:`repro.core.sharing.
    solve_arrays_and_grad` (implicit-function-theorem vjp for the
    fixed-point law, forward-mode elsewhere).  Returns ``(t, grads)``
    with ``t`` of shape ``(B, G)`` and ``grads[name][b, i, j] =
    ∂t[b, i]/∂name[b, j]``; ``grad_kwargs`` forward to the solver
    (``utilization=``, ``softmin_beta=``, ...).  Requires jax."""
    from .sharing import solve_arrays_and_grad
    n, f, bs, bytes_ = np.broadcast_arrays(
        *(np.asarray(a, dtype=np.float64)
          for a in (n, f, bs, bytes_)))
    (_, _, _, bw), bw_grads = solve_arrays_and_grad(
        n, f, bs, wrt=wrt, **grad_kwargs)
    active = (n > 0) & (bytes_ > 0)
    safe_bw = np.where(active, np.maximum(bw, _DUR_TINY), 1.0)
    t = np.where(active, bytes_ * n / (safe_bw * 1e9), 0.0)
    scale = np.where(active, -bytes_ * n / (safe_bw ** 2 * 1e9), 0.0)
    grads = {name: scale[:, :, None] * g for name, g in bw_grads.items()}
    if "cores" in grads:
        # t depends on n both through the share solve (chained above) and
        # explicitly in the numerator — the per-rank slice of the group's
        # work shrinks as agents are added.
        direct = np.where(active, bytes_ / (safe_bw * 1e9), 0.0)
        B, G = t.shape
        grads["cores"] = grads["cores"] + direct[:, :, None] * np.eye(G)
    return t, grads
