"""Plan-cache keys and batched packing for the serving subsystem.

The cache contract mirrors the substrate's jit cache one level up: a
compiled plan is reusable for any request with the same *structure*
(:func:`repro.api.structure_key` — kernels, options, topology; not the
numeric payload), and batch sizes round up to the substrate's
power-of-two buckets (:func:`repro.core.backend.bucket`) so a tick of
B requests runs on the plan compiled for ``bucket(B)`` rows.  Padding
rows are exactly neutral (``n = 0`` groups / empty placements — the
substrate's :func:`repro.core.backend.pad_rows` invariant), which is
what keeps coalesced responses bit-for-bit equal to per-request solves.
"""

from __future__ import annotations

import hashlib

import numpy as np

from .. import api
from ..core import backend as backend_mod
from ..core.topology import Placed


def group_key(scenario: "api.Scenario", verb: str) -> tuple:
    """The coalescing key: requests with equal keys can share one
    batched solve.  This is exactly :func:`repro.api.structure_key`."""
    return api.structure_key(scenario, verb=verb)


def plan_entry(verb: str, sig: tuple, n_requests: int) -> tuple[tuple, int]:
    """Map a structure signature plus a live batch size to the cache
    entry that serves it: ``(entry_key, rows)`` where ``rows`` is the
    power-of-two bucket the plan was (or will be) compiled for.

    Simulation plans carry their numbers (the signature includes them),
    and one run is shared by every identical request in the tick, so
    the entry is bucket-free."""
    if verb == "simulate":
        return (sig,), 1
    rows = backend_mod.bucket(n_requests)
    return (sig, rows), rows


def key_label(verb: str, scenario: "api.Scenario", rows: int) -> str:
    """Short deterministic metrics label for one cache entry, in the
    same spirit as the jit cache's key labels: human-scannable prefix
    plus a structure digest."""
    sig = api.structure_key(scenario, verb=verb)
    digest = hashlib.blake2s(repr(sig).encode(),
                             digest_size=5).hexdigest()
    return f"{verb}/{scenario.arch}/B{rows}/{digest}"


def compile_group(scenarios: "list[api.Scenario]", verb: str,
                  rows: int) -> "api.Plan":
    """Compile the plan that serves a structure group at ``rows``
    capacity: the scenarios padded (by replicating the first — every
    scenario in a group shares the structure the plan freezes) up to
    the bucket, traced once.

    Prediction groups compile through :class:`repro.api.ScenarioBatch`
    to a batch plan whose numeric payload is swapped per tick; a
    simulation group compiles its (single, fully-specified) scenario
    directly."""
    if verb == "simulate":
        return api.compile(scenarios[0], verb="simulate")
    padded = list(scenarios) + [scenarios[0]] * (rows - len(scenarios))
    return api.compile(api.ScenarioBatch.of(padded), verb="predict")


def swap_arrays(scenarios: "list[api.Scenario]", rows: int, G: int
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pack a tick's requests into the ``(rows, G)`` number arrays a
    cached (unplaced) batch plan swaps in via ``plan.run(cores=, f=,
    b_s=)``.  Rows past the live requests stay zero — the neutral
    padding the jax path would add internally anyway, so the bucketed
    solve is bit-for-bit the direct one."""
    n = np.zeros((rows, G))
    f = np.zeros((rows, G))
    bs = np.zeros((rows, G))
    for i, sc in enumerate(scenarios):
        for j, r in enumerate(sc.runs):
            spec = r.spec
            n[i, j] = r.n
            f[i, j] = spec.f[sc.arch]
            bs[i, j] = spec.bs[sc.arch]
    return n, f, bs


def padded_placements(scenarios: "list[api.Scenario]", rows: int) -> tuple:
    """Per-request placement lists padded with empty rows up to the
    bucket, for a cached placed plan's ``run(placement=...)`` swap.
    Empty rows pack to all-masked grid lanes — neutral by the grid
    solver's masking contract."""
    live = tuple(
        tuple(Placed(r.group(sc.arch), r.domain) for r in sc.runs)
        for sc in scenarios)
    return live + ((),) * (rows - len(live))
