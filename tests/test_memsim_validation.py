"""Validate the analytic model against the microscopic queue simulator —
the stand-in for the paper's hardware measurements (Fig. 8 error study).

The paper reports: error < 8% globally, < 5% in 75% of cases, across 30
pairings x 4 architectures.  We hold our reproduction to the same bar
against the queue instrument (utilization="queue" — see core/sharing.py).
"""

import itertools

import pytest

from repro.core import memsim, sharing, table2

DOMAIN = {"BDW-1": 10, "BDW-2": 18, "CLX": 20, "ROME": 8}

# A representative subset (full sweep lives in benchmarks/fig8_error.py).
PAIRS = [
    ("DCOPY", "DDOT2"), ("JacobiL3-v1", "DDOT1"), ("STREAM", "JacobiL2-v1"),
    ("DAXPY", "DSCAL"), ("vectorSUM", "Schoenauer"), ("DDOT3", "DCOPY"),
]


def _errors(arch, ka, kb, configs):
    a, b = table2.kernel(ka), table2.kernel(kb)
    errs = []
    for na, nb in configs:
        if na == 0 or nb == 0:
            continue
        pred = sharing.pair(a, b, arch, na, nb, utilization="queue")
        sim = memsim.simulate([sharing.Group.of(a, arch, na),
                               sharing.Group.of(b, arch, nb)])
        for i, n in ((0, na), (1, nb)):
            model = pred.bw_per_core[i]
            errs.append(abs(sim[i] / n - model) / model)
    return errs


@pytest.mark.parametrize("arch", sorted(DOMAIN))
@pytest.mark.parametrize("ka,kb", PAIRS)
def test_full_domain_error_below_8pct(arch, ka, kb):
    """Orange dots of paper Fig. 4: domain fully occupied."""
    n = DOMAIN[arch]
    cfgs = [(n // 4, n - n // 4), (n // 2, n - n // 2),
            (3 * n // 4, n - 3 * n // 4)]
    errs = _errors(arch, ka, kb, cfgs)
    assert max(errs) < 0.09, f"max err {max(errs):.3f}"


@pytest.mark.parametrize("arch", sorted(DOMAIN))
@pytest.mark.parametrize("ka,kb", PAIRS[:3])
def test_symmetric_scaling_error(arch, ka, kb):
    """Blue dots of paper Fig. 4: equal groups scaling to saturation."""
    n = DOMAIN[arch]
    cfgs = [(k, k) for k in (1, 2, n // 4, n // 2) if k]
    errs = _errors(arch, ka, kb, cfgs)
    assert max(errs) < 0.09, f"max err {max(errs):.3f}"


def test_total_bandwidth_conserved():
    """Simulator never exceeds the Eq. 4 envelope."""
    a, b = table2.kernel("DCOPY"), table2.kernel("DDOT2")
    for arch, n in DOMAIN.items():
        g = [sharing.Group.of(a, arch, n // 2),
             sharing.Group.of(b, arch, n - n // 2)]
        sim = memsim.simulate(g)
        assert sum(sim) <= sharing.overlapped_saturated_bw(g) * 1.001


def test_memsim_empty_groups():
    assert memsim.simulate([sharing.Group(n=0, f=0.5, bs=10.0)]) == (0.0,)


def test_memsim_seed_is_reproducible_and_exposed():
    """Calibration ensembles need reproducible instruments: identical
    seeds must give identical results, and the seed must be recorded in
    the result itself."""
    g = [sharing.Group(n=4, f=0.2, bs=100.0),
         sharing.Group(n=4, f=0.4, bs=90.0)]
    a = memsim.simulate_result(g, n_events=6000, seed=7)
    b = memsim.simulate_result(g, n_events=6000, seed=7)
    assert a == b
    assert a.seed == 7 and a.events > 0 and a.sim_time_s > 0
    # the seeded phase draw differs from the deterministic stagger
    base = memsim.simulate_result(g, n_events=6000)
    assert base.seed is None
    assert a.bw != base.bw


def test_memsim_default_path_unchanged_by_seed_plumbing():
    """seed=None must reproduce the historical deterministic stagger —
    simulate() and simulate_result() agree bitwise."""
    g = [sharing.Group(n=3, f=0.3, bs=80.0)]
    assert memsim.simulate(g, n_events=6000) == \
        memsim.simulate_result(g, n_events=6000).bw
