"""Paper Fig. 9: relative bandwidth gain/loss of kernel A paired with B
(equal thread split of the full domain), normalized to A self-paired.

Checks the paper's headline qualitative claims:
  * gain/loss sign follows the f-ratio, consistently across Intel CPUs;
  * CLX shows the smallest variations;
  * Rome differs for DAXPY+DSCAL because f_DAXPY > f_DSCAL there (reversed
    vs. Intel).
"""

from __future__ import annotations

import time

from repro import api
from repro.core import table2

DOMAIN = {"BDW-1": 10, "BDW-2": 18, "CLX": 20, "ROME": 8}


def gain_matrix(arch):
    """All K×K pairings (mixed and self-paired) as ONE facade batch.

    api.ScenarioBatch.pairing_matrix lays out rows 0..K²-1 as the mixed
    pairs (A with B) and rows K²..K²+K-1 as the self-pairings (A with A);
    the Fig. 9 bar height is mixed_bw[A,B] / self_bw[A].  With jax
    importable the K²+K scenarios dispatch to the jitted solver.
    """
    n_each = DOMAIN[arch] // 2
    k = len(table2.FIG9_KERNELS)
    scenarios = api.ScenarioBatch.pairing_matrix(
        arch, table2.FIG9_KERNELS, n_each)
    t0 = time.perf_counter()
    batch = api.predict(scenarios)
    us = (time.perf_counter() - t0) * 1e6 / (k * k)
    mixed = batch.bw_group[:k * k, 0].reshape(k, k)
    homo = batch.bw_group[k * k:, 0]
    gains = mixed / homo[:, None]
    return {(ka, kb): float(gains[i, j])
            for i, ka in enumerate(table2.FIG9_KERNELS)
            for j, kb in enumerate(table2.FIG9_KERNELS)}, us


def rows():
    out = []
    spreads = {}
    matrices = {}
    for arch in DOMAIN:
        m, us = gain_matrix(arch)
        matrices[arch] = m
        gains = [v for (a, b), v in m.items() if a != b]
        spreads[arch] = max(gains) - min(gains)
        ex = m[("DCOPY", "DDOT2")]
        out.append((f"fig9/{arch}", us,
                    f"pairs={len(m)};min={min(gains):.3f};"
                    f"max={max(gains):.3f};DCOPY+DDOT2={ex:.3f}"))
    intel = ("BDW-1", "BDW-2", "CLX")
    clx_smallest = spreads["CLX"] == min(spreads[a] for a in intel)
    # The DAXPY+DSCAL sign flip, read off the already-solved matrices
    # (n_each is DOMAIN//2 on both archs, matching the paper's split).
    dax_dscal_rome = matrices["ROME"][("DAXPY", "DSCAL")]
    dax_dscal_bdw = matrices["BDW-1"][("DAXPY", "DSCAL")]
    out.append(("fig9/check/clx_smallest_variation", 0.0,
                f"{clx_smallest};spreads="
                + ";".join(f"{a}={spreads[a]:.3f}" for a in spreads)))
    out.append(("fig9/check/daxpy_dscal_rome_flip", 0.0,
                f"rome_gain={dax_dscal_rome:.3f}(>1 expected);"
                f"bdw_gain={dax_dscal_bdw:.3f}(<1 expected)"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
