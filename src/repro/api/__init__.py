"""The library's front door: declarative scenarios in, predictions out.

The paper's pitch is radical simplicity — two numbers per kernel,
``(f, b_s)``, predict any pairing — and this package is that simplicity
as an API.  Callers state *what* (kernels, machine, placement, noise)::

    from repro import api

    pred = api.predict(api.Scenario.on("CLX")
                       .run("DCOPY", 12).run("DDOT2", 8))
    pred.bw_per_core          # per-core GB/s for each kernel

and the library picks *how*: the scalar reference solver, the batched
numpy solver, the jitted jax backend, or the desync event engine —
see :mod:`repro.api.engine` for the dispatch table.  Callers that
evaluate the same structure repeatedly compile a *plan* instead::

    plan = api.compile(batch)     # trace once: pack, resolve, select jit
    plan.run()                    # bit-for-bit api.predict(batch)
    plan.run(f=f2, b_s=bs2)       # new numbers, no re-trace

Modules:
  scenario — the frozen ``Scenario`` builder + ``ScenarioBatch`` sweeps
  registry — one kernel-spec resolution chain (Table II name →
             calibration → (f, bs) → ECM-from-loop-features) with
             suggestion-bearing lookup errors
  plan     — ``compile``/``Plan.run``: the two-phase API the verbs
             are sugar over (docs/plans.md)
  engine   — ``predict`` / ``simulate`` one-shot sugar
  results  — the unified ``Prediction`` / ``BatchPrediction`` /
             ``SimulationResult`` schema with dict/ndjson export
             (streaming included)

The pre-facade entry points (``sharing.predict``, ``solve_batch``,
``topology.predict_placed``, ``DesyncSimulator``/``run_batch``,
``calibrate.fit_scaling``) remain supported — they are the engines the
facade dispatches to, and facade results are bit-for-bit theirs.
"""

from .engine import JAX_BATCH_CUTOFF, predict, simulate
from .plan import (BatchPlan, PlacedBatchPlan, PlacedPlan, Plan,
                   ScalarPlan, SimulatePlan, compile, derive_member_seed,
                   infer_verb, structure_key)
from .registry import (PROVENANCES, ResolvedSpec, from_loop_features,
                       from_static_analysis, known_archs, known_kernels,
                       resolve, suggest, unknown_key_error,
                       unknown_key_message)
from .results import (BatchPrediction, DomainShare, GroupShare,
                      PlacedBatchPrediction, Prediction, Sensitivities,
                      SimulationResult, dump_dicts, dump_ndjson,
                      iter_ndjson, load_ndjson)
from .scenario import (DEFAULT_WORK_BYTES, Noise, RunSpec, Scenario,
                       ScenarioBatch, StepSpec)

__all__ = [
    "predict", "simulate", "JAX_BATCH_CUTOFF",
    "compile", "Plan", "ScalarPlan", "PlacedPlan", "BatchPlan",
    "PlacedBatchPlan", "SimulatePlan", "derive_member_seed",
    "infer_verb", "structure_key",
    "Scenario", "ScenarioBatch", "RunSpec", "StepSpec", "Noise",
    "DEFAULT_WORK_BYTES",
    "resolve", "ResolvedSpec", "from_loop_features",
    "from_static_analysis", "PROVENANCES", "known_kernels",
    "known_archs", "suggest", "unknown_key_error", "unknown_key_message",
    "Prediction", "BatchPrediction", "PlacedBatchPrediction",
    "SimulationResult", "Sensitivities", "GroupShare", "DomainShare",
    "dump_ndjson", "iter_ndjson", "dump_dicts", "load_ndjson",
]
