"""paper-stream: the paper's own Table II kernel suite packaged as a
selectable 'architecture' — running it on TPU calibrates (f, b_s) for the
HBM interface exactly as the paper calibrated its x86 domains."""

import dataclasses

from .base import ModelConfig

# Not a transformer; fields are placeholders.  The launch path special-cases
# family via name == "paper-stream" (see launch/dryrun.py).
CONFIG = ModelConfig(
    name="paper-stream",
    family="dense",
    n_layers=0,
    d_model=0,
    n_heads=1,
    kv_heads=1,
    d_ff=0,
    vocab=0,
)


def reduced() -> ModelConfig:
    return CONFIG
