"""Microscopic queue-level memory-controller simulator.

The container has no multicore hardware to *measure* bandwidth sharing on, so
this discrete-event simulator plays the role of the paper's LIKWID
measurements: it implements the mechanism sketched in the paper's Fig. 5 —
"a kernel with higher f can queue more requests per core and thus get more
share of bandwidth per core" — and the analytic model (core/sharing.py,
Eqs. 4–5) is validated against it (tests/test_sharing_vs_memsim.py,
benchmarks/fig8_error.py).

Mechanism (per core running kernel k):
  * The core *generates* cache-line requests at its natural demand rate —
    one line per ``Δ = 64 B / (f · b_s)`` seconds, the kernel's single-core
    ECM line time (so an uncontended core draws exactly its single-thread
    bandwidth ``f · b_s``).
  * At most ``W = max(1, round(Q_max · f))`` requests may be outstanding
    (the Fig. 5 picture: a kernel with higher f keeps a deeper queue).
    When the window is full, generation stalls until a completion.
  * The controller serves the shared FCFS queue one line per
    ``64 B / b(mix)`` seconds, where ``b(mix)`` is the Eq. 4 envelope (the
    phenomenological "capacity depends weakly on the workload mix" input,
    exactly as in the paper).

In deep saturation every core pins its window, the circulating population is
round-robined by FCFS, and shares emerge ∝ n·W ∝ n·f (Eq. 5); in light load
each core gets its demand ``f·b_s``.  Window discretization and
queue-residence effects produce the few-percent deviations that the paper's
Fig. 8 error study quantifies against real hardware.
"""

from __future__ import annotations

import dataclasses
import heapq
import random
from collections import deque
from typing import Sequence

from .sharing import Group, overlapped_saturated_bw

CACHELINE = 64.0  # bytes

_GEN, _COMPLETE = 0, 1


@dataclasses.dataclass
class _Core:
    group: int
    gap_s: float          # natural inter-request interval
    window: int           # max outstanding requests
    outstanding: int = 0
    stalled: bool = False
    completed: int = 0


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Outcome of one queue simulation, with its provenance.

    ``seed`` records the phase-jitter RNG seed the run used (``None`` for
    the deterministic stagger), so calibration ensembles built on the
    simulator are reproducible from the result alone.
    """

    bw: tuple[float, ...]   # attained bandwidth per group [GB/s]
    seed: int | None        # phase-jitter seed (None = deterministic)
    events: int             # interface services counted after warmup
    sim_time_s: float       # simulated span


def simulate(groups: Sequence[Group], *, sim_time_s: float | None = None,
             q_max: int = 48, warmup_frac: float = 0.15,
             n_events: int = 40_000, seed: int | None = None
             ) -> tuple[float, ...]:
    """Run the queue simulation; return attained bandwidth per group [GB/s].

    ``sim_time_s=None`` sizes the window to ~``n_events`` interface services,
    which bounds Python event-loop cost while keeping sampling error ≪ 1 %.
    ``seed`` randomizes the cores' initial request phases (see
    :func:`simulate_result`); the default ``None`` keeps the historical
    deterministic stagger bit-for-bit.
    """
    return simulate_result(groups, sim_time_s=sim_time_s, q_max=q_max,
                           warmup_frac=warmup_frac, n_events=n_events,
                           seed=seed).bw


def simulate_result(groups: Sequence[Group], *,
                    sim_time_s: float | None = None, q_max: int = 48,
                    warmup_frac: float = 0.15, n_events: int = 40_000,
                    seed: int | None = None) -> SimResult:
    """:func:`simulate` returning a :class:`SimResult` with provenance.

    With ``seed=None`` each core's first request is launched on the
    deterministic stagger ``(ci+1)·gap/n_cores`` (the historical behavior,
    reproduced exactly).  With an integer ``seed`` the initial phases are
    drawn uniformly from ``[0, gap)`` by ``random.Random(seed)``: different
    seeds explore different interleavings of the same steady state —
    window discretization and queue-residence effects then vary by a few
    percent, which is exactly the measurement-style scatter the
    calibration ensembles (repro.calibrate) average over.  Identical
    seeds give identical results.
    """
    groups = tuple(groups)
    b_mix = overlapped_saturated_bw(groups)
    if b_mix <= 0 or all(g.n == 0 for g in groups):
        return SimResult(bw=tuple(0.0 for _ in groups), seed=seed,
                         events=0, sim_time_s=0.0)
    service_s = CACHELINE / (b_mix * 1e9)
    if sim_time_s is None:
        sim_time_s = n_events * service_s

    cores: list[_Core] = []
    for gi, g in enumerate(groups):
        if g.n == 0 or g.f <= 0:
            continue
        gap = CACHELINE / (g.f * g.bs * 1e9)
        window = max(1, round(q_max * g.f))
        cores.extend(_Core(group=gi, gap_s=gap, window=window)
                     for _ in range(g.n))

    heap: list[tuple[float, int, int, int]] = []   # (t, seq, kind, core)
    seq = 0
    rng = random.Random(seed) if seed is not None else None
    for ci, c in enumerate(cores):
        if rng is None:
            t0 = (ci + 1) * c.gap_s / max(1, len(cores))
        else:
            t0 = rng.uniform(0.0, c.gap_s)
        heapq.heappush(heap, (t0, seq, _GEN, ci)); seq += 1

    queue: deque[int] = deque()
    mem_idle = True
    counted_from = sim_time_s * warmup_frac

    def start_service(now: float) -> None:
        nonlocal mem_idle, seq
        if mem_idle and queue:
            ci = queue.popleft()
            mem_idle = False
            heapq.heappush(heap, (now + service_s, seq, _COMPLETE, ci))
            seq += 1

    def generate(ci: int, now: float) -> None:
        nonlocal seq
        c = cores[ci]
        if c.outstanding < c.window:
            c.outstanding += 1
            queue.append(ci)
            start_service(now)
            heapq.heappush(heap, (now + c.gap_s, seq, _GEN, ci)); seq += 1
        else:
            c.stalled = True

    while heap:
        now, _, kind, ci = heapq.heappop(heap)
        if now > sim_time_s:
            break
        c = cores[ci]
        if kind == _GEN:
            generate(ci, now)
        else:
            mem_idle = True
            c.outstanding -= 1
            if now >= counted_from:
                c.completed += 1
            if c.stalled:
                c.stalled = False
                generate(ci, now)
            start_service(now)

    window_s = sim_time_s - counted_from
    bw = [0.0] * len(groups)
    for c in cores:
        bw[c.group] += c.completed * CACHELINE / window_s / 1e9
    return SimResult(bw=tuple(bw), seed=seed,
                     events=sum(c.completed for c in cores),
                     sim_time_s=sim_time_s)
