"""Decoder-only transformer LM (dense and MoE) with scan-stacked layers.

Covers qwen2-0.5b, qwen2.5-32b, qwen1.5-32b, nemotron-4-15b, olmoe-1b-7b,
granite-moe-1b-a400m, and the text backbone of internvl2-26b.

Layers are stacked on a leading axis and traversed with ``lax.scan`` so the
HLO is O(1) in depth (fast 512-device dry-run compiles); ``cfg.remat``
wraps the layer body in ``jax.checkpoint`` for training.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers, moe as moe_lib


# --------------------------------------------------------------------------
# Params
# --------------------------------------------------------------------------


def layer_params(cfg: ModelConfig, key):
    ka, km, k3 = jax.random.split(key, 3)
    p = {
        "ln1": layers.norm_params(cfg),
        "attn": layers.attention_params(cfg, ka),
        "ln2": layers.norm_params(cfg),
    }
    if cfg.moe is not None:
        p["moe"] = moe_lib.moe_params(cfg, km)
    else:
        p["mlp"] = layers.mlp_params(cfg, km)
    return p


def init_params(cfg: ModelConfig, key):
    ke, kl, ku = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    # Params are ALWAYS stacked on a leading layer axis (uniform sharding
    # rules); cfg.use_scan only selects scan vs. indexed unroll in forward.
    stacked = jax.vmap(functools.partial(layer_params, cfg))(layer_keys)
    p = {
        "embed": layers.embed_init(ke, cfg.vocab, cfg.d_model,
                                   jnp.dtype(cfg.param_dtype)),
        "layers": stacked,
        "ln_f": layers.norm_params(cfg),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = layers.dense_init(ku, cfg.d_model, cfg.vocab,
                                         jnp.dtype(cfg.param_dtype))
    return p


# --------------------------------------------------------------------------
# Forward (teacher-forced / prefill)
# --------------------------------------------------------------------------


def _layer_fwd(cfg: ModelConfig, lp, x, positions):
    h = layers.apply_norm(cfg, lp["ln1"], x)
    x = x + layers.attention(cfg, lp["attn"], h, positions)
    h = layers.apply_norm(cfg, lp["ln2"], x)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        y, aux = moe_lib.apply_moe(cfg, lp["moe"], h)
    else:
        y = layers.apply_mlp(cfg, lp["mlp"], h)
    return x + y, aux


def hidden_states(cfg: ModelConfig, params, x, positions):
    """Run the layer stack over embeddings x: (B, S, D)."""
    body = functools.partial(_layer_fwd, cfg)
    if cfg.remat:
        body = layers.remat(cfg, body)
    if cfg.use_scan:
        def scan_body(carry, lp):
            y, aux = body(lp, carry, positions)
            return y, aux
        x, auxs = jax.lax.scan(scan_body, x, params["layers"])
        aux = jnp.sum(auxs)
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, a = body(lp, x, positions)
            aux = aux + a
    return layers.apply_norm(cfg, params["ln_f"], x), aux


def forward(cfg: ModelConfig, params, tokens, *, extra_embeddings=None):
    """tokens: (B, S) -> logits (B, S(+P), vocab).

    ``extra_embeddings`` (B, P, D) are prepended (VLM patch stubs)."""
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if extra_embeddings is not None:
        x = jnp.concatenate(
            [extra_embeddings.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])[None, :]
    x, aux = hidden_states(cfg, params, x, positions)
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return layers.unembed(cfg, w, x), aux


def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight: float = 0.01):
    """Cross-entropy LM loss.  batch: {tokens (B,S), labels (B,S)} with
    labels == -1 masked out; VLM batches add 'patches' (B,P,D)."""
    logits, aux = forward(cfg, params, batch["tokens"],
                          extra_embeddings=batch.get("patches"))
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:       # VLM prefix: score text only
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss + aux_weight * aux, {"lm_loss": loss, "aux_loss": aux}


# --------------------------------------------------------------------------
# Decode (single token with KV cache)
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    hd = cfg.head_dim_
    dt = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, batch, max_seq, cfg.kv_heads, hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def _layer_decode(cfg: ModelConfig, lp, x, ck, cv, pos):
    h = layers.apply_norm(cfg, lp["ln1"], x)
    a, ck, cv = layers.attention_decode(cfg, lp["attn"], h, ck, cv, pos)
    x = x + a
    h = layers.apply_norm(cfg, lp["ln2"], x)
    if cfg.moe is not None:
        y, _ = moe_lib.apply_moe(cfg, lp["moe"], h)
    else:
        y = layers.apply_mlp(cfg, lp["mlp"], h)
    return x + y, ck, cv


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """tokens: (B,) int32; pos: (B,) current positions.
    Returns (logits (B, vocab), new_cache)."""
    x = params["embed"][tokens[:, None]].astype(jnp.dtype(cfg.dtype))

    if cfg.use_scan:
        def body(carry, inp):
            x = carry
            lp, ck, cv = inp
            x, ck, cv = _layer_decode(cfg, lp, x, ck, cv, pos)
            return x, (ck, cv)
        x, (ks, vs) = jax.lax.scan(body, x,
                                   (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, ck, cv = _layer_decode(cfg, lp, x, cache["k"][i],
                                      cache["v"][i], pos)
            ks.append(ck)
            vs.append(cv)
        new_cache = {"k": jnp.stack(ks), "v": jnp.stack(vs)}

    x = layers.apply_norm(cfg, params["ln_f"], x)
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed(cfg, w, x)[:, 0]
    return logits, new_cache
