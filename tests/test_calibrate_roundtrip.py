"""Slow certification: the full Table II × architecture round trip.

Acceptance gate of the calibration PR — every (kernel, arch) cell's
``(f, b_s)`` must be recovered from memsim-generated scaling curves
within the paper's 8 % bound, with the batched fit running as one
vectorized pass.  Runs in the dedicated `-m slow` CI job alongside the
``BENCH_calibrate.json`` artifact regeneration.
"""

import pytest

from repro.calibrate import ERROR_BOUND, certify
from repro.core.table2 import ARCHS, TABLE2

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def report():
    return certify()  # full grid: every Table II kernel × arch, 3 seeds


def test_every_cell_within_bound(report):
    assert len(report.cells) == len(TABLE2) * len(ARCHS)
    bad = [c for c in report.cells
           if c.f_err >= ERROR_BOUND or c.bs_err >= ERROR_BOUND]
    assert not bad, [(c.kernel, c.arch, c.f_err, c.bs_err) for c in bad]


def test_holdout_pair_predictions_within_bound(report):
    assert report.pairs, "certification must exercise paired shares"
    assert report.max_pair_err < ERROR_BOUND, [
        (p.kernels, p.arch, p.errs) for p in report.pairs
        if max(p.errs) >= ERROR_BOUND]


def test_confidence_intervals_cover_truth(report):
    """The seed-ensemble CI must be a meaningful band: finite, ordered,
    and (loosely) bracketing the fitted value."""
    for (kern, arch), cell in report.intervals.items():
        for field in ("f", "bs"):
            v = cell[field]
            assert v.lo <= v.value <= v.hi, (kern, arch, field)
            assert v.n_seeds == report.n_seeds


def test_batched_pass_beats_sequential_baseline(report):
    """The single-pass fit must not be slower than the per-cell loop it
    replaces (the artifact records the actual speedup)."""
    assert report.wall_sequential_s > report.wall_batched_s


def test_report_round_trips_to_json(report):
    import json
    d = json.loads(json.dumps(report.to_json_dict()))
    assert d["ok"] is True
    assert d["benchmark"] == "calibrate_roundtrip"
    assert len(d["cells"]) == len(report.cells)
