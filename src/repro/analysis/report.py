"""Static-analysis report: derived features next to Table II, plus the
lint sweep.

This is the closing of the loop the auditor exists for: every Table II
kernel in this repo has *two* independent feature sources — the paper's
hand-transcribed stream counts (``core/table2.py``) and the counts the
jaxpr walker derives from the kernel's own trace (:mod:`.traffic` /
:mod:`.features`).  :func:`cross_check` pushes both through the same
ECM bridge (:func:`repro.api.registry.from_loop_features`) and compares
the resulting serial fractions ``f``:

* **exact cells** — the derived ``(reads, writes, rfo)`` must equal the
  Table II row integer-for-integer and the two ``f`` values must agree
  to ``EXACT_F_TOL``;
* **write-allocate-ambiguous cells** — the *functional* (out-of-place)
  forms of DSCAL/DAXPY carry one RFO stream the paper's in-place C
  loops do not; their ``f`` must stay within ``AMBIGUOUS_BOUND``
  (docs/known-issues.md quantifies the actual gap at 0–3%).

The measured Table II ``f`` is reported alongside as a *diagnostic*
column only: ECM-predicted vs measured ``f`` differs by design (the
model is an upper bound on overlap), so the gate compares static
against Table II **through the same model**, never against the
measurement.

CLI::

    python -m repro.analysis.report               # cross-check, CLX
    python -m repro.analysis.report --arch ROME   # another machine
    python -m repro.analysis.report --lint        # lint the repo corpus
    python -m repro.analysis.report --json        # machine-readable

``--lint`` exits non-zero when any diagnostic fires, so CI can gate on
it; the cross-check exits non-zero when any cell breaks its bound.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import sys
from typing import Callable, Sequence

from ..core.backend import HAVE_JAX
from ..core.table2 import ARCHS, TABLE2

#: |f_static - f_table| / f_table bound for write-allocate-ambiguous
#: cells (functional DSCAL/DAXPY forms); exact cells use EXACT_F_TOL.
AMBIGUOUS_BOUND = 0.15
EXACT_F_TOL = 1e-3
#: Derived flops/iter may carry a reduction-accumulator epsilon
#: (one add per block, ~1/8192 per iteration at the suite sizes).
FLOP_TOL = 0.01


@dataclasses.dataclass(frozen=True)
class Case:
    """One static-suite cell: a Table II row and how to rebuild its
    kernel as a traceable callable."""

    table_name: str                       # Table II row to reproduce
    label: str                            # display name (variant-tagged)
    build: Callable[[], tuple]            # () -> (fn, args)
    reuse: bool = True                    # layer condition on/off
    exact: bool = True                    # counts must match the table


def _map_case(name: str, n_arrays: int, *, in_place: bool = False,
              scalars: int = 1):
    def build():
        import jax.numpy as jnp
        from ..kernels.stream import LANES, map_stream
        n = LANES * 64
        s = jnp.arange(1, scalars + 1, dtype=jnp.float32) if scalars > 1 \
            else jnp.float32(3.0)
        arrays = tuple(jnp.ones(n, jnp.float32) for _ in range(n_arrays))
        return (functools.partial(map_stream, name, in_place=in_place),
                (s, *arrays))
    return build


def _reduce_case(name: str, n_arrays: int):
    def build():
        import jax.numpy as jnp
        from ..kernels.stream import LANES, reduce_stream
        n = LANES * 64
        arrays = tuple(jnp.ones(n, jnp.float32) for _ in range(n_arrays))
        return functools.partial(reduce_stream, name), arrays
    return build


def _jacobi_case(version: int):
    def build():
        import jax.numpy as jnp
        from ..kernels.jacobi import jacobi_v1, jacobi_v2
        a = jnp.ones((66, 128), jnp.float32)
        if version == 1:
            return jacobi_v1, (a, jnp.float32(0.25))
        f = jnp.ones((66, 128), jnp.float32)
        return (functools.partial(jacobi_v2, ax=0.25, ay=0.25, b1=0.5,
                                  relax=1.0), (a, f))
    return build


def static_suite() -> tuple[Case, ...]:
    """Every Table II row as a (kernel builder, reuse flag, exactness)
    cell — plus the functional DSCAL/DAXPY variants whose extra RFO
    stream is the documented write-allocate ambiguity."""
    return (
        Case("DCOPY", "DCOPY", _map_case("dcopy", 1)),
        Case("DSCAL", "DSCAL (in-place)",
             _map_case("dscal", 1, in_place=True)),
        Case("DSCAL", "DSCAL (functional)", _map_case("dscal", 1),
             exact=False),
        Case("DAXPY", "DAXPY (in-place)",
             _map_case("daxpy", 2, in_place=True)),
        Case("DAXPY", "DAXPY (functional)", _map_case("daxpy", 2),
             exact=False),
        Case("ADD", "ADD", _map_case("add", 2)),
        Case("STREAM", "STREAM", _map_case("stream", 2)),
        Case("WAXPBY", "WAXPBY", _map_case("waxpby", 2, scalars=2)),
        Case("Schoenauer", "Schoenauer", _map_case("schoenauer", 3)),
        Case("vectorSUM", "vectorSUM", _reduce_case("vectorsum", 1)),
        Case("DDOT1", "DDOT1", _reduce_case("ddot1", 1)),
        Case("DDOT2", "DDOT2", _reduce_case("ddot2", 2)),
        Case("DDOT3", "DDOT3", _reduce_case("ddot3", 3)),
        Case("JacobiL2-v1", "JacobiL2-v1", _jacobi_case(1), reuse=True),
        Case("JacobiL3-v1", "JacobiL3-v1", _jacobi_case(1), reuse=False),
        Case("JacobiL2-v2", "JacobiL2-v2", _jacobi_case(2), reuse=True),
        Case("JacobiL3-v2", "JacobiL3-v2", _jacobi_case(2), reuse=False),
    )


def _bridge_f(name: str, reads: int, writes: int, rfo: int,
              flops: float, read_only: bool, arch: str) -> float:
    from ..api.registry import from_loop_features
    rs = from_loop_features(name, reads=reads, writes=writes, rfo=rfo,
                            flops_per_iter=flops, machine=arch,
                            read_only=read_only)
    return rs.spec.f[arch]


def cross_check(arch: str = "CLX", cases: Sequence[Case] | None = None
                ) -> list[dict]:
    """Derive features for every suite cell and compare against Table II
    through the shared ECM bridge.  Each row dict carries the derived
    and tabulated counts, both bridged ``f`` values, the measured ``f``
    (diagnostic), the applicable bound, and ``ok``."""
    from .features import features
    if arch not in ARCHS:
        from ..api.registry import unknown_key_error
        raise unknown_key_error("architecture", arch, ARCHS)
    rows = []
    for case in (static_suite() if cases is None else cases):
        fn, args = case.build()
        lf = features(fn, *args, name=case.label, reuse=case.reuse)
        ref = TABLE2[case.table_name]
        counts_match = (
            lf.reads == ref.reads and lf.writes == ref.writes
            and lf.rfo == ref.rfo
            and abs(lf.flops_per_iter - ref.flops_per_iter) <= FLOP_TOL)
        f_static = _bridge_f(case.label, lf.reads, lf.writes, lf.rfo,
                             lf.flops_per_iter, lf.read_only, arch)
        f_table = _bridge_f(case.table_name, ref.reads, ref.writes,
                            ref.rfo, ref.flops_per_iter, ref.read_only,
                            arch)
        f_err = abs(f_static - f_table) / f_table
        bound = EXACT_F_TOL if case.exact else AMBIGUOUS_BOUND
        ok = f_err <= bound and (counts_match or not case.exact)
        rows.append({
            "label": case.label, "table": case.table_name, "arch": arch,
            "exact": case.exact, "reuse": case.reuse,
            "static": {"reads": lf.reads, "writes": lf.writes,
                       "rfo": lf.rfo,
                       "flops": round(lf.flops_per_iter, 4)},
            "table2": {"reads": ref.reads, "writes": ref.writes,
                       "rfo": ref.rfo, "flops": ref.flops_per_iter},
            "counts_match": counts_match,
            "f_static": f_static, "f_table_ecm": f_table,
            "f_err": f_err, "bound": bound,
            "f_measured": ref.f.get(arch),
            "ok": ok,
        })
    return rows


# ---------------------------------------------------------------------------
# Lint corpus: the repo's own kernels and plans (false-positive guard)
# ---------------------------------------------------------------------------


def lint_corpus() -> list:
    """Lint every in-repo traceable kernel plus a compiled batch plan, a
    placed-batch plan, and a packed grid.  The repo's own artifacts
    must come back clean — any diagnostic here is either a real
    regression or a linter false positive, and both block CI."""
    # Note .lint the module, not the package-level lint() dispatcher —
    # the function shadows the submodule on the package namespace.
    from .lint import lint_callable, lint_grid, lint_plan
    diags = []
    for case in static_suite():
        fn, args = case.build()
        diags += lint_callable(fn, *args, name=case.label)

    import jax.numpy as jnp
    from ..kernels.rmsnorm import rmsnorm
    x = jnp.ones((64, 128), jnp.float32)
    w = jnp.ones((128,), jnp.float32)
    diags += lint_callable(rmsnorm, x, w, name="rmsnorm")

    from .. import api
    batch = api.ScenarioBatch([
        api.Scenario.on("CLX").run("DCOPY", 12).run("DDOT2", 8),
        api.Scenario.on("CLX").run("STREAM", 10).run("DDOT1", 6),
    ])
    diags += lint_plan(api.compile(batch))

    from ..core import topology
    from ..core.sharing import Group
    topo = topology.preset("CLX-2S")
    d0, d1 = topo.domain_names[:2]
    grid = topology.pack_placed(topo, [
        [topology.Placed(Group(n=4, f=0.33, bs=102.4), d0)],
        [topology.Placed(Group(n=2, f=0.5, bs=102.4), d0),
         topology.Placed(Group(n=2, f=0.5, bs=102.4), d1)],
    ])
    diags += lint_grid(grid)
    return diags


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _fmt_counts(c: dict) -> str:
    return f"R{c['reads']} W{c['writes']} RFO{c['rfo']} F{c['flops']:g}"


def main(argv: Sequence[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.report",
        description="static traffic analysis vs Table II, and the "
                    "trace-contract lint sweep")
    parser.add_argument("--arch", default="CLX", choices=ARCHS,
                        help="architecture for the f cross-check")
    parser.add_argument("--lint", action="store_true",
                        help="lint the in-repo kernel/plan corpus "
                             "instead of cross-checking")
    parser.add_argument("--json", action="store_true",
                        help="emit machine-readable JSON")
    args = parser.parse_args(argv)

    if not HAVE_JAX:
        print("jax is not available: static analysis needs a tracer",
              file=sys.stderr)
        return 2

    if args.lint:
        diags = lint_corpus()
        if args.json:
            print(json.dumps([dataclasses.asdict(d) for d in diags],
                             indent=2))
        else:
            for d in diags:
                print(d)
            print(f"{len(diags)} diagnostic(s) over the repo corpus")
        return 1 if diags else 0

    rows = cross_check(args.arch)
    if args.json:
        print(json.dumps(rows, indent=2))
    else:
        hdr = (f"{'kernel':<20} {'static':>22} {'Table II':>20} "
               f"{'f_static':>9} {'f_table':>8} {'f_meas':>7} "
               f"{'err':>7}  status")
        print(f"static cross-check on {args.arch} "
              f"(exact tol {EXACT_F_TOL:g}, ambiguous bound "
              f"{AMBIGUOUS_BOUND:.0%})")
        print(hdr)
        for r in rows:
            meas = r["f_measured"]
            print(f"{r['label']:<20} {_fmt_counts(r['static']):>22} "
                  f"{_fmt_counts(r['table2']):>20} "
                  f"{r['f_static']:>9.4f} {r['f_table_ecm']:>8.4f} "
                  f"{meas if meas is None else format(meas, '7.3f')} "
                  f"{r['f_err']:>6.2%}  "
                  f"{'ok' if r['ok'] else 'FAIL'}"
                  f"{'' if r['exact'] else ' (ambiguous)'}")
    return 0 if all(r["ok"] for r in rows) else 1


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
