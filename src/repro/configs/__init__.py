"""Architecture registry: one module per assigned architecture."""

from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, MoeConfig, ShapeConfig

# The 10 assigned architectures (the dry-run / roofline matrix).
ARCH_IDS = (
    "recurrentgemma-2b",
    "qwen2-0.5b",
    "qwen2.5-32b",
    "qwen1.5-32b",
    "nemotron-4-15b",
    "mamba2-1.3b",
    "internvl2-26b",
    "olmoe-1b-7b",
    "granite-moe-1b-a400m",
    "whisper-tiny",
)

# The paper's own kernel suite, selectable as --arch paper-stream.
PAPER_SUITE = "paper-stream"
ALL_IDS = ARCH_IDS + (PAPER_SUITE,)


def _module(arch: str):
    return importlib.import_module(
        f".{arch.replace('-', '_').replace('.', '_')}", __package__)


def get_config(arch: str) -> ModelConfig:
    if arch not in ALL_IDS:
        raise KeyError(f"unknown arch {arch!r}; available: {ALL_IDS}")
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()


__all__ = ["ARCH_IDS", "ALL_IDS", "PAPER_SUITE", "SHAPES", "ModelConfig",
           "MoeConfig", "ShapeConfig", "get_config", "get_reduced"]
