import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with 512 placeholder devices, and extract the roofline terms.

The FIRST TWO LINES above must stay first: jax locks the device count on
first init, so the XLA flag must be set before any jax-importing import.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k --mesh both --out results/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --all   # the full matrix

Each cell appends one JSON line: memory_analysis, cost_analysis flops/bytes,
collective byte accounting, the three roofline terms, and MODEL_FLOPS
ratios.  Already-present (arch, shape, mesh) cells are skipped, so the
matrix can be filled incrementally across invocations.
"""

import argparse     # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp                        # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                      # noqa: E402
from repro.configs.base import SHAPES          # noqa: E402
from repro.core import hlo as hlo_lib          # noqa: E402
from repro.core.machine import TPU_V5E         # noqa: E402
from repro.models import model_for             # noqa: E402
from repro.optim import cosine_schedule        # noqa: E402
from repro.runtime import sharding as shard_rules  # noqa: E402
from repro.runtime import steps as steps_lib   # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.obs import log as obs_log               # noqa: E402


def cell_should_run(cfg, shape) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "long_500k skipped: full O(L^2) attention (DESIGN.md)"
    return True, ""


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D train / 2·N·D inference (N_active for MoE)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def lower_cell(arch: str, shape_name: str, mesh, *, microbatches: int = 1,
               fsdp: bool | None = None, cfg=None, dp_only: bool = False,
               cfg_over: dict | None = None):
    import dataclasses as dc
    cfg = cfg or configs.get_config(arch)
    if cfg_over:
        cfg = dc.replace(cfg, **cfg_over)
    shape = SHAPES[shape_name]
    model = model_for(cfg)

    if shape.kind == "train":
        state_shape = jax.eval_shape(
            lambda: steps_lib.init_train_state(model, jax.random.key(0)))
        batch_specs = model.input_specs(shape)
        step, state_sh, batch_sh = steps_lib.jit_train_step(
            model, mesh, state_shape, batch_specs,
            lr_fn=cosine_schedule(3e-4, 100, 10000),
            microbatches=microbatches, fsdp=fsdp, dp_only=dp_only)
        lowered = step.lower(state_shape, batch_specs)
    elif shape.kind == "prefill":
        batch_specs = model.input_specs(shape)
        params_shape = jax.eval_shape(
            lambda: model.init(jax.random.key(0)))
        pshard = shard_rules.param_shardings(cfg, mesh, params_shape,
                                             fsdp=fsdp, dp_only=dp_only)
        bshard = shard_rules.batch_shardings(mesh, batch_specs,
                                             dp_only=dp_only)

        def prefill(params, batch):
            loss, metrics = model.loss(params, batch)
            return loss
        fn = jax.jit(prefill, in_shardings=(pshard, bshard))
        lowered = fn.lower(params_shape, batch_specs)
    else:  # decode
        specs = model.input_specs(shape)
        params_shape = jax.eval_shape(
            lambda: model.init(jax.random.key(0)))
        step, pshard, cshard, tok_sh = steps_lib.jit_serve_step(
            model, mesh, params_shape, specs["cache"],
            batch=shape.global_batch, fsdp=fsdp)
        lowered = step.lower(params_shape, specs["cache"],
                             specs["tokens"], specs["pos"])
    return lowered


def _cell_costs(compiled) -> dict:
    cost = compiled.cost_analysis() or {}
    stats = hlo_lib.collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": stats.total_wire_bytes,
        "counts": stats.counts,
        "wire_by_op": stats.wire_bytes,
    }


def _aux_depths(cfg) -> tuple[int, int]:
    if cfg.family == "hybrid":
        k = len(cfg.block_pattern or ("rec", "rec", "attn"))
        return k, 2 * k
    return 1, 2


def extrapolated_costs(arch, shape_name, mesh, *, fsdp=None,
                       dp_only=False, microbatches=1,
                       cfg_over=None) -> dict:
    """XLA's cost_analysis counts while-loop bodies ONCE, so a scan-stacked
    model under-reports flops/bytes/collectives by ~n_layers.  We recover
    exact totals by compiling the model UNROLLED at two small depths (k1,
    k2) and extrapolating linearly to the full depth — exact because layers
    are uniform."""
    import dataclasses as dc
    cfg = configs.get_config(arch)
    if cfg_over:
        cfg = dc.replace(cfg, **cfg_over)
    k1, k2 = _aux_depths(cfg)
    total = {}
    samples = {}
    for k in (k1, k2):
        over = {"n_layers": k, "use_scan": False}
        if cfg.family == "encdec":
            over["enc_layers"] = k
        cfg_k = dc.replace(cfg, **over)
        lowered = lower_cell(arch, shape_name, mesh, cfg=cfg_k, fsdp=fsdp,
                             dp_only=dp_only, microbatches=microbatches)
        samples[k] = _cell_costs(lowered.compile())
    L = cfg.n_layers
    for key in ("flops", "bytes", "wire"):
        slope = (samples[k2][key] - samples[k1][key]) / (k2 - k1)
        # Layout/fusion noise can make the slope slightly negative for tiny
        # per-layer costs; clamp to the k1 sample as a floor.
        total[key] = max(samples[k1][key] + slope * (L - k1),
                         samples[k1][key] * 0.5, 0.0)
    total["counts_per_layer"] = samples[k2]["counts"]
    return total


def analyse(lowered, compiled, arch, shape_name, mesh_name, n_chips,
            elapsed_s, extra_costs=None):
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo_text = compiled.as_text()
    stats = hlo_lib.collective_stats(hlo_text)
    if extra_costs is not None:
        # Exact totals from the unrolled-depth extrapolation (the scanned
        # compile under-counts while-loop bodies).  All figures per-device.
        cost = {"flops": extra_costs["flops"],
                "bytes accessed": extra_costs["bytes"]}
        stats = hlo_lib.CollectiveStats(
            counts=stats.counts, operand_bytes=stats.operand_bytes,
            wire_bytes={"total": extra_costs["wire"]})
    terms = hlo_lib.roofline_terms(
        f"{arch}/{shape_name}/{mesh_name}", cost, stats, n_chips=n_chips,
        model_flops_total=model_flops(cfg, shape))
    mem_fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "peak_memory_in_bytes"):
        mem_fields[f] = getattr(mem, f, None)
    args_b = mem_fields.get("argument_size_in_bytes") or 0
    temp_b = mem_fields.get("temp_size_in_bytes") or 0
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_chips": n_chips, "status": "ok", "compile_s": round(elapsed_s, 1),
        "flops_per_chip": terms.flops,
        "hbm_bytes_per_chip": terms.hbm_bytes,
        "wire_bytes_per_chip": terms.wire_bytes,
        "collective_counts": stats.counts,
        "collective_wire_bytes": stats.wire_bytes,
        "t_compute_s": terms.t_compute,
        "t_memory_s": terms.t_memory,
        "t_collective_s": terms.t_collective,
        "dominant": terms.dominant,
        "model_flops_per_chip": terms.model_flops,
        "useful_flop_ratio": terms.useful_flop_ratio,
        "roofline_fraction": terms.roofline_fraction,
        "memory_analysis": mem_fields,
        "bytes_per_device_est": (args_b + temp_b) / max(n_chips, 1),
        "fits_hbm": ((args_b + temp_b) / max(n_chips, 1))
        < TPU_V5E.hbm_bytes,
    }


def run_cell(arch, shape_name, mesh_name, out_path, *, microbatches=1,
             fsdp=None, dp_only=False, variant="baseline", cfg_over=None):
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_should_run(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": reason, "variant": variant}
        _append(out_path, rec)
        obs_log.emit(f"SKIP {arch}/{shape_name}/{mesh_name}: {reason}",
                     event="launch.dryrun.skip", arch=arch,
                     shape=shape_name, mesh=mesh_name, reason=reason)
        return rec
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = 512 if multi else 256
    if not multi:
        # Single-pod mesh on 512 placeholder devices: use the first 256.
        import numpy as np
        devs = np.asarray(jax.devices()[:256]).reshape(16, 16)
        from jax.sharding import Mesh
        mesh = Mesh(devs, ("data", "model"))
    t0 = time.time()
    try:
        lowered = lower_cell(arch, shape_name, mesh,
                             microbatches=microbatches, fsdp=fsdp,
                             dp_only=dp_only, cfg_over=cfg_over)
        compiled = lowered.compile()
        extra = extrapolated_costs(arch, shape_name, mesh, fsdp=fsdp,
                                   dp_only=dp_only,
                                   microbatches=microbatches,
                                   cfg_over=cfg_over)
        rec = analyse(lowered, compiled, arch, shape_name, mesh_name,
                      n_chips, time.time() - t0, extra_costs=extra)
        rec["variant"] = variant
        rec["options"] = {"microbatches": microbatches, "dp_only": dp_only,
                          "fsdp": fsdp, "cfg_over": cfg_over or {}}
        obs_log.emit(
            f"OK   {arch}/{shape_name}/{mesh_name}[{variant}]: "
            f"dominant={rec['dominant']} "
            f"roofline={rec['roofline_fraction']:.3f} "
            f"t=({rec['t_compute_s']:.3f},{rec['t_memory_s']:.3f},"
            f"{rec['t_collective_s']:.3f})s "
            f"mem/dev={rec['bytes_per_device_est']/2**30:.2f}GiB "
            f"({rec['compile_s']}s)",
            event="launch.dryrun.ok", arch=arch, shape=shape_name,
            mesh=mesh_name, variant=variant, dominant=rec["dominant"],
            roofline_fraction=rec["roofline_fraction"],
            compile_s=rec["compile_s"])
    except Exception as e:  # noqa: BLE001 — record the failure and move on
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "variant": variant,
               "traceback": traceback.format_exc()[-2000:]}
        obs_log.emit(f"FAIL {arch}/{shape_name}/{mesh_name}: "
                     f"{type(e).__name__}: {e}", stream=sys.stderr,
                     event="launch.dryrun.fail", arch=arch,
                     shape=shape_name, mesh=mesh_name,
                     error=f"{type(e).__name__}: {e}")
    _append(out_path, rec)
    return rec


def _append(path, rec):
    if path:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "a") as f:
            f.write(json.dumps(rec) + "\n")


def _done_cells(path):
    done = set()
    if path and os.path.exists(path):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS)
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true",
                    help="run the full 10x4x2 matrix (resumable)")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--dp-only", action="store_true",
                    help="no TP: FSDP params + batch over the whole mesh")
    ap.add_argument("--remat-policy", choices=("nothing", "dots"),
                    default=None)
    ap.add_argument("--variant", default="baseline",
                    help="label for this record (perf experiments)")
    args = ap.parse_args()
    cfg_over = {}
    if args.remat_policy:
        cfg_over["remat_policy"] = args.remat_policy

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        cells = [(a, s, m) for a in configs.ARCH_IDS
                 for s in ("train_4k", "prefill_32k", "decode_32k",
                           "long_500k")
                 for m in meshes]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape, m) for m in meshes]

    done = _done_cells(args.out) if args.variant == "baseline" else set()
    for arch, shape, mesh_name in cells:
        if (arch, shape, mesh_name) in done:
            obs_log.emit(f"SKIP (done) {arch}/{shape}/{mesh_name}",
                         event="launch.dryrun.skip", arch=arch,
                         shape=shape, mesh=mesh_name, reason="done")
            continue
        run_cell(arch, shape, mesh_name, args.out,
                 microbatches=args.microbatches, dp_only=args.dp_only,
                 variant=args.variant, cfg_over=cfg_over or None)


if __name__ == "__main__":
    main()
