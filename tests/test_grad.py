"""Gradient correctness for the differentiable Eq. 1–5 forward chain.

Every analytic jacobian the repo exposes is checked against central
finite differences at 1e-5 relative tolerance:

* ``sharing.solve_arrays_and_grad`` — all utilization laws × all
  gradient inputs (``f``, ``b_s``, ``cores``);
* the fixed-point law's implicit-function-theorem ``custom_vjp``;
* ``sharing.solve_placed_and_grad`` — masked placed batches, with
  gradients exactly zero where the mask poisons padding;
* ``desync_batch.work_durations_and_grad`` — the engine's step-timing
  twin;
* the softmin knob — forward values unchanged, gradient path smoothed;
* the facade (``plan.grad`` / ``Sensitivities``) and the gradient
  pod-plan co-design built on top.

The recursion law is a staircase in *integer* n (its sweep masks on
``i <= n``), so the ``cores`` checks use non-integer occupancies where
the law is locally smooth; the fixed-point law is continuous in n by
construction — that is what makes it the co-design relaxation — and is
additionally checked at integer n.
"""

import itertools

import numpy as np
import pytest

from repro.core import sharing
from repro.core.backend import HAVE_JAX
from repro.core.desync_batch import work_durations, work_durations_and_grad

jax_only = pytest.mark.skipif(not HAVE_JAX, reason="jax not importable")

RTOL = 1e-5

# Non-integer occupancies: smooth for every law (see module docstring).
N0 = np.array([[2.3, 4.6], [1.4, 3.2]])
F0 = np.array([[0.42, 0.71], [0.93, 0.18]])
BS0 = np.array([[82.0, 95.0], [120.0, 105.0]])

_ARG = {"cores": 0, "f": 1, "b_s": 2}


def _fd_jacobian(n, f, bs, wrt, mode, eps=1e-6, **kw):
    """Central-difference ∂bw[b, i]/∂wrt[b, j] via the forward solver."""
    arrs = [np.asarray(a, dtype=np.float64) for a in (n, f, bs)]
    B, G = arrs[0].shape
    k = _ARG[wrt]
    out = np.zeros((B, G, G))
    for b in range(B):
        for j in range(G):
            hi = [a.copy() for a in arrs]
            lo = [a.copy() for a in arrs]
            hi[k][b, j] += eps
            lo[k][b, j] -= eps
            _, _, _, bw_hi = sharing.solve_arrays(
                *hi, utilization=mode, backend="numpy", **kw)
            _, _, _, bw_lo = sharing.solve_arrays(
                *lo, utilization=mode, backend="numpy", **kw)
            out[b, :, j] = (bw_hi[b] - bw_lo[b]) / (2 * eps)
    return out


def _assert_close(got, want, label):
    denom = np.abs(want) + 1e-9
    rel = np.max(np.abs(got - want) / denom)
    assert rel < RTOL, f"{label}: max rel err {rel:.3e}"


# ---------------------------------------------------------------------------
# solve_arrays_and_grad: every law × every input
# ---------------------------------------------------------------------------


@jax_only
@pytest.mark.parametrize("mode", sharing.UTILIZATION_MODES)
@pytest.mark.parametrize("wrt", ["f", "b_s", "cores"])
def test_solve_grad_matches_fd(mode, wrt):
    (b, alphas, util, bw), grads = sharing.solve_arrays_and_grad(
        N0, F0, BS0, wrt=(wrt,), utilization=mode)
    # forward outputs are the plain solve, bit for bit
    fb, fa, fu, fbw = sharing.solve_arrays(N0, F0, BS0, utilization=mode,
                                           backend="numpy")
    np.testing.assert_allclose(bw, fbw, rtol=1e-12)
    _assert_close(grads[wrt], _fd_jacobian(N0, F0, BS0, wrt, mode),
                  f"{mode}/{wrt}")


@jax_only
def test_fixedpoint_implicit_vjp_continuous_at_integer_n():
    """The fixed-point law is smooth in n even at integers — the property
    the pod-plan relaxation depends on (the IFT vjp must agree with FD
    straddling an integer occupancy)."""
    n = np.array([[2.0, 4.0]])
    _, grads = sharing.solve_arrays_and_grad(
        n, F0[:1], BS0[:1], wrt=("cores",), utilization="fixedpoint")
    _assert_close(grads["cores"],
                  _fd_jacobian(n, F0[:1], BS0[:1], "cores", "fixedpoint"),
                  "fixedpoint/cores@integer-n")


@jax_only
def test_utilization_curve_grad_matches_fd():
    """The numpy-side analytic dU/df (used by the Gauss–Newton fit)
    agrees with FD for every law."""
    n = np.array([1.0, 2.7, 6.3, 14.0])
    eps = 1e-7
    for mode in sharing.UTILIZATION_MODES:
        u, du = sharing.utilization_curve_grad(n, 0.37, mode=mode)
        np.testing.assert_allclose(
            u, sharing.utilization_curve(n, 0.37, mode=mode), rtol=1e-12)
        fd = (sharing.utilization_curve(n, 0.37 + eps, mode=mode)
              - sharing.utilization_curve(n, 0.37 - eps, mode=mode)) \
            / (2 * eps)
        _assert_close(du, fd, f"utilization_curve_grad/{mode}")


@jax_only
def test_unknown_wrt_suggests():
    with pytest.raises(KeyError, match="gradient input"):
        sharing.solve_arrays_and_grad(N0, F0, BS0, wrt=("bs",))


# ---------------------------------------------------------------------------
# Softmin knob: forward unchanged, gradients smoothed
# ---------------------------------------------------------------------------


@jax_only
def test_softmin_changes_gradients_not_values():
    (_, _, _, bw), g_exact = sharing.solve_arrays_and_grad(
        N0, F0, BS0, wrt=("f",), utilization="queue")
    (_, _, _, bw_soft), g_soft = sharing.solve_arrays_and_grad(
        N0, F0, BS0, wrt=("f",), utilization="queue", softmin_beta=50.0)
    np.testing.assert_allclose(bw_soft, bw, rtol=1e-12)
    assert np.all(np.isfinite(g_soft["f"]))
    # At the saturation kink the exact path picks a subgradient branch;
    # the smoothed path blends — they must differ somewhere.
    n_kink = np.array([[1.0 / 0.42, 4.6], [1.4, 3.2]])
    _, ge = sharing.solve_arrays_and_grad(
        n_kink, F0, BS0, wrt=("f",), utilization="queue")
    _, gs = sharing.solve_arrays_and_grad(
        n_kink, F0, BS0, wrt=("f",), utilization="queue",
        softmin_beta=5.0)
    assert not np.allclose(ge["f"], gs["f"])


# ---------------------------------------------------------------------------
# Placed batches: masked padding has exactly zero gradient
# ---------------------------------------------------------------------------


@jax_only
def test_placed_grad_masked_padding_is_zero():
    B, D, K = 2, 2, 3
    rng = np.random.default_rng(7)
    n = rng.uniform(1.2, 6.8, (B, D, K))
    f = rng.uniform(0.1, 0.9, (B, D, K))
    bs = rng.uniform(50.0, 150.0, (B, D, K))
    mask = np.ones((B, D, K), bool)
    mask[0, 1, 2] = False
    mask[1, 0, 0] = False
    # Poison the padding: gradients must not propagate NaN/inf.
    n[~mask] = np.nan
    f[~mask] = np.inf
    pred, grads = sharing.solve_placed_and_grad(
        n, f, bs, mask=mask, wrt=("f", "b_s", "cores"))
    lane = mask[..., :, None] & mask[..., None, :]
    for name, g in grads.items():
        assert g.shape == (B, D, K, K), name
        assert np.all(np.isfinite(g)), name
        assert np.all(g[~lane] == 0.0), name
    # Live lanes match FD on the sanitized arrays.
    n_c = np.where(mask, n, 0.0)
    f_c = np.where(mask, f, 0.0)
    eps = 1e-6
    d, k = 0, 1
    hi, lo = f_c.copy(), f_c.copy()
    hi[0, d, k] += eps
    lo[0, d, k] -= eps
    p_hi = sharing.solve_placed_batch(n_c, hi, bs, mask=mask,
                                      backend="numpy")
    p_lo = sharing.solve_placed_batch(n_c, lo, bs, mask=mask,
                                      backend="numpy")
    fd = (p_hi.bw_group[0, d] - p_lo.bw_group[0, d]) / (2 * eps)
    _assert_close(grads["f"][0, d, :, k], fd, "placed/f live lane")


# ---------------------------------------------------------------------------
# Desync step-timing twin
# ---------------------------------------------------------------------------


@jax_only
def test_work_durations_grad_matches_fd():
    by = np.array([[1e9, 2e9], [5e8, 3e9]])
    t, grads = work_durations_and_grad(N0, F0, BS0, by,
                                       wrt=("f", "b_s", "cores"))
    np.testing.assert_allclose(t, work_durations(N0, F0, BS0, by),
                               rtol=1e-12)
    eps = 1e-6
    arrs = {"f": F0, "b_s": BS0, "cores": N0}
    for wrt, base in arrs.items():
        k = _ARG[wrt]
        fd = np.zeros((2, 2, 2))
        for b in range(2):
            for j in range(2):
                args_hi = [N0.copy(), F0.copy(), BS0.copy()]
                args_lo = [N0.copy(), F0.copy(), BS0.copy()]
                args_hi[k][b, j] += eps
                args_lo[k][b, j] -= eps
                fd[b, :, j] = (work_durations(*args_hi, by)[b]
                               - work_durations(*args_lo, by)[b]) \
                    / (2 * eps)
        _assert_close(grads[wrt], fd, f"work_durations/{wrt}")


@jax_only
def test_work_durations_masked_groups_are_zero():
    n = np.array([[2.0, 0.0]])
    by = np.array([[1e9, 0.0]])
    t, grads = work_durations_and_grad(n, F0[:1], BS0[:1], by,
                                       wrt=("f", "b_s", "cores"))
    assert t[0, 1] == 0.0
    for name, g in grads.items():
        assert np.all(g[0, 1, :] == 0.0), name


# ---------------------------------------------------------------------------
# Facade: plan.grad + Sensitivities schema
# ---------------------------------------------------------------------------


@jax_only
def test_plan_grad_scalar_matches_run_fd():
    from repro import api
    plan = api.compile(
        api.Scenario.on("CLX").run("DCOPY", 4).run("DAXPY", 6))
    pred = plan.grad(wrt=("f", "b_s", "cores"))
    assert pred.sensitivities is not None
    assert pred.sensitivities.wrt == ("f", "b_s", "cores")
    G = len(pred.groups)
    jac = pred.sensitivities["f"]
    assert jac.shape == (G, G)
    f0 = np.array([g.f for g in pred.groups])
    eps = 1e-6
    for j in range(G):
        hi, lo = f0.copy(), f0.copy()
        hi[j] += eps
        lo[j] -= eps
        fd = (np.array(plan.run(f=hi).bw_group)
              - np.array(plan.run(f=lo).bw_group)) / (2 * eps)
        _assert_close(jac[:, j], fd, f"plan.grad f[{j}]")
    # forward block is the unchanged plain solve
    np.testing.assert_allclose(pred.bw_group, plan.run().bw_group)


@jax_only
def test_sensitivities_round_trip():
    from repro import api
    plan = api.compile(
        api.Scenario.on("CLX").run("DCOPY", 4).run("DDOT2", 2))
    pred = plan.grad()
    d = pred.to_dict()
    assert d["sensitivities"]["kind"] == "sensitivities"
    back = api.Prediction.from_dict(d)
    for name in pred.sensitivities.wrt:
        np.testing.assert_allclose(back.sensitivities[name],
                                   pred.sensitivities[name])
    with pytest.raises(KeyError, match="gradient input"):
        pred.sensitivities["nope"]


@jax_only
def test_simulate_plan_grad_raises():
    from repro import api
    plan = api.compile(
        api.Scenario.on("CLX").ranks(2).step("DCOPY", 1e9),
        verb="simulate")
    with pytest.raises(NotImplementedError, match="while_loop"):
        plan.grad()


# ---------------------------------------------------------------------------
# Co-design: gradient pod-plan search
# ---------------------------------------------------------------------------


def _terms():
    from repro.core.hlo import RooflineTerms
    return RooflineTerms(name="step", t_compute=0.0, t_memory=0.0,
                         t_collective=0.0, flops=2.0e12, hbm_bytes=8.0e9,
                         wire_bytes=1.0e9, model_flops=2.0e12)


def test_pod_coefficients_match_simulation():
    from repro.runtime.overlap_schedule import (evaluate_pod_plans,
                                                pod_step_coefficients)
    terms = _terms()
    coeffs = pod_step_coefficients(terms)
    cands = [(1.0, 1.0, 1.0, 1.0), (1.3, 0.9, 0.9, 0.9),
             (0.7, 1.1, 1.1, 1.1)]
    for cand, ev in zip(cands, evaluate_pod_plans(terms, cands)):
        assert float(coeffs.makespan(cand)) == pytest.approx(
            ev.t_step, rel=1e-12)


def test_gradient_pod_plan_recovers_enumerator():
    from repro.runtime.overlap_schedule import best_pod_plan
    terms = _terms()
    vals = [0.7, 0.85, 1.0, 1.15, 1.3]
    grid = [c for c in itertools.product(vals, repeat=4)
            if abs(sum(c) - 4.0) < 1e-12]
    i_e, e_e = best_pod_plan(terms, grid, method="enumerate")
    i_g, e_g = best_pod_plan(terms, grid, method="gradient")
    assert e_g.t_step <= e_e.t_step * 1.01 + 1e-18
    assert i_g == i_e  # noiseless: the analytic objective is exact


def test_pod_plan_method_validation():
    from repro.runtime.overlap_schedule import (best_pod_plan,
                                                gradient_pod_plan)
    terms = _terms()
    grid = [(1.0, 1.0, 1.0, 1.0), (1.2, 0.8, 1.0, 1.0)]
    with pytest.raises(KeyError, match="pod-plan method"):
        best_pod_plan(terms, grid, method="gradiant")
    with pytest.raises(ValueError, match="total load"):
        gradient_pod_plan(terms, [(1.0,) * 4, (1.1, 1.0, 1.0, 1.0)])


def test_makespan_grad_softmax_knob():
    from repro.runtime.overlap_schedule import pod_step_coefficients
    coeffs = pod_step_coefficients(_terms())
    x = np.array([1.2, 0.9, 1.0, 0.9])
    t_exact, g_exact = coeffs.makespan_and_grad(x)
    t_soft, g_soft = coeffs.makespan_and_grad(x, softmax_tau=1e-4)
    assert t_soft == t_exact            # forward never changes
    assert g_exact.sum() == pytest.approx(np.max(coeffs.a * x) / 1.2)
    assert np.all(g_soft >= 0) and np.isfinite(g_soft).all()
