"""nemotron-4-15b [dense]: GQA kv=8, squared-ReLU MLP.
[arXiv:2402.16819; unverified]"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    kv_heads=8,
    d_ff=24576,
    vocab=256000,
    act="sq_relu",
    norm="layernorm",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=192,
        vocab=512, remat=False, dtype="float32")
