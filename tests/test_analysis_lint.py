"""Trace-contract linter: one seeded true positive per rule, and the
false-positive guard over the repo's own kernels and plans."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.analysis import lint as lint_fn
from repro.analysis.lint import (RULES, Diagnostic, lint_callable,
                                 lint_grid, lint_plan)
from repro.analysis.report import lint_corpus
from repro.core import topology
from repro.core.sharing import Group

jax.config.update("jax_enable_x64", False)


def _batch_plan():
    batch = api.ScenarioBatch([
        api.Scenario.on("CLX").run("DCOPY", 12).run("DDOT2", 8),
        api.Scenario.on("CLX").run("STREAM", 10).run("DDOT1", 6),
    ])
    return api.compile(batch)


def _grid():
    topo = topology.preset("CLX-2S")
    d0, d1 = topo.domain_names[:2]
    return topology.pack_placed(topo, [
        [topology.Placed(Group(n=4, f=0.33, bs=102.4), d0)],
        [topology.Placed(Group(n=2, f=0.5, bs=102.4), d0),
         topology.Placed(Group(n=2, f=0.5, bs=102.4), d1)],
    ])


# ---------------------------------------------------------------------------
# Seeded true positives — one per rule
# ---------------------------------------------------------------------------


def test_weak_const_flags_baked_scalar():
    c = jnp.asarray(2.0)            # 0-d closure capture -> trace const
    diags = lint_callable(lambda v: v * c, jnp.ones((8, 8)), name="fix")
    assert [d.rule for d in diags] == ["weak-const"]
    d = diags[0]
    assert d.severity == "warning" and d.target == "fix"
    assert "argument" in d.suggestion
    assert "2.0" in d.message


def test_bucket_bypass_flags_unbucketed_jit_boundary():
    inner = jax.jit(lambda v: v * 2.0)
    big = jnp.ones((100, 64), jnp.float32)    # bucket(100) = 128 != 100
    diags = lint_callable(lambda v: inner(v) + 1.0, big, name="sweep")
    assert any(d.rule == "bucket-bypass" for d in diags)
    d = next(d for d in diags if d.rule == "bucket-bypass")
    assert "128" in d.suggestion and "bucket" in d.suggestion


def test_f64_promotion_flags_strong_scalar():
    w = np.float64(2.0)             # strongly typed: promotes under x64
    diags = lint_callable(lambda v: v * w, jnp.ones((8, 8), jnp.float32),
                          name="promo")
    assert [d.rule for d in diags] == ["f64-promotion"]
    assert "float64" in diags[0].message


def test_f64_promotion_flags_float32_plan_arrays():
    plan = _batch_plan()
    bad = dataclasses.replace(plan, n=plan.n.astype(np.float32))
    diags = lint_plan(bad)
    assert [d.rule for d in diags] == ["f64-promotion"]
    assert "'n'" in diags[0].message


def test_bucket_bypass_flags_plan_bucket_drift():
    plan = _batch_plan()

    class DriftedPlan(type(plan)):
        # A deserialized/hand-rolled plan whose cached bucket no longer
        # matches the substrate policy.
        @property
        def bucket(self):
            return (len(self) + 1, self.n.shape[1])

    bad = DriftedPlan(**{f.name: getattr(plan, f.name)
                         for f in dataclasses.fields(plan)})
    diags = lint_plan(bad, rules=("bucket-bypass",))
    assert [d.rule for d in diags] == ["bucket-bypass"]
    assert "recompile" in diags[0].suggestion


def test_padding_escape_flags_live_masked_lane():
    grid = _grid()
    bad_n = grid.n.copy()
    idx = tuple(np.argwhere(~grid.mask)[0])
    bad_n[idx] = 3.0
    diags = lint_grid(dataclasses.replace(grid, n=bad_n))
    assert [d.rule for d in diags] == ["padding-escape"]
    assert diags[0].severity == "error"
    assert "mask" in diags[0].message


def test_padding_escape_flags_nonfinite_occupied_cell():
    grid = _grid()
    bad_f = grid.f.copy()
    idx = tuple(np.argwhere(grid.mask)[0])
    bad_f[idx] = np.nan
    diags = lint_grid(dataclasses.replace(grid, f=bad_f))
    assert [d.rule for d in diags] == ["padding-escape"]
    assert "non-finite" in diags[0].message


def test_padding_escape_flags_placed_batch_plan():
    topo = topology.preset("CLX-2S")
    d0, d1 = topo.domain_names[:2]
    scen = [api.Scenario.on("CLX").using(topo).placed("DCOPY", 4, d0),
            api.Scenario.on("CLX").using(topo).placed("DCOPY", 2, d0)
                                              .placed("DDOT2", 2, d1)]
    plan = api.compile(api.ScenarioBatch(scen))
    assert isinstance(plan, api.PlacedBatchPlan)
    assert (~plan.grid.mask).any()          # ragged batch -> padding
    assert lint_plan(plan) == []            # pristine plan is clean
    bad = dataclasses.replace(plan, grid=dataclasses.replace(
        plan.grid, n=np.where(plan.grid.mask, plan.grid.n, 5.0)))
    diags = lint_plan(bad, rules=("padding-escape",))
    assert any(d.rule == "padding-escape" for d in diags)


# ---------------------------------------------------------------------------
# False-positive guard + surface
# ---------------------------------------------------------------------------


def test_repo_corpus_lints_clean():
    assert lint_corpus() == []


def test_clean_callable_and_plan_and_grid():
    assert lint_callable(lambda v: v + 1.0, jnp.ones((8, 8))) == []
    assert lint_plan(_batch_plan()) == []
    assert lint_grid(_grid()) == []


def test_unknown_rule_suggests():
    with pytest.raises(KeyError, match="weak-const"):
        lint_callable(lambda v: v, jnp.ones(4), rules=("weakconst",))


def test_dispatcher_routes_and_rejects():
    assert lint_fn(_grid()) == []
    assert lint_fn(_batch_plan()) == []
    assert lint_fn(lambda v: v + 1.0, jnp.ones(4)) == []
    with pytest.raises(TypeError, match="cannot lint"):
        lint_fn(42)


def test_diagnostic_str_is_actionable():
    d = Diagnostic(rule="weak-const", severity="warning", target="k",
                   message="m", suggestion="s")
    assert str(d) == "[weak-const] k: m — fix: s"


def test_rule_catalog_complete():
    assert set(RULES) == {"weak-const", "bucket-bypass", "f64-promotion",
                          "padding-escape"}
