"""Serving example: batched greedy decode with KV cache across three
architecture families (dense GQA, SSM, hybrid RG-LRU) — the decode shapes
are the memory-bound regime the paper's model governs.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model_for

B, STEPS, MAX_SEQ = 4, 24, 64

for arch in ("qwen2-0.5b", "mamba2-1.3b", "recurrentgemma-2b"):
    cfg = configs.get_reduced(arch)
    model = model_for(cfg)
    params = model.init(jax.random.key(0))
    cache = model.init_cache(B, MAX_SEQ)
    step = jax.jit(model.decode_step)

    tokens = jnp.zeros((B,), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    logits, cache = step(params, cache, tokens, pos)  # compile
    t0 = time.perf_counter()
    outs = []
    for _ in range(STEPS):
        logits, cache = step(params, cache, tokens, pos)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1
        outs.append(int(tokens[0]))
    jax.block_until_ready(logits)
    ms = (time.perf_counter() - t0) / STEPS * 1e3
    state_kind = ("KV cache" if arch.startswith("qwen")
                  else "O(1) recurrent state" if "mamba" in arch
                  else "ring-buffer KV + LRU state")
    print(f"{arch:20s} [{state_kind:26s}] {ms:6.1f} ms/token  "
          f"sample={outs[:8]}")
