"""ndjson-over-HTTP transport: the asyncio front end.

A deliberately small HTTP/1.1 server on ``asyncio`` streams (stdlib
only — no framework): POST an ndjson body of request lines to
``/v1/solve`` (or the verb-pinning aliases ``/v1/predict`` /
``/v1/simulate``) and the responses stream back as chunked ndjson, one
line per request **in request order**, as each one's coalesced solve
lands.  ``GET /healthz`` answers liveness (503 while draining);
``GET /statsz`` returns the plan-cache, coalescer, and substrate cache
stats (``backend.cache_stats(scope="all")``) as one JSON document.

Connections are one-shot (``Connection: close``): the client idiom is
one POST per workload, many lines per POST — coalescing happens across
lines *and* across concurrent connections, so parallel clients batch
into the same ticks.
"""

from __future__ import annotations

import asyncio
import json
import time

from ..core import backend as backend_mod
from ..obs import metrics
from .cache import PlanCache
from .coalesce import Coalescer, ServeConfig, ServeError
from . import protocol

#: Largest accepted request body (bytes); admission control for the
#: transport layer, matching the coalescer's queue bound in spirit.
MAX_BODY = 32 * 1024 * 1024
_MAX_HEADER = 64 * 1024

_STATUS_TEXT = {200: "OK", 400: "Bad Request", 404: "Not Found",
                405: "Method Not Allowed", 411: "Length Required",
                413: "Payload Too Large", 429: "Too Many Requests",
                500: "Internal Server Error", 503: "Service Unavailable",
                504: "Gateway Timeout"}


class App:
    """The server: one coalescer + plan cache behind an asyncio
    listener.  Socket-free layers stay reachable (``app.coalescer``,
    ``app.cache``) so tests and embedders can bypass HTTP."""

    def __init__(self, config: ServeConfig | None = None, *,
                 cache: PlanCache | None = None):
        self.config = config or ServeConfig()
        # "is None", not "or": an empty PlanCache is len() == 0 == falsy.
        self.cache = (cache if cache is not None
                      else PlanCache(self.config.cache_entries))
        self.coalescer = Coalescer(self.config, cache=self.cache)
        self._server: asyncio.base_events.Server | None = None
        self._t0 = time.monotonic()

    # -- lifecycle ----------------------------------------------------------

    async def start(self, host: str = "127.0.0.1", port: int = 0) -> int:
        """Bind and listen; returns the bound port (useful with
        ``port=0``)."""
        self.coalescer.start()
        self._server = await asyncio.start_server(
            self._client, host=host, port=port)
        return self._server.sockets[0].getsockname()[1]

    async def shutdown(self, *, drain: bool = True) -> None:
        """Stop listening, then drain (or fail) queued requests."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        await self.coalescer.close(drain=drain)

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        await self._server.serve_forever()

    # -- introspection ------------------------------------------------------

    def statsz(self) -> dict:
        return {
            "uptime_s": round(time.monotonic() - self._t0, 3),
            "coalescer": self.coalescer.stats(),
            "plan_cache": self.cache.stats(),
            "caches": backend_mod.cache_stats(scope="all"),
        }

    # -- the connection handler ---------------------------------------------

    async def _client(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            method, path, headers = await self._head(reader)
            if method is None:
                return
            if path in ("/healthz", "/statsz"):
                if method != "GET":
                    await self._json(writer, 405, {
                        "ok": False, "error": f"{path} is GET-only"})
                elif path == "/healthz":
                    draining = self.coalescer._closed
                    await self._json(
                        writer, 503 if draining else 200,
                        {"ok": not draining,
                         "status": "draining" if draining else "serving"})
                else:
                    await self._json(writer, 200, self.statsz())
                return
            verb = {"/v1/solve": None, "/v1/predict": "predict",
                    "/v1/simulate": "simulate"}.get(path, "?")
            if verb == "?":
                await self._json(writer, 404, {
                    "ok": False, "error": f"no route {path!r}; try "
                    f"/v1/solve, /v1/predict, /v1/simulate, /healthz, "
                    f"/statsz"})
                return
            if method != "POST":
                await self._json(writer, 405, {
                    "ok": False, "error": f"{path} is POST-only "
                    f"(ndjson body, one request per line)"})
                return
            body, err = await self._body(reader, headers)
            if err is not None:
                await self._json(writer, err[0], {"ok": False,
                                                  "error": err[1]})
                return
            await self._stream(writer, body, verb)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass     # client went away; nothing to answer
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except ConnectionError:  # pragma: no cover - racy teardown
                pass

    async def _head(self, reader):
        try:
            raw = await reader.readuntil(b"\r\n\r\n")
        except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
            return None, None, None
        if len(raw) > _MAX_HEADER:
            return None, None, None
        lines = raw.decode("latin-1").split("\r\n")
        parts = lines[0].split()
        if len(parts) != 3:
            return None, None, None
        headers = {}
        for line in lines[1:]:
            if ":" in line:
                k, v = line.split(":", 1)
                headers[k.strip().lower()] = v.strip()
        return parts[0].upper(), parts[1], headers

    async def _body(self, reader, headers):
        if "content-length" not in headers:
            return None, (411, "POST needs a Content-Length")
        try:
            length = int(headers["content-length"])
        except ValueError:
            return None, (411, "malformed Content-Length")
        if length > MAX_BODY:
            return None, (413, f"body over {MAX_BODY} bytes")
        return await reader.readexactly(length), None

    async def _stream(self, writer, body: bytes, verb: str | None) -> None:
        """Submit every request line, then stream the response lines in
        request order as their (coalesced, out-of-order) solves land."""
        lines = [ln for ln in body.decode("utf-8", "replace").splitlines()
                 if ln.strip()]
        metrics.counter("serve.http.posts").inc()
        tasks = [asyncio.ensure_future(self._one(ln, verb))
                 for ln in lines]
        writer.write(
            b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: application/x-ndjson\r\n"
            b"Transfer-Encoding: chunked\r\n"
            b"Connection: close\r\n\r\n")
        for t in tasks:
            row = await t
            data = (json.dumps(row) + "\n").encode()
            writer.write(b"%x\r\n%s\r\n" % (len(data), data))
            await writer.drain()   # transport backpressure, per line
        writer.write(b"0\r\n\r\n")
        await writer.drain()

    async def _one(self, line: str, verb: str | None) -> dict:
        req_id = None
        t0 = time.monotonic()
        try:
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                raise protocol.BadRequest(f"bad JSON: {e}") from None
            if isinstance(d, dict):
                req_id = d.get("id")
                if verb is not None:
                    d = {**d, "kind": verb}
            req = protocol.parse_request(d)
            result = await self.coalescer.submit(
                req.scenario, verb=req.verb, deadline_s=req.deadline_s)
            return protocol.build_response(
                req, result, time.monotonic() - t0)
        except Exception as e:   # per-line isolation: stream continues
            if not isinstance(e, ServeError):
                metrics.counter("serve.http.errors").inc()
            return protocol.error_response(req_id, e)

    async def _json(self, writer, status: int, payload: dict) -> None:
        data = (json.dumps(payload, sort_keys=True) + "\n").encode()
        writer.write(
            b"HTTP/1.1 %d %s\r\n"
            b"Content-Type: application/json\r\n"
            b"Content-Length: %d\r\n"
            b"Connection: close\r\n\r\n%s"
            % (status, _STATUS_TEXT.get(status, "?").encode(),
               len(data), data))
        await writer.drain()
