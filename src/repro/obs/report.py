"""Summarize an exported trace: ``python -m repro.obs.report FILE``.

Reads the ndjson event stream written by :func:`repro.obs.export.
write_ndjson` (or the ``REPRO_TRACE=1`` at-exit hook) and prints, per
span name: call count, total/mean/p50/p95/max wall time — plus the
metric rows and any log lines.  With no FILE it summarizes the current
in-process buffer, which makes it usable from tests and notebooks::

    python -m repro.obs.report repro-trace.ndjson
    python -m repro.obs.report repro-trace.ndjson --sort total --top 10
"""

from __future__ import annotations

import argparse
import json
import sys

__all__ = ["summarize", "render", "main"]


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return sorted_vals[int(idx)]


def summarize(rows: list[dict]) -> dict:
    """Group ndjson rows into span aggregates, metrics, and logs."""
    spans: dict[str, list[float]] = {}
    metrics, logs, meta = [], [], []
    for row in rows:
        kind = row.get("kind")
        if kind == "span":
            spans.setdefault(row["name"], []).append(float(row["dur_us"]))
        elif kind == "metric":
            metrics.append(row)
        elif kind == "log":
            logs.append(row)
        elif kind == "meta":
            meta.append(row)
    agg = []
    for name, durs in spans.items():
        durs.sort()
        agg.append({"name": name, "count": len(durs),
                    "total_us": sum(durs),
                    "mean_us": sum(durs) / len(durs),
                    "p50_us": _percentile(durs, 0.50),
                    "p95_us": _percentile(durs, 0.95),
                    "max_us": durs[-1]})
    return {"spans": agg, "metrics": metrics, "logs": logs, "meta": meta}


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:9.3f}s "
    if us >= 1e3:
        return f"{us / 1e3:9.3f}ms"
    return f"{us:9.1f}us"


def render(summary: dict, *, sort: str = "total", top: int = 0,
           fh=None) -> None:
    out = fh if fh is not None else sys.stdout
    key = {"total": "total_us", "mean": "mean_us", "count": "count",
           "max": "max_us", "name": "name"}[sort]
    spans = sorted(summary["spans"], key=lambda r: r[key],
                   reverse=(sort != "name"))
    if top:
        spans = spans[:top]
    if spans:
        w = max(len(r["name"]) for r in spans)
        print(f"{'span':<{w}}  {'count':>7}  {'total':>11}  {'mean':>11}"
              f"  {'p50':>11}  {'p95':>11}  {'max':>11}", file=out)
        for r in spans:
            print(f"{r['name']:<{w}}  {r['count']:>7d}"
                  f"  {_fmt_us(r['total_us']):>11}"
                  f"  {_fmt_us(r['mean_us']):>11}"
                  f"  {_fmt_us(r['p50_us']):>11}"
                  f"  {_fmt_us(r['p95_us']):>11}"
                  f"  {_fmt_us(r['max_us']):>11}", file=out)
    else:
        print("no spans recorded", file=out)
    for row in summary["meta"]:
        attrs = row.get("attrs", {})
        print(f"! {row['name']}: {attrs}", file=out)
    if summary["metrics"]:
        print(file=out)
        print("metrics:", file=out)
        for m in summary["metrics"]:
            labels = m.get("labels") or {}
            label_s = ("{" + ", ".join(f"{k}={v}" for k, v in
                                       sorted(labels.items())) + "}"
                       if labels else "")
            stats = {k: v for k, v in m.items()
                     if k not in ("kind", "name", "labels", "type")}
            print(f"  {m['name']}{label_s} [{m['type']}] {stats}", file=out)
    if summary["logs"]:
        print(file=out)
        print(f"log lines: {len(summary['logs'])}", file=out)


def _load_rows(path: str | None) -> list[dict]:
    if path is None:
        from . import export

        return export.event_dicts() + export.metric_dicts()
    rows = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a repro.obs ndjson trace export.")
    parser.add_argument("file", nargs="?", default=None,
                        help="ndjson trace file (default: the in-process "
                             "buffer)")
    parser.add_argument("--sort", default="total",
                        choices=("total", "mean", "count", "max", "name"))
    parser.add_argument("--top", type=int, default=0,
                        help="show only the top N spans (0 = all)")
    args = parser.parse_args(argv)
    try:
        rows = _load_rows(args.file)
    except OSError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    render(summarize(rows), sort=args.sort, top=args.top)
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess
    raise SystemExit(main())
