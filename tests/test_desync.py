"""Desynchronization-dynamics tests: the simulator must reproduce the
paper's HPCG phenomenology (Figs. 1 and 3) from the sharing model alone."""

import random

import pytest

from repro.core.desync import (Allreduce, DesyncSimulator, Idle, WaitNeighbors,
                               Work, durations_by_tag, end_spread, skewness,
                               start_spread)

MB = 1e6
N_RANKS = 20


def _programs(followup, seed):
    rng = random.Random(seed)
    progs = []
    for _ in range(N_RANKS):
        progs.append([
            Idle(rng.expovariate(1 / 6e-5), tag="noise"),
            Work("Schoenauer", 40 * MB, tag="symgs"),
            Work("DDOT2", 8 * MB, tag="ddot2"),
            *followup,
        ])
    return progs


def _skews(followup, seeds=range(6)):
    out = []
    for s in seeds:
        sim = DesyncSimulator(_programs(followup, s), "CLX")
        recs = sim.run(t_max=60)
        out.append((skewness(durations_by_tag(recs, "ddot2")),
                    start_spread(recs, "ddot2"), end_spread(recs, "ddot2")))
    return out


def test_resynchronization_with_allreduce():
    """Fig. 1: late DDOT2 starters overlap with idleness in MPI_Allreduce,
    run faster, and the rank distribution resynchronizes: negative skew,
    end spread < start spread."""
    res = _skews([Allreduce(), Work("DAXPY", 30 * MB, tag="daxpy")])
    assert sum(sk < 0 for sk, _, _ in res) >= 4
    assert all(es < ss for _, ss, es in res)


def test_desynchronization_with_daxpy():
    """Fig. 3(b): follow-up DAXPY has higher f than DDOT2 — early finishers
    steal bandwidth from stragglers: positive skew, spread grows."""
    res = _skews([Work("DAXPY", 30 * MB, tag="daxpy")])
    assert all(sk > 0 for sk, _, _ in res)
    assert all(es > ss for _, ss, es in res)


def test_late_starters_run_faster():
    """Fig. 1(c): DDOT2 runtime decreases monotonically with start time."""
    sim = DesyncSimulator(_programs([Allreduce()], seed=3), "CLX")
    recs = sim.run(t_max=60)
    dd = sorted((r.start, r.duration) for r in recs if r.tag == "ddot2")
    starts = [s for s, _ in dd]
    durs = [d for _, d in dd]
    # Pearson-free check: first-third mean duration > last-third mean.
    k = len(durs) // 3
    assert sum(durs[:k]) / k > sum(durs[-k:]) / k
    assert starts == sorted(starts)


def test_homogeneous_lockstep_stays_synchronized():
    """No noise, same program: all ranks finish simultaneously."""
    progs = [[Work("STREAM", 10 * MB, tag="w")] for _ in range(8)]
    recs = DesyncSimulator(progs, "BDW-2").run()
    ends = [r.end for r in recs if r.tag == "w"]
    assert max(ends) - min(ends) < 1e-9


def test_bandwidth_conservation_during_overlap():
    """Two groups overlapping: total time consistent with shared bandwidth,
    longer than the isolated-run time."""
    progs = [[Work("DCOPY", 50 * MB, tag="a")] for _ in range(10)] + \
            [[Work("DDOT2", 50 * MB, tag="b")] for _ in range(10)]
    recs = DesyncSimulator(progs, "CLX").run()
    t_a = max(r.end for r in recs if r.tag == "a")
    solo = DesyncSimulator(
        [[Work("DCOPY", 50 * MB, tag="a")] for _ in range(10)], "CLX").run()
    t_solo = max(r.end for r in solo if r.tag == "a")
    assert t_a > t_solo  # contention must cost something


def test_allreduce_is_global_barrier():
    progs = [
        [Idle(1e-3, tag="late"), Allreduce(), Work("STREAM", MB, tag="w")],
        [Allreduce(), Work("STREAM", MB, tag="w")],
    ]
    recs = DesyncSimulator(progs, "CLX").run()
    w_starts = [r.start for r in recs if r.tag == "w"]
    assert max(w_starts) - min(w_starts) < 1e-9
    assert min(w_starts) >= 1e-3


def test_deadlock_detection():
    progs = [[Allreduce()], [Allreduce(), Allreduce()]]
    sim = DesyncSimulator(progs, "CLX")
    with pytest.raises(RuntimeError, match="deadlock"):
        sim.run(t_max=1.0)


def test_records_are_consistent():
    progs = _programs([Allreduce()], seed=0)
    recs = DesyncSimulator(progs, "CLX").run()
    by_rank = {}
    for r in recs:
        assert r.end >= r.start - 1e-12
        by_rank.setdefault(r.rank, []).append(r)
    for rank, rs in by_rank.items():
        rs.sort(key=lambda r: r.index)
        assert len(rs) == len(progs[rank])
        for a, b in zip(rs, rs[1:]):
            assert b.start >= a.end - 1e-9
