"""The facade: Scenario builder, registry resolution chain, dispatch,
and the unified result schema.

Equivalence against the underlying engines is covered separately in
test_api_equivalence.py; this file covers the facade's own semantics —
build-time validation, provenance, error suggestions, engine selection,
and the dict/ndjson export surface.
"""

import io
import json

import numpy as np
import pytest

from repro import api
from repro.core import machine, sharing, table2, topology
from repro.core.sharing import HAVE_JAX


# ---------------------------------------------------------------------------
# Scenario builder
# ---------------------------------------------------------------------------


def test_builder_is_immutable_and_composable():
    base = api.Scenario.on("CLX").run("DCOPY", 12)
    extended = base.run("DDOT2", 8)
    assert len(base.runs) == 1
    assert len(extended.runs) == 2
    # The shared prefix is untouched: templates are safe to reuse.
    assert api.predict(base.run("DAXPY", 4)).groups[1].name == "DAXPY"
    assert api.predict(extended).groups[1].name == "DDOT2"


def test_run_rejects_bad_counts_and_mixing():
    sc = api.Scenario.on("CLX")
    with pytest.raises(ValueError, match="non-negative int"):
        sc.run("DCOPY", -1)
    with pytest.raises(ValueError, match="non-negative int"):
        sc.run("DCOPY", 2.5)
    prog = api.Scenario.on("CLX").ranks(4).step("DCOPY", 1e6)
    with pytest.raises(ValueError, match="cannot mix"):
        prog.run("DDOT2", 4)
    with pytest.raises(ValueError, match="cannot mix"):
        sc.run("DCOPY", 4).ranks(4)


def test_unknown_kernel_suggests_nearest():
    with pytest.raises(KeyError, match="did you mean 'DCOPY'"):
        api.Scenario.on("CLX").run("DCPY", 4)
    with pytest.raises(KeyError, match="known kernels"):
        api.Scenario.on("CLX").run("nope", 4)


def test_unknown_arch_suggests_nearest():
    with pytest.raises(KeyError, match="did you mean 'CLX'"):
        api.Scenario.on("CLV").run("DCOPY", 4)
    # The same contract on the pre-facade entry points (satellite):
    with pytest.raises(KeyError, match="did you mean 'ROME'"):
        sharing.Group.of(table2.kernel("DCOPY"), "ROMA", 2)
    with pytest.raises(KeyError, match="did you mean 'DDOT2'"):
        table2.kernel("DDOT_2")
    with pytest.raises(KeyError, match="did you mean 'CLX-2S'"):
        topology.preset("CLX-2")


def test_options_whitelist():
    sc = api.Scenario.on("CLX").options(utilization="queue", t_max=5.0)
    assert sc.utilization == "queue"
    assert sc.t_max == 5.0
    with pytest.raises(TypeError, match="unknown scenario options"):
        sc.options(utlization="queue")


def test_program_steps_require_ranks():
    with pytest.raises(ValueError, match=r"\.ranks\(R\)"):
        api.Scenario.on("CLX").step("DCOPY", 1e6)
    with pytest.raises(ValueError, match=r"\.ranks\(R\)"):
        api.Scenario.on("CLX").barrier()


def test_per_rank_bytes_must_match_rank_count():
    sc = api.Scenario.on("CLX").ranks(4)
    with pytest.raises(ValueError, match="4 ranks"):
        sc.step("DCOPY", [1e6, 2e6])


def test_placed_requires_topology_and_full_placement():
    sc = api.Scenario.on("CLX").placed("DCOPY", 4, "CLX/d0")
    with pytest.raises(ValueError, match="no topology"):
        api.predict(sc)
    half = (api.Scenario.on("CLX").using("CLX")
            .placed("DCOPY", 4, "CLX/d0").run("DDOT2", 4))
    with pytest.raises(ValueError, match="place every group"):
        api.predict(half)


def test_using_accepts_preset_names():
    sc = (api.Scenario.on("CLX").using("CLX-2S")
          .placed("DCOPY", 4, "CLX/s0/d0"))
    assert api.predict(sc).engine == "topology"
    with pytest.raises(KeyError, match="topology preset"):
        api.Scenario.on("CLX").using("CLX-3S")


# ---------------------------------------------------------------------------
# Registry resolution chain
# ---------------------------------------------------------------------------


def test_resolve_table2_name():
    r = api.resolve("DCOPY", arch="CLX")
    assert r.provenance == "table2"
    assert r.spec is table2.TABLE2["DCOPY"]


def test_resolve_custom_specs_mapping():
    specs = {"phase": table2.KernelSpec.synthetic("phase", 0.5, 800.0)}
    r = api.resolve("phase", specs=specs)
    assert r.provenance == "custom"
    with pytest.raises(KeyError, match="known kernels: \\['phase'\\]"):
        api.resolve("phse", specs=specs)


def test_resolve_explicit_and_synthetic_specs():
    assert api.resolve(table2.kernel("DAXPY")).provenance == "explicit"
    syn = table2.KernelSpec.synthetic("mine", 0.4, 100.0)
    assert api.resolve(syn).provenance == "synthetic"


def test_resolve_f_bs_pair():
    r = api.resolve((0.5, 819.0), name="bwd")
    assert r.provenance == "synthetic"
    assert r.spec.f == {"TPU": 0.5}
    assert r.spec.bs == {"TPU": 819.0}


def test_resolve_calibration_mapping():
    r = api.resolve({"f": {"CLX": 0.2}, "bs": {"CLX": 100.0}},
                    name="cal", arch="CLX")
    assert r.provenance == "calibrated"
    assert r.spec.f["CLX"] == 0.2

    class FakeCalibratedValue:
        def __init__(self, value):
            self.value = value

    r2 = api.resolve({"f": FakeCalibratedValue(0.3),
                      "bs": FakeCalibratedValue(90.0)},
                     arch="ROME", name="cal2")
    assert r2.provenance == "calibrated"
    assert r2.spec.f == {"ROME": 0.3}
    # Scalar values without an arch cannot be keyed.
    with pytest.raises(ValueError, match="pass arch="):
        api.resolve({"f": 0.3, "bs": 90.0}, name="cal3")


def test_resolve_rejects_garbage():
    with pytest.raises(TypeError, match="cannot resolve"):
        api.resolve(42)


def test_from_loop_features_is_ecm_route():
    r = api.from_loop_features("mycopy", reads=1, writes=1, rfo=1,
                               flops_per_iter=0, machine=machine.CLX)
    assert r.provenance == "ecm"
    assert set(r.spec.f) == {"CLX"}
    assert 0 < r.spec.f["CLX"] <= 1
    # Matches the direct ECM prediction for the same stream mix.
    from repro.core import ecm
    direct = ecm.predict(table2.kernel("DCOPY"), machine.CLX)
    assert r.spec.f["CLX"] == pytest.approx(direct.f)


def test_from_loop_features_accepts_machine_names():
    by_name = api.from_loop_features("mycopy", reads=1, writes=1, rfo=1,
                                     flops_per_iter=0, machine="CLX")
    by_model = api.from_loop_features("mycopy", reads=1, writes=1, rfo=1,
                                      flops_per_iter=0,
                                      machine=machine.CLX)
    assert by_name.spec.f == by_model.spec.f
    assert by_name.spec.bs == by_model.spec.bs


def test_from_loop_features_unknown_machine_suggests():
    with pytest.raises(KeyError, match=r"did you mean 'CLX'"):
        api.from_loop_features("k", reads=1, writes=1, rfo=0,
                               flops_per_iter=1, machine="CLX2")
    with pytest.raises(TypeError, match="MachineModel"):
        api.from_loop_features("k", reads=1, writes=1, rfo=0,
                               flops_per_iter=1, machine=42)


def test_from_loop_features_unknown_bandwidth_class_suggests():
    with pytest.raises(KeyError, match=r"did you mean 'read_only'"):
        api.from_loop_features("k", reads=1, writes=0, rfo=0,
                               flops_per_iter=1, machine="CLX",
                               bandwidth_class="readonly")


def test_from_loop_features_bandwidth_class_override():
    forced = api.from_loop_features("k", reads=2, writes=1, rfo=1,
                                    flops_per_iter=1, machine="CLX",
                                    bandwidth_class="read_only")
    assert forced.spec.bs["CLX"] == \
        machine.CLX.saturated_bw_gbs["read_only"]


def test_from_static_analysis_unknown_machine_suggests():
    import functools

    import jax.numpy as jnp

    from repro.kernels.stream import map_stream
    fn = functools.partial(map_stream, "dcopy")
    args = (jnp.float32(1.0), jnp.ones(1024, jnp.float32))
    with pytest.raises(KeyError, match=r"did you mean 'ROME'"):
        api.from_static_analysis(fn, args, machine="ROME2")


def test_prelabelled_resolved_spec_passthrough():
    labelled = api.ResolvedSpec(spec=table2.kernel("DCOPY"),
                                provenance="calibrated")
    p = api.predict(api.Scenario.on("CLX").run(labelled, 4))
    assert p.groups[0].provenance == "calibrated"


# ---------------------------------------------------------------------------
# Engine dispatch
# ---------------------------------------------------------------------------


def test_single_scenario_uses_scalar_engine():
    p = api.predict(api.Scenario.on("CLX").run("DCOPY", 4))
    assert p.engine == "scalar"


def test_small_batch_uses_numpy():
    b = api.ScenarioBatch.split_sweep("CLX", "DCOPY", "DDOT2", 8)
    assert api.predict(b).engine == "numpy"


@pytest.mark.skipif(not HAVE_JAX, reason="jax not importable")
def test_large_batch_uses_jax():
    base = api.Scenario.on("CLX").run("DCOPY", 1).run("DDOT2", 1)
    na = 1 + np.arange(api.JAX_BATCH_CUTOFF) % 19
    b = base.batch(np.stack([na, 20 - na], axis=-1))
    assert api.predict(b).engine == "jax"
    assert api.predict(b, backend="numpy").engine == "numpy"


def test_predict_rejects_program_scenarios():
    prog = api.Scenario.on("CLX").ranks(2).step("DCOPY", 1e6)
    with pytest.raises(ValueError, match="simulate"):
        api.predict(prog)


def test_simulate_rejects_nothing_to_run():
    with pytest.raises(ValueError, match="nothing to simulate"):
        api.simulate(api.Scenario.on("CLX"))


def test_batched_predict_rejects_placed_scenarios():
    placed = (api.Scenario.on("CLX").using("CLX")
              .placed("DCOPY", 4, "CLX/d0"))
    plain = api.Scenario.on("CLX").run("DCOPY", 4)
    with pytest.raises(ValueError, match="placed"):
        api.predict(api.ScenarioBatch.of([plain, placed]))


def test_batch_requires_uniform_options():
    a = api.Scenario.on("CLX").run("DCOPY", 4)
    b = api.Scenario.on("CLX").options(utilization="queue").run("DCOPY", 4)
    with pytest.raises(ValueError, match="solver options"):
        api.ScenarioBatch.of([a, b])


def test_ragged_batch_pads_with_neutral_groups():
    scens = [api.Scenario.on("CLX").run("DCOPY", 4),
             api.Scenario.on("CLX").run("DCOPY", 4).run("DDOT2", 4)
             .run("DAXPY", 2)]
    batch = api.predict(api.ScenarioBatch.of(scens), backend="numpy")
    n, f, bs, names = api.ScenarioBatch.of(scens).arrays
    assert n.shape == (2, 3)
    assert n[0].tolist() == [4, 0, 0]
    # Row 0 must equal the unpadded scalar solve.
    ref = api.predict(scens[0])
    assert batch[0].bw_group == ref.bw_group
    assert len(batch[0].groups) == 1
    assert len(batch[1].groups) == 3


def test_mixed_arch_batch_labels_rows_correctly():
    scens = [api.Scenario.on("CLX").run("DCOPY", 4),
             api.Scenario.on("ROME").run("DCOPY", 4)]
    batch = api.predict(api.ScenarioBatch.of(scens), backend="numpy")
    assert batch.archs == ("CLX", "ROME")
    assert batch.arch == "mixed"
    assert batch[0].arch == "CLX"
    assert batch[1].arch == "ROME"
    # Each row solved with its own arch's (f, bs).
    assert batch[1].bw_group == api.predict(scens[1]).bw_group
    assert [d["arch"] for d in batch.to_dicts()] == ["CLX", "ROME"]


def test_batch_rows_keep_genuine_zero_thread_groups():
    sc = api.Scenario.on("CLX").run("DCOPY", 0).run("DDOT2", 4)
    ref = api.predict(sc)
    assert len(ref.groups) == 2
    row = api.predict(api.ScenarioBatch.of(
        [sc, api.Scenario.on("CLX").run("DAXPY", 2)]), backend="numpy")[0]
    # The n = 0 group survives (distinguished from padding by its
    # provenance), and the row equals the scalar result exactly.
    assert len(row.groups) == 2
    assert row.bw_group == ref.bw_group
    assert row.groups[0].n == 0


def test_simulation_batch_requires_uniform_t_max_and_topology():
    a = api.Scenario.on("CLX").ranks(2).step("DCOPY", 1e6)
    b = a.options(t_max=1.0)
    with pytest.raises(ValueError, match="t_max"):
        api.simulate(api.ScenarioBatch.of([a, b]))
    # An explicit t_max overrides every scenario, so mixing is fine then.
    res = api.simulate(api.ScenarioBatch.of([a, b]), t_max=5.0)
    assert res.n_scenarios == 2


def test_scenario_batch_counts_shape_checked():
    base = api.Scenario.on("CLX").run("DCOPY", 1).run("DDOT2", 1)
    with pytest.raises(ValueError, match=r"\(B, 2\)"):
        base.batch(np.ones((4, 3)))


# ---------------------------------------------------------------------------
# Simulation facade
# ---------------------------------------------------------------------------


def test_group_mode_simulation_places_runs_on_domains():
    topo = topology.preset("CLX-2S")
    sc = (api.Scenario.on("CLX").using(topo)
          .run("DCOPY", 2, domain="CLX/s0/d0", bytes=1e6)
          .run("DDOT2", 2, domain="CLX/s1/d0", bytes=1e6))
    res = api.simulate(sc)
    assert res.n_ranks == 4
    # Separate domains: neither kernel contends with the other, so each
    # pair finishes as if alone (same finish for both ranks of a group).
    recs = res.records()
    ends = {}
    for r in recs:
        ends.setdefault(r.tag, set()).add(round(r.end, 12))
    assert len(ends["DCOPY"]) == 1
    assert len(ends["DDOT2"]) == 1


def test_noise_ensemble_expands_to_batch():
    sc = (api.Scenario.on("CLX").ranks(3)
          .step("DCOPY", 1e6)
          .with_noise(1e-5, seed=3, ensemble=5))
    res = api.simulate(sc)
    assert res.n_scenarios == 5
    assert res.engine == "desync-numpy"
    # Different seeds -> different noise draws -> different makespans.
    assert len({round(float(t), 15) for t in res.t_end}) > 1


def test_simulation_batch_fuses_inner_ensembles():
    # Batch × ensemble composition: each scenario's E members become
    # adjacent rows of one fused run, mapped by result.members.
    sc_a = (api.Scenario.on("CLX").ranks(2).step("DCOPY", 1e6)
            .with_noise(1e-5, seed=1, ensemble=2))
    sc_b = (api.Scenario.on("CLX").ranks(2).step("DCOPY", 2e6)
            .with_noise(1e-5, seed=2, ensemble=3))
    res = api.simulate(api.ScenarioBatch.of([sc_a, sc_b]))
    assert res.n_scenarios == 5
    assert res.members == ((0, 0), (0, 1), (1, 0), (1, 1), (1, 2))
    assert res.rows_for(0) == (0, 1)
    assert res.rows_for(1) == (2, 3, 4)
    # Only forcing the legacy one-row-per-scenario path raises, with a
    # suggestion pointing back at the fused default.
    with pytest.raises(ValueError, match="fuse_ensembles"):
        api.simulate(api.ScenarioBatch.of([sc_a, sc_b]),
                     fuse_ensembles=False)
    # ensemble=1 batches stay legal (and unmapped) on the legacy path.
    one = api.simulate(api.ScenarioBatch.of(
        [sc_a.with_noise(1e-5, seed=1), sc_b.with_noise(1e-5, seed=2)]),
        fuse_ensembles=False)
    assert one.n_scenarios == 2
    assert one.members is None


def test_simulation_result_analysis_helpers():
    sc = (api.Scenario.on("CLX").ranks(4)
          .with_noise(6e-5, seed=0, ensemble=2)
          .step("Schoenauer", 4e6, tag="symgs")
          .step("DDOT2", 1e6, tag="ddot2")
          .barrier())
    res = api.simulate(sc, t_max=60)
    assert res.skew("ddot2").shape == (2,)
    assert len(res.durations("ddot2", 1)) == 4
    assert res.end_spread("ddot2", 0) >= 0.0
    assert res.makespan(0) > 0.0
    d = res.to_dict(tags=["ddot2"])
    json.dumps(d)  # fully json-serializable
    assert d["n_scenarios"] == 2
    assert len(d["skew"]["ddot2"]) == 2


# ---------------------------------------------------------------------------
# Result schema + export
# ---------------------------------------------------------------------------


def test_prediction_schema_carries_provenance_and_domains():
    p = api.predict(api.Scenario.on("CLX").run("DCOPY", 12)
                    .run((0.5, 100.0), 8, name="mine"))
    assert [g.provenance for g in p.groups] == ["table2", "synthetic"]
    assert len(p.domains) == 1
    assert p.total_bw == pytest.approx(sum(p.bw_group))


def test_topology_prediction_domain_breakdown():
    sc = (api.Scenario.on("CLX").using("CLX-2S")
          .placed("DCOPY", 10, "CLX/s0/d0")
          .placed("DDOT2", 10, "CLX/s1/d0"))
    p = api.predict(sc)
    assert {d.domain for d in p.domains} == {"CLX/s0/d0", "CLX/s1/d0"}
    assert p.domain_bw("CLX/s0/d0") == pytest.approx(p.bw_group[0])
    with pytest.raises(KeyError, match="did you mean"):
        p.domain_bw("CLX/s0/d1")


def test_prediction_dict_round_trip():
    p = api.predict(api.Scenario.on("CLX").run("DCOPY", 12)
                    .run("DDOT2", 8))
    d = p.to_dict()
    json.dumps(d)
    assert api.Prediction.from_dict(d) == p


def test_ndjson_round_trip_flattens_batches():
    single = api.predict(api.Scenario.on("CLX").run("DAXPY", 4))
    batch = api.predict(
        api.ScenarioBatch.split_sweep("CLX", "DCOPY", "DDOT2", 6),
        backend="numpy")
    buf = io.StringIO()
    n = api.dump_ndjson([single, batch], buf)
    assert n == 1 + len(batch)
    buf.seek(0)
    loaded = api.load_ndjson(buf)
    assert loaded[0] == single
    for i in range(len(batch)):
        assert loaded[1 + i] == batch[i]


def test_load_ndjson_rejects_other_kinds():
    buf = io.StringIO(json.dumps({"kind": "simulation"}) + "\n")
    with pytest.raises(ValueError, match="not a prediction"):
        api.load_ndjson(buf)
