"""Unit + property tests for the bandwidth-sharing model (paper Eqs. 4-5)."""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import sharing, table2
from repro.core.sharing import Group, overlapped_saturated_bw, request_shares


def test_eq4_example():
    """Hand-computed Eq. 4: thread-weighted mean."""
    g = [Group(n=6, f=0.2, bs=100.0), Group(n=4, f=0.4, bs=50.0)]
    assert overlapped_saturated_bw(g) == pytest.approx((6 * 100 + 4 * 50) / 10)


def test_eq5_fig5_example():
    """The paper's Fig. 5 setup: 6 vs 4 cores, f_II >> f_I."""
    g = [Group(n=6, f=0.1, bs=100.0), Group(n=4, f=0.8, bs=100.0)]
    a = request_shares(g)
    assert a[0] == pytest.approx(0.6 * 0.1 / (0.6 * 0.1 + 0.4 * 0.8) * 10 / 10)
    assert a[0] == pytest.approx(0.6 / (0.6 + 3.2))
    assert sum(a) == pytest.approx(1.0)
    # Kernel II queues more requests per core -> more bandwidth per core.
    pred = sharing.predict(g, saturated=True)
    assert pred.bw_per_core[1] > pred.bw_per_core[0]


def test_homogeneous_split_is_linear():
    """f_I == f_II: share is determined by thread counts alone."""
    g = [Group(n=3, f=0.25, bs=80.0), Group(n=7, f=0.25, bs=80.0)]
    pred = sharing.predict(g, saturated=True)
    assert pred.alphas[0] == pytest.approx(0.3)
    assert pred.bw_per_core[0] == pytest.approx(pred.bw_per_core[1])


def test_global_f_factor_cancels():
    """Paper Sect. V: 'a global reduction factor in f cancels out in the
    model (5)' — shares are invariant under f -> c*f."""
    g1 = [Group(n=5, f=0.30, bs=60.0), Group(n=5, f=0.20, bs=70.0)]
    g2 = [Group(n=5, f=0.15, bs=60.0), Group(n=5, f=0.10, bs=70.0)]
    p1 = sharing.predict(g1, saturated=True)
    p2 = sharing.predict(g2, saturated=True)
    assert p1.alphas == pytest.approx(p2.alphas)
    assert p1.bw_group == pytest.approx(p2.bw_group)


def test_dcopy_gains_over_ddot2():
    """Fig. 6 discussion: DCOPY (higher f) gains share when paired with
    DDOT2, and overall bandwidth drops as DCOPY threads increase (its b_s is
    lower than read-only DDOT2's)."""
    dcopy, ddot2 = table2.kernel("DCOPY"), table2.kernel("DDOT2")
    for arch, n_dom in [("BDW-1", 10), ("BDW-2", 18), ("CLX", 20), ("ROME", 8)]:
        prev_total = None
        for n_a in range(1, n_dom):
            pred = sharing.pair(dcopy, ddot2, arch, n_a, n_dom - n_a)
            share_percore_a = pred.bw_per_core[0]
            share_percore_b = pred.bw_per_core[1]
            assert share_percore_a > share_percore_b  # f_DCOPY > f_DDOT2
            if prev_total is not None:
                assert pred.total_bw <= prev_total + 1e-9
            prev_total = pred.total_bw


def test_fig9_gain_sign_follows_f_ratio():
    """Fig. 9: gain or loss vs. self-pairing follows the f ratio; the b_s
    envelope (Eq. 4) modulates the magnitude."""
    for arch in table2.ARCHS:
        for ka in table2.FIG9_KERNELS:
            for kb in table2.FIG9_KERNELS:
                a, b = table2.kernel(ka), table2.kernel(kb)
                gain = sharing.gain_vs_self(a, b, arch, 5)
                f_ratio = a.f[arch] / b.f[arch]
                bs_ratio = b.bs[arch] / a.bs[arch]
                if f_ratio > 1.05 and bs_ratio > 0.95:
                    assert gain > 1.0, (arch, ka, kb)
                if f_ratio < 0.95 and bs_ratio < 1.05:
                    assert gain < 1.0, (arch, ka, kb)


def test_unsaturated_single_core():
    """One core alone draws its single-thread bandwidth f*b_s."""
    spec = table2.kernel("STREAM")
    g = [Group.of(spec, "CLX", 1)]
    pred = sharing.predict(g)
    assert pred.bw_group[0] == pytest.approx(
        spec.f["CLX"] * spec.bs["CLX"], rel=1e-6)


def test_queue_utilization_knee():
    spec = table2.kernel("DDOT2")
    f, bs = spec.f["CLX"], spec.bs["CLX"]
    n_knee = int(1 / f) + 1
    pred = sharing.predict([Group.of(spec, "CLX", n_knee + 4)],
                           utilization="queue")
    assert pred.total_bw == pytest.approx(bs)


def test_runtime_prediction():
    g = [Group(n=2, f=0.3, bs=100.0), Group(n=2, f=0.3, bs=100.0)]
    t = sharing.runtime(g, [1e9, 2e9])
    assert t[1] == pytest.approx(2 * t[0])


def test_utilization_curve_typo_mode_suggests():
    """A typo'd mode= raises the registry's suggestion-bearing
    unknown-key error instead of silently falling through."""
    with pytest.raises(KeyError) as ei:
        sharing.utilization_curve([1, 2], 0.3, mode="recurson")
    msg = str(ei.value)
    assert "recurson" in msg
    assert "did you mean 'recursion'" in msg
    for known in sharing.UTILIZATION_MODES:
        assert known in msg


def test_utilization_curve_known_modes_accepted():
    for mode in sharing.UTILIZATION_MODES:
        u = sharing.utilization_curve([1, 4, 9], 0.3, mode=mode)
        assert ((0 <= u) & (u <= 1)).all()


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------

groups_strategy = st.lists(
    st.builds(Group,
              n=st.integers(min_value=0, max_value=64),
              f=st.floats(min_value=0.01, max_value=1.0),
              bs=st.floats(min_value=1.0, max_value=1000.0)),
    min_size=1, max_size=6,
).filter(lambda gs: sum(g.n for g in gs) > 0)


@given(groups_strategy)
@settings(max_examples=200, deadline=None)
def test_shares_sum_to_one(gs):
    a = request_shares(gs)
    if any(g.n * g.f > 0 for g in gs):
        assert sum(a) == pytest.approx(1.0)


@given(groups_strategy)
@settings(max_examples=200, deadline=None)
def test_total_bw_within_envelope(gs):
    pred = sharing.predict(gs)
    envelope = max(g.bs for g in gs)
    assert pred.total_bw <= envelope * (1 + 1e-9)
    assert all(b >= 0 for b in pred.bw_group)


@given(groups_strategy)
@settings(max_examples=200, deadline=None)
def test_eq4_envelope_bounds(gs):
    b = overlapped_saturated_bw(gs)
    nonzero = [g for g in gs if g.n]
    assert min(g.bs for g in nonzero) - 1e-9 <= b <= max(
        g.bs for g in nonzero) + 1e-9


@given(groups_strategy, st.floats(min_value=0.1, max_value=0.99))
@settings(max_examples=200, deadline=None)
def test_alpha_scale_invariance(gs, c):
    p1 = request_shares(gs)
    p2 = request_shares([Group(g.n, g.f * c, g.bs) for g in gs])
    assert p1 == pytest.approx(p2, rel=1e-9)


@given(st.integers(min_value=1, max_value=32),
       st.integers(min_value=1, max_value=32),
       st.floats(min_value=0.05, max_value=1.0),
       st.floats(min_value=0.05, max_value=1.0))
@settings(max_examples=200, deadline=None)
def test_higher_f_gets_higher_percore_share(na, nb, fa, fb):
    g = [Group(n=na, f=fa, bs=100.0), Group(n=nb, f=fb, bs=100.0)]
    pred = sharing.predict(g, saturated=True)
    if fa > fb:
        assert pred.bw_per_core[0] >= pred.bw_per_core[1]
