"""Round-trip error certification: measure → fit → predict → compare.

The paper validates its model with a Fig. 8-style error study (< 8 %
everywhere).  This module holds the *calibration* pipeline to the same
bar, with the queue simulator as ground truth:

1. **measure** — synthesize a seed ensemble of homogeneous scaling curves
   for every requested (kernel, arch) cell (:mod:`repro.calibrate.traces`);
2. **fit** — recover ``(f, b_s)`` for all cells in one batched pass
   (:mod:`repro.calibrate.fit`), timing it against a sequential per-cell
   baseline;
3. **predict** — materialize calibrated :class:`KernelSpec` objects and
   predict held-out *paired* share measurements through the ordinary
   Eq. 4–5 solver;
4. **certify** — report per-cell input-recovery error and per-kernel
   paired-share error, and fail if any exceeds the paper's 8 % bound.

``python -m repro.calibrate.certify --out BENCH_calibrate.json`` writes
the committed artifact; ``benchmarks/calibrate_roundtrip.py`` wraps the
same entry point for the benchmark driver and the slow CI job.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Sequence

import numpy as np

from ..api import ResolvedSpec, Scenario, ScenarioBatch
from ..api import predict as api_predict
from ..obs import log as obs_log
from ..core.table2 import ARCHS, TABLE2, KernelSpec
from .fit import (aggregate_ensemble, calibrated_specs, fit_scaling,
                  fit_scaling_cell)
from .traces import DOMAIN_CORES, synthesize_ensemble, \
    synthesize_pair_trace

#: The paper's global error bound (Fig. 8): model within 8 % everywhere.
ERROR_BOUND = 0.08


@dataclasses.dataclass(frozen=True)
class CellError:
    """Input-recovery error of one (kernel, arch) cell."""

    kernel: str
    arch: str
    f_true: float
    f_fit: float
    bs_true: float
    bs_fit: float

    @property
    def f_err(self) -> float:
        return abs(self.f_fit - self.f_true) / self.f_true

    @property
    def bs_err(self) -> float:
        return abs(self.bs_fit - self.bs_true) / self.bs_true


@dataclasses.dataclass(frozen=True)
class PairError:
    """Held-out paired-share prediction error (per kernel of the pair)."""

    kernels: tuple[str, str]
    arch: str
    n: tuple[int, int]
    measured: tuple[float, float]   # memsim ground truth [GB/s]
    predicted: tuple[float, float]  # Eq. 4–5 with calibrated specs

    @property
    def errs(self) -> tuple[float, float]:
        return tuple(abs(p - m) / m if m > 0 else 0.0
                     for p, m in zip(self.predicted, self.measured))


@dataclasses.dataclass
class CertificationReport:
    cells: list[CellError]
    pairs: list[PairError]
    intervals: dict                 # {(kernel, arch): {"f": ..., "bs": ...}}
    n_traces: int
    n_seeds: int
    noise: float
    backend: str
    wall_batched_s: float
    wall_sequential_s: float

    @property
    def max_f_err(self) -> float:
        return max((c.f_err for c in self.cells), default=0.0)

    @property
    def max_bs_err(self) -> float:
        return max((c.bs_err for c in self.cells), default=0.0)

    @property
    def max_pair_err(self) -> float:
        return max((e for p in self.pairs for e in p.errs), default=0.0)

    @property
    def speedup(self) -> float:
        if self.wall_batched_s <= 0:
            return float("inf")
        return self.wall_sequential_s / self.wall_batched_s

    def ok(self, bound: float = ERROR_BOUND) -> bool:
        return (self.max_f_err < bound and self.max_bs_err < bound
                and self.max_pair_err < bound)

    def worst_cells(self, k: int = 5) -> list[CellError]:
        return sorted(self.cells,
                      key=lambda c: max(c.f_err, c.bs_err))[-k:][::-1]

    def to_json_dict(self) -> dict:
        return {
            "benchmark": "calibrate_roundtrip",
            "error_bound": ERROR_BOUND,
            "ok": self.ok(),
            "n_traces": self.n_traces,
            "n_seeds": self.n_seeds,
            "noise": self.noise,
            "backend": self.backend,
            "max_f_err": self.max_f_err,
            "max_bs_err": self.max_bs_err,
            "max_pair_err": self.max_pair_err,
            "fit_wall_s": {
                "batched": self.wall_batched_s,
                "sequential_baseline": self.wall_sequential_s,
                "speedup_x": self.speedup,
            },
            "cells": [{
                "kernel": c.kernel, "arch": c.arch,
                "f_true": c.f_true, "f_fit": c.f_fit,
                "f_err": c.f_err, "bs_true": c.bs_true,
                "bs_fit": c.bs_fit, "bs_err": c.bs_err,
            } for c in self.cells],
            "pairs": [{
                "kernels": list(p.kernels), "arch": p.arch,
                "n": list(p.n), "measured": list(p.measured),
                "predicted": list(p.predicted), "errs": list(p.errs),
            } for p in self.pairs],
            "intervals": {
                f"{k}/{a}": {
                    field: {"value": v.value, "lo": v.lo, "hi": v.hi,
                            "n_seeds": v.n_seeds}
                    for field, v in cell.items()
                } for (k, a), cell in sorted(self.intervals.items())
            },
        }


def _holdout_pairs(kernels: Sequence[str], archs: Sequence[str],
                   per_arch: int, truth: dict[str, KernelSpec]
                   ) -> list[tuple[str, str, str, int, int]]:
    """A deterministic rotation of kernel pairings and domain splits.
    Pairings are heterogeneous whenever two distinct kernels are
    available (a self-pair would re-test the fitted homogeneous curve
    rather than a held-out mix)."""
    out = []
    ks = [k for k in kernels if k in truth]
    if not ks or per_arch <= 0:
        return out
    for ai, arch in enumerate(archs):
        n_dom = DOMAIN_CORES[arch]
        for j in range(per_arch):
            ia = (ai + j) % len(ks)
            # offset in [1, len-1] -> always a distinct partner when one
            # exists; a single-kernel grid degenerates to a self-pair.
            ib = (ia + 1 + j % max(1, len(ks) - 1)) % len(ks)
            n_a = max(1, (j + 1) * n_dom // (per_arch + 1))
            out.append((ks[ia], ks[ib], arch, n_a, max(1, n_dom - n_a)))
    return out


def certify(kernels: Sequence[str] | None = None,
            archs: Sequence[str] | None = None, *,
            seeds: Sequence[int] = (0, 1, 2), noise: float = 0.02,
            n_events: int = 12_000, pairs_per_arch: int = 4,
            utilization: str = "queue", backend: str = "auto",
            specs: dict[str, KernelSpec] | None = None,
            sequential_baseline: bool = True) -> CertificationReport:
    """Run the full measure→fit→predict round trip; see module doc.

    Defaults cover **every** Table II kernel × architecture cell with a
    3-seed ensemble — the acceptance grid.  ``specs`` overrides the
    ground-truth table (used by tests to certify synthetic kernels).
    """
    truth = dict(TABLE2 if specs is None else specs)
    kernels = sorted(truth) if kernels is None else list(kernels)
    archs = list(ARCHS) if archs is None else list(archs)

    # 1. measure — the (kernel × arch × seed) trace grid.
    traces = synthesize_ensemble(kernels, archs, seeds, noise=noise,
                                 n_events=n_events, specs=truth)

    # 2. fit — one batched pass, then the per-cell loop it replaces.
    # Warm both paths once untimed so jit compilation (amortized across
    # repeated certifications) does not skew the comparison.
    fit = fit_scaling(traces, utilization=utilization, backend=backend)
    seen_shapes: set[int] = set()
    for tr in traces.scaling:
        if len(tr.cores) not in seen_shapes:
            seen_shapes.add(len(tr.cores))
            fit_scaling_cell(tr, utilization=utilization,
                             backend=fit.backend)
    t0 = time.perf_counter()
    fit = fit_scaling(traces, utilization=utilization,
                      backend=fit.backend)
    wall_batched = time.perf_counter() - t0
    wall_seq = 0.0
    if sequential_baseline:
        t0 = time.perf_counter()
        for tr in traces.scaling:
            fit_scaling_cell(tr, utilization=utilization,
                             backend=fit.backend)
        wall_seq = time.perf_counter() - t0

    # 3. aggregate + materialize calibrated specs.
    intervals = aggregate_ensemble(fit)
    cal = calibrated_specs(fit, templates=truth)
    cells = [CellError(
        kernel=k, arch=a,
        f_true=truth[k].f[a], f_fit=cal[k].f[a],
        bs_true=truth[k].bs[a], bs_fit=cal[k].bs[a])
        for k in kernels for a in archs]

    # 4. held-out paired shares: measured with *true* specs, predicted
    # with *calibrated* specs — declared as one facade scenario batch and
    # solved in one batched Eq. 4–5 call (same math as fit.predict_pairs,
    # with the calibration provenance recorded on every group).
    held_out = _holdout_pairs(kernels, archs, pairs_per_arch, truth)
    pair_traces = [synthesize_pair_trace(ka, kb, arch, na, nb,
                                         seed=17 + i, n_events=n_events,
                                         specs=truth)
                   for i, (ka, kb, arch, na, nb) in enumerate(held_out)]
    labeled = {k: ResolvedSpec(spec=s, provenance="calibrated")
               for k, s in cal.items()}
    scens = [Scenario.on(pt.arch, utilization=utilization)
             .run(labeled[pt.kernels[0]], pt.n[0])
             .run(labeled[pt.kernels[1]], pt.n[1])
             for pt in pair_traces]
    predicted = (api_predict(ScenarioBatch.of(scens)).bw_group
                 if scens else np.zeros((0, 2)))
    pair_errors = [PairError(
        kernels=pt.kernels, arch=pt.arch, n=pt.n,
        measured=pt.bandwidth,
        predicted=(float(predicted[i, 0]), float(predicted[i, 1])))
        for i, pt in enumerate(pair_traces)]

    return CertificationReport(
        cells=cells, pairs=pair_errors, intervals=intervals,
        n_traces=len(traces), n_seeds=len(seeds), noise=noise,
        backend=fit.backend, wall_batched_s=wall_batched,
        wall_sequential_s=wall_seq)


def cross_check_static(report: CertificationReport | None = None, *,
                       arch: str = "CLX") -> list[dict]:
    """Static-analysis cross-check: the jaxpr-derived loop features of
    every in-repo Table II kernel against the paper's transcribed
    counts, both pushed through the same ECM bridge
    (:func:`repro.analysis.report.cross_check`).

    When a :class:`CertificationReport` is supplied, each row also
    carries the round-trip *calibrated* ``f`` for its cell as a
    diagnostic column (``f_calibrated``) — the gate itself compares the
    two model-bridged values only, because ECM-predicted and
    measured/fitted ``f`` differ by design (docs/known-issues.md).
    """
    from ..analysis.report import cross_check
    rows = cross_check(arch)
    if report is not None:
        fitted = {(c.kernel, c.arch): c.f_fit for c in report.cells}
        for r in rows:
            r["f_calibrated"] = fitted.get((r["table"], arch))
    return rows


#: Reduced certification grid shared by ``--quick`` runs and the
#: benchmark driver's rows().
QUICK_GRID = dict(kernels=("DCOPY", "DDOT2", "DAXPY", "JacobiL3-v1"),
                  archs=("CLX", "ROME"), seeds=(0, 1), n_events=8_000)


def certify_quick(*, backend: str = "auto") -> CertificationReport:
    """The reduced smoke-test grid (one source of truth for every
    quick entry point)."""
    g = QUICK_GRID
    return certify(list(g["kernels"]), list(g["archs"]),
                   seeds=g["seeds"], n_events=g["n_events"],
                   backend=backend)


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_calibrate.json",
                    help="JSON artifact path")
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid (see QUICK_GRID)")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "numpy", "jax"))
    ap.add_argument("--static", action="store_true",
                    help="also cross-check jaxpr-derived features "
                         "against Table II / the calibrated cells")
    ap.add_argument("--static-arch", default="CLX",
                    help="architecture for the --static cross-check")
    args = ap.parse_args(argv)
    report = (certify_quick(backend=args.backend) if args.quick
              else certify(backend=args.backend))
    out = report.to_json_dict()
    static_ok = True
    if args.static:
        rows = cross_check_static(report, arch=args.static_arch)
        static_ok = all(r["ok"] for r in rows)
        out["static_cross_check"] = {"arch": args.static_arch,
                                     "ok": static_ok, "rows": rows}
        max_err = max(r["f_err"] for r in rows)
        obs_log.emit(f"static cross-check ({args.static_arch}): "
                     f"{len(rows)} cells  max f err {max_err:.2%}  "
                     f"(ok={static_ok})",
                     event="calibrate.certify.static",
                     arch=args.static_arch, cells=len(rows),
                     max_f_err=max_err, ok=static_ok)
        for r in rows:
            if not r["ok"]:
                obs_log.emit(f"  static FAIL: {r['label']} derived "
                             f"{r['static']} vs Table II {r['table2']} "
                             f"(f err {r['f_err']:.2%}, bound "
                             f"{r['bound']:.0%})",
                             event="calibrate.certify.static_fail",
                             label=r["label"], f_err=r["f_err"])
    with open(args.out, "w") as fh:
        json.dump(out, fh, indent=2)
    obs_log.emit(f"cells={len(report.cells)}  traces={report.n_traces}  "
                 f"backend={report.backend}",
                 event="calibrate.certify.grid",
                 cells=len(report.cells), traces=report.n_traces,
                 backend=report.backend)
    obs_log.emit(f"max err: f {report.max_f_err:.2%}  "
                 f"bs {report.max_bs_err:.2%}"
                 f"  pairs {report.max_pair_err:.2%}  "
                 f"(bound {ERROR_BOUND:.0%})",
                 event="calibrate.certify.errors",
                 max_f_err=report.max_f_err, max_bs_err=report.max_bs_err,
                 max_pair_err=report.max_pair_err, bound=ERROR_BOUND)
    obs_log.emit(f"batched fit {report.wall_batched_s * 1e3:.1f} ms vs "
                 f"sequential per-cell "
                 f"{report.wall_sequential_s * 1e3:.1f} ms "
                 f"->  {report.speedup:.1f}x",
                 event="calibrate.certify.timing",
                 wall_batched_s=report.wall_batched_s,
                 wall_sequential_s=report.wall_sequential_s,
                 speedup=report.speedup)
    for c in report.worst_cells(3):
        obs_log.emit(f"  worst cell: {c.kernel}/{c.arch}  f {c.f_err:.2%}  "
                     f"bs {c.bs_err:.2%}",
                     event="calibrate.certify.worst_cell",
                     kernel=c.kernel, arch=c.arch,
                     f_err=c.f_err, bs_err=c.bs_err)
    obs_log.emit(f"wrote {args.out}  (ok={report.ok()})",
                 event="calibrate.certify.artifact",
                 path=args.out, ok=report.ok())
    return 0 if (report.ok() and static_ok) else 1


if __name__ == "__main__":
    raise SystemExit(main())
