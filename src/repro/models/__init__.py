"""Model zoo: one factory covering all 10 assigned architectures.

``model_for(cfg)`` returns a :class:`Model` facade with a uniform
interface; the runtime (train/serve step builders, dry-run) never touches
family-specific code.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import mamba2, rglru, transformer, whisper


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, dict], tuple[jax.Array, dict]]
    init_cache: Callable[..., Any]
    decode_step: Callable[..., tuple[jax.Array, Any]]

    def input_specs(self, shape: ShapeConfig, *,
                    batch_override: int | None = None) -> dict:
        """ShapeDtypeStruct stand-ins for one step's inputs (no allocation)."""
        cfg = self.cfg
        b = batch_override or shape.global_batch
        s = shape.seq_len
        i32 = jnp.int32
        if shape.kind in ("train", "prefill"):
            specs = {
                "tokens": jax.ShapeDtypeStruct((b, s), i32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
            if cfg.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_audio_frames, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            if cfg.family == "vlm":
                specs["patches"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
            return specs
        # decode: one new token against a seq_len-deep cache
        return {
            "tokens": jax.ShapeDtypeStruct((b,), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32),
            "cache": jax.eval_shape(
                lambda: self.init_cache(b, s)),
        }


def _lm_batch_adapter(cfg: ModelConfig, loss_fn):
    def loss(params, batch):
        return loss_fn(cfg, params, batch)
    return loss


def model_for(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return Model(
            cfg=cfg,
            init=lambda key: transformer.init_params(cfg, key),
            loss=_lm_batch_adapter(cfg, transformer.loss_fn),
            init_cache=lambda b, s: transformer.init_cache(cfg, b, s),
            decode_step=lambda params, cache, tokens, pos:
                transformer.decode_step(cfg, params, cache, tokens, pos),
        )
    if fam == "ssm":
        return Model(
            cfg=cfg,
            init=lambda key: mamba2.init_params(cfg, key),
            loss=_lm_batch_adapter(cfg, mamba2.loss_fn),
            init_cache=lambda b, s=0: mamba2.init_cache(cfg, b, s),
            decode_step=lambda params, cache, tokens, pos:
                mamba2.decode_step(cfg, params, cache, tokens, pos),
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            init=lambda key: rglru.init_params(cfg, key),
            loss=_lm_batch_adapter(cfg, rglru.loss_fn),
            init_cache=lambda b, s: rglru.init_cache(cfg, b, s),
            decode_step=lambda params, cache, tokens, pos:
                rglru.decode_step(cfg, params, cache, tokens, pos),
        )
    if fam == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: whisper.init_params(cfg, key),
            loss=_lm_batch_adapter(cfg, whisper.loss_fn),
            init_cache=lambda b, s: whisper.init_cache(cfg, b, s),
            decode_step=lambda params, cache, tokens, pos:
                whisper.decode_step(cfg, params, cache, tokens, pos),
        )
    raise ValueError(f"unknown family {fam!r}")


__all__ = ["Model", "model_for", "mamba2", "rglru", "transformer", "whisper"]
