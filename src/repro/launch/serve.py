"""Model-decode demo launcher: batched greedy decoding with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --batch 4 --steps 32

Naming note: this is the *transformer inference* demo (decode-loop
latency for the reference models).  The library's serving **subsystem**
— prediction-as-a-service over the paper's bandwidth-sharing model,
with plan caching and request coalescing — is :mod:`repro.serve`,
started with ``python -m repro.serve --port ...`` (docs/serving.md).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model_for
from repro.runtime import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.obs import log as obs_log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    model = model_for(cfg)
    mesh = make_host_mesh()

    params = model.init(jax.random.key(0))
    cache = model.init_cache(args.batch, args.max_seq)
    params_shape = jax.eval_shape(lambda: params)
    cache_shape = jax.eval_shape(lambda: cache)
    step, pshard, cshard, tok_sh = steps_lib.jit_serve_step(
        model, mesh, params_shape, cache_shape, batch=args.batch)
    params = jax.device_put(params, pshard)
    cache = jax.device_put(cache, cshard)

    tokens = jnp.zeros((args.batch,), jnp.int32)
    pos = jnp.zeros((args.batch,), jnp.int32)
    generated = []
    t0 = time.time()
    for t in range(args.steps):
        logits, cache = step(params, cache, tokens, pos)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        pos = pos + 1
        generated.append(tokens)
    jax.block_until_ready(tokens)
    dt = (time.time() - t0) / args.steps
    toks = jnp.stack(generated, axis=1)
    obs_log.emit(f"decoded {args.steps} tokens x {args.batch} seqs "
                 f"({dt*1e3:.1f} ms/token)",
                 event="launch.serve.decoded", steps=args.steps,
                 batch=args.batch, ms_per_token=dt * 1e3)
    obs_log.emit(f"sample: {toks[0][:16].tolist()}",
                 event="launch.serve.sample",
                 tokens=toks[0][:16].tolist())


if __name__ == "__main__":
    main()
