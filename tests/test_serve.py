"""The serving subsystem: plan cache, request coalescer, transport.

Acceptance gates of the serving PR: coalesced responses must be
bit-for-bit equal to per-request ``api.predict``/``plan.run`` on both
backends; admission control must reject over-queue submits (429) and
expire past-deadline requests (504); graceful drain must leave no
dropped futures; the plan cache must hit (rate 1.0) on
repeated-structure workloads and evict LRU-first; and
``cache_stats(scope=...)`` must report the jit and plan caches without
double-counting.  The coalescer tests are socket-free (``asyncio.run``
directly); one HTTP test drives the full wire path.
"""

import asyncio
import json
import threading

import numpy as np
import pytest

from repro import api
from repro.core import backend
from repro.obs import trace
from repro.serve import (App, BadRequest, Coalescer, DeadlineExceeded,
                         Draining, PlanCache, QueueFull, ServeConfig,
                         build_response, error_response, parse_request,
                         plan_cache_stats)

BACKENDS = ["numpy"] + (["jax"] if backend.HAVE_JAX else [])

D0, D1 = "CLX/s0/d0", "CLX/s1/d0"


def _scenarios(b, bk="numpy"):
    """b same-structure scenarios with distinct numeric payloads."""
    return [api.Scenario.on("CLX", backend=bk, jax_cutoff=1)
            .run("DCOPY", 1 + i % 19).run("DDOT2", 20 - i % 19)
            for i in range(b)]


def _assert_same_prediction(got, ref):
    np.testing.assert_array_equal(got.bw_group, ref.bw_group)
    np.testing.assert_array_equal(got.alphas, ref.alphas)
    np.testing.assert_array_equal(got.b_overlap, ref.b_overlap)
    assert got.total_bw == ref.total_bw
    assert [g.provenance for g in got.groups] == \
        [g.provenance for g in ref.groups]


# ---------------------------------------------------------------------------
# coalesced == per-request, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bk", BACKENDS)
def test_coalesced_bit_for_bit(bk):
    scs = _scenarios(16, bk)

    async def main():
        async with Coalescer(ServeConfig(tick_s=1e-3)) as c:
            got = await asyncio.gather(*[c.submit(sc) for sc in scs])
            return got, c.cache.stats(), c.stats()

    got, cache, stats = asyncio.run(main())
    # The per-request reference: api.predict of each scenario, solved
    # as the same batch the coalescer packed (compile(...).run() of the
    # concurrent request set) — same backend, same power-of-two bucket,
    # so equality is exact, not approximate.
    refs = api.predict(api.ScenarioBatch.of(scs))
    for i, g in enumerate(got):
        _assert_same_prediction(g, refs[i])
    # ...and against the scalar reference solver, which the numpy batch
    # path reproduces bit-for-bit (the jitted jax path is allowed the
    # usual 1-ULP compiler latitude).
    for sc, g in zip(scs, got):
        ref = api.predict(sc)
        if bk == "numpy":
            _assert_same_prediction(g, ref)
        else:
            np.testing.assert_allclose(g.bw_group, ref.bw_group,
                                       rtol=1e-13)
    # One structure -> one plan compile, one batched solve.
    assert cache["misses"] == 1
    assert stats["accepted"] == stats["completed"] == 16


@pytest.mark.parametrize("bk", BACKENDS)
def test_repeated_structure_hits_cache(bk):
    scs = _scenarios(8, bk)

    async def main():
        async with Coalescer(ServeConfig(tick_s=1e-3)) as c:
            first = await asyncio.gather(*[c.submit(sc) for sc in scs])
            second = await asyncio.gather(*[c.submit(sc) for sc in scs])
            return first, second, c.cache.stats()

    first, second, cache = asyncio.run(main())
    for a, b in zip(first, second):
        _assert_same_prediction(a, b)
    assert cache["misses"] == 1 and cache["hits"] >= 1


def test_mixed_structures_split_groups():
    a = api.Scenario.on("CLX").run("DCOPY", 12).run("DDOT2", 8)
    b = api.Scenario.on("CLX").run("JacobiL2-v1", 7)
    c_ = api.Scenario.on("CLX").run("DCOPY", 3).run("DDOT2", 17)

    async def main():
        async with Coalescer(ServeConfig(tick_s=1e-3)) as c:
            return await asyncio.gather(
                c.submit(a), c.submit(b), c.submit(c_)), c.cache.stats()

    (ra, rb, rc), cache = asyncio.run(main())
    _assert_same_prediction(ra, api.predict(a))
    _assert_same_prediction(rb, api.predict(b))
    _assert_same_prediction(rc, api.predict(c_))
    # a and c_ share a structure (and a plan); b has its own.
    assert cache["entries"] == 2


def test_placed_bit_for_bit():
    scs = [api.Scenario.on("CLX").using("CLX-2S")
           .placed("DCOPY", 2 + i, D0).placed("DDOT2", 18 - i, D1)
           for i in range(6)]

    async def main():
        async with Coalescer(ServeConfig(tick_s=1e-3)) as c:
            return await asyncio.gather(*[c.submit(sc) for sc in scs])

    got = asyncio.run(main())
    for sc, g in zip(scs, got):
        ref = api.predict(sc)
        _assert_same_prediction(g, ref)
        assert [d.domain for d in g.domains] == \
            [d.domain for d in ref.domains]


def test_simulate_shared_and_bit_for_bit():
    sim = (api.Scenario.on("CLX").ranks(4).with_noise(6e-5, ensemble=2)
           .step("DDOT2", 2e6, tag="ddot2").barrier())
    other = sim.with_noise(6e-5, seed=7, ensemble=2)

    async def main():
        async with Coalescer(ServeConfig(tick_s=1e-3)) as c:
            return await asyncio.gather(
                c.submit(sim), c.submit(sim), c.submit(other))

    s1, s2, s3 = asyncio.run(main())
    assert s1 is s2          # identical structure -> one shared run
    ref = api.simulate(sim)
    np.testing.assert_array_equal(s1.t_end, ref.t_end)
    np.testing.assert_array_equal(s1.skew("ddot2"), ref.skew("ddot2"))
    # A different seed is a different structure key -> its own run.
    np.testing.assert_array_equal(s3.t_end, api.simulate(other).t_end)
    assert not np.array_equal(s3.t_end, s1.t_end)


# ---------------------------------------------------------------------------
# admission control, deadlines, drain
# ---------------------------------------------------------------------------


def test_deadline_expired_requests_fail_504():
    sc = _scenarios(1)[0]

    async def main():
        async with Coalescer(ServeConfig(tick_s=1e-2)) as c:
            ok_task = asyncio.ensure_future(c.submit(sc))
            with pytest.raises(DeadlineExceeded):
                await c.submit(sc, deadline_s=0.0)
            ok = await ok_task          # live request still solved
            return ok, c.stats()

    ok, stats = asyncio.run(main())
    _assert_same_prediction(ok, api.predict(sc))
    assert stats["expired"] == 1 and stats["completed"] == 1
    assert DeadlineExceeded.status == 504


def test_queue_full_rejects_429_and_drain_completes():
    scs = _scenarios(3)

    async def main():
        c = Coalescer(ServeConfig(tick_s=5.0, max_queue=2))
        t1 = asyncio.ensure_future(c.submit(scs[0]))
        t2 = asyncio.ensure_future(c.submit(scs[1]))
        await asyncio.sleep(0.05)       # both queued, tick window open
        with pytest.raises(QueueFull):
            await c.submit(scs[2])
        # Graceful drain: close() cuts the 5 s window short and the
        # queued requests still complete.
        await c.close(drain=True)
        return await t1, await t2, c.stats()

    r1, r2, stats = asyncio.run(main())
    _assert_same_prediction(r1, api.predict(scs[0]))
    _assert_same_prediction(r2, api.predict(scs[1]))
    assert stats["rejected"] == 1 and stats["completed"] == 2
    assert QueueFull.status == 429


def test_drain_leaves_no_dropped_futures():
    scs = _scenarios(32)

    async def main():
        c = Coalescer(ServeConfig(tick_s=0.2))
        tasks = [asyncio.ensure_future(c.submit(sc)) for sc in scs]
        await asyncio.sleep(0)          # enqueue, don't let the tick end
        await c.close(drain=True)
        return await asyncio.gather(*tasks), c.stats()

    got, stats = asyncio.run(main())
    assert stats["completed"] == 32
    for sc, g in zip(scs, got):
        _assert_same_prediction(g, api.predict(sc))


def test_close_without_drain_fails_pending_and_rejects_new():
    scs = _scenarios(4)

    async def main():
        c = Coalescer(ServeConfig(tick_s=0.5))
        tasks = [asyncio.ensure_future(c.submit(sc)) for sc in scs]
        await asyncio.sleep(0)
        await c.close(drain=False)
        results = await asyncio.gather(*tasks, return_exceptions=True)
        with pytest.raises(Draining):
            await c.submit(scs[0])
        return results

    results = asyncio.run(main())
    assert len(results) == 4
    assert all(isinstance(r, Draining) for r in results)


# ---------------------------------------------------------------------------
# plan cache: LRU, warmup, stats scopes
# ---------------------------------------------------------------------------


def test_plan_cache_lru_eviction():
    cache = PlanCache(max_entries=2)
    built = []

    def make(i):
        return lambda: built.append(i) or i

    assert cache.get_or_build(("a",), make("a")) == "a"
    assert cache.get_or_build(("b",), make("b")) == "b"
    assert cache.get_or_build(("a",), make("a2")) == "a"   # refresh a
    assert cache.get_or_build(("c",), make("c")) == "c"    # evicts b
    assert cache.get_or_build(("a",), make("a3")) == "a"   # a survived
    assert cache.get_or_build(("b",), make("b2")) == "b2"  # b was evicted
    st = cache.stats()
    assert st["entries"] == 2 and st["evictions"] == 2
    assert built == ["a", "b", "c", "b2"]


def test_warmup_gives_hit_rate_one():
    template = _scenarios(1)[0]
    cache = PlanCache()
    built = cache.warmup(template, buckets=(1, 5))   # buckets 1 and 8
    assert built == 2 and len(cache) == 2
    scs = _scenarios(6)     # bucket(6) == 8: warmed

    async def main():
        async with Coalescer(ServeConfig(tick_s=1e-3),
                             cache=cache) as c:
            return await asyncio.gather(*[c.submit(sc) for sc in scs])

    got = asyncio.run(main())
    for sc, g in zip(scs, got):
        _assert_same_prediction(g, api.predict(sc))
    st = cache.stats()
    assert st["misses"] == 2          # only the warmup compiles
    assert st["hits"] >= 1            # the live tick was a pure hit
    # Warming again is free: every bucket already cached.
    assert cache.warmup(template, buckets=(1, 5)) == 0


def test_cache_stats_scope_selector():
    backend.clear_jit_cache()         # reset metrics for exact counts
    cache = PlanCache()
    cache.get_or_build(("x",), lambda: "x", label="L")
    cache.get_or_build(("x",), lambda: "x", label="L")
    jit = backend.cache_stats()       # default: the historical shape
    assert set(jit) == {"hits", "misses", "entries", "hit_rate",
                        "buckets"}
    plan = backend.cache_stats(scope="plan")
    assert plan["hits"] == 1 and plan["misses"] == 1
    assert plan["buckets"]["L"]["hits"] == 1
    assert plan == plan_cache_stats()
    both = backend.cache_stats(scope="all")
    assert set(both) >= {"jit", "plan"}
    # No double-counting: each scope owns disjoint counters.
    assert both["jit"] == jit and both["plan"]["hits"] == 1
    assert "serve.plan.hit" not in json.dumps(jit)
    with pytest.raises(KeyError, match="cache scope"):
        backend.cache_stats(scope="nope")


# ---------------------------------------------------------------------------
# structure keys
# ---------------------------------------------------------------------------


def test_structure_key_contract():
    a, b = _scenarios(2)
    assert a.runs[0].n != b.runs[0].n
    # predict: numbers are swappable, not structural.
    assert api.structure_key(a) == api.structure_key(b)
    other = api.Scenario.on("CLX").run("JacobiL2-v1", 7)
    assert api.structure_key(a) != api.structure_key(other)
    assert api.structure_key(a) != \
        api.structure_key(a.options(utilization=0.7))
    # simulate: numbers (and seeds) are structural.
    sim = (api.Scenario.on("CLX").ranks(2)
           .step("DDOT2", 2e6).barrier())
    assert api.structure_key(sim) != \
        api.structure_key(sim.with_noise(5e-5, seed=3))
    assert api.infer_verb(sim) == "simulate"
    assert api.infer_verb(a) == "predict"
    batch = api.ScenarioBatch.of([a, b])
    assert api.structure_key(batch) == \
        (api.structure_key(a), api.structure_key(b))
    with pytest.raises(ValueError, match="verb"):
        api.structure_key(a, verb="banana")


@pytest.mark.parametrize("bk", BACKENDS)
def test_batch_rows_match_getitem(bk):
    # The serving fan-out uses BatchPrediction.rows() (one bulk tolist
    # pass); it must be indistinguishable from per-row __getitem__.
    scs = _scenarios(5, bk)
    pred = api.predict(api.ScenarioBatch.of(scs))
    rows = pred.rows()
    assert len(rows) == len(pred) == 5
    for i in range(len(pred)):
        assert rows[i] == pred[i]
        assert repr(rows[i]) == repr(pred[i])
    assert pred.rows(2) == [pred[0], pred[1]]


# ---------------------------------------------------------------------------
# obs: correlated spans across the stack
# ---------------------------------------------------------------------------


def test_request_spans_correlate():
    trace.enable(clear_events=True)
    try:
        scs = _scenarios(4)

        async def main():
            async with Coalescer(ServeConfig(tick_s=1e-3)) as c:
                await asyncio.gather(*[c.submit(sc) for sc in scs])

        asyncio.run(main())
    finally:
        trace.disable()
    names = [e[1] for e in trace.events()]
    assert names.count("serve.accept") == 4
    assert "serve.coalesce" in names and "api.plan.run" in names
    by_name = {e[1]: e for e in trace.events()}
    # plan.run nests inside the coalescing span (same thread, deeper).
    assert by_name["api.plan.run"][5] > by_name["serve.coalesce"][5]
    trace.clear()


# ---------------------------------------------------------------------------
# protocol: parse/build
# ---------------------------------------------------------------------------


def test_protocol_parse_and_response():
    req = parse_request({
        "id": 7, "arch": "CLX", "deadline_ms": 250,
        "groups": [{"kernel": "DCOPY", "n": 12},
                   {"kernel": [0.5, 110.0], "n": 8, "tag": "custom"}]})
    assert req.verb == "predict" and req.deadline_s == 0.25
    pred = api.predict(req.scenario)
    out = build_response(req, pred, 0.002)
    assert out["id"] == 7 and out["ok"] and out["kind"] == "prediction"
    assert out["total_bw"] == pred.total_bw and out["serve_ms"] == 2.0

    sim = parse_request({
        "arch": "CLX", "ranks": 4, "t_max": 5, "tags": ["ddot2"],
        "noise": {"exp_mean_s": 6e-5, "ensemble": 2},
        "steps": [{"op": "work", "kernel": "DDOT2", "bytes": 2e6,
                   "tag": "ddot2"}, {"op": "barrier"}]})
    assert sim.verb == "simulate" and sim.scenario.t_max == 5.0
    body = build_response(sim, api.simulate(sim.scenario), 0.01)
    assert body["kind"] == "simulation" and "ddot2" in body["skew"]


@pytest.mark.parametrize("bad,match", [
    ({}, "missing required field 'arch'"),
    ({"arch": "CLX", "bogus": 1}, "unknown request fields"),
    ({"arch": "CLX", "groups": [{"kernel": {"x": 1}, "n": 2}]},
     "kernel must be"),
    ({"arch": "CLX", "kind": "guess"}, "kind must be"),
    ({"arch": "CLX", "ranks": 2, "steps": [{"op": "warp"}]},
     "unknown op"),
    ({"arch": "CLX", "options": {"nope": 1}}, "unknown scenario options"),
])
def test_protocol_rejects_bad_requests(bad, match):
    with pytest.raises(BadRequest, match=match):
        parse_request(bad)
    assert BadRequest.status == 400


def test_error_response_envelope():
    out = error_response(3, DeadlineExceeded("too slow"))
    assert out == {"id": 3, "ok": False, "kind": "error", "status": 504,
                   "error": "too slow"}


# ---------------------------------------------------------------------------
# the wire: one full HTTP round trip
# ---------------------------------------------------------------------------


class _Server:
    """App on a background thread with its own loop (the client side of
    the test is blocking http.client)."""

    def __enter__(self):
        self.loop = asyncio.new_event_loop()
        ready = threading.Event()
        self.box = {}

        def run():
            asyncio.set_event_loop(self.loop)

            async def go():
                self.app = App(ServeConfig(tick_s=1e-3))
                self.box["stop"] = asyncio.Event()
                self.port = await self.app.start(port=0)
                ready.set()
                await self.box["stop"].wait()
                await self.app.shutdown(drain=True)

            self.loop.run_until_complete(go())
            self.loop.close()

        self.thread = threading.Thread(target=run, daemon=True)
        self.thread.start()
        assert ready.wait(10), "server failed to start"
        return self

    def __exit__(self, *exc):
        self.loop.call_soon_threadsafe(self.box["stop"].set)
        self.thread.join(10)
        assert not self.thread.is_alive(), "server failed to shut down"


def test_http_round_trip_streams_in_order():
    from repro.serve import client

    rows = [{"id": i, "arch": "CLX",
             "groups": [{"kernel": "DCOPY", "n": 1 + i},
                        {"kernel": "DDOT2", "n": 19 - i}]}
            for i in range(10)]
    rows.insert(5, {"id": "bad", "arch": "CLX",
                    "groups": [{"kernel": "NOPE", "n": 2}]})
    with _Server() as srv:
        status, health = client.get_json("127.0.0.1", srv.port,
                                         "/healthz")
        assert status == 200 and health["ok"]
        out = client.solve("127.0.0.1", srv.port, rows)
        # Streamed in request order, bad line isolated.
        assert [r["id"] for r in out] == [r["id"] for r in rows]
        bad = out[5]
        assert not bad["ok"] and bad["status"] == 400
        assert "NOPE" in bad["error"]
        for r in (x for x in out if x["ok"]):
            sc = api.Scenario.on("CLX").run("DCOPY", 1 + r["id"]) \
                .run("DDOT2", 19 - r["id"])
            assert r["total_bw"] == api.predict(sc).total_bw
        status, stats = client.get_json("127.0.0.1", srv.port,
                                        "/statsz")
        assert status == 200
        assert stats["coalescer"]["accepted"] == 10
        assert stats["plan_cache"]["entries"] >= 1
        assert set(stats["caches"]) >= {"jit", "plan"}
        status, err = client.get_json("127.0.0.1", srv.port, "/wat")
        assert status == 404 and not err["ok"]
    # Exiting the context asserts a clean drain/shutdown.
