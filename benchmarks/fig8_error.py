"""Paper Fig. 8: modeling-error overview — 30+ pairings x 4 architectures,
symmetric scaling.  Error = |(b_sim - b_model) / b_model| per kernel per
configuration; we report median / p75 / max per architecture, matching the
paper's box-plot summary (paper: <8% globally, <5% for 75% of cases)."""

from __future__ import annotations

import itertools
import statistics
import time

from repro import api
from repro.core import memsim, sharing, table2

DOMAIN = {"BDW-1": 10, "BDW-2": 18, "CLX": 20, "ROME": 8}


def errors_for(arch: str, n_events=20_000):
    n_dom = DOMAIN[arch]
    pairs = list(itertools.combinations(table2.FIG9_KERNELS, 2))  # 45 > 30
    configs = [(ka, kb, n) for ka, kb in pairs
               for n in (2, n_dom // 4, n_dom // 2) if n > 0]
    # Model: every (pairing, split) of this arch in ONE facade batch.
    batch = api.predict(api.ScenarioBatch.of(
        [api.Scenario.on(arch, utilization="queue")
         .run(ka, n).run(kb, n) for ka, kb, n in configs]))
    errs = []
    for row, (ka, kb, n) in enumerate(configs):
        a, b = table2.kernel(ka), table2.kernel(kb)
        sim = memsim.simulate([sharing.Group.of(a, arch, n),
                               sharing.Group.of(b, arch, n)],
                              n_events=n_events)
        for i in range(2):
            model = batch.bw_per_core[row, i]
            errs.append(abs(sim[i] / n - model) / model)
    return errs


def rows():
    out = []
    all_errs = []
    for arch in DOMAIN:
        t0 = time.perf_counter()
        errs = errors_for(arch)
        us = (time.perf_counter() - t0) * 1e6 / len(errs)
        all_errs += errs
        q3 = statistics.quantiles(errs, n=4)[2]
        out.append((f"fig8/{arch}", us,
                    f"n={len(errs)};median={statistics.median(errs)*100:.1f}%"
                    f";p75={q3*100:.1f}%;max={max(errs)*100:.1f}%"))
    q3 = statistics.quantiles(all_errs, n=4)[2]
    frac5 = sum(e < 0.05 for e in all_errs) / len(all_errs)
    frac8 = sum(e < 0.08 for e in all_errs) / len(all_errs)
    out.append(("fig8/GLOBAL", 0.0,
                f"n={len(all_errs)};median="
                f"{statistics.median(all_errs)*100:.2f}%;p75={q3*100:.1f}%;"
                f"max={max(all_errs)*100:.1f}%;<5%={frac5*100:.0f}%;"
                f"<8%={frac8*100:.0f}%;paper=max8%_p75-5%"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
