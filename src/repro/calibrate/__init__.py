"""Calibration subsystem: fit the model's inputs from measured curves.

The paper's sharing model needs exactly two numbers per kernel and
architecture — the memory request fraction ``f`` and the saturated
bandwidth ``b_s`` — which "can either be measured directly or predicted
using the ECM model".  ``repro.core.ecm`` is the prediction route; this
package is the *measurement* route, closing the measure→model loop:

  traces   — versioned JSON/ndjson schema for bandwidth-vs-cores scaling
             curves and paired-share measurements, plus the built-in
             synthetic generator backed by the queue simulator
             (:mod:`repro.core.memsim`);
  fit      — batched profile-least-squares estimators over the Eq. 1–5
             forward model: all (kernel, arch, seed) cells in one
             vectorized numpy or ``jax.vmap`` pass, seed-ensemble
             confidence intervals, Eq. 4 envelope recovery from paired
             totals, and materialization as first-class
             :class:`repro.core.table2.KernelSpec` objects;
  certify  — Fig. 8-style round-trip certification (fit on homogeneous
             curves, predict held-out paired shares, hold every cell to
             the paper's < 8 % bound), emitting ``BENCH_calibrate.json``.

Workflow for users with real hardware: record LIKWID/perf scaling curves
into the trace schema, ``load_traces`` → ``fit_scaling`` →
``calibrated_specs``, and hand the resulting specs to ``Group.of``, the
topology solver, or the desync engines — no hand transcription of
Table II-style values.
"""

from .certify import (ERROR_BOUND, CellError, CertificationReport,
                      PairError, certify)
from .fit import (CalibratedValue, EnvelopeFit, ScalingFit,
                  aggregate_ensemble, calibrated_specs, fit_envelope,
                  fit_scaling, fit_scaling_cell, forward_bandwidth,
                  predict_pairs)
from .traces import (DOMAIN_CORES, SCHEMA_VERSION, PairTrace, ScalingTrace,
                     TraceSet, dump_traces, load_traces,
                     synthesize_ensemble, synthesize_pair_trace,
                     synthesize_scaling_trace)

__all__ = [
    "ERROR_BOUND", "CellError", "CertificationReport", "PairError",
    "certify", "CalibratedValue", "EnvelopeFit", "ScalingFit",
    "aggregate_ensemble", "calibrated_specs", "fit_envelope",
    "fit_scaling", "fit_scaling_cell", "forward_bandwidth",
    "predict_pairs", "DOMAIN_CORES", "SCHEMA_VERSION", "PairTrace",
    "ScalingTrace", "TraceSet", "dump_traces", "load_traces",
    "synthesize_ensemble", "synthesize_pair_trace",
    "synthesize_scaling_trace",
]
