"""Deterministic stand-in for the ``hypothesis`` property-testing API.

The container this repo is developed in does not ship ``hypothesis`` and no
new packages may be installed.  This module provides the small slice of the
API our tests use (``given``, ``settings``, and the ``strategies`` functions
``integers``, ``floats``, ``lists``, ``builds``, ``sampled_from`` plus the
``.filter``/``.map`` combinators) backed by a seeded ``random.Random`` so
runs are reproducible.  When the real ``hypothesis`` is importable it is
always preferred — see ``conftest.py`` — so environments that have it lose
nothing (shrinking, the example database, health checks).

Sampling intentionally over-weights boundary values (min/max of numeric
ranges, min/max list sizes) because those are where the model code has
special cases (n=0 groups, f=1 saturation).
"""

from __future__ import annotations

import functools
import random
import sys
import types
import zlib

_BOUNDARY_PROB = 0.15
_FILTER_TRIES = 5000


class SearchStrategy:
    """A lazily-evaluated value generator, mirroring hypothesis' type."""

    def __init__(self, draw):
        self._draw = draw

    def example_with(self, rng: random.Random):
        return self._draw(rng)

    def filter(self, predicate) -> "SearchStrategy":
        base = self._draw

        def draw(rng):
            for _ in range(_FILTER_TRIES):
                value = base(rng)
                if predicate(value):
                    return value
            raise RuntimeError(
                "fallback-hypothesis: .filter predicate rejected "
                f"{_FILTER_TRIES} consecutive examples")

        return SearchStrategy(draw)

    def map(self, fn) -> "SearchStrategy":
        base = self._draw
        return SearchStrategy(lambda rng: fn(base(rng)))


def integers(min_value: int = -(2**16), max_value: int = 2**16
             ) -> SearchStrategy:
    def draw(rng):
        if rng.random() < _BOUNDARY_PROB:
            return rng.choice((min_value, max_value))
        return rng.randint(min_value, max_value)
    return SearchStrategy(draw)


def floats(min_value: float = 0.0, max_value: float = 1.0, *,
           allow_nan: bool = False, allow_infinity: bool = False,
           width: int = 64, **_ignored) -> SearchStrategy:
    def draw(rng):
        if rng.random() < _BOUNDARY_PROB:
            return rng.choice((min_value, max_value))
        return rng.uniform(min_value, max_value)
    return SearchStrategy(draw)


def lists(elements: SearchStrategy, *, min_size: int = 0, max_size: int = 10,
          **_ignored) -> SearchStrategy:
    def draw(rng):
        if rng.random() < _BOUNDARY_PROB:
            size = rng.choice((min_size, max_size))
        else:
            size = rng.randint(min_size, max_size)
        return [elements.example_with(rng) for _ in range(size)]
    return SearchStrategy(draw)


def sampled_from(population) -> SearchStrategy:
    population = list(population)
    return SearchStrategy(lambda rng: rng.choice(population))


def builds(target, *arg_strategies, **kwarg_strategies) -> SearchStrategy:
    def draw(rng):
        args = [s.example_with(rng) for s in arg_strategies]
        kwargs = {k: s.example_with(rng)
                  for k, s in kwarg_strategies.items()}
        return target(*args, **kwargs)
    return SearchStrategy(draw)


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def settings(max_examples: int = 100, deadline=None, **_ignored):
    """Record run parameters on the test function for ``given`` to read."""
    def decorate(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return decorate


def given(*arg_strategies, **kwarg_strategies):
    """Run the test once per generated example, deterministically seeded
    per test name so failures reproduce across runs."""
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper():
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            n_examples = getattr(fn, "_fallback_max_examples", 100)
            for _ in range(n_examples):
                args = [s.example_with(rng) for s in arg_strategies]
                kwargs = {k: s.example_with(rng)
                          for k, s in kwarg_strategies.items()}
                fn(*args, **kwargs)
        # Drop the functools.wraps back-reference: pytest follows
        # __wrapped__ to the original signature and would then try to
        # fixture-inject the strategy-supplied parameters.
        del wrapper.__wrapped__
        return wrapper
    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` + ``hypothesis.strategies``."""
    this = sys.modules[__name__]
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = this
    hyp.HealthCheck = types.SimpleNamespace(
        too_slow=None, filter_too_much=None, data_too_large=None)
    hyp.assume = lambda condition: True
    hyp.__version__ = "0.0-fallback"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = this
