"""Unified instrumentation: spans, metrics, and trace export.

The repo's runtime signals used to be scattered — backend cache
counters, solver iteration counts, raw ``print()`` reporting — with no
way to see where a ``plan.run()`` or ``calibrate.fit`` spends its time.
This package is the one substrate they all share:

* :mod:`~repro.obs.trace` — nestable spans (context manager /
  decorator) over a thread-safe ring buffer; near-zero cost while
  disabled, enabled via ``REPRO_TRACE=1`` or :func:`trace.enable`.
* :mod:`~repro.obs.metrics` — named counters / gauges / histograms on
  a process-wide registry (supersedes ``core.backend``'s private
  ``_STATS`` dict).
* :mod:`~repro.obs.export` — ndjson event stream + Chrome
  ``trace_event`` JSON for ``chrome://tracing`` / Perfetto; written
  automatically at exit under ``REPRO_TRACE=1``.
* :mod:`~repro.obs.log` — structured stdout reporter (text unchanged,
  events under tracing).
* :mod:`~repro.obs.report` — ``python -m repro.obs.report`` summary
  CLI over an exported ndjson file.

Probes are wired through every hot layer (backend jit cache, the
Eq. 4-5 solvers, the desync event loop, Gauss-Newton calibration,
pod-plan relaxation, plan compile/run), so one traced run of any
benchmark or example emits a complete correlated timeline.  Span and
metric names follow ``layer.noun.verb`` — the full catalog lives in
docs/observability.md.

Instrumentation never changes results: with tracing disabled every
probed function is bit-for-bit its un-instrumented self
(tests/test_obs.py), and the measured overhead is gated by
benchmarks/obs_overhead.py (< 2 % disabled, < 10 % enabled at B=256).
"""

from . import export, log, metrics, trace
from .metrics import REGISTRY, counter, gauge, histogram
from .trace import disable, enable, enabled, instant, span, traced

__all__ = [
    "trace", "metrics", "export", "log",
    "span", "traced", "instant", "enabled", "enable", "disable",
    "counter", "gauge", "histogram", "REGISTRY",
]
