"""Instrumentation overhead gate for the obs layer (spans + metrics).

The instrumentation PR wires trace spans and metric probes through the
hot layers (api plan dispatch, backend jit cache, Eq. 4-5 solver,
desync event loop).  This benchmark proves the two bounds the layer is
held to, on the B = 256 placed-batch solve from
``benchmarks/placement_scaling.py``:

* ``disabled`` — with tracing off (the default), the probes must cost
  < 2 % of the solve.  Measured as a per-call microbenchmark of the
  disabled fast paths (``trace.span``/``trace.enabled``/counter inc),
  multiplied by the number of probe sites one ``plan.run()`` actually
  crosses (counted by running once with tracing on), relative to the
  disabled end-to-end run time.  This estimate is an upper bound: most
  disabled sites are a bare ``enabled()`` check, cheaper than a full
  disabled ``span()`` call.
* ``enabled`` — with tracing on, the end-to-end run must stay within
  10 % of the disabled run ((t_on - t_off) / t_off < 0.10).

``python benchmarks/obs_overhead.py --out BENCH_obs.json`` writes the
committed artifact and exits nonzero if a bound is broken.
``--trace-out FILE`` additionally records one fully-traced demo run
(jit compile + placed-batch predict + desync simulate) and writes the
Chrome ``trace_event`` artifact for chrome://tracing / Perfetto.
"""

from __future__ import annotations

import argparse
import gc
import json
import sys
import time

from repro import api
from repro.core import backend as backend_mod
from repro.obs import export, metrics, trace

B_SWEEP = 256
DISABLED_BOUND = 0.02   # probe cost with tracing off, fraction of run
ENABLED_BOUND = 0.10    # end-to-end slowdown with tracing on
REPS = 30
SAMPLES = 7

KERNELS = ("DCOPY", "DDOT2", "DAXPY", "Schoenauer")
DOMAINS = ("CLX/s0/d0", "CLX/s1/d0")


def _time_us(fn, reps: int = REPS, samples: int = SAMPLES) -> float:
    """Best-of-``samples`` mean over ``reps`` calls, in µs, GC paused
    (same protocol as benchmarks/placement_scaling.py)."""
    best = float("inf")
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(samples):
            t0 = time.perf_counter()
            for _ in range(reps):
                fn()
            best = min(best, (time.perf_counter() - t0) / reps)
            gc.collect()
    finally:
        if gc_was_enabled:
            gc.enable()
    return best * 1e6


def _placed_scenarios(b: int, shift: int = 0) -> list:
    """B placement candidates for a two-kernel co-run on CLX-2S (the
    benchmarks/placement_scaling.py sweep)."""
    base = api.Scenario.on("CLX").using("CLX-2S")
    out = []
    for i in range(b):
        j = i + shift
        sc = (base
              .placed(KERNELS[j % 3], 1 + j % 8, DOMAINS[j % 2])
              .placed(KERNELS[(j + 1) % 4], 1 + (j * 3) % 8,
                      DOMAINS[(j + 1) % 2]))
        if j % 2:
            sc = sc.placed("DAXPY", 1 + j % 4, DOMAINS[0])
        out.append(sc)
    return out


def _metric_totals() -> dict:
    """Snapshot reduced to one update-count per instrument (counter
    value, histogram count; gauges report 1 write)."""
    out = {}
    for r in metrics.snapshot():
        key = (r["name"], tuple(sorted(r["labels"].items())))
        v = r["count"] if r["type"] == "histogram" else r.get("value")
        out[key] = float(v if v is not None else 1)
    return out


def _sim_scenario():
    MB = 1e6
    return (api.Scenario.on("CLX").ranks(6)
            .with_noise(6e-5, seed=0, ensemble=4)
            .step("Schoenauer", 8 * MB, tag="symgs")
            .step("DDOT2", 2 * MB, tag="ddot2")
            .barrier()
            .step("DAXPY", 6 * MB, tag="daxpy"))


def measure() -> dict:
    plan = api.compile(api.ScenarioBatch.of(_placed_scenarios(B_SWEEP)))
    plan.run()                      # warm caches + jit before timing

    # Per-call cost of the disabled fast paths.
    t_span_off_us = _time_us(lambda: trace.span("bench.noop"),
                             reps=20_000, samples=SAMPLES)
    t_check_us = _time_us(trace.enabled, reps=20_000, samples=SAMPLES)
    t_counter_us = _time_us(metrics.counter("bench.count").inc,
                            reps=20_000, samples=SAMPLES)

    # Probe sites one plan.run() crosses: run once traced and count.
    trace.enable(clear_events=True)
    before = _metric_totals()
    plan.run()
    n_spans = len(trace.events())
    after = _metric_totals()
    n_metric_updates = int(sum(
        after[k] - before.get(k, 0.0) for k in after
        if not k[0].startswith("bench.")))
    trace.disable()
    trace.clear()

    # End-to-end: tracing off vs on (large buffer so nothing reallocs).
    t_off_us = _time_us(plan.run)
    trace.enable(capacity=1 << 18, clear_events=True)
    t_on_us = _time_us(plan.run)
    trace.disable()
    trace.clear()
    metrics.reset()

    probe_cost_us = (n_spans * max(t_span_off_us, t_check_us)
                     + n_metric_updates * t_counter_us)
    disabled_frac = probe_cost_us / t_off_us
    enabled_frac = max(0.0, (t_on_us - t_off_us) / t_off_us)

    return {
        "B": B_SWEEP,
        "backend": plan.engine,
        "span_call_disabled_ns": round(t_span_off_us * 1e3, 2),
        "enabled_check_ns": round(t_check_us * 1e3, 2),
        "counter_inc_ns": round(t_counter_us * 1e3, 2),
        "spans_per_run": n_spans,
        "metric_updates_per_run": n_metric_updates,
        "run_disabled_us": round(t_off_us, 1),
        "run_enabled_us": round(t_on_us, 1),
        "disabled_overhead_frac": round(disabled_frac, 5),
        "enabled_overhead_frac": round(enabled_frac, 4),
    }


def write_demo_trace(path: str) -> dict:
    """One fully-traced run touching every layer: jit compile (backend),
    placed-batch predict (api -> sharing), desync simulate (desync).
    Writes the Chrome trace_event artifact and returns span-name counts."""
    backend_mod.clear_jit_cache()    # force backend.jit.build spans
    trace.enable(capacity=1 << 18, clear_events=True)
    try:
        plan = api.compile(api.ScenarioBatch.of(_placed_scenarios(64)))
        plan.run()
        sim = api.compile(_sim_scenario(), verb="simulate")
        sim.run(t_max=60.0)
        export.write_chrome_trace(path)
        names: dict[str, int] = {}
        for ev in trace.events():
            names[ev[1]] = names.get(ev[1], 0) + 1
    finally:
        trace.disable()
        trace.clear()
        metrics.reset()
    return names


def check(r: dict) -> bool:
    return (r["disabled_overhead_frac"] < DISABLED_BOUND
            and r["enabled_overhead_frac"] < ENABLED_BOUND)


def rows():
    r = measure()
    return [
        (f"obs/B={r['B']}/run_disabled", r["run_disabled_us"],
         f"probe_sites={r['spans_per_run']}"),
        (f"obs/B={r['B']}/run_enabled", r["run_enabled_us"],
         f"enabled_frac={r['enabled_overhead_frac']}"),
        ("obs/span_call_disabled", r["span_call_disabled_ns"] / 1e3,
         f"counter_inc={r['counter_inc_ns']}ns"),
        ("obs/check/bounds", 0.0,
         f"ok={check(r)};disabled<{DISABLED_BOUND};"
         f"enabled<{ENABLED_BOUND}"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="JSON artifact path")
    ap.add_argument("--trace-out", default=None,
                    help="also write a demo Chrome trace to this path")
    args = ap.parse_args(argv)
    r = measure()
    ok = check(r)
    if args.trace_out:
        names = write_demo_trace(args.trace_out)
        layers = {n.split(".", 1)[0] for n in names}
        r["demo_trace"] = {"path": args.trace_out,
                           "span_names": dict(sorted(names.items())),
                           "layers": sorted(layers)}
        print(f"wrote {args.trace_out}  "
              f"(layers: {', '.join(sorted(layers))})")
    report = {
        "benchmark": "obs_overhead",
        "jax": backend_mod.HAVE_JAX,
        "bound_disabled_frac": DISABLED_BOUND,
        "bound_enabled_frac": ENABLED_BOUND,
        "ok": ok,
        "results": r,
    }
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.out}  (ok={ok})")
    print(f"B={r['B']}: disabled run {r['run_disabled_us']:.0f}us "
          f"({r['spans_per_run']} probe sites, est overhead "
          f"{r['disabled_overhead_frac']:.3%})  enabled run "
          f"{r['run_enabled_us']:.0f}us "
          f"(+{r['enabled_overhead_frac']:.1%})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
