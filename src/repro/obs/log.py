"""Structured stdout reporter: same text, plus events under tracing.

``certify.py`` and the ``launch/`` scripts used to report progress with
raw ``print()`` — human-readable but invisible to the trace timeline.
:func:`emit` keeps the stdout text *byte-identical by default* and, when
tracing is enabled, additionally records a structured ``"log"`` event
(message + typed fields) into the trace buffer, so a ``REPRO_TRACE=1``
run exports every report line in the ndjson stream alongside the spans
it happened between.

    from repro.obs import log

    log.emit(f"step {i:4d}  loss {loss:.4f}", event="train.step",
             step=i, loss=loss)

``event`` names follow the span naming scheme (``layer.noun.verb``);
the raw text rides along as the ``text`` attribute.
"""

from __future__ import annotations

import sys

from . import trace

__all__ = ["emit"]


def emit(text: str, *, event: str = "log", stream=None, **fields) -> None:
    """Print ``text`` (stdout by default, byte-identical to the print it
    replaces) and, when tracing is on, record it as a structured event
    with the given fields."""
    print(text, file=stream if stream is not None else sys.stdout)
    if trace.enabled():
        trace.instant(event, kind="log", text=text, **(fields or {}))
