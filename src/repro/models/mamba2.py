"""Mamba-2 (SSD — state-space duality) language model.

The attention-free assigned architecture (mamba2-1.3b): the SSD chunked
scan is the memory-bound kernel par excellence — O(S) state streaming with
tiny arithmetic intensity at decode — making it the natural TPU analogue of
the paper's streaming suite.

Projections are kept as separate matrices (wz/wx/wb/wc/wdt) rather than one
fused in_proj: each is then cleanly column- or row-shardable for tensor
parallelism without resharding at the split boundaries (see
runtime/sharding.py).

Train/prefill path: chunked SSD —
  within chunk c (length Q), with per-step log-decay l_t = dt_t * A_h:
    L_ij = exp(cum_i - cum_j)  (j <= i)            # intra-chunk decay mask
    Y_intra = (C B^T ⊙ L) @ (dt ⊙ X)
    S_c     = Σ_j exp(cum_Q - cum_j) B_j ⊗ (dt_j X_j)   # chunk state
    Y_inter = exp(cum_i) C_i @ H_{c-1};  H_c = exp(cum_Q) H_{c-1} + S_c
  H carried by lax.scan over chunks.

Decode path: the linear recurrence h = a h + dt * (B ⊗ x), y = h C + D x,
with causal depthwise-conv states of width 4 on x, B, C.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers

CONV_W = 4
HEAD_DIM = 64


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = cfg.ssm_heads or d_in // HEAD_DIM
    hd = d_in // n_heads
    return d_in, n_heads, hd, cfg.ssm_state


def layer_params(cfg: ModelConfig, key):
    d = cfg.d_model
    d_in, nh, hd, n = _dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 9)
    return {
        "ln": layers.norm_params(cfg),
        "wz": layers.dense_init(ks[0], d, d_in, dt),
        "wx": layers.dense_init(ks[1], d, d_in, dt),
        "wb": layers.dense_init(ks[2], d, n, dt),
        "wc": layers.dense_init(ks[3], d, n, dt),
        "wdt": layers.dense_init(ks[4], d, nh, dt),
        "conv_x": (jax.random.normal(ks[5], (CONV_W, d_in), jnp.float32)
                   * 0.5).astype(dt),
        "conv_xb": jnp.zeros((d_in,), dt),
        "conv_b": (jax.random.normal(ks[6], (CONV_W, n), jnp.float32)
                   * 0.5).astype(dt),
        "conv_bb": jnp.zeros((n,), dt),
        "conv_c": (jax.random.normal(ks[7], (CONV_W, n), jnp.float32)
                   * 0.5).astype(dt),
        "conv_cb": jnp.zeros((n,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(dt),
        "d_skip": jnp.ones((nh,), dt),
        "dt_bias": jnp.zeros((nh,), dt),
        "out_ln": layers.norm_params(cfg, d_in),
        "out_proj": layers.dense_init(ks[8], d_in, d, dt),
    }


def init_params(cfg: ModelConfig, key):
    ke, kl = jax.random.split(key)
    lkeys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(functools.partial(layer_params, cfg))(lkeys)
    return {
        "embed": layers.embed_init(ke, cfg.vocab, cfg.d_model,
                                   jnp.dtype(cfg.param_dtype)),
        "layers": stacked,
        "ln_f": layers.norm_params(cfg),
    }


# --------------------------------------------------------------------------
# SSD chunked scan
# --------------------------------------------------------------------------


def _ssd_chunked(x, b_in, c_in, log_a, chunk: int):
    """x: (B,S,H,P); b_in/c_in: (B,S,N); log_a: (B,S,H) (dt already folded
    into x).  Returns y: (B,S,H,P)."""
    bsz, s, h, p = x.shape
    n = b_in.shape[-1]
    q = min(chunk, s)
    while s % q:
        q -= 1
    nc = s // q

    xc = x.reshape(bsz, nc, q, h, p)
    bc = b_in.reshape(bsz, nc, q, n)
    cc = c_in.reshape(bsz, nc, q, n)
    lc = log_a.reshape(bsz, nc, q, h)
    cum = jnp.cumsum(lc, axis=2)                       # (B,NC,Q,H)

    # Intra-chunk: L_ij = exp(cum_i - cum_j), j <= i.
    li = cum[:, :, :, None, :]                         # (B,NC,Q,1,H)
    lj = cum[:, :, None, :, :]                         # (B,NC,1,Q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    # Mask the exponent (not the result): exp of a large positive diff above
    # the diagonal would be inf and poison gradients through jnp.where.
    decay = jnp.exp(jnp.where(mask, li - lj, -1e30))   # (B,NC,Q,Q,H)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)         # (B,NC,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay, xc)

    # Chunk summary state: S_c = sum_j exp(cum_Q - cum_j) B_j (x_j)^T.
    w = jnp.exp(cum[:, :, -1:, :] - cum)               # (B,NC,Q,H)
    s_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc, w, xc)
    a_chunk = jnp.exp(cum[:, :, -1, :])                # (B,NC,H)

    def scan_body(h_prev, inp):
        s_c, a_c = inp                                  # (B,H,N,P), (B,H)
        h_new = a_c[..., None, None] * h_prev + s_c
        return h_new, h_prev

    h0 = jnp.zeros((bsz, h, n, p), x.dtype)
    _, h_befores = jax.lax.scan(
        scan_body,
        h0,
        (s_chunk.transpose(1, 0, 2, 3, 4), a_chunk.transpose(1, 0, 2)),
    )
    h_befores = h_befores.transpose(1, 0, 2, 3, 4)      # (B,NC,H,N,P)

    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp",
                         cc, jnp.exp(cum), h_befores)
    return (y_intra + y_inter).reshape(bsz, s, h, p)


def _causal_conv(u, w, b):
    """Depthwise causal conv, width CONV_W, SiLU.  u: (B,S,C); w: (W,C)."""
    pads = [(0, 0), (CONV_W - 1, 0), (0, 0)]
    up = jnp.pad(u, pads)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(CONV_W))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(u.dtype)


def _mixer(cfg: ModelConfig, lp, x):
    """SSD sequence mixer.  x: (B, S, D) -> (B, S, D)."""
    bsz, s, _ = x.shape
    d_in, nh, hd, n = _dims(cfg)
    xdt = x.dtype
    z = x @ lp["wz"].astype(xdt)
    xs = _causal_conv(x @ lp["wx"].astype(xdt),
                      lp["conv_x"].astype(xdt), lp["conv_xb"].astype(xdt))
    b_in = _causal_conv(x @ lp["wb"].astype(xdt),
                        lp["conv_b"].astype(xdt), lp["conv_bb"].astype(xdt))
    c_in = _causal_conv(x @ lp["wc"].astype(xdt),
                        lp["conv_c"].astype(xdt), lp["conv_cb"].astype(xdt))
    dt_raw = x @ lp["wdt"].astype(xdt)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = -jnp.exp(lp["a_log"].astype(jnp.float32))              # (H,)
    log_a = dt * a[None, None, :]

    xh = xs.reshape(bsz, s, nh, hd).astype(jnp.float32)
    x_dt = xh * dt[..., None]
    y = _ssd_chunked(x_dt, b_in.astype(jnp.float32),
                     c_in.astype(jnp.float32), log_a, cfg.ssm_chunk)
    y = y + lp["d_skip"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(bsz, s, d_in).astype(xdt)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(xdt)
    y = layers.apply_norm(cfg, lp["out_ln"], y)
    return y @ lp["out_proj"].astype(xdt)


def hidden_states(cfg: ModelConfig, params, x):
    def body(lp, x):
        return x + _mixer(cfg, lp, layers.apply_norm(cfg, lp["ln"], x))
    if cfg.remat:
        body = layers.remat(cfg, body)

    if cfg.use_scan:
        def scan_body(carry, lp):
            return body(lp, carry), None
        x, _ = jax.lax.scan(scan_body, x, params["layers"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x = body(lp, x)
    return layers.apply_norm(cfg, params["ln_f"], x)


def forward(cfg: ModelConfig, params, tokens):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = hidden_states(cfg, params, x)
    return layers.unembed(cfg, params["embed"], x)


def loss_fn(cfg: ModelConfig, params, batch):
    logits = forward(cfg, params, batch["tokens"])
    labels = batch["labels"]
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss, {"lm_loss": loss}


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int = 0):
    """SSM state + conv rings: O(1) in sequence length."""
    d_in, nh, hd, n = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    return {
        "ssm": jnp.zeros((L, batch, nh, n, hd), dt),
        "conv_x": jnp.zeros((L, batch, CONV_W - 1, d_in), dt),
        "conv_b": jnp.zeros((L, batch, CONV_W - 1, n), dt),
        "conv_c": jnp.zeros((L, batch, CONV_W - 1, n), dt),
    }


def _conv_step(u, hist, w, b):
    """u: (B, C) new input; hist: (B, W-1, C) -> (out (B,C), new hist)."""
    full = jnp.concatenate([hist, u[:, None]], axis=1)
    out = jnp.einsum("bwc,wc->bc", full, w) + b
    out = jax.nn.silu(out.astype(jnp.float32)).astype(u.dtype)
    return out, full[:, 1:]


def _mixer_step(cfg: ModelConfig, lp, x, cache):
    """x: (B, D) single step.  cache: dict of this layer's states."""
    bsz = x.shape[0]
    d_in, nh, hd, n = _dims(cfg)
    xdt = x.dtype
    z = x @ lp["wz"].astype(xdt)
    xs, cx = _conv_step(x @ lp["wx"].astype(xdt), cache["conv_x"],
                        lp["conv_x"].astype(xdt), lp["conv_xb"].astype(xdt))
    b_in, cb = _conv_step(x @ lp["wb"].astype(xdt), cache["conv_b"],
                          lp["conv_b"].astype(xdt), lp["conv_bb"].astype(xdt))
    c_in, cc = _conv_step(x @ lp["wc"].astype(xdt), cache["conv_c"],
                          lp["conv_c"].astype(xdt), lp["conv_cb"].astype(xdt))
    dt_raw = x @ lp["wdt"].astype(xdt)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + lp["dt_bias"].astype(jnp.float32))   # (B,H)
    a = jnp.exp(dt * -jnp.exp(lp["a_log"].astype(jnp.float32)))  # (B,H)
    xh = xs.reshape(bsz, nh, hd).astype(jnp.float32)
    upd = jnp.einsum("bn,bhp->bhnp", b_in.astype(jnp.float32),
                     xh * dt[..., None])
    new_ssm = a[..., None, None] * cache["ssm"].astype(jnp.float32) + upd
    y = jnp.einsum("bn,bhnp->bhp", c_in.astype(jnp.float32), new_ssm)
    y = y + lp["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(bsz, d_in).astype(xdt)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(xdt)
    y = layers.apply_norm(cfg, lp["out_ln"], y)
    out = y @ lp["out_proj"].astype(xdt)
    new_cache = {"ssm": new_ssm.astype(cache["ssm"].dtype),
                 "conv_x": cx, "conv_b": cb, "conv_c": cc}
    return out, new_cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))   # (B, D)

    def body(carry, inp):
        x = carry
        lp, layer_cache = inp
        h = layers.apply_norm(cfg, lp["ln"], x)
        y, new_cache = _mixer_step(cfg, lp, h, layer_cache)
        return x + y, new_cache

    if cfg.use_scan:
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    else:
        caches = []
        for i in range(cfg.n_layers):
            inp = jax.tree.map(lambda a: a[i], (params["layers"], cache))
            x, nc = body(x, inp)
            caches.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    x = layers.apply_norm(cfg, params["ln_f"], x)
    logits = layers.unembed(cfg, params["embed"], x)
    return logits, new_cache
