"""Unit tests for the sharding rules (runtime/sharding.py) on a tiny
host mesh — spec selection, divisibility fallback, stacked-layer handling."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro import configs
from repro.models import model_for
from repro.runtime import sharding as sh

import numpy as np


def _mesh():
    dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def _specs(arch, fsdp=None):
    cfg = configs.get_reduced(arch)
    model = model_for(cfg)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    shardings = sh.param_shardings(cfg, _mesh(), params_shape, fsdp=fsdp)
    return cfg, params_shape, shardings


def test_dense_tp_specs():
    cfg, shapes, shardings = _specs("qwen2-0.5b", fsdp=False)
    flat = {sh._path_str(p): s for p, s in
            jax.tree_util.tree_flatten_with_path(shardings)[0]}
    # Stacked layers: leading None then column/row TP.
    assert flat["layers/attn/wq"].spec == P(None, None, "model")
    assert flat["layers/attn/wo"].spec == P(None, "model", None)
    assert flat["layers/mlp/wi"].spec == P(None, None, "model")
    assert flat["layers/mlp/wo"].spec == P(None, "model", None)
    assert flat["embed"].spec == P("model", None)


def test_moe_expert_sharding():
    cfg, shapes, shardings = _specs("olmoe-1b-7b")
    flat = {sh._path_str(p): s for p, s in
            jax.tree_util.tree_flatten_with_path(shardings)[0]}
    assert flat["layers/moe/wi"].spec == P(None, "model", None, None)
    # Replicated (stacked rule prepends a None for the layer axis).
    assert flat["layers/moe/router"].spec in (P(), P(None))


def test_divisibility_fallback():
    """A dim not divisible by the mesh axis must drop its sharding."""
    mesh_devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(mesh_devs, ("data", "model"))
    spec = sh._validate(P(None, "model"), (8, 7), mesh)
    assert spec == P(None, "model")  # model axis size 1 divides everything

    # Simulate a 16-wide axis by checking the logic directly:
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    spec = sh._validate(P(None, "model"), (8, 7), FakeMesh())
    assert spec == P(None, None)
    spec = sh._validate(P(("data", "model"), None), (8, 7), FakeMesh())
    assert spec in (P(None, None), P(None))


def test_batch_shardings():
    mesh = _mesh()
    specs = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    out = sh.batch_shardings(mesh, specs)
    assert out["tokens"].spec[0] in ("data", ("data",))


def test_cache_shardings_kv_vs_seq():
    cfg = configs.get_reduced("qwen2-0.5b")
    model = model_for(cfg)
    cache_shape = jax.eval_shape(lambda: model.init_cache(4, 32))
    out = sh.cache_shardings(cfg, _mesh(), cache_shape)
    # (L, B, S, KV, hd): kv_heads=2 divisible by model axis (size 1 here).
    assert out["k"].spec == P(None, ("data",), None, "model", None)


def test_all_archs_shardings_build():
    for arch in configs.ARCH_IDS:
        cfg = configs.get_reduced(arch)
        model = model_for(cfg)
        params_shape = jax.eval_shape(lambda: model.init(jax.random.key(0)))
        shardings = sh.param_shardings(cfg, _mesh(), params_shape)
        assert jax.tree.leaves(shardings), arch
