"""Serving smoke test over a real socket and process boundary.

Boots ``python -m repro.serve`` as a subprocess (warmup flags
included), waits for ``/healthz``, streams an ndjson workload through
``/v1/solve`` with the stdlib client, checks ``/statsz``, then sends
SIGTERM and requires a clean graceful-drain exit (code 0).  This is
what the CI ``serve-smoke`` job runs; locally::

    PYTHONPATH=src python benchmarks/serve_smoke.py
"""

from __future__ import annotations

import argparse
import os
import signal
import subprocess
import sys
import time

from repro.serve import client

HOST = "127.0.0.1"


def wait_healthy(port: int, proc, timeout_s: float = 30.0) -> dict:
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        if proc.poll() is not None:
            raise SystemExit(f"server died early (exit {proc.returncode})")
        try:
            status, health = client.get_json(HOST, port, "/healthz")
        except OSError:
            time.sleep(0.1)
            continue
        assert status == 200 and health["ok"], health
        return health
    raise SystemExit("server never came up")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--port", type=int, default=8123)
    ap.add_argument("--n", type=int, default=32, help="workload lines")
    args = ap.parse_args(argv)

    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serve", "--port", str(args.port),
         "--warmup", "CLX/DCOPY:12/DDOT2:8", "--warmup-buckets", "1,32"],
        env=env)
    try:
        wait_healthy(args.port, proc)

        rows = [{"id": k, "arch": "CLX",
                 "groups": [{"kernel": "DCOPY", "n": 1 + k % 19},
                            {"kernel": "DDOT2", "n": 20 - (1 + k % 19)}]}
                for k in range(args.n)]
        out = client.solve(HOST, args.port, rows)
        assert [r["id"] for r in out] == list(range(args.n)), \
            "response order must match request order"
        bad = [r for r in out if not r.get("ok")]
        assert not bad, bad
        assert all(r["total_bw"] > 0 for r in out)

        status, stats = client.get_json(HOST, args.port, "/statsz")
        assert status == 200
        co, pc = stats["coalescer"], stats["plan_cache"]
        assert co["completed"] == args.n, co
        assert pc["hits"] >= 1, f"warmed structure must hit: {pc}"
        print(f"smoke ok: {args.n} requests in {co['ticks']} ticks, "
              f"plan cache hits={pc['hits']} misses={pc['misses']}")
    finally:
        if proc.poll() is None:
            proc.send_signal(signal.SIGTERM)
            code = proc.wait(timeout=60)
        else:
            code = proc.returncode
    assert code == 0, f"graceful drain must exit 0, got {code}"
    print("graceful shutdown ok (exit 0)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
