"""Nestable spans over a thread-safe ring buffer — the tracing core.

The paper's method is measurement-first: Eq. 4-5 predictions are only as
good as the signals behind them, and the same holds for this repo's own
runtime.  This module records *where time goes* inside a solve /
simulate / calibrate / plan call as a tree of spans:

    from repro.obs import trace

    with trace.span("sharing.solve_arrays", backend="numpy", B=256):
        ...                      # nested spans become children

Design constraints (in priority order):

1. **Near-zero cost when disabled.**  ``span(...)`` checks one module
   global and returns a shared no-op context manager; no timestamps, no
   allocation beyond the kwargs dict at the call site.  Probes in
   per-event hot loops must additionally guard with ``if enabled():``.
2. **Bounded memory.**  Events land in a fixed-capacity ring buffer
   (default ``REPRO_TRACE_CAPACITY`` = 65536); old events are
   overwritten, never grown.  ``dropped()`` reports the overflow count
   so exporters can flag truncation instead of lying by omission.
3. **Correlation without coordination.**  Each event carries a
   monotonic ``perf_counter_ns`` start, duration, thread id, and nest
   depth; exporters rebuild the parent/child tree from (tid, depth,
   time) alone — probes never pass span handles around.

Enable via ``REPRO_TRACE=1`` in the environment (which also registers
an at-exit export, see :mod:`repro.obs.export`) or programmatically
with :func:`enable` / :func:`disable`.

Events are plain tuples ``(kind, name, t0_ns, dur_ns, tid, depth,
attrs)`` — ``kind`` is ``"span"``, ``"instant"``, or ``"log"``; attrs
is a dict or None.  Use :mod:`repro.obs.export` to turn them into
ndjson or Chrome ``trace_event`` JSON.
"""

from __future__ import annotations

import functools
import os
import threading
import time

__all__ = [
    "enabled", "enable", "disable", "span", "traced", "instant",
    "events", "clear", "dropped", "DEFAULT_CAPACITY",
]

_TRUTHY = frozenset({"1", "true", "yes", "on"})

DEFAULT_CAPACITY = 65536


def _env_flag(name: str) -> bool:
    return os.environ.get(name, "").strip().lower() in _TRUTHY


def _env_capacity() -> int:
    raw = os.environ.get("REPRO_TRACE_CAPACITY", "")
    try:
        cap = int(raw)
    except ValueError:
        return DEFAULT_CAPACITY
    return cap if cap > 0 else DEFAULT_CAPACITY


class _RingBuffer:
    """Fixed-capacity event store; appends are O(1) under one lock."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self._cap = int(capacity)
        self._buf: list = [None] * self._cap
        self._n = 0  # total events ever appended
        self._lock = threading.Lock()

    def append(self, event) -> None:
        with self._lock:
            self._buf[self._n % self._cap] = event
            self._n += 1

    def snapshot(self) -> list:
        """Events in append order, oldest surviving first."""
        with self._lock:
            n, cap = self._n, self._cap
            if n <= cap:
                return list(self._buf[:n])
            i = n % cap
            return list(self._buf[i:]) + list(self._buf[:i])

    def clear(self) -> None:
        with self._lock:
            self._buf = [None] * self._cap
            self._n = 0

    @property
    def dropped(self) -> int:
        with self._lock:
            return max(0, self._n - self._cap)

    @property
    def capacity(self) -> int:
        return self._cap


BUFFER = _RingBuffer(_env_capacity())

_TLS = threading.local()

# The one global the disabled fast path reads.  Module-level lookup of a
# bool is the cheapest gate python offers short of deleting the probe.
_ENABLED = _env_flag("REPRO_TRACE")


def enabled() -> bool:
    """True when spans are being recorded.  Hot loops guard expensive
    attribute computation with this before building kwargs."""
    return _ENABLED


def enable(*, capacity: int | None = None, clear_events: bool = False) -> None:
    """Turn tracing on (idempotent).  ``capacity`` resizes (and clears)
    the ring buffer; ``clear_events`` drops already-recorded events."""
    global _ENABLED, BUFFER
    if capacity is not None and capacity != BUFFER.capacity:
        BUFFER = _RingBuffer(capacity)
    elif clear_events:
        BUFFER.clear()
    _ENABLED = True


def disable() -> None:
    """Turn tracing off.  Recorded events stay in the buffer."""
    global _ENABLED
    _ENABLED = False


def events() -> list:
    """Snapshot of recorded event tuples, oldest first."""
    return BUFFER.snapshot()


def clear() -> None:
    """Drop all recorded events (the enabled/disabled state is kept)."""
    BUFFER.clear()


def dropped() -> int:
    """Events lost to ring-buffer overwrite since the last clear."""
    return BUFFER.dropped


class _NoopSpan:
    """Shared do-nothing span: the entire disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **attrs) -> None:
        pass


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("name", "attrs", "_t0", "_depth")

    def __init__(self, name: str, attrs: dict | None):
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        depth = getattr(_TLS, "depth", 0)
        _TLS.depth = depth + 1
        self._depth = depth
        self._t0 = time.perf_counter_ns()
        return self

    def set(self, **attrs) -> None:
        """Attach attributes discovered mid-span (iteration counts,
        residuals, chosen backend...)."""
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter_ns()
        _TLS.depth = self._depth
        if exc_type is not None:
            self.set(error=exc_type.__name__)
        BUFFER.append(("span", self.name, self._t0, t1 - self._t0,
                       threading.get_ident(), self._depth, self.attrs))
        return False


def span(name: str, **attrs):
    """Context manager timing a named region.  Attributes are any
    json-serializable kwargs; add more later with ``.set(...)``."""
    if not _ENABLED:
        return _NOOP
    return _Span(name, attrs or None)


def instant(name: str, *, kind: str = "instant", **attrs) -> None:
    """Record a zero-duration event (a log line, a decision point)."""
    if not _ENABLED:
        return
    t = time.perf_counter_ns()
    BUFFER.append((kind, name, t, 0, threading.get_ident(),
                   getattr(_TLS, "depth", 0), attrs or None))


def traced(name: str | None = None):
    """Decorator form of :func:`span`; the span is named after the
    function (``module.qualname``) unless ``name`` is given."""

    def wrap(fn):
        label = name or f"{fn.__module__.rsplit('.', 1)[-1]}.{fn.__qualname__}"

        @functools.wraps(fn)
        def inner(*args, **kwargs):
            if not _ENABLED:
                return fn(*args, **kwargs)
            with _Span(label, None):
                return fn(*args, **kwargs)

        return inner

    return wrap


# When tracing was requested via the environment, arrange for the
# timeline to be written out at interpreter exit so that *any* script —
# benchmark, example, test run — emits its trace with no code changes.
if _ENABLED:  # pragma: no cover - exercised via subprocess in tests
    import atexit

    def _export_at_exit() -> None:
        if BUFFER.snapshot():
            from . import export as _export  # lazy: avoids import cycles

            _export.write_default_artifacts()

    atexit.register(_export_at_exit)
