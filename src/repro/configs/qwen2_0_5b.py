"""qwen2-0.5b [dense]: GQA kv=2, QKV bias, tied embeddings.
[arXiv:2407.10671; hf]"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    kv_heads=2,
    d_ff=4864,
    vocab=151936,
    act="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=512, remat=False, dtype="float32")
