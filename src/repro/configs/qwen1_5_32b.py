"""qwen1.5-32b [dense]: full MHA KV (kv=40), QKV bias.
[hf:Qwen/Qwen1.5-32B; hf]"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    kv_heads=40,
    d_ff=27392,
    vocab=152064,
    act="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=160,
        vocab=512, remat=False, dtype="float32")
