"""Pallas TPU flash-attention (prefill) kernel with GQA support.

TPU-native tiling: the grid is (batch*heads, q_blocks, kv_blocks) with the
kv dimension innermost — TPU grids execute sequentially, so the online-
softmax state (row max ``m``, row sum ``l``, accumulator ``acc``) lives in
VMEM scratch and carries across kv steps.  Causal blocks strictly above the
diagonal are skipped with ``pl.when`` (no data is even DMA'd for them when
the compiler can prove it).  Block shapes are MXU-aligned (multiples of 128
on the contraction and lane axes).

The kernel computes one (1, bq, d) output tile per (bh, iq) pair; GQA maps
query head h to kv head h // (H // KV) inside the BlockSpec index maps, so
no KV replication ever materializes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
STATS_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  n_kv_blocks: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

        if causal:
            q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_ref[:, :1]                             # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    if causal:
        # Skip blocks entirely above the diagonal.
        pl.when(ik * bk <= iq * bq + bq - 1)(_body)
    else:
        _body()

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        out_ref[0] = (acc_ref[...] / l).astype(out_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Flash attention.

    Args:
      q: (B, H, S, D) queries.
      k, v: (B, KV, S, D) keys/values; H must be a multiple of KV (GQA).
    Returns:
      (B, H, S, D) attention output.
    """
    b, h, s, d = q.shape
    _, kv, sk, _ = k.shape
    if h % kv:
        raise ValueError(f"H={h} not a multiple of KV={kv}")
    group = h // kv
    scale = (d ** -0.5) if scale is None else scale
    bq = min(block_q, s)
    bk = min(block_k, sk)
    if s % bq or sk % bk:
        raise ValueError(f"seq lengths ({s},{sk}) not divisible by blocks "
                         f"({bq},{bk})")
    n_q, n_k = s // bq, sk // bk

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * kv, sk, d)
    vf = v.reshape(b * kv, sk, d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk,
        n_kv_blocks=n_k)

    def kv_index(bh, iq, ik):
        return ((bh // h) * kv + (bh % h) // group, ik, 0)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_index),
            pl.BlockSpec((1, bk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, STATS_LANES), jnp.float32),
            pltpu.VMEM((bq, STATS_LANES), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
