"""Property tests: the facade is bit-for-bit the engines it dispatches to.

Acceptance gate of the facade PR: ``api.predict`` must match
``sharing.predict`` (scalar), ``sharing.solve_batch`` (batched, both
backends), and ``topology.predict_placed`` exactly — same floats, not
approximately — on their native inputs, and ``api.simulate`` must
reproduce ``desync_batch.run_batch`` record lists exactly.  Works with
real hypothesis or the deterministic fallback shim.
"""

import random

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import api
from repro.core import sharing, table2, topology
from repro.core.desync import Allreduce, Idle, WaitNeighbors, Work
from repro.core.desync_batch import run_batch
from repro.core.sharing import HAVE_JAX, Group

BACKENDS = ["numpy"] + (["jax"] if HAVE_JAX else [])
KERNELS = sorted(table2.TABLE2)
UTILS = ["recursion", "queue", 0.7]

kernel_names = st.sampled_from(KERNELS)
archs = st.sampled_from(table2.ARCHS)
utils = st.sampled_from(UTILS)
counts = st.integers(min_value=0, max_value=12)


def _scenario_from(arch, util, ks, ns):
    sc = api.Scenario.on(arch).options(utilization=util)
    for k, n in zip(ks, ns):
        sc = sc.run(k, n)
    return sc


# ---------------------------------------------------------------------------
# api.predict (scalar path) == sharing.predict
# ---------------------------------------------------------------------------


@settings(max_examples=150, deadline=None)
@given(arch=archs, util=utils,
       ks=st.lists(kernel_names, min_size=1, max_size=5),
       seed=st.integers(min_value=0, max_value=10**6))
def test_scalar_predict_bit_for_bit(arch, util, ks, seed):
    rng = random.Random(seed)
    ns = [rng.randint(0, 12) for _ in ks]
    groups = [Group.of(table2.kernel(k), arch, n) for k, n in zip(ks, ns)]
    ref = sharing.predict(groups, utilization=util)
    got = api.predict(_scenario_from(arch, util, ks, ns))
    assert got.bw_group == ref.bw_group
    assert got.alphas == ref.alphas
    assert got.b_overlap == ref.b_overlap
    assert got.bw_per_core == ref.bw_per_core
    assert got.total_bw == ref.total_bw


# ---------------------------------------------------------------------------
# api.predict (batched path) == sharing.solve_batch, both backends
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(arch=archs, util=utils,
       seed=st.integers(min_value=0, max_value=10**6),
       b=st.integers(min_value=1, max_value=12))
def test_batched_predict_bit_for_bit(arch, util, seed, b):
    # Backends loop inside the test: the fallback hypothesis shim does
    # not compose @given with @pytest.mark.parametrize.
    rng = random.Random(seed)
    scens, raw_scens = [], []
    for _ in range(b):
        g = rng.randint(1, 4)
        ks = [rng.choice(KERNELS) for _ in range(g)]
        ns = [rng.randint(0, 12) for _ in range(g)]
        scens.append(_scenario_from(arch, util, ks, ns))
        raw_scens.append([Group.of(table2.kernel(k), arch, n)
                          for k, n in zip(ks, ns)])
    for backend in BACKENDS:
        got = api.predict(api.ScenarioBatch.of(scens), backend=backend)
        ref = sharing.predict_batch(raw_scens, utilization=util,
                                    backend=backend)
        np.testing.assert_array_equal(got.bw_group, ref.bw_group)
        np.testing.assert_array_equal(got.alphas, ref.alphas)
        np.testing.assert_array_equal(got.b_overlap, ref.b_overlap)
        np.testing.assert_array_equal(got.bw_per_core, ref.bw_per_core)


def test_batched_predict_matches_scalar_rows():
    """Facade batch rows materialize to exactly the facade scalar result
    (the padding round trip keeps names, counts, and floats)."""
    scens = [api.Scenario.on("CLX").run("DCOPY", 4),
             api.Scenario.on("CLX").run("DDOT2", 3).run("DAXPY", 5)
             .run("STREAM", 2)]
    batch = api.predict(api.ScenarioBatch.of(scens), backend="numpy")
    for i, sc in enumerate(scens):
        ref = api.predict(sc)
        assert batch[i].bw_group == ref.bw_group
        assert [g.name for g in batch[i].groups] \
            == [g.name for g in ref.groups]


# ---------------------------------------------------------------------------
# api.predict (placed) == topology.predict_placed
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(preset_name=st.sampled_from(["CLX-2S", "ROME-2S-NPS4",
                                    "BDW-2-2S", "TPUv5e-pod4"]),
       seed=st.integers(min_value=0, max_value=10**6),
       util=utils)
def test_placed_predict_bit_for_bit(preset_name, seed, util):
    rng = random.Random(seed)
    topo = topology.preset(preset_name)
    arch = "CLX"
    domains = topo.domain_names
    sc = (api.Scenario.on(arch).using(topo)
          .options(utilization=util, strict=False))
    placements = []
    for _ in range(rng.randint(1, 6)):
        k = rng.choice(KERNELS)
        n = rng.randint(1, 3)
        dom = rng.choice(domains)
        sc = sc.placed(k, n, dom)
        placements.append(
            topology.Placed(Group.of(table2.kernel(k), arch, n), dom))
    ref = topology.predict_placed(topo, placements, strict=False,
                                  utilization=util)
    got = api.predict(sc)
    assert got.bw_group == tuple(ref.bw_group)
    assert got.total_bw == ref.total_bw
    for name in domains:
        assert got.domain_bw(name) == ref.domain_bw(name)


def test_placed_predict_respects_strict_capacity():
    sc = (api.Scenario.on("CLX").using("CLX")
          .placed("DCOPY", 21, "CLX/d0"))
    with pytest.raises(ValueError, match="overcommitted"):
        api.predict(sc)


# ---------------------------------------------------------------------------
# api.simulate == desync_batch.run_batch
# ---------------------------------------------------------------------------


def _native_programs(arch, n_ranks, steps, noise, seeds):
    """Build run_batch's native inputs the way the facade promises to:
    ensemble member m of base seed 0 draws from an independent stream
    seeded by ``derive_member_seed(0, m)`` (the splittable counter)."""
    batch = []
    for s in seeds:
        rng = random.Random(api.derive_member_seed(0, s))
        progs = []
        draws = [rng.expovariate(1 / noise) for _ in range(n_ranks)]
        for r in range(n_ranks):
            prog = [Idle(draws[r], tag="noise")]
            for item in steps:
                prog.append(item if not isinstance(item, Work)
                            else Work(item.kernel, item.bytes,
                                      tag=item.tag))
            progs.append(prog)
        batch.append(progs)
    return batch


@pytest.mark.parametrize("backend", BACKENDS)
def test_simulate_program_mode_bit_for_bit(backend):
    MB = 1e6
    steps = [Work("Schoenauer", 8 * MB, tag="symgs"),
             Work("DDOT2", 2 * MB, tag="ddot2"),
             Allreduce(),
             Work("DAXPY", 6 * MB, tag="daxpy")]
    ref = run_batch(_native_programs("CLX", 6, steps, 6e-5, range(4)),
                    "CLX", t_max=60.0, backend=backend)
    sc = (api.Scenario.on("CLX").ranks(6)
          .with_noise(6e-5, seed=0, ensemble=4)
          .step("Schoenauer", 8 * MB, tag="symgs")
          .step("DDOT2", 2 * MB, tag="ddot2")
          .barrier()
          .step("DAXPY", 6 * MB, tag="daxpy"))
    got = api.simulate(sc, t_max=60.0, backend=backend)
    assert got.raw.n_scenarios == ref.n_scenarios
    for b in range(ref.n_scenarios):
        assert got.records(b) == ref.records[b]
    np.testing.assert_array_equal(got.raw.t_end, ref.t_end)


def test_simulate_halo_bit_for_bit():
    MB = 1e6
    steps = [Work("DCOPY", 4 * MB, tag="copy"),
             WaitNeighbors(),
             Work("DDOT2", 2 * MB, tag="ddot2")]
    ref = run_batch(_native_programs("CLX", 5, steps, 4e-5, range(3)),
                    "CLX", t_max=60.0)
    sc = (api.Scenario.on("CLX").ranks(5)
          .with_noise(4e-5, seed=0, ensemble=3)
          .step("DCOPY", 4 * MB, tag="copy")
          .halo()
          .step("DDOT2", 2 * MB, tag="ddot2"))
    got = api.simulate(sc, t_max=60.0)
    for b in range(3):
        assert got.records(b) == ref.records[b]


def test_simulate_placed_topology_bit_for_bit():
    MB = 1e6
    topo = topology.preset("CLX-2S")
    placement = ["CLX/s0/d0", "CLX/s0/d0", "CLX/s1/d0", "CLX/s1/d0"]
    progs = [[Work("DCOPY", 2 * MB, tag="DCOPY")],
             [Work("DDOT2", 2 * MB, tag="DDOT2")],
             [Work("DCOPY", 2 * MB, tag="DCOPY")],
             [Work("DDOT2", 2 * MB, tag="DDOT2")]]
    ref = run_batch([progs], "CLX", topology=topo, placement=placement,
                    t_max=60.0)
    sc = (api.Scenario.on("CLX").using(topo)
          .run("DCOPY", 1, domain="CLX/s0/d0", bytes=2 * MB)
          .run("DDOT2", 1, domain="CLX/s0/d0", bytes=2 * MB)
          .run("DCOPY", 1, domain="CLX/s1/d0", bytes=2 * MB)
          .run("DDOT2", 1, domain="CLX/s1/d0", bytes=2 * MB))
    got = api.simulate(sc, t_max=60.0)
    assert got.records(0) == ref.records[0]


# ---------------------------------------------------------------------------
# Export round trip under random scenarios
# ---------------------------------------------------------------------------


@settings(max_examples=80, deadline=None)
@given(arch=archs, util=utils,
       ks=st.lists(kernel_names, min_size=1, max_size=4),
       seed=st.integers(min_value=0, max_value=10**6))
def test_dict_round_trip_property(arch, util, ks, seed):
    rng = random.Random(seed)
    ns = [rng.randint(0, 9) for _ in ks]
    p = api.predict(_scenario_from(arch, util, ks, ns))
    assert api.Prediction.from_dict(p.to_dict()) == p
