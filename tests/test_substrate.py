"""Substrate tests: data determinism, optimizer, schedules, compression,
checkpointing (atomic/async/elastic)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.checkpoint import (CheckpointManager, latest_step,
                              load_checkpoint, save_checkpoint)
from repro.data import HostLoader, SyntheticLM
from repro.optim import (adamw_init, adamw_update, compress_int8,
                         cosine_schedule, decompress_int8,
                         error_feedback_compress, global_norm,
                         linear_warmup)

CFG = configs.get_reduced("qwen2-0.5b")


# --------------------------------------------------------------------------
# Data
# --------------------------------------------------------------------------


def test_data_deterministic_across_restarts():
    ds = SyntheticLM(CFG, seq_len=32, global_batch=8)
    b1 = ds.batch(7)
    b2 = ds.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_data_host_sharding_partitions_global_batch():
    ds = SyntheticLM(CFG, seq_len=16, global_batch=8)
    full_like = [ds.batch(3, host_index=i, host_count=4)["tokens"]
                 for i in range(4)]
    assert all(t.shape == (2, 16) for t in full_like)
    # Different hosts see different data.
    assert not np.array_equal(full_like[0], full_like[1])


def test_data_labels_are_shifted_tokens():
    ds = SyntheticLM(CFG, seq_len=16, global_batch=2)
    b = ds.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_loader_prefetch_order():
    ds = SyntheticLM(CFG, seq_len=8, global_batch=2)
    loader = HostLoader(ds, start_step=5)
    try:
        got = next(iter(loader))
        np.testing.assert_array_equal(got["tokens"], ds.batch(5)["tokens"])
    finally:
        loader.close()


# --------------------------------------------------------------------------
# Optimizer
# --------------------------------------------------------------------------


def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([4.0, -3.0])}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params, lr=0.1,
                                     weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_adamw_clip_norm():
    params = {"w": jnp.ones(4)}
    state = adamw_init(params)
    g = {"w": jnp.full(4, 1e6)}
    p2, _ = adamw_update(g, state, params, lr=1e-3, clip_norm=1.0,
                         weight_decay=0.0)
    # Post-clip update is bounded by lr * O(1).
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 1e-2


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_schedules():
    f = linear_warmup(1.0, 10)
    assert float(f(0)) == pytest.approx(0.1)
    assert float(f(9)) == pytest.approx(1.0)
    g = cosine_schedule(1.0, 10, 110, final_frac=0.1)
    assert float(g(110)) == pytest.approx(0.1, abs=1e-2)
    assert float(g(5)) < 1.0


# --------------------------------------------------------------------------
# Compression
# --------------------------------------------------------------------------


@given(st.integers(min_value=1, max_value=2000), st.floats(0.1, 100.0))
@settings(max_examples=30, deadline=None)
def test_int8_roundtrip_error_bounded(n, scale):
    rng = np.random.default_rng(n)
    g = jnp.asarray(rng.standard_normal(n) * scale, jnp.float32)
    q, s = compress_int8(g)
    deq = decompress_int8(q, s, g.shape, jnp.float32)
    # Block-wise max error <= scale_block (1/127 of block max).
    err = np.max(np.abs(np.asarray(deq - g)))
    assert err <= float(jnp.max(jnp.abs(g))) / 127.0 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(512), jnp.float32)
    err = jnp.zeros_like(g)
    acc_true = np.zeros(512)
    acc_sent = np.zeros(512)
    for _ in range(50):
        q, s, err = error_feedback_compress(g, err)
        acc_true += np.asarray(g)
        acc_sent += np.asarray(decompress_int8(q, s, g.shape, jnp.float32))
    # Error feedback keeps the cumulative transmitted signal aligned.
    drift = np.max(np.abs(acc_sent - acc_true))
    assert drift <= float(jnp.max(jnp.abs(g))) / 127 + 1e-5


# --------------------------------------------------------------------------
# Checkpointing
# --------------------------------------------------------------------------


def _tree(x=1.0):
    return {"a": jnp.full((3, 2), x), "b": {"c": jnp.arange(4)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 10, _tree(2.5))
    assert latest_step(d) == 10
    restored, manifest = load_checkpoint(d, 10, _tree(0.0))
    np.testing.assert_array_equal(restored["a"], _tree(2.5)["a"])
    assert manifest["step"] == 10


def test_checkpoint_atomic_no_partial(tmp_path):
    d = str(tmp_path)
    # A leftover .tmp dir must be invisible to latest_step.
    os.makedirs(os.path.join(d, "step_00000005.tmp"))
    assert latest_step(d) is None


def test_checkpoint_manager_async_and_gc(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=2)
    for s in (10, 20, 30):
        mgr.save_async(s, _tree(float(s)))
    mgr.wait()
    assert latest_step(d) == 30
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                   if n.startswith("step_"))
    assert len(steps) == 2  # retention


def test_checkpoint_structure_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    with pytest.raises(ValueError, match="leaves"):
        load_checkpoint(d, 1, {"only": jnp.zeros(2)})


def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    """Restore onto explicit shardings (the elastic path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path)
    save_checkpoint(d, 2, _tree(3.0))
    mesh = jax.make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P())
    shardings = {"a": sh, "b": {"c": sh}}
    restored, _ = load_checkpoint(d, 2, _tree(0.0), shardings=shardings)
    assert restored["a"].sharding == sh
