"""Token-choice top-k Mixture-of-Experts layer (GShard-style dense dispatch).

The dispatch/combine einsums are the EP-friendly formulation: with the
expert axis sharded over the mesh "model" axis, XLA lowers the dispatch to
an all-to-all, which is exactly the collective the bandwidth-sharing
analysis treats as a high-f stream.

Capacity-based: each expert processes at most C = ceil(cap_factor * T * k / E)
tokens; overflow tokens are dropped (their contribution is the residual
pass-through) — the standard production trade for static shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers


def moe_params(cfg: ModelConfig, key):
    assert cfg.moe is not None
    e, d, ff = cfg.moe.n_experts, cfg.d_model, cfg.moe.d_ff_expert
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "router": layers.dense_init(ks[0], d, e, dt),
        "wi": (jax.random.normal(ks[1], (e, d, ff), jnp.float32)
               * scale).astype(dt),
        "wg": (jax.random.normal(ks[2], (e, d, ff), jnp.float32)
               * scale).astype(dt),
        "wo": (jax.random.normal(ks[3], (e, ff, d), jnp.float32)
               * (ff ** -0.5)).astype(dt),
    }
    return p


GROUP = 256   # tokens per dispatch group (GShard 'G' dimension)


def apply_moe(cfg: ModelConfig, p, x, *, cap_factor: float = 1.25,
              group_size: int = GROUP):
    """x: (B, S, D) -> (B, S, D), plus aux load-balancing loss.

    Grouped GShard dispatch: tokens are split into groups of ``group_size``
    and capacity is enforced PER GROUP — the dispatch tensor is
    (B, nG, G, E, C_g) with C_g = cap·G·k/E, so its footprint scales
    linearly in tokens (a single global capacity buffer would scale
    quadratically).  The (group, token) -> (expert, slot) einsum is the
    all-to-all the EP sharding turns into on the mesh.
    """
    b, s, d = x.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    g = min(group_size, s)
    while s % g:
        g -= 1
    ng = s // g
    cap = max(4, int(cap_factor * g * k / e))

    xg = x.reshape(b, ng, g, d)
    logits = (xg @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (B,nG,G,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (B,nG,G,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # One-hot expert assignment per slot: (B,nG,G,k,E).
    assign = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)
    # Queue position within (group, expert); slot 0 has priority across the
    # whole group, then slot 1, etc.
    a_flat = assign.transpose(0, 1, 3, 2, 4).reshape(b, ng, k * g, e)
    pos = jnp.cumsum(a_flat, axis=2) - a_flat
    within = (pos < cap) * a_flat
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap,
                            dtype=jnp.float32) * within[..., None]
    dispatch = pos_oh.reshape(b, ng, k, g, e, cap).transpose(0, 1, 3, 2, 4, 5)
    disp_tec = jnp.sum(dispatch, axis=3)                     # (B,nG,G,E,C)
    comb_tec = jnp.einsum("bgtkec,bgtk->bgtec", dispatch, gate_vals)

    # Dispatch: (B,nG,E,C,D) — with E sharded this is the all-to-all.
    expert_in = jnp.einsum("bgtec,bgtd->bgecd", disp_tec,
                           xg.astype(jnp.float32)).astype(x.dtype)
    h = jnp.einsum("bgecd,edf->bgecf", expert_in, p["wi"].astype(x.dtype))
    gt = jnp.einsum("bgecd,edf->bgecf", expert_in, p["wg"].astype(x.dtype))
    h = jax.nn.silu(gt.astype(jnp.float32)).astype(x.dtype) * h
    expert_out = jnp.einsum("bgecf,efd->bgecd", h, p["wo"].astype(x.dtype))

    out = jnp.einsum("bgtec,bgecd->bgtd", comb_tec,
                     expert_out.astype(jnp.float32))
    out = out.reshape(b, s, d).astype(x.dtype)

    # Aux load-balance loss (Switch-style): E * sum_e(frac_tokens*frac_prob)
    me = jnp.mean(probs, axis=(0, 1, 2))
    ce = jnp.mean(jnp.sum(assign, axis=3), axis=(0, 1, 2))
    aux = e * jnp.sum(me * ce)
    return out, aux
