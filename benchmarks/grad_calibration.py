"""Gradient-path payoff: Gauss-Newton calibration and gradient pod plans.

Two claims from the differentiable-chain PR, measured side by side
against the derivative-free baselines they replace:

* ``fit`` — jacobian-based Gauss-Newton refinement (the
  ``fit_scaling`` default) vs the retired golden-section bracket on the
  full ``BENCH_calibrate`` grid (every Table II kernel x architecture
  cell, 3-seed ensemble).  Acceptance: ``(f, b_s)`` agree to < 1e-3
  relative on every cell while Gauss-Newton spends fewer residual
  evaluations (537 vs 579 per cell); wall-clock for both passes is
  recorded.
* ``podplan`` — ``best_pod_plan(method="gradient")`` (projected
  descent on the analytic pod-step makespan + shortlist simulation) vs
  ``method="enumerate"`` (simulate every candidate) on a headline
  space of >= 10^4 load distributions, plus a recovery sweep over
  **every** ``topology.PRESETS`` entry.  Acceptance: the gradient
  winner's simulated step time is within 1 % of the enumerator's
  optimum on each preset (it recovers the exact argmin on the noiseless
  simulator, whose step time the analytic objective matches bitwise).

``python benchmarks/grad_calibration.py --out BENCH_grad.json`` writes
the committed artifact and exits nonzero if a bound is broken.
"""

from __future__ import annotations

import argparse
import gc
import json
import sys
import time
import warnings

import numpy as np

from repro.calibrate import fit_scaling, synthesize_ensemble
from repro.core import backend as backend_mod
from repro.core import table2, topology
from repro.runtime.overlap_schedule import RooflineTerms, best_pod_plan

FIT_REL_BOUND = 1e-3       # GN vs golden agreement, every cell
MAKESPAN_BOUND = 1.01      # gradient winner vs enumerator optimum
MIN_HEADLINE_CANDIDATES = 10_000

SEEDS = (0, 1, 2)
NOISE = 0.02
N_EVENTS = 4_000

HEADLINE_PRESET = "TPUv5e-pod8"
HEADLINE_TOTAL = 10        # compositions of 10 into 8 parts: 19448
# Per-preset recovery grids: total load split over the preset's D
# domains; totals chosen so the exhaustive baseline stays tractable.
RECOVERY_TOTALS = {1: 8, 2: 12, 4: 8, 8: 5}

TERMS = RooflineTerms(name="grad-bench", t_compute=0.004, t_memory=0.006,
                      t_collective=0.001, flops=2e12, hbm_bytes=8e9,
                      wire_bytes=1e9, model_flops=2e12)


def _time_us(fn, reps: int = 10, samples: int = 5) -> float:
    """Best-of-``samples`` mean over ``reps`` calls, in us, GC paused
    (same protocol as benchmarks/placement_scaling.py)."""
    best = float("inf")
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(samples):
            t0 = time.perf_counter()
            for _ in range(reps):
                fn()
            best = min(best, (time.perf_counter() - t0) / reps)
            gc.collect()
    finally:
        if gc_was_enabled:
            gc.enable()
    return best * 1e6


def _compositions(total: int, d: int):
    """Every way to split ``total`` units over ``d`` domains (all
    candidates share one total, as the gradient method requires)."""
    if d == 1:
        yield (total,)
        return
    for head in range(total + 1):
        for rest in _compositions(total - head, d - 1):
            yield (head, *rest)


# ---------------------------------------------------------------------------
# Part 1: Gauss-Newton vs golden-section on the BENCH_calibrate grid
# ---------------------------------------------------------------------------

def measure_fit() -> dict:
    kernels = sorted(table2.TABLE2)
    archs = list(table2.ARCHS)
    traces = synthesize_ensemble(kernels, archs, SEEDS, noise=NOISE,
                                 n_events=N_EVENTS)

    gn = fit_scaling(traces, utilization="queue")           # also warms
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        gold = fit_scaling(traces, utilization="queue", refine="golden")
        t_gn = _time_us(lambda: fit_scaling(traces, utilization="queue"))
        t_gold = _time_us(lambda: fit_scaling(traces, utilization="queue",
                                              refine="golden"))

    f_rel = np.abs(gn.f - gold.f) / np.abs(gold.f)
    bs_rel = np.abs(gn.bs - gold.bs) / np.abs(gold.bs)

    # Per-(kernel, arch) cell: max relative disagreement over the seed
    # ensemble — the per-cell evidence behind docs/calibration.md.
    cells: dict[tuple[str, str], dict] = {}
    for i, tr in enumerate(gn.traces):
        c = cells.setdefault((tr.kernel, tr.arch), {"f_rel": 0.0,
                                                    "bs_rel": 0.0})
        c["f_rel"] = max(c["f_rel"], float(f_rel[i]))
        c["bs_rel"] = max(c["bs_rel"], float(bs_rel[i]))

    return {
        "n_cells": len(cells),
        "n_traces": len(gn.traces),
        "seeds": list(SEEDS),
        "noise": NOISE,
        "backend": gn.backend,
        "max_f_rel": float(f_rel.max()),
        "max_bs_rel": float(bs_rel.max()),
        "n_evals_gauss_newton": gn.n_evals,
        "n_evals_golden": gold.n_evals,
        "fit_gauss_newton_us": round(t_gn, 1),
        "fit_golden_us": round(t_gold, 1),
        "max_f_sigma": float(np.max(gn.f_sigma)),
        "cells": [{"kernel": k, "arch": a, **v}
                  for (k, a), v in sorted(cells.items())],
    }


def check_fit(r: dict) -> bool:
    return (r["max_f_rel"] <= FIT_REL_BOUND
            and r["max_bs_rel"] <= FIT_REL_BOUND
            and r["n_evals_gauss_newton"] < r["n_evals_golden"]
            and r["n_cells"] == len(table2.TABLE2) * len(table2.ARCHS))


# ---------------------------------------------------------------------------
# Part 2: gradient pod plan vs full enumeration
# ---------------------------------------------------------------------------

def measure_podplan(presets=None) -> dict:
    presets = list(topology.PRESETS) if presets is None else list(presets)

    # Headline: the >= 10^4-candidate space where enumeration hurts.
    topo = topology.preset(HEADLINE_PRESET)
    cands = list(_compositions(HEADLINE_TOTAL, len(topo.domain_names)))
    t0 = time.perf_counter()
    i_enum, ev_enum = best_pod_plan(TERMS, cands, method="enumerate",
                                    topology=topo)
    t_enum = time.perf_counter() - t0
    t0 = time.perf_counter()
    i_grad, ev_grad = best_pod_plan(TERMS, cands, method="gradient",
                                    topology=topo)
    t_grad = time.perf_counter() - t0
    headline = {
        "preset": HEADLINE_PRESET,
        "n_candidates": len(cands),
        "enumerate_s": round(t_enum, 3),
        "gradient_s": round(t_grad, 4),
        "speedup": round(t_enum / t_grad, 1),
        "t_step_enumerate": ev_enum.t_step,
        "t_step_gradient": ev_grad.t_step,
        "recovered_argmin": bool(i_grad == i_enum),
        "makespan_ratio": ev_grad.t_step / ev_enum.t_step,
    }

    # Recovery sweep: every preset topology, exhaustive baseline.
    recovery = []
    for name in presets:
        topo = topology.preset(name)
        d = len(topo.domain_names)
        total = RECOVERY_TOTALS[d]
        cands = list(_compositions(total, d))
        i_e, ev_e = best_pod_plan(TERMS, cands, method="enumerate",
                                  topology=topo)
        i_g, ev_g = best_pod_plan(TERMS, cands, method="gradient",
                                  topology=topo)
        recovery.append({
            "preset": name,
            "domains": d,
            "n_candidates": len(cands),
            "recovered_argmin": bool(i_g == i_e),
            "makespan_ratio": ev_g.t_step / ev_e.t_step,
        })

    return {"headline": headline, "recovery": recovery}


def check_podplan(r: dict) -> bool:
    ok = r["headline"]["n_candidates"] >= MIN_HEADLINE_CANDIDATES
    ok &= r["headline"]["makespan_ratio"] <= MAKESPAN_BOUND
    for row in r["recovery"]:
        ok &= row["makespan_ratio"] <= MAKESPAN_BOUND
    return bool(ok)


def measure() -> dict:
    return {"fit": measure_fit(), "podplan": measure_podplan()}


def check(r: dict) -> bool:
    return check_fit(r["fit"]) and check_podplan(r["podplan"])


def rows():
    """Reduced grid for benchmarks/run.py (the driver stays fast; the
    full grid runs via __main__ / the committed artifact)."""
    fit = measure_fit()
    pod = measure_podplan(presets=("CLX-2S", "TPUv5e-pod4"))
    h = pod["headline"]
    ok = check_fit(fit) and check_podplan(pod)
    out = [
        ("grad/fit/gauss_newton", fit["fit_gauss_newton_us"],
         f"golden={fit['fit_golden_us']:.0f}us;"
         f"evals={fit['n_evals_gauss_newton']}v{fit['n_evals_golden']};"
         f"max_f_rel={fit['max_f_rel']:.1e}"),
        (f"grad/podplan/{h['preset']}/enumerate", h["enumerate_s"] * 1e6,
         f"candidates={h['n_candidates']}"),
        (f"grad/podplan/{h['preset']}/gradient", h["gradient_s"] * 1e6,
         f"speedup={h['speedup']:.0f}x;"
         f"recovered={h['recovered_argmin']}"),
        ("grad/check/bounds", 0.0,
         f"ok={ok};fit_rel<={FIT_REL_BOUND};"
         f"makespan<={MAKESPAN_BOUND}"),
    ]
    if not ok:
        raise AssertionError(
            f"gradient-path bounds broken: max_f_rel={fit['max_f_rel']:.2e}"
            f" max_bs_rel={fit['max_bs_rel']:.2e}"
            f" headline_ratio={h['makespan_ratio']:.4f}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args(argv)
    r = measure()
    ok = check(r)
    report = {
        "benchmark": "grad_calibration",
        "jax": backend_mod.HAVE_JAX,
        "bound_fit_rel": FIT_REL_BOUND,
        "bound_makespan_ratio": MAKESPAN_BOUND,
        "min_headline_candidates": MIN_HEADLINE_CANDIDATES,
        "ok": ok,
        "results": r,
    }
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.out}  (ok={ok})")
    fit, h = r["fit"], r["podplan"]["headline"]
    print(f"fit: {fit['n_cells']} cells  GN {fit['fit_gauss_newton_us']:.0f}us"
          f" ({fit['n_evals_gauss_newton']} evals)  golden"
          f" {fit['fit_golden_us']:.0f}us ({fit['n_evals_golden']} evals)"
          f"  max rel diff f={fit['max_f_rel']:.1e}"
          f" bs={fit['max_bs_rel']:.1e}")
    print(f"podplan: {h['n_candidates']} candidates on {h['preset']}  "
          f"enumerate {h['enumerate_s']:.2f}s  gradient {h['gradient_s']:.3f}s"
          f"  ({h['speedup']:.0f}x)  recovered={h['recovered_argmin']}")
    n_rec = sum(row["recovered_argmin"] for row in r["podplan"]["recovery"])
    print(f"recovery: argmin on {n_rec}/{len(r['podplan']['recovery'])}"
          f" presets (all within {MAKESPAN_BOUND - 1:.0%} makespan)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
