"""Versioned trace schema: the measurement side of the measure→model loop.

The paper's only model inputs — the memory request fraction ``f`` and the
saturated bandwidth ``b_s`` per kernel — "can either be measured directly
or predicted using the ECM model".  This module defines the *measured*
route's data format: bandwidth-vs-active-cores scaling curves
(:class:`ScalingTrace`) and paired-kernel share measurements
(:class:`PairTrace`), serialized as JSON or ndjson under an explicit
``schema`` version so traces recorded today keep loading tomorrow.

Users with real hardware record traces with LIKWID/perf and feed them to
:mod:`repro.calibrate.fit`; the hermetic container has no multicore x86,
so the microscopic queue simulator (:mod:`repro.core.memsim`) doubles as
the built-in synthetic trace generator (:func:`synthesize_scaling_trace`,
:func:`synthesize_pair_trace`) — which is also what lets the round-trip
certification (:mod:`repro.calibrate.certify`) exercise the full pipeline
end to end with a known ground truth.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterable, Sequence

import numpy as np

from ..core import memsim
from ..core.machine import X86_MACHINES
from ..core.sharing import Group
from ..core.table2 import TABLE2, KernelSpec

SCHEMA_VERSION = 1

#: Contention-domain sizes (paper Table I) — the default scaling range.
DOMAIN_CORES = {name: m.cores_per_domain for name, m in X86_MACHINES.items()}


@dataclasses.dataclass(frozen=True)
class ScalingTrace:
    """One homogeneous bandwidth-vs-active-cores curve.

    ``bandwidth[i]`` is the *aggregate* attained bandwidth [GB/s] with
    ``cores[i]`` active cores all running ``kernel`` on one contention
    domain of ``arch`` — the paper's Fig. 2-style saturation curve, and
    the input from which :mod:`repro.calibrate.fit` recovers ``(f, b_s)``.
    """

    kernel: str
    arch: str
    cores: tuple[int, ...]
    bandwidth: tuple[float, ...]
    seed: int | None = None       # generator / measurement-noise seed
    noise: float = 0.0            # relative sigma of applied noise
    source: str = "measured"      # "measured" | "memsim"

    def __post_init__(self):
        if len(self.cores) != len(self.bandwidth):
            raise ValueError(
                f"{self.kernel}/{self.arch}: {len(self.cores)} core counts "
                f"vs {len(self.bandwidth)} bandwidth samples")
        if not self.cores:
            raise ValueError(f"{self.kernel}/{self.arch}: empty trace")
        if any(c <= 0 for c in self.cores):
            raise ValueError(f"{self.kernel}/{self.arch}: core counts must "
                             f"be positive, got {self.cores}")
        if list(self.cores) != sorted(set(self.cores)):
            raise ValueError(f"{self.kernel}/{self.arch}: core counts must "
                             f"be strictly ascending, got {self.cores}")
        if any(b <= 0 for b in self.bandwidth):
            raise ValueError(f"{self.kernel}/{self.arch}: bandwidths must "
                             f"be positive, got {self.bandwidth}")

    def to_json_dict(self) -> dict:
        return {"schema": SCHEMA_VERSION, "kind": "scaling",
                "kernel": self.kernel, "arch": self.arch,
                "cores": list(self.cores),
                "bandwidth": list(self.bandwidth), "seed": self.seed,
                "noise": self.noise, "source": self.source}


@dataclasses.dataclass(frozen=True)
class PairTrace:
    """One paired-kernel share measurement (the paper's Fig. 6/8 points):
    group A runs ``kernels[0]`` on ``n[0]`` cores while group B runs
    ``kernels[1]`` on ``n[1]`` cores of the same domain; ``bandwidth``
    holds each group's attained aggregate [GB/s]."""

    kernels: tuple[str, str]
    arch: str
    n: tuple[int, int]
    bandwidth: tuple[float, float]
    seed: int | None = None
    source: str = "measured"

    def __post_init__(self):
        for field, want in (("kernels", 2), ("n", 2), ("bandwidth", 2)):
            if len(getattr(self, field)) != want:
                raise ValueError(f"pair trace {field} must have exactly "
                                 f"{want} entries")
        if any(x <= 0 for x in self.n):
            raise ValueError(f"pair trace core counts must be positive, "
                             f"got {self.n}")

    def to_json_dict(self) -> dict:
        return {"schema": SCHEMA_VERSION, "kind": "pair",
                "kernels": list(self.kernels), "arch": self.arch,
                "n": list(self.n), "bandwidth": list(self.bandwidth),
                "seed": self.seed, "source": self.source}


def _trace_from_dict(d: dict) -> ScalingTrace | PairTrace:
    schema = d.get("schema")
    if schema != SCHEMA_VERSION:
        raise ValueError(
            f"unsupported trace schema {schema!r} (this reader understands "
            f"schema {SCHEMA_VERSION}); regenerate or convert the trace")
    kind = d.get("kind")
    if kind == "scaling":
        return ScalingTrace(
            kernel=d["kernel"], arch=d["arch"], cores=tuple(d["cores"]),
            bandwidth=tuple(d["bandwidth"]), seed=d.get("seed"),
            noise=d.get("noise", 0.0), source=d.get("source", "measured"))
    if kind == "pair":
        return PairTrace(
            kernels=tuple(d["kernels"]), arch=d["arch"], n=tuple(d["n"]),
            bandwidth=tuple(d["bandwidth"]), seed=d.get("seed"),
            source=d.get("source", "measured"))
    raise ValueError(f"unknown trace kind {kind!r}")


@dataclasses.dataclass(frozen=True)
class TraceSet:
    """A loaded collection of traces, split by kind."""

    scaling: tuple[ScalingTrace, ...] = ()
    pairs: tuple[PairTrace, ...] = ()

    def __len__(self) -> int:
        return len(self.scaling) + len(self.pairs)

    def to_arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, list]:
        """Pack the scaling traces into padded cell arrays for the batched
        fit: ``(cores (C, N), bandwidth (C, N), mask (C, N), traces)``.
        Cell c is ``self.scaling[c]``; padding entries have ``mask``
        False and ``cores = 0``."""
        C = len(self.scaling)
        N = max((len(t.cores) for t in self.scaling), default=0)
        n = np.zeros((C, max(N, 1)))
        y = np.zeros((C, max(N, 1)))
        mask = np.zeros((C, max(N, 1)), dtype=bool)
        for c, tr in enumerate(self.scaling):
            k = len(tr.cores)
            n[c, :k] = tr.cores
            y[c, :k] = tr.bandwidth
            mask[c, :k] = True
        return n, y, mask, list(self.scaling)


def dump_traces(traces: Iterable[ScalingTrace | PairTrace],
                path: str | pathlib.Path, *, ndjson: bool = False) -> None:
    """Write traces as a schema-versioned JSON file (or ndjson when asked:
    one trace object per line, append-friendly for long measurement
    campaigns)."""
    path = pathlib.Path(path)
    dicts = [t.to_json_dict() for t in traces]
    if ndjson:
        path.write_text("".join(json.dumps(d) + "\n" for d in dicts))
    else:
        path.write_text(json.dumps(
            {"schema": SCHEMA_VERSION, "traces": dicts}, indent=2))


def load_traces(path: str | pathlib.Path) -> TraceSet:
    """Load a JSON or ndjson trace file into a :class:`TraceSet`.

    The format is sniffed from the content: a JSON object with a
    ``traces`` list, a bare JSON list, or newline-delimited JSON objects.
    Every record must carry ``schema == 1``.
    """
    text = pathlib.Path(path).read_text()
    stripped = text.lstrip()
    if stripped.startswith("{") or stripped.startswith("["):
        try:
            doc = json.loads(text)
        except json.JSONDecodeError:
            doc = None
        if isinstance(doc, list):
            dicts = doc
        elif isinstance(doc, dict) and "traces" in doc:
            # The wrapper's schema declaration covers records that do not
            # repeat it per-record.
            dicts = [{"schema": doc.get("schema"), **d}
                     for d in doc["traces"]]
        elif isinstance(doc, dict):
            dicts = [doc]       # single-record ndjson file
        else:                   # "{...}\n{...}" ndjson of objects
            dicts = [json.loads(line) for line in text.splitlines()
                     if line.strip()]
    else:
        raise ValueError(f"{path}: not a JSON/ndjson trace file")
    scaling, pairs = [], []
    for d in dicts:
        tr = _trace_from_dict(d)
        (scaling if isinstance(tr, ScalingTrace) else pairs).append(tr)
    return TraceSet(scaling=tuple(scaling), pairs=tuple(pairs))


# ---------------------------------------------------------------------------
# Built-in synthetic generator: the queue simulator plays LIKWID.
# ---------------------------------------------------------------------------


def _resolve(kernel: str | KernelSpec,
             specs: dict[str, KernelSpec] | None) -> KernelSpec:
    if isinstance(kernel, KernelSpec):
        return kernel
    return (specs or TABLE2)[kernel]


def synthesize_scaling_trace(kernel: str | KernelSpec, arch: str, *,
                             n_max: int | None = None,
                             seed: int | None = None, noise: float = 0.0,
                             n_events: int = 20_000,
                             specs: dict[str, KernelSpec] | None = None
                             ) -> ScalingTrace:
    """Generate one homogeneous scaling curve with the queue simulator.

    Runs ``memsim`` with ``n = 1..n_max`` cores of ``kernel`` (default
    ``n_max``: the architecture's contention-domain size) and, when
    ``noise > 0``, multiplies each sample by seeded lognormal-ish
    ``1 + N(0, noise)`` measurement scatter.  ``seed`` drives both the
    simulator's phase jitter and the noise draw, so identical seeds give
    identical traces (tested) and a seed ensemble gives the scatter the
    fit's confidence intervals average over.
    """
    spec = _resolve(kernel, specs)
    if n_max is None:
        n_max = DOMAIN_CORES[arch]
    rng = np.random.default_rng(seed)
    cores = tuple(range(1, n_max + 1))
    bw = []
    for n in cores:
        sim_seed = None if seed is None else int(rng.integers(2**31))
        res = memsim.simulate_result([Group.of(spec, arch, n)],
                                     seed=sim_seed, n_events=n_events)
        bw.append(res.bw[0])
    if noise > 0.0:
        factors = np.maximum(1.0 + noise * rng.standard_normal(len(bw)),
                             0.05)
        bw = [b * float(c) for b, c in zip(bw, factors)]
    return ScalingTrace(kernel=spec.name, arch=arch, cores=cores,
                        bandwidth=tuple(bw), seed=seed, noise=noise,
                        source="memsim")


def synthesize_pair_trace(kernel_a: str | KernelSpec,
                          kernel_b: str | KernelSpec, arch: str,
                          n_a: int, n_b: int, *, seed: int | None = None,
                          n_events: int = 20_000,
                          specs: dict[str, KernelSpec] | None = None
                          ) -> PairTrace:
    """Generate one paired-share measurement with the queue simulator —
    the held-out data the certification predicts from fitted specs."""
    a, b = _resolve(kernel_a, specs), _resolve(kernel_b, specs)
    res = memsim.simulate_result(
        [Group.of(a, arch, n_a), Group.of(b, arch, n_b)],
        seed=seed, n_events=n_events)
    return PairTrace(kernels=(a.name, b.name), arch=arch, n=(n_a, n_b),
                     bandwidth=(res.bw[0], res.bw[1]), seed=seed,
                     source="memsim")


def synthesize_ensemble(kernels: Sequence[str | KernelSpec],
                        archs: Sequence[str], seeds: Sequence[int], *,
                        n_max: int | None = None, noise: float = 0.02,
                        n_events: int = 20_000,
                        specs: dict[str, KernelSpec] | None = None
                        ) -> TraceSet:
    """The full (kernel × arch × seed) scaling-trace grid — one cell per
    trace, ready for the single-pass batched fit."""
    out = [synthesize_scaling_trace(k, arch, n_max=n_max, seed=s,
                                    noise=noise, n_events=n_events,
                                    specs=specs)
           for k in kernels for arch in archs for s in seeds]
    return TraceSet(scaling=tuple(out))
