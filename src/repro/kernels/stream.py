"""Pallas TPU kernels for the paper's Table II streaming suite.

These are the calibration workloads of the reproduction: the paper measured
(f, b_s) for each of these loops on x86; on TPU they characterize the HBM
interface the same way.  Each kernel is tiled for VMEM with explicit
BlockSpecs: 1-D arrays are viewed as (rows, LANES) with LANES = 128 (the VPU
lane count) and the grid walks row-blocks sized to keep the working set of
all streams within a VMEM budget.

Map kernels (DSCAL/DAXPY/ADD/STREAM/WAXPBY/DCOPY/Schoenauer) write one output
stream; reduction kernels (vectorSUM/DDOT1/2/3) accumulate a scalar across
grid steps through a (1, 1) output block pinned to the same location (TPU
grid is sequential, so cross-step accumulation is well-defined).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8
DEFAULT_BLOCK_ROWS = 256          # 256 x 128 f32 = 128 KiB per stream block


def _fit_block(rows: int, block_rows: int) -> int:
    """Largest divisor of ``rows`` not exceeding ``block_rows``."""
    block_rows = min(block_rows, rows)
    while rows % block_rows:
        block_rows -= 1
    return block_rows


# ---------------------------------------------------------------------------
# Map kernels: out = expr(*ins)
# ---------------------------------------------------------------------------

_MAP_EXPRS = {
    "dscal":      lambda s, a: s * a,
    "daxpy":      lambda s, a, b: a + s * b,
    "add":        lambda s, a, b: a + b,
    "stream":     lambda s, a, b: a + s * b,          # STREAM triad
    "waxpby":     lambda s, a, b: s[0] * a + s[1] * b,
    "dcopy":      lambda s, a: a,
    "schoenauer": lambda s, a, b, c: a + b * c,
}


def _map_kernel(expr, scalar_ref, *refs):
    ins = [r[...] for r in refs[:-1]]
    out = refs[-1]
    out[...] = expr(scalar_ref[0], *ins)  # scalar row: (n_scalars,)


#: Kernels whose Table II form writes back into a read operand
#: (``a[i] = s*a[i]``, ``a[i] = a[i] + s*b[i]``): the value maps the
#: kernel name to the index of the overwritten array operand.
_INPLACE_TARGET = {"dscal": 0, "daxpy": 0}


def map_stream(name: str, scalar: jax.Array, *arrays: jax.Array,
               block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = True,
               in_place: bool = False) -> jax.Array:
    """Run one Table II map kernel over equal-shaped 1-D arrays.

    ``in_place=True`` declares the paper's C semantics for the kernels
    that overwrite a read operand (DSCAL/DAXPY): the output buffer
    aliases that input via ``input_output_aliases``, so the written
    cache lines are already present and no write-allocate (RFO) stream
    exists — which is exactly what the static traffic auditor derives
    from the alias declaration.  Functionally identical to the default
    out-of-place form.
    """
    expr = _MAP_EXPRS[name]
    n = arrays[0].shape[0]
    if n % LANES:
        raise ValueError(f"size {n} not a multiple of {LANES}")
    rows = n // LANES
    block_rows = _fit_block(rows, block_rows)
    grid = (rows // block_rows,)
    views = [a.reshape(rows, LANES) for a in arrays]
    scalar2d = jnp.atleast_1d(scalar).reshape(1, -1)
    extra = {}
    if in_place:
        target = _INPLACE_TARGET.get(name)
        if target is None:
            raise ValueError(
                f"in_place=True is only meaningful for the kernels that "
                f"overwrite a read operand "
                f"({sorted(_INPLACE_TARGET)}); {name!r} writes a "
                f"distinct output array")
        # +1 skips the scalar operand in the pallas input numbering.
        extra["input_output_aliases"] = {1 + target: 0}

    out = pl.pallas_call(
        functools.partial(_map_kernel, expr),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, scalar2d.shape[1]), lambda i: (0, 0)),
            *[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
              for _ in views],
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), arrays[0].dtype),
        interpret=interpret,
        **extra,
    )(scalar2d, *views)
    return out.reshape(n)


# ---------------------------------------------------------------------------
# Reduction kernels: scalar += expr(*ins)
# ---------------------------------------------------------------------------

_REDUCE_EXPRS = {
    "vectorsum": lambda a: a,
    "ddot1":     lambda a: a * a,
    "ddot2":     lambda a, b: a * b,
    "ddot3":     lambda a, b, c: a * b * c,
}


def _reduce_kernel(expr, *refs):
    *ins, out = refs
    partial = jnp.sum(expr(*[r[...] for r in ins]))

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out[0, 0] = jnp.zeros((), out.dtype)

    out[0, 0] += partial.astype(out.dtype)


def reduce_stream(name: str, *arrays: jax.Array,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = True) -> jax.Array:
    """Run one Table II reduction kernel; returns a scalar."""
    expr = _REDUCE_EXPRS[name]
    n = arrays[0].shape[0]
    if n % LANES:
        raise ValueError(f"size {n} not a multiple of {LANES}")
    rows = n // LANES
    block_rows = _fit_block(rows, block_rows)
    grid = (rows // block_rows,)
    views = [a.reshape(rows, LANES) for a in arrays]

    out = pl.pallas_call(
        functools.partial(_reduce_kernel, expr),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
                  for _ in views],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(*views)
    return out[0, 0]
