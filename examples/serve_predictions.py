"""Prediction-as-a-service round trip, in one process.

Boots the ndjson-over-HTTP serving subsystem (``repro.serve``) on an
ephemeral port, streams a small workload through ``/v1/solve`` with the
stdlib client, inspects ``/statsz``, and drains gracefully.  The same
wire protocol works from any HTTP client::

    python -m repro.serve --port 8080 --warmup CLX/DCOPY:12/DDOT2:8
    printf '{"arch": "CLX", "groups": [...]}\n' | \
        curl -sN --data-binary @- http://127.0.0.1:8080/v1/solve

See docs/serving.md for the architecture (plan cache -> coalescer ->
transport) and the full request schema.
"""

import asyncio

from repro import api
from repro.serve import App, ServeConfig, client


def workload(n):
    """n same-structure requests with different core splits: they
    coalesce into batched solves through one cached plan."""
    return [{"id": k, "arch": "CLX",
             "groups": [{"kernel": "DCOPY", "n": 1 + k % 19},
                        {"kernel": "DDOT2", "n": 20 - (1 + k % 19)}]}
            for k in range(n)]


async def main():
    app = App(ServeConfig(tick_s=1e-3))
    # Precompile the workload's structure over the buckets it can hit,
    # so the serving phase below is a pure plan-cache-hit run.
    app.cache.warmup(api.Scenario.on("CLX").run("DCOPY", 12)
                     .run("DDOT2", 8), buckets=(1, 32))
    port = await app.start(port=0)
    print(f"serving on 127.0.0.1:{port}")

    # The blocking stdlib client runs in a worker thread; the server
    # (and its coalescer) lives on this loop.
    loop = asyncio.get_running_loop()
    rows = await loop.run_in_executor(
        None, lambda: client.solve("127.0.0.1", port, workload(24)))
    ok = [r for r in rows if r.get("ok")]
    print(f"{len(ok)}/{len(rows)} requests ok; "
          f"first total_bw = {ok[0]['total_bw']:.1f} GB/s")
    assert len(ok) == len(rows) == 24
    assert [r["id"] for r in rows] == list(range(24)), "order preserved"

    status, stats = await loop.run_in_executor(
        None, lambda: client.get_json("127.0.0.1", port, "/statsz"))
    co, pc = stats["coalescer"], stats["plan_cache"]
    print(f"statsz: accepted={co['accepted']} ticks={co['ticks']} "
          f"plan_cache hits={pc['hits']} misses={pc['misses']}")
    assert status == 200 and co["completed"] == 24
    assert pc["hits"] >= 1, "warmed structure must hit, not recompile"

    await app.shutdown(drain=True)
    print("drained cleanly")


if __name__ == "__main__":
    asyncio.run(main())
