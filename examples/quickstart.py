"""Quickstart: the paper's bandwidth-sharing model through the facade.

Declare *what* runs (a Scenario); the library picks *how* to solve it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro import api
from repro.core import memsim, sharing, table2

# Two kernels sharing a fully-populated 20-core Cascade Lake socket:
# DCOPY on 12 cores, DDOT2 on 8.
scenario = api.Scenario.on("CLX").run("DCOPY", 12).run("DDOT2", 8)

for g in api.predict(scenario).groups:
    print(f"{g.name:6s}: f={g.f:.3f}  b_s={g.bs:.1f} GB/s  "
          f"[{g.provenance}]")

pred = api.predict(scenario)
print(f"\nEq.4 mixed envelope : {pred.b_overlap:.1f} GB/s")
print(f"Eq.5 request shares : alpha = {pred.alphas[0]:.3f} / "
      f"{pred.alphas[1]:.3f}")
print(f"per-core bandwidth  : DCOPY {pred.bw_per_core[0]:.2f}  "
      f"DDOT2 {pred.bw_per_core[1]:.2f} GB/s")

# Validate against the microscopic queue simulator (the stand-in for the
# paper's LIKWID measurements).
dcopy, ddot2 = table2.kernel("DCOPY"), table2.kernel("DDOT2")
sim = memsim.simulate([sharing.Group.of(dcopy, "CLX", 12),
                       sharing.Group.of(ddot2, "CLX", 8)])
print(f"queue simulator     : DCOPY {sim[0]/12:.2f}  DDOT2 {sim[1]/8:.2f} "
      "GB/s per core")
err = max(abs(sim[0] / 12 - pred.bw_per_core[0]) / pred.bw_per_core[0],
          abs(sim[1] / 8 - pred.bw_per_core[1]) / pred.bw_per_core[1])
print(f"model error         : {err*100:.1f}%  (paper: < 8%)")

# Every prediction exports to one machine-readable schema.
print(f"\nas dict             : total_bw="
      f"{pred.to_dict()['total_bw']:.1f} GB/s "
      f"(schema v{pred.to_dict()['schema']})")
