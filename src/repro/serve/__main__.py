"""``python -m repro.serve``: run the prediction service.

Binds the ndjson-over-HTTP front end and serves until SIGINT/SIGTERM,
then drains gracefully (queued requests finish; new ones get 503).
``--warmup ARCH/KERNEL:N[/KERNEL:N...]`` precompiles the plans for a
scenario structure at the given ``--warmup-buckets`` so the first live
tick is a cache hit.

(The *model-decode* demo formerly reachable in this namespace lives at
:mod:`repro.launch.serve` / ``examples/serve_decode.py``.)
"""

from __future__ import annotations

import argparse
import asyncio
import json
import signal
import sys

from .. import api
from ..core import backend as backend_mod
from .coalesce import ServeConfig
from .http import App


def _warmup_scenario(spec: str) -> "api.Scenario":
    """Parse ``ARCH/KERNEL:N[/KERNEL:N...]`` into a scenario."""
    arch, *groups = spec.split("/")
    if not groups:
        raise SystemExit(
            f"--warmup {spec!r}: expected ARCH/KERNEL:N[/KERNEL:N...]")
    sc = api.Scenario.on(arch)
    for g in groups:
        kernel, _, n = g.partition(":")
        sc = sc.run(kernel, int(n or 1))
    return sc


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="prediction-as-a-service over the bandwidth-sharing "
                    "model (ndjson over HTTP; see docs/serving.md)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787,
                    help="0 picks a free port (printed on startup)")
    ap.add_argument("--tick-ms", type=float, default=1.0,
                    help="coalescing window (ms)")
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--max-queue", type=int, default=1024)
    ap.add_argument("--deadline-ms", type=float, default=30000.0,
                    help="default per-request deadline (ms); requests "
                         "may override per line")
    ap.add_argument("--cache-entries", type=int, default=128,
                    help="plan-cache LRU capacity")
    ap.add_argument("--warmup", action="append", default=[],
                    metavar="ARCH/KERNEL:N[/KERNEL:N...]",
                    help="precompile plans for this structure "
                         "(repeatable)")
    ap.add_argument("--warmup-buckets", default="1,64",
                    help="comma-separated batch sizes to warm "
                         "(rounded up to power-of-two buckets)")
    args = ap.parse_args(argv)

    config = ServeConfig(
        tick_s=args.tick_ms / 1e3, max_batch=args.max_batch,
        max_queue=args.max_queue,
        default_deadline_s=(args.deadline_ms / 1e3
                            if args.deadline_ms > 0 else None),
        cache_entries=args.cache_entries)
    return asyncio.run(_serve(args, config))


async def _serve(args, config: ServeConfig) -> int:
    app = App(config)
    buckets = [int(b) for b in args.warmup_buckets.split(",") if b]
    for spec in args.warmup:
        built = app.cache.warmup(_warmup_scenario(spec), buckets=buckets)
        print(f"warmup {spec}: {built} plan(s) compiled", flush=True)
    port = await app.start(args.host, args.port)
    print(f"repro.serve: serving on http://{args.host}:{port} "
          f"(tick {config.tick_s * 1e3:g} ms, max_batch "
          f"{config.max_batch}, backend substrate "
          f"{'jax+numpy' if backend_mod.HAVE_JAX else 'numpy'})",
          flush=True)

    stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    for sig in (signal.SIGINT, signal.SIGTERM):
        try:
            loop.add_signal_handler(sig, stop.set)
        except NotImplementedError:   # pragma: no cover - non-unix
            signal.signal(sig, lambda *_: stop.set())
    await stop.wait()
    print("repro.serve: draining...", flush=True)
    await app.shutdown(drain=True)
    stats = app.coalescer.stats()
    print("repro.serve: drained "
          + json.dumps({k: stats[k] for k in
                        ("accepted", "completed", "errors", "expired",
                         "rejected")}), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
