"""Paper Table II: the loop-kernel suite with its measured characteristics.

Each :class:`KernelSpec` carries the *only two inputs the sharing model needs*
(per architecture): the memory request fraction ``f`` and the saturated
bandwidth ``b_s``.  It also carries the stream decomposition (R+W+RFO) and
flops/iteration so the analytic ECM path (core/ecm.py) can *predict* ``f``
instead of using the measured value.

Values marked in ``RECONSTRUCTED`` were unreadable in the archived table and
are filled by interpolation consistent with the paper's stated invariants
(read-only kernels saturate 5–15 % higher than write kernels; CLX has the
smallest spread in both ``f`` and ``b_s``; on Rome ``f`` is close to 1 for
streaming kernels and ``f_DAXPY > f_DSCAL``, reversed vs. Intel).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

import numpy as np

ARCHS = ("BDW-1", "BDW-2", "CLX", "ROME")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One loop kernel of the paper's suite.

    ``reads``/``writes``/``rfo`` count *cache-line streams* over the relevant
    bottleneck per iteration (memory for streaming kernels, L3 for stencils).
    """

    name: str
    body: str                      # pseudo-code, documentation only
    reads: int
    writes: int
    rfo: int
    flops_per_iter: float
    f: Mapping[str, float]         # memory request fraction, per arch
    bs: Mapping[str, float]        # saturated bandwidth [GB/s], per arch
    read_only: bool = False
    # Python oracle used by the desync simulator & benchmarks (element-wise).
    ref: Callable[..., np.ndarray] | None = dataclasses.field(
        default=None, compare=False
    )

    @property
    def elem_transfers(self) -> int:
        return self.reads + self.writes + self.rfo

    @property
    def bytes_per_iter(self) -> float:
        return 8.0 * self.elem_transfers  # double precision

    @property
    def code_balance(self) -> float:
        """B_c [B/F].  ``inf`` for flop-free kernels (DCOPY)."""
        if self.flops_per_iter == 0:
            return float("inf")
        return self.bytes_per_iter / self.flops_per_iter

    def single_core_bw(self, arch: str) -> float:
        """Paper Eq. 3 inverted: b_meas = f * b_s."""
        return self.f[arch] * self.bs[arch]

    @classmethod
    def synthetic(cls, name: str, f: float, bs: float, *,
                  arch: str = "TPU") -> "KernelSpec":
        """A minimal spec carrying only the two sharing-model inputs —
        for callers (straggler monitor, pod planners, tests) that model
        custom phases rather than Table II kernels."""
        return cls(name=name, body="", reads=1, writes=0, rfo=0,
                   flops_per_iter=1, f={arch: f}, bs={arch: bs})

    @classmethod
    def from_static_analysis(cls, fn, args=(), *, machine=None,
                             name: str | None = None, reuse: bool = True,
                             write_allocate: bool = True) -> "KernelSpec":
        """Derive a spec from the kernel's *own code*: trace
        ``fn(*args)``, walk the jaxpr for its stream decomposition and
        flop count (:mod:`repro.analysis`), and predict ``(f, b_s)``
        through the ECM bridge — Table II rows without hand
        transcription.  ``machine=None`` covers every Table II
        architecture; ``reuse``/``write_allocate`` are the layer-
        condition and RFO policy knobs of
        :func:`repro.analysis.features.derive`."""
        # Lazy import: the api facade sits above core (same pattern as
        # the error helper in :func:`kernel` below).
        from ..api.registry import from_static_analysis
        return from_static_analysis(
            fn, args, machine=machine, name=name, reuse=reuse,
            write_allocate=write_allocate).spec

    @classmethod
    def from_calibration(cls, name: str, f: Mapping[str, float],
                         bs: Mapping[str, float], *,
                         template: "KernelSpec | None" = None
                         ) -> "KernelSpec":
        """Build a first-class spec from *calibrated* model inputs.

        ``f``/``bs`` are per-architecture mappings recovered by
        :mod:`repro.calibrate` from measured (or simulated) scaling
        curves — the paper's "measured directly" route, closing the
        measure→model loop.  When ``template`` names an existing spec
        (e.g. the Table II row being re-derived), its stream
        decomposition, body, and reference oracle are kept so ECM
        prediction and the desync simulator work on the calibrated spec
        unchanged; otherwise a minimal streaming decomposition is
        assumed, as in :meth:`synthetic`.

        Every value is validated against the model's admissible ranges
        (``0 < f <= 1``, ``bs > 0``) — calibration noise must not smuggle
        unphysical inputs into Eqs. 4–5.
        """
        f = dict(f)
        bs = dict(bs)
        if set(f) != set(bs):
            raise ValueError(
                f"architecture sets differ: f has {sorted(f)}, "
                f"bs has {sorted(bs)}")
        for arch in f:
            if not 0.0 < f[arch] <= 1.0:
                raise ValueError(
                    f"calibrated f[{arch!r}] = {f[arch]} outside (0, 1]")
            if not bs[arch] > 0.0:
                raise ValueError(
                    f"calibrated bs[{arch!r}] = {bs[arch]} must be > 0")
        if template is not None:
            return dataclasses.replace(template, name=name, f=f, bs=bs)
        return cls(name=name, body="", reads=1, writes=0, rfo=0,
                   flops_per_iter=1, f=f, bs=bs)


def _spec(name, body, r, w, rfo, flops, f, bs, read_only=False) -> KernelSpec:
    return KernelSpec(
        name=name, body=body, reads=r, writes=w, rfo=rfo,
        flops_per_iter=flops,
        f=dict(zip(ARCHS, f)), bs=dict(zip(ARCHS, bs)),
        read_only=read_only,
    )


# Per-arch values ordered (BDW-1, BDW-2, CLX, ROME).
RECONSTRUCTED: frozenset[tuple[str, str, str]] = frozenset({
    # (kernel, field, arch) triples filled by interpolation — see module doc.
    ("vectorSUM", "f", "BDW-2"), ("vectorSUM", "f", "CLX"), ("vectorSUM", "f", "ROME"),
    ("vectorSUM", "bs", "BDW-1"), ("vectorSUM", "bs", "ROME"),
    ("DDOT1", "f", "BDW-1"), ("DDOT1", "f", "CLX"), ("DDOT1", "f", "ROME"),
    ("DDOT1", "bs", "BDW-1"), ("DDOT1", "bs", "ROME"),
    ("DDOT2", "f", "BDW-1"), ("DDOT2", "f", "CLX"), ("DDOT2", "f", "ROME"),
    ("DDOT2", "bs", "BDW-1"), ("DDOT2", "bs", "ROME"),
    ("DDOT3", "f", "BDW-1"), ("DDOT3", "f", "BDW-2"), ("DDOT3", "f", "CLX"),
    ("DDOT3", "f", "ROME"), ("DDOT3", "bs", "BDW-1"), ("DDOT3", "bs", "ROME"),
    ("DSCAL", "f", "CLX"), ("DSCAL", "f", "ROME"),
    ("DSCAL", "bs", "BDW-2"), ("DSCAL", "bs", "CLX"),
    ("DAXPY", "f", "BDW-1"), ("DAXPY", "f", "CLX"), ("DAXPY", "f", "ROME"),
    ("DAXPY", "bs", "BDW-1"),
})

TABLE2: dict[str, KernelSpec] = {s.name: s for s in [
    # --- read-only -------------------------------------------------------
    _spec("vectorSUM", "s += a[i]", 1, 0, 0, 1,
          f=(0.241, 0.180, 0.150, 0.780),
          bs=(63.8, 66.9, 111.1, 36.0), read_only=True),
    _spec("DDOT1", "s += a[i]*a[i]", 1, 0, 0, 2,
          f=(0.240, 0.178, 0.150, 0.780),
          bs=(63.7, 66.7, 110.5, 36.0), read_only=True),
    _spec("DDOT2", "s += a[i]*b[i]", 2, 0, 0, 2,
          f=(0.252, 0.179, 0.151, 0.790),
          bs=(63.2, 65.8, 108.7, 35.8), read_only=True),
    _spec("DDOT3", "s += a[i]*b[i]*c[i]", 3, 0, 0, 3,
          f=(0.255, 0.181, 0.153, 0.800),
          bs=(63.0, 65.5, 100.9, 35.5), read_only=True),
    # --- read-write ------------------------------------------------------
    _spec("DSCAL", "a[i] = s*a[i]", 1, 1, 0, 1,
          f=(0.374, 0.301, 0.215, 0.780),
          bs=(54.1, 61.5, 103.0, 34.9)),
    _spec("DAXPY", "a[i] = a[i] + s*b[i]", 2, 1, 0, 2,
          f=(0.315, 0.239, 0.205, 0.820),
          bs=(54.0, 60.8, 102.5, 32.6)),
    _spec("ADD", "a[i] = b[i] + c[i]", 2, 1, 1, 1,
          f=(0.309, 0.228, 0.199, 0.831),
          bs=(53.1, 62.2, 102.0, 32.2)),
    _spec("STREAM", "a[i] = b[i] + s*c[i]", 2, 1, 1, 2,
          f=(0.309, 0.228, 0.199, 0.838),
          bs=(53.2, 62.2, 102.4, 32.2)),
    _spec("WAXPBY", "a[i] = r*b[i] + s*c[i]", 2, 1, 1, 3,
          f=(0.309, 0.228, 0.199, 0.842),
          bs=(53.2, 62.2, 102.4, 32.2)),
    _spec("DCOPY", "a[i] = b[i]", 1, 1, 1, 0,
          f=(0.320, 0.242, 0.190, 0.803),
          bs=(53.5, 60.9, 104.2, 32.5)),
    _spec("Schoenauer", "a[i] = b[i] + c[i]*d[i]", 3, 1, 1, 2,
          f=(0.299, 0.223, 0.185, 0.859),
          bs=(53.1, 60.5, 101.7, 31.7)),
    # --- 2d 5-point stencils (transfers & balance w.r.t. L3) -------------
    _spec("JacobiL2-v1", "b[j][i] = s*(a[j][i±1] + a[j±1][i]); LC@L2 ok",
          1, 1, 1, 4,
          f=(0.252, 0.195, 0.157, 0.749),
          bs=(53.6, 60.9, 104.1, 32.8)),
    _spec("JacobiL3-v1", "same, LC@L2 violated (5 streams in L3)",
          3, 1, 1, 4,
          f=(0.141, 0.104, 0.100, 0.542),
          bs=(53.2, 60.5, 103.2, 32.6)),
    _spec("JacobiL2-v2", "residual-tracking 5-point stencil; LC@L2 ok",
          2, 1, 1, 13,
          f=(0.247, 0.188, 0.167, 0.804),
          bs=(53.5, 62.3, 102.9, 33.2)),
    _spec("JacobiL3-v2", "same, LC@L2 violated",
          4, 1, 1, 13,
          f=(0.142, 0.105, 0.088, 0.458),
          bs=(52.9, 60.8, 103.2, 32.1)),
]}

# Code-balance values quoted in the paper (B/F), for validation of our
# stream decomposition.  Jacobi balances are per *lattice site update* over
# the L3 boundary; v2 counts the full flop set of the residual form.
PAPER_CODE_BALANCE: dict[str, float] = {
    "vectorSUM": 8.0, "DDOT1": 4.0, "DDOT2": 8.0, "DDOT3": 8.0,
    "DSCAL": 16.0, "DAXPY": 12.0, "ADD": 32.0, "STREAM": 16.0,
    "WAXPBY": 10.67, "Schoenauer": 20.0,
    "JacobiL2-v1": 6.0, "JacobiL3-v1": 10.0,
    "JacobiL2-v2": 2.46, "JacobiL3-v2": 3.69,
}

# The 10 kernels of the paper's Fig. 9 pairing matrix.
FIG9_KERNELS = (
    "vectorSUM", "DDOT2", "DDOT3", "DCOPY", "Schoenauer",
    "DAXPY", "DSCAL", "JacobiL2-v1", "JacobiL3-v1", "STREAM",
)


def kernel(name: str) -> KernelSpec:
    try:
        return TABLE2[name]
    except KeyError:
        # Lazy import: the api facade sits above core, so core modules
        # only reach for its shared error helper at raise time.
        from ..api.registry import unknown_key_error
        raise unknown_key_error("kernel", name, TABLE2) from None
