"""The facade's one-shot verbs: ``predict(scenario)`` and
``simulate(scenario)``.

Both are sugar over the two-phase plan API (:mod:`repro.api.plan`):
``predict(x)`` is ``compile(x, verb="predict").run()`` and
``simulate(x)`` is ``compile(x, verb="simulate").run(...)`` — one trace,
one run, results bit-for-bit identical to the compiled path (that
equivalence is a tested invariant).  Callers that evaluate the same
structure repeatedly — sweeps, calibration inner loops, pod-plan
searches — should hold the plan and call ``run`` themselves.

The dispatch table (chosen at compile time, see
:func:`repro.api.plan.compile`):

=====================  =====================================================
scenario shape          engine
=====================  =====================================================
single, unplaced        scalar reference path (``sharing.predict``)
single, placed          topology solver (``topology.predict_placed``)
batch, unplaced         batched array solver (``sharing.solve_arrays``) —
                        numpy, or the substrate's cached jitted jax solver
                        when importable and B is at least the configured
                        cutoff (``REPRO_JAX_CUTOFF`` / ``jax_cutoff=``)
batch, placed on one    placed-grid solver
topology                (``sharing.solve_placed_batch`` over the packed
                        ``(B, D, K)`` occupancy grid; dispatch sees the
                        flattened ``B·D`` row count)
any, ``simulate``       batched desync event engine
                        (``desync_batch.run_encoded``; numpy reference or
                        the cached jitted ``lax.while_loop`` on request;
                        batch × noise-ensemble grids fuse into one run)
=====================  =====================================================

The old module-level entry points stay exactly as they are — they *are*
the engines — so the facade adds dispatch and a uniform result schema
(:mod:`repro.api.results`), never a second implementation.  Backend
resolution itself lives in one place for the whole tree:
:func:`repro.core.backend.resolve`.
"""

from __future__ import annotations

from ..core import backend as backend_mod
from .plan import compile as compile_plan
from .results import (BatchPrediction, PlacedBatchPrediction, Prediction,
                      SimulationResult)
from .scenario import Scenario, ScenarioBatch

#: Default ``backend="auto"`` jax cutoff (see
#: :data:`repro.core.backend.DEFAULT_JAX_CUTOFF`).  Kept here as the
#: facade-level alias; the effective value honors the
#: ``REPRO_JAX_CUTOFF`` environment variable and per-call
#: ``jax_cutoff=`` overrides.
JAX_BATCH_CUTOFF = backend_mod.DEFAULT_JAX_CUTOFF


def predict(scenario: Scenario | ScenarioBatch, *,
            backend: str | None = None,
            jax_cutoff: int | None = None
            ) -> Prediction | BatchPrediction | PlacedBatchPrediction:
    """Solve the sharing model (Eqs. 4–5) for a scenario or batch.

    One-shot sugar for ``compile(scenario, verb="predict").run(...)``.
    ``backend`` overrides the scenario's own backend option
    (``"numpy"`` / ``"jax"`` / ``"auto"``); ``jax_cutoff`` overrides
    the ``auto`` threshold for this call.  Returns a
    :class:`Prediction` for a single scenario, a
    :class:`BatchPrediction` for an unplaced batch, a
    :class:`PlacedBatchPrediction` for a batch placed on one topology.
    """
    return compile_plan(scenario, verb="predict").run(
        backend=backend, jax_cutoff=jax_cutoff)


def simulate(scenario: Scenario | ScenarioBatch, *,
             backend: str | None = None, t_max: float | None = None,
             on_deadlock: str = "mask",
             fuse_ensembles: bool = True) -> SimulationResult:
    """Run a scenario (or batch) through the desync event engine.

    One-shot sugar for ``compile(scenario, verb="simulate").run(...)``.
    A single scenario with ``.with_noise(..., ensemble=B)`` expands to B
    independent noise draws (member seeds derived deterministically from
    the scenario's seed via :func:`repro.api.plan.derive_member_seed`);
    a :class:`ScenarioBatch` simulates its B scenarios, each scenario's
    own ensemble fused in as adjacent rows (``result.members`` maps rows
    back to ``(scenario, member)``).  All members advance in **one**
    batched engine call.  ``fuse_ensembles=False`` forces the legacy
    one-row-per-scenario contract, which rejects inner ensembles.

    ``backend`` (``"numpy"`` default / ``"jax"``) and ``t_max`` override
    the scenarios' options; ``on_deadlock`` is the batched engine's
    masking contract (``"mask"`` or ``"raise"``).
    """
    return compile_plan(scenario, verb="simulate",
                        fuse_ensembles=fuse_ensembles).run(
        backend=backend, t_max=t_max, on_deadlock=on_deadlock)
