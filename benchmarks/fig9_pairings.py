"""Paper Fig. 9: relative bandwidth gain/loss of kernel A paired with B
(equal thread split of the full domain), normalized to A self-paired.

Checks the paper's headline qualitative claims:
  * gain/loss sign follows the f-ratio, consistently across Intel CPUs;
  * CLX shows the smallest variations;
  * Rome differs for DAXPY+DSCAL because f_DAXPY > f_DSCAL there (reversed
    vs. Intel).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import sharing, table2

DOMAIN = {"BDW-1": 10, "BDW-2": 18, "CLX": 20, "ROME": 8}


def gain_matrix(arch):
    """All K×K pairings (mixed and self-paired) as ONE batched solve.

    Scenario layout: rows 0..K²-1 are the mixed pairs (A with B), rows
    K²..K²+K-1 the self-pairings (A with A); the Fig. 9 bar height is
    mixed_bw[A,B] / self_bw[A].
    """
    n_each = DOMAIN[arch] // 2
    kernels = [table2.kernel(k) for k in table2.FIG9_KERNELS]
    k = len(kernels)
    fs = np.array([s.f[arch] for s in kernels])
    bss = np.array([s.bs[arch] for s in kernels])

    ia, ib = np.divmod(np.arange(k * k), k)
    f = np.concatenate([
        np.stack([fs[ia], fs[ib]], axis=-1),           # mixed
        np.stack([fs, fs], axis=-1)])                  # self-paired
    bs = np.concatenate([
        np.stack([bss[ia], bss[ib]], axis=-1),
        np.stack([bss, bss], axis=-1)])
    n = np.full_like(f, n_each)

    batch = sharing.solve_batch(n, f, bs)
    mixed = batch.bw_group[:k * k, 0].reshape(k, k)
    homo = batch.bw_group[k * k:, 0]
    gains = mixed / homo[:, None]
    return {(ka, kb): float(gains[i, j])
            for i, ka in enumerate(table2.FIG9_KERNELS)
            for j, kb in enumerate(table2.FIG9_KERNELS)}


def rows():
    out = []
    spreads = {}
    for arch in DOMAIN:
        t0 = time.perf_counter()
        m = gain_matrix(arch)
        us = (time.perf_counter() - t0) * 1e6 / len(m)
        gains = [v for (a, b), v in m.items() if a != b]
        spreads[arch] = max(gains) - min(gains)
        ex = m[("DCOPY", "DDOT2")]
        out.append((f"fig9/{arch}", us,
                    f"pairs={len(m)};min={min(gains):.3f};"
                    f"max={max(gains):.3f};DCOPY+DDOT2={ex:.3f}"))
    intel = ("BDW-1", "BDW-2", "CLX")
    clx_smallest = spreads["CLX"] == min(spreads[a] for a in intel)
    dax_dscal_rome = sharing.gain_vs_self(
        table2.kernel("DAXPY"), table2.kernel("DSCAL"), "ROME", 4)
    dax_dscal_bdw = sharing.gain_vs_self(
        table2.kernel("DAXPY"), table2.kernel("DSCAL"), "BDW-1", 5)
    out.append(("fig9/check/clx_smallest_variation", 0.0,
                f"{clx_smallest};spreads="
                + ";".join(f"{a}={spreads[a]:.3f}" for a in spreads)))
    out.append(("fig9/check/daxpy_dscal_rome_flip", 0.0,
                f"rome_gain={dax_dscal_rome:.3f}(>1 expected);"
                f"bdw_gain={dax_dscal_bdw:.3f}(<1 expected)"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
