"""Bandwidth-sharing deep dive: Fig. 6/7/9 scenarios + the TPU transplant.

Run:  PYTHONPATH=src python examples/bandwidth_sharing.py
"""

import numpy as np

from repro import api
from repro.core import table2
from repro.core.overlap import Phase, overlap_pair
from repro.runtime.overlap_schedule import plan_gradient_overlap
from repro.core.hlo import RooflineTerms

print("=" * 70)
print("1. Full-domain sweep (paper Fig. 6): DCOPY vs DDOT2 on CLX")
print("=" * 70)
# Declare the sweep once; one batched facade call solves every split.
splits = np.array([[na, 20 - na] for na in range(2, 20, 3)])
batch = api.predict(api.Scenario.on("CLX")
                    .run("DCOPY", 1).run("DDOT2", 1).batch(splits))
print(f"{'n_DCOPY':>8} {'n_DDOT2':>8} {'bw/core A':>10} {'bw/core B':>10} "
      f"{'total':>8}")
for (na, nb), p in zip(splits, batch):
    print(f"{na:>8} {nb:>8} {p.bw_per_core[0]:>10.2f} "
          f"{p.bw_per_core[1]:>10.2f} {p.total_bw:>8.1f}")
print("-> DCOPY (higher f) wins per-core share; total sags toward DCOPY's "
      "lower b_s (the Fig. 6 'bend').")

print()
print("=" * 70)
print("2. Fig. 9 gain/loss: who profits from co-scheduling?")
print("=" * 70)
for arch in table2.ARCHS:
    mixed = api.predict(api.Scenario.on(arch)
                        .run("DAXPY", 4).run("DSCAL", 4))
    homo = api.predict(api.Scenario.on(arch)
                       .run("DAXPY", 4).run("DAXPY", 4))
    g1 = mixed.bw_group[0] / homo.bw_group[0]
    print(f"  {arch:6s}: DAXPY paired with DSCAL -> {g1:.3f}x "
          f"({'gain' if g1 > 1 else 'loss'})")
print("-> sign flips on Rome (f_DAXPY > f_DSCAL there) — paper Sect. V.")

print()
print("=" * 70)
print("3. TPU transplant: gradient reduce-scatter vs backward compute")
print("=" * 70)
# A training step whose roofline came out of the dry-run:
terms = RooflineTerms(name="example", t_compute=0, t_memory=0,
                      t_collective=0, flops=2.0e13, hbm_bytes=4.0e12,
                      wire_bytes=1.5e10)
plan = plan_gradient_overlap(terms)
print(f"  serial step        : {plan.t_serial*1e3:8.2f} ms")
print(f"  naive 'free' overlap: {plan.t_naive_roofline*1e3:8.2f} ms "
      "(classical roofline promise)")
print(f"  sharing-model plan : {plan.t_planned*1e3:8.2f} ms with "
      f"{plan.n_buckets} buckets (overlap={plan.overlap})")

print()
print("  Two HBM-bound streams (the case the naive model gets wrong):")
a, b = Phase("a", hbm_bytes=5e9), Phase("b", hbm_bytes=5e9)
pr = overlap_pair(a, b)
print(f"    serial {pr.t_serial*1e3:.2f} ms | shared {pr.t_overlap*1e3:.2f}"
      f" ms | naive {pr.t_naive*1e3:.2f} ms")
print("    -> overlapping two saturating streams buys nothing; Eq. 4/5 "
      "predict it, max() does not.")
