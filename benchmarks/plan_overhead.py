"""Compiled-plan payoff: plan.run() vs per-call api.predict re-dispatch.

The plan API's contract is "trace once, run many": ``api.compile``
pays spec resolution, array packing, and backend + jit selection once,
and ``plan.run()`` re-executes with only the solve.  This benchmark
records what that buys on a B-scenario sweep:

* ``percall``  — the headline: one ``plan.run()`` against the
  pre-plan idiom of B separate ``api.predict(scenario)`` calls
  (acceptance: >= 5x at B >= 256);
* ``amortize`` — ``plan.run()`` against ``api.predict(batch)``, i.e.
  what re-tracing costs even when the caller already batches;
* ``swap``     — ``plan.run(f=..., b_s=...)``, the calibration
  inner-loop idiom (new numbers, no re-trace);
* ``sim``      — ``plan.run()`` against ``api.simulate(scenario)`` for
  a noise ensemble (the program-encoding walk amortized);
* ``jit_cache`` — substrate cache hit rate across same-bucket plans
  (jax only; see repro.core.backend.cache_stats).

``python benchmarks/plan_overhead.py --out BENCH_plan.json`` writes the
committed artifact and exits nonzero if the headline bound is broken.
"""

from __future__ import annotations

import argparse
import gc
import json
import sys
import time

import numpy as np

from repro import api
from repro.core import backend as backend_mod

B_SWEEP = 256
SPEEDUP_BOUND = 5.0    # plan.run() vs per-call predict, the acceptance gate
REPS = 30
SAMPLES = 7


def _time_pair_us(fn_a, fn_b, reps: int = REPS,
                  samples: int = SAMPLES) -> tuple[float, float]:
    """Best-of-``samples`` mean over ``reps`` calls for two functions,
    in µs.  Sample blocks alternate between the two so slow drift
    (thermal, other tenants) hits both sides alike; GC is paused so
    collection pauses don't land on one side."""
    best_a = best_b = float("inf")
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(samples):
            t0 = time.perf_counter()
            for _ in range(reps):
                fn_a()
            best_a = min(best_a, (time.perf_counter() - t0) / reps)
            t0 = time.perf_counter()
            for _ in range(reps):
                fn_b()
            best_b = min(best_b, (time.perf_counter() - t0) / reps)
            gc.collect()
    finally:
        if gc_was_enabled:
            gc.enable()
    return best_a * 1e6, best_b * 1e6


def _time_us(fn, reps: int = REPS, samples: int = SAMPLES) -> float:
    return _time_pair_us(fn, fn, reps=reps, samples=samples)[0]


def _scenarios(b: int) -> list:
    base = api.Scenario.on("CLX")
    na = 1 + np.arange(b) % 19
    return [base.run("DCOPY", int(a)).run("DDOT2", int(20 - a))
            for a in na]


def measure() -> dict:
    scens = _scenarios(B_SWEEP)
    batch = api.ScenarioBatch.of(scens)
    plan = api.compile(batch)
    plan.run()                      # warm caches + jit before timing

    t_percall = _time_us(lambda: [api.predict(sc) for sc in scens],
                         reps=3, samples=5)
    t_batch, t_run = _time_pair_us(lambda: api.predict(batch), plan.run)
    f2 = plan.f * 1.01
    bs2 = plan.bs * 0.99
    t_swap = _time_us(lambda: plan.run(f=f2, b_s=bs2))

    # Simulation-plan payoff: the program-encoding walk amortized.
    sim_sc = (api.Scenario.on("CLX").ranks(8)
              .with_noise(5e-5, seed=0, ensemble=16)
              .step("DCOPY", 4e6).step("DDOT2", 1e6).barrier())
    sim_plan = api.compile(sim_sc)
    sim_plan.run()
    t_sim_oneshot, t_sim_run = _time_pair_us(
        lambda: api.simulate(sim_sc), sim_plan.run, reps=3, samples=5)

    # Jit-cache reuse across same-bucket plans: B = 200 and B = 256
    # both pad into the 256-row bucket, so the second compile+run must
    # hit the substrate cache instead of recompiling.
    cache = None
    if backend_mod.HAVE_JAX:
        before = backend_mod.cache_stats()
        for b in (200, 224, B_SWEEP):
            p = api.compile(api.ScenarioBatch.of(_scenarios(b)))
            p.run(backend="jax")
        after = backend_mod.cache_stats()
        hits = after["hits"] - before["hits"]
        misses = after["misses"] - before["misses"]
        cache = {
            "lookups": hits + misses,
            "hits": hits,
            "misses": misses,
            "hit_rate": round(hits / (hits + misses), 3)
            if hits + misses else 0.0,
            "process_entries": after["entries"],
        }

    return {
        "B": B_SWEEP,
        "backend": plan.engine,
        "percall_us": round(t_percall, 1),
        "predict_batch_us": round(t_batch, 3),
        "plan_run_us": round(t_run, 3),
        "plan_swap_us": round(t_swap, 3),
        "speedup_vs_percall": round(t_percall / t_run, 1),
        "speedup_vs_batch": round(t_batch / t_run, 2),
        "sim_oneshot_us": round(t_sim_oneshot, 1),
        "sim_run_us": round(t_sim_run, 1),
        "sim_speedup": round(t_sim_oneshot / t_sim_run, 2),
        "jit_cache": cache,
    }


def check(r: dict) -> bool:
    ok = r["speedup_vs_percall"] >= SPEEDUP_BOUND
    if r["jit_cache"] is not None:
        # Same-bucket plans must actually share compiled solvers.
        ok &= r["jit_cache"]["hits"] >= 1
    return ok


def rows():
    r = measure()
    out = [
        (f"plan/B={r['B']}/percall_predict", r["percall_us"],
         f"plan_run={r['plan_run_us']:.1f}us;"
         f"speedup={r['speedup_vs_percall']:.1f}x"),
        (f"plan/B={r['B']}/predict_batch", r["predict_batch_us"],
         f"plan_run={r['plan_run_us']:.1f}us;"
         f"speedup={r['speedup_vs_batch']:.2f}x"),
        (f"plan/B={r['B']}/swap_f_bs", r["plan_swap_us"], "no-retrace"),
        ("plan/sim/ensemble16", r["sim_run_us"],
         f"oneshot={r['sim_oneshot_us']:.1f}us;"
         f"speedup={r['sim_speedup']:.2f}x"),
    ]
    if r["jit_cache"] is not None:
        c = r["jit_cache"]
        out.append(("plan/jit_cache/same_bucket", 0.0,
                    f"hit_rate={c['hit_rate']};hits={c['hits']};"
                    f"misses={c['misses']}"))
    out.append(("plan/check/bounds", 0.0,
                f"ok={check(r)};speedup>={SPEEDUP_BOUND:.0f}x"))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args(argv)
    r = measure()
    ok = check(r)
    report = {
        "benchmark": "plan_overhead",
        "jax": backend_mod.HAVE_JAX,
        "bound_speedup_vs_percall": SPEEDUP_BOUND,
        "ok": ok,
        "results": r,
    }
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.out}  (ok={ok})")
    print(f"B={r['B']}: per-call {r['percall_us']:.0f}us  "
          f"batch {r['predict_batch_us']:.0f}us  "
          f"plan.run {r['plan_run_us']:.0f}us  "
          f"({r['speedup_vs_percall']:.1f}x vs per-call, "
          f"{r['speedup_vs_batch']:.2f}x vs batch)")
    print(f"simulate ensemble=16: one-shot {r['sim_oneshot_us']:.0f}us  "
          f"plan.run {r['sim_run_us']:.0f}us "
          f"({r['sim_speedup']:.2f}x)")
    if r["jit_cache"] is not None:
        print(f"jit cache (same-bucket plans): {r['jit_cache']}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
