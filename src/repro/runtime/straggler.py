"""Straggler monitor: the paper's desynchronization theory applied to
data-parallel workers.

The paper's key dynamical result: when a step phase overlaps (across
workers) with a *higher-f* follow-up phase, worker skew is AMPLIFIED
(positive skewness); overlap with idleness (a barrier / allreduce wait)
RESYNCHRONIZES.  For a barrier-free async-ish training loop this predicts
whether skew grows without bound — and hence when to inject a sync barrier.

``StragglerMonitor`` tracks per-worker step durations, estimates the skew
trend, and consults the desync simulator for the amplification sign.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Sequence

from ..api import Scenario
from ..api import compile as compile_plan
from ..core.desync import skewness
from ..core.topology import Topology


@dataclasses.dataclass
class StepPhase:
    """One phase of the training step, as seen by the contention model."""
    name: str
    bytes_hbm: float
    f: float            # request fraction of the phase
    bs: float           # envelope bandwidth (GB/s)


class StragglerMonitor:
    """Decides when to inject a barrier based on observed skew + theory."""

    def __init__(self, n_workers: int, *, window: int = 32,
                 skew_limit: float = 1.0):
        self.n_workers = n_workers
        self.window = window
        self.skew_limit = skew_limit
        self._durations: deque[Sequence[float]] = deque(maxlen=window)

    def record(self, step_durations: Sequence[float]):
        self._durations.append(tuple(step_durations))

    @property
    def observed_skew(self) -> float:
        if not self._durations:
            return 0.0
        per_worker = [sum(d[i] for d in self._durations)
                      for i in range(self.n_workers)]
        return skewness(per_worker)

    def should_inject_barrier(self) -> bool:
        return abs(self.observed_skew) > self.skew_limit and \
            self.observed_skew > 0

    def predict_amplification(self, phases: Sequence[StepPhase], *,
                              probe: int = 1,
                              topology: Topology | None = None,
                              placement: Sequence[str] | None = None,
                              ensemble: int = 16, seed: int = 0,
                              backend: str = "numpy") -> float:
        """Simulate a barrier-free loop of the given phases and return the
        skewness of phase[probe]'s accumulated time — positive means the
        configuration amplifies desync and needs periodic barriers.

        The skew is estimated over an ``ensemble`` of independent noise
        draws (member streams split deterministically from ``seed`` via
        :func:`repro.api.plan.derive_member_seed`), all advanced in one
        batched :meth:`repro.core.desync.DesyncSimulator.run_batch` call,
        so the estimate does not hinge on a single lucky draw and costs
        one run instead of ``ensemble``.  ``ensemble=1`` equals a scalar
        ``DesyncSimulator`` run of the member-0 program (the batched
        engine with B = 1 matches the scalar engine record for record);
        note the scalar engine's own clock-advance and rank-truncation
        fixes shifted absolute skew values relative to earlier releases.

        ``topology``/``placement`` pin workers to contention domains (e.g.
        one HBM domain per chip of a :func:`repro.core.topology.tpu_pod`):
        workers only amplify each other's skew through domains they share.
        """
        if ensemble < 1:
            raise ValueError(f"ensemble must be >= 1, got {ensemble}")
        if (topology is None) != (placement is None):
            raise ValueError("topology and placement must be given together")
        # One barrier-free iteration after established skew — the paper's
        # Fig. 3 setting (multi-iteration feedback forms computational
        # wavefronts that mix the signal).
        sc = Scenario.on("TPU").ranks(self.n_workers)
        for ph in phases:
            sc = sc.step((ph.f, ph.bs), ph.bytes_hbm, name=ph.name,
                         tag=ph.name)
        sc = sc.with_noise(5e-5, seed=seed, ensemble=ensemble)
        if topology is not None:
            sc = sc.using(topology).on_domains(placement)
        # One compile per ensemble (noise draws and program encoding
        # traced once; same-shaped ensembles share the jitted engine
        # process-wide); a masked-out deadlocked draw would silently
        # skew the ensemble skew statistic, so abort loudly instead.
        plan = compile_plan(sc, verb="simulate")
        res = plan.run(t_max=120.0, backend=backend, on_deadlock="raise")
        return res.mean_skew(phases[probe].name)
