"""LoopFeatures: normalize a traffic audit into the model's code inputs.

The bridge between :mod:`repro.analysis.traffic` (total element traffic
per call) and the registry's ECM rung (per-iteration cache-line stream
counts).  Two policy knobs mirror the paper's Table II distinctions:

* ``reuse`` — the layer condition.  ``True`` merges load streams that
  walk the *same base buffer* (the Jacobi up/mid/down row views become
  one stream, the LC-satisfied ``JacobiL2-*`` rows); ``False`` counts
  every view as its own stream (the LC-violated ``JacobiL3-*`` rows).
* ``write_allocate`` — the RFO policy.  ``True`` charges one RFO stream
  per store whose destination is *not* an alias of an input buffer (a
  fresh output line must be read before it is written); stores declared
  in-place via ``input_output_aliases`` never RFO.  The policy is
  arch-dependent in reality (non-temporal stores, Rome's write-combining)
  — the certification cross-check documents a ≤ 15 % ``f`` bound for
  the affected kernels instead of pretending it is exact.

``derive`` is pure accounting; :func:`features` is the one-call
``audit + derive`` convenience used by ``KernelSpec.from_static_analysis``
and the registry's ``"static"`` resolution rung.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

from .traffic import Stream, TrafficAudit, audit


@dataclasses.dataclass(frozen=True)
class LoopFeatures:
    """Per-iteration code features of one loop kernel — the exact inputs
    :func:`repro.api.registry.from_loop_features` consumes, plus the
    byte accounting the golden tests pin."""

    name: str
    reads: int
    writes: int
    rfo: int
    flops_per_iter: float
    bytes_per_iter: float       # actual dtypes, counted streams only
    iters: int                  # lattice updates per audited call
    itemsize: int               # dominant element size [B]
    read_only: bool
    reuse: bool
    write_allocate: bool
    notes: tuple[str, ...] = ()

    @property
    def streams(self) -> int:
        return self.reads + self.writes + self.rfo

    @property
    def code_balance(self) -> float:
        """B_c [B/F] with the audited element size; ``inf`` when the
        kernel performs no floating-point work (DCOPY)."""
        if self.flops_per_iter == 0:
            return float("inf")
        return self.bytes_per_iter / self.flops_per_iter


def _group_by_base(streams: list[Stream]) -> dict[str, list[Stream]]:
    groups: dict[str, list[Stream]] = {}
    for s in streams:
        groups.setdefault(s.base, []).append(s)
    return groups


def _stream_count(elements: int, iters: int) -> int:
    """Streams implied by ``elements`` traffic over ``iters`` updates:
    one per ``iters`` elements, rounded (halo rows make the ratio
    slightly exceed an integer), never rounded to zero."""
    if iters <= 0:
        return 1
    return max(1, round(elements / iters))


def derive(traffic: TrafficAudit, *, reuse: bool = True,
           write_allocate: bool = True,
           name: str | None = None) -> LoopFeatures:
    """Normalize an audit to per-iteration stream counts; see module doc
    for the ``reuse`` (layer condition) and ``write_allocate`` (RFO)
    policies."""
    iters = traffic.iters
    loads = list(traffic.loads)
    stores = list(traffic.stores)

    reads = 0
    counted: list[Stream] = []
    if reuse:
        for group in _group_by_base(loads).values():
            biggest = max(group, key=lambda s: s.elements)
            reads += _stream_count(biggest.elements, iters)
            counted.append(biggest)
    else:
        for s in loads:
            reads += _stream_count(s.elements, iters)
            counted.append(s)

    writes = rfo = 0
    for group in _group_by_base(stores).values():
        biggest = max(group, key=lambda s: s.elements)
        count = _stream_count(biggest.elements, iters)
        writes += count
        counted.append(biggest)
        if write_allocate and not biggest.aliased:
            rfo += count
            counted.append(biggest)   # the RFO line travels too

    itemsizes = [s.itemsize for s in counted] or [8]
    bytes_per_iter = float(sum(
        _stream_count(s.elements, iters) * s.itemsize
        for s in counted)) if counted else 0.0
    # ``counted`` lists each RFO'd store twice on purpose: the
    # write-allocate line is charged at the store's element size.

    read_only = writes == 0 and rfo == 0
    notes = list(traffic.notes)
    if traffic.reductions:
        notes.append(
            f"{traffic.reductions} grid-resident accumulator output(s) "
            f"excluded from the store streams (register/VMEM-held)")
    if traffic.gathers or traffic.scatters:
        notes.append(
            f"irregular access: {traffic.gathers} gather / "
            f"{traffic.scatters} scatter sites — streaming counts "
            f"understate their traffic")
    return LoopFeatures(
        name=name or traffic.name, reads=reads, writes=writes, rfo=rfo,
        flops_per_iter=traffic.flops / iters if iters else 0.0,
        bytes_per_iter=bytes_per_iter, iters=iters,
        itemsize=max(set(itemsizes), key=itemsizes.count),
        read_only=read_only, reuse=reuse,
        write_allocate=write_allocate, notes=tuple(notes))


def features(fn: Callable, *args: Any, name: str | None = None,
             reuse: bool = True, write_allocate: bool = True
             ) -> LoopFeatures:
    """One-call static analysis: trace ``fn(*args)``, walk the jaxpr,
    and return its per-iteration :class:`LoopFeatures`."""
    return derive(audit(fn, *args, name=name), reuse=reuse,
                  write_allocate=write_allocate, name=name)
