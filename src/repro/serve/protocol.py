"""Wire schema: ndjson request lines in, result dicts out.

One request per JSON line (the same streaming idiom as
``api.iter_ndjson``); the transport is free to carry lines over
anything — :mod:`repro.serve.http` streams them over chunked HTTP.
A request line declares a scenario::

    {"id": 1, "arch": "CLX",
     "groups": [{"kernel": "DCOPY", "n": 12},
                {"kernel": "DDOT2", "n": 8}]}

and comes back as the prediction's ``to_dict()`` plus the serving
envelope (``id`` echoed, ``ok``, ``serve_ms``).  Placed scenarios add
``"topology"`` and per-group ``"domain"``; program-mode requests
(``"ranks"``/``"steps"``/``"noise"``) simulate instead of predict.
Kernels are anything the registry resolves from JSON: a Table II name,
an ``[f, b_s]`` pair, or ``{"f": ..., "b_s": ...}``.  Errors come back
as ``{"ok": false, "kind": "error", "status": ..., "error": ...}``
lines, so one bad request never poisons the stream.

Full field reference: docs/serving.md.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

from .. import api
from .coalesce import BadRequest

#: Step operators accepted in ``"steps"`` lists, mapped to the
#: Scenario program-mode builders.
STEP_OPS = ("work", "barrier", "halo", "idle")


@dataclasses.dataclass(frozen=True)
class Request:
    """One parsed request line, ready for the coalescer."""

    id: object
    verb: str
    scenario: "api.Scenario"
    deadline_s: float | None
    tags: tuple[str, ...]   # simulate: per-tag skew blocks in the reply


def _kernel_ref(spec, where: str):
    if isinstance(spec, str):
        return spec
    if isinstance(spec, (list, tuple)) and len(spec) == 2:
        return (float(spec[0]), float(spec[1]))
    if isinstance(spec, Mapping) and "f" in spec:
        return (float(spec["f"]), float(spec.get("b_s", spec.get("bs"))))
    raise BadRequest(
        f"{where}: kernel must be a name, an [f, b_s] pair, or "
        f"{{'f': ..., 'b_s': ...}}; got {spec!r}")


def _require(d: Mapping, field: str, where: str = "request"):
    if field not in d:
        raise BadRequest(f"{where}: missing required field {field!r}")
    return d[field]


def parse_request(d: Mapping) -> Request:
    """Build the scenario a request line describes.

    Raises :class:`BadRequest` (HTTP 400) with a field-level message on
    anything malformed — including scenario-builder validation errors,
    which surface with their original suggestion-bearing text."""
    if not isinstance(d, Mapping):
        raise BadRequest(f"request must be a JSON object, got "
                         f"{type(d).__name__}")
    known = {"id", "kind", "arch", "topology", "options", "deadline_ms",
             "groups", "ranks", "domains", "noise", "steps", "t_max",
             "tags"}
    bad = set(d) - known
    if bad:
        raise BadRequest(f"unknown request fields {sorted(bad)}; "
                         f"allowed: {sorted(known)}")
    arch = _require(d, "arch")
    options = dict(d.get("options") or {})
    if "t_max" in d:
        options["t_max"] = float(d["t_max"])
    try:
        sc = api.Scenario.on(arch)
        if options:
            sc = sc.options(**options)   # validates against the allowed set
    except TypeError as e:
        raise BadRequest(f"options: {e}") from None
    try:
        if d.get("topology") is not None:
            sc = sc.using(d["topology"])
        for i, g in enumerate(d.get("groups") or ()):
            where = f"groups[{i}]"
            kwargs = {}
            if g.get("tag") is not None:
                kwargs["tag"] = str(g["tag"])
            if g.get("bytes") is not None:
                kwargs["bytes"] = float(g["bytes"])
            sc = sc.run(_kernel_ref(_require(g, "kernel", where), where),
                        int(_require(g, "n", where)),
                        domain=g.get("domain"), **kwargs)
        if d.get("ranks") is not None:
            sc = sc.ranks(int(d["ranks"]))
        if d.get("noise") is not None:
            nz = d["noise"]
            sc = sc.with_noise(
                float(nz.get("exp_mean_s", 5e-5)),
                seed=int(nz.get("seed", 0)),
                ensemble=int(nz.get("ensemble", 1)),
                tag=str(nz.get("tag", "noise")))
        for i, s in enumerate(d.get("steps") or ()):
            where = f"steps[{i}]"
            op = s.get("op", "work")
            if op == "work":
                kwargs = {}
                if s.get("tag") is not None:
                    kwargs["tag"] = str(s["tag"])
                sc = sc.step(
                    _kernel_ref(_require(s, "kernel", where), where),
                    _require(s, "bytes", where), **kwargs)
            elif op == "barrier":
                sc = sc.barrier(**{k: s[k] for k in ("cost_s", "tag")
                                   if k in s})
            elif op == "halo":
                sc = sc.halo(**{k: s[k] for k in ("cost_s", "tag")
                                if k in s})
            elif op == "idle":
                sc = sc.idle(float(_require(s, "s", where)),
                             **({"tag": str(s["tag"])} if "tag" in s
                                else {}))
            else:
                raise BadRequest(
                    f"{where}: unknown op {op!r}; expected one of "
                    f"{list(STEP_OPS)}")
        if d.get("domains") is not None:
            sc = sc.on_domains([str(x) for x in d["domains"]])
    except BadRequest:
        raise
    except (ValueError, TypeError, KeyError) as e:
        raise BadRequest(str(e)) from None
    verb = d.get("kind")
    if verb is None:
        verb = api.infer_verb(sc)
    elif verb not in ("predict", "simulate"):
        raise BadRequest(f"kind must be 'predict' or 'simulate', "
                         f"got {verb!r}")
    deadline_s = (float(d["deadline_ms"]) / 1e3
                  if d.get("deadline_ms") is not None else None)
    return Request(id=d.get("id"), verb=verb, scenario=sc,
                   deadline_s=deadline_s,
                   tags=tuple(str(t) for t in d.get("tags") or ()))


def build_response(req: Request, result, elapsed_s: float) -> dict:
    """The success envelope: ``result.to_dict()`` (the unified results
    schema, unchanged) wrapped with the request id and serve timing."""
    if hasattr(result, "to_dict"):
        body = (result.to_dict(tags=req.tags)
                if req.verb == "simulate" else result.to_dict())
    else:                     # pragma: no cover - defensive
        body = {"result": result}
    return {"id": req.id, "ok": True,
            "serve_ms": round(elapsed_s * 1e3, 3), **body}


def error_response(req_id, exc: Exception) -> dict:
    """The failure envelope; ``status`` carries the HTTP-ish code of
    :class:`repro.serve.coalesce.ServeError` subclasses (500 for
    anything else)."""
    return {"id": req_id, "ok": False, "kind": "error",
            "status": getattr(exc, "status", 500),
            "error": str(exc) or type(exc).__name__}
