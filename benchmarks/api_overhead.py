"""Facade dispatch overhead: api.predict vs a direct solve_batch call.

The facade's contract is "declare once, predict many": a ScenarioBatch
is built once (kernel resolution, array packing, validation — all cached
on the frozen batch) and predicted as often as the serving loop needs.
This benchmark measures what the *per-predict* dispatch layer costs on
top of the engine it dispatches to, per batch size and backend:

    overhead = t(api.predict(batch)) / t(sharing.solve_batch(arrays)) - 1

Acceptance: < 5 % at B = 1, ~0 at B >= 64 (where the solve dominates).
``python benchmarks/api_overhead.py --out BENCH_api.json`` writes the
committed artifact and exits nonzero if the bound is broken.
"""

from __future__ import annotations

import argparse
import gc
import json
import sys
import time
from typing import Sequence

import numpy as np

from repro import api
from repro.core import sharing
from repro.core.backend import HAVE_JAX

B_SIZES = (1, 16, 64, 256)
OVERHEAD_BOUND_B1 = 0.05     # < 5 % at B = 1 (the acceptance bound)
OVERHEAD_BOUND_LARGE = 0.05  # "~0" at B >= 64 ...
ABS_SLACK_US = 30.0          # ... or additive cost within dispatch jitter
                             # (the jitted solve itself wobbles ~100 µs
                             # run to run on a shared container)
REPS = 100
SAMPLES = 25


def _time_pair_us(fn_a, fn_b, reps: int = REPS,
                  samples: int = SAMPLES) -> tuple[float, float]:
    """Best-of-``samples`` mean over ``reps`` calls for two functions,
    in µs.  Sample blocks alternate between the two so slow drift
    (thermal, other tenants) hits both sides alike; min-of-means is
    robust to scheduler noise without single-timestamp lucky bias.
    GC is paused so collection pauses don't land on one side."""
    best_a = best_b = float("inf")
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(samples):
            t0 = time.perf_counter()
            for _ in range(reps):
                fn_a()
            best_a = min(best_a, (time.perf_counter() - t0) / reps)
            t0 = time.perf_counter()
            for _ in range(reps):
                fn_b()
            best_b = min(best_b, (time.perf_counter() - t0) / reps)
            gc.collect()
    finally:
        if gc_was_enabled:
            gc.enable()
    return best_a * 1e6, best_b * 1e6


def _batch_for(b: int) -> api.ScenarioBatch:
    """B two-group scenarios cycling through distinct thread splits."""
    base = api.Scenario.on("CLX").run("DCOPY", 1).run("DDOT2", 1)
    na = 1 + np.arange(b) % 19
    return base.batch(np.stack([na, 20 - na], axis=-1))


def measure(backends: Sequence[str] | None = None) -> list[dict]:
    out = []
    for b in B_SIZES:
        batch = _batch_for(b)
        n, f, bs, names = batch.arrays  # packing is paid at build time
        bks = backends if backends is not None else (
            ["numpy"] + (["jax"] if HAVE_JAX else []))
        for bk in bks:
            direct = lambda: sharing.solve_batch(  # noqa: E731
                n, f, bs, names=names, backend=bk)
            facade = lambda: api.predict(batch, backend=bk)  # noqa: E731
            direct()
            facade()    # warm caches + jit before timing
            t_direct, t_facade = _time_pair_us(direct, facade)
            out.append({
                "B": b, "backend": bk,
                "direct_us": round(t_direct, 3),
                "facade_us": round(t_facade, 3),
                "overhead_us": round(t_facade - t_direct, 3),
                "overhead_pct": round(
                    (t_facade / t_direct - 1.0) * 100.0, 2),
            })
    return out


def check(results: list[dict]) -> bool:
    """B = 1 must be under the relative acceptance bound.  At B >= 64
    the facade's cost is a few µs additive while the jitted solve's own
    run-to-run jitter is tens of µs, so a relative bound alone would
    flap — accept when either the relative bound or the additive slack
    holds."""
    ok = True
    for r in results:
        abs_us = r["facade_us"] - r["direct_us"]
        if r["B"] == 1:
            ok &= r["overhead_pct"] <= OVERHEAD_BOUND_B1 * 100.0
        elif r["B"] >= 64:
            ok &= (r["overhead_pct"] <= OVERHEAD_BOUND_LARGE * 100.0
                   or abs_us <= ABS_SLACK_US)
    return ok


def rows():
    results = measure()
    out = [(f"api_overhead/B={r['B']}/{r['backend']}", r["facade_us"],
            f"direct={r['direct_us']:.1f}us;"
            f"overhead={r['overhead_pct']:+.2f}%")
           for r in results]
    out.append(("api_overhead/check/bounds", 0.0,
                f"ok={check(results)};bound_B1<"
                f"{OVERHEAD_BOUND_B1:.0%};bound_B>=64<"
                f"{OVERHEAD_BOUND_LARGE:.0%}"))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="JSON artifact path")
    args = ap.parse_args(argv)
    results = measure()
    ok = check(results)
    report = {
        "benchmark": "api_overhead",
        "jax": HAVE_JAX,
        "bounds": {"B1": OVERHEAD_BOUND_B1,
                   "large": OVERHEAD_BOUND_LARGE},
        "ok": ok,
        "results": results,
    }
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.out}  (ok={ok})")
    for r in results:
        print(f"B={r['B']:>4} {r['backend']:>5}: facade "
              f"{r['facade_us']:8.1f}us  direct {r['direct_us']:8.1f}us  "
              f"overhead {r['overhead_pct']:+.2f}%")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
