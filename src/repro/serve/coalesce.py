"""The request coalescer: many concurrent requests, one batched solve.

PR 5's measurement (``BENCH_plan.json``) is the motivation: a compiled
plan re-run is ~40× a per-call ``predict`` at B = 256, so the winning
move under concurrency is to *not* solve requests one by one.  The
coalescer holds requests for one tick (default 1 ms), groups the tick's
arrivals by structure key, and runs each group as a single batched
``plan.run()`` on a cached plan, fanning results back to the awaiting
futures.  Under light load a request pays one tick of latency; under
heavy load the batch packs to ``max_batch`` and throughput scales with
the batched-solver win instead of per-request overhead.

Admission control and backpressure: the queue is bounded
(``max_queue`` → :class:`QueueFull`, HTTP 429), each request carries a
deadline (expired requests fail with :class:`DeadlineExceeded`, HTTP
504, *before* wasting a solve), and ``close(drain=True)`` stops intake
but runs every queued request to completion — no future is ever left
unresolved.

Everything here is socket-free: tests drive ``submit``/``close``
directly under ``asyncio.run``.  Solves run inline on the event loop
(a deliberate single-process design — the solve *is* the service;
see docs/serving.md for the scaling discussion).
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
import weakref
from collections import deque

from .. import api
from ..obs import metrics, trace
from .cache import PlanCache
from . import keys as keys_mod


class ServeError(Exception):
    """Base class for request-level serving failures; ``status`` is the
    HTTP status the transport maps the error to."""
    status = 500


class BadRequest(ServeError):
    status = 400


class QueueFull(ServeError):
    status = 429


class Draining(ServeError):
    status = 503


class DeadlineExceeded(ServeError):
    status = 504


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs for one coalescer (and the server wrapping it)."""

    #: Coalescing window: how long the loop sleeps after waking so
    #: concurrent arrivals land in one batch.  0 disables the wait.
    tick_s: float = 1e-3
    #: Most requests drained per tick; the rest wait for the next one.
    max_batch: int = 256
    #: Admission bound: submits beyond this many queued requests are
    #: rejected with :class:`QueueFull` (the backpressure signal).
    max_queue: int = 1024
    #: Deadline applied to requests that do not carry their own
    #: (seconds; ``None`` = no deadline).
    default_deadline_s: float | None = 30.0
    #: LRU capacity of the plan cache the server builds when the caller
    #: does not pass one.
    cache_entries: int = 128


@dataclasses.dataclass
class _Pending:
    scenario: "api.Scenario"
    verb: str
    future: asyncio.Future
    deadline: float | None   # absolute time.monotonic(), or None
    t_submit: float
    seq: int


class Coalescer:
    """Tick-based batching front for the prediction/simulation engines.

    Usage (socket-free)::

        c = Coalescer(ServeConfig(tick_s=1e-3))
        pred = await c.submit(scenario)            # one Prediction back
        await c.close(drain=True)

    ``submit`` enqueues and awaits; the background tick task drains the
    queue, groups by :func:`repro.api.structure_key`, and solves each
    group through the plan cache.  The task starts lazily on first
    submit (or explicitly via :meth:`start`).
    """

    def __init__(self, config: ServeConfig | None = None, *,
                 cache: PlanCache | None = None):
        self.config = config or ServeConfig()
        # "is None", not "or": an empty PlanCache is len() == 0 == falsy.
        self.cache = (cache if cache is not None
                      else PlanCache(self.config.cache_entries))
        self._pending: deque[_Pending] = deque()
        self._wake = asyncio.Event()
        self._closing = asyncio.Event()
        self._task: asyncio.Task | None = None
        self._closed = False
        self._seq = 0
        self._ticks = 0
        self.counts = {"accepted": 0, "completed": 0, "rejected": 0,
                       "expired": 0, "errors": 0, "drained": 0}
        # Hot-path instrument handles, resolved once: the registry
        # lookup (name + label canonicalization under a lock) costs
        # more than the update itself at coalescing rates.
        self._m_accepted = {
            v: metrics.counter("serve.accepted", verb=v)
            for v in ("predict", "simulate")}
        self._m_latency = {
            v: metrics.histogram("serve.latency_s", verb=v)
            for v in ("predict", "simulate")}
        self._m_batch = metrics.histogram("serve.tick.batch")
        self._m_depth = metrics.gauge("serve.queue.depth")
        # Structure-key memo for resubmitted scenario *objects* (the
        # embedded-client pattern: a calibration loop holds scenarios
        # and submits them every round).  Keyed by id() with a weakref
        # identity check, so a recycled id never returns a stale key.
        self._key_memo: dict = {}

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> None:
        """Start the tick task (idempotent; ``submit`` also starts it)."""
        if self._task is None:
            self._task = asyncio.get_running_loop().create_task(
                self._run(), name="repro.serve.coalescer")

    async def close(self, *, drain: bool = True) -> None:
        """Stop intake and shut the tick task down.

        ``drain=True`` (graceful): every queued request still runs and
        resolves its future.  ``drain=False``: queued requests fail
        immediately with :class:`Draining`.  Either way no future is
        left unresolved."""
        self._closed = True
        self._closing.set()
        if not drain:
            while self._pending:
                p = self._pending.popleft()
                if not p.future.done():
                    p.future.set_exception(
                        Draining("server shut down before this request "
                                 "was solved"))
                self.counts["drained"] += 1
        self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None
        self._gauge()

    async def __aenter__(self) -> "Coalescer":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close(drain=True)

    # -- intake -------------------------------------------------------------

    async def submit(self, scenario, *, verb: str | None = None,
                     deadline_s: float | None = None):
        """Enqueue one request and await its result.

        Raises :class:`Draining` after :meth:`close`, :class:`QueueFull`
        at the admission bound, :class:`DeadlineExceeded` when the
        request's deadline passes before it is solved, and re-raises
        whatever the solve itself raised (as :class:`BadRequest` for
        scenario validation errors)."""
        if self._closed:
            metrics.counter("serve.rejected", reason="draining").inc()
            self.counts["rejected"] += 1
            raise Draining("server is draining; not accepting requests")
        if len(self._pending) >= self.config.max_queue:
            metrics.counter("serve.rejected", reason="queue_full").inc()
            self.counts["rejected"] += 1
            raise QueueFull(
                f"queue full ({self.config.max_queue} requests pending)")
        if verb is None:
            verb = api.infer_verb(scenario)
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        now = time.monotonic()
        self._seq += 1
        with trace.span("serve.accept", verb=verb, seq=self._seq):
            p = _Pending(
                scenario=scenario, verb=verb,
                future=asyncio.get_running_loop().create_future(),
                deadline=(now + deadline_s
                          if deadline_s is not None else None),
                t_submit=now, seq=self._seq)
            self._pending.append(p)
            self._m_accepted[verb].inc()
            self.counts["accepted"] += 1
            self.start()
            self._wake.set()
        return await p.future

    # -- the tick loop ------------------------------------------------------

    async def _run(self) -> None:
        while True:
            if not self._pending:
                if self._closed:
                    return
                self._wake.clear()
                await self._wake.wait()
                continue
            if self.config.tick_s > 0 and not self._closed:
                # The coalescing window: let concurrent arrivals land.
                # Waiting on the closing event (instead of a bare sleep)
                # lets close() cut the window short, so drains never
                # stall a full tick.
                try:
                    await asyncio.wait_for(self._closing.wait(),
                                           timeout=self.config.tick_s)
                except asyncio.TimeoutError:
                    pass
            batch = []
            while self._pending and len(batch) < self.config.max_batch:
                batch.append(self._pending.popleft())
            self._gauge()
            self._ticks += 1
            self._process(batch)
            await asyncio.sleep(0)  # yield between solves under load

    def _process(self, batch: "list[_Pending]") -> None:
        now = time.monotonic()
        groups: dict[tuple, list[_Pending]] = {}
        for p in batch:
            if p.future.done():     # caller gave up (cancel/timeout)
                continue
            if p.deadline is not None and now > p.deadline:
                metrics.counter("serve.expired", verb=p.verb).inc()
                self.counts["expired"] += 1
                p.future.set_exception(DeadlineExceeded(
                    f"deadline passed before solve (queued "
                    f"{now - p.t_submit:.3f}s)"))
                continue
            groups.setdefault((p.verb,) + (self._group_key(
                p.scenario, p.verb),), []).append(p)
        if not groups:
            return
        with trace.span("serve.coalesce", tick=self._ticks,
                        n=sum(len(g) for g in groups.values()),
                        groups=len(groups)):
            for (verb, sig), plist in groups.items():
                self._m_batch.observe(len(plist))
                try:
                    results = self._solve(verb, sig, plist)
                except ServeError as e:
                    self._fail(plist, e)
                except (ValueError, TypeError, KeyError) as e:
                    self._fail(plist, BadRequest(str(e)))
                except Exception as e:  # engine failure: report, keep serving
                    self._fail(plist, ServeError(
                        f"{type(e).__name__}: {e}"))
                else:
                    done = time.monotonic()
                    latency = self._m_latency[verb]
                    for p, result in zip(plist, results):
                        if not p.future.done():
                            p.future.set_result(result)
                        latency.observe(done - p.t_submit)
                        self.counts["completed"] += 1

    def _group_key(self, sc, verb: str) -> tuple:
        memo = self._key_memo
        mk = (id(sc), verb)
        hit = memo.get(mk)
        if hit is not None and hit[0]() is sc:
            return hit[1]
        key = keys_mod.group_key(sc, verb)
        if len(memo) >= 4096:        # bound the memo; rebuilt on demand
            memo.clear()
        try:
            memo[mk] = (weakref.ref(sc), key)
        except TypeError:            # pragma: no cover - non-weakrefable
            pass
        return key

    def _fail(self, plist: "list[_Pending]", exc: ServeError) -> None:
        metrics.counter("serve.errors").inc(len(plist))
        self.counts["errors"] += len(plist)
        for p in plist:
            if not p.future.done():
                p.future.set_exception(exc)

    # -- the batched solve --------------------------------------------------

    def _solve(self, verb: str, sig: tuple,
               plist: "list[_Pending]") -> list:
        scens = [p.scenario for p in plist]
        first = scens[0]
        key, rows = keys_mod.plan_entry(verb, sig, len(scens))
        label = keys_mod.key_label(verb, first, rows)
        plan = self.cache.get_or_build(
            key, lambda: keys_mod.compile_group(scens, verb, rows),
            label=label)
        if verb == "simulate":
            # Identical structure (numbers included) → one shared run.
            return [plan.run()] * len(scens)
        if first.is_placed or first.topo is not None:
            pred = plan.run(
                placement=keys_mod.padded_placements(scens, rows))
        else:
            n, f, bs = keys_mod.swap_arrays(scens, rows, plan.n.shape[1])
            pred = plan.run(cores=n, f=f, b_s=bs)
            return pred.rows(len(scens))   # bulk fan-out (one tolist pass)
        return [pred[i] for i in range(len(scens))]

    # -- introspection ------------------------------------------------------

    def _gauge(self) -> None:
        self._m_depth.set(len(self._pending))

    def stats(self) -> dict:
        """Coalescer gauges for ``/statsz``: intake counters, queue
        depth, tick count, and the live config."""
        return {
            "queue_depth": len(self._pending),
            "closed": self._closed,
            "ticks": self._ticks,
            **self.counts,
            "config": dataclasses.asdict(self.config),
        }
