"""Training launcher.

On real hardware this runs under one process per host with
``jax.distributed.initialize()``; on this container it drives the same code
path on the host's devices with a reduced config.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 200 --reduced --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import logging
import time

import jax

from repro import configs
from repro.data import SyntheticLM
from repro.models import model_for
from repro.optim import cosine_schedule
from repro.runtime import loop as loop_lib
from repro.runtime import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.obs import log as obs_log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_IDS, required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    model = model_for(cfg)
    mesh = make_host_mesh()
    lr_fn = cosine_schedule(args.lr, args.steps // 10 + 1, args.steps)

    dataset = SyntheticLM(cfg, seq_len=args.seq_len,
                          global_batch=args.batch)

    state = steps_lib.init_train_state(model, jax.random.key(0))
    state_shape = jax.eval_shape(lambda: state)
    batch_specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                   for k, v in dataset.batch(0).items()}
    step_fn, state_sh, _ = steps_lib.jit_train_step(
        model, mesh, state_shape, batch_specs, lr_fn=lr_fn,
        microbatches=args.microbatches)
    state = jax.device_put(state, state_sh)

    ckpt = None
    start = 0
    if args.ckpt_dir:
        from repro.checkpoint import CheckpointManager
        ckpt = CheckpointManager(args.ckpt_dir)
        restored, manifest = ckpt.restore_latest(state)
        if restored is not None:
            state, start = restored, int(manifest["step"])
            obs_log.emit(f"restored from step {start}",
                         event="launch.train.restore", step=start)

    from repro.data import HostLoader
    loader = HostLoader(dataset, start_step=start)
    t0 = time.time()
    try:
        losses = []
        step = start
        for batch in loader:
            if step >= args.steps:
                break
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            step += 1
            if step % args.log_every == 0:
                dt = (time.time() - t0) / (step - start)
                obs_log.emit(f"step {step}: loss={losses[-1]:.4f} "
                             f"({dt*1e3:.0f} ms/step)",
                             event="launch.train.step", step=step,
                             loss=losses[-1], ms_per_step=dt * 1e3)
            if ckpt and step % args.ckpt_every == 0:
                ckpt.save_async(step, state, extra={"loss": losses[-1]})
        if ckpt:
            ckpt.save_async(step, state, extra={"final": True})
            ckpt.wait()
        obs_log.emit(f"done: step={step} first_loss={losses[0]:.4f} "
                     f"last_loss={losses[-1]:.4f}",
                     event="launch.train.done", step=step,
                     first_loss=losses[0], last_loss=losses[-1])
    finally:
        loader.close()


if __name__ == "__main__":
    main()
