"""The repro.obs instrumentation layer: spans, metrics, exporters —
and the two contracts the rest of the stack holds it to:

* **identity** — with tracing disabled (the default), every probed
  function returns bit-for-bit the same arrays/records as with tracing
  enabled: probes observe, they never steer;
* **overhead** — the disabled fast path is nanoseconds per probe site
  (the < 2 % end-to-end bound is certified by
  ``benchmarks/obs_overhead.py`` / BENCH_obs.json).
"""

from __future__ import annotations

import io
import json
import time

import numpy as np
import pytest

from repro import api
from repro.calibrate import ScalingTrace, fit_scaling, forward_bandwidth
from repro.core import backend as backend_mod
from repro.core import sharing
from repro.core.hlo import RooflineTerms
from repro.obs import export, metrics, report, trace
from repro.obs import log as obs_log
from repro.runtime.overlap_schedule import (StopReason,
                                            gradient_pod_plan,
                                            pod_step_coefficients,
                                            relax_pod_plan)


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with tracing off, stores empty, and
    the ring buffer back at its default capacity."""
    def pristine():
        trace.enable(capacity=trace.DEFAULT_CAPACITY, clear_events=True)
        trace.disable()
        trace.clear()
        metrics.reset()

    pristine()
    yield
    pristine()


# ---------------------------------------------------------------------------
# trace: spans, nesting, the disabled no-op path, the ring buffer
# ---------------------------------------------------------------------------


def test_span_nesting_depth_and_attrs():
    trace.enable(clear_events=True)
    with trace.span("outer", who="t") as sp:
        with trace.span("inner"):
            pass
        sp.set(extra=3)
    evs = trace.events()
    assert [(e[0], e[1]) for e in evs] == [("span", "inner"),
                                           ("span", "outer")]
    inner, outer = evs
    assert inner[5] == 1 and outer[5] == 0          # depth
    assert inner[4] == outer[4]                     # same thread id
    assert outer[6] == {"who": "t", "extra": 3}
    assert outer[3] >= inner[3] >= 0                # durations nest
    assert outer[2] <= inner[2]                     # outer opened first


def test_disabled_span_is_shared_noop():
    assert not trace.enabled()
    a = trace.span("x", k=1)
    b = trace.span("y")
    assert a is b                      # one shared no-op object
    with a as sp:
        sp.set(anything=1)             # must be accepted and dropped
    trace.instant("z", k=2)
    trace.enable(clear_events=True)
    assert trace.events() == []        # nothing was recorded while off


def test_span_records_exception():
    trace.enable(clear_events=True)
    with pytest.raises(ValueError):
        with trace.span("boom"):
            raise ValueError("no")
    (ev,) = trace.events()
    assert ev[1] == "boom" and ev[6]["error"] == "ValueError"


def test_ring_buffer_wraps_oldest_first():
    trace.enable(capacity=4, clear_events=True)
    for i in range(10):
        trace.instant("tick", i=i)
    evs = trace.events()
    assert [e[6]["i"] for e in evs] == [6, 7, 8, 9]  # newest 4, in order
    assert trace.dropped() == 6
    trace.clear()
    assert trace.events() == [] and trace.dropped() == 0


def test_traced_decorator_labels_by_qualname():
    @trace.traced()
    def helper():
        return 41 + 1

    trace.enable(clear_events=True)
    assert helper() == 42
    (ev,) = trace.events()
    assert ev[1].endswith("helper") and "." in ev[1]


# ---------------------------------------------------------------------------
# metrics: counters / gauges / histograms and the registry
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_roundtrip():
    metrics.counter("c", k="a").inc()
    metrics.counter("c", k="a").inc(2)
    metrics.counter("c", k="b").inc()
    metrics.gauge("g").set(1.5)
    h = metrics.histogram("h")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    snap = {(r["name"], tuple(sorted(r["labels"].items()))): r
            for r in metrics.snapshot()}
    assert snap[("c", (("k", "a"),))]["value"] == 3
    assert snap[("c", (("k", "b"),))]["value"] == 1
    assert snap[("g", ())]["value"] == 1.5
    hrow = snap[("h", ())]
    assert hrow["count"] == 3 and hrow["sum"] == 6.0
    assert hrow["min"] == 1.0 and hrow["max"] == 3.0
    assert hrow["mean"] == pytest.approx(2.0)


def test_metrics_validation_and_reset():
    with pytest.raises(ValueError):
        metrics.counter("c2").inc(-1)
    metrics.counter("shared")
    with pytest.raises(TypeError):
        metrics.histogram("shared")    # same name, different type
    metrics.reset()
    assert metrics.snapshot() == []


# ---------------------------------------------------------------------------
# export: ndjson + Chrome trace_event
# ---------------------------------------------------------------------------


def _tiny_trace():
    trace.enable(clear_events=True)
    with trace.span("a.b.outer", k=1):
        with trace.span("a.b.inner"):
            pass
    trace.instant("a.mark", n=2)
    metrics.counter("a.count").inc(5)


def test_ndjson_export_rows():
    _tiny_trace()
    buf = io.StringIO()
    export.write_ndjson(buf)
    rows = [json.loads(line) for line in buf.getvalue().splitlines()]
    kinds = [r["kind"] for r in rows]
    assert kinds.count("span") == 2 and kinds.count("instant") == 1
    assert any(r["kind"] == "metric" and r["name"] == "a.count"
               and r["value"] == 5 for r in rows)
    spans = [r for r in rows if r["kind"] == "span"]
    assert all(r["dur_us"] >= 0 and r["ts_us"] >= 0 for r in spans)


def test_ndjson_reports_drops():
    trace.enable(capacity=2, clear_events=True)
    for i in range(5):
        trace.instant("t", i=i)
    buf = io.StringIO()
    export.write_ndjson(buf, include_metrics=False)
    first = json.loads(buf.getvalue().splitlines()[0])
    assert first["kind"] == "meta" and first["name"] == "trace.dropped"
    assert first["attrs"]["dropped"] == 3


def test_chrome_trace_structure(tmp_path):
    _tiny_trace()
    doc = export.chrome_trace()
    phases = [e["ph"] for e in doc["traceEvents"]]
    assert "M" in phases                       # process/thread metadata
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"a.b.outer", "a.b.inner"}
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in xs)
    assert [e["name"] for e in doc["traceEvents"] if e["ph"] == "i"] \
        == ["a.mark"]
    out = tmp_path / "t.trace.json"
    export.write_chrome_trace(out)
    assert json.loads(out.read_text())["traceEvents"]


def test_report_cli_and_summary(tmp_path, capsys):
    _tiny_trace()
    path = tmp_path / "run.ndjson"
    with open(path, "w") as fh:
        export.write_ndjson(fh)
    summary = report.summarize([json.loads(s)
                                for s in path.read_text().splitlines()])
    names = {s["name"]: s for s in summary["spans"]}
    assert names["a.b.outer"]["count"] == 1
    assert names["a.b.outer"]["total_us"] >= names["a.b.inner"]["total_us"]
    assert report.main([str(path)]) == 0
    assert "a.b.outer" in capsys.readouterr().out
    assert report.main([str(tmp_path / "missing.ndjson")]) == 2


def test_log_emit_stdout_is_plain_print(capsys):
    obs_log.emit("hello world", event="x.y", n=1)
    assert capsys.readouterr().out == "hello world\n"
    trace.enable(clear_events=True)
    obs_log.emit("again", event="x.y", n=2)
    assert capsys.readouterr().out == "again\n"
    (ev,) = trace.events()
    assert ev[0] == "log" and ev[6] == {"text": "again", "n": 2}


# ---------------------------------------------------------------------------
# backend cache stats (satellite: per-bucket breakdown + reset)
# ---------------------------------------------------------------------------


def test_backend_cache_stats_buckets_and_reset():
    backend_mod.clear_jit_cache()
    key = ("test_obs.fn", 7)
    backend_mod.jitted(key, lambda: (lambda x: x + 1))
    backend_mod.jitted(key, lambda: (lambda x: x + 1))
    stats = backend_mod.cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1
    assert stats["entries"] == 1 and stats["hit_rate"] == 0.5
    bucket = stats["buckets"]["test_obs.fn/7"]
    assert bucket["hits"] == 1 and bucket["misses"] == 1
    assert bucket["compile_s"] >= 0.0
    backend_mod.clear_jit_cache()       # also resets the registry
    stats = backend_mod.cache_stats()
    assert stats == {"hits": 0, "misses": 0, "entries": 0,
                     "hit_rate": 0.0, "buckets": {}}


# ---------------------------------------------------------------------------
# identity: tracing must never change a probed function's output
# ---------------------------------------------------------------------------


def _on_off(fn):
    """Run ``fn`` with tracing off then on; return both results."""
    trace.disable()
    off = fn()
    trace.enable(clear_events=True)
    try:
        on = fn()
    finally:
        trace.disable()
        trace.clear()
    return off, on


def test_identity_solve_arrays():
    n = np.array([[2.0, 4.0], [1.0, 3.0]])
    f = np.array([[0.4, 0.7], [0.9, 0.2]])
    bs = np.array([[82.0, 95.0], [120.0, 105.0]])
    for mode in sharing.UTILIZATION_MODES:
        off, on = _on_off(lambda: sharing.solve_arrays(
            n, f, bs, utilization=mode, backend="numpy"))
        for a, b in zip(off, on):
            np.testing.assert_array_equal(a, b)


def test_identity_placed_batch_predict():
    base = api.Scenario.on("CLX").using("CLX-2S")
    scens = [base.placed("DCOPY", 1 + i % 4, "CLX/s0/d0")
                 .placed("DDOT2", 1 + (i * 3) % 4, "CLX/s1/d0")
             for i in range(8)]
    batch = api.ScenarioBatch.of(scens)
    off, on = _on_off(lambda: api.predict(batch).bw_group)
    np.testing.assert_array_equal(off, on)


def test_identity_simulate():
    MB = 1e6
    sc = (api.Scenario.on("CLX").ranks(4)
          .with_noise(6e-5, seed=0, ensemble=2)
          .step("DCOPY", 2 * MB).barrier().step("DAXPY", MB))

    def run():
        res = api.simulate(sc, t_max=60.0)
        return res.t_end.copy(), [res.records(b)
                                  for b in range(res.n_scenarios)]

    (t_off, rec_off), (t_on, rec_on) = _on_off(run)
    np.testing.assert_array_equal(t_off, t_on)
    assert rec_off == rec_on


def test_identity_fit_scaling():
    cores = tuple(range(1, 13))
    bw = forward_bandwidth(np.array(cores), 0.3, 80.0,
                           utilization="queue")
    tr = ScalingTrace(kernel="syn", arch="X", cores=cores,
                      bandwidth=tuple(float(b) for b in bw))
    off, on = _on_off(lambda: fit_scaling([tr], backend="numpy"))
    np.testing.assert_array_equal(off.f, on.f)
    np.testing.assert_array_equal(off.bs, on.bs)


def _coeffs():
    terms = RooflineTerms(name="step", t_compute=0.0, t_memory=0.0,
                          t_collective=0.0, flops=2.0e12,
                          hbm_bytes=8.0e9, wire_bytes=1.0e9,
                          model_flops=2.0e12)
    return pod_step_coefficients(terms), terms


def test_identity_relax_pod_plan():
    coeffs, _ = _coeffs()
    lb, ub = [0.7] * 4, [1.3] * 4
    off, on = _on_off(lambda: relax_pod_plan(coeffs, total=4.0,
                                             lb=lb, ub=ub))
    np.testing.assert_array_equal(off.x, on.x)
    assert off.t == on.t and off.n_iters == on.n_iters
    assert off.trajectory == on.trajectory
    assert off.stop_reason == on.stop_reason


# ---------------------------------------------------------------------------
# relax_pod_plan trajectory + stop reason (satellite regression test)
# ---------------------------------------------------------------------------


def test_relax_trajectory_and_stop_reason():
    coeffs, _ = _coeffs()
    res = relax_pod_plan(coeffs, total=4.0, lb=[0.7] * 4, ub=[1.3] * 4,
                         iters=300)
    # Historical 3-tuple unpacking still works.
    x, t, n = res
    assert (x == res.x).all() and t == res.t and n == res.n_iters
    # Trajectory: initial projection first, one entry per iterate after.
    assert len(res.trajectory) == res.n_iters + 1
    # Best-by-exact-makespan (improvements below the 1e-12 relative
    # stall threshold intentionally don't move the incumbent).
    assert res.t == pytest.approx(min(res.trajectory), rel=1e-11)
    assert res.stop_reason == StopReason.CONVERGED
    assert res.stop_reason == "converged"   # str-enum compares plainly
    assert str(res.stop_reason) == "converged"


def test_relax_stop_reason_iters_exhausted():
    coeffs, _ = _coeffs()
    res = relax_pod_plan(coeffs, total=4.0, lb=[0.7] * 4, ub=[1.3] * 4,
                         iters=2)
    assert res.n_iters == 2
    assert res.stop_reason == StopReason.ITERS_EXHAUSTED
    assert len(res.trajectory) == 3


def test_relax_stop_reason_point_polytope():
    coeffs, _ = _coeffs()
    res = relax_pod_plan(coeffs, total=4.0, lb=[1.0] * 4, ub=[1.0] * 4)
    assert res.stop_reason == StopReason.POINT_POLYTOPE
    assert res.n_iters == 0 and len(res.trajectory) == 1
    np.testing.assert_allclose(res.x, [1.0] * 4)


def test_gradient_plan_result_carries_relaxation():
    _, terms = _coeffs()
    cands = [(1.0, 1.0, 1.0, 1.0), (1.3, 0.9, 0.9, 0.9),
             (0.7, 1.1, 1.1, 1.1)]
    res = gradient_pod_plan(terms, cands)
    assert isinstance(res.stop_reason, StopReason)
    assert len(res.trajectory) == res.n_iters + 1
    assert res.t_relaxed == pytest.approx(min(res.trajectory), rel=1e-11)


# ---------------------------------------------------------------------------
# overhead: the disabled fast path must stay in nanosecond territory
# ---------------------------------------------------------------------------


def test_disabled_probe_calls_are_cheap():
    assert not trace.enabled()
    reps = 20_000
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _ in range(reps):
            trace.span("bench.noop")
        best = min(best, (time.perf_counter() - t0) / reps)
    # ~0.1 µs in practice; 5 µs is the generous CI-noise ceiling that
    # still guarantees < 2 % on any probed hot path (see BENCH_obs.json
    # for the certified end-to-end numbers).
    assert best < 5e-6, f"disabled span() costs {best * 1e9:.0f} ns"
