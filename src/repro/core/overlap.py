"""Overlap-aware TPU step model — the paper's bandwidth-sharing idea applied
to a TPU chip's HBM interface.

The classical three-term roofline ``max(T_comp, T_mem, T_coll)`` assumes the
collective's HBM drain is free; serial addition assumes no overlap at all.
This module interpolates with the paper's model: when a compute phase overlaps
with a collective whose send/recv buffers also stream through HBM, both are
"kernels" contending for HBM bandwidth.  Each phase's memory request fraction
is ``f = T_hbm / T_phase`` (the TPU analogue of ECM Eq. 2); the collective's
HBM stream has ``f ≈ 1`` while it is ICI-bound (DMA continuously drains).

Used by runtime/overlap_schedule.py to decide whether overlapping a gradient
reduce-scatter with backward compute is a win, and with what bucket size.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .machine import TPU_V5E, TpuModel
from .sharing import Group
from .topology import ContentionDomain, predict_single_domain


@dataclasses.dataclass(frozen=True)
class Phase:
    """One schedulable unit of a step (e.g. 'bwd matmul L17', 'grad RS')."""

    name: str
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0

    def times(self, tpu: TpuModel = TPU_V5E) -> tuple[float, float, float]:
        t_c = self.flops / tpu.peak_flops_bf16
        t_m = self.hbm_bytes / (tpu.hbm_bw_gbs * 1e9)
        t_i = self.ici_bytes / (tpu.ici_links * tpu.ici_link_gbs * 1e9)
        return t_c, t_m, t_i

    def t_solo(self, tpu: TpuModel = TPU_V5E) -> float:
        """Roofline time of the phase running alone on the chip."""
        return max(self.times(tpu))

    def request_fraction(self, tpu: TpuModel = TPU_V5E) -> float:
        """f = T_hbm / T_phase — how hungry this phase is for HBM while it
        runs (paper Eq. 2 with T_phase playing T_ECM)."""
        t = self.t_solo(tpu)
        if t <= 0:
            return 0.0
        return min(1.0, self.times(tpu)[1] / t)


@dataclasses.dataclass(frozen=True)
class OverlapPrediction:
    t_serial: float      # phases run back-to-back
    t_overlap: float     # phases co-scheduled, HBM shared per the model
    t_naive: float       # max(t_a, t_b): the "perfect overlap" assumption

    @property
    def gain_vs_serial(self) -> float:
        return self.t_serial / self.t_overlap if self.t_overlap else 1.0

    @property
    def worthwhile(self) -> bool:
        return self.t_overlap < self.t_serial * 0.995


def _chip_domain(tpu: TpuModel) -> ContentionDomain:
    """One chip's HBM interface as a contention domain (the TPU leaf of
    core/topology.py trees)."""
    return ContentionDomain(f"{tpu.name}/hbm", n_cores=8, tpu=tpu)


def _hbm_shared_rates(active: Sequence[Phase], tpu: TpuModel
                      ) -> list[float]:
    """Per-phase progress rate (fraction of solo speed) while co-scheduled.

    HBM is arbitrated by the paper's model on the chip's contention domain:
    each phase is a Group with n=1 (one DMA/load stream agent), f from
    Eq. 2, and b_s = HBM bandwidth (the envelope does not vary by stream
    kind on TPU: Eq. 4 degenerates to b_s).  A phase's non-HBM legs (MXU
    time, ICI time) are unaffected; its HBM leg stretches by 1/share.
    """
    groups = [Group(n=1, f=p.request_fraction(tpu), bs=tpu.hbm_bw_gbs,
                    name=p.name) for p in active]
    # numpy backend: overlap_pair calls this every event step with 2-3
    # groups, where jit dispatch overhead would dominate the solve.
    pred = predict_single_domain(groups, _chip_domain(tpu),
                                 backend="numpy")
    rates = []
    for p, bw in zip(active, pred.bw_group):
        t_c, t_m, t_i = p.times(tpu)
        solo = p.t_solo(tpu)
        if solo <= 0:
            rates.append(1.0)
            continue
        if p.hbm_bytes <= 0:
            t_m_shared = 0.0
        elif bw > 0:
            t_m_shared = p.hbm_bytes / (bw * 1e9)
        else:
            t_m_shared = float("inf")
        stretched = max(t_c, t_m_shared, t_i)
        rates.append(solo / stretched if stretched > 0 else 1.0)
    return rates


def overlap_pair(a: Phase, b: Phase, tpu: TpuModel = TPU_V5E
                 ) -> OverlapPrediction:
    """Co-schedule two phases; event-step until both complete."""
    t_serial = a.t_solo(tpu) + b.t_solo(tpu)
    t_naive = max(a.t_solo(tpu), b.t_solo(tpu))

    remaining = {p.name: p.t_solo(tpu) for p in (a, b)}
    tol = {p.name: max(p.t_solo(tpu) * 1e-9, 1e-18) for p in (a, b)}
    phases = {p.name: p for p in (a, b)}
    t = 0.0
    while remaining:
        active = [phases[k] for k in sorted(remaining)]
        rates = _hbm_shared_rates(active, tpu)
        # time to first completion at current rates
        dt = min(remaining[p.name] / r if r > 0 else float("inf")
                 for p, r in zip(active, rates))
        if not (dt < float("inf")):
            break  # nothing can progress (degenerate zero-work phases)
        t += dt
        done = []
        for p, r in zip(active, rates):
            remaining[p.name] -= r * dt
            if remaining[p.name] <= tol[p.name]:
                done.append(p.name)
        for k in done:
            del remaining[k]
    return OverlapPrediction(t_serial=t_serial, t_overlap=t, t_naive=t_naive)


def best_bucket_count(compute: Phase, collective: Phase, *,
                      max_buckets: int = 32, tpu: TpuModel = TPU_V5E
                      ) -> tuple[int, float]:
    """Choose how many buckets to split ``collective`` into so that each
    bucket overlaps the tail of ``compute`` (classic DDP bucketing, but sized
    with the sharing model instead of assuming free overlap).

    Returns (n_buckets, predicted step time).  n_buckets == 0 means "do not
    overlap — run the collective after compute".
    """
    t_serial = compute.t_solo(tpu) + collective.t_solo(tpu)
    best = (0, t_serial)
    for nb in (1, 2, 4, 8, 16, max_buckets):
        if nb > max_buckets:
            break
        # Bucket i of the collective overlaps the last (nb-i)/nb of compute:
        # approximate by overlapping the whole collective with the whole
        # compute but with the collective's first bucket delayed; with equal
        # buckets the pipeline behaves like pair-overlap plus one bucket of
        # exposed tail.
        bucket = Phase(collective.name + f"/b{nb}",
                       flops=collective.flops / nb,
                       hbm_bytes=collective.hbm_bytes / nb,
                       ici_bytes=collective.ici_bytes / nb)
        pair_pred = overlap_pair(compute, Phase(
            collective.name + "/body",
            flops=collective.flops * (nb - 1) / nb,
            hbm_bytes=collective.hbm_bytes * (nb - 1) / nb,
            ici_bytes=collective.ici_bytes * (nb - 1) / nb), tpu)
        t = pair_pred.t_overlap + bucket.t_solo(tpu)
        if t < best[1]:
            best = (nb, t)
    return best
