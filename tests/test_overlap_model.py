"""Tests for the TPU overlap-aware step model (beyond-paper application)."""

import pytest

from repro.core.machine import TPU_V5E
from repro.core.overlap import Phase, best_bucket_count, overlap_pair


def test_phase_roofline_times():
    p = Phase("x", flops=197e12, hbm_bytes=0.0)
    assert p.t_solo() == pytest.approx(1.0)
    p = Phase("m", hbm_bytes=819e9)
    assert p.t_solo() == pytest.approx(1.0)
    p = Phase("c", ici_bytes=4 * 50e9)
    assert p.t_solo() == pytest.approx(1.0)


def test_request_fraction():
    # Perfectly compute-bound: f ~ ratio of mem time to total.
    p = Phase("mm", flops=197e12, hbm_bytes=819e9 / 2)
    assert p.request_fraction() == pytest.approx(0.5)
    p = Phase("stream", hbm_bytes=819e9)
    assert p.request_fraction() == pytest.approx(1.0)


def test_compute_plus_collective_overlaps_well():
    """A compute-bound phase (low f) and an ICI-bound collective (tiny HBM
    demand) overlap almost perfectly."""
    comp = Phase("bwd", flops=1e12, hbm_bytes=1e9)      # f ~ 0.24
    coll = Phase("rs", ici_bytes=1e9, hbm_bytes=1e8)    # ICI-bound
    pred = overlap_pair(comp, coll)
    assert pred.t_overlap < pred.t_serial * 0.75
    assert pred.t_overlap >= pred.t_naive * 0.999


def test_two_memory_bound_phases_dont_overlap():
    """Two HBM-saturating streams: sharing model says overlap ~ serial
    (the classical 'perfect overlap' roofline would wrongly claim 2x)."""
    a = Phase("a", hbm_bytes=1e9)
    b = Phase("b", hbm_bytes=1e9)
    pred = overlap_pair(a, b)
    assert pred.t_overlap == pytest.approx(pred.t_serial, rel=0.05)
    assert pred.t_naive == pytest.approx(pred.t_serial / 2, rel=1e-6)
    assert not pred.worthwhile


def test_overlap_never_worse_than_serial_or_better_than_naive():
    cases = [
        (Phase("a", flops=5e12, hbm_bytes=2e9), Phase("b", ici_bytes=5e8)),
        (Phase("a", hbm_bytes=3e9), Phase("b", flops=9e13, hbm_bytes=1e8)),
        (Phase("a", hbm_bytes=1e9, ici_bytes=1e9), Phase("b", hbm_bytes=1e9)),
    ]
    for a, b in cases:
        pred = overlap_pair(a, b)
        assert pred.t_overlap <= pred.t_serial * (1 + 1e-9)
        assert pred.t_overlap >= pred.t_naive * (1 - 1e-9)


def test_bucket_count_for_gradient_reduce():
    """Typical FSDP backward: compute-bound backward + ICI reduce-scatter.
    Bucketing should find overlap worthwhile with >= 1 bucket."""
    bwd = Phase("bwd", flops=50e12, hbm_bytes=10e9)
    rs = Phase("rs", ici_bytes=8e9, hbm_bytes=2e9)
    nb, t = best_bucket_count(bwd, rs)
    assert nb >= 1
    assert t < bwd.t_solo() + rs.t_solo()


def test_bucket_count_skips_hopeless_overlap():
    """Two fully HBM-bound phases: overlap gains nothing; expect 0 or a
    no-better-than-serial outcome."""
    a = Phase("a", hbm_bytes=5e9)
    b = Phase("b", hbm_bytes=5e9)
    nb, t = best_bucket_count(a, b)
    assert t >= (a.t_solo() + b.t_solo()) * 0.99
