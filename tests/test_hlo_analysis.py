"""Tests for HLO collective parsing and roofline-term construction."""

import pytest

from repro.core.hlo import (CollectiveStats, RooflineTerms, collective_stats,
                            roofline_terms)
from repro.core.machine import TPU_V5E

HLO_SAMPLE = """
HloModule jit_step

ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[512,256]{1,0} all-gather(f32[128,256]{1,0} %ar), replica_groups={{0,1,2,3}}, dimensions={0}
  %rs = bf16[32,256]{1,0} reduce-scatter(bf16[128,256]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[512,256]{1,0} collective-permute(f32[512,256]{1,0} %ag), source_target_pairs={{0,1},{1,2}}
  %a2a = f32[512,256]{1,0} all-to-all(f32[512,256]{1,0} %cp), replica_groups={{0,1,2,3}}
  ROOT %done = f32[128,256]{1,0} add(%p0, %p0)
}
"""


def test_counts():
    s = collective_stats(HLO_SAMPLE)
    assert s.counts == {"all-reduce": 1, "all-gather": 1, "reduce-scatter": 1,
                        "collective-permute": 1, "all-to-all": 1}


def test_operand_bytes():
    s = collective_stats(HLO_SAMPLE)
    f32_128_256 = 128 * 256 * 4
    assert s.operand_bytes["all-reduce"] == f32_128_256
    assert s.operand_bytes["all-gather"] == f32_128_256
    assert s.operand_bytes["reduce-scatter"] == 128 * 256 * 2
    assert s.operand_bytes["collective-permute"] == 512 * 256 * 4


def test_wire_bytes_ring_model():
    s = collective_stats(HLO_SAMPLE)
    f32_128_256 = 128 * 256 * 4
    ring = 3 / 4
    assert s.wire_bytes["all-reduce"] == pytest.approx(2 * f32_128_256 * ring)
    # all-gather wire bytes charge the (bigger) result.
    assert s.wire_bytes["all-gather"] == pytest.approx(
        512 * 256 * 4 * ring)
    assert s.wire_bytes["reduce-scatter"] == pytest.approx(
        128 * 256 * 2 * ring)
    assert s.wire_bytes["collective-permute"] == pytest.approx(512 * 256 * 4)


def test_async_start_done_counted_once():
    text = """
  %ags = (f32[128]{0}, f32[512]{0}) all-gather-start(f32[128]{0} %x), replica_groups={{0,1,2,3}}
  %agd = f32[512]{0} all-gather-done((f32[128]{0}, f32[512]{0}) %ags)
"""
    s = collective_stats(text)
    assert s.counts.get("all-gather", 0) == 1


def test_no_collectives():
    s = collective_stats("ENTRY main { ROOT %x = f32[2]{0} parameter(0) }")
    assert s.total_wire_bytes == 0
    assert s.counts == {}


def test_roofline_terms_dominance():
    stats = CollectiveStats(counts={}, operand_bytes={}, wire_bytes={})
    # Memory-bound case: 819 GB moved per device, tiny flops.
    t = roofline_terms("x", {"flops": 1e9, "bytes accessed": 819e9},
                       stats, n_chips=256, model_flops_total=1e9 * 256)
    assert t.dominant == "memory"
    assert t.t_memory == pytest.approx(1.0)
    assert t.hbm_bytes == pytest.approx(819e9)


def test_roofline_fraction_useful_flops():
    stats = CollectiveStats(counts={}, operand_bytes={}, wire_bytes={})
    cost = {"flops": 2 * 197e12, "bytes accessed": 1e9}
    t = roofline_terms("x", cost, stats, n_chips=1,
                       model_flops_total=197e12)
    # Half the compiled flops are useful; compute-bound; fraction = 0.5.
    assert t.dominant == "compute"
    assert t.roofline_fraction == pytest.approx(0.5)
    assert t.useful_flop_ratio == pytest.approx(0.5)


def test_group_size_v2_form():
    text = ("%ar = f32[64]{0} all-reduce(f32[64]{0} %x), "
            "replica_groups=[2,128]<=[256]")
    s = collective_stats(text)
    ring = 127 / 128
    assert s.wire_bytes["all-reduce"] == pytest.approx(2 * 64 * 4 * ring)


# ---------------------------------------------------------------------------
# _shape_bytes dtype coverage (the shape grammar's element types)
# ---------------------------------------------------------------------------


def test_shape_bytes_full_width_dtypes():
    from repro.core.hlo import _shape_bytes
    assert _shape_bytes("f32", "256,1024") == 256 * 1024 * 4
    assert _shape_bytes("pred", "64") == 64
    assert _shape_bytes("s8", "10") == 10
    assert _shape_bytes("s16", "10") == 20
    assert _shape_bytes("u32", "10") == 40
    assert _shape_bytes("c128", "2") == 32
    assert _shape_bytes("f32", "") == 4           # scalar f32[]


def test_shape_bytes_f8_variants():
    from repro.core.hlo import _shape_bytes
    for dt in ("f8e4m3fn", "f8e5m2", "f8e4m3", "f8e3m4",
               "f8e4m3fnuz", "f8e5m2fnuz", "f8e4m3b11fnuz", "f8e8m0fnu"):
        assert _shape_bytes(dt, "128") == 128, dt


def test_shape_bytes_subbyte_types_pack():
    from repro.core.hlo import _shape_bytes
    assert _shape_bytes("s4", "16") == 8          # two per byte
    assert _shape_bytes("u4", "3") == 2           # rounds up
    assert _shape_bytes("f4e2m1fn", "8") == 4


def test_shape_bytes_unknown_dtype_raises_with_suggestion():
    from repro.core.hlo import _shape_bytes
    with pytest.raises(ValueError, match=r"did you mean 'f8e4m3fn'"):
        _shape_bytes("f8e4m3fn2", "8")
    with pytest.raises(ValueError, match="_DTYPE_BITS"):
        _shape_bytes("q32", "8")


def test_collective_stats_counts_f8_traffic():
    text = ("%ar = f8e4m3fnuz[128,256] all-reduce("
            "f8e4m3fnuz[128,256] %x), replica_groups={{0,1}}")
    s = collective_stats(text)
    assert s.operand_bytes["all-reduce"] == 128 * 256
