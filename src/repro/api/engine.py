"""The facade's two verbs: ``predict(scenario)`` and ``simulate(scenario)``.

Callers declare *what* (a :class:`repro.api.scenario.Scenario` or
:class:`ScenarioBatch`); this module picks *how*:

=====================  =====================================================
scenario shape          engine
=====================  =====================================================
single, unplaced        scalar reference path (``sharing.predict``)
single, placed          topology solver (``topology.predict_placed``)
batch, B < 64           batched numpy solver (``sharing.solve_batch``)
batch, B >= 64          jitted jax solver, when importable (else numpy)
any, ``simulate``       batched desync event engine
                        (``desync_batch.run_batch``; numpy reference or
                        jitted ``lax.while_loop`` on request)
=====================  =====================================================

The old module-level entry points stay exactly as they are — they *are*
the engines — so the facade adds dispatch and a uniform result schema
(:mod:`repro.api.results`), never a second implementation: a facade
prediction is bit-for-bit what the underlying engine returns.
"""

from __future__ import annotations

import random
from typing import Sequence

from ..core import desync_batch, sharing, topology as topology_mod
from ..core.desync import Allreduce, Idle, Item, WaitNeighbors, Work
from ..core.sharing import HAVE_JAX
from ..core.table2 import KernelSpec
from .results import (BatchPrediction, Prediction, SimulationResult,
                      from_share_prediction, from_topology_prediction)
from .scenario import Scenario, ScenarioBatch

#: Batches at least this large dispatch to the jitted jax solver (when
#: importable) under ``backend="auto"``: below it, jit dispatch overhead
#: outweighs the vmap win (see BENCH_api.json).
JAX_BATCH_CUTOFF = 64


def _batch_backend(batch: ScenarioBatch, override: str | None) -> str:
    backend = override or batch.scenarios[0].backend
    if backend == "auto":
        return "jax" if (HAVE_JAX and len(batch) >= JAX_BATCH_CUTOFF) \
            else "numpy"
    return backend


def predict(scenario: Scenario | ScenarioBatch, *,
            backend: str | None = None) -> Prediction | BatchPrediction:
    """Solve the sharing model (Eqs. 4–5) for a scenario or batch.

    Dispatches per the table in the module doc; ``backend`` overrides the
    scenario's own backend option (``"numpy"`` / ``"jax"`` / ``"auto"``).
    Returns a :class:`Prediction` for a single scenario, a
    :class:`BatchPrediction` for a batch.
    """
    if isinstance(scenario, ScenarioBatch):
        return _predict_batch(scenario, backend)
    if not isinstance(scenario, Scenario):
        raise TypeError(
            f"predict() takes a Scenario or ScenarioBatch, got "
            f"{type(scenario).__name__}")
    if scenario.steps:
        raise ValueError(
            "this scenario describes rank programs (.step); use "
            "simulate(scenario) for the event engine, or .run groups "
            "for predict()")
    if scenario.is_placed or scenario.topo is not None:
        return _predict_placed(scenario, backend)
    pred = sharing.predict(scenario.groups, **scenario.solver_options())
    return from_share_prediction(pred, arch=scenario.arch,
                                 provenance=scenario.provenance,
                                 engine="scalar")


def _predict_placed(scenario: Scenario, backend: str | None) -> Prediction:
    if scenario.topo is None:
        raise ValueError(
            "scenario has .placed groups but no topology; add "
            ".using(<topology or preset name>)")
    missing = [r.tag for r in scenario.runs if r.domain is None]
    if missing:
        raise ValueError(
            f"groups {missing} have no domain but the scenario has a "
            f"topology; place every group with .placed(kernel, n, domain)")
    placements = [topology_mod.Placed(r.group(scenario.arch), r.domain)
                  for r in scenario.runs]
    kwargs = scenario.solver_options()
    kwargs["backend"] = backend or scenario.backend
    kwargs["strict"] = scenario.strict
    pred = topology_mod.predict_placed(scenario.topo, placements, **kwargs)
    return from_topology_prediction(pred, arch=scenario.arch,
                                    provenance=scenario.provenance)


def _predict_batch(batch: ScenarioBatch,
                   backend: str | None) -> BatchPrediction:
    batch.predictable  # cached O(B) validation; raises on misuse
    resolved = _batch_backend(batch, backend)
    n, f, bs, names = batch.arrays
    raw = sharing.solve_batch(n, f, bs, names=names, backend=resolved,
                              **batch.scenarios[0].solver_options())
    return BatchPrediction(archs=batch.archs, engine=resolved,
                           raw=raw, provenance=batch.provenance)


# ---------------------------------------------------------------------------
# simulate
# ---------------------------------------------------------------------------


def _noise_items(scenario: Scenario, member: int,
                 R: int) -> list[Item | None]:
    """Per-rank leading Idle items for ensemble member ``member`` — drawn
    in rank order from ``Random(seed + member)``, the convention every
    pre-facade consumer (straggler monitor, HPCG study) used, so
    migrated callers reproduce their histories bit-for-bit."""
    noise = scenario.noise
    if noise is None:
        return [None] * R
    rng = random.Random(noise.seed + member)
    return [Idle(rng.expovariate(1.0 / noise.exp_mean_s), tag=noise.tag)
            for _ in range(R)]


def _programs_for(scenario: Scenario, member: int
                  ) -> tuple[list[list[Item]], Sequence[str] | None]:
    """One ensemble member's per-rank programs + placement."""
    if scenario.steps:
        R = scenario.n_ranks
        if R is None:
            raise ValueError("program-mode scenario never called .ranks(R)")
        lead = _noise_items(scenario, member, R)
        progs: list[list[Item]] = []
        for r in range(R):
            prog: list[Item] = [lead[r]] if lead[r] is not None else []
            for s in scenario.steps:
                if s.kind == "work":
                    prog.append(Work(s.resolved.name, s.bytes_for(r),
                                     tag=s.tag))
                elif s.kind == "barrier":
                    prog.append(Allreduce(cost_s=s.cost_s, tag=s.tag))
                elif s.kind == "halo":
                    prog.append(WaitNeighbors(cost_s=s.cost_s, tag=s.tag))
                else:
                    prog.append(Idle(s.cost_s, tag=s.tag))
            progs.append(prog)
        return progs, scenario.rank_domains
    # Group mode: each run contributes n ranks, one Work each.
    if not scenario.runs:
        raise ValueError("nothing to simulate: scenario has no groups or "
                         "steps")
    R = scenario.total_threads
    lead = _noise_items(scenario, member, R)
    progs = []
    placement: list[str] = []
    r = 0
    for run in scenario.runs:
        for _ in range(run.n):
            prog = [lead[r]] if lead[r] is not None else []
            prog.append(Work(run.resolved.name, run.bytes, tag=run.tag))
            progs.append(prog)
            placement.append(run.domain or "")
            r += 1
    has_domains = any(placement)
    if has_domains and not all(placement):
        raise ValueError(
            "either every group or no group must be placed on a domain")
    return progs, (tuple(placement) if has_domains else None)


def _collect_specs(scenarios: Sequence[Scenario]) -> dict[str, KernelSpec]:
    specs: dict[str, KernelSpec] = {}
    for sc in scenarios:
        for res in ([s.resolved for s in sc.steps if s.resolved is not None]
                    + [r.resolved for r in sc.runs]):
            prev = specs.get(res.name)
            if prev is not None and prev is not res.spec \
                    and prev != res.spec:
                raise ValueError(
                    f"two different specs named {res.name!r} in one "
                    f"simulation batch")
            specs[res.name] = res.spec
    return specs


def simulate(scenario: Scenario | ScenarioBatch, *,
             backend: str | None = None, t_max: float | None = None,
             on_deadlock: str = "mask") -> SimulationResult:
    """Run a scenario (or batch) through the desync event engine.

    A single scenario with ``.with_noise(..., ensemble=B)`` expands to B
    independent noise draws; a :class:`ScenarioBatch` simulates its B
    scenarios (each contributing one member — candidate plans, phase
    mixes).  All members advance in **one**
    :func:`repro.core.desync_batch.run_batch` call.

    ``backend`` (``"numpy"`` default / ``"jax"``) and ``t_max`` override
    the scenarios' options; ``on_deadlock`` is the batched engine's
    masking contract (``"mask"`` or ``"raise"``).
    """
    if isinstance(scenario, Scenario):
        members = [(scenario, b)
                   for b in range(scenario.noise.ensemble
                                  if scenario.noise else 1)]
        scenarios = [scenario]
    elif isinstance(scenario, ScenarioBatch):
        scenarios = list(scenario.scenarios)
        for i, sc in enumerate(scenarios):
            if sc.noise is not None and sc.noise.ensemble != 1:
                raise ValueError(
                    f"scenario {i} asks for a noise ensemble inside a "
                    f"ScenarioBatch; ensembles are for single-scenario "
                    f"simulate()")
        members = [(sc, 0) for sc in scenarios]
    else:
        raise TypeError(
            f"simulate() takes a Scenario or ScenarioBatch, got "
            f"{type(scenario).__name__}")

    first = scenarios[0]
    programs_batch = []
    placement0: Sequence[str] | None = None
    for i, (sc, member) in enumerate(members):
        if sc.arch != first.arch:
            raise ValueError("all simulated scenarios must share one arch")
        if t_max is None and sc.t_max != first.t_max:
            raise ValueError(
                f"scenario {i} sets t_max={sc.t_max} but scenario 0 "
                f"sets {first.t_max}; a batch runs on one clock horizon "
                f"(or pass t_max= to simulate() explicitly)")
        if sc.topo != first.topo:
            raise ValueError(
                f"scenario {i} uses a different topology than "
                f"scenario 0; a batch shares one topology")
        progs, placement = _programs_for(sc, member)
        if i == 0:
            placement0 = placement
        elif placement != placement0:
            raise ValueError(
                "all simulated scenarios must share one placement")
        programs_batch.append(progs)

    topo = first.topo
    if placement0 is not None and topo is None:
        raise ValueError(
            "scenario places ranks on domains but has no topology; add "
            ".using(<topology or preset name>)")
    if topo is not None and placement0 is None:
        topo = None  # unplaced scenario on a topology: single shared domain

    resolved_backend = backend or ("numpy" if first.backend == "auto"
                                   else first.backend)
    res = desync_batch.run_batch(
        programs_batch, first.arch, _collect_specs(scenarios),
        topology=topo, placement=placement0,
        t_max=t_max if t_max is not None else first.t_max,
        backend=resolved_backend, on_deadlock=on_deadlock)
    return SimulationResult(arch=first.arch,
                            engine=f"desync-{resolved_backend}", raw=res)
