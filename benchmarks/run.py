"""Benchmark driver: one module per paper table/figure.

Default output is ``name,us_per_call,derived`` CSV on stdout:
    PYTHONPATH=src python -m benchmarks.run [--only fig8]

``--json`` instead aggregates every module's rows into one
machine-readable report (optionally written to ``--out``):
    PYTHONPATH=src python -m benchmarks.run --json --out report.json
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

from . import (api_overhead, calibrate_roundtrip, desync_scaling,
               fig6_full_domain, fig7_symmetric, fig8_error, fig9_pairings,
               hpcg_desync, table2_kernels, tpu_overlap)

MODULES = {
    "table2": table2_kernels,
    "fig6": fig6_full_domain,
    "fig7": fig7_symmetric,
    "fig8": fig8_error,
    "fig9": fig9_pairings,
    "hpcg": hpcg_desync,
    "tpu_overlap": tpu_overlap,
    "desync_scaling": desync_scaling,
    "calibrate": calibrate_roundtrip,
    "api_overhead": api_overhead,
}


def collect(keys) -> tuple[dict[str, list[dict]], dict[str, str]]:
    """Run the requested modules; returns (rows per module, failures)."""
    results: dict[str, list[dict]] = {}
    failures: dict[str, str] = {}
    for key in keys:
        try:
            results[key] = [
                {"name": name, "us_per_call": round(us, 1),
                 "derived": derived}
                for name, us, derived in MODULES[key].rows()]
        except Exception:  # noqa: BLE001
            failures[key] = traceback.format_exc(limit=1)
    return results, failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=sorted(MODULES), default=None)
    ap.add_argument("--json", action="store_true",
                    help="emit one aggregated JSON report instead of CSV")
    ap.add_argument("--out", default=None,
                    help="with --json: write the report here instead of "
                         "stdout")
    args = ap.parse_args()
    keys = [args.only] if args.only else list(MODULES)

    if args.json:
        results, failures = collect(keys)
        report = {
            "benchmark": "benchmarks.run",
            "modules": results,
            "failures": failures,
            "n_rows": sum(len(r) for r in results.values()),
            "ok": not failures,
        }
        text = json.dumps(report, indent=2) + "\n"
        if args.out:
            with open(args.out, "w") as fh:
                fh.write(text)
            print(f"wrote {args.out}  (modules={len(results)}, "
                  f"rows={report['n_rows']}, ok={report['ok']})")
        else:
            sys.stdout.write(text)
        if failures:
            sys.exit(1)
        return

    print("name,us_per_call,derived")
    failures = 0
    for key in keys:
        try:
            for name, us, derived in MODULES[key].rows():
                print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{key}/ERROR,0.0,{traceback.format_exc(limit=1)!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
