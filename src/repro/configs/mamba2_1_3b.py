"""mamba2-1.3b [ssm]: SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,             # unused (attention-free)
    kv_heads=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_chunk=256,
    ssm_expand=2,
    ssm_heads=64,          # d_inner 4096 / head_dim 64
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, vocab=512, ssm_state=16,
        ssm_chunk=32, ssm_heads=4, remat=False, dtype="float32")
