"""HPCG desynchronization demo (paper Figs. 1 & 3), with rank timelines.

Run:  PYTHONPATH=src python examples/hpcg_desync_demo.py
"""

import random

from repro.core.desync import (Allreduce, DesyncSimulator, Idle, Work,
                               durations_by_tag, skewness)

MB = 1e6
N = 20


def program(rng, tail):
    return [
        Idle(rng.expovariate(1 / 6e-5), tag="noise"),
        Work("Schoenauer", 40 * MB, tag="symgs"),
        Work("DDOT2", 8 * MB, tag="ddot2"),
        *tail,
    ]


def run(tail, label):
    rng = random.Random(7)
    sim = DesyncSimulator([program(rng, tail) for _ in range(N)], "CLX")
    recs = sim.run(t_max=60)
    dd = durations_by_tag(recs, "ddot2", n_ranks=N)
    starts = {r.rank: r.start for r in recs if r.tag == "ddot2"}
    print(f"\n--- {label} ---")
    print(f"DDOT2 accumulated-time skewness: {skewness(dd):+.2f}")
    order = sorted(range(N), key=lambda r: starts[r])
    t0 = min(starts.values())
    scale = 4e4
    for r in order:
        rec = next(x for x in recs if x.tag == "ddot2" and x.rank == r)
        off = int((rec.start - t0) * scale)
        width = max(1, int(rec.duration * scale))
        print(f"  rank {r:2d} |{' ' * off}{'#' * width}")


run([Allreduce(), Work("DAXPY", 30 * MB, tag="daxpy")],
    "Fig. 1: DDOT2 -> MPI_Allreduce  (late starters overlap idleness: "
    "RESYNC, negative skew)")
run([Work("DAXPY", 30 * MB, tag="daxpy")],
    "Fig. 3b: DDOT2 -> DAXPY (higher-f follow-up steals bandwidth: "
    "DESYNC, positive skew)")
