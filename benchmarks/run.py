"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV to stdout.  Run with:
    PYTHONPATH=src python -m benchmarks.run [--only fig8]
"""

from __future__ import annotations

import argparse
import sys
import traceback

from . import (calibrate_roundtrip, desync_scaling, fig6_full_domain,
               fig7_symmetric, fig8_error, fig9_pairings, hpcg_desync,
               table2_kernels, tpu_overlap)

MODULES = {
    "table2": table2_kernels,
    "fig6": fig6_full_domain,
    "fig7": fig7_symmetric,
    "fig8": fig8_error,
    "fig9": fig9_pairings,
    "hpcg": hpcg_desync,
    "tpu_overlap": tpu_overlap,
    "desync_scaling": desync_scaling,
    "calibrate": calibrate_roundtrip,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(MODULES), default=None)
    args = ap.parse_args()
    mods = {args.only: MODULES[args.only]} if args.only else MODULES
    print("name,us_per_call,derived")
    failures = 0
    for key, mod in mods.items():
        try:
            for name, us, derived in mod.rows():
                print(f"{name},{us:.1f},{derived}")
            sys.stdout.flush()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{key}/ERROR,0.0,{traceback.format_exc(limit=1)!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
