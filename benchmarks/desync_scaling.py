"""Throughput benchmark for the batched desync engine.

Measures engine throughput in retired events per second over

* a rank sweep      R ∈ {8, 64, 512} at B = 1, and
* a scenario sweep  B ∈ {1, 32, 256} at R = 64,

plus the headline comparison: a B = 256, R = 64 ensemble in one
``run_batch`` call versus 256 sequential scalar ``DesyncSimulator.run``
calls of the same scenarios (the speedup that makes seed-ensemble skew
estimation and candidate-plan search affordable).

Run:  PYTHONPATH=src python benchmarks/desync_scaling.py [--quick]
                                                         [--out FILE]

Writes ``BENCH_desync.json`` (perf-trajectory artifact) and prints the
usual ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import json
import random
import time

from repro.core.desync import Allreduce, DesyncSimulator, Idle, Work
from repro.core.desync_batch import run_batch

MB = 1e6
ARCH = "CLX"
T_MAX = 60.0


def hpcg_programs(n_ranks: int, seed: int):
    """The Fig. 1 HPCG iteration (noise → SymGS → DDOT2 → allreduce →
    DAXPY), scaled down so event count, not simulated seconds, dominates."""
    rng = random.Random(seed)
    progs = []
    for _ in range(n_ranks):
        progs.append([
            Idle(rng.expovariate(1 / 6e-5), tag="noise"),
            Work("Schoenauer", 4 * MB, tag="symgs"),
            Work("DDOT2", 0.8 * MB, tag="ddot2"),
            Allreduce(),
            Work("DAXPY", 3 * MB, tag="daxpy"),
        ])
    return progs


def scenarios(n_scenarios: int, n_ranks: int):
    return [hpcg_programs(n_ranks, seed) for seed in range(n_scenarios)]


def measure_batched(n_scenarios: int, n_ranks: int, *,
                    backend: str = "numpy") -> dict:
    batch = scenarios(n_scenarios, n_ranks)
    t0 = time.perf_counter()
    res = run_batch(batch, ARCH, t_max=T_MAX, backend=backend)
    wall = time.perf_counter() - t0
    return {
        "mode": f"batched-{backend}",
        "B": n_scenarios,
        "R": n_ranks,
        "events": res.n_events,
        "steps": res.n_steps,
        "wall_s": wall,
        "events_per_s": res.n_events / wall if wall > 0 else float("inf"),
    }


def measure_sequential(n_scenarios: int, n_ranks: int) -> dict:
    batch = scenarios(n_scenarios, n_ranks)
    events = 0
    t0 = time.perf_counter()
    for progs in batch:
        events += len(DesyncSimulator(progs, ARCH).run(t_max=T_MAX))
    wall = time.perf_counter() - t0
    return {
        "mode": "sequential-scalar",
        "B": n_scenarios,
        "R": n_ranks,
        "events": events,
        "wall_s": wall,
        "events_per_s": events / wall if wall > 0 else float("inf"),
    }


def run_grid(*, quick: bool = False) -> dict:
    rank_sweep = [8, 64] if quick else [8, 64, 512]
    scen_sweep = [1, 32] if quick else [1, 32, 256]
    speedup_b = 32 if quick else 256
    speedup_r = 64

    out = {
        "benchmark": "desync_scaling",
        "arch": ARCH,
        "quick": quick,
        "rank_sweep": [measure_batched(1, r) for r in rank_sweep],
        "scenario_sweep": [measure_batched(b, 64) for b in scen_sweep],
    }
    seq = measure_sequential(speedup_b, speedup_r)
    bat = measure_batched(speedup_b, speedup_r)
    out["speedup"] = {
        "B": speedup_b,
        "R": speedup_r,
        "sequential": seq,
        "batched": bat,
        "x": seq["wall_s"] / bat["wall_s"] if bat["wall_s"] > 0
        else float("inf"),
    }
    return out


def rows():
    """CSV rows for benchmarks/run.py (quick grid, so the driver stays
    fast; the full grid runs via __main__ / the slow CI job)."""
    grid = run_grid(quick=True)
    out = []
    for entry in grid["rank_sweep"] + grid["scenario_sweep"]:
        out.append((
            f"desync_scaling/B{entry['B']}xR{entry['R']}",
            entry["wall_s"] * 1e6,
            f"events={entry['events']};events_per_s="
            f"{entry['events_per_s']:.0f}"))
    sp = grid["speedup"]
    out.append((
        f"desync_scaling/speedup_B{sp['B']}xR{sp['R']}",
        sp["batched"]["wall_s"] * 1e6,
        f"speedup_vs_sequential={sp['x']:.1f}x"))
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid (smoke test)")
    ap.add_argument("--out", default="BENCH_desync.json",
                    help="JSON output path")
    args = ap.parse_args()
    grid = run_grid(quick=args.quick)
    with open(args.out, "w") as fh:
        json.dump(grid, fh, indent=2)
    for entry in grid["rank_sweep"] + grid["scenario_sweep"]:
        print(f"B={entry['B']:>4} R={entry['R']:>4}  "
              f"{entry['events']:>7} events  {entry['wall_s']:8.3f}s  "
              f"{entry['events_per_s']:>10.0f} events/s")
    sp = grid["speedup"]
    print(f"B={sp['B']} R={sp['R']} batched {sp['batched']['wall_s']:.3f}s "
          f"vs sequential {sp['sequential']['wall_s']:.3f}s  ->  "
          f"{sp['x']:.1f}x")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
