"""HPCG desynchronization demo (paper Figs. 1 & 3), with rank timelines.

The whole experiment is one declarative facade scenario: 20 MPI ranks,
an exponential start jitter, the HPCG phase sequence, and a tail that
either resynchronizes (allreduce) or amplifies skew (DAXPY).

Run:  PYTHONPATH=src python examples/hpcg_desync_demo.py
"""

from repro import api

MB = 1e6
N = 20

BASE = (api.Scenario.on("CLX").ranks(N)
        .with_noise(6e-5, seed=7)
        .step("Schoenauer", 40 * MB, tag="symgs")
        .step("DDOT2", 8 * MB, tag="ddot2"))


def run(scenario, label):
    res = api.simulate(scenario, t_max=60)
    dd = res.durations("ddot2")
    recs = res.records()
    starts = {r.rank: r.start for r in recs if r.tag == "ddot2"}
    print(f"\n--- {label} ---")
    print(f"DDOT2 accumulated-time skewness: {res.skew('ddot2')[0]:+.2f}")
    order = sorted(range(N), key=lambda r: starts[r])
    t0 = min(starts.values())
    scale = 4e4
    for r in order:
        rec = next(x for x in recs if x.tag == "ddot2" and x.rank == r)
        off = int((rec.start - t0) * scale)
        width = max(1, int(rec.duration * scale))
        print(f"  rank {r:2d} |{' ' * off}{'#' * width}")
    return dd


run(BASE.barrier().step("DAXPY", 30 * MB, tag="daxpy"),
    "Fig. 1: DDOT2 -> MPI_Allreduce  (late starters overlap idleness: "
    "RESYNC, negative skew)")
run(BASE.step("DAXPY", 30 * MB, tag="daxpy"),
    "Fig. 3b: DDOT2 -> DAXPY (higher-f follow-up steals bandwidth: "
    "DESYNC, positive skew)")
