"""Declarative scenarios: state *what* runs where, not *how* to solve it.

A :class:`Scenario` is a frozen, composable description of one
contention experiment::

    Scenario.on("CLX").run("DCOPY", 12).run("DDOT2", 8)

Every builder method returns a new frozen scenario, so partial scenarios
are safely shareable templates.  Two shapes exist:

* **group mode** (``.run`` / ``.placed``) — concurrent thread groups,
  the paper's Eqs. 4–5 setting.  ``api.predict`` solves it; with
  ``.using(topology)`` and per-run domains it becomes a multi-domain
  placement solve.
* **program mode** (``.ranks`` + ``.step`` / ``.barrier`` / ``.halo`` /
  ``.idle``) — every rank executes the step sequence; ``api.simulate``
  runs it through the desync event engine.  ``.with_noise`` prepends a
  per-rank exponential jitter (the paper's Fig. 1/3 perturbation) and
  can request a whole seed ensemble in one scenario.

:class:`ScenarioBatch` packs B scenarios into the rectangular ``(B, G)``
arrays the batched solvers consume — ragged group lists are padded with
the neutral ``n = 0`` entries — and the sweep constructors
(:meth:`ScenarioBatch.split_sweep`, :meth:`ScenarioBatch.symmetric_sweep`,
:meth:`ScenarioBatch.pairing_matrix`, :meth:`Scenario.batch`) build the
common paper sweeps in one line.

Kernel references are resolved **at build time** through
:mod:`repro.api.registry` (Table II name → calibrated mapping →
``(f, bs)`` pair → explicit spec), so typos fail immediately with a
suggestion, and every group carries its spec provenance into the result.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

from ..core.sharing import Group
from ..core.table2 import KernelSpec
from ..core.topology import Placed, Topology
from ..core.topology import preset as topology_preset
from .registry import ResolvedSpec, resolve

#: Default per-run transfer volume for ``simulate`` on group-mode
#: scenarios (the HPCG study's SymGS scale: enough work that sharing
#: dynamics, not startup transients, dominate).
DEFAULT_WORK_BYTES = 32e6


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One concurrent thread group of a group-mode scenario."""

    resolved: ResolvedSpec
    n: int
    domain: str | None
    bytes: float
    tag: str

    @property
    def spec(self) -> KernelSpec:
        return self.resolved.spec

    def group(self, arch: str) -> Group:
        return Group.of(self.spec, arch, self.n)


@dataclasses.dataclass(frozen=True)
class StepSpec:
    """One program item executed (in order) by every rank."""

    kind: str                         # "work" | "barrier" | "halo" | "idle"
    tag: str
    resolved: ResolvedSpec | None = None
    bytes: tuple[float, ...] | float | None = None  # scalar or per-rank
    cost_s: float = 0.0

    def bytes_for(self, rank: int) -> float:
        if isinstance(self.bytes, tuple):
            return self.bytes[rank]
        return float(self.bytes)


@dataclasses.dataclass(frozen=True)
class Noise:
    """Per-rank exponential start jitter, optionally as a seed ensemble."""

    exp_mean_s: float
    seed: int = 0
    ensemble: int = 1
    tag: str = "noise"


def _resolve_ref(kernel, arch: str, name: str | None) -> ResolvedSpec:
    if isinstance(kernel, ResolvedSpec):
        if arch not in kernel.spec.f:
            from .registry import known_archs, unknown_key_error
            raise unknown_key_error("architecture", arch,
                                    known_archs(kernel.spec))
        return kernel
    return resolve(kernel, arch=arch, name=name)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A frozen, declarative contention scenario.  See module doc."""

    arch: str
    runs: tuple[RunSpec, ...] = ()
    steps: tuple[StepSpec, ...] = ()
    n_ranks: int | None = None
    topo: Topology | None = None
    rank_domains: tuple[str, ...] | None = None
    noise: Noise | None = None
    # Solver options, forwarded verbatim to the engines.
    utilization: str | float = "recursion"
    p0_factor: float = 0.5
    saturated: bool | None = None
    backend: str = "auto"
    t_max: float = 10.0
    strict: bool = True   # topology solves: reject overcommitted domains
    # Dispatch knobs, resolved by the backend substrate
    # (repro.core.backend): None defers to REPRO_JAX_CUTOFF /
    # REPRO_CHUNK_B or the process defaults.
    jax_cutoff: int | None = None
    chunk: int | None = None

    # -- constructors -------------------------------------------------------

    @classmethod
    def on(cls, arch: str, **options) -> "Scenario":
        """Start a scenario on architecture ``arch`` (a Table II column
        like ``"CLX"``, or any arch your specs carry, e.g. ``"TPU"``)."""
        return cls(arch=arch, **options)

    # -- group mode ---------------------------------------------------------

    def run(self, kernel, n: int, *, domain: str | None = None,
            bytes: float = DEFAULT_WORK_BYTES, tag: str | None = None,
            name: str | None = None) -> "Scenario":
        """Add a group of ``n`` threads all executing ``kernel``.

        ``kernel`` is anything :func:`repro.api.registry.resolve`
        accepts: a Table II name, a :class:`KernelSpec`, an ``(f, bs)``
        pair, a calibration mapping, or a pre-labelled
        :class:`ResolvedSpec`.  ``domain`` pins the group to a
        contention domain of the scenario's topology (see
        :meth:`using`); ``bytes`` only matters when the scenario is
        *simulated* rather than predicted.
        """
        if self.steps:
            raise ValueError(
                "cannot mix .run() groups with .step() programs in one "
                "scenario; use a second scenario")
        if not isinstance(n, (int, np.integer)) or n < 0:
            raise ValueError(f"thread count must be a non-negative int, "
                             f"got {n!r}")
        res = _resolve_ref(kernel, self.arch, name)
        run = RunSpec(resolved=res, n=int(n), domain=domain,
                      bytes=float(bytes), tag=tag or res.name)
        return dataclasses.replace(self, runs=self.runs + (run,))

    def placed(self, kernel, n: int, domain: str, **kwargs) -> "Scenario":
        """:meth:`run` with a required contention-domain placement."""
        return self.run(kernel, n, domain=domain, **kwargs)

    def using(self, topology: "Topology | str") -> "Scenario":
        """Attach a machine topology (a :class:`Topology` or a preset
        name like ``"CLX-2S"``) for ``.placed`` groups / rank domains."""
        if isinstance(topology, str):
            topology = topology_preset(topology)
        return dataclasses.replace(self, topo=topology)

    # -- program mode -------------------------------------------------------

    def ranks(self, n_ranks: int) -> "Scenario":
        """Switch to program mode: ``n_ranks`` ranks each execute the
        subsequent :meth:`step`/:meth:`barrier`/... sequence."""
        if self.runs:
            raise ValueError(
                "cannot mix .ranks() programs with .run() groups in one "
                "scenario; use a second scenario")
        if n_ranks < 1:
            raise ValueError(f"n_ranks must be >= 1, got {n_ranks}")
        return dataclasses.replace(self, n_ranks=int(n_ranks))

    def _need_ranks(self) -> int:
        if self.n_ranks is None:
            raise ValueError(
                "call .ranks(R) before adding program steps")
        return self.n_ranks

    def step(self, kernel, bytes, *, tag: str | None = None,
             name: str | None = None) -> "Scenario":
        """Every rank executes ``kernel`` over ``bytes`` (a scalar, or a
        per-rank sequence for imbalanced work)."""
        R = self._need_ranks()
        res = _resolve_ref(kernel, self.arch, name)
        if isinstance(bytes, (Sequence, np.ndarray)):
            per_rank = tuple(float(b) for b in bytes)
            if len(per_rank) != R:
                raise ValueError(
                    f"step gives {len(per_rank)} byte counts for {R} "
                    f"ranks")
            bspec: tuple[float, ...] | float = per_rank
        else:
            bspec = float(bytes)
        s = StepSpec(kind="work", tag=tag or res.name, resolved=res,
                     bytes=bspec)
        return dataclasses.replace(self, steps=self.steps + (s,))

    def barrier(self, cost_s: float = 5e-6,
                tag: str = "allreduce") -> "Scenario":
        """A global collective: blocks until every rank reaches it."""
        self._need_ranks()
        s = StepSpec(kind="barrier", tag=tag, cost_s=float(cost_s))
        return dataclasses.replace(self, steps=self.steps + (s,))

    def halo(self, cost_s: float = 2e-6, tag: str = "p2p") -> "Scenario":
        """A neighbor wait (halo exchange) between adjacent ranks."""
        self._need_ranks()
        s = StepSpec(kind="halo", tag=tag, cost_s=float(cost_s))
        return dataclasses.replace(self, steps=self.steps + (s,))

    def idle(self, duration_s: float, tag: str = "idle") -> "Scenario":
        """A fixed-duration delay on every rank."""
        self._need_ranks()
        s = StepSpec(kind="idle", tag=tag, cost_s=float(duration_s))
        return dataclasses.replace(self, steps=self.steps + (s,))

    def on_domains(self, placement: Sequence[str]) -> "Scenario":
        """Pin rank r to contention domain ``placement[r]`` of the
        scenario's topology (program mode)."""
        R = self._need_ranks()
        placement = tuple(placement)
        if len(placement) != R:
            raise ValueError(
                f"placement names {len(placement)} domains for {R} ranks")
        return dataclasses.replace(self, rank_domains=placement)

    def with_noise(self, exp_mean_s: float = 5e-5, *, seed: int = 0,
                   ensemble: int = 1, tag: str = "noise") -> "Scenario":
        """Prepend per-rank exponential start jitter; ``ensemble > 1``
        simulates that many independent seeds in one batched run."""
        if ensemble < 1:
            raise ValueError(f"ensemble must be >= 1, got {ensemble}")
        return dataclasses.replace(
            self, noise=Noise(exp_mean_s=float(exp_mean_s), seed=int(seed),
                              ensemble=int(ensemble), tag=tag))

    # -- options ------------------------------------------------------------

    def options(self, **kwargs) -> "Scenario":
        """Override solver options: ``utilization``, ``p0_factor``,
        ``saturated``, ``backend``, ``t_max``, ``strict``, plus the
        dispatch knobs ``jax_cutoff`` (the ``backend="auto"`` jax
        threshold for this scenario; default ``REPRO_JAX_CUTOFF`` / 64)
        and ``chunk`` (stream batched solves in slabs of this many
        scenarios; default ``REPRO_CHUNK_B`` / off)."""
        allowed = {"utilization", "p0_factor", "saturated", "backend",
                   "t_max", "strict", "jax_cutoff", "chunk"}
        bad = set(kwargs) - allowed
        if bad:
            raise TypeError(
                f"unknown scenario options {sorted(bad)}; allowed: "
                f"{sorted(allowed)}")
        return dataclasses.replace(self, **kwargs)

    # -- derived views ------------------------------------------------------

    @property
    def groups(self) -> tuple[Group, ...]:
        """The scenario's thread groups as the scalar solver sees them."""
        return tuple(r.group(self.arch) for r in self.runs)

    @property
    def provenance(self) -> tuple[str, ...]:
        return tuple(r.resolved.provenance for r in self.runs)

    @property
    def is_placed(self) -> bool:
        return any(r.domain is not None for r in self.runs)

    @property
    def total_threads(self) -> int:
        return sum(r.n for r in self.runs)

    def solver_options(self) -> dict:
        return dict(utilization=self.utilization,
                    p0_factor=self.p0_factor, saturated=self.saturated)

    def dispatch_options(self) -> dict:
        """The substrate-facing knobs (uniform across a batch)."""
        return dict(backend=self.backend, jax_cutoff=self.jax_cutoff,
                    chunk=self.chunk)

    # -- batching -----------------------------------------------------------

    def batch(self, counts) -> "ScenarioBatch":
        """Sweep this scenario's thread counts: ``counts`` is ``(B, G)``
        (one column per ``.run`` group); each row becomes one scenario."""
        counts = np.asarray(counts)
        if counts.ndim != 2 or counts.shape[1] != len(self.runs):
            raise ValueError(
                f"counts must be (B, {len(self.runs)}) for this "
                f"scenario's {len(self.runs)} groups, got "
                f"{counts.shape}")
        scens = []
        for row in counts:
            runs = tuple(dataclasses.replace(r, n=int(c))
                         for r, c in zip(self.runs, row))
            scens.append(dataclasses.replace(self, runs=runs))
        return ScenarioBatch.of(scens)


# ---------------------------------------------------------------------------
# Batches and sweeps
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScenarioBatch:
    """B scenarios solved (or simulated) together.

    For ``predict``, scenarios are group-mode: unplaced batches pack
    into rectangular ``(B, G)`` arrays (ragged lists padded with
    neutral ``n = 0`` groups); batches placed on **one shared
    topology** pack into a ``(B, D, K)`` occupancy-masked grid and
    solve as one flattened call (mixing placed and unplaced scenarios
    is rejected).  For ``simulate``, scenarios must share the rank
    count, topology, and placement (the batched desync engine's
    contract); programs may differ freely, and each scenario's
    ``with_noise(ensemble=E)`` members fuse into the same batched run.
    """

    scenarios: tuple[Scenario, ...]

    @classmethod
    def of(cls, scenarios: Sequence[Scenario]) -> "ScenarioBatch":
        scenarios = tuple(scenarios)
        if not scenarios:
            raise ValueError("a ScenarioBatch needs at least one scenario")
        first = scenarios[0]
        for i, sc in enumerate(scenarios):
            if sc.solver_options() != first.solver_options() or \
                    sc.dispatch_options() != first.dispatch_options():
                raise ValueError(
                    f"scenario {i} has different solver options than "
                    f"scenario 0; a batch is solved with one option set")
        return cls(scenarios=scenarios)

    def __len__(self) -> int:
        return len(self.scenarios)

    def __getitem__(self, i: int) -> Scenario:
        return self.scenarios[i]

    @functools.cached_property
    def archs(self) -> tuple[str, ...]:
        return tuple(sc.arch for sc in self.scenarios)

    @functools.cached_property
    def arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                              tuple[tuple[str, ...], ...]]:
        """Padded ``(n, f, bs, names)`` arrays of shape ``(B, G)``."""
        scens = self.scenarios
        g_max = max((len(sc.runs) for sc in scens), default=0)
        shape = (len(scens), max(g_max, 1))
        n = np.zeros(shape)
        f = np.zeros(shape)
        bs = np.zeros(shape)
        names = [[""] * shape[1] for _ in scens]
        for i, sc in enumerate(scens):
            for j, r in enumerate(sc.runs):
                spec = r.spec
                n[i, j] = r.n
                f[i, j] = spec.f[sc.arch]
                bs[i, j] = spec.bs[sc.arch]
                names[i][j] = r.tag
        return n, f, bs, tuple(tuple(row) for row in names)

    @functools.cached_property
    def is_placed(self) -> bool:
        """True when the batch is a topology-placed solve: every scenario
        placed on **one shared topology**.  Raises on incoherent mixes —
        placed next to unplaced scenarios, differing topologies, or a
        topology with unplaced groups — because those have no meaningful
        common grid."""
        flags = [sc.is_placed or sc.topo is not None
                 for sc in self.scenarios]
        if not any(flags):
            return False
        first = self.scenarios[0]
        for i, (sc, flag) in enumerate(zip(self.scenarios, flags)):
            if not flag:
                raise ValueError(
                    f"scenario {i} is unplaced but the batch has placed "
                    f"scenarios; a batch is either all placed on one "
                    f"topology or all single-domain")
            if sc.topo is None:
                raise ValueError(
                    f"scenario {i} has .placed groups but no topology; "
                    f"add .using(<topology or preset name>)")
            if sc.topo != first.topo:
                raise ValueError(
                    f"scenario {i} uses a different topology than "
                    f"scenario 0; a placed batch shares one topology")
            missing = [r.tag for r in sc.runs if r.domain is None]
            if missing:
                raise ValueError(
                    f"scenario {i}: groups {missing} have no domain but "
                    f"the scenario has a topology; place every group "
                    f"with .placed(kernel, n, domain)")
        return True

    @functools.cached_property
    def placements(self) -> "tuple[tuple[Placed, ...], ...]":
        """Per-scenario placement lists of a placed batch (input order)."""
        if not self.is_placed:
            raise ValueError("batch has no placed scenarios")
        return tuple(
            tuple(Placed(r.group(sc.arch), r.domain) for r in sc.runs)
            for sc in self.scenarios)

    @functools.cached_property
    def predictable(self) -> bool:
        """Validate the batch for ``predict`` (cached, so repeated
        predicts on one batch pay the O(B) scan once)."""
        for i, sc in enumerate(self.scenarios):
            if sc.steps:
                raise ValueError(
                    f"scenario {i} describes rank programs; use "
                    f"simulate(batch)")
        self.is_placed  # coherence: all placed on one topology, or none
        return True

    @functools.cached_property
    def provenance(self) -> tuple[tuple[str, ...], ...]:
        """(B, G) provenance labels ("" for padding groups)."""
        _, _, _, names = self.arrays
        out = []
        for sc, row in zip(self.scenarios, names):
            prov = list(sc.provenance)
            prov += [""] * (len(row) - len(prov))
            out.append(tuple(prov))
        return tuple(out)

    # -- sweep constructors -------------------------------------------------

    @classmethod
    def split_sweep(cls, arch: str, kernel_a, kernel_b, n_total: int,
                    **options) -> "ScenarioBatch":
        """All ``(i, n_total - i)`` splits of a fully populated domain
        between two kernels (the paper's Fig. 6 sweep), one batch."""
        base = (Scenario.on(arch, **options)
                .run(kernel_a, 1).run(kernel_b, 1))
        na = np.arange(1, n_total)
        return base.batch(np.stack([na, n_total - na], axis=-1))

    @classmethod
    def symmetric_sweep(cls, arch: str, kernel_a, kernel_b, n_max: int,
                        **options) -> "ScenarioBatch":
        """Symmetric thread scaling ``n = 1 .. n_max`` per kernel (the
        paper's Fig. 7 curves), one batch."""
        base = (Scenario.on(arch, **options)
                .run(kernel_a, 1).run(kernel_b, 1))
        ns = np.arange(1, n_max + 1)
        return base.batch(np.stack([ns, ns], axis=-1))

    @classmethod
    def pairing_matrix(cls, arch: str, kernels: Sequence, n_each: int,
                       **options) -> "ScenarioBatch":
        """The Fig. 9 layout: rows ``0 .. K²-1`` are all mixed pairs
        (A with B, each on ``n_each`` threads), rows ``K² .. K²+K-1``
        the self-pairings (A with A) the gains are normalized by."""
        ks = list(kernels)
        scens = []
        for ka in ks:
            for kb in ks:
                scens.append(Scenario.on(arch, **options)
                             .run(ka, n_each).run(kb, n_each))
        for ka in ks:
            scens.append(Scenario.on(arch, **options)
                         .run(ka, n_each).run(ka, n_each))
        return cls.of(scens)
