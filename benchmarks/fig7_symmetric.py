"""Paper Fig. 7: per-kernel bandwidth under symmetric thread scaling
(n threads per kernel, n = 1 .. domain/2) — model vs. queue simulator.

Also reports the paper's qualitative scaling observations: CLX scales well
from 2 to 4 threads; Rome nearly saturates with one thread per kernel.
"""

from __future__ import annotations

import time

from repro import api
from repro.core import memsim, sharing, table2

PAIRINGS = [("DCOPY", "DDOT2"), ("JacobiL3-v1", "DDOT1"),
            ("STREAM", "JacobiL2-v1")]
DOMAIN = {"BDW-1": 10, "BDW-2": 18, "CLX": 20, "ROME": 8}


def curve(arch, ka, kb):
    """Returns (points, model_us): per-point model solve time excludes the
    queue-simulator validation runs (same convention as fig6)."""
    a, b = table2.kernel(ka), table2.kernel(kb)
    n_half = DOMAIN[arch] // 2
    # Model: the whole thread-scaling curve is one facade batch.
    scenarios = api.ScenarioBatch.symmetric_sweep(arch, ka, kb, n_half,
                                                  utilization="queue")
    t0 = time.perf_counter()
    batch = api.predict(scenarios)
    model_us = (time.perf_counter() - t0) * 1e6 / n_half
    pts = []
    for row, nt in enumerate(range(1, n_half + 1)):
        sim = memsim.simulate([sharing.Group.of(a, arch, int(nt)),
                               sharing.Group.of(b, arch, int(nt))],
                              n_events=20_000)
        pts.append((int(nt), tuple(batch.bw_per_core[row]),
                    (sim[0] / nt, sim[1] / nt)))
    return pts, model_us


def rows():
    out = []
    for arch in DOMAIN:
        for ka, kb in PAIRINGS:
            pts, us = curve(arch, ka, kb)
            series = "|".join(
                f"n={n}:model=({m[0]:.1f},{m[1]:.1f})"
                f":sim=({s[0]:.1f},{s[1]:.1f})" for n, m, s in pts)
            out.append((f"fig7/{arch}/{ka}+{kb}", us, series))
    # Qualitative checks from the paper text.
    rome, _ = curve("ROME", "DCOPY", "DDOT2")
    one_thread_total = sum(rome[0][1]) * 1
    sat = table2.kernel("DCOPY").bs["ROME"]
    out.append(("fig7/check/rome_one_thread_near_saturation", 0.0,
                f"total@n=1={one_thread_total:.1f};bs={sat:.1f};"
                f"ratio={one_thread_total/sat:.2f}"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
