"""Beyond-paper benchmark: the sharing model applied to TPU step planning.

Reads the dry-run roofline records (results/dryrun*.jsonl, if present) and
for each train cell reports the overlap plan: serial vs. planned vs. naive
("perfect overlap") step time and the chosen gradient-bucket count.  The
delta between planned and naive is exactly the HBM-contention effect the
paper's Eqs. 4-5 quantify — the naive roofline over-promises.

Falls back to three analytic example workloads when no dry-run results
exist (so `python -m benchmarks.run` is self-contained).
"""

from __future__ import annotations

import glob
import json
import time

from repro.core.hlo import CollectiveStats, RooflineTerms
from repro.core.overlap import Phase, overlap_pair
from repro.runtime.overlap_schedule import plan_gradient_overlap

FALLBACK = [
    # name, flops/chip, hbm bytes/chip, wire bytes/chip
    ("example/compute_bound_train", 5.0e12, 1.0e10, 4.0e9),
    ("example/memory_bound_train", 2.0e11, 2.0e11, 1.0e9),
    ("example/collective_bound_train", 1.0e12, 2.0e10, 6.0e10),
]


def _records():
    recs = []
    for path in sorted(glob.glob("results/dryrun*.jsonl")):
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                if r.get("status") == "ok" and r.get("shape") == "train_4k":
                    recs.append((f"{r['arch']}/{r['mesh']}",
                                 r["flops_per_chip"],
                                 r["hbm_bytes_per_chip"],
                                 r["wire_bytes_per_chip"]))
    return recs or FALLBACK


def rows():
    out = []
    for name, flops, hbm, wire in _records():
        t0 = time.perf_counter()
        terms = RooflineTerms(name=name, t_compute=0, t_memory=0,
                              t_collective=0, flops=flops, hbm_bytes=hbm,
                              wire_bytes=wire)
        plan = plan_gradient_overlap(terms)
        us = (time.perf_counter() - t0) * 1e6
        out.append((f"tpu_overlap/{name}", us,
                    f"overlap={plan.overlap};buckets={plan.n_buckets};"
                    f"t_serial={plan.t_serial*1e3:.2f}ms;"
                    f"t_planned={plan.t_planned*1e3:.2f}ms;"
                    f"t_naive={plan.t_naive_roofline*1e3:.2f}ms;"
                    f"gain={plan.predicted_gain:.3f}"))
    # The two-memory-bound-streams sanity case from the paper's insight.
    a = Phase("grad_io", hbm_bytes=5e9)
    b = Phase("weight_prefetch", hbm_bytes=5e9)
    pred = overlap_pair(a, b)
    out.append(("tpu_overlap/two_hbm_streams", 0.0,
                f"serial={pred.t_serial*1e3:.2f}ms;"
                f"shared={pred.t_overlap*1e3:.2f}ms;"
                f"naive={pred.t_naive*1e3:.2f}ms;"
                "naive_underestimates_by="
                f"{pred.t_overlap/pred.t_naive:.2f}x"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
