"""Rank-level discrete-event simulator of barrier-free bulk-synchronous
programs on a shared contention domain — the "new kind of MPI simulation
technique that can take node-level bottlenecks into account" the paper's
outlook calls for, and the engine behind the HPCG desynchronization study
(paper Figs. 1 and 3).

Each rank executes a program: a sequence of memory-bound kernel work items,
collectives, neighbor waits, and idle gaps.  At every instant, the set of
in-flight kernels across ranks forms groups; the sharing model (Eqs. 4–5)
dictates each rank's bandwidth and hence its progress rate.  Desync or resync
emerges from the dynamics — nothing about skew is put in by hand.

Ranks may be pinned to different contention domains of a
:class:`repro.core.topology.Topology` (dual-socket nodes, NPS4 Rome, TPU
pods): kernels only contend with kernels on the *same* domain, and all
populated domains are solved in one batched call per event step.

The same engine doubles as the TPU straggler model: ranks = data-parallel
workers, kernels = step phases, allreduce = the gradient reduction.
"""

from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Sequence

from .sharing import Group, predict_batch
from .table2 import TABLE2, KernelSpec
from .topology import Topology

EPS = 1e-15


# --------------------------------------------------------------------------
# Program description
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Work:
    """Memory-bound loop kernel moving ``bytes`` over the interface."""
    kernel: str           # key into core.table2.TABLE2 (or custom specs)
    bytes: float
    tag: str = ""         # label for reporting (e.g. "DDOT2")


@dataclasses.dataclass(frozen=True)
class Allreduce:
    """Global collective: blocks until every rank reaches it."""
    cost_s: float = 5e-6
    tag: str = "allreduce"


@dataclasses.dataclass(frozen=True)
class WaitNeighbors:
    """Point-to-point dependency: blocks until both neighbor ranks have
    retired at least as many program items as this rank (halo exchange)."""
    cost_s: float = 2e-6
    tag: str = "p2p"


@dataclasses.dataclass(frozen=True)
class Idle:
    """Fixed-duration delay (noise / injected perturbation)."""
    duration_s: float
    tag: str = "idle"


Item = Work | Allreduce | WaitNeighbors | Idle


@dataclasses.dataclass
class Record:
    rank: int
    index: int
    tag: str
    start: float
    end: float

    @property
    def duration(self) -> float:
        return self.end - self.start


# --------------------------------------------------------------------------
# Simulator
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _RankState:
    program: Sequence[Item]
    pc: int = 0
    remaining_bytes: float = 0.0
    ready_at: float = 0.0       # for Idle / collective cost
    blocked: bool = False       # waiting on allreduce / neighbors
    releasing: bool = False     # neighbor wait satisfied, draining its cost
    started_current: float = 0.0

    @property
    def done(self) -> bool:
        return self.pc >= len(self.program)

    def current(self) -> Item | None:
        return None if self.done else self.program[self.pc]


class DesyncSimulator:
    """Event-driven co-execution of per-rank programs on one or more
    contention domains.

    ``topology``/``placement`` pin each rank to a domain (``placement[r]``
    is a domain name of ``topology``); the default is the paper's setting —
    every rank on a single shared domain.
    """

    def __init__(self, programs: Sequence[Sequence[Item]], arch: str,
                 specs: dict[str, KernelSpec] | None = None, *,
                 topology: Topology | None = None,
                 placement: Sequence[str] | None = None):
        self.programs = programs
        self.arch = arch
        self.specs = dict(TABLE2 if specs is None else specs)
        self.records: list[Record] = []
        if (topology is None) != (placement is None):
            raise ValueError("topology and placement must be given together")
        if topology is not None:
            if len(placement) != len(programs):
                raise ValueError(
                    f"placement names {len(placement)} domains for "
                    f"{len(programs)} ranks")
            for dom in placement:
                topology.domain(dom)  # raises KeyError on unknown names
        self.topology = topology
        self.placement = (tuple(placement) if placement is not None
                          else ("domain0",) * len(programs))

    def _group_of(self, kernel: str, n: int) -> Group:
        spec = self.specs[kernel]
        return Group.of(spec, self.arch, n)

    @classmethod
    def run_batch(cls, programs_batch, arch: str,
                  specs: dict[str, KernelSpec] | None = None, *,
                  topology: Topology | None = None,
                  placement: Sequence[str] | None = None,
                  t_max: float = 10.0, backend: str = "numpy",
                  on_deadlock: str = "mask"):
        """Run B independent scenarios in one batched simulation.

        ``programs_batch`` is a B-long sequence of scenarios, each an R-long
        sequence of per-rank programs (same R across scenarios; topology and
        placement are shared).  Returns a
        :class:`repro.core.desync_batch.BatchRunResult`; with B = 1 the
        records reproduce :meth:`run` exactly.  A deadlocked scenario is
        masked in :attr:`BatchRunResult.failed` by default
        (``on_deadlock="raise"`` aborts instead, like :meth:`run`).  See
        :mod:`repro.core.desync_batch` for the engine.
        """
        from .desync_batch import run_batch as _run_batch
        return _run_batch(programs_batch, arch, specs,
                          topology=topology, placement=placement,
                          t_max=t_max, backend=backend,
                          on_deadlock=on_deadlock)

    def run(self, *, t_max: float = 10.0) -> list[Record]:
        ranks = [_RankState(program=p) for p in self.programs]
        n = len(ranks)
        t = 0.0
        self.records = []

        def begin_item(r: int, now: float) -> None:
            st = ranks[r]
            st.started_current = now
            item = st.current()
            if isinstance(item, Work):
                st.remaining_bytes = item.bytes
            elif isinstance(item, Idle):
                st.ready_at = now + item.duration_s
            elif isinstance(item, (Allreduce, WaitNeighbors)):
                st.blocked = True

        def finish_item(r: int, now: float) -> None:
            st = ranks[r]
            item = st.current()
            tag = item.tag or getattr(item, "kernel", type(item).__name__)
            self.records.append(
                Record(rank=r, index=st.pc, tag=tag,
                       start=st.started_current, end=now))
            st.pc += 1
            st.blocked = False
            st.releasing = False
            if not st.done:
                begin_item(r, now)

        for r in range(n):
            if ranks[r].program:
                begin_item(r, 0.0)

        while t < t_max and not all(st.done for st in ranks):
            # -- resolve collectives: when every rank is blocked at an
            # Allreduce, the collective runs for its cost and the *global*
            # clock advances with it — no record may start before time has
            # actually progressed.
            t_after = self._resolve_allreduce(ranks, t, finish_item)
            if t_after is not None:
                t = t_after
                continue  # re-evaluate doneness/groups after retirements
            # -- neighbor waits whose dependency is now satisfied start
            # draining their p2p cost: they retire ``cost_s`` later, through
            # the normal event loop (so the cost occupies real clock time).
            self._release_neighbors(ranks, t)

            # -- group working ranks by (domain, kernel)
            working: dict[tuple[str, str], list[int]] = defaultdict(list)
            for r, st in enumerate(ranks):
                it = st.current()
                if isinstance(it, Work) and not st.blocked:
                    working[(self.placement[r], it.kernel)].append(r)

            # -- progress rates: every populated domain is an independent
            # Eq. 4–5 instance; solve them all in one batched call.
            rates: dict[int, float] = {}
            if working:
                domains = sorted({dom for dom, _ in working})
                per_dom = [sorted(k for d, k in working if d == dom)
                           for dom in domains]
                scenarios = [
                    [self._group_of(k, len(working[(dom, k)]))
                     for k in kernels]
                    for dom, kernels in zip(domains, per_dom)]
                # numpy backend: the per-event batches are tiny, so jit
                # dispatch overhead would dominate any vmap win here.
                batch = predict_batch(scenarios, backend="numpy")
                per_core = batch.bw_per_core
                for row, (dom, kernels) in enumerate(zip(domains, per_dom)):
                    for j, k in enumerate(kernels):
                        for r in working[(dom, k)]:
                            rates[r] = per_core[row, j] * 1e9  # bytes/s

            # -- find the next event time
            dt = math.inf
            for r, st in enumerate(ranks):
                it = st.current()
                if it is None:
                    continue
                if isinstance(it, Work) and r in rates and rates[r] > 0:
                    dt = min(dt, st.remaining_bytes / rates[r])
                elif isinstance(it, Idle) or st.releasing:
                    dt = min(dt, max(st.ready_at - t, 0.0))
            if not math.isfinite(dt):
                # Only blocked ranks remain but no collective resolved — a
                # genuine deadlock in the program description.
                raise RuntimeError(
                    f"desync simulator deadlock at t={t:.6f}s: "
                    f"pcs={[st.pc for st in ranks]}")
            dt = max(dt, EPS)
            t += dt

            # -- advance work and retire finished items
            for r, st in enumerate(ranks):
                it = st.current()
                if isinstance(it, Work) and r in rates:
                    st.remaining_bytes -= rates[r] * dt
                    if st.remaining_bytes <= EPS * max(1.0, it.bytes):
                        finish_item(r, t)
                elif (isinstance(it, Idle) or st.releasing) and \
                        t >= st.ready_at - EPS:
                    finish_item(r, t)

        return self.records

    # -- collective resolution ------------------------------------------------

    def _resolve_allreduce(self, ranks, t, finish_item) -> float | None:
        """Release a fully-assembled allreduce; returns the advanced global
        clock (``t + cost``), or ``None`` if the collective is not ready.

        The clock *must* advance with the collective's cost: finishing items
        at ``t + cost`` while the loop keeps integrating from ``t`` would
        let subsequent work accrue bandwidth during the collective — i.e.
        collectives would be free, and records could start before global
        time reached their start.
        """
        blocked = [(r, st) for r, st in enumerate(ranks)
                   if isinstance(st.current(), Allreduce) and st.blocked]
        if not blocked:
            return None
        # MPI semantics: the collective is over the full communicator — a
        # rank that already exited can never participate again.
        if len(blocked) == len(ranks):
            cost = max(st.current().cost_s for _, st in blocked)
            t_after = t + cost
            for r, _ in blocked:
                finish_item(r, t_after)
            return t_after
        return None

    def _release_neighbors(self, ranks, t) -> None:
        """Mark satisfied neighbor waits as draining: the rank retires the
        item ``cost_s`` of *global* time later (via ``ready_at``), not at a
        fabricated future timestamp while the clock stands still.  Because
        the waiter's ``pc`` only advances at retirement, dependency chains
        propagate with the p2p latency instead of collapsing instantly."""
        n = len(ranks)
        for r, st in enumerate(ranks):
            it = st.current()
            if not (isinstance(it, WaitNeighbors) and st.blocked):
                continue
            nbrs = [x for x in (r - 1, r + 1) if 0 <= x < n]
            if all(ranks[x].pc >= st.pc or ranks[x].done for x in nbrs):
                st.blocked = False
                st.releasing = True
                st.ready_at = t + it.cost_s


# --------------------------------------------------------------------------
# Analysis helpers
# --------------------------------------------------------------------------


def durations_by_tag(records: Sequence[Record], tag: str, *,
                     n_ranks: int | None = None,
                     missing: float = 0.0) -> list[float]:
    """Accumulated time per rank spent in items with ``tag``.

    Returns one entry per rank ``0 .. n_ranks-1``.  ``n_ranks`` defaults to
    the highest rank appearing in *any* record plus one, so a straggler that
    never retired a ``tag`` item within ``t_max`` still shows up — as
    ``missing`` (default 0.0; pass ``float('nan')`` to make truncation
    explicit) — instead of being silently dropped from the sample that
    :func:`skewness` is computed over.
    """
    if n_ranks is None:
        n_ranks = max((rec.rank for rec in records), default=-1) + 1
    acc: dict[int, float] = defaultdict(float)
    tagged: set[int] = set()
    for rec in records:
        if rec.tag == tag:
            acc[rec.rank] += rec.duration
            tagged.add(rec.rank)
    return [acc[r] if r in tagged else missing for r in range(n_ranks)]


def skewness(xs: Sequence[float]) -> float:
    """Fisher skewness of a sample; the paper's desync/resync indicator
    (positive → desynchronization amplified; negative → resynchronization)."""
    n = len(xs)
    if n < 3:
        return 0.0
    mean = sum(xs) / n
    m2 = sum((x - mean) ** 2 for x in xs) / n
    m3 = sum((x - mean) ** 3 for x in xs) / n
    if m2 <= 0:
        return 0.0
    return m3 / m2 ** 1.5


def start_spread(records: Sequence[Record], tag: str) -> float:
    starts = [r.start for r in records if r.tag == tag]
    return max(starts) - min(starts) if starts else 0.0


def end_spread(records: Sequence[Record], tag: str) -> float:
    ends = [r.end for r in records if r.tag == tag]
    return max(ends) - min(ends) if ends else 0.0
