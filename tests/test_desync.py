"""Desynchronization-dynamics tests: the simulator must reproduce the
paper's HPCG phenomenology (Figs. 1 and 3) from the sharing model alone."""

import random

import pytest

from repro.core.desync import (Allreduce, DesyncSimulator, Idle, WaitNeighbors,
                               Work, durations_by_tag, end_spread, skewness,
                               start_spread)

MB = 1e6
N_RANKS = 20


def _programs(followup, seed):
    rng = random.Random(seed)
    progs = []
    for _ in range(N_RANKS):
        progs.append([
            Idle(rng.expovariate(1 / 6e-5), tag="noise"),
            Work("Schoenauer", 40 * MB, tag="symgs"),
            Work("DDOT2", 8 * MB, tag="ddot2"),
            *followup,
        ])
    return progs


def _skews(followup, seeds=range(6)):
    out = []
    for s in seeds:
        sim = DesyncSimulator(_programs(followup, s), "CLX")
        recs = sim.run(t_max=60)
        out.append((skewness(durations_by_tag(recs, "ddot2")),
                    start_spread(recs, "ddot2"), end_spread(recs, "ddot2")))
    return out


def test_resynchronization_with_allreduce():
    """Fig. 1: late DDOT2 starters overlap with idleness in MPI_Allreduce,
    run faster, and the rank distribution resynchronizes: negative skew,
    end spread < start spread."""
    res = _skews([Allreduce(), Work("DAXPY", 30 * MB, tag="daxpy")])
    assert sum(sk < 0 for sk, _, _ in res) >= 4
    assert all(es < ss for _, ss, es in res)


def test_desynchronization_with_daxpy():
    """Fig. 3(b): follow-up DAXPY has higher f than DDOT2 — early finishers
    steal bandwidth from stragglers: positive skew, spread grows."""
    res = _skews([Work("DAXPY", 30 * MB, tag="daxpy")])
    assert all(sk > 0 for sk, _, _ in res)
    assert all(es > ss for _, ss, es in res)


def test_late_starters_run_faster():
    """Fig. 1(c): DDOT2 runtime decreases monotonically with start time."""
    sim = DesyncSimulator(_programs([Allreduce()], seed=3), "CLX")
    recs = sim.run(t_max=60)
    dd = sorted((r.start, r.duration) for r in recs if r.tag == "ddot2")
    starts = [s for s, _ in dd]
    durs = [d for _, d in dd]
    # Pearson-free check: first-third mean duration > last-third mean.
    k = len(durs) // 3
    assert sum(durs[:k]) / k > sum(durs[-k:]) / k
    assert starts == sorted(starts)


def test_homogeneous_lockstep_stays_synchronized():
    """No noise, same program: all ranks finish simultaneously."""
    progs = [[Work("STREAM", 10 * MB, tag="w")] for _ in range(8)]
    recs = DesyncSimulator(progs, "BDW-2").run()
    ends = [r.end for r in recs if r.tag == "w"]
    assert max(ends) - min(ends) < 1e-9


def test_bandwidth_conservation_during_overlap():
    """Two groups overlapping: total time consistent with shared bandwidth,
    longer than the isolated-run time."""
    progs = [[Work("DCOPY", 50 * MB, tag="a")] for _ in range(10)] + \
            [[Work("DDOT2", 50 * MB, tag="b")] for _ in range(10)]
    recs = DesyncSimulator(progs, "CLX").run()
    t_a = max(r.end for r in recs if r.tag == "a")
    solo = DesyncSimulator(
        [[Work("DCOPY", 50 * MB, tag="a")] for _ in range(10)], "CLX").run()
    t_solo = max(r.end for r in solo if r.tag == "a")
    assert t_a > t_solo  # contention must cost something


def test_allreduce_is_global_barrier():
    progs = [
        [Idle(1e-3, tag="late"), Allreduce(), Work("STREAM", MB, tag="w")],
        [Allreduce(), Work("STREAM", MB, tag="w")],
    ]
    recs = DesyncSimulator(progs, "CLX").run()
    w_starts = [r.start for r in recs if r.tag == "w"]
    assert max(w_starts) - min(w_starts) < 1e-9
    assert min(w_starts) >= 1e-3


def test_deadlock_detection():
    progs = [[Allreduce()], [Allreduce(), Allreduce()]]
    sim = DesyncSimulator(progs, "CLX")
    with pytest.raises(RuntimeError, match="deadlock"):
        sim.run(t_max=1.0)


def test_records_are_consistent():
    progs = _programs([Allreduce()], seed=0)
    recs = DesyncSimulator(progs, "CLX").run()
    by_rank = {}
    for r in recs:
        assert r.end >= r.start - 1e-12
        by_rank.setdefault(r.rank, []).append(r)
    for rank, rs in by_rank.items():
        rs.sort(key=lambda r: r.index)
        assert len(rs) == len(progs[rank])
        for a, b in zip(rs, rs[1:]):
            assert b.start >= a.end - 1e-9


def _assert_nonoverlapping_monotone(recs, progs):
    """Every program item retired exactly once, per-rank records are
    contiguous in program order, strictly non-overlapping, and never
    start before global time progressed there."""
    by_rank = {r: [] for r in range(len(progs))}
    for r in recs:
        by_rank[r.rank].append(r)
    for rank, rs in by_rank.items():
        rs.sort(key=lambda r: r.index)
        assert [r.index for r in rs] == list(range(len(progs[rank])))
        for a, b in zip(rs, rs[1:]):
            assert b.start == a.end  # back-to-back, no time travel
        for a in rs:
            assert a.end >= a.start


def test_collective_cost_advances_global_clock():
    """Regression (collective time travel): allreduce cost must occupy
    global time — finishing at t + cost while the loop keeps integrating
    from t made collectives free and let records start in the future."""
    cost = 5e-6
    progs = [[Allreduce(cost_s=cost), Work("STREAM", MB, tag="w")]
             for _ in range(4)]
    recs = DesyncSimulator(progs, "CLX").run()
    _assert_nonoverlapping_monotone(recs, progs)
    ar_recs = [r for r in recs if r.tag == "allreduce"]
    assert all(r.duration == pytest.approx(cost) for r in ar_recs)
    # Work starts exactly when the collective released, not at t=0.
    assert all(r.start == pytest.approx(cost)
               for r in recs if r.tag == "w")


def test_p2p_cost_advances_global_clock():
    """Regression: a satisfied neighbor wait drains its cost through the
    event loop, so the waiter's records stay monotone and the p2p record
    has positive duration."""
    progs = [[Work("STREAM", MB, tag="w"), WaitNeighbors(cost_s=2e-6),
              Work("STREAM", MB, tag="w2")] for _ in range(4)]
    recs = DesyncSimulator(progs, "CLX").run()
    _assert_nonoverlapping_monotone(recs, progs)
    p2p = [r for r in recs if r.tag == "p2p"]
    assert len(p2p) == 4
    assert all(r.duration >= 2e-6 - 1e-12 for r in p2p)


def test_hpcg_scenarios_have_no_time_travel():
    """The Fig. 1/3 scenarios produce per-rank non-overlapping, monotone
    records after the clock-advance fixes."""
    for tail in ([Allreduce(), Work("DAXPY", 30 * MB, tag="daxpy")],
                 [WaitNeighbors(), Work("DAXPY", 30 * MB, tag="daxpy")]):
        progs = _programs(tail, seed=1)
        recs = DesyncSimulator(progs, "CLX").run(t_max=60)
        _assert_nonoverlapping_monotone(recs, progs)


def test_durations_by_tag_keeps_silent_ranks():
    """Regression (silent rank drop): a rank that never retired a tagged
    item still appears in the per-rank sample instead of shrinking it."""
    progs = [[Work("STREAM", MB, tag="w")],
             [Idle(1e-3), Work("STREAM", MB, tag="w")],
             [Idle(50.0)]]  # never reaches any 'w' item
    recs = DesyncSimulator(progs, "CLX").run(t_max=1.0)
    durs = durations_by_tag(recs, "w")
    assert len(durs) == 3
    assert durs[0] > 0 and durs[1] > 0 and durs[2] == 0.0
    nan_durs = durations_by_tag(recs, "w", missing=float("nan"))
    assert nan_durs[2] != nan_durs[2]  # NaN marks the truncated rank
    assert durations_by_tag(recs, "w", n_ranks=5)[3:] == [0.0, 0.0]
