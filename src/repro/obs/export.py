"""Turn recorded events into ndjson and Chrome ``trace_event`` JSON.

Two output formats, one source of truth (the trace ring buffer plus the
metrics registry):

* **ndjson** — one json object per line via the same ``dump_dicts``
  idiom as :func:`repro.api.results.dump_ndjson`: machine-greppable,
  streamable, and the input format of ``python -m repro.obs.report``.
  Span rows carry ``kind/name/ts_us/dur_us/tid/depth/attrs``; the
  metrics snapshot is appended as ``kind: "metric"`` rows.
* **Chrome trace JSON** — the ``trace_event`` format's complete
  (``"ph": "X"``) events, loadable directly in ``chrome://tracing`` or
  https://ui.perfetto.dev: drag the file in and the span tree renders
  as a flame chart per thread.

When tracing was enabled via ``REPRO_TRACE=1``, an at-exit hook (see
:mod:`repro.obs.trace`) calls :func:`write_default_artifacts`, so any
benchmark or example emits ``<base>.ndjson`` + ``<base>.trace.json``
(base from ``REPRO_TRACE_OUT``, default ``repro-trace``) with no code
changes.
"""

from __future__ import annotations

import json
import os

from . import metrics, trace

__all__ = [
    "event_dicts", "metric_dicts", "write_ndjson", "chrome_trace",
    "write_chrome_trace", "write_default_artifacts", "DEFAULT_BASENAME",
]

DEFAULT_BASENAME = "repro-trace"


def event_dicts(events: list | None = None) -> list[dict]:
    """Event tuples -> ndjson-ready dicts (timestamps in microseconds,
    relative to the earliest event so files diff cleanly)."""
    evs = trace.events() if events is None else events
    if not evs:
        return []
    t0 = min(e[2] for e in evs)
    rows = []
    for kind, name, t_ns, dur_ns, tid, depth, attrs in evs:
        row = {"kind": kind, "name": name,
               "ts_us": (t_ns - t0) / 1000.0, "dur_us": dur_ns / 1000.0,
               "tid": tid, "depth": depth}
        if attrs:
            row["attrs"] = attrs
        rows.append(row)
    return rows


def metric_dicts() -> list[dict]:
    """Metrics snapshot as ``kind: "metric"`` ndjson rows."""
    return [{"kind": "metric", **row} for row in metrics.snapshot()]


def write_ndjson(fh_or_path, events: list | None = None, *,
                 include_metrics: bool = True) -> int:
    """Stream events (and the metrics snapshot) as ndjson; returns the
    row count.  Accepts an open file handle or a path."""
    from ..api.results import dump_dicts  # lazy: obs must import before api

    rows = event_dicts(events)
    if trace.dropped():
        rows.insert(0, {"kind": "meta", "name": "trace.dropped",
                        "ts_us": 0.0, "dur_us": 0.0, "tid": 0, "depth": 0,
                        "attrs": {"dropped": trace.dropped(),
                                  "capacity": trace.BUFFER.capacity}})
    if include_metrics:
        rows.extend(metric_dicts())
    if hasattr(fh_or_path, "write"):
        return dump_dicts(iter(rows), fh_or_path)
    with open(fh_or_path, "w") as fh:
        return dump_dicts(iter(rows), fh)


def chrome_trace(events: list | None = None, *,
                 process_name: str = "repro") -> dict:
    """Events -> a ``chrome://tracing`` / Perfetto-loadable document."""
    evs = trace.events() if events is None else events
    pid = os.getpid()
    out = [{"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
            "args": {"name": process_name}}]
    if not evs:
        return {"traceEvents": out, "displayTimeUnit": "ms"}
    t0 = min(e[2] for e in evs)
    tids = sorted({e[4] for e in evs})
    # Renumber thread ids densely so the timeline rows read 0, 1, 2...
    tid_map = {t: i for i, t in enumerate(tids)}
    for t, i in tid_map.items():
        out.append({"ph": "M", "pid": pid, "tid": i, "name": "thread_name",
                    "args": {"name": f"thread-{t}"}})
    for kind, name, t_ns, dur_ns, tid, depth, attrs in evs:
        ev = {"name": name, "cat": kind, "pid": pid, "tid": tid_map[tid],
              "ts": (t_ns - t0) / 1000.0}
        if kind == "span":
            ev["ph"] = "X"
            ev["dur"] = dur_ns / 1000.0
        else:
            ev["ph"] = "i"
            ev["s"] = "t"  # thread-scoped instant
        args = {"depth": depth}
        if attrs:
            args.update(attrs)
        ev["args"] = args
        out.append(ev)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path, events: list | None = None) -> int:
    """Write the Chrome trace document; returns the event count."""
    doc = chrome_trace(events)
    with open(path, "w") as fh:
        json.dump(doc, fh, sort_keys=True)
        fh.write("\n")
    return len(doc["traceEvents"])


def write_default_artifacts(basename: str | None = None) -> tuple[str, str]:
    """Write ``<base>.ndjson`` and ``<base>.trace.json`` (the pair the
    ``REPRO_TRACE=1`` at-exit hook emits); returns the two paths."""
    base = basename or os.environ.get("REPRO_TRACE_OUT", "").strip() \
        or DEFAULT_BASENAME
    nd, ch = f"{base}.ndjson", f"{base}.trace.json"
    write_ndjson(nd)
    write_chrome_trace(ch)
    return nd, ch
