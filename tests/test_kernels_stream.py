"""Pallas interpret-mode vs pure-jnp oracle: Table II streaming suite."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops

jax.config.update("jax_enable_x64", False)

MAP_CASES = {
    "dscal": 1, "daxpy": 2, "add": 2, "stream": 2, "waxpby": 2,
    "dcopy": 1, "schoenauer": 3,
}
REDUCE_CASES = {"vectorsum": 1, "ddot1": 1, "ddot2": 2, "ddot3": 3}


def _arrays(n_arrays, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal(n), dtype) for _ in range(n_arrays)]


def _scalar(name):
    if name == "waxpby":
        return jnp.asarray([1.7, -0.3], jnp.float32)
    return jnp.asarray(0.7, jnp.float32)


@pytest.mark.parametrize("name,n_in", sorted(MAP_CASES.items()))
@pytest.mark.parametrize("n", [128, 1024, 128 * 300])
def test_map_kernels_match_ref(name, n_in, n):
    arrays = _arrays(n_in, n, jnp.float32)
    s = _scalar(name)
    got = ops.stream_map(name, s, *arrays, impl="interpret")
    want = ops.stream_map(name, s, *arrays, impl="jnp")
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("name,n_in", sorted(REDUCE_CASES.items()))
@pytest.mark.parametrize("n", [128, 2048, 128 * 300])
def test_reduce_kernels_match_ref(name, n_in, n):
    arrays = _arrays(n_in, n, jnp.float32, seed=1)
    got = ops.stream_reduce(name, *arrays, impl="interpret")
    want = ops.stream_reduce(name, *arrays, impl="jnp")
    np.testing.assert_allclose(got, want, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_map_dtypes(dtype):
    arrays = _arrays(2, 512, dtype)
    got = ops.stream_map("stream", jnp.asarray(0.5, dtype), *arrays,
                         impl="interpret")
    want = ops.stream_map("stream", jnp.asarray(0.5, dtype), *arrays,
                          impl="jnp")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-6)


@given(rows=st.integers(min_value=1, max_value=64),
       block=st.sampled_from([1, 2, 4, 8]),
       name=st.sampled_from(sorted(MAP_CASES)))
@settings(max_examples=25, deadline=None)
def test_map_shape_sweep(rows, block, name):
    if rows % block:
        rows = block * max(1, rows // block)
    n = rows * 128
    arrays = _arrays(MAP_CASES[name], n, jnp.float32, seed=rows)
    s = _scalar(name)
    got = ops.stream_map(name, s, *arrays, impl="interpret")
    want = ops.stream_map(name, s, *arrays, impl="jnp")
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_non_multiple_of_lanes_raises():
    with pytest.raises(ValueError, match="multiple"):
        from repro.kernels.stream import map_stream
        map_stream("dcopy", jnp.asarray(0.0), jnp.ones(100))
