"""Paper Fig. 6: bandwidth share per kernel on a fully populated domain.

Three pairings (DCOPY+DDOT2, JacobiL3-v1+DDOT1, STREAM+JacobiL2-v1) on all
four architectures.  For every split (n_I, n_t - n_I) we report the model's
per-core bandwidth for both kernels, the total, and the queue-simulator
measurement with its relative deviation.
"""

from __future__ import annotations

import time

from repro.core import memsim, sharing, table2

PAIRINGS = [("DCOPY", "DDOT2"), ("JacobiL3-v1", "DDOT1"),
            ("STREAM", "JacobiL2-v1")]
DOMAIN = {"BDW-1": 10, "BDW-2": 18, "CLX": 20, "ROME": 8}


def rows():
    out = []
    for arch, n_dom in DOMAIN.items():
        for ka, kb in PAIRINGS:
            a, b = table2.kernel(ka), table2.kernel(kb)
            t0 = time.perf_counter()
            worst = 0.0
            for na in range(1, n_dom):
                nb = n_dom - na
                pred = sharing.pair(a, b, arch, na, nb, utilization="queue")
                sim = memsim.simulate(
                    [sharing.Group.of(a, arch, na),
                     sharing.Group.of(b, arch, nb)], n_events=20_000)
                for i, n in ((0, na), (1, nb)):
                    err = abs(sim[i] / n - pred.bw_per_core[i]) \
                        / pred.bw_per_core[i]
                    worst = max(worst, err)
            us = (time.perf_counter() - t0) * 1e6 / (n_dom - 1)
            mid = sharing.pair(a, b, arch, n_dom // 2, n_dom - n_dom // 2,
                               utilization="queue")
            out.append((
                f"fig6/{arch}/{ka}+{kb}", us,
                f"bw_core=({mid.bw_per_core[0]:.2f},{mid.bw_per_core[1]:.2f})"
                f";total={mid.total_bw:.1f};max_err={worst*100:.1f}%"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
