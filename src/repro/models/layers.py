"""Shared neural building blocks (pure-functional JAX).

Params are plain nested dicts of jax.Array.  Every function takes
``cfg: ModelConfig`` for dtype/architecture switches.  Compute runs in
``cfg.dtype`` (bf16 by default) with f32 norms/softmax accumulations;
params are stored in ``cfg.param_dtype``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops

# --------------------------------------------------------------------------
# Init helpers
# --------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = (d_in ** -0.5) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32)).astype(dtype)


def remat(cfg: ModelConfig, fn, static_argnums=()):
    """Apply the configured rematerialization policy to a layer body."""
    if not cfg.remat:
        return fn
    policy = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[cfg.remat_policy]
    return jax.checkpoint(fn, policy=policy, static_argnums=static_argnums)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def norm_params(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    p = {"w": jnp.ones((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "rmsnorm":
        impl = cfg.kernels if cfg.kernels != "pallas" else "pallas"
        return ops.rmsnorm(x, p["w"], impl=impl).astype(x.dtype)
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    y = y * p["w"].astype(jnp.float32) + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope_freqs(cfg: ModelConfig, positions: jax.Array) -> tuple[jax.Array,
                                                                jax.Array]:
    """positions: (...,) int -> cos/sin of shape (..., head_dim//2)."""
    hd = cfg.head_dim_
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32)
                                    / hd))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D) with cos/sin (..., S, D//2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------


def attention_params(cfg: ModelConfig, key, d_model: int | None = None):
    d = d_model or cfg.d_model
    hd = cfg.head_dim_
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], d, cfg.kv_heads * hd, dt),
        "wv": dense_init(ks[2], d, cfg.kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.kv_heads * hd,), dt)
    return p


def _project_qkv(cfg: ModelConfig, p, x):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return (q.reshape(b, s, cfg.n_heads, hd),
            k.reshape(b, s, cfg.kv_heads, hd),
            v.reshape(b, s, cfg.kv_heads, hd))


CHUNK_Q = 2048   # q-block size of the chunked-attention path


def _sdpa_block(qf, kf, vf, *, scale, q0, k0, causal, local_window):
    """One q-block against one kv-slice.  qf: (B,bq,KV,g,hd);
    kf/vf: (B,bk,KV,hd).  q0/k0: global offsets.

    MXU-style mixed precision: operands stay in their storage dtype (bf16
    in production) and only the dot ACCUMULATORS are f32
    (preferred_element_type) — softmax statistics in f32, probabilities
    stored back in the storage dtype.  This halves the HBM traffic of the
    two big attention tensors vs. upcasting everything.
    """
    bq, bk = qf.shape[1], kf.shape[1]
    logits = jnp.einsum("bskgd,btkd->bkgst", qf, kf,
                        preferred_element_type=jnp.float32) * scale
    qpos = q0 + jnp.arange(bq)[:, None]
    kpos = k0 + jnp.arange(bk)[None, :]
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= qpos >= kpos
    if local_window:
        mask &= kpos > qpos - local_window
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(qf.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", probs, vf,
                      preferred_element_type=jnp.float32).astype(qf.dtype)


def _sdpa(cfg: ModelConfig, q, k, v, *, causal: bool,
          local_window: int = 0, cross: bool = False) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,T,KV,hd) -> (B,S,H,hd).

    GQA without materializing repeated KV: reshape H -> (KV, group).

    For long sequences the computation is CHUNKED over q blocks with static
    causal kv-prefix slices (python-unrolled): flash-attention's memory
    behavior expressed in pure jnp, so the dry-run roofline sees O(S·bq)
    temporaries and the exact causal flop count — and XLA's cost analysis
    accounts every block (no while-loop undercount).  On real TPU hardware
    cfg.kernels="pallas" swaps in the true flash kernel.
    """
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    group = h // kv
    scale = hd ** -0.5
    qf = q.reshape(b, s, kv, group, hd)
    kf, vf = k, v
    offset = t - s if (causal and not cross) else 0

    if s <= CHUNK_Q:
        out = _sdpa_block(qf, kf, vf, scale=scale, q0=offset, k0=0,
                          causal=causal and not cross,
                          local_window=local_window)
        return out.reshape(b, s, h, hd).astype(q.dtype)

    blocks = []
    bq = CHUNK_Q
    for i in range(0, s, bq):
        q0 = i + offset
        qb = qf[:, i:i + bq]
        if causal and not cross:
            hi = min(q0 + qb.shape[1], t)          # causal prefix
            lo = max(0, q0 - local_window + 1) if local_window else 0
            lo = (lo // bq) * bq                   # keep slices aligned
        else:
            lo, hi = 0, t
        out = _sdpa_block(qb, kf[:, lo:hi], vf[:, lo:hi], scale=scale,
                          q0=q0, k0=lo, causal=causal and not cross,
                          local_window=local_window)
        blocks.append(out)
    out = jnp.concatenate(blocks, axis=1)
    return out.reshape(b, s, h, hd).astype(q.dtype)


def attention(cfg: ModelConfig, p, x, positions, *, causal=True,
              local_window=0):
    """Full self-attention over x: (B, S, D)."""
    b, s, d = x.shape
    q, k, v = _project_qkv(cfg, p, x)
    cos, sin = rope_freqs(cfg, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if cfg.kernels in ("pallas", "interpret") and causal and not local_window:
        out = ops.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=True,
                            impl=cfg.kernels).transpose(0, 2, 1, 3)
    else:
        out = _sdpa(cfg, q, k, v, causal=causal, local_window=local_window)
    return out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)


def attention_decode(cfg: ModelConfig, p, x, cache_k, cache_v, pos, *,
                     local_window: int = 0):
    """One-token decode.  x: (B, 1, D); caches (B, S, KV, hd); pos (B,).

    Returns (out (B,1,D), new_k, new_v)."""
    b = x.shape[0]
    hd = cfg.head_dim_
    q, k, v = _project_qkv(cfg, p, x)           # (B,1,H/KV,hd)
    cos, sin = rope_freqs(cfg, pos[:, None])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    s_cache = cache_k.shape[1]
    if local_window and local_window < s_cache:
        # Ring buffer for local attention: write at pos % window.
        slot = (pos % local_window)
    else:
        slot = pos
    # In-place single-row write per sequence (vs. a full-cache select,
    # which would charge 2x the cache size to HBM every step).
    upd = jax.vmap(
        lambda c, row, p: jax.lax.dynamic_update_slice_in_dim(
            c, row, p, axis=0))
    cache_k = upd(cache_k, k, slot)
    cache_v = upd(cache_v, v, slot)

    q_ = q.transpose(0, 2, 1, 3).reshape(b, cfg.n_heads, hd)
    k_ = cache_k.transpose(0, 2, 1, 3)           # (B,KV,S,hd)
    v_ = cache_v.transpose(0, 2, 1, 3)
    if local_window and local_window < s_cache:
        lengths = jnp.minimum(pos + 1, local_window).astype(jnp.int32)
        # Ring buffer valid region is [0, min(pos+1, window)); RoPE encodes
        # absolute positions so attention content is position-correct.
        out = ops.decode_attention(q_, k_, v_, lengths,
                                   impl=cfg.kernels)
    else:
        out = ops.decode_attention(q_, k_, v_, (pos + 1).astype(jnp.int32),
                                   impl=cfg.kernels)
    out = out.reshape(b, 1, cfg.n_heads * hd)
    return out @ p["wo"].astype(x.dtype), cache_k, cache_v


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------


def mlp_params(cfg: ModelConfig, key, d_ff: int | None = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {"wi": dense_init(ks[0], d, ff, dt),
                "wg": dense_init(ks[1], d, ff, dt),
                "wo": dense_init(ks[2], ff, d, dt)}
    return {"wi": dense_init(ks[0], d, ff, dt),
            "wo": dense_init(ks[2], ff, d, dt)}


def apply_mlp(cfg: ModelConfig, p, x):
    h = x @ p["wi"].astype(x.dtype)
    if cfg.act == "swiglu":
        g = x @ p["wg"].astype(x.dtype)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    elif cfg.act == "geglu":
        g = x @ p["wg"].astype(x.dtype)
        h = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * h
    elif cfg.act == "sq_relu":
        r = jnp.maximum(h.astype(jnp.float32), 0.0)
        h = (r * r).astype(x.dtype)
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(cfg.act)
    return h @ p["wo"].astype(x.dtype)


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def unembed(cfg: ModelConfig, emb_or_w, x):
    w = emb_or_w.astype(x.dtype)
    logits = x @ (w.T if w.shape[0] == cfg.vocab else w)
    return softcap(logits.astype(jnp.float32), cfg.logits_softcap)
