"""Compiled execution plans + the backend substrate.

Acceptance gates of the plan PR: ``compile(x).run()`` must be
bit-for-bit ``predict(x)`` / ``simulate(x)`` across backends; re-running
a plan with swapped ``(f, b_s)`` / ``cores`` must match a fresh compile
of the modified scenarios; same-bucket plans must share jitted solvers
through the substrate's process-wide cache; and the ``auto`` cutoff /
chunking knobs must be honored everywhere.  Works with real hypothesis
or the deterministic fallback shim.
"""

import random

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import api
from repro.core import backend, sharing, table2

BACKENDS = ["numpy"] + (["jax"] if backend.HAVE_JAX else [])
KERNELS = sorted(table2.TABLE2)
UTILS = ["recursion", "queue", 0.7]

kernel_names = st.sampled_from(KERNELS)
archs = st.sampled_from(table2.ARCHS)
utils = st.sampled_from(UTILS)


def _scenario_from(arch, util, ks, ns):
    sc = api.Scenario.on(arch).options(utilization=util)
    for k, n in zip(ks, ns):
        sc = sc.run(k, n)
    return sc


def _sweep_batch(b, arch="CLX", **options):
    base = api.Scenario.on(arch, **options).run("DCOPY", 1).run("DDOT2", 1)
    na = 1 + np.arange(b) % 19
    return base.batch(np.stack([na, 20 - na], axis=-1))


# ---------------------------------------------------------------------------
# compile(x).run() == predict(x), bit for bit
# ---------------------------------------------------------------------------


@settings(max_examples=80, deadline=None)
@given(arch=archs, util=utils,
       ks=st.lists(kernel_names, min_size=1, max_size=5),
       seed=st.integers(min_value=0, max_value=10**6))
def test_scalar_plan_bit_for_bit(arch, util, ks, seed):
    rng = random.Random(seed)
    ns = [rng.randint(0, 12) for _ in ks]
    sc = _scenario_from(arch, util, ks, ns)
    plan = api.compile(sc, verb="predict")
    assert isinstance(plan, api.ScalarPlan)
    assert plan.kind == "scalar"
    ref = api.predict(sc)
    got = plan.run()
    assert got == ref
    assert plan.run() == ref  # re-running re-executes, identically


@settings(max_examples=30, deadline=None)
@given(util=utils, seed=st.integers(min_value=0, max_value=10**6))
def test_placed_plan_bit_for_bit(util, seed):
    rng = random.Random(seed)
    from repro.core import topology
    topo = topology.preset("CLX-2S")
    sc = (api.Scenario.on("CLX").using(topo)
          .options(utilization=util, strict=False))
    for _ in range(rng.randint(1, 5)):
        sc = sc.placed(rng.choice(KERNELS), rng.randint(1, 3),
                       rng.choice(topo.domain_names))
    plan = api.compile(sc, verb="predict")
    assert isinstance(plan, api.PlacedPlan)
    assert plan.run() == api.predict(sc)


@settings(max_examples=20, deadline=None)
@given(util=utils, seed=st.integers(min_value=0, max_value=10**6),
       b=st.integers(min_value=1, max_value=12))
def test_batch_plan_bit_for_bit(util, seed, b):
    rng = random.Random(seed)
    scens = []
    for _ in range(b):
        g = rng.randint(1, 4)
        ks = [rng.choice(KERNELS) for _ in range(g)]
        ns = [rng.randint(0, 12) for _ in range(g)]
        scens.append(_scenario_from("CLX", util, ks, ns))
    batch = api.ScenarioBatch.of(scens)
    plan = api.compile(batch, verb="predict")
    assert isinstance(plan, api.BatchPlan)
    for bk in BACKENDS:
        ref = api.predict(batch, backend=bk)
        got = plan.run(backend=bk)
        assert got.engine == ref.engine == bk
        np.testing.assert_array_equal(got.bw_group, ref.bw_group)
        np.testing.assert_array_equal(got.alphas, ref.alphas)
        np.testing.assert_array_equal(got.b_overlap, ref.b_overlap)
        for i in range(b):
            assert got[i] == ref[i]


def test_predict_is_compile_and_run_sugar():
    batch = _sweep_batch(8)
    assert api.predict(batch).engine == api.compile(batch).engine
    sc = api.Scenario.on("CLX").run("DCOPY", 4)
    assert api.compile(sc).run() == api.predict(sc)


# ---------------------------------------------------------------------------
# Swapped numbers == fresh compile
# ---------------------------------------------------------------------------


def test_swap_f_bs_matches_fresh_compile():
    plan = api.compile(_sweep_batch(12))
    f2 = plan.f * 0.9
    bs2 = plan.bs * 1.15
    for bk in BACKENDS:
        got = plan.run(f=f2, b_s=bs2, backend=bk)
        ref = sharing.solve_batch(plan.n, f2, bs2, backend=bk)
        np.testing.assert_array_equal(got.bw_group, ref.bw_group)
        np.testing.assert_array_equal(got.alphas, ref.alphas)
        np.testing.assert_array_equal(got.b_overlap, ref.b_overlap)
    # And against a genuinely re-built scenario batch (synthetic specs
    # carrying the swapped numbers).
    scens = [api.Scenario.on("CLX")
             .run((f2[i, 0], bs2[i, 0]), int(plan.n[i, 0]))
             .run((f2[i, 1], bs2[i, 1]), int(plan.n[i, 1]))
             for i in range(len(plan))]
    fresh = api.predict(api.ScenarioBatch.of(scens), backend="numpy")
    np.testing.assert_array_equal(
        plan.run(f=f2, b_s=bs2, backend="numpy").bw_group, fresh.bw_group)


def test_swap_cores_matches_fresh_compile():
    base = api.Scenario.on("CLX").run("DCOPY", 1).run("DDOT2", 1)
    plan = api.compile(base.batch(np.stack(
        [1 + np.arange(10), 11 - np.arange(10)], axis=-1)))
    counts2 = np.stack([2 + np.arange(10), 12 - np.arange(10)], axis=-1)
    got = plan.run(cores=counts2, backend="numpy")
    ref = api.predict(base.batch(counts2), backend="numpy")
    np.testing.assert_array_equal(got.bw_group, ref.bw_group)
    for i in range(10):
        assert got[i] == ref[i]


def test_scalar_plan_swaps():
    sc = api.Scenario.on("CLX").run("DCOPY", 6).run("DDOT2", 6)
    plan = api.compile(sc)
    got = plan.run(cores=[4, 8])
    ref = api.predict(api.Scenario.on("CLX").run("DCOPY", 4)
                      .run("DDOT2", 8))
    assert got.bw_group == ref.bw_group
    got2 = plan.run(f=[0.3, 0.4], b_s=[100.0, 90.0])
    ref2 = api.predict(api.Scenario.on("CLX")
                       .run((0.3, 100.0), 6).run((0.4, 90.0), 6))
    assert got2.bw_group == ref2.bw_group


def test_swap_shape_errors():
    plan = api.compile(_sweep_batch(6))
    with pytest.raises(ValueError, match="broadcastable"):
        plan.run(f=np.ones((3, 5)))
    sc_plan = api.compile(api.Scenario.on("CLX").run("DCOPY", 4))
    with pytest.raises(ValueError, match="1 groups"):
        sc_plan.run(cores=[1, 2, 3])


# ---------------------------------------------------------------------------
# compile(x).run() == simulate(x)
# ---------------------------------------------------------------------------


def _sim_scenario():
    MB = 1e6
    return (api.Scenario.on("CLX").ranks(6)
            .with_noise(6e-5, seed=0, ensemble=4)
            .step("Schoenauer", 8 * MB, tag="symgs")
            .step("DDOT2", 2 * MB, tag="ddot2")
            .barrier()
            .step("DAXPY", 6 * MB, tag="daxpy"))


@pytest.mark.parametrize("bk", BACKENDS)
def test_simulate_plan_bit_for_bit(bk):
    sc = _sim_scenario()
    plan = api.compile(sc)           # noise/programs => simulate inferred
    assert isinstance(plan, api.SimulatePlan)
    assert plan.kind == "simulate"
    ref = api.simulate(sc, t_max=60.0, backend=bk)
    got = plan.run(t_max=60.0, backend=bk)
    assert got.engine == ref.engine == f"desync-{bk}"
    assert got.n_scenarios == ref.n_scenarios == 4
    for b in range(4):
        assert got.records(b) == ref.records(b)
    np.testing.assert_array_equal(got.t_end, ref.t_end)
    # The trace froze the noise draws: re-running is deterministic.
    again = plan.run(t_max=60.0, backend=bk)
    for b in range(4):
        assert again.records(b) == got.records(b)


def test_group_mode_compiles_to_simulate_on_request():
    sc = (api.Scenario.on("CLX")
          .run("DCOPY", 2, bytes=1e6).run("DDOT2", 2, bytes=1e6))
    plan = api.compile(sc, verb="simulate")
    ref = api.simulate(sc)
    assert plan.run().records(0) == ref.records(0)
    # Without a verb, group mode means predict.
    assert isinstance(api.compile(sc), api.ScalarPlan)
    # ...but declared noise means simulate — for single scenarios AND
    # batches (a noisy batch must not silently drop its noise).
    noisy = sc.with_noise(5e-5, seed=3)
    assert isinstance(api.compile(noisy), api.SimulatePlan)
    nb = api.ScenarioBatch.of([noisy, sc.with_noise(5e-5, seed=4)])
    assert isinstance(api.compile(nb), api.SimulatePlan)


def test_simulate_plan_swap_specs():
    MB = 1e6
    sc = (api.Scenario.on("CLX").ranks(4)
          .with_noise(5e-5, seed=2, ensemble=2)
          .step((0.3, 100.0), 4 * MB, name="phase")
          .step("DDOT2", MB))
    plan = api.compile(sc)
    got = plan.run(specs={"phase": (0.5, 80.0)})
    sc2 = (api.Scenario.on("CLX").ranks(4)
           .with_noise(5e-5, seed=2, ensemble=2)
           .step((0.5, 80.0), 4 * MB, name="phase")
           .step("DDOT2", MB))
    ref = api.simulate(sc2)
    for b in range(2):
        assert got.records(b) == ref.records(b)
    # A typo'd kernel name must not become a silent no-op swap.
    with pytest.raises(KeyError, match="did you mean 'phase'"):
        plan.run(specs={"phse": (0.5, 80.0)})


def test_simulate_batch_must_be_rectangular():
    a = api.Scenario.on("CLX").ranks(8).step("DCOPY", 4e6)
    b = api.Scenario.on("CLX").ranks(4).step("DCOPY", 4e6)
    with pytest.raises(ValueError, match="rectangular"):
        api.simulate(api.ScenarioBatch.of([a, b]))
    with pytest.raises(ValueError, match="rectangular"):
        api.compile(api.ScenarioBatch.of([b, a]), verb="simulate")


def test_simulate_mixed_t_max_raises_at_run_without_override():
    a = api.Scenario.on("CLX").ranks(2).step("DCOPY", 1e6)
    b = a.options(t_max=1.0)
    plan = api.compile(api.ScenarioBatch.of([a, b]), verb="simulate")
    with pytest.raises(ValueError, match="t_max"):
        plan.run()
    assert plan.run(t_max=5.0).n_scenarios == 2


# ---------------------------------------------------------------------------
# Deterministic splittable seeds
# ---------------------------------------------------------------------------


def test_member_seed_streams_are_independent():
    # The old convention Random(seed + member) aliased adjacent
    # ensembles: (0, 1) and (1, 0) shared a stream.  The split must not.
    assert api.derive_member_seed(0, 1) != api.derive_member_seed(1, 0)
    seen = {api.derive_member_seed(s, m)
            for s in range(8) for m in range(64)}
    assert len(seen) == 8 * 64


def test_repeated_simulate_is_reproducible():
    sc = (api.Scenario.on("CLX").ranks(3).step("DCOPY", 1e6)
          .with_noise(1e-5, seed=7, ensemble=5))
    r1 = api.simulate(sc)
    r2 = api.simulate(sc)
    np.testing.assert_array_equal(r1.t_end, r2.t_end)
    # Different base seeds give different draws.
    r3 = api.simulate(sc.with_noise(1e-5, seed=8, ensemble=5))
    assert not np.array_equal(r1.t_end, r3.t_end)


# ---------------------------------------------------------------------------
# Substrate: resolve policy, cutoff knob, jit cache, chunking
# ---------------------------------------------------------------------------


def test_resolve_explicit_backends():
    assert backend.resolve("numpy") == "numpy"
    assert backend.resolve("auto", 4, prefer="numpy") == "numpy"
    with pytest.raises(ValueError, match="unknown backend"):
        backend.resolve("bogus")
    if backend.HAVE_JAX:
        assert backend.resolve("jax") == "jax"
        assert backend.resolve("auto", None) == "jax"
    else:
        with pytest.raises(RuntimeError, match="jax"):
            backend.resolve("jax")
        assert backend.resolve("auto", None) == "numpy"


def test_cutoff_env_and_override(monkeypatch):
    monkeypatch.delenv(backend.JAX_CUTOFF_ENV, raising=False)
    assert backend.jax_cutoff() == backend.DEFAULT_JAX_CUTOFF
    monkeypatch.setenv(backend.JAX_CUTOFF_ENV, "4")
    assert backend.jax_cutoff() == 4
    assert backend.jax_cutoff(16) == 16           # per-call wins over env
    if backend.HAVE_JAX:
        assert backend.resolve("auto", 8) == "jax"
        assert backend.resolve("auto", 8, jax_cutoff=16) == "numpy"
    monkeypatch.setenv(backend.JAX_CUTOFF_ENV, "not-a-number")
    with pytest.raises(ValueError, match="REPRO_JAX_CUTOFF"):
        backend.jax_cutoff()


@pytest.mark.skipif(not backend.HAVE_JAX, reason="jax not importable")
def test_cutoff_honored_by_facade_and_solvers(monkeypatch):
    batch = _sweep_batch(8)
    assert api.predict(batch).engine == "numpy"            # below 64
    assert api.predict(batch, jax_cutoff=4).engine == "jax"
    monkeypatch.setenv(backend.JAX_CUTOFF_ENV, "4")
    assert api.predict(batch).engine == "jax"
    monkeypatch.delenv(backend.JAX_CUTOFF_ENV)
    # Scenario-level knob flows through compile — and survives a run
    # that re-resolves (backend="auto" must not discard it).
    small = _sweep_batch(8, jax_cutoff=2)
    plan = api.compile(small)
    assert plan.engine == "jax"
    assert plan.run(backend="auto").engine == "jax"
    # Placed scenarios honor the knob too (their topology solve is a
    # batched solve_batch call like any other).
    placed = (api.Scenario.on("CLX").using("CLX-2S")
              .placed("DCOPY", 4, "CLX/s0/d0"))
    ref = api.predict(placed)
    got = api.predict(placed, jax_cutoff=1)
    assert got.bw_group == pytest.approx(ref.bw_group, rel=1e-9)
    pplan = api.compile(placed.options(jax_cutoff=1, chunk=4))
    assert pplan.solver_kwargs["jax_cutoff"] == 1
    assert pplan.solver_kwargs["chunk"] == 4
    # And the pre-facade batched paths resolve through the same policy.
    assert sharing.resolve_backend("auto", 8) == "numpy"
    assert sharing.resolve_backend("auto", 8, jax_cutoff=2) == "jax"
    from repro.calibrate import fit as fit_mod
    from repro.calibrate.traces import synthesize_scaling_trace
    traces = [synthesize_scaling_trace(k, "CLX", seed=0)
              for k in ("DCOPY", "DDOT2")]
    assert fit_mod.fit_scaling(traces).backend == "numpy"   # 2 < 64
    assert fit_mod.fit_scaling(traces, jax_cutoff=1).backend == "jax"


@pytest.mark.skipif(not backend.HAVE_JAX, reason="jax not importable")
def test_jit_cache_shared_across_same_bucket_plans():
    # B = 130 and B = 200 both pad into the 256-row bucket (G = 2,
    # same n_max bucket), so the second plan's run must reuse the
    # first's compiled solver: hits grow, misses don't.
    p1 = api.compile(_sweep_batch(130))
    p1.run(backend="jax")
    assert p1.bucket == (256, 2)
    s1 = backend.cache_stats()
    p2 = api.compile(_sweep_batch(200))
    assert p2.bucket == p1.bucket
    p2.run(backend="jax")
    s2 = backend.cache_stats()
    assert s2["misses"] == s1["misses"]
    assert s2["hits"] == s1["hits"] + 1
    ref = api.predict(_sweep_batch(200), backend="jax")
    np.testing.assert_array_equal(
        p2.run(backend="jax").bw_group, ref.bw_group)


def _placed_sweep(b, *, ragged=False, arch="CLX", **options):
    """B placed scenarios on CLX-2S; ``ragged=True`` varies the
    per-scenario group count (1–2 per domain) without changing the
    padded grid bucket."""
    scens = []
    for i in range(b):
        sc = (api.Scenario.on(arch, **options).using("CLX-2S")
              .placed("DCOPY", 1 + i % 8, "CLX/s0/d0")
              .placed("DDOT2", 1 + (i * 3) % 8, "CLX/s1/d0"))
        if not ragged or i % 2:
            sc = sc.placed("DAXPY", 1 + i % 4, "CLX/s0/d0")
        scens.append(sc)
    return api.ScenarioBatch.of(scens)


@pytest.mark.skipif(not backend.HAVE_JAX, reason="jax not importable")
def test_jit_cache_shared_across_placement_axis_buckets():
    # Two placed batches of different raggedness flatten to (B·D, K)
    # rows that pad into one bucket — the second run must reuse the
    # first's compiled solver through the substrate cache.
    p1 = api.compile(_placed_sweep(70, ragged=True))
    assert isinstance(p1, api.PlacedBatchPlan)
    p1.run(backend="jax")
    # B·D = 70·2 = 140 -> 256-row bucket; K = 2 groups per domain max.
    assert p1.bucket == (256, 2)
    s1 = backend.cache_stats()
    p2 = api.compile(_placed_sweep(100))
    assert p2.bucket == p1.bucket
    p2.run(backend="jax")
    s2 = backend.cache_stats()
    assert s2["misses"] == s1["misses"]
    assert s2["hits"] == s1["hits"] + 1
    # And an *unplaced* batch of the same flattened bucket (256 rows,
    # 2 groups, same n_max bucket of 16) shares the very same compiled
    # solver: placement adds no cache axis.
    base = api.Scenario.on("CLX").run("DCOPY", 1).run("DDOT2", 1)
    na = 1 + np.arange(150) % 8
    unplaced = api.compile(base.batch(np.stack(
        [na, np.full_like(na, 8)], axis=-1)))
    assert unplaced.bucket == (256, 2)
    s3 = backend.cache_stats()
    unplaced.run(backend="jax")
    s4 = backend.cache_stats()
    assert s4["misses"] == s3["misses"]


def test_placed_batch_plan_bit_for_bit():
    batch = _placed_sweep(9, ragged=True)
    plan = api.compile(batch)
    assert plan.kind == "placed-batch"
    res = plan.run(backend="numpy")
    for i, sc in enumerate(batch.scenarios):
        assert res[i] == api.predict(sc, backend="numpy")
    # run() == predict(batch), and re-running is deterministic.
    again = api.predict(batch, backend="numpy")
    for i in range(len(batch)):
        assert again[i] == res[i]


def test_placed_batch_plan_swaps():
    batch = _placed_sweep(6)
    plan = api.compile(batch)
    ref = plan.run(backend="numpy")
    got = plan.run(f=0.4, backend="numpy")
    assert all(g.f == 0.4 for g in got[0].groups)
    assert got[0] != ref[0]
    # Swapping the placement re-packs on the same topology without
    # re-tracing: moving every group to one domain matches a fresh
    # compile of so-placed scenarios.
    from repro.core.topology import Placed
    moved = [[Placed(p.group, "CLX/s1/d0") for p in row]
             for row in batch.placements]
    got2 = plan.run(placement=moved, backend="numpy")
    fresh = api.ScenarioBatch.of([
        api.Scenario.on("CLX").using("CLX-2S").options(strict=False)
        .placed("DCOPY", sc.runs[0].n, "CLX/s1/d0")
        .placed("DDOT2", sc.runs[1].n, "CLX/s1/d0")
        .placed("DAXPY", sc.runs[2].n, "CLX/s1/d0")
        for sc in batch.scenarios])
    # strict differs between plan (True) and fresh batch; capacity
    # holds here, so numbers must agree exactly.
    ref2 = api.predict(fresh, backend="numpy")
    for i in range(len(batch)):
        assert got2[i].bw_group == ref2[i].bw_group
    with pytest.raises(ValueError, match="scenarios for the plan's"):
        plan.run(placement=moved[:2])


def test_fused_ensemble_seed_stability():
    # Pinned member results: the fused batch×ensemble path must keep
    # every (scenario, member) row bit-identical to the explicit
    # cross-product the known-issues doc used to prescribe (one
    # single-scenario ensemble simulate per batch row).
    scens = [(api.Scenario.on("CLX").ranks(3)
              .step("DCOPY", 1e6 * (i + 1), tag="w")
              .barrier()
              .with_noise(2e-5, seed=11 + i, ensemble=3))
             for i in range(3)]
    fused = api.simulate(api.ScenarioBatch.of(scens))
    assert fused.n_scenarios == 9
    for i, sc in enumerate(scens):
        solo = api.simulate(sc)          # explicit cross-product row
        rows = fused.rows_for(i)
        assert len(rows) == 3
        for m, b in enumerate(rows):
            assert solo.records(m) == fused.records(b)
            assert solo.t_end[m] == fused.t_end[b]


def test_chunked_solve_bit_for_bit(monkeypatch):
    rng = np.random.default_rng(5)
    n = rng.integers(0, 12, size=(23, 3)).astype(float)
    f = rng.uniform(0.05, 1.0, size=(23, 3))
    bs = rng.uniform(50, 200, size=(23, 3))
    for bk in BACKENDS:
        ref = sharing.solve_batch(n, f, bs, backend=bk)
        got = sharing.solve_batch(n, f, bs, backend=bk, chunk=7)
        np.testing.assert_array_equal(got.bw_group, ref.bw_group)
        np.testing.assert_array_equal(got.b_overlap, ref.b_overlap)
        np.testing.assert_array_equal(got.util, ref.util)
    monkeypatch.setenv(backend.CHUNK_ENV, "5")
    got = sharing.solve_batch(n, f, bs, backend="numpy")
    ref2 = sharing.solve_batch(n, f, bs, backend="numpy", chunk=1000)
    np.testing.assert_array_equal(got.bw_group, ref2.bw_group)


def test_chunked_plan_run_bit_for_bit():
    plan = api.compile(_sweep_batch(40))
    ref = plan.run(backend="numpy")
    got = plan.run(backend="numpy", chunk=16)
    np.testing.assert_array_equal(got.bw_group, ref.bw_group)
    # Scenario-level chunk option compiles into the plan.
    chunky = api.compile(_sweep_batch(40, chunk=8))
    np.testing.assert_array_equal(chunky.run(backend="numpy").bw_group,
                                  ref.bw_group)


def test_bucket_and_pad_rows():
    assert [backend.bucket(x) for x in (1, 2, 3, 64, 65, 200)] == \
        [1, 2, 4, 64, 128, 256]
    a = np.arange(6, dtype=float).reshape(3, 2)
    padded = backend.pad_rows(a, 8)
    assert padded.shape == (8, 2)
    np.testing.assert_array_equal(padded[:3], a)
    assert padded[3:].sum() == 0.0
    assert backend.pad_rows(a, 3) is a
    with pytest.raises(ValueError, match="cannot pad"):
        backend.pad_rows(a, 2)


def test_exactly_one_resolution_implementation():
    """No HAVE_JAX dispatch forks outside core/backend.py: the probe is
    defined exactly once, and every `backend == "auto"` decision routes
    through repro.core.backend.resolve."""
    import pathlib
    src = pathlib.Path(sharing.__file__).resolve().parent.parent
    offenders = []
    for path in src.rglob("*.py"):
        text = path.read_text()
        if path.name == "backend.py":
            continue
        if "HAVE_JAX = " in text:
            offenders.append(f"{path.name}: defines HAVE_JAX")
        if 'backend = "jax" if' in text or "'jax' if HAVE_JAX" in text \
                or '"jax" if HAVE_JAX' in text:
            offenders.append(f"{path.name}: private auto-dispatch fork")
    assert not offenders, offenders
