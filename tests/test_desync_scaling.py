"""Scaling-benchmark entry points: fast smoke tests for the default CI
job, and the headline B=256 speedup measurement under the ``slow``
marker (run by the dedicated ``-m slow`` CI job, which also regenerates
the full-grid BENCH_desync.json artifact once — see
.github/workflows/ci.yml)."""

import json
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks import desync_scaling  # noqa: E402


def test_quick_grid_smoke():
    """The reduced grid runs, counts events, and shows a batching win."""
    grid = desync_scaling.run_grid(quick=True)
    assert grid["benchmark"] == "desync_scaling"
    for entry in grid["rank_sweep"] + grid["scenario_sweep"]:
        assert entry["events"] == entry["B"] * entry["R"] * 5
        assert entry["events_per_s"] > 0
    sp = grid["speedup"]
    assert sp["batched"]["events"] == sp["sequential"]["events"]
    # Smoke-level only: batching must not *lose* to sequential runs even
    # on a loaded CI box (the real >= 10x bar lives in the slow test and
    # the committed artifact, where timing noise is acceptable context).
    assert sp["x"] > 1.0


def test_rows_for_benchmark_driver():
    rows = desync_scaling.rows()
    assert any("speedup" in name for name, _, _ in rows)
    for name, us, derived in rows:
        assert name.startswith("desync_scaling/")
        assert us >= 0


def test_committed_bench_artifact_records_speedup_target():
    """The committed perf-trajectory artifact covers the required grid
    and demonstrates the >= 10x acceptance criterion."""
    grid = json.loads((REPO / "BENCH_desync.json").read_text())
    assert [e["R"] for e in grid["rank_sweep"]] == [8, 64, 512]
    assert [e["B"] for e in grid["scenario_sweep"]] == [1, 32, 256]
    assert grid["speedup"]["B"] == 256 and grid["speedup"]["R"] == 64
    assert grid["speedup"]["x"] >= 10.0


@pytest.mark.slow
def test_full_scale_ensemble_meets_speedup_target():
    """Acceptance criterion, measured live: the B=256, R=64 ensemble
    completes >= 10x faster than 256 sequential scalar runs.  (Only the
    headline legs run here; the full grid runs once in the CI artifact
    step.)"""
    seq = desync_scaling.measure_sequential(256, 64)
    bat = desync_scaling.measure_batched(256, 64)
    assert bat["events"] == seq["events"]
    assert seq["wall_s"] / bat["wall_s"] >= 10.0
