"""Benchmark-trend gate: validate every committed BENCH_*.json.

The repo commits one JSON artifact per benchmark (BENCH_placement.json,
BENCH_plan.json, ...).  Each artifact already records whether its own
acceptance bounds held when it was produced; this checker re-reads the
committed files and fails CI if

* any artifact with an ``ok`` flag says ``false`` (a regression was
  committed), or
* a tracked *headline metric* slipped below its floor — the floors are
  restated here so a benchmark that silently relaxed its own bound
  still trips the gate, or
* an expected artifact is missing or unparseable.

``python benchmarks/trend.py`` prints one line per check and exits
nonzero on the first failure (after printing all of them).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

#: (artifact, dotted path into the JSON, comparator, floor/ceiling).
#: Comparators: ">=" metric must stay at or above, "<" strictly below.
HEADLINES = [
    ("BENCH_placement.json", "results.speedup_vs_percall", ">=", 10.0),
    ("BENCH_placement.json", "results.jit_cache.hit_rate", ">=", 1.0),
    ("BENCH_plan.json", "results.speedup_vs_percall", ">=", 5.0),
    ("BENCH_calibrate.json", "max_f_err", "<", 0.08),
    ("BENCH_calibrate.json", "max_bs_err", "<", 0.08),
    ("BENCH_calibrate.json", "max_pair_err", "<", 0.08),
    ("BENCH_desync.json", "speedup.x", ">=", 5.0),
    ("BENCH_obs.json", "results.disabled_overhead_frac", "<", 0.02),
    ("BENCH_obs.json", "results.enabled_overhead_frac", "<", 0.10),
    ("BENCH_analysis.json", "max_f_err", "<", 0.15),
    ("BENCH_analysis.json", "lint.diagnostics", "<", 1),
    ("BENCH_serve.json", "results.speedup_c64", ">=", 5.0),
    ("BENCH_serve.json", "results.plan_cache.hit_rate", ">=", 1.0),
]

#: Artifacts whose top-level ``ok`` flag must be true.
OK_FLAGGED = ("BENCH_analysis.json", "BENCH_api.json",
              "BENCH_calibrate.json", "BENCH_grad.json", "BENCH_obs.json",
              "BENCH_placement.json", "BENCH_plan.json",
              "BENCH_serve.json")


def _dig(obj, path: str):
    for part in path.split("."):
        if not isinstance(obj, dict) or part not in obj:
            return None
        obj = obj[part]
    return obj


def check_dir(root: str) -> list[tuple[str, bool]]:
    """One (message, passed) row per check, in declaration order."""
    rows: list[tuple[str, bool]] = []
    cache: dict[str, dict | None] = {}

    def load(name: str):
        if name not in cache:
            path = os.path.join(root, name)
            try:
                with open(path) as fh:
                    cache[name] = json.load(fh)
            except (OSError, ValueError):
                cache[name] = None
        return cache[name]

    for name in OK_FLAGGED:
        doc = load(name)
        if doc is None:
            rows.append((f"{name}: missing or unparseable", False))
        else:
            ok = doc.get("ok") is True
            rows.append((f"{name}: ok={doc.get('ok')}", ok))

    for name, path, op, bound in HEADLINES:
        doc = load(name)
        val = _dig(doc, path) if doc is not None else None
        if not isinstance(val, (int, float)):
            rows.append((f"{name}: {path} missing", False))
            continue
        passed = val >= bound if op == ">=" else val < bound
        rows.append((f"{name}: {path}={val:g} {op} {bound:g}", passed))
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default=".",
                    help="directory holding the BENCH_*.json artifacts")
    args = ap.parse_args(argv)
    rows = check_dir(args.dir)
    n_fail = 0
    for msg, passed in rows:
        print(("PASS " if passed else "FAIL ") + msg)
        n_fail += not passed
    print(f"{len(rows) - n_fail}/{len(rows)} benchmark trend checks passed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
