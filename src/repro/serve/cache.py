"""The serving plan cache: compiled plans kept hot across requests.

One level above the substrate's jit cache: where
:func:`repro.core.backend.jitted` caches compiled *solver callables*
per shape bucket, this caches compiled *plans* (trace + pack + backend
resolution + the jitted callable underneath) per scenario structure and
bucket, so a long-running server pays ``api.compile`` once per distinct
request shape.  Hit/miss/eviction counters land in the ``repro.obs``
metrics registry under ``serve.plan.*`` with the same ``key=`` label
convention as the jit cache, and the whole scope is queryable as
``backend.cache_stats(scope="plan")`` (registered at import).
"""

from __future__ import annotations

import threading
import time
import weakref
from collections import OrderedDict
from typing import Callable

from ..core import backend as backend_mod
from ..obs import metrics, trace
from . import keys as keys_mod

_HIT_METRIC = "serve.plan.hit"
_MISS_METRIC = "serve.plan.miss"
_EVICT_METRIC = "serve.plan.evict"
_COMPILE_METRIC = "serve.plan.compile_s"

#: Live caches, for the aggregate ``plan_cache_stats`` scope.
_CACHES: "weakref.WeakSet[PlanCache]" = weakref.WeakSet()


class PlanCache:
    """LRU cache of compiled plans, keyed by structure + bucket.

    Thread-safe get-or-build (builds happen outside the lock; a racing
    duplicate keeps the first insertion, mirroring the jit cache's
    policy — both plans compute the same thing).  ``max_entries``
    bounds memory: least-recently-used entries evict first.
    """

    def __init__(self, max_entries: int = 128):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self._entries: OrderedDict[tuple, object] = OrderedDict()
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        _CACHES.add(self)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get_or_build(self, key: tuple, build: Callable[[], object], *,
                     label: str = "?") -> object:
        """Return the cached plan for ``key``, building (and caching)
        it on first request.  ``label`` is the metrics key label."""
        with self._lock:
            plan = self._entries.get(key)
            if plan is not None:
                self._entries.move_to_end(key)
                self._hits += 1
        if plan is not None:
            metrics.counter(_HIT_METRIC, key=label).inc()
            return plan
        with trace.span("serve.plan.build", key=label):
            t0 = time.perf_counter()
            plan = build()
            dt = time.perf_counter() - t0
        metrics.counter(_MISS_METRIC, key=label).inc()
        metrics.histogram(_COMPILE_METRIC, key=label).observe(dt)
        with self._lock:
            self._misses += 1
            self._entries.setdefault(key, plan)
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self._evictions += 1
                metrics.counter(_EVICT_METRIC).inc()
            return self._entries[key]

    def warmup(self, scenario, *, verb: str | None = None,
               buckets=(1,)) -> int:
        """Precompile the plans that will serve ``scenario``'s structure
        at each batch bucket (each rounded up to a power of two), so the
        first live tick hits.  Returns the number of entries compiled
        (cached buckets count zero)."""
        from .. import api
        if verb is None:
            verb = api.infer_verb(scenario)
        built = 0
        for b in sorted({backend_mod.bucket(b) for b in buckets}):
            sig = keys_mod.group_key(scenario, verb)
            key, rows = keys_mod.plan_entry(verb, sig, b)
            before = self._misses
            self.get_or_build(
                key, lambda: keys_mod.compile_group([scenario], verb, rows),
                label=keys_mod.key_label(verb, scenario, rows))
            built += self._misses - before
            if verb == "simulate":
                break  # bucket-free: one entry serves every batch size
        return built

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def stats(self) -> dict:
        """This cache's counters (process-lifetime hit/miss/eviction
        totals plus current entry count) — the ``/statsz`` payload."""
        with self._lock:
            hits, misses = self._hits, self._misses
            entries, evictions = len(self._entries), self._evictions
        total = hits + misses
        return {
            "hits": hits,
            "misses": misses,
            "entries": entries,
            "max_entries": self.max_entries,
            "evictions": evictions,
            "hit_rate": (hits / total) if total else 0.0,
        }


def plan_cache_stats() -> dict:
    """The ``cache_stats(scope="plan")`` provider: process-wide plan
    cache counters in the jit scope's shape.  Hit/miss totals and the
    per-key ``"buckets"`` breakdown come from the ``serve.plan.*``
    metrics (disjoint from the jit scope's ``backend.jit.*`` counters,
    so ``scope="all"`` never double-counts); ``"entries"`` sums the
    live caches."""
    buckets: dict[str, dict] = {}

    def _bucket(label: str) -> dict:
        return buckets.setdefault(
            label, {"hits": 0, "misses": 0, "compile_s": 0.0})

    hits = misses = evictions = 0
    for row in metrics.snapshot():
        label = row["labels"].get("key")
        if row["name"] == _HIT_METRIC and label is not None:
            _bucket(label)["hits"] = row["value"]
            hits += row["value"]
        elif row["name"] == _MISS_METRIC and label is not None:
            _bucket(label)["misses"] = row["value"]
            misses += row["value"]
        elif row["name"] == _COMPILE_METRIC and label is not None:
            _bucket(label)["compile_s"] = row["sum"]
        elif row["name"] == _EVICT_METRIC:
            evictions += row["value"]
    total = hits + misses
    return {
        "hits": hits,
        "misses": misses,
        "entries": sum(len(c) for c in _CACHES),
        "evictions": evictions,
        "hit_rate": (hits / total) if total else 0.0,
        "buckets": buckets,
    }


backend_mod.register_cache_scope("plan", plan_cache_stats)
