"""AdamW with global-norm clipping — pure pytree functions.

State mirrors the param tree (same shapes/shardings: the optimizer shards
exactly like FSDP params with zero extra code — ZeRO-style by construction).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """One AdamW step.  ``lr`` may be a scalar or a schedule value."""
    step = state.step + 1

    if clip_norm:
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
