"""Benchmark driver: one module per paper table/figure.

Default output is ``name,us_per_call,derived`` CSV on stdout:
    PYTHONPATH=src python -m benchmarks.run [--only fig8]

``--json`` aggregates every module's rows into one machine-readable
report (optionally written to ``--out``); rows are consumed from a
generator module by module, so the working set is one module's rows:
    PYTHONPATH=src python -m benchmarks.run --json --out report.json

``--ndjson`` is the fully streaming form — one JSON line per row,
written as it is produced through the facade's streaming writer
(:func:`repro.api.dump_dicts`), nothing accumulated; the right mode
when the row count is huge or a consumer tails the file live:
    PYTHONPATH=src python -m benchmarks.run --ndjson --out report.ndjson
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

from repro.api import dump_dicts

from . import (analysis_accuracy, api_overhead, calibrate_roundtrip,
               desync_scaling, fig6_full_domain, fig7_symmetric, fig8_error,
               fig9_pairings, grad_calibration, hpcg_desync, obs_overhead,
               placement_scaling, plan_overhead, table2_kernels,
               tpu_overlap)

MODULES = {
    "analysis": analysis_accuracy,
    "table2": table2_kernels,
    "fig6": fig6_full_domain,
    "fig7": fig7_symmetric,
    "fig8": fig8_error,
    "fig9": fig9_pairings,
    "hpcg": hpcg_desync,
    "tpu_overlap": tpu_overlap,
    "desync_scaling": desync_scaling,
    "calibrate": calibrate_roundtrip,
    "api_overhead": api_overhead,
    "plan_overhead": plan_overhead,
    "placement_scaling": placement_scaling,
    "grad": grad_calibration,
    "obs": obs_overhead,
}


def iter_rows(keys, failures: dict[str, str]):
    """Yield ``(module_key, row_dict)`` as modules produce them; a
    module that raises records its traceback in ``failures`` and the
    stream moves on."""
    for key in keys:
        try:
            for name, us, derived in MODULES[key].rows():
                yield key, {"name": name, "us_per_call": round(us, 1),
                            "derived": derived}
        except Exception:  # noqa: BLE001
            failures[key] = traceback.format_exc(limit=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=sorted(MODULES), default=None)
    ap.add_argument("--json", action="store_true",
                    help="emit one aggregated JSON report instead of CSV")
    ap.add_argument("--ndjson", action="store_true",
                    help="stream one JSON line per row as produced "
                         "(never materializes the full row list)")
    ap.add_argument("--out", default=None,
                    help="with --json/--ndjson: write here instead of "
                         "stdout")
    args = ap.parse_args()
    keys = [args.only] if args.only else list(MODULES)

    if args.ndjson:
        failures: dict[str, str] = {}
        rows = ({"module": key, **row}
                for key, row in iter_rows(keys, failures))
        if args.out:
            with open(args.out, "w") as fh:
                n = dump_dicts(rows, fh)
            print(f"wrote {args.out}  (rows={n}, "
                  f"failures={len(failures)})")
        else:
            dump_dicts(rows, sys.stdout)
        for key, tb in failures.items():
            print(f"FAILED {key}: {tb}", file=sys.stderr)
        if failures:
            sys.exit(1)
        return

    if args.json:
        # Modules are atomic in the aggregate report: a module that
        # fails mid-iteration contributes its traceback, never a
        # partial row set that could be mistaken for real results.
        failures = {}
        results: dict[str, list[dict]] = {}
        for key in keys:
            module_failures: dict[str, str] = {}
            rows = [row for _, row in iter_rows([key], module_failures)]
            if module_failures:
                failures.update(module_failures)
            else:
                results[key] = rows
        report = {
            "benchmark": "benchmarks.run",
            "modules": results,
            "failures": failures,
            "n_rows": sum(len(r) for r in results.values()),
            "ok": not failures,
        }
        text = json.dumps(report, indent=2) + "\n"
        if args.out:
            with open(args.out, "w") as fh:
                fh.write(text)
            print(f"wrote {args.out}  (modules={len(results)}, "
                  f"rows={report['n_rows']}, ok={report['ok']})")
        else:
            sys.stdout.write(text)
        if failures:
            sys.exit(1)
        return

    print("name,us_per_call,derived")
    failures = {}
    for key, row in iter_rows(keys, failures):
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        sys.stdout.flush()
    for key, tb in failures.items():
        print(f"{key}/ERROR,0.0,{tb!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
