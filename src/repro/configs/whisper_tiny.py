"""whisper-tiny [audio]: enc-dec, conv frontend STUBBED (input_specs provides
precomputed frame embeddings).  [arXiv:2212.04356; unverified]"""

import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,            # decoder layers
    enc_layers=4,
    d_model=384,
    n_heads=6,
    kv_heads=6,
    d_ff=1536,
    vocab=51865,
    act="gelu",
    norm="layernorm",
    n_audio_frames=1500,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, enc_layers=2, d_model=64, n_heads=2, kv_heads=2,
        d_ff=128, vocab=512, n_audio_frames=64, remat=False, dtype="float32")
