"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the allclose tests (tests/test_kernels_*.py)
and the default compute path of the model zoo (CPU dry-run compiles use
these; the Pallas path is enabled per-config on real TPU hardware).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Table II streaming suite
# --------------------------------------------------------------------------


def vectorsum(a):
    return jnp.sum(a)


def ddot1(a):
    return jnp.sum(a * a)


def ddot2(a, b):
    return jnp.sum(a * b)


def ddot3(a, b, c):
    return jnp.sum(a * b * c)


def dscal(s, a):
    return s * a


def daxpy(s, a, b):
    return a + s * b


def add(a, b):
    return a + b


def stream_triad(s, a, b):
    return a + s * b


def waxpby(r, s, a, b):
    return r * a + s * b


def dcopy(a):
    return a


def schoenauer(a, b, c):
    return a + b * c


# --------------------------------------------------------------------------
# Jacobi stencils
# --------------------------------------------------------------------------


def jacobi_v1(a, s):
    """5-point sweep on the interior; boundary copied through."""
    res = (a[1:-1, :-2] + a[1:-1, 2:] + a[:-2, 1:-1] + a[2:, 1:-1]) * s
    out = a.at[1:-1, 1:-1].set(res)
    return out


def jacobi_v2(a, f, *, ax, ay, b1, relax):
    r1 = (ax * (a[1:-1, :-2] + a[1:-1, 2:])
          + ay * (a[:-2, 1:-1] + a[2:, 1:-1])
          + b1 * a[1:-1, 1:-1] - f[1:-1, 1:-1]) / b1
    out = a.at[1:-1, 1:-1].set(a[1:-1, 1:-1] - relax * r1)
    residual = jnp.sum((r1 * r1).astype(jnp.float32))
    return out, residual


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------


def attention(q, k, v, *, causal=True, scale=None):
    """(B, H, S, D) x (B, KV, S, D) -> (B, H, S, D), GQA by repetition."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    group = h // kv
    scale = (d ** -0.5) if scale is None else scale
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, k.shape[2]), bool))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, scale=None):
    """(B, H, D) x (B, KV, S, D) -> (B, H, D) with per-batch lengths.

    GQA via grouped einsum — the KV cache is NEVER expanded to H heads
    (a jnp.repeat here would double the dominant HBM stream of decode and
    break the cache's sharding under SPMD).
    """
    b, h, d = q.shape
    kv, s = k_cache.shape[1], k_cache.shape[2]
    group = h // kv
    scale = (d ** -0.5) if scale is None else scale
    qg = q.reshape(b, kv, group, d)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(s)[None, None, None, :]
    logits = jnp.where(pos < lengths[:, None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,bksd->bkgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, d).astype(q.dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------


def rmsnorm(x, w, *, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)
            ).astype(x.dtype)


def rmsnorm_residual(x, residual, w, *, eps=1e-6):
    h = x.astype(jnp.float32) + residual.astype(jnp.float32)
    y = rmsnorm(h, w, eps=eps)
    return y.astype(x.dtype), h.astype(x.dtype)
