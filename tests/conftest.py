"""Test-suite bootstrap.

Prefers the real ``hypothesis`` package; in hermetic containers where it is
unavailable (and cannot be installed), registers the deterministic fallback
from ``_hypothesis_fallback.py`` before test modules import it.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401  (real package wins when present)
except ModuleNotFoundError:
    import _hypothesis_fallback

    _hypothesis_fallback.install()
