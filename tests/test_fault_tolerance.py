"""Fault-tolerance integration tests: checkpoint/restart, preemption
recovery, elastic host-count change, straggler policy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data import SyntheticLM
from repro.models import model_for
from repro.optim import constant
from repro.runtime import (SimulatedFailure, init_train_state,
                           run_with_restarts)
from repro.runtime.steps import build_train_step
from repro.runtime.straggler import StragglerMonitor

CFG = configs.get_reduced("qwen2-0.5b")


def _make_state():
    model = model_for(CFG)
    return init_train_state(model, jax.random.key(0))


def _make_step_fn():
    model = model_for(CFG)
    return jax.jit(build_train_step(model, lr_fn=constant(1e-3)))


def _dataset():
    return SyntheticLM(CFG, seq_len=32, global_batch=4)


def test_loop_runs_and_loss_decreases(tmp_path):
    res = run_with_restarts(
        make_state=_make_state, make_step_fn=_make_step_fn,
        dataset=_dataset(), ckpt_dir=str(tmp_path), n_steps=30,
        ckpt_every=10)
    assert res.final_step == 30
    assert len(res.losses) == 30
    # Structured (Markov) data => the model learns something.
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])


def test_preemption_restart_continues_exactly(tmp_path):
    """Crash at step 17; restart must resume from step 10's checkpoint and
    produce the same final state as an uninterrupted run."""
    crashes = {"armed": True}

    def hook(step):
        if step == 17 and crashes["armed"]:
            crashes["armed"] = False
            raise SimulatedFailure("node lost at step 17")

    res = run_with_restarts(
        make_state=_make_state, make_step_fn=_make_step_fn,
        dataset=_dataset(), ckpt_dir=str(tmp_path), n_steps=25,
        ckpt_every=10, failure_hook=hook)
    assert res.restarts == 1
    assert res.restored_from == 10
    assert res.final_step == 25

    # Uninterrupted reference run.
    ref = run_with_restarts(
        make_state=_make_state, make_step_fn=_make_step_fn,
        dataset=_dataset(), ckpt_dir=str(tmp_path) + "_ref", n_steps=25,
        ckpt_every=10)
    # Same last-step losses (determinism through restart).
    assert res.losses[-1] == pytest.approx(ref.losses[-1], rel=1e-4)


def test_too_many_failures_raises(tmp_path):
    def hook(step):
        raise SimulatedFailure("always failing")

    with pytest.raises(SimulatedFailure):
        run_with_restarts(
            make_state=_make_state, make_step_fn=_make_step_fn,
            dataset=_dataset(), ckpt_dir=str(tmp_path), n_steps=10,
            ckpt_every=2, max_restarts=2, failure_hook=hook)


def test_elastic_data_resharding():
    """The same global batch is recoverable under a different host count."""
    ds = SyntheticLM(CFG, seq_len=16, global_batch=8)
    one_host = ds.batch(4, host_index=0, host_count=1)["tokens"]
    two_hosts = np.concatenate([
        ds.batch(4, host_index=0, host_count=2)["tokens"],
        ds.batch(4, host_index=1, host_count=2)["tokens"],
    ])
    # Note: host shards use independent seeds, so content differs, but
    # shapes and determinism per (step, host) hold:
    again = np.concatenate([
        ds.batch(4, host_index=0, host_count=2)["tokens"],
        ds.batch(4, host_index=1, host_count=2)["tokens"],
    ])
    np.testing.assert_array_equal(two_hosts, again)
    assert one_host.shape == (8, 16)
    assert two_hosts.shape == (8, 16)


def test_straggler_monitor_skew_detection():
    mon = StragglerMonitor(n_workers=8, skew_limit=0.5)
    rng = np.random.default_rng(0)
    for _ in range(16):
        base = rng.normal(1.0, 0.01, size=8)
        base[7] += rng.exponential(0.5)          # one chronic straggler
        mon.record(base)
    assert mon.observed_skew > 0.5
    assert mon.should_inject_barrier()


def test_straggler_monitor_balanced_no_barrier():
    mon = StragglerMonitor(n_workers=8, skew_limit=0.5)
    rng = np.random.default_rng(1)
    for _ in range(16):
        mon.record(rng.normal(1.0, 0.01, size=8))
    assert not mon.should_inject_barrier()


def test_straggler_theory_amplification_sign():
    """The paper's dynamical result wired into the policy: a higher-f
    follow-up phase amplifies desync (positive skew of the probe phase's
    accumulated time); a lower-f follow-up damps it."""
    from repro.runtime.straggler import StepPhase

    def phases(f_followup):
        return [
            StepPhase("fwd", bytes_hbm=40e6, f=0.19, bs=800.0),
            StepPhase("probe", bytes_hbm=8e6, f=0.15, bs=800.0),
            StepPhase("grad_io", bytes_hbm=30e6, f=f_followup, bs=800.0),
        ]

    mon = StragglerMonitor(n_workers=20)
    assert mon.predict_amplification(phases(0.9), probe=1) > 0.2
    assert mon.predict_amplification(phases(0.05), probe=1) < -0.2
