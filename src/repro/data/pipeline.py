"""Deterministic synthetic data pipeline with host sharding and prefetch.

Production shape without production storage: every batch is a pure function
of (seed, step, host_index) — fully reproducible across restarts and elastic
reshards (a host that takes over another's shard regenerates identical
data), which is what makes the checkpoint/restart tests exact.

The token stream is a order-2 Markov chain over the vocab (not iid uniform)
so that the LM loss actually *decreases* during the example training runs.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Deterministic synthetic LM dataset."""

    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    markov: bool = True

    def batch(self, step: int, *, host_index: int = 0, host_count: int = 1
              ) -> dict[str, np.ndarray]:
        if self.global_batch % host_count:
            raise ValueError("global_batch must divide host_count")
        local = self.global_batch // host_count
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_index]))
        v = self.cfg.vocab
        if self.markov:
            # Cheap structured stream: x_{t+1} = (a*x_t + b + noise) mod V.
            a = 6364136223846793005 % v or 1
            x = rng.integers(0, v, size=(local, 1))
            noise = rng.integers(0, 17, size=(local, self.seq_len))
            toks = np.empty((local, self.seq_len + 1), np.int64)
            toks[:, 0] = x[:, 0]
            for t in range(self.seq_len):
                toks[:, t + 1] = (toks[:, t] * a + 13 + noise[:, t]) % v
        else:
            toks = rng.integers(0, v, size=(local, self.seq_len + 1))
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if self.cfg.family == "encdec":
            batch["frames"] = rng.standard_normal(
                (local, self.cfg.n_audio_frames, self.cfg.d_model)
            ).astype(np.float32) * 0.1
        if self.cfg.family == "vlm":
            batch["patches"] = rng.standard_normal(
                (local, self.cfg.n_patches, self.cfg.d_model)
            ).astype(np.float32) * 0.1
        return batch


class HostLoader:
    """Iterator over host-local batches with background double-buffering."""

    def __init__(self, dataset: SyntheticLM, *, start_step: int = 0,
                 host_index: int = 0, host_count: int = 1,
                 prefetch: int = 2, shardings=None):
        self.dataset = dataset
        self.host_index = host_index
        self.host_count = host_count
        self.shardings = shardings
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _produce_one(self, step: int):
        batch = self.dataset.batch(step, host_index=self.host_index,
                                   host_count=self.host_count)
        if self.shardings is not None:
            batch = {k: jax.device_put(v, self.shardings.get(k))
                     for k, v in batch.items()}
        return batch

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self._produce_one(step), timeout=0.25)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        item = self._q.get()
        self._step += 1
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for a global batch (used by the dry-run)."""
    b, s = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.n_audio_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    return specs
